"""Multi-device correctness tests.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the flag binds at first jax init, so the main test process can't use it).

Checks:
  * a2a (shard_map expert-parallel) MoE == dense reference on a (2,4) mesh;
  * sharded train step loss == single-device loss for a smoke dense arch;
  * einsum MoE dispatch == dense reference at high capacity.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, timeout=420):
    src = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
        import sys
        sys.path.insert(0, %r)
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_smoke_mesh
        from repro.launch import sharding as shd
        """
        % os.path.join(REPO, "src")
    ) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", src], capture_output=True, text=True, timeout=timeout
    )
    assert proc.returncode == 0, f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-3000:]}"
    return proc.stdout


def test_moe_a2a_matches_dense():
    out = run_sub(
        """
        from repro.configs import get_config
        from repro.models.moe_dispatch import moe_ffn
        from repro.models import init_params

        cfg = get_config("olmoe-1b-7b", smoke=True).replace(capacity_factor=4.0)
        params = init_params(cfg, jax.random.PRNGKey(0))
        layer_moe = jax.tree.map(lambda x: x[0], params["moe_layers"])["moe"]

        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 16, cfg.d_model)).astype(np.float32)) * 0.3

        # dense reference (no mesh)
        shd.set_mesh(None)
        y_ref, aux_ref = moe_ffn(cfg.replace(moe_impl="dense"), layer_moe, x)

        # a2a on a (2,4) mesh, tokens sharded over both axes
        mesh = make_smoke_mesh((2, 4), ("data", "model"))
        shd.set_mesh(mesh, {"expert": ("model",)})
        cfg_a2a = cfg.replace(moe_impl="a2a")

        @jax.jit
        def f(x):
            y, aux = moe_ffn(cfg_a2a, layer_moe, x)
            return y, aux

        y_a2a, aux_a2a = f(x)
        err = float(jnp.max(jnp.abs(y_a2a - y_ref)))
        print("MAXERR", err)
        print("AUXERR", float(jnp.abs(aux_a2a - aux_ref)))
        assert err < 2e-4, err
        """
    )
    assert "MAXERR" in out


def test_moe_einsum_matches_dense():
    out = run_sub(
        """
        from repro.configs import get_config
        from repro.models.moe_dispatch import moe_ffn
        from repro.models import init_params

        cfg = get_config("olmoe-1b-7b", smoke=True).replace(capacity_factor=8.0)
        params = init_params(cfg, jax.random.PRNGKey(0))
        layer_moe = jax.tree.map(lambda x: x[0], params["moe_layers"])["moe"]
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)).astype(np.float32)) * 0.3
        shd.set_mesh(None)
        y_ref, _ = moe_ffn(cfg.replace(moe_impl="dense"), layer_moe, x)
        y_ein, _ = moe_ffn(cfg.replace(moe_impl="einsum"), layer_moe, x)
        err = float(jnp.max(jnp.abs(y_ein - y_ref)))
        print("MAXERR", err)
        assert err < 2e-4, err
        """
    )
    assert "MAXERR" in out


def test_sharded_train_step_matches_single_device():
    out = run_sub(
        """
        from repro.configs import get_config
        from repro.launch.steps import (abstract_params, build_train_step,
                                        batch_pspecs, train_shardings, abstract_opt_state)
        from repro.models import init_params, make_dummy_batch
        from repro.optim import get_optimizer

        cfg = get_config("deepseek-7b", smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = make_dummy_batch(cfg, 8, 32, "train", rng)
        step, opt = build_train_step(cfg)
        opt_state = opt.init(params)

        # single device
        shd.set_mesh(None)
        p1, o1, loss1 = jax.jit(step)(params, opt_state, batch)

        # sharded (2,4)
        mesh = make_smoke_mesh((2, 4), ("data", "model"))
        shd.set_mesh(mesh, {"act_seq": "model"})
        ps, osh, bs = train_shardings(cfg, params, opt_state, batch, 8)
        p2, o2, loss2 = jax.jit(step, in_shardings=(ps, osh, bs),
                                out_shardings=(ps, osh, None))(params, opt_state, batch)
        print("LOSS", float(loss1), float(loss2))
        assert abs(float(loss1) - float(loss2)) < 2e-4
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-4)
        print("PARAMS MATCH")
        """
    )
    assert "PARAMS MATCH" in out


def test_serve_step_sharded_runs():
    out = run_sub(
        """
        from repro.configs import get_config
        from repro.launch.steps import build_serve_step, cache_pspecs, batch_pspecs
        from repro.models import init_cache, init_params
        from repro.launch.sharding import param_pspecs

        cfg = get_config("gemma2-2b", smoke=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        mesh = make_smoke_mesh((2, 4), ("data", "model"))
        shd.set_mesh(mesh)
        B, S = 8, 64
        cache = init_cache(cfg, B, S)
        step = build_serve_step(cfg)
        ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                       is_leaf=lambda x: isinstance(x, P))
        cs = ns(cache_pspecs(cfg, cache, B, S))
        ps = ns(param_pspecs(params))
        tok = jnp.zeros((B, 1), jnp.int32)
        ts = ns(batch_pspecs(cfg, tok, B))
        f = jax.jit(step, in_shardings=(ps, cs, ts, NamedSharding(mesh, P())),
                    out_shardings=(ts, cs))
        nxt, cache = f(params, cache, tok, jnp.asarray(0, jnp.int32))
        nxt2, cache = f(params, cache, nxt, jnp.asarray(1, jnp.int32))
        print("DECODED", np.asarray(nxt2).shape)
        """
    )
    assert "DECODED" in out
