"""Unit + property tests for the core scheduling library.

Paper claims validated here:
  * Theorem 1: the (MC)^2MKP DP is optimal (== brute force).
  * Theorems 2/3/4/5: MarIn/MarCo/MarDecUn/MarDec are optimal on their
    regimes (== DP).
  * Section 5.2: lower-limit removal preserves optimal cost.
  * Section 3.1 insight: OLAR/uniform/greedy are NOT total-cost optimal in
    general (strictly worse on some instance).
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean container: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    ItemClass,
    Problem,
    brute_force_schedule,
    marco,
    mardec,
    mardecun,
    marin,
    olar,
    proportional,
    random_problem,
    random_schedule,
    remove_lower_limits,
    restore_lower_limits,
    schedule,
    select_algorithm,
    solve_mc2mkp,
    solve_schedule_dp,
    solve_schedule_dp_jax,
    total_cost,
    uniform,
    validate_schedule,
)

# ---------------------------------------------------------------------------
# hypothesis strategies
# ---------------------------------------------------------------------------

regimes = st.sampled_from(["arbitrary", "linear", "increasing", "decreasing"])


@st.composite
def instances(draw, regime=None, max_n=5, max_T=14):
    rgm = regime or draw(regimes)
    n = draw(st.integers(1, max_n))
    T = draw(st.integers(max(1, n), max_T))
    seed = draw(st.integers(0, 2**32 - 1))
    rng = np.random.default_rng(seed)
    return random_problem(rng, n=n, T=T, regime=rgm)


# ---------------------------------------------------------------------------
# DP vs brute force (Theorem 1)
# ---------------------------------------------------------------------------


@settings(max_examples=120, deadline=None)
@given(instances())
def test_dp_optimal_vs_brute_force(p):
    dp = solve_schedule_dp(p)
    validate_schedule(p, dp)
    bf = brute_force_schedule(p)
    assert total_cost(p, dp) == pytest.approx(total_cost(p, bf), rel=1e-9)


@settings(max_examples=40, deadline=None)
@given(instances())
def test_jax_dp_matches_numpy_dp(p):
    xj = solve_schedule_dp_jax(p)
    validate_schedule(p, xj)
    assert total_cost(p, xj) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-5)


# ---------------------------------------------------------------------------
# Monotone-regime algorithms vs DP (Theorems 2-5)
# ---------------------------------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(instances(regime="increasing"))
def test_marin_optimal(p):
    x = marin(p)
    validate_schedule(p, x)
    assert total_cost(p, x) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-9)


@settings(max_examples=100, deadline=None)
@given(instances(regime="linear"))
def test_marco_optimal(p):
    x = marco(p)
    validate_schedule(p, x)
    assert total_cost(p, x) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-9)


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 5), st.integers(1, 14), st.integers(0, 2**32 - 1))
def test_mardecun_optimal(n, T, seed):
    rng = np.random.default_rng(seed)
    p = random_problem(rng, n=n, T=T, regime="decreasing", max_upper=T, with_lower=False)
    # force unlimited: U_i = T for all
    tables = tuple(
        np.interp(np.arange(T + 1), np.arange(len(t)), t) if len(t) < T + 1 else t[: T + 1]
        for t in p.cost_tables
    )
    # re-synthesize with proper decreasing tables of full width instead
    from repro.core.costs import sublinear_cost

    tables = tuple(
        sublinear_cost(T, float(rng.uniform(5, 40)), float(rng.uniform(2, 20)), float(rng.uniform(0, 0.2)))
        for _ in range(n)
    )
    p = Problem(T=T, lower=np.zeros(n, int), upper=np.full(n, T), cost_tables=tables)
    x = mardecun(p)
    validate_schedule(p, x)
    assert total_cost(p, x) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-9, abs=1e-9)


@settings(max_examples=100, deadline=None)
@given(instances(regime="decreasing"))
def test_mardec_optimal(p):
    x = mardec(p)
    validate_schedule(p, x)
    assert total_cost(p, x) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-9, abs=1e-9)


# ---------------------------------------------------------------------------
# Lower-limit removal (Section 5.2)
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(instances())
def test_lower_limit_removal_equivalence(p):
    p0 = remove_lower_limits(p)
    p0.validate()
    assert p0.T == p.T - int(p.lower.sum())
    assert np.all(p0.lower == 0)
    x0 = solve_schedule_dp(p0)
    x = restore_lower_limits(p, x0)
    validate_schedule(p, x)
    assert total_cost(p, x) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-9)


# ---------------------------------------------------------------------------
# Baselines: validity everywhere, suboptimality somewhere
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(instances())
def test_baselines_valid(p):
    rng = np.random.default_rng(0)
    for fn in (olar, uniform, proportional):
        validate_schedule(p, fn(p))
    validate_schedule(p, random_schedule(p, rng))


def test_baselines_not_total_cost_optimal():
    """On a decreasing-marginal fleet, consolidation wins; spreading
    baselines must be strictly worse somewhere."""
    rng = np.random.default_rng(7)
    worse = {"olar": False, "uniform": False, "proportional": False}
    for _ in range(50):
        p = random_problem(rng, n=4, T=12, regime="decreasing")
        opt = total_cost(p, solve_schedule_dp(p))
        for name, fn in (("olar", olar), ("uniform", uniform), ("proportional", proportional)):
            if total_cost(p, fn(p)) > opt + 1e-9:
                worse[name] = True
    assert all(worse.values()), worse


# ---------------------------------------------------------------------------
# General (MC)^2MKP (arbitrary weights, partial packing allowed)
# ---------------------------------------------------------------------------


def test_mc2mkp_partial_packing():
    """With arbitrary weights the knapsack may not be fillable; the solver
    must return the minimal-cost MAXIMAL packing (occupancy precedence)."""
    classes = [
        ItemClass(weights=[3, 5], costs=[10.0, 1.0]),
        ItemClass(weights=[4], costs=[2.0]),
    ]
    # capacity 8: 3+4=7 or 5+4=9(too big) -> maximal occupancy 7, cost 12
    sol = solve_mc2mkp(classes, T=8)
    assert sol.used_capacity == 7
    assert sol.total_cost == pytest.approx(12.0)
    # capacity 9: 5+4=9 fills it, cost 3 < alternative 3+4=7
    sol = solve_mc2mkp(classes, T=9)
    assert sol.used_capacity == 9
    assert sol.total_cost == pytest.approx(3.0)


def test_mc2mkp_occupancy_precedence_over_cost():
    """Maximal occupancy has precedence even when a lighter packing is
    cheaper (rule 2a's -y * large-constant term)."""
    classes = [ItemClass(weights=[1, 4], costs=[0.0, 100.0])]
    sol = solve_mc2mkp(classes, T=4)
    assert sol.used_capacity == 4
    assert sol.total_cost == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(instances())
def test_auto_dispatch_is_optimal(p):
    x = schedule(p, "auto")
    validate_schedule(p, x)
    assert total_cost(p, x) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-9, abs=1e-9)


def test_select_algorithm_regimes():
    rng = np.random.default_rng(3)
    assert select_algorithm(random_problem(rng, 4, 10, "increasing")) == "marin"
    p_lin = random_problem(rng, 4, 10, "linear")
    assert select_algorithm(p_lin) in ("marco", "mardecun")
    assert select_algorithm(random_problem(rng, 4, 10, "arbitrary")) == "dp"


# ---------------------------------------------------------------------------
# Beyond-paper: deadline-constrained energy minimization
# ---------------------------------------------------------------------------


def test_schedule_with_deadline():
    from repro.core.scheduler import schedule_with_deadline
    from repro.core.costs import linear_cost

    rng = np.random.default_rng(5)
    n, T = 4, 20
    p = random_problem(rng, n=n, T=T, regime="increasing")
    # time ~ j / speed, speeds differ
    speeds = rng.uniform(0.5, 3.0, size=n)
    times = [np.arange(int(u) + 1) / s for u, s in zip(p.upper, speeds)]

    # loose deadline: same optimum as unconstrained
    x_loose = schedule_with_deadline(p, times, deadline=1e9)
    assert total_cost(p, x_loose) == pytest.approx(total_cost(p, solve_schedule_dp(p)), rel=1e-9)

    # binding deadline: valid, respects per-device time, >= unconstrained cost
    dl = max(float(times[i][int(x_loose[i])]) for i in range(n)) * 0.9 + 1e-9
    try:
        x_tight = schedule_with_deadline(p, times, deadline=dl)
    except ValueError:
        return  # infeasible at this T - acceptable outcome for random case
    validate_schedule(p, x_tight)
    for i in range(n):
        assert times[i][int(x_tight[i])] <= dl + 1e-12
    assert total_cost(p, x_tight) >= total_cost(p, x_loose) - 1e-9


def test_schedule_with_deadline_infeasible():
    from repro.core.scheduler import schedule_with_deadline

    rng = np.random.default_rng(6)
    p = random_problem(rng, n=3, T=10, regime="linear")
    times = [np.arange(int(u) + 1) * 1.0 for u in p.upper]
    with pytest.raises(ValueError):
        schedule_with_deadline(p, times, deadline=0.5)  # < 1 batch anywhere


# ---------------------------------------------------------------------------
# General (MC)^2MKP with ARBITRARY item weights vs brute force (the paper's
# full Definition 2 generality, not just the scheduling specialization)
# ---------------------------------------------------------------------------


def _brute_force_mc2mkp(classes, T):
    import itertools

    best = (-1, float("inf"))  # (occupancy, cost) with occupancy precedence
    for combo in itertools.product(*[range(len(c.weights)) for c in classes]):
        w = sum(int(c.weights[j]) for c, j in zip(classes, combo))
        cost = sum(float(c.costs[j]) for c, j in zip(classes, combo))
        if w > T:
            continue
        if w > best[0] or (w == best[0] and cost < best[1]):
            best = (w, cost)
    return best


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 4), st.integers(1, 20), st.integers(0, 2**32 - 1))
def test_general_mc2mkp_vs_brute_force(n, T, seed):
    rng = np.random.default_rng(seed)
    classes = []
    for _ in range(n):
        m = int(rng.integers(1, 5))
        weights = rng.integers(0, T + 3, size=m)
        costs = rng.uniform(0, 10, size=m)
        classes.append(ItemClass(weights=weights, costs=costs))
    want_w, want_c = _brute_force_mc2mkp(classes, T)
    if want_w < 0:
        return  # no feasible packing; solver raises - separately covered
    sol = solve_mc2mkp(classes, T)
    assert sol.used_capacity == want_w
    assert sol.total_cost == pytest.approx(want_c, rel=1e-9, abs=1e-9)
