"""Hierarchical fleet scheduling (PR 8, DESIGN.md §16).

Covers the two-level decomposition in ``repro.core.fleet``:

- exactness: singleton clusters and/or quantum=1 reproduce the flat DP
  objective exactly (the decomposition's only loss is intra-cluster
  quantization);
- certified gap: for random small fleets the clustered objective stays
  within the self-reported ``gap_bound`` of the flat DP optimum, and never
  beats it (the flat DP is optimal);
- determinism: k-means labels are a pure function of (problem, seed) with
  canonical first-appearance numbering;
- ring sharding: the class-axis ring DP is bit-identical to the unsharded
  fused DP on a forced-8-device host (subprocess, same pattern as
  test_sweep_engine.py);
- PlanPolicy: FederatedServer legacy kwargs are warn-once shims that are
  bit-identical to the policy= spelling, and fleet-mode round planning
  goes through Solver.solve_fleet.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - clean container
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    Problem,
    Solver,
    SweepEngine,
    cluster_clients,
    random_problem,
    solve_fleet,
    total_cost,
    validate_schedule,
)
from repro.core._deprecation import reset_deprecation_warnings
from repro.core.fleet import PlanPolicy

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _quiet_shims():
    reset_deprecation_warnings()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield
    reset_deprecation_warnings()


def _flat_objective(problem: Problem, engine: SweepEngine) -> float:
    sol = Solver(engine=engine).solve([problem], algorithm="dp_batch")
    return float(sol.objectives[0])


def _rand(seed: int, n: int, T: int, regime: str = "arbitrary") -> Problem:
    return random_problem(np.random.default_rng(seed), n=n, T=T, regime=regime)


# ---------------------------------------------------------------------------
# exactness
# ---------------------------------------------------------------------------


def test_singleton_clusters_match_flat_dp_exactly():
    p = _rand(0, n=16, T=40)
    eng = SweepEngine()
    fsol = solve_fleet(p, engine=eng, clusters=16, quantum=1)
    assert fsol.num_clusters == 16
    assert fsol.gap_bound <= 1e-6
    flat = _flat_objective(p, eng)
    assert fsol.objective == pytest.approx(flat, rel=1e-9)
    validate_schedule(p, np.asarray(fsol.schedule))
    assert int(np.sum(fsol.schedule)) == p.T


@pytest.mark.parametrize("k", [2, 4, 7])
def test_quantum_one_is_exact_for_any_clustering(k):
    p = _rand(k, n=24, T=60)
    eng = SweepEngine()
    fsol = solve_fleet(p, engine=eng, clusters=k, quantum=1)
    flat = _flat_objective(p, eng)
    assert fsol.quantum == 1
    assert fsol.objective == pytest.approx(flat, rel=1e-9)
    assert fsol.gap_bound <= 1e-6
    validate_schedule(p, np.asarray(fsol.schedule))


# ---------------------------------------------------------------------------
# certified gap vs flat DP (hypothesis parity sweep, n <= 64)
# ---------------------------------------------------------------------------


@st.composite
def _fleet_cases(draw):
    return (
        draw(st.integers(min_value=0, max_value=10_000)),  # seed
        draw(st.integers(min_value=4, max_value=24)),  # n
        draw(st.integers(min_value=1, max_value=6)),  # k
        draw(st.integers(min_value=1, max_value=4)),  # q
        draw(st.sampled_from(["arbitrary", "increasing", "decreasing", "linear"])),
    )


@settings(max_examples=12, deadline=None)
@given(_fleet_cases())
def test_fleet_within_certified_gap_of_flat_dp(case):
    seed, n, k, q, regime = case
    p = _rand(seed, n=n, T=max(2 * n, 12), regime=regime)
    eng = SweepEngine()
    fsol = solve_fleet(p, engine=eng, clusters=min(k, n), quantum=q)
    flat = _flat_objective(p, eng)
    scale = max(abs(flat), 1.0)
    # flat DP is optimal: the decomposition can never beat it
    assert fsol.objective >= flat - 1e-6 * scale
    # ... and stays within its own certified bound
    assert fsol.objective <= flat * (1.0 + fsol.gap_bound) + 1e-6 * scale
    X = np.asarray(fsol.schedule)
    validate_schedule(p, X)
    assert int(X.sum()) == p.T
    assert fsol.objective == pytest.approx(total_cost(p, X), rel=1e-9)


def test_auto_parameters_and_solver_facade_agree():
    p = _rand(3, n=36, T=90)
    eng = SweepEngine()
    via_solver = Solver(engine=eng).solve_fleet(p)
    direct = solve_fleet(p, engine=SweepEngine())
    assert via_solver.objective == pytest.approx(direct.objective, rel=1e-12)
    assert np.array_equal(via_solver.schedule, direct.schedule)
    assert via_solver.num_clusters == max(1, round(np.sqrt(36)))


def test_solve_fleet_via_policy_defaults():
    p = _rand(9, n=20, T=50)
    pol = PlanPolicy(fleet_clusters=5, fleet_quantum=2, fleet_seed=7)
    a = Solver(engine=SweepEngine()).solve_fleet(p, policy=pol)
    b = solve_fleet(p, engine=SweepEngine(), clusters=5, quantum=2, seed=7)
    assert a.objective == pytest.approx(b.objective, rel=1e-12)
    assert np.array_equal(a.schedule, b.schedule)


# ---------------------------------------------------------------------------
# k-means determinism
# ---------------------------------------------------------------------------


def test_cluster_labels_deterministic_and_canonical():
    p = _rand(11, n=40, T=100)
    l1 = cluster_clients(p, clusters=6, seed=3)
    l2 = cluster_clients(p, clusters=6, seed=3)
    assert np.array_equal(l1, l2)
    # first-appearance canonical numbering: labels appear in increasing order
    seen = []
    for lab in l1:
        if lab not in seen:
            seen.append(int(lab))
    assert seen == sorted(seen) and seen[0] == 0
    # identity labels when k == n
    ident = cluster_clients(p, clusters=40, seed=3)
    assert np.array_equal(ident, np.arange(40))


def test_fleet_solution_deterministic_under_fixed_seed():
    p = _rand(21, n=48, T=120)
    a = solve_fleet(p, engine=SweepEngine(), seed=5)
    b = solve_fleet(p, engine=SweepEngine(), seed=5)
    assert np.array_equal(a.schedule, b.schedule)
    assert np.array_equal(a.labels, b.labels)
    assert a.objective == b.objective and a.gap_bound == b.gap_bound


# ---------------------------------------------------------------------------
# serve-layer front-end
# ---------------------------------------------------------------------------


def test_service_submit_fleet_matches_engine_path():
    from repro.serve import SchedulerService

    p = _rand(17, n=18, T=44)
    with SchedulerService(max_batch=16, max_delay_s=0.001) as svc:
        fut = svc.submit_fleet(p, clusters=4, quantum=2)
        fsol = fut.result(timeout=120)
        assert fut.done()
    ref = solve_fleet(p, engine=SweepEngine(), clusters=4, quantum=2)
    assert fsol.objective == pytest.approx(ref.objective, rel=1e-9)
    assert np.array_equal(fsol.schedule, ref.schedule)


# ---------------------------------------------------------------------------
# PlanPolicy: legacy FederatedServer kwargs are bit-identical warn-once shims
# ---------------------------------------------------------------------------


def _make_server(**kwargs):
    import jax.numpy as jnp

    from repro.fl import EnergyEstimator, FederatedServer, make_fleet
    from repro.optim.optimizers import sgd

    est = EnergyEstimator(make_fleet(np.random.default_rng(0), 6))
    est.calibrate(np.random.default_rng(1))
    loss = lambda params, batch: jnp.mean((params["w"] - batch) ** 2)  # noqa: E731
    return FederatedServer(loss, {"w": jnp.ones(())}, sgd(1e-2), est, **kwargs)


def test_legacy_server_kwargs_bit_identical_to_policy():
    s_old = _make_server(round_T=12, algorithm="auto")
    s_new = _make_server(policy=PlanPolicy(round_T=12, algorithm="auto"))
    po, pn = s_old.plan_round(0, 12), s_new.plan_round(0, 12)
    assert np.array_equal(po.assignments, pn.assignments)
    assert po.est_cost == pn.est_cost


def test_legacy_server_kwargs_warn_once_per_kwarg():
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        _make_server(round_T=12, algorithm="auto")
        _make_server(round_T=12)  # second use: already warned
    msgs = [str(w.message) for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(msgs) == 2
    assert any("FederatedServer(round_T=...)" in m for m in msgs)
    assert any("FederatedServer(algorithm=...)" in m for m in msgs)
    assert all("PlanPolicy" in m for m in msgs)


def test_policy_and_legacy_kwargs_are_mutually_exclusive():
    with pytest.raises(ValueError, match="not both"):
        _make_server(policy=PlanPolicy(), round_T=5)


def test_fleet_mode_round_plan_is_a_valid_schedule():
    s = _make_server(policy=PlanPolicy(fleet_clusters=3, round_T=12))
    plan = s.plan_round(0, 12)
    assert int(plan.assignments.sum()) == 12
    assert plan.est_cost >= 0.0


def test_plan_policy_validation():
    with pytest.raises(ValueError, match="frontier_mode requires time_tables"):
        PlanPolicy(frontier_mode="knee")


# ---------------------------------------------------------------------------
# class-axis ring sharding: bit-identical on a forced-8-device host
# ---------------------------------------------------------------------------


def test_ring_sharded_dp_bit_identical_forced_8_devices():
    src = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
        )
        import sys
        sys.path.insert(0, %r)
        import numpy as np
        import jax
        from repro.core import (SweepEngine, make_sweep_mesh, random_problem,
                                solve_schedule_dp_batch)

        assert len(jax.devices()) == 8, jax.devices()
        rng = np.random.default_rng(7)
        regimes = ("arbitrary", "linear", "increasing", "decreasing")
        probs = [
            random_problem(rng, n=int(rng.integers(3, 12)), T=int(rng.integers(8, 30)),
                           regime=regimes[b %% len(regimes)])
            for b in range(6)
        ]
        mesh = make_sweep_mesh()
        eng_ring = SweepEngine(ring_mesh=mesh)
        X_ring = eng_ring.solve(probs)
        X_ref = SweepEngine().solve(probs)
        X_un = solve_schedule_dp_batch(probs)
        assert np.array_equal(X_ring, X_ref), "ring-sharded != unsharded"
        assert np.array_equal(X_ring, X_un), "ring-sharded != uncached"

        # mesh and ring_mesh are mutually exclusive
        try:
            SweepEngine(mesh=mesh, ring_mesh=mesh)
        except ValueError:
            pass
        else:
            raise AssertionError("mesh+ring_mesh should raise")

        # fleet solve riding on the ring engine stays exact at q=1
        from repro.core import Solver
        p = random_problem(np.random.default_rng(3), n=16, T=40)
        fsol = Solver(engine=SweepEngine(ring_mesh=make_sweep_mesh())).solve_fleet(
            p, clusters=4, quantum=1)
        flat = Solver(engine=SweepEngine()).solve([p], algorithm="dp_batch")
        assert abs(fsol.objective - float(flat.objectives[0])) <= 1e-6
        print("RING_OK")
        """
        % os.path.join(REPO, "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", src], capture_output=True, text=True, timeout=420
    )
    assert proc.returncode == 0, (
        f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-3000:]}"
    )
    assert "RING_OK" in proc.stdout
