"""Shape/dtype sweeps for the Pallas min-plus kernel vs the jnp oracle.

The kernel runs in interpret mode (CPU container); the oracle is
``minplus_step_ref``; a hand-rolled numpy triple-check guards the oracle.
"""

import numpy as np
import pytest

from repro.core import Problem, solve_schedule_dp, total_cost
from repro.core.jax_dp import solve_schedule_dp_jax
from repro.kernels import BIG, minplus_pallas, minplus_step_ref


def numpy_minplus(kprev, cost):
    Tp, W = len(kprev), len(cost)
    out = np.full(Tp, float(BIG))
    idx = np.zeros(Tp, dtype=np.int32)
    for t in range(Tp):
        for j in range(min(W, t + 1)):
            v = kprev[t - j] + cost[j]
            v = min(v, float(BIG))
            if v < out[t]:
                out[t] = v
                idx[t] = j
    return out, idx


def random_row(rng, Tp, frac_inf=0.3):
    k = rng.uniform(0, 100, size=Tp).astype(np.float32)
    mask = rng.random(Tp) < frac_inf
    k[mask] = float(BIG)
    k[0] = 0.0
    return k


@pytest.mark.parametrize("Tp", [1, 7, 64, 255, 1024, 1500])
@pytest.mark.parametrize("W", [1, 5, 130, 700])
def test_ref_matches_numpy(Tp, W):
    rng = np.random.default_rng(Tp * 1000 + W)
    kprev = random_row(rng, Tp)
    cost = rng.uniform(0, 10, size=W).astype(np.float32)
    got_v, got_i = minplus_step_ref(kprev, cost)
    want_v, want_i = numpy_minplus(kprev.astype(np.float64), cost.astype(np.float64))
    np.testing.assert_allclose(np.asarray(got_v), want_v, rtol=1e-6)
    # argmin must point at an equally-minimal item (ties may differ)
    chosen = kprev[np.maximum(np.arange(Tp) - np.asarray(got_i), 0)] + cost[np.asarray(got_i)]
    chosen = np.minimum(chosen, float(BIG))
    np.testing.assert_allclose(chosen, want_v, rtol=1e-6)


@pytest.mark.parametrize("Tp,W,BT", [
    (64, 16, 32),
    (255, 64, 64),
    (1024, 256, 256),
    (1000, 511, 128),
    pytest.param(2048, 1024, 1024, marks=pytest.mark.slow),  # big interpret-mode sweep
    (33, 33, 1024),  # tile larger than the row
])
def test_pallas_matches_ref(Tp, W, BT):
    rng = np.random.default_rng(Tp + W + BT)
    kprev = random_row(rng, Tp)
    cost = rng.uniform(0, 10, size=W).astype(np.float32)
    cost[W // 2 :] += np.where(rng.random(W - W // 2) < 0.2, float(BIG), 0.0).astype(np.float32)
    cost = np.minimum(cost, float(BIG))
    ref_v, _ = minplus_step_ref(kprev, cost)
    pal_v, pal_i = minplus_pallas(kprev, cost, BT=BT, interpret=True)
    np.testing.assert_allclose(np.asarray(pal_v), np.asarray(ref_v), rtol=1e-6)
    # argmin consistency: reconstruct value from index
    pi = np.asarray(pal_i)
    src = np.arange(Tp) - pi
    ok = src >= 0
    recon = np.where(ok, kprev[np.maximum(src, 0)] + cost[pi], float(BIG))
    recon = np.minimum(recon, float(BIG))
    np.testing.assert_allclose(recon, np.asarray(ref_v), rtol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.int32])
def test_pallas_dtype_coercion(dtype):
    rng = np.random.default_rng(0)
    kprev = rng.integers(0, 50, size=128).astype(dtype)
    cost = rng.integers(0, 9, size=32).astype(dtype)
    ref_v, _ = minplus_step_ref(kprev.astype(np.float32), cost.astype(np.float32))
    pal_v, _ = minplus_pallas(kprev, cost, BT=64, interpret=True)
    np.testing.assert_allclose(np.asarray(pal_v), np.asarray(ref_v), rtol=1e-6)


def test_dp_via_pallas_backend_end_to_end():
    """Full scheduling DP with the Pallas kernel == numpy DP."""
    rng = np.random.default_rng(42)
    from repro.core import random_problem

    for regime in ("arbitrary", "decreasing", "increasing"):
        p = random_problem(rng, n=5, T=40, regime=regime)
        x_pal = solve_schedule_dp_jax(p, backend="pallas")
        x_np = solve_schedule_dp(p)
        assert total_cost(p, x_pal) == pytest.approx(total_cost(p, x_np), rel=1e-5)
