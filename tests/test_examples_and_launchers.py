"""Smoke tests: every example script and launcher runs end-to-end."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def run(args, timeout=420):
    proc = subprocess.run(
        [sys.executable] + args, capture_output=True, text=True, timeout=timeout,
        cwd=REPO, env=ENV,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-2500:]}"
    return proc.stdout


def test_quickstart():
    out = run(["examples/quickstart.py"])
    assert "energy saved vs uniform" in out


def test_carbon_aware():
    out = run(["examples/carbon_aware.py"])
    assert "emissions reduced" in out


def test_heterogeneous_cluster():
    out = run(["examples/heterogeneous_cluster.py"])
    assert "per-step energy saved" in out


def test_fl_energy_training_short():
    out = run(["examples/fl_energy_training.py", "--rounds", "3", "--clients", "3",
               "--layers", "1", "--d-model", "64", "--compare"])
    assert "energy:" in out and "saved" in out


def test_train_launcher():
    out = run(["-m", "repro.launch.train", "--arch", "deepseek-7b", "--rounds", "2",
               "--clients", "3", "--seq", "16", "--max-batches", "4"])
    assert "total_energy_J" in out


def test_serve_launcher():
    out = run(["-m", "repro.launch.serve", "--arch", "gemma2-2b", "--batch", "2",
               "--prompt-len", "8", "--gen", "4"])
    assert "decode" in out
