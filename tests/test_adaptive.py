"""Adaptive planning under drift (DESIGN.md §18).

The adaptive contract: the calibrator survives adversarial telemetry, the
drift detector and reliability scores are pure functions of (seed,
telemetry) — identical serial vs pipelined and across kill/resume — and
speculative planning commits in-band rounds with ZERO extra engine
dispatches while drifted rounds fall back to a fresh solve. With the
policy defaults everything here is inert and campaigns stay byte-identical
to the pre-adaptive loop (asserted in tests/test_faults.py et al.).
"""

import math

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean container: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core.sweep import SweepEngine
from repro.data import client_corpora, make_lm_examples
from repro.fl import (
    ClientFault,
    DriftDetector,
    DriftPlan,
    EnergyEstimator,
    FaultPlan,
    FederatedServer,
    PlanPolicy,
    RoundFaults,
    make_fleet,
    run_campaign,
    watermark_split,
)
from repro.fl.toy import make_tiny_lm
from repro.optim import sgd

VOCAB = 64
DIM = 16
SEQ = 8

tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)

ADAPTIVE_POLICY = dict(
    lookahead=3, drift_tolerance=0.1, watermark_quantile=0.5, reliability=0.25
)


def _build(seed=0, n_clients=5, engine=None, policy_kwargs=None):
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n_clients, max_batches=8)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, n_clients, 400, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    T = sum(d.max_batches for d in fleet) // 2
    policy = PlanPolicy(
        engine=engine if engine is not None else SweepEngine(),
        **(policy_kwargs or {}),
    )
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(seed)),
        client_optimizer=sgd(0.3),
        estimator=est,
        policy=policy,
    )
    return server, examples, rng, T


def _assert_histories_equal(a, b):
    assert len(a.rounds) == len(b.rounds)
    for ra, rb in zip(a.rounds, b.rounds):
        np.testing.assert_array_equal(ra.assignments, rb.assignments)
        assert ra.mean_loss == rb.mean_loss
        assert ra.energy_joules == rb.energy_joules
        assert ra.estimated_joules == rb.estimated_joules
        da = None if ra.adaptive is None else ra.adaptive.as_dict()
        db = None if rb.adaptive is None else rb.adaptive.as_dict()
        assert da == db
    np.testing.assert_array_equal(a.losses, b.losses)
    assert a.total_energy == b.total_energy
    assert a.adaptive_stats == b.adaptive_stats


def _assert_params_equal(pa, pb):
    for x, y in zip(jax.tree_util.tree_leaves(pa), jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# the calibrator: robust observe + persistence
# ---------------------------------------------------------------------------


def _estimator(seed=0, n=4, **kwargs):
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n, max_batches=8)
    est = EnergyEstimator(fleet, **kwargs)
    est.calibrate(rng)
    return est, rng


def test_observe_survives_adversarial_spikes():
    """Regression: a NaN / inf / negative / 1000x telemetry spike must not
    corrupt the table (the pre-PR-10 plain EMA halved or 500x'd EVERY entry
    on one bad packet)."""
    est, _ = _estimator(seed=1)
    before = est._tables[0].copy()
    for bad in (float("nan"), float("inf"), -5.0, 0.0):
        est.observe(0, 3, bad)
        np.testing.assert_array_equal(est._tables[0], before)
    assert est._dropped == 4
    # a finite 1000x spike: huber attenuation + factor clip bound the damage
    est.observe(0, 3, float(before[3]) * 1000.0)
    after = est._tables[0]
    assert np.all(np.isfinite(after))
    assert float(after[3] / before[3]) <= est.clip + 1e-12
    # ...and the estimate recovers after a few sane observations
    for _ in range(8):
        est.observe(0, 3, float(before[3]))
    assert abs(float(est._tables[0][3]) - float(before[3])) / float(before[3]) < 0.25


def test_observe_in_band_is_bit_identical_to_legacy_ema():
    """In-band (|z| <= huber_delta) observations take the EXACT pre-PR-10
    EMA step — robustness must not perturb the calibrated steady state."""
    est_new, _ = _estimator(seed=2)
    legacy = [t.copy() for t in est_new._tables]
    ema = est_new.ema
    rng = np.random.default_rng(7)
    for _ in range(20):
        i = int(rng.integers(0, len(legacy)))
        j = int(rng.integers(1, len(legacy[i])))
        m = float(legacy[i][j]) * float(1.0 + 0.1 * rng.uniform(-1, 1))
        est_new.observe(i, j, m)
        blended = (1 - ema) * legacy[i][j] + ema * m
        legacy[i] = legacy[i] * (blended / legacy[i][j])
    for a, b in zip(est_new._tables, legacy):
        np.testing.assert_array_equal(a, b)


def test_state_dict_roundtrip_and_legacy_layout():
    est, rng = _estimator(seed=3)
    for _ in range(12):
        i = int(rng.integers(0, 4))
        dev = est.fleet[i]
        j = int(rng.integers(1, dev.max_batches + 1))
        est.observe(i, j, dev.measure(j, rng))
    est.record_round_outcome([0, 1, 2], faulty=[2])
    state = est.state_dict()
    # table keys keep the pre-PR-10 npz layout bit-compatible
    for i in range(4):
        np.testing.assert_array_equal(state[f"{i:04d}"], est._tables[i])

    est2, _ = _estimator(seed=99)
    est2.load_state_dict(state)
    for a, b in zip(est2._tables, est._tables):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(est2._trend, est._trend)
    np.testing.assert_array_equal(est2._reliability, est._reliability)
    assert est2._point_stats == est._point_stats
    assert est2._dropped == est._dropped

    # a legacy checkpoint (tables only) loads with fresh calibration state
    est3, _ = _estimator(seed=99)
    est3.load_state_dict({f"{i:04d}": est._tables[i] for i in range(4)})
    for a, b in zip(est3._tables, est._tables):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(est3._reliability, np.ones(4))
    assert est3._point_stats == {}


def test_reliability_downweights_planning_problem_only():
    est, _ = _estimator(seed=4)
    T = sum(d.max_batches for d in est.fleet) // 2
    baseline = est.problem(T)
    truth_before = est.true_problem(T)
    for _ in range(6):
        est.record_round_outcome([0, 1, 2, 3], faulty=[1])
    w = est.reliability_weights()
    assert w[1] < 1.0 and all(w[i] == 1.0 for i in (0, 2, 3))
    p = est.problem(T, reliability=w)
    p.validate()
    assert p.upper[1] < baseline.upper[1]
    assert len(p.cost_tables[1]) == p.upper[1] + 1
    # the flaky client's table PREFIX is untouched — only capacity shrinks
    np.testing.assert_array_equal(
        p.cost_tables[1], baseline.cost_tables[1][: p.upper[1] + 1]
    )
    # ...and the TRUE simulator tables never move
    truth_after = est.true_problem(T)
    np.testing.assert_array_equal(truth_after.upper, truth_before.upper)
    for a, b in zip(truth_after.cost_tables, truth_before.cost_tables):
        np.testing.assert_array_equal(a, b)


def test_predict_problem_extrapolates_trend():
    est, _ = _estimator(seed=5)
    dev = est.fleet[0]
    # steady +10% drift: the trend EWMA learns a factor > 1
    for _ in range(10):
        est.observe(0, dev.max_batches, float(est._tables[0][dev.max_batches]) * 1.1)
    assert est._trend[0] > 1.0
    T = sum(d.max_batches for d in est.fleet) // 2
    p0, p2 = est.problem(T), est.predict_problem(T, steps=2)
    np.testing.assert_array_equal(p0.upper, p2.upper)
    np.testing.assert_allclose(
        p2.cost_tables[0], p0.cost_tables[0] * est._trend[0] ** 2
    )
    # steps=0 is exactly the current snapshot
    for a, b in zip(est.predict_problem(T, steps=0).cost_tables, p0.cost_tables):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# drift plan + detector: deterministic pure functions of (seed, telemetry)
# ---------------------------------------------------------------------------


def test_drift_plan_generation_is_deterministic():
    kw = dict(num_rounds=8, n_clients=6, p_event=0.5)
    a = DriftPlan.generate(11, **kw)
    b = DriftPlan.generate(11, **kw)
    np.testing.assert_array_equal(a.scales, b.scales)
    assert a.events == b.events and a.events
    assert not np.array_equal(a.scales, DriftPlan.generate(12, **kw).scales)
    assert (a.scales > 0).all()


def test_drift_detector_flags_step_and_stays_quiet_in_band():
    det = DriftDetector(tolerance=0.1)
    rng = np.random.default_rng(0)
    for _ in range(30):  # calibrated noise well inside the tolerance
        assert not det.update(float(rng.normal(0.0, 0.01)))
    assert det.alarms == 0
    flagged = [det.update(0.3) for _ in range(5)]  # a 30% cost step
    assert any(flagged)
    assert det.alarms >= 1


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_drift_detector_is_a_pure_function_of_telemetry(seed):
    """Same telemetry -> same classifications and same final state, and a
    state round-trip mid-stream continues identically (the kill/resume and
    serial-vs-pipelined guarantee, distilled)."""
    rng = np.random.default_rng(seed)
    signal = [float(v) for v in rng.normal(0.0, 0.08, size=40)]
    a, b = DriftDetector(tolerance=0.1), DriftDetector(tolerance=0.1)
    out_a = [a.update(v) for v in signal]
    c = DriftDetector(tolerance=0.1)
    out_b = []
    for t, v in enumerate(signal):
        out_b.append(b.update(v))
        if t == len(signal) // 2:  # checkpoint/restore mid-stream
            c.load_state(b.state())
            b = c
    assert out_a == out_b
    assert a.state() == b.state()


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_reliability_scores_are_a_pure_function_of_outcomes(seed):
    rng = np.random.default_rng(seed)
    est_a, _ = _estimator(seed=6)
    est_b, _ = _estimator(seed=6)
    for _ in range(15):
        part = [int(i) for i in np.nonzero(rng.random(4) < 0.8)[0]]
        faulty = [int(i) for i in part if rng.random() < 0.3]
        est_a.record_round_outcome(part, faulty)
        est_b.record_round_outcome(part, faulty)
    np.testing.assert_array_equal(est_a.reliability_scores(), est_b.reliability_scores())
    np.testing.assert_array_equal(
        est_a.reliability_weights(), est_b.reliability_weights()
    )


# ---------------------------------------------------------------------------
# the watermark split: what mid-round telemetry can legitimately see
# ---------------------------------------------------------------------------


def test_watermark_split_classifies_early_vs_late():
    x = np.array([8, 6, 4, 2], dtype=np.int64)
    faults = RoundFaults(
        round_index=0,
        completed=np.array([1, 6, 2, 2], dtype=np.int64),  # crash@1, straggle->2
        crashed=(0,),
        stragglers=(2,),
    )
    early, late, wm = watermark_split(faults, x, quantile=0.5)
    assert wm.t_barrier == 8.0 and wm.t_watermark == 5.0
    # client 0 crashed at t=1 < watermark: early; straggler always early
    assert early.crashed == (0,) and early.stragglers == (2,)
    assert late == ()
    np.testing.assert_array_equal(early.completed, [1, 6, 2, 2])
    assert wm.early_detected == (0, 2)

    # a crash AFTER the watermark is invisible until it happens
    faults_late = RoundFaults(
        round_index=0,
        completed=np.array([7, 6, 4, 2], dtype=np.int64),
        crashed=(0,),
        stragglers=(),
    )
    early2, late2, wm2 = watermark_split(faults_late, x, quantile=0.5)
    assert early2 is None and late2 == (0,)
    assert wm2.late_detected == (0,)


def test_plan_policy_validates_adaptive_knobs():
    with pytest.raises(ValueError, match="lookahead"):
        PlanPolicy(lookahead=-1)
    with pytest.raises(ValueError, match="drift_tolerance"):
        PlanPolicy(drift_tolerance=0.0)
    with pytest.raises(ValueError, match="reliability"):
        PlanPolicy(reliability=1.5)
    with pytest.raises(ValueError, match="watermark_quantile"):
        PlanPolicy(watermark_quantile=1.0)
    with pytest.raises(ValueError, match="min-energy planning path"):
        PlanPolicy(lookahead=2, frontier_mode="knee", time_tables=())


# ---------------------------------------------------------------------------
# campaign-level: speculation, drift, watermark, chaos
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_stationary_speculation_commits_with_zero_extra_solves():
    """The tentpole accounting: a drift-free lookahead-k campaign dispatches
    exactly ceil(R / k) solver batches — every speculative round validates
    in-band and commits a pre-solved schedule with ZERO extra dispatches."""
    R, k = 6, 3
    engine = SweepEngine()
    server, examples, rng, T = _build(
        seed=2, engine=engine, policy_kwargs=dict(lookahead=k)
    )
    before = engine.cache_stats()
    h = run_campaign(server, examples, R, round_T=T, batch_size=4, rng=rng)
    after = engine.cache_stats()
    dispatches = (after["hits"] + after["misses"]) - (before["hits"] + before["misses"])
    assert dispatches == math.ceil(R / k)
    stats = h.adaptive_stats
    assert stats["speculation_batches"] == math.ceil(R / k)
    assert stats["speculation_hits"] == R - math.ceil(R / k)
    assert stats["speculation_misses"] == 0
    assert stats["speculation_hit_rate"] == 1.0
    assert h.summary()["replan_rate"] == 0.0
    # committed speculative plans are feasible and carry honest fresh costs
    for r in h.rounds:
        assert int(np.asarray(r.assignments).sum()) == T
        if r.adaptive is not None and r.adaptive.speculation == "hit":
            assert r.estimated_joules > 0


@pytest.mark.chaos
def test_abrupt_drift_invalidates_speculation():
    """A 3x regime flip must (a) trip the detector and (b) force at least
    one speculation miss — the stale pre-solved schedule is NOT committed."""
    R, k = 6, 3
    drift = DriftPlan.step(num_rounds=R, n_clients=5, round_index=2,
                           clients=(0, 1), factor=3.0)
    server, examples, rng, T = _build(seed=3, policy_kwargs=dict(lookahead=k))
    h = run_campaign(
        server, examples, R, round_T=T, batch_size=4, rng=rng, drift=drift
    )
    stats = h.adaptive_stats
    assert stats["drift_rounds"] >= 1
    assert stats["speculation_misses"] >= 1
    drifted = [r.round_index for r in h.rounds if r.adaptive and r.adaptive.drifted]
    assert drifted and min(drifted) >= 2  # no false alarm before the flip


@pytest.mark.chaos
def test_serial_and_pipelined_adaptive_campaigns_are_bit_identical():
    """§11 under the FULL adaptive policy: speculation + drift + chaos +
    watermark + reliability, serial vs pipelined, bit for bit."""
    drift = DriftPlan.generate(seed=7, num_rounds=4, n_clients=5, p_event=0.3)
    faults = FaultPlan.generate(
        seed=13, num_rounds=4, n_clients=5, p_crash=0.4, p_straggle=0.3
    )
    server_s, ex_s, rng_s, T = _build(seed=1, policy_kwargs=ADAPTIVE_POLICY)
    h_s = run_campaign(
        server_s, ex_s, 4, round_T=T, batch_size=4, rng=rng_s,
        faults=faults, drift=drift,
    )
    server_p, ex_p, rng_p, _ = _build(seed=1, policy_kwargs=ADAPTIVE_POLICY)
    h_p = run_campaign(
        server_p, ex_p, 4, round_T=T, batch_size=4, rng=rng_p,
        faults=faults, drift=drift, pipelined=True,
    )
    _assert_histories_equal(h_s, h_p)
    _assert_params_equal(server_s.params, server_p.params)


@pytest.mark.chaos
def test_killed_adaptive_campaign_resumes_bit_identically(tmp_path):
    """Kill/resume with speculation in flight: the pending plan decision and
    the speculative buffer round-trip through the checkpoint, so the resumed
    campaign replays the SAME schedules — history, params, and adaptive
    telemetry all match the uninterrupted run."""
    drift = DriftPlan.generate(seed=7, num_rounds=5, n_clients=5)
    faults = FaultPlan.generate(
        seed=23, num_rounds=5, n_clients=5, p_crash=0.3, p_straggle=0.2
    )
    server_a, ex_a, rng_a, T = _build(seed=5, policy_kwargs=ADAPTIVE_POLICY)
    h_a = run_campaign(
        server_a, ex_a, 5, round_T=T, batch_size=4, rng=rng_a,
        faults=faults, drift=drift,
    )

    class _Kill(Exception):
        pass

    def killer(res):
        if res.round_index == 2:
            raise _Kill()

    ckpt = str(tmp_path / "campaign")
    server_b, ex_b, rng_b, _ = _build(seed=5, policy_kwargs=ADAPTIVE_POLICY)
    with pytest.raises(_Kill):
        run_campaign(
            server_b, ex_b, 5, round_T=T, batch_size=4, rng=rng_b,
            faults=faults, drift=drift, checkpoint_dir=ckpt, on_round=killer,
        )
    server_c, ex_c, rng_c, _ = _build(seed=5, policy_kwargs=ADAPTIVE_POLICY)
    h_c = run_campaign(
        server_c, ex_c, 5, round_T=T, batch_size=4, rng=rng_c,
        faults=faults, drift=drift, checkpoint_dir=ckpt,
    )
    _assert_histories_equal(h_a, h_c)
    _assert_params_equal(server_a.params, server_c.params)


@pytest.mark.chaos
def test_watermark_recovery_matches_reactive_and_saves_barrier_wait():
    """Straggler-heavy chaos (no crashes): every fault is early-detectable,
    so the watermark residual instance is byte-for-byte the reactive one —
    recovered assignments are bit-identical — and recovery work overlaps the
    barrier wait (positive saved time)."""
    faults = FaultPlan.generate(
        seed=31, num_rounds=4, n_clients=5, p_crash=0.0, p_straggle=0.6
    )
    assert faults.client_faults
    server_r, ex_r, rng_r, T = _build(seed=8)
    h_r = run_campaign(
        server_r, ex_r, 4, round_T=T, batch_size=4, rng=rng_r, faults=faults
    )
    server_w, ex_w, rng_w, _ = _build(
        seed=8, policy_kwargs=dict(watermark_quantile=0.5)
    )
    h_w = run_campaign(
        server_w, ex_w, 4, round_T=T, batch_size=4, rng=rng_w, faults=faults
    )
    # stragglers only => the early split sees the EXACT reactive faults
    for rr, rw in zip(h_r.rounds, h_w.rounds):
        np.testing.assert_array_equal(rr.assignments, rw.assignments)
        assert rr.mean_loss == rw.mean_loss
        assert rr.energy_joules == rw.energy_joules
    _assert_params_equal(server_r.params, server_w.params)
    stats = h_w.adaptive_stats
    assert stats["early_replans"] >= 1
    assert stats["barrier_wait_saved"] > 0.0
    wm_rounds = [r for r in h_w.rounds if r.adaptive and r.adaptive.watermark]
    assert wm_rounds
    for r in wm_rounds:
        wm = r.adaptive.watermark
        assert wm.early_finish <= wm.reactive_finish
        assert wm.late_detected == ()


@pytest.mark.chaos
def test_watermark_late_crash_takes_second_pass():
    """A crash AFTER the watermark is invisible mid-round: the second
    post-barrier pass recovers it (full T still trained) and the round
    honestly reports zero barrier-wait savings."""
    server, examples, rng, T = _build(
        seed=9, policy_kwargs=dict(watermark_quantile=0.2)
    )
    # completing 90% of its window puts the crash past the 0.2-quantile
    # (clients 3 and 1 carry work in this seed's round-1 plan)
    faults = FaultPlan(
        seed=0, client_faults=(ClientFault(1, 3, "crash", 0.9),
                               ClientFault(1, 1, "straggle", 2.0)),
    )
    h = run_campaign(
        server, examples, 3, round_T=T, batch_size=4, rng=rng, faults=faults
    )
    wm = h.rounds[1].adaptive.watermark
    assert wm is not None
    assert 3 in wm.late_detected
    assert 1 in wm.early_detected
    assert wm.saved == 0.0  # conservative: late crash forces post-barrier work
    rec = h.rounds[1].recovery
    assert rec is not None
    # both passes landed: the round still trains the full workload
    assert int(np.asarray(h.rounds[1].assignments).sum()) == T


@pytest.mark.chaos
def test_reliability_downweighting_shrinks_flaky_clients_share():
    """A chronically crashing client loses planning capacity over the
    campaign (its assigned share drops), while the TRUE simulator tables
    stay untouched and every round still schedules exactly T batches."""
    victim_faults = tuple(
        ClientFault(r, 0, "crash", 0.3) for r in range(5)
    )
    faults = FaultPlan(seed=0, client_faults=victim_faults)
    server, examples, rng, T = _build(
        seed=10, policy_kwargs=dict(reliability=0.5)
    )
    truth_before = server.estimator.true_problem(T)
    h = run_campaign(
        server, examples, 5, round_T=T, batch_size=4, rng=rng, faults=faults
    )
    w = server.estimator.reliability_weights()
    assert w[0] < 1.0 and all(w[i] == 1.0 for i in range(1, 5))
    # the NEXT planning snapshot caps the flaky client below full capacity
    assert server.build_problem(T).upper[0] < truth_before.upper[0]
    for r in h.rounds:
        assert int(np.asarray(r.assignments).sum()) == T
    truth_after = server.estimator.true_problem(T)
    np.testing.assert_array_equal(truth_after.upper, truth_before.upper)
    for a, b in zip(truth_after.cost_tables, truth_before.cost_tables):
        np.testing.assert_array_equal(a, b)
