"""Serving-layer resilience (DESIGN.md §17): flush retry with backoff,
the circuit breaker's closed → open → half-open life cycle and its degraded
direct-solve path, and real deadline enforcement on the staged futures."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    CircuitBreaker,
    RetryPolicy,
    Solver,
    TransientEngineError,
    is_transient,
    random_problem,
)
from repro.core.sweep import SweepEngine
from repro.fl.faults import FlakyEngine
from repro.serve import SchedulerService, ServiceClosed


def _probs(rng, k=4, n=6, T=24):
    return [random_problem(rng, n=n, T=T) for _ in range(k)]


def _baseline(probs, split=False):
    with SchedulerService(engine=SweepEngine(), max_delay_s=0.001) as svc:
        return np.asarray(svc.submit(probs, split_regimes=split).result(timeout=60))


# ---------------------------------------------------------------------------
# resilience primitives
# ---------------------------------------------------------------------------


def test_retry_policy_delays_are_bounded_and_deterministic():
    pol = RetryPolicy(max_attempts=5, base_delay_s=0.01, max_delay_s=0.05, seed=3)
    a = [pol.delay(k, pol.make_rng()) for k in range(1, 5)]
    b = [pol.delay(k, pol.make_rng()) for k in range(1, 5)]
    assert a == b  # deterministic per (policy seed, attempt)
    for k, d in enumerate(a, start=1):
        assert 0 < d <= 0.05 * (1 + pol.jitter)
    assert a[1] > a[0]  # exponential until the cap


def test_is_transient_recognizes_marker_class_and_attribute():
    assert is_transient(TransientEngineError("x"))
    err = RuntimeError("flaky")
    assert not is_transient(err)
    err.transient = True
    assert is_transient(err)


def test_circuit_breaker_state_machine():
    now = [0.0]
    br = CircuitBreaker(failure_threshold=2, cooldown_s=10.0, clock=lambda: now[0])
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"  # one short of the threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    now[0] = 10.5  # cooldown elapsed: exactly ONE half-open probe
    assert br.allow()
    assert not br.allow()  # second concurrent probe is rejected
    br.record_failure()  # failed probe re-opens
    assert br.state == "open"
    now[0] = 21.0
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.allow()
    st = br.stats()
    assert st["opens"] == 2 and st["probes"] == 2


# ---------------------------------------------------------------------------
# flush retry / degraded serving
# ---------------------------------------------------------------------------


def test_transient_flush_failure_retries_bit_identically():
    rng = np.random.default_rng(0)
    probs = _probs(rng)
    want = _baseline(probs)
    flaky = FlakyEngine(SweepEngine(), fail_ordinals=(0,))
    with SchedulerService(
        engine=flaky, max_delay_s=0.001, retry=RetryPolicy()
    ) as svc:
        got = np.asarray(svc.submit(probs).result(timeout=60))
        st = svc.stats()
    np.testing.assert_array_equal(want, got)
    assert st["retries"] == 1 and st["flush_failures"] == 1
    assert st["degraded_flushes"] == 0
    assert flaky.fault_stats()["injected_failures"] == 1


def test_non_transient_failure_propagates_without_retry():
    class _BoomEngine:
        def dispatch(self, batch, split_regimes=False):
            raise RuntimeError("boom")

        def cache_stats(self):
            return {}

    rng = np.random.default_rng(1)
    with SchedulerService(
        engine=_BoomEngine(), max_delay_s=0.001, retry=RetryPolicy()
    ) as svc:
        f = svc.submit(_probs(rng, k=2))
        with pytest.raises(RuntimeError, match="boom"):
            f.result(timeout=30)
        st = svc.stats()
    assert st["retries"] == 0  # non-transient: fail fast, never retried
    assert st["flush_failures"] == 1
    assert svc.stats()["inflight_rows"] == 0


def test_retry_exhaustion_without_breaker_propagates():
    flaky = FlakyEngine(SweepEngine(), fail_ordinals=range(50))
    rng = np.random.default_rng(2)
    with SchedulerService(
        engine=flaky, max_delay_s=0.001, retry=RetryPolicy(max_attempts=3)
    ) as svc:
        f = svc.submit(_probs(rng, k=2))
        with pytest.raises(TransientEngineError):
            f.result(timeout=30)
    assert svc.stats()["inflight_rows"] == 0


@pytest.mark.parametrize("split", [False, True])
def test_open_breaker_serves_degraded_bit_identical_schedules(split):
    rng = np.random.default_rng(3)
    probs = _probs(rng)
    want = _baseline(probs, split=split)
    flaky = FlakyEngine(SweepEngine(), fail_ordinals=range(50))
    with SchedulerService(
        engine=flaky,
        max_delay_s=0.001,
        retry=RetryPolicy(max_attempts=2),
        breaker=CircuitBreaker(failure_threshold=1, cooldown_s=60.0),
    ) as svc:
        f = svc.submit(probs, split_regimes=split)
        got = np.asarray(f.result(timeout=60))
        np.testing.assert_array_equal(want, got)
        # the degraded path has no fused-DP row to expose
        if not split:
            with pytest.raises(ValueError, match="degraded"):
                f.k_last()
        st = svc.stats()
        assert st["breaker"]["state"] == "open"
        assert st["degraded_flushes"] == 1 and st["degraded_rows"] == len(probs)
        # while open, new flushes go straight to the degraded path — the
        # engine is not touched again
        calls_before = flaky.fault_stats()["dispatches"]
        got2 = np.asarray(svc.submit(probs, split_regimes=split).result(timeout=60))
        np.testing.assert_array_equal(want, got2)
        assert flaky.fault_stats()["dispatches"] == calls_before
        assert svc.stats()["degraded_flushes"] == 2


def test_half_open_probe_closes_breaker_and_restores_engine_path():
    rng = np.random.default_rng(4)
    probs = _probs(rng, k=3)
    want = _baseline(probs)
    flaky = FlakyEngine(SweepEngine(), fail_ordinals=(0,))  # heals after one
    br = CircuitBreaker(failure_threshold=1, cooldown_s=0.15)
    with SchedulerService(engine=flaky, max_delay_s=0.001, breaker=br) as svc:
        np.testing.assert_array_equal(
            want, np.asarray(svc.submit(probs).result(timeout=60))
        )
        assert br.state == "open"  # first flush failed, served degraded
        time.sleep(0.2)  # past the cooldown: next flush is the probe
        f = svc.submit(probs)
        np.testing.assert_array_equal(want, np.asarray(f.result(timeout=60)))
        assert br.state == "closed"
        _ = np.asarray(f.k_last())  # engine-served again: the DP row is back
        assert br.stats()["probes"] == 1 and br.stats()["opens"] == 1


def test_solver_retry_recovers_transient_direct_dispatch():
    rng = np.random.default_rng(5)
    probs = _probs(rng)
    want = Solver(engine=SweepEngine()).solve(probs, algorithm="dp_batch")
    flaky = FlakyEngine(SweepEngine(), fail_ordinals=(0,))
    got = Solver(engine=flaky, retry=RetryPolicy()).solve(
        probs, algorithm="dp_batch"
    )
    for a, b in zip(want.schedules, got.schedules):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(want.k_last, got.k_last)
    assert flaky.fault_stats()["injected_failures"] == 1
    # without a policy the same fault propagates (bit-identical legacy path)
    flaky2 = FlakyEngine(SweepEngine(), fail_ordinals=(0,))
    with pytest.raises(TransientEngineError):
        Solver(engine=flaky2).solve(probs, algorithm="dp_batch")


# ---------------------------------------------------------------------------
# future deadline semantics
# ---------------------------------------------------------------------------


class _GatedHandle:
    def __init__(self, gate, B, n):
        self._gate, self._B, self._n = gate, B, n

    def result(self):
        assert self._gate.wait(timeout=60), "test gate never opened"
        return np.zeros((self._B, self._n), dtype=np.int64)

    def objectives(self):
        return np.zeros(self._B)

    def k_last(self):
        assert self._gate.wait(timeout=60)
        return np.zeros((self._B, 1))


class _GatedEngine:
    """Engine stand-in whose solves block until the test opens the gate."""

    def __init__(self):
        self.gate = threading.Event()
        self.dispatched = 0

    def dispatch(self, batch, split_regimes=False):
        self.dispatched += 1
        return _GatedHandle(self.gate, batch.B, batch.n)

    def cache_stats(self):
        return {}


def _tiny(rng):
    return random_problem(rng, n=2, T=4, regime="linear")


def test_schedule_future_timeout_then_retry_no_inflight_leak():
    eng = _GatedEngine()
    rng = np.random.default_rng(6)
    with SchedulerService(engine=eng, max_delay_s=0.001) as svc:
        f = svc.submit(_tiny(rng))
        with pytest.raises(TimeoutError, match="not served"):
            f.result(timeout=0.05)
        assert svc.stats()["inflight_rows"] == 1  # still in flight, not leaked
        eng.gate.set()
        X = f.result(timeout=30)  # the SAME future succeeds on retry
        assert X.shape == (2,)
        deadline = time.monotonic() + 30
        while svc.stats()["inflight_rows"] and time.monotonic() < deadline:
            time.sleep(0.005)
    assert svc.stats()["inflight_rows"] == 0


def test_fleet_future_result_enforces_real_deadline():
    rng = np.random.default_rng(7)
    p = random_problem(rng, n=64, T=512)
    with SchedulerService(engine=SweepEngine(), max_delay_s=0.001) as svc:
        fut = svc.submit_fleet(p, clusters=8)
        with pytest.raises(TimeoutError, match="fleet solve"):
            fut.result(timeout=1e-9)
        sol = fut.result(timeout=120)  # nothing cached on the timed-out pass
        want = Solver(engine=SweepEngine()).solve_fleet(p, clusters=8)
        np.testing.assert_array_equal(sol.schedule, want.schedule)
        assert sol.objective == want.objective


def test_close_racing_blocked_submit_raises_service_closed():
    """A submit blocked on backpressure when close() lands must see
    ServiceClosed (a terminal state), NOT ServiceOverloaded (a retryable
    one) — and the requests already admitted must still be served."""
    eng = _GatedEngine()
    rng = np.random.default_rng(8)
    svc = SchedulerService(engine=eng, max_delay_s=0.0005, max_pending=2)
    admitted = svc.submit([_tiny(rng), _tiny(rng)])  # fills the admission bound
    deadline = time.monotonic() + 30
    while eng.dispatched == 0 and time.monotonic() < deadline:
        time.sleep(0.005)  # wait until the filler flush is in flight

    errs = []

    def blocked_submit():
        try:
            svc.submit(_tiny(rng), timeout=30)
        except Exception as e:  # noqa: BLE001 - recorded for the assertion
            errs.append(e)

    t = threading.Thread(target=blocked_submit)
    t.start()
    time.sleep(0.1)  # let it enter the backpressure wait
    closer = threading.Thread(target=svc.close)
    closer.start()
    time.sleep(0.1)
    eng.gate.set()  # let the in-flight flush finish so close() can drain
    t.join(timeout=30)
    closer.join(timeout=30)
    assert not t.is_alive() and not closer.is_alive()
    assert len(errs) == 1 and isinstance(errs[0], ServiceClosed)
    X = admitted.result(timeout=30)  # admitted work drained through close
    assert X.shape == (2, 2)
    assert svc.stats()["inflight_rows"] == 0
