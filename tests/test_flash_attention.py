"""Flash-attention Pallas kernel vs the pure-jnp oracle
(repro.models.layers.attention): forward + gradients, across mask kinds,
GQA ratios, softcap, and block shapes. Interpret mode (CPU container).

Interpret-mode Pallas is slow — the whole module is marked ``slow`` and
excluded from tier-1 (run the full suite with -m "slow or not slow")."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.kernels.flash_attention import flash_attention
from repro.models.layers import attention


def make_qkv(rng, B, H, Hkv, S, D):
    q = jnp.asarray(rng.normal(size=(B, H, S, D)).astype(np.float32)) * 0.5
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32)) * 0.5
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32)) * 0.5
    return q, k, v


def oracle(q, k, v, kind, window, softcap):
    # oracle expects (B, S, H, D)
    S = q.shape[2]
    pos = jnp.arange(S)
    out = attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        q_pos=pos, kv_pos=pos, kind=kind, window=window, attn_softcap=softcap,
    )
    return jnp.moveaxis(out, 2, 1)


CASES = [
    # (B, H, Hkv, S, D, kind, window, softcap, Bq, Bk)
    (2, 4, 4, 128, 32, "causal", 0, 0.0, 32, 32),
    (1, 4, 1, 128, 32, "causal", 0, 0.0, 64, 32),  # MQA
    (2, 8, 2, 64, 16, "causal", 0, 0.0, 16, 16),  # GQA 4
    (1, 2, 2, 128, 32, "sliding", 48, 0.0, 32, 32),
    (1, 2, 2, 96, 16, "bidirectional", 0, 0.0, 32, 32),
    (1, 2, 1, 128, 32, "causal", 0, 30.0, 32, 64),  # softcap + GQA
]


@pytest.mark.parametrize("B,H,Hkv,S,D,kind,window,softcap,Bq,Bk", CASES)
def test_flash_forward_matches_oracle(B, H, Hkv, S, D, kind, window, softcap, Bq, Bk):
    rng = np.random.default_rng(B * 100 + S)
    q, k, v = make_qkv(rng, B, H, Hkv, S, D)
    got = flash_attention(q, k, v, kind, window, softcap, None, Bq, Bk, True)
    want = oracle(q, k, v, kind, window, softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,Hkv,S,D,kind,window,softcap,Bq,Bk", CASES[:4] + CASES[5:])
def test_flash_gradients_match_oracle(B, H, Hkv, S, D, kind, window, softcap, Bq, Bk):
    rng = np.random.default_rng(B * 37 + S)
    q, k, v = make_qkv(rng, B, H, Hkv, S, D)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, kind, window, softcap, None, Bq, Bk, True)
        return jnp.sum(jnp.sin(o))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(oracle(q, k, v, kind, window, softcap)))

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_flash, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-5,
            err_msg=f"grad d{name} mismatch",
        )


def test_flash_bf16_io():
    rng = np.random.default_rng(0)
    q, k, v = make_qkv(rng, 1, 4, 4, 128, 32)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    got = flash_attention(qb, kb, vb, "causal", 0, 0.0, None, 32, 32, True)
    want = oracle(q, k, v, "causal", 0, 0.0)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=2e-2, atol=2e-2
    )


def test_dense_model_with_pallas_attention_matches_xla():
    """End-to-end: a dense smoke model with attn_impl='pallas' reproduces the
    XLA path's loss and gradients (interpret mode, single device)."""
    from repro.configs import get_config
    from repro.models import init_params, loss_fn, make_dummy_batch

    cfg = get_config("deepseek-7b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = make_dummy_batch(cfg, 2, 128, "train", rng)

    l_xla, g_xla = jax.value_and_grad(loss_fn)(params, cfg.replace(attn_impl="xla"), batch)
    cfg_p = cfg.replace(attn_impl="pallas", attn_block_q=64)
    l_pal, g_pal = jax.value_and_grad(loss_fn)(params, cfg_p, batch)
    assert abs(float(l_xla) - float(l_pal)) < 2e-5
    for a, b in zip(jax.tree.leaves(g_xla), jax.tree.leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)
