"""Scheduler-as-a-service (DESIGN.md §14): coalescing batcher over the engine.

Claims under test:
  * every coalesced request is BIT-IDENTICAL to solving it alone — mixed
    regimes, ragged shapes, nonzero lower limits, single-Problem and batch
    requests, plain and regime-split, including k_last/objectives demux;
  * the service actually batches (fewer flushes than requests) and a lone
    sub-max-batch request still flushes within ~max_delay;
  * bounded admission: a stuck engine backs producers up, times them out
    with :class:`ServiceOverloaded`, and serves everything on release;
  * close() drains in-flight requests, then refuses new ones;
  * warm() covers the pow2 ladder so served steady state performs zero
    fresh XLA traces; flushes never exceed max_batch rows (they'd leave
    the warmed ladder);
  * an FL campaign planning through the service matches the engine path.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import Problem, ProblemBatch, SweepEngine, random_problem, solve_schedule_dp_batch
from repro.core.sweep import request_bucket
from repro.serve import (
    SchedulerService,
    ServiceClosed,
    ServiceOverloaded,
    coalesce_key,
    combine_batches,
    pow2_ladder,
    warm_batch,
)

REGIMES = ("arbitrary", "linear", "increasing", "decreasing")


def ragged_problems(rng, N, max_n=6, max_T=24, with_lower=True):
    return [
        random_problem(
            rng,
            n=int(rng.integers(1, max_n + 1)),
            T=int(rng.integers(1, max_T + 1)),
            regime=REGIMES[i % len(REGIMES)],
            with_lower=with_lower,
        )
        for i in range(N)
    ]


# ---------------------------------------------------------------------------
# coalesce primitives
# ---------------------------------------------------------------------------


def test_coalesce_key_matches_engine_bucket_math():
    rng = np.random.default_rng(0)
    for p in ragged_problems(rng, 6):
        b = ProblemBatch.from_problems([p])
        nb, Tb, Wb = request_bucket(b)
        assert coalesce_key(b, False) == (nb, Tb, Wb, False)
        assert coalesce_key(b, True) == (nb, Tb, Wb, True)
        for v in (nb, Tb, Wb):  # pow2 axes
            assert v & (v - 1) == 0 and v >= 1


def test_combine_batches_slices_and_padding_inert():
    rng = np.random.default_rng(1)
    groups = [ProblemBatch.from_problems(ragged_problems(rng, k)) for k in (1, 3, 2)]
    combined, slices = combine_batches(groups)
    assert combined.B == 6 and slices == [(0, 1), (1, 4), (4, 6)]
    X_all = solve_schedule_dp_batch(combined)
    for g, (lo, hi) in zip(groups, slices):
        np.testing.assert_array_equal(X_all[lo:hi, : g.n], solve_schedule_dp_batch(g))


def test_pow2_ladder_and_warm_batch():
    assert pow2_ladder(1) == [1]
    assert pow2_ladder(5) == [1, 2, 4, 8]
    assert pow2_ladder(16) == [1, 2, 4, 8, 16]
    wb = warm_batch(4, 12, 8, B=3, regime="arbitrary")
    wb.validate()
    assert wb.B == 3
    assert request_bucket(wb) == (4, 16, 8)  # lands in the spec's bucket
    mono = warm_batch(4, 12, 8, B=2, regime="increasing")
    assert request_bucket(mono) == (4, 16, 8)
    solve_schedule_dp_batch(wb)  # feasible by construction


# ---------------------------------------------------------------------------
# service: correctness of served results
# ---------------------------------------------------------------------------


def test_served_results_bit_identical_mixed_regimes_and_shapes():
    rng = np.random.default_rng(2)
    probs = ragged_problems(rng, 10)
    eng = SweepEngine()
    with SchedulerService(engine=eng, max_batch=4, max_delay_s=0.005) as svc:
        futs = [svc.submit(p) for p in probs]  # squeeze path
        multi = ProblemBatch.from_problems(probs[:3])
        f_multi = svc.submit(multi)
        f_split = [svc.submit(p, split_regimes=True) for p in probs[:4]]

        for p, f in zip(probs, futs):
            x = f.result(timeout=300)
            assert x.shape == (p.n,)
            np.testing.assert_array_equal(x, eng.solve([p])[0, : p.n])
        X_multi = f_multi.result(timeout=300)
        np.testing.assert_array_equal(
            X_multi[:, : multi.n], eng.solve(probs[:3])[:, : multi.n]
        )
        for p, f in zip(probs[:4], f_split):
            np.testing.assert_array_equal(
                f.result(timeout=300), eng.solve([p], split_regimes=True)[0, : p.n]
            )
    s = svc.stats()
    assert s["completed_requests"] == s["requests"] == 15
    assert s["flushes"] < s["requests"], "nothing coalesced"
    assert s["inflight_rows"] == 0 and s["pending_rows"] == 0


def test_future_demuxes_k_last_and_objectives():
    rng = np.random.default_rng(3)
    probs = ragged_problems(rng, 5, with_lower=False)
    eng = SweepEngine()
    with SchedulerService(engine=eng, max_batch=8, max_delay_s=0.005) as svc:
        futs = [svc.submit(p) for p in probs]
        # probs[1] is linear (monotone): under split_regimes it rides the
        # marginal path, whose handle has no free-T Pareto row
        f_split = svc.submit(probs[1], split_regimes=True)
        for p, f in zip(probs, futs):
            solo = eng.dispatch(ProblemBatch.from_problems([p]))
            np.testing.assert_array_equal(f.k_last(timeout=300), solo.k_last()[0])
            assert f.objectives() == pytest.approx(float(solo.objectives()[0]))
        # regime-split requests expose objectives but no free-T Pareto row,
        # exactly like the engine's split-dispatch handles
        assert f_split.objectives(timeout=300) == pytest.approx(
            float(eng.dispatch(ProblemBatch.from_problems([probs[1]]),
                               split_regimes=True).objectives()[0])
        )
        with pytest.raises(Exception):
            f_split.k_last()


def test_lone_request_flushes_on_max_delay():
    rng = np.random.default_rng(4)
    p = random_problem(rng, n=3, T=8, regime="linear")
    eng = SweepEngine()
    eng.solve([p])  # trace outside the timed window
    with SchedulerService(engine=eng, max_batch=64, max_delay_s=0.05) as svc:
        t0 = time.monotonic()
        x = svc.submit(p).result(timeout=300)
        waited = time.monotonic() - t0
    np.testing.assert_array_equal(x, eng.solve([p])[0, : p.n])
    assert waited >= 0.04, f"flushed before the max-delay window ({waited:.3f}s)"
    assert svc.stats()["delay_flushes"] == 1 and svc.stats()["size_flushes"] == 0


# ---------------------------------------------------------------------------
# backpressure + shutdown (stub engine: no XLA in the loop)
# ---------------------------------------------------------------------------


class _GatedHandle:
    def __init__(self, gate, B, n):
        self._gate, self._B, self._n = gate, B, n

    def result(self):
        assert self._gate.wait(timeout=60), "test gate never opened"
        return np.zeros((self._B, self._n), dtype=np.int64)

    def objectives(self):
        return np.zeros(self._B)

    def k_last(self):
        return np.zeros((self._B, 1), dtype=np.int64)


class _GatedEngine:
    """Engine stand-in whose solves block until the test opens the gate."""

    def __init__(self):
        self.gate = threading.Event()
        self.dispatched_rows = []

    def dispatch(self, batch, split_regimes=False):
        self.dispatched_rows.append(batch.B)
        return _GatedHandle(self.gate, batch.B, batch.n)


def _tiny(rng):
    return random_problem(rng, n=2, T=4, regime="linear")


def test_backpressure_blocks_then_rejects_then_drains():
    rng = np.random.default_rng(5)
    eng = _GatedEngine()
    svc = SchedulerService(engine=eng, max_batch=2, max_delay_s=0.001, max_pending=4)
    try:
        held = [svc.submit(_tiny(rng)) for _ in range(4)]  # fills the bound
        deadline = time.monotonic() + 30  # flushed (inflight) but unfinished
        while svc.stats()["flushes"] < 2 and time.monotonic() < deadline:
            time.sleep(0.002)
        with pytest.raises(ServiceOverloaded):
            svc.submit(_tiny(rng), timeout=0.05)
        assert svc.stats()["rejected"] == 1

        # a submitter ALREADY blocked on admission gets served on release
        late = {}
        t = threading.Thread(
            target=lambda: late.__setitem__("f", svc.submit(_tiny(rng), timeout=30))
        )
        t.start()
        time.sleep(0.05)
        assert "f" not in late  # still blocked: bound is honest
        eng.gate.set()
        t.join(timeout=30)
        assert not t.is_alive()
        for f in held + [late["f"]]:
            assert f.result(timeout=30).shape == (2,)
    finally:
        eng.gate.set()
        svc.close()


def test_flushes_never_exceed_max_batch_rows():
    """Rows arriving while a bucket is ripe must NOT grow a flush past
    max_batch — an overflow would leave the warmed pow2-B ladder and pay a
    cold trace in steady state (the bench gates this end-to-end)."""
    rng = np.random.default_rng(6)
    eng = _GatedEngine()
    eng.gate.set()
    with SchedulerService(engine=eng, max_batch=4, max_delay_s=0.5, max_pending=512) as svc:
        futs = [svc.submit(_tiny(rng)) for _ in range(37)]
        for f in futs:
            f.result(timeout=60)
    assert max(eng.dispatched_rows) <= 4
    assert sum(eng.dispatched_rows) == 37


def test_close_serves_in_flight_then_refuses():
    rng = np.random.default_rng(7)
    eng = _GatedEngine()
    svc = SchedulerService(engine=eng, max_batch=64, max_delay_s=30.0, max_pending=512)
    futs = [svc.submit(_tiny(rng)) for _ in range(5)]  # parked: no trigger ripe
    assert not any(f.done() for f in futs)
    eng.gate.set()
    svc.close(timeout=60)  # close must flush + serve them, then stop
    for f in futs:
        assert f.result(timeout=1).shape == (2,)
    s = svc.stats()
    assert s["close_flushes"] >= 1 and s["completed_requests"] == 5
    with pytest.raises(ServiceClosed):
        svc.submit(_tiny(rng))
    svc.close()  # idempotent


def test_engine_failure_propagates_to_futures():
    class _BoomEngine:
        def dispatch(self, batch, split_regimes=False):
            raise RuntimeError("boom")

    rng = np.random.default_rng(8)
    with SchedulerService(engine=_BoomEngine(), max_batch=2, max_delay_s=0.001) as svc:
        f = svc.submit(_tiny(rng))
        with pytest.raises(RuntimeError, match="boom"):
            f.result(timeout=30)
    assert svc.stats()["inflight_rows"] == 0  # failed rows retire too


# ---------------------------------------------------------------------------
# warm(): steady state pays zero cold traces
# ---------------------------------------------------------------------------


def test_warm_covers_steady_state_zero_traces():
    rng = np.random.default_rng(9)
    probs = [random_problem(rng, n=3, T=11, regime=REGIMES[i % 4], with_lower=False)
             for i in range(12)]
    batches = [ProblemBatch.from_problems([p]) for p in probs]
    buckets = sorted(set(request_bucket(b) for b in batches))

    eng = SweepEngine()
    with SchedulerService(engine=eng, max_batch=4, max_delay_s=0.002) as svc:
        traced = svc.warm(buckets)
        assert traced > 0  # cold cache: the ladder really traced
        assert svc.warm(buckets) == 0  # idempotent: everything warm
        before = eng.cache_stats()["compiles"]
        futs = [svc.submit(b) for b in batches]
        for b, f in zip(batches, futs):
            np.testing.assert_array_equal(f.result(timeout=300), eng.dispatch(b).result())
        assert eng.cache_stats()["compiles"] == before, "steady state paid a cold trace"
    per_bucket = eng.cache_stats()["per_bucket_hits"]
    assert sum(per_bucket.values()) > 0 and all(":T16:" in k for k in per_bucket)


def test_warm_refuses_plans_larger_than_the_lru():
    """Warming more executables than the engine LRU holds would evict the
    oldest warm entries and steady state would pay cold traces anyway —
    warm() must refuse up front instead of silently thrashing."""
    eng = SweepEngine(max_entries=4)
    with SchedulerService(engine=eng, max_batch=4) as svc:  # ladder [1,2,4]
        with pytest.raises(ValueError, match="max_entries"):
            svc.warm([(2, 8, 8), (4, 16, 16)])  # 2 specs x 3 sizes = 6 > 4
        svc.warm([(2, 8, 8)])  # 3 executables: fits
    assert eng.cache_stats()["compiles"] == 3


# ---------------------------------------------------------------------------
# FL campaign planning through the service
# ---------------------------------------------------------------------------


def test_campaign_scenarios_via_service_match_engine_path():
    from repro.fl import EnergyEstimator, FederatedServer, make_fleet

    rng = np.random.default_rng(10)
    fleet = make_fleet(rng, 4, max_batches=6)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    cap = sum(d.max_batches for d in fleet)

    def mk_server(**kw):
        return FederatedServer(
            None, None, None, est,
            round_T=cap // 2, scenario_T_candidates=[cap // 3, cap // 2],
            scenario_dropouts=[(0,), (1,)], **kw,
        )

    srv = mk_server(engine=SweepEngine())
    direct = srv.solve_scenarios(*srv.build_scenarios(cap // 2))
    with SchedulerService(engine=SweepEngine(), max_batch=8, max_delay_s=0.005) as svc:
        srv2 = mk_server(service=svc)
        assert srv2.engine is svc.engine  # service's engine becomes the default
        served = srv2.solve_scenarios(*srv2.build_scenarios(cap // 2))
    np.testing.assert_array_equal(direct.assignments, served.assignments)
    np.testing.assert_array_equal(direct.energies, served.energies)
    assert svc.stats()["requests"] == 1 and svc.stats()["flushes"] == 1
