"""Monotone-cost fast path (DESIGN.md §13): batched marginal schedulers.

Claims under test:
  * batched MarIn/MarCo (the jitted selection kernel) and MarDecUn/MarDec
    are BIT-IDENTICAL to the serial NumPy heap/sort/packing oracles on
    monotone instances — ragged n/T, lower/upper limits, inert batch
    padding, and exact-tie tie-breaking included;
  * on monotone instances the fast path's schedules cost exactly what the
    DP's cost (both optimal);
  * mixed-regime ``schedule_batch``/``SweepEngine`` solves return rows in
    ORIGINAL problem order, bit-identical to solving each regime sub-batch
    alone;
  * serial and batched algorithm dispatch share one regime detector and
    cannot disagree;
  * marginal selection executables live in their own sweep-engine shape
    buckets (compile once, hit afterwards) without disturbing the DP
    buckets.

All parity instances use float32-representable cost tables (integer-valued
or pre-rounded) so the float32 kernel and the float64 oracles see the same
marginal order — see the precision contract in ``core/marginal_jax.py``.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean container: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    Problem,
    ProblemBatch,
    SweepEngine,
    marco,
    marco_batch,
    mardec,
    mardec_batch,
    mardecun,
    mardecun_batch,
    marin,
    marin_batch,
    random_problem,
    schedule,
    schedule_batch,
    select_algorithm,
    select_algorithm_batch,
    solve_schedule_dp,
    solve_schedule_dp_batch,
    total_cost,
    validate_schedule,
)

# one fixed kernel envelope for most parity tests: every batch is padded to
# it, so the selection kernel compiles exactly once for the whole module
ENV_B, ENV_N, ENV_W = 8, 8, 32


def f32_safe(p: Problem) -> Problem:
    """The instance the float32 paths actually see (tables rounded once)."""
    return Problem(
        T=p.T,
        lower=p.lower,
        upper=p.upper,
        cost_tables=tuple(t.astype(np.float32).astype(np.float64) for t in p.cost_tables),
    )


def integer_increasing_problem(rng, n, T, max_u=None, max_marginal=6, with_lower=True):
    """Increasing-marginal instance with INTEGER tables: exact in float32
    and riddled with exact marginal ties — the tie-break torture case."""
    max_u = max_u or min(T, ENV_W - 1)
    while True:
        upper = rng.integers(1, max_u + 1, size=n)
        if upper.sum() >= T:
            break
    lower = np.minimum(rng.integers(0, 3, size=n), upper) if with_lower else np.zeros(n, np.int64)
    while lower.sum() > T:
        k = int(rng.integers(0, n))
        lower[k] = max(0, lower[k] - 1)
    tables = tuple(
        np.concatenate(
            [[0.0], np.cumsum(np.sort(rng.integers(0, max_marginal, size=int(u))))]
        ).astype(np.float64)
        for u in upper
    )
    return Problem(T=T, lower=lower, upper=upper, cost_tables=tables)


def padded(problems) -> ProblemBatch:
    return ProblemBatch.from_problems(problems).pad_to(B=ENV_B, n=ENV_N, W=ENV_W)


# ---------------------------------------------------------------------------
# selection kernel vs serial heap (MarIn) / sort-and-fill (MarCo)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(1, ENV_B), st.integers(0, 2**32 - 1))
def test_marin_batch_bit_identical_to_serial(B, seed):
    rng = np.random.default_rng(seed)
    probs = [
        f32_safe(
            random_problem(
                rng,
                n=int(rng.integers(1, ENV_N + 1)),
                T=int(rng.integers(1, 25)),
                regime="increasing",
                max_upper=ENV_W - 1,
            )
        )
        for _ in range(B)
    ]
    X = marin_batch(padded(probs))
    for b, p in enumerate(probs):
        assert np.array_equal(X[b, : p.n], marin(p)), (b, X[b], marin(p))
        assert np.all(X[b, p.n :] == 0)
    assert np.all(X[B:] == 0)  # phantom instances stay empty


@settings(max_examples=25, deadline=None)
@given(st.integers(1, ENV_B), st.integers(0, 2**32 - 1))
def test_marin_batch_integer_tie_breaks(B, seed):
    rng = np.random.default_rng(seed)
    probs = [
        integer_increasing_problem(rng, n=int(rng.integers(1, ENV_N + 1)), T=int(rng.integers(1, 20)))
        for _ in range(B)
    ]
    X = marin_batch(padded(probs))
    for b, p in enumerate(probs):
        assert np.array_equal(X[b, : p.n], marin(p)), (b, X[b], marin(p))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, ENV_B), st.integers(0, 2**32 - 1))
def test_marco_batch_bit_identical_to_serial(B, seed):
    rng = np.random.default_rng(seed)
    probs = []
    for _ in range(B):
        n = int(rng.integers(1, ENV_N + 1))
        while True:
            upper = rng.integers(1, ENV_W, size=n)
            T = int(rng.integers(1, 25))
            if upper.sum() >= T:
                break
        # integer per-task marginals with cross-resource ties: MarCo's
        # stable sort order is the thing under test
        tables = tuple(
            np.arange(int(u) + 1, dtype=np.float64) * int(rng.integers(1, 5)) for u in upper
        )
        probs.append(Problem(T=T, lower=np.zeros(n, np.int64), upper=upper, cost_tables=tables))
    X = marco_batch(padded(probs))
    for b, p in enumerate(probs):
        assert np.array_equal(X[b, : p.n], marco(p)), (b, X[b], marco(p))


def test_marginal_batch_dp_objective_equality():
    """On monotone instances the fast path and the DP are both optimal:
    integer tables make the equality EXACT (float32 sums below 2^24)."""
    rng = np.random.default_rng(7)
    probs = [integer_increasing_problem(rng, n=4, T=14, max_marginal=5) for _ in range(6)]
    X = marin_batch(probs)
    X_dp = solve_schedule_dp_batch(probs)
    for b, p in enumerate(probs):
        validate_schedule(p, X[b, : p.n])
        assert total_cost(p, X[b, : p.n]) == total_cost(p, X_dp[b, : p.n])
        assert total_cost(p, X[b, : p.n]) == total_cost(p, solve_schedule_dp(p))


# ---------------------------------------------------------------------------
# MarDecUn / MarDec
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(0, 2**32 - 1))
def test_mardecun_batch_bit_identical_to_serial(B, seed):
    rng = np.random.default_rng(seed)
    probs = []
    for _ in range(B):
        n = int(rng.integers(1, 6))
        T = int(rng.integers(1, 15))
        p = random_problem(rng, n=n, T=T, regime="decreasing", max_upper=T, with_lower=False)
        # force unlimited: widen every table to full T capacity
        from repro.core.costs import sublinear_cost

        tables = tuple(
            sublinear_cost(T, float(rng.uniform(5, 40)), float(rng.uniform(2, 20)))
            for _ in range(n)
        )
        probs.append(Problem(T=T, lower=np.zeros(n, np.int64), upper=np.full(n, T), cost_tables=tables))
    X = mardecun_batch(probs)
    for b, p in enumerate(probs):
        assert np.array_equal(X[b, : p.n], mardecun(p))
        # ragged batching pads with zero-capacity resources; the serial
        # algorithm must agree on the padded materialization too
        assert np.array_equal(X[b], mardecun(ProblemBatch.from_problems(probs).instance(b)))


def test_mardecun_capacity_guard():
    """Zero-capacity resources are ignored (dropout/padding); resources with
    SOME capacity below T still raise, serial and batched alike."""
    tbl = lambda u: np.concatenate([[0.0], 10 - np.arange(1, u + 1, dtype=np.float64) * 0.5]).cumsum()  # noqa: E731
    ok = Problem(T=6, lower=[0, 0], upper=[6, 0], cost_tables=(tbl(6), np.zeros(1)))
    x = mardecun(ok)
    assert np.array_equal(x, mardecun_batch([ok])[0])
    assert x.sum() == 6 and x[1] == 0
    bad = Problem(T=6, lower=[0, 0], upper=[6, 3], cost_tables=(tbl(6), tbl(3)))
    with pytest.raises(ValueError, match="MarDecUn requires"):
        mardecun(bad)
    with pytest.raises(ValueError, match="MarDecUn requires"):
        mardecun_batch([bad])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 5), st.integers(0, 2**32 - 1))
def test_mardec_batch_bit_identical_to_serial(B, seed):
    rng = np.random.default_rng(seed)
    probs = [
        random_problem(rng, n=int(rng.integers(1, 5)), T=int(rng.integers(4, 14)), regime="decreasing")
        for _ in range(B)
    ]
    X_list = mardec_batch(probs)
    X_batch = mardec_batch(ProblemBatch.from_problems(probs))  # padded envelope
    np.testing.assert_array_equal(X_list, X_batch)
    for b, p in enumerate(probs):
        assert np.array_equal(X_list[b, : p.n], mardec(p))


# ---------------------------------------------------------------------------
# one shared dispatch rule (serial == batched, padding-invariant)
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(["arbitrary", "linear", "increasing", "decreasing"]), st.integers(0, 2**32 - 1))
def test_select_algorithm_serial_equals_batched(regime, seed):
    rng = np.random.default_rng(seed)
    probs = [
        random_problem(rng, n=int(rng.integers(1, 6)), T=int(rng.integers(2, 20)), regime=regime)
        for _ in range(4)
    ]
    batched = select_algorithm_batch(probs)
    padded_algs = select_algorithm_batch(ProblemBatch.from_problems(probs).pad_to(B=8, n=8))
    for b, p in enumerate(probs):
        assert select_algorithm(p) == batched[b]
        assert batched[b] == padded_algs[b]  # padding cannot change dispatch
    batch = ProblemBatch.from_problems(probs)
    assert list(batch.regimes()) == [batch.instance(b).regime() for b in range(batch.B)]


def test_dropout_zero_capacity_dispatch():
    """A dropped-out client (U = 0) must not flip the dispatch rule or
    break the selected algorithm (paper §6 'loss of a device')."""
    n, T = 4, 9
    tables = [np.arange(13, dtype=np.float64) * c for c in (2.0, 3.0, 1.0, 2.0)]
    tables[1] = np.zeros(1)  # client 1 dropped: U = 0
    upper = np.array([12, 0, 12, 12])
    p = Problem(T=T, lower=np.zeros(n, np.int64), upper=upper, cost_tables=tuple(tables))
    assert p.regime() == "constant"
    alg = select_algorithm(p)
    assert alg == "mardecun"  # capacity-aware: the U=0 client is ignored
    x = schedule(p, "auto")
    validate_schedule(p, x)
    assert total_cost(p, x) == total_cost(p, solve_schedule_dp(p))
    assert np.array_equal(x, schedule_batch([p], "auto")[0])


# ---------------------------------------------------------------------------
# mixed-regime split: order, sub-batch bit-identity, engine bucketing
# ---------------------------------------------------------------------------


def _mixed_problems(rng, B=8):
    regimes = ("arbitrary", "linear", "increasing", "decreasing")
    return [
        random_problem(
            rng,
            n=int(rng.integers(1, ENV_N + 1)),
            T=int(rng.integers(2, 16)),
            regime=regimes[b % len(regimes)],
        )
        for b in range(B)
    ]


def test_mixed_regime_schedule_batch_matches_subbatches():
    rng = np.random.default_rng(11)
    probs = _mixed_problems(rng)
    eng = SweepEngine()
    xs = schedule_batch(probs, "auto", engine=eng)
    assert len(xs) == len(probs)
    algs = select_algorithm_batch(probs)
    for alg_group in sorted(set(algs)):
        idx = [b for b, a in enumerate(algs) if a == alg_group]
        xs_alone = schedule_batch([probs[b] for b in idx], "auto", engine=eng)
        for j, b in enumerate(idx):
            # original-order rows == solving the regime sub-batch alone
            assert np.array_equal(xs[b], xs_alone[j]), (alg_group, b)
    for p, x in zip(probs, xs):
        validate_schedule(p, x)
        assert total_cost(p, x) == pytest.approx(
            total_cost(p, solve_schedule_dp(p)), rel=1e-5, abs=1e-9
        )


def test_split_engine_handle_and_bucketing():
    rng = np.random.default_rng(12)
    probs = _mixed_problems(rng)
    eng = SweepEngine()
    h = eng.dispatch(probs, split_regimes=True)
    X = h.result()
    assert X.shape == (len(probs), max(p.n for p in probs))
    # objectives: 0-lower-limit optimal cost per instance, any regime
    from repro.core import remove_lower_limits

    obj = h.objectives()
    for b, p in enumerate(probs):
        p0 = remove_lower_limits(p)
        x0 = X[b, : p.n] - p.lower
        assert obj[b] == pytest.approx(total_cost(p0, x0), rel=1e-5, abs=1e-5)
    with pytest.raises(ValueError, match="k_last"):
        h.k_last()
    s1 = eng.cache_stats()
    assert s1["entries"] >= 2  # at least one DP + one marginal bucket
    # same shapes again: pure hits, no new compiles
    X2 = eng.solve(probs, split_regimes=True)
    np.testing.assert_array_equal(X, X2)
    s2 = eng.cache_stats()
    assert s2["compiles"] == s1["compiles"] and s2["entries"] == s1["entries"]
    assert s2["hits"] > s1["hits"]
    # a pure-DP batch takes the classic path: plain SweepHandle, k_last works
    dp_probs = [p for p, a in zip(probs, select_algorithm_batch(probs)) if a == "dp"]
    h_dp = eng.dispatch(dp_probs, split_regimes=True)
    assert hasattr(h_dp, "k_last") and h_dp.k_last().shape[0] == len(dp_probs)
    np.testing.assert_array_equal(
        h_dp.result(), solve_schedule_dp_batch(dp_probs)
    )


def test_unsplit_default_unchanged():
    """The default (no split) engine contract is untouched: bit-identical
    to the uncached batched DP even on monotone instances."""
    rng = np.random.default_rng(13)
    probs = _mixed_problems(rng, B=6)
    X = SweepEngine().solve(probs)
    np.testing.assert_array_equal(X, solve_schedule_dp_batch(probs))


@pytest.mark.slow
def test_wide_sweep_parity_slow():
    """Sweep-scale parity: the acceptance-criteria shape class (wide W,
    many units) against the serial heap, plus DP-cost equality."""
    rng = np.random.default_rng(14)
    B, n, T = 8, 16, 512
    probs = []
    for _ in range(B):
        upper = np.full(n, (2 * T) // n)
        tables = tuple(
            np.concatenate(
                [[0.0], np.cumsum(np.sort(rng.integers(1, 1000, size=int(u))))]
            ).astype(np.float64)
            for u in upper
        )
        probs.append(Problem(T=T, lower=np.zeros(n, np.int64), upper=upper, cost_tables=tables))
    X = marin_batch(probs)
    for b, p in enumerate(probs):
        assert np.array_equal(X[b, : p.n], marin(p))
    X_dp = solve_schedule_dp_batch(probs)
    for b, p in enumerate(probs):
        assert total_cost(p, X[b, : p.n]) == total_cost(p, X_dp[b, : p.n])
