"""Unit tests: parameter sharding rules and the loop-aware HLO cost walker."""

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shd
from repro.launch.hlo_analysis import analyze_hlo


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.fixture(autouse=True)
def mesh():
    shd.set_mesh(FakeMesh())
    yield
    shd.set_mesh(None)


def test_param_rules_basic():
    assert shd.infer_pspec("layers/attn/wq", (30, 4096, 32, 128)) == P(None, "data", "model", None)
    assert shd.infer_pspec("layers/attn/wo", (30, 32, 128, 4096)) == P(None, "model", None, "data")
    assert shd.infer_pspec("layers/mlp/w_in", (30, 4096, 11008)) == P(None, "data", "model")
    assert shd.infer_pspec("emb", (50304, 2048)) == P("model", "data")
    assert shd.infer_pspec("ln_f", (2048,)) == P()


def test_param_rules_divisibility_fallback():
    # MQA: kv head dim 1 can't shard over model=16 -> dropped
    assert shd.infer_pspec("layers/attn/wk", (52, 6144, 1, 128)) == P(None, "data", None, None)
    # kv=8 not divisible by 16 either
    assert shd.infer_pspec("layers/attn/wk", (32, 4096, 8, 128)) == P(None, "data", None, None)
    # odd d_model not divisible by data=16 -> fsdp dropped too
    assert shd.infer_pspec("layers/mlp/w_in", (2, 100, 48)) == P(None, None, "model")


def test_expert_rules_no_axis_duplication():
    spec = shd.infer_pspec("moe/experts/w_gate", (58, 256, 7168, 2048))
    flat = [a for part in spec if part is not None for a in ((part,) if isinstance(part, str) else part)]
    assert len(flat) == len(set(flat)), f"duplicated mesh axis in {spec}"


HLO = """\
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot.1 = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8]{1,0} all-gather(%dot.1), channel_id=1, replica_groups=[4,4]<=[16], dimensions={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %ag)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_walker_expands_while_trip_count():
    c = analyze_hlo(HLO)
    # dot: 2 * 8*8 * 8 = 1024 flops per trip, 10 trips (condition constant)
    assert c.flops == pytest.approx(10 * 1024)
    # all-gather: 8*8*4 bytes * (n-1)/n with group size 4 -> 192 per trip
    assert c.coll_bytes["all-gather"] == pytest.approx(10 * 256 * 3 / 4)


def test_walker_trip_count_from_backend_config():
    hlo = HLO.replace(
        "condition=%cond.1, body=%body.1",
        'condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}',
    )
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(7 * 1024)


def test_walker_dus_in_place():
    hlo = """\
HloModule t

ENTRY %main (a: f32[128,64], u: f32[1,64]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  %u = f32[1,64]{1,0} parameter(1)
  %z = s32[] constant(0)
  ROOT %d = f32[128,64]{1,0} dynamic-update-slice(%a, %u, %z, %z)
}
"""
    c = analyze_hlo(hlo)
    # in-place: 2 * update bytes (1*64*4), NOT 2 * full buffer
    assert c.mem_bytes == pytest.approx(2 * 64 * 4)
