"""Async round pipeline (DESIGN.md §11): the pipelined campaign runner must
be bit-identical to the serial one, planner-thread crashes must surface in
the caller, and the executors/futures must keep their contracts."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import Problem
from repro.core.sweep import SweepEngine
from repro.data import client_corpora, make_lm_examples
from repro.fl import (
    AsyncCampaignRunner,
    CampaignRunner,
    EnergyEstimator,
    FederatedServer,
    PlanFuture,
    SerialPlanExecutor,
    ThreadPlanExecutor,
    make_fleet,
    run_campaign,
)
from repro.fl.toy import make_tiny_lm
from repro.optim import sgd

VOCAB = 64
DIM = 16
SEQ = 8

tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)


def _build(seed=0, n_clients=5, engine=None, scenarios=True):
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n_clients, max_batches=8)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, n_clients, 400, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    T = sum(d.max_batches for d in fleet) // 2
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(seed)),
        client_optimizer=sgd(0.3),
        estimator=est,
        algorithm="auto",
        scenario_T_candidates=[T // 2, T] if scenarios else None,
        scenario_dropouts=[[0], [1]] if scenarios else None,
        engine=engine if engine is not None else SweepEngine(),
    )
    return server, examples, rng, T


# ---------------------------------------------------------------------------
# determinism: pipelined == serial, bit for bit
# ---------------------------------------------------------------------------


def test_pipelined_campaign_bit_identical_to_serial():
    server_s, ex_s, rng_s, T = _build(seed=0)
    h_serial = run_campaign(server_s, ex_s, 3, round_T=T, batch_size=4, rng=rng_s)

    server_p, ex_p, rng_p, _ = _build(seed=0)
    h_pipe = AsyncCampaignRunner(server_p).run(ex_p, 3, T, 4, rng_p)

    assert len(h_serial.rounds) == len(h_pipe.rounds) == 3
    for a, b in zip(h_serial.rounds, h_pipe.rounds):
        np.testing.assert_array_equal(a.assignments, b.assignments)
        assert a.mean_loss == b.mean_loss
        assert a.energy_joules == b.energy_joules
        assert a.estimated_joules == b.estimated_joules
        assert a.makespan_joules == b.makespan_joules
        assert a.scenarios.labels == b.scenarios.labels
        np.testing.assert_array_equal(a.scenarios.assignments, b.scenarios.assignments)
        np.testing.assert_array_equal(a.scenarios.energies, b.scenarios.energies)
    np.testing.assert_array_equal(h_serial.losses, h_pipe.losses)
    assert h_serial.total_energy == h_pipe.total_energy
    # both plan the same solves: identical engine traffic on fresh engines
    assert h_serial.dp_cache_stats == h_pipe.dp_cache_stats
    # the final models match too (aggregation is part of the shared path)
    for pa, pb in zip(jax.tree.leaves(server_s.params), jax.tree.leaves(server_p.params)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_pipeline_stats_observability():
    server, ex, rng, T = _build(seed=1)
    hist = run_campaign(server, ex, 2, round_T=T, batch_size=4, rng=rng, pipelined=True)
    stats = hist.pipeline_stats
    assert stats.mode == "pipelined"
    assert len(stats.round_wall_s) == 2
    assert stats.planner_busy_s > 0.0
    assert 0.0 <= stats.overlap_fraction <= 1.0
    # plan + scenario task per round, all recorded by label
    labels = [t["label"] for t in stats.tasks]
    assert labels == ["plan[0]", "scenarios[0]", "plan[1]", "scenarios[1]"]
    summary = hist.summary()
    assert summary["pipeline_mode"] == "pipelined"
    assert "planner_overlap_fraction" in summary
    # serial mode reports zero overlap by construction
    server2, ex2, rng2, _ = _build(seed=1)
    h2 = run_campaign(server2, ex2, 2, round_T=T, batch_size=4, rng=rng2)
    assert h2.pipeline_stats.mode == "serial"
    assert h2.pipeline_stats.overlap_fraction == 0.0


# ---------------------------------------------------------------------------
# crash propagation + thread hygiene
# ---------------------------------------------------------------------------


class _BoomEngine(SweepEngine):
    def dispatch(self, problems, split_regimes=False):
        raise RuntimeError("boom: scenario solve exploded")


def _planner_threads():
    return [t for t in threading.enumerate() if t.name.startswith("fl-planner")]


def test_planner_thread_exception_propagates():
    server, ex, rng, T = _build(seed=2, engine=_BoomEngine())
    with pytest.raises(RuntimeError, match="boom"):
        run_campaign(server, ex, 3, round_T=T, batch_size=4, rng=rng, pipelined=True)
    # the planner thread is joined even on failure
    assert _planner_threads() == []


def test_serial_mode_raises_same_error():
    server, ex, rng, T = _build(seed=2, engine=_BoomEngine())
    with pytest.raises(RuntimeError, match="boom"):
        run_campaign(server, ex, 3, round_T=T, batch_size=4, rng=rng)


def test_planner_thread_cleanup_on_success():
    server, ex, rng, T = _build(seed=3)
    AsyncCampaignRunner(server).run(ex, 2, T, 4, rng)
    assert _planner_threads() == []


# ---------------------------------------------------------------------------
# executor / future contracts
# ---------------------------------------------------------------------------


def test_serial_executor_runs_inline_and_counts_blocked():
    ex = SerialPlanExecutor()
    ran = []
    f = ex.submit("t", lambda v: ran.append(v) or v * 2, 21)
    assert ran == [21]  # inline at submit time
    assert f.done() and f.result() == 42
    assert f.blocked_s == f.busy_s  # serial planning is fully on the hot path


def test_thread_executor_fifo_and_shutdown():
    ex = ThreadPlanExecutor(name="fl-planner-test")
    order = []

    def task(i):
        time.sleep(0.005)
        order.append(i)
        return i

    futs = [ex.submit(f"t{i}", task, i) for i in range(5)]
    assert [f.result() for f in futs] == list(range(5))
    assert order == list(range(5))  # FIFO: submission order == execution order
    ex.shutdown()
    assert not any(t.name == "fl-planner-test" for t in threading.enumerate())


def test_plan_future_reraises():
    ex = ThreadPlanExecutor(name="fl-planner-test2")
    try:
        f = ex.submit("bad", lambda: (_ for _ in ()).throw(ValueError("nope")))
        with pytest.raises(ValueError, match="nope"):
            f.result()
        with pytest.raises(ValueError, match="nope"):  # sticky
            f.result()
    finally:
        ex.shutdown()


def test_campaign_runner_rejects_unknown_mode():
    server, _, _, _ = _build(seed=4, scenarios=False)
    with pytest.raises(ValueError, match="unknown pipeline mode"):
        CampaignRunner(server, mode="warp")


# ---------------------------------------------------------------------------
# SweepEngine.dispatch handle
# ---------------------------------------------------------------------------


def test_sweep_dispatch_matches_solve():
    rng = np.random.default_rng(0)
    problems = []
    for _ in range(3):
        n, T = 4, 12
        upper = rng.integers(4, 9, n)
        tables = tuple(np.cumsum(rng.uniform(0.5, 2.0, u + 1)) - 1 for u in upper)
        problems.append(
            Problem(T=T, lower=np.zeros(n, dtype=int), upper=upper, cost_tables=tables)
        )
    eng = SweepEngine()
    handle = eng.dispatch(problems)
    X = handle.result()
    assert handle.done()
    assert X is handle.result()  # memoized
    np.testing.assert_array_equal(X, eng.solve(problems))
    assert isinstance(PlanFuture, type)  # exported symbol sanity
