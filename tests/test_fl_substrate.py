"""Integration tests: FL substrate (client scan masking, FedAvg aggregation,
energy accounting, estimator), optimizers, data pipeline, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import client_corpora, dirichlet_sizes, lm_round_batches, make_lm_examples
from repro.fl import EnergyEstimator, FederatedServer, make_fleet, run_campaign
from repro.fl.client import local_train
from repro.fl.toy import make_tiny_lm
from repro.optim import adafactor, adamw, apply_updates, momentum, sgd

VOCAB = 64
DIM = 16
SEQ = 8

# batch: (B, SEQ+1) int tokens
tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("opt_name", ["sgd", "momentum", "adamw", "adafactor"])
def test_optimizers_reduce_loss(opt_name):
    opt = {"sgd": sgd(0.5), "momentum": momentum(0.3), "adamw": adamw(0.05), "adafactor": adafactor(0.05)}[opt_name]
    key = jax.random.PRNGKey(0)
    params = tiny_lm_init(key)
    batch = jax.random.randint(jax.random.PRNGKey(1), (8, SEQ + 1), 0, VOCAB)
    state = opt.init(params)
    l0 = tiny_lm_loss(params, batch)
    for _ in range(20):
        loss, grads = jax.value_and_grad(tiny_lm_loss)(params, batch)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    l1 = tiny_lm_loss(params, batch)
    assert float(l1) < float(l0)
    assert np.isfinite(float(l1))


# ---------------------------------------------------------------------------
# client masking
# ---------------------------------------------------------------------------


def test_local_train_masking_exact():
    """num_steps=k must equal an unmasked k-step run; steps beyond k are no-ops."""
    key = jax.random.PRNGKey(0)
    params = tiny_lm_init(key)
    batches = jax.random.randint(jax.random.PRNGKey(2), (5, 4, SEQ + 1), 0, VOCAB)
    opt = sgd(0.1)

    p3, _ = local_train(tiny_lm_loss, opt, params, batches, jnp.asarray(3))
    # manual 3 steps
    q = params
    for s in range(3):
        _, g = jax.value_and_grad(tiny_lm_loss)(q, batches[s])
        u, _ = opt.update(g, (), q)
        q = apply_updates(q, u)
    for a, b in zip(jax.tree.leaves(p3), jax.tree.leaves(q)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-6)

    p0, loss0 = local_train(tiny_lm_loss, opt, params, batches, jnp.asarray(0))
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(loss0) == 0.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_pipeline_shapes_and_coverage():
    rng = np.random.default_rng(0)
    corpora = client_corpora(rng, n_clients=4, tokens_per_client=500, vocab_size=VOCAB)
    sizes = dirichlet_sizes(rng, 4, 2000, alpha=0.5)
    assert sizes.sum() == 2000 and np.all(sizes >= 1)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    for ex in examples:
        assert ex.shape[1] == SEQ + 1
    b0 = lm_round_batches(examples, max_steps=6, batch_size=4, round_index=0)
    b1 = lm_round_batches(examples, max_steps=6, batch_size=4, round_index=1)
    assert b0.shape == (4, 6, 4, SEQ + 1)
    assert not np.array_equal(b0, b1)  # rounds advance through the corpus


# ---------------------------------------------------------------------------
# end-to-end FL
# ---------------------------------------------------------------------------


def _make_campaign(algorithm, n_clients=5, rounds=4, seed=0):
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n_clients, max_batches=8)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, n_clients, 400, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(seed)),
        client_optimizer=sgd(0.3),
        estimator=est,
        algorithm=algorithm,
    )
    T = sum(d.max_batches for d in fleet) // 2
    hist = run_campaign(server, examples, rounds, round_T=T, batch_size=4, rng=rng)
    return hist


def test_fl_campaign_trains_and_accounts_energy():
    hist = _make_campaign("auto")
    assert len(hist.rounds) == 4
    # loss decreases over the campaign
    assert hist.rounds[-1].mean_loss < hist.rounds[0].mean_loss
    # energy accounting is positive and assignments sum to T each round
    for r in hist.rounds:
        assert r.energy_joules > 0
        assert r.assignments.sum() == hist.rounds[0].assignments.sum()


def test_fl_energy_scheduler_beats_uniform():
    h_opt = _make_campaign("auto", seed=3)
    h_uni = _make_campaign("uniform", seed=3)
    assert h_opt.total_energy < h_uni.total_energy
    # and the model still trains comparably (not a degenerate schedule)
    assert np.isfinite(h_opt.losses).all()


def test_estimator_tracks_truth():
    rng = np.random.default_rng(1)
    fleet = make_fleet(rng, 4, max_batches=10)
    est = EnergyEstimator(fleet)
    est.calibrate(rng, probe_points=6)
    for i, dev in enumerate(fleet):
        true = dev.true_table()
        got = est._tables[i]
        # within 25% at the top end after calibration
        assert got[-1] == pytest.approx(true[-1], rel=0.35)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    params = tiny_lm_init(jax.random.PRNGKey(0))
    tree = {"params": params, "step": jnp.asarray(7), "nested": [jnp.ones(3), {"a": jnp.zeros((2, 2))}]}
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "hi"})
    restored, manifest = load_checkpoint(str(tmp_path), 7, tree)
    assert manifest["extra"]["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fl_round_with_device_dropout():
    """Dropped devices get zero work; the round still trains and accounts
    energy only for participants (paper §6 future-work item)."""
    rng = np.random.default_rng(9)
    n = 5
    fleet = make_fleet(rng, n, max_batches=8)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, n, 400, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(0)),
        client_optimizer=sgd(0.3),
        estimator=est,
        algorithm="auto",
    )
    server.round_T = sum(d.max_batches for d in fleet) // 2
    from repro.data import lm_round_batches

    batches = lm_round_batches(examples, max(d.max_batches for d in fleet), 4, 0)
    res = server.run_round(0, batches, rng, unavailable=[1, 3])
    assert res.assignments[1] == 0 and res.assignments[3] == 0
    assert res.assignments.sum() > 0
    assert res.energy_joules > 0
    # extreme: all but one drop -> workload shrinks to survivor capacity
    res2 = server.run_round(1, batches, rng, unavailable=[0, 1, 2, 3])
    assert res2.assignments[4] == res2.assignments.sum() > 0
