"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and finiteness; decode-capable archs also run a
prefill + two decode steps and check cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.models import (
    decode_fn,
    init_cache,
    init_params,
    loss_fn,
    make_dummy_batch,
    param_count,
    prefill_fn,
    supports_mode,
)
from repro.optim import apply_updates, get_optimizer

ARCHS = list_archs()
B, S = 2, 32


def _setup(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    return cfg, params, rng


def test_all_archs_registered():
    assert set(ARCHS) == {
        "xlstm-1.3b", "zamba2-2.7b", "granite-20b", "paligemma-3b",
        "olmoe-1b-7b", "hubert-xlarge", "deepseek-v3-671b", "deepseek-7b",
        "gemma2-2b", "minitron-8b",
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg, params, rng = _setup(arch)
    batch = make_dummy_batch(cfg, B, S, "train", rng)
    opt = get_optimizer("sgd", 0.1)
    state = opt.init(params)

    @jax.jit
    def train_step(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss

    p1, state, l0 = train_step(params, state, batch)
    assert np.isfinite(float(l0)), f"{arch} loss not finite"
    p2, state, l1 = train_step(p1, state, batch)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0) + 1.0  # sanity: not exploding
    # at least one param changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1))
    )
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_smoke(arch):
    cfg, params, rng = _setup(arch)
    batch = make_dummy_batch(cfg, B, S, "prefill", rng)
    logits = jax.jit(lambda p, b: prefill_fn(p, cfg, b))(params, batch)
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits)).all(), f"{arch} prefill logits not finite"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_smoke(arch):
    cfg, params, rng = _setup(arch)
    shape = INPUT_SHAPES["decode_32k"]
    ok, reason = supports_mode(cfg, shape)
    if not ok:
        pytest.skip(reason)
    cfg = cfg.replace(moe_impl="einsum") if cfg.num_experts else cfg
    cache = init_cache(cfg, B, S)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)).astype(np.int32))

    @jax.jit
    def step(params, cache, tok, pos):
        return decode_fn(params, cfg, cache, tok, pos)

    logits0, cache = step(params, cache, tok, jnp.asarray(0, jnp.int32))
    assert logits0.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits0)).all(), f"{arch} decode logits not finite"
    logits1, cache = step(params, cache, tok, jnp.asarray(1, jnp.int32))
    assert np.isfinite(np.asarray(logits1)).all()
    # decoding at a later position must differ (state/cache advanced)
    assert not np.allclose(np.asarray(logits0), np.asarray(logits1))


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma2-2b", "xlstm-1.3b", "zamba2-2.7b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode step-by-step must match the parallel forward."""
    cfg, params, rng = _setup(arch)
    T = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)).astype(np.int32))
    full_logits = prefill_fn(params, cfg, {"tokens": tokens})
    cache = init_cache(cfg, B, T)
    outs = []
    for t in range(T):
        lg, cache = decode_fn(params, cfg, cache, tokens[:, t : t + 1], jnp.asarray(t, jnp.int32))
        outs.append(lg)
    step_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )


def test_param_counts_full_configs():
    """Full-config param counts are in the right ballpark (name ~ size)."""
    import math

    expectations = {
        "deepseek-7b": (6e9, 8.5e9),
        "gemma2-2b": (2e9, 3.5e9),
        "granite-20b": (18e9, 24e9),
        "minitron-8b": (7e9, 10.5e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "xlstm-1.3b": (1.0e9, 1.8e9),
        "zamba2-2.7b": (2.2e9, 3.4e9),
        "hubert-xlarge": (0.8e9, 1.3e9),
        "paligemma-3b": (2.2e9, 3.5e9),  # text tower only (vision stubbed)
        "deepseek-v3-671b": (580e9, 720e9),
    }
    from repro.configs.base import INPUT_SHAPES  # noqa

    for arch, (lo, hi) in expectations.items():
        cfg = get_config(arch)
        n = _analytic_param_count(cfg)
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"


def _analytic_param_count(cfg):
    """Counts params analytically from the config (no allocation)."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    hd = cfg.hd
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    total = V * d  # embedding
    if not cfg.tie_embeddings and cfg.family != "encoder":
        total += d * V
    if cfg.family == "encoder":
        total += cfg.frame_dim * d + d * V + d
    if cfg.family == "vlm":
        total += cfg.patch_dim * d

    def attn_params():
        return d * H * hd + 2 * d * Hkv * hd + H * hd * d

    def mla_params():
        qr, kr, rd = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
        nd, vd = cfg.hd, cfg.v_head_dim
        return (
            d * qr + qr * H * (nd + rd) + d * kr + kr * H * (nd + vd) + d * rd + H * vd * d
        )

    def mlp_params(f):
        mult = 3 if cfg.mlp_kind.startswith("gated") else 2
        return mult * d * f

    if cfg.family in ("dense", "vlm"):
        total += L * (attn_params() + mlp_params(cfg.d_ff))
    elif cfg.family == "encoder":
        total += L * (attn_params() + mlp_params(cfg.d_ff))
    elif cfg.family == "moe":
        n_moe = L - cfg.dense_prefix_layers
        a = mla_params() if cfg.use_mla else attn_params()
        moe_ffn = cfg.num_experts * 3 * d * cfg.d_ff_expert + d * cfg.num_experts
        if cfg.num_shared_experts:
            moe_ffn += 3 * d * cfg.d_ff_expert * cfg.num_shared_experts
        total += n_moe * (a + moe_ffn)
        total += cfg.dense_prefix_layers * (a + 3 * d * cfg.d_ff)
        if cfg.use_mtp:
            total += 2 * d * d + (a + 3 * d * cfg.d_ff)
    elif cfg.family == "ssm":
        inner = cfg.ssm_expand * d
        DV = inner // H
        DK = DV // 2
        m = d * 2 * inner + H * DV * (2 * DK + DV) + 2 * inner * H + inner * d
        s = d * 4 * d + 4 * (d // H) * d + d * d
        per_group = (cfg.slstm_every - 1) * m + s
        total += (L // cfg.slstm_every) * per_group
    elif cfg.family == "hybrid":
        inner = cfg.ssm_expand * d
        Hm = inner // cfg.ssm_head_dim
        N = cfg.ssm_state
        conv_dim = inner + 2 * N
        m = d * (2 * inner + 2 * N + Hm) + cfg.ssm_conv * conv_dim + inner * d
        total += L * m
        total += attn_params() + mlp_params(cfg.d_ff) + 2 * d * d  # shared block
    return total


def test_gemma2_windowed_decode_matches_prefill():
    """Long-context sliding-window decode (cache slice path) must stay exact:
    teacher-forced decode == parallel forward with small window << S_max."""
    cfg = get_config("gemma2-2b", smoke=True).replace(window=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    T = 16  # S_max 16 > 2*window -> windowed slice path active
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, T)).astype(np.int32))
    full_logits = prefill_fn(params, cfg, {"tokens": tokens})
    cache = init_cache(cfg, 2, T)
    outs = []
    for t in range(T):
        lg, cache = decode_fn(params, cfg, cache, tokens[:, t : t + 1], jnp.asarray(t, jnp.int32))
        outs.append(lg)
    step_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )
