"""Batched scheduling engine (DESIGN.md §9): ProblemBatch packing, the
vmapped/stacked min-plus DP, batched backtracking, dispatch, sweeps, and the
FL scenario-planning hook.

Core claim under test: ``solve_schedule_dp_batch`` over B stacked instances
is EQUIVALENT to looping the per-instance solvers — bit-identical schedules
vs ``solve_schedule_dp_jax`` (same float32 program, same tie-breaking) and
equal assignments/costs vs the numpy ``solve_schedule_dp``, across mixed
regimes and ragged ``n`` / ``U_i`` / ``T``.
"""

import numpy as np
import pytest

from repro.core import (
    Problem,
    ProblemBatch,
    deadline_sweep,
    random_problem,
    remove_lower_limits,
    schedule_batch,
    solve_schedule_dp,
    solve_schedule_dp_batch,
    solve_schedule_dp_jax,
    total_cost,
    total_cost_batch,
    validate_schedule,
    validate_schedule_batch,
)

REGIMES = ("arbitrary", "linear", "increasing", "decreasing")


def random_mixed_problems(rng, B, max_n=6, max_T=24):
    """B instances with ragged n, ragged U_i, ragged T, mixed regimes."""
    out = []
    for b in range(B):
        n = int(rng.integers(1, max_n + 1))
        T = int(rng.integers(max(1, n), max_T + 1))
        out.append(random_problem(rng, n=n, T=T, regime=REGIMES[b % len(REGIMES)]))
    return out


# ---------------------------------------------------------------------------
# ProblemBatch packing
# ---------------------------------------------------------------------------


def test_problem_batch_roundtrip():
    rng = np.random.default_rng(11)
    probs = random_mixed_problems(rng, 7)
    batch = ProblemBatch.from_problems(probs)
    assert batch.B == 7
    assert batch.n == max(p.n for p in probs)
    assert batch.W == max(int(p.upper.max()) for p in probs) + 1
    for b, p in enumerate(probs):
        q = batch.instance(b)
        assert q.T == p.T
        assert np.array_equal(q.lower[: p.n], p.lower)
        assert np.array_equal(q.upper[: p.n], p.upper)
        for i in range(p.n):
            np.testing.assert_allclose(q.cost_tables[i], p.cost_tables[i])
        # padded resources can only take 0 tasks at 0 cost
        for i in range(p.n, batch.n):
            assert int(q.upper[i]) == 0 and float(q.cost_tables[i][0]) == 0.0


def test_problem_batch_lower_limit_removal_matches_per_instance():
    rng = np.random.default_rng(12)
    probs = random_mixed_problems(rng, 9)
    batch = ProblemBatch.from_problems(probs)
    b0 = remove_lower_limits(batch)
    assert np.all(b0.lower == 0)
    for b, p in enumerate(probs):
        p0 = remove_lower_limits(p)
        assert int(b0.T[b]) == p0.T
        assert np.array_equal(b0.upper[b, : p.n], p0.upper)
        for i in range(p.n):
            u = int(p0.upper[i])
            np.testing.assert_allclose(
                b0.costs[b, i, : u + 1], p0.cost_tables[i][: u + 1]
            )


def test_problem_batch_validation_errors():
    rng = np.random.default_rng(13)
    p = random_problem(rng, n=3, T=8, regime="linear")
    with pytest.raises(ValueError):
        ProblemBatch.from_problems([])
    batch = ProblemBatch.from_problems([p])
    bad = ProblemBatch(
        T=np.array([10**6]), lower=batch.lower, upper=batch.upper, costs=batch.costs
    )
    with pytest.raises(ValueError):
        bad.validate()


# ---------------------------------------------------------------------------
# Batched DP == per-instance solvers (randomized, mixed regimes, ragged)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B", [1, 7, 32])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_batch_dp_equals_per_instance(B, seed):
    rng = np.random.default_rng(100 + seed)
    probs = random_mixed_problems(rng, B)
    X = solve_schedule_dp_batch(probs)
    assert X.shape == (B, max(p.n for p in probs))
    for b, p in enumerate(probs):
        row = X[b, : p.n]
        validate_schedule(p, row)
        # padded resources are always assigned 0
        assert np.all(X[b, p.n :] == 0)
        # bit-identical vs the per-instance jitted solver
        assert np.array_equal(row, solve_schedule_dp_jax(p)), (b, row)
        # equal cost (and, with float32-safe tables, equal schedule) vs numpy
        x_np = solve_schedule_dp(p)
        assert total_cost(p, row) == pytest.approx(total_cost(p, x_np), rel=1e-5)


def test_batch_dp_prebuilt_batch_and_costs():
    rng = np.random.default_rng(42)
    probs = random_mixed_problems(rng, 5)
    batch = ProblemBatch.from_problems(probs)
    X = solve_schedule_dp_batch(batch)
    validate_schedule_batch(batch, X)
    tc = total_cost_batch(batch, X)
    for b, p in enumerate(probs):
        assert tc[b] == pytest.approx(total_cost(p, X[b, : p.n]), rel=1e-12)


def test_batch_dp_ragged_T_uses_per_instance_t_star():
    """Same fleet, very different workloads: padding to T_max must not leak
    across instances."""
    rng = np.random.default_rng(7)
    base = random_problem(rng, n=5, T=40, regime="arbitrary", with_lower=False)
    probs = [
        Problem(T=t, lower=base.lower, upper=base.upper, cost_tables=base.cost_tables)
        for t in (1, 7, 23, 40)
    ]
    X = solve_schedule_dp_batch(probs)
    for b, p in enumerate(probs):
        assert int(X[b].sum()) == p.T
        assert np.array_equal(X[b], solve_schedule_dp_jax(p))


def test_batch_dp_with_lower_limits():
    rng = np.random.default_rng(8)
    probs = [random_problem(rng, n=4, T=16, regime="arbitrary") for _ in range(6)]
    assert any(int(p.lower.sum()) > 0 for p in probs)
    X = solve_schedule_dp_batch(probs)
    for b, p in enumerate(probs):
        validate_schedule(p, X[b, : p.n])
        assert total_cost(p, X[b, : p.n]) == pytest.approx(
            total_cost(p, solve_schedule_dp(p)), rel=1e-5
        )


# ---------------------------------------------------------------------------
# Batched Pallas kernel vs batched reference
# ---------------------------------------------------------------------------


def _random_rows(rng, B, Tp, W):
    k = rng.uniform(0, 100, size=(B, Tp)).astype(np.float32)
    k[rng.random((B, Tp)) < 0.3] = 1e30
    k[:, 0] = 0.0
    c = rng.uniform(0, 10, size=(B, W)).astype(np.float32)
    c[rng.random((B, W)) < 0.1] = 1e30
    return k, c


@pytest.mark.parametrize("B,Tp,W,BT", [
    (1, 64, 16, 32),
    (4, 70, 33, 32),
    pytest.param(8, 255, 64, 64, marks=pytest.mark.slow),  # larger interpret-mode sweep
])
def test_batched_pallas_matches_batched_ref(B, Tp, W, BT):
    from repro.kernels import minplus_pallas_batch, minplus_step_ref_batch

    rng = np.random.default_rng(B * 1000 + Tp + W)
    k, c = _random_rows(rng, B, Tp, W)
    rv, ri = minplus_step_ref_batch(k, c)
    pv, pi = minplus_pallas_batch(k, c, BT=BT, interpret=True)
    np.testing.assert_allclose(np.asarray(pv), np.asarray(rv), rtol=1e-6)
    # argmin: reconstructed value must equal the min (ties may differ)
    pi = np.asarray(pi)
    src = np.arange(Tp)[None, :] - pi
    ok = src >= 0
    rows = np.arange(B)[:, None]
    recon = np.where(
        ok, k[rows, np.maximum(src, 0)] + np.take_along_axis(c, pi, axis=1), 1e30
    )
    recon = np.minimum(recon, 1e30)
    np.testing.assert_allclose(recon, np.asarray(rv), rtol=1e-6)


def test_batched_ref_matches_unbatched_rows():
    from repro.kernels import minplus_step_ref, minplus_step_ref_batch

    rng = np.random.default_rng(3)
    k, c = _random_rows(rng, 6, 90, 40)
    bv, bi = minplus_step_ref_batch(k, c)
    for b in range(6):
        v, i = minplus_step_ref(k[b], c[b])
        np.testing.assert_array_equal(np.asarray(bv)[b], np.asarray(v))
        np.testing.assert_array_equal(np.asarray(bi)[b], np.asarray(i))


def test_batch_dp_pallas_backend_end_to_end():
    rng = np.random.default_rng(9)
    probs = [random_problem(rng, n=3, T=10, regime=r) for r in ("arbitrary", "decreasing")]
    Xp = solve_schedule_dp_batch(probs, backend="pallas")
    Xr = solve_schedule_dp_batch(probs, backend="ref")
    for b, p in enumerate(probs):
        validate_schedule(p, Xp[b, : p.n])
        assert total_cost(p, Xp[b, : p.n]) == pytest.approx(
            total_cost(p, Xr[b, : p.n]), rel=1e-5
        )


# ---------------------------------------------------------------------------
# schedule_batch dispatch + deadline_sweep
# ---------------------------------------------------------------------------


def test_schedule_batch_auto_dispatch_optimal():
    rng = np.random.default_rng(20)
    probs = random_mixed_problems(rng, 12)
    xs = schedule_batch(probs, "auto")
    assert len(xs) == len(probs)
    for p, x in zip(probs, xs):
        validate_schedule(p, x)
        assert total_cost(p, x) == pytest.approx(
            total_cost(p, solve_schedule_dp(p)), rel=1e-5, abs=1e-9
        )


def test_schedule_batch_named_algorithms():
    rng = np.random.default_rng(21)
    probs = [random_problem(rng, n=4, T=15, regime="increasing") for _ in range(4)]
    for alg in ("dp_batch", "marin", "olar"):
        xs = schedule_batch(probs, alg)
        for p, x in zip(probs, xs):
            validate_schedule(p, x)
    with pytest.raises(ValueError):
        schedule_batch(probs, "no_such_algorithm")
    assert schedule_batch([]) == []


def test_deadline_sweep_matches_looped_and_is_monotone():
    from repro.core.scheduler import schedule_with_deadline

    rng = np.random.default_rng(22)
    n, T = 5, 30
    p = random_problem(rng, n=n, T=T, regime="increasing")
    speeds = rng.uniform(0.5, 3.0, size=n)
    times = [np.arange(int(u) + 1) / s for u, s in zip(p.upper, speeds)]
    x_free = solve_schedule_dp(p)
    d_max = max(float(times[i][int(x_free[i])]) for i in range(n))
    deadlines = [d_max * f for f in (1.0, 1.5, 2.5, 10.0)]

    X = deadline_sweep(p, times, deadlines)
    assert X.shape == (len(deadlines), n)
    prev = None
    for d, x in zip(deadlines, X):
        validate_schedule(p, x)
        for i in range(n):
            assert times[i][int(x[i])] <= d + 1e-9
        x_loop = schedule_with_deadline(p, times, d, algorithm="dp_jax")
        assert total_cost(p, x) == pytest.approx(total_cost(p, x_loop), rel=1e-5)
        e = total_cost(p, x)
        assert prev is None or e <= prev + 1e-9
        prev = e


def test_deadline_sweep_infeasible_point_raises():
    rng = np.random.default_rng(23)
    p = random_problem(rng, n=3, T=10, regime="linear")
    times = [np.arange(int(u) + 1) * 1.0 for u in p.upper]
    with pytest.raises(ValueError, match="deadline_sweep point"):
        deadline_sweep(p, times, [100.0, 0.5])


# ---------------------------------------------------------------------------
# FL scenario-planning hook
# ---------------------------------------------------------------------------


def test_server_scenario_planning_hook():
    import jax.numpy as jnp

    from repro.fl import EnergyEstimator, FederatedServer, make_fleet
    from repro.fl.server import apply_dropout
    from repro.optim.optimizers import sgd

    rng = np.random.default_rng(0)
    fleet = make_fleet(rng, 6, max_batches=12)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)

    def loss_fn(params, batch):
        return jnp.mean((params["w"] * batch[..., 0] - batch[..., 1]) ** 2)

    server = FederatedServer(
        loss_fn,
        {"w": jnp.ones(())},
        sgd(1e-2),
        est,
        round_T=20,
        scenario_T_candidates=[10, 30, 10**9],  # last one clamps to capacity
        scenario_dropouts=[(0,), (1, 2)],
    )
    batches = rng.normal(size=(6, 4, 2, 2)).astype(np.float32)
    res = server.run_round(0, batches, rng)
    assert res.scenarios is not None
    rep = res.scenarios
    assert len(rep.labels) == 5
    assert rep.assignments.shape == (5, 6)
    assert rep.energies.shape == (5,)
    # each scenario's schedule is optimal for its instance
    cap = sum(d.max_batches for d in fleet)
    base = est.problem(20)
    expected = [
        est.problem(10),
        est.problem(30),
        est.problem(cap),
        apply_dropout(base, (0,)),
        apply_dropout(base, (1, 2)),
    ]
    for b, p in enumerate(expected):
        validate_schedule(p, rep.assignments[b])
        assert rep.energies[b] == pytest.approx(
            total_cost(p, solve_schedule_dp(p)), rel=1e-5
        )
    # dropout scenarios assign nothing to dropped clients
    assert rep.assignments[3, 0] == 0
    assert rep.assignments[4, 1] == 0 and rep.assignments[4, 2] == 0


def test_server_explicit_round_T_param():
    import jax.numpy as jnp

    from repro.fl import EnergyEstimator, FederatedServer, make_fleet
    from repro.optim.optimizers import sgd

    rng = np.random.default_rng(1)
    fleet = make_fleet(rng, 4, max_batches=10)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)

    def loss_fn(params, batch):
        return jnp.mean((params["w"] * batch[..., 0] - batch[..., 1]) ** 2)

    server = FederatedServer(loss_fn, {"w": jnp.ones(())}, sgd(1e-2), est, round_T=12)
    batches = rng.normal(size=(4, 4, 2, 2)).astype(np.float32)
    res = server.run_round(0, batches, rng)
    assert res.scenarios is None
    assert int(res.assignments.sum()) == 12
    # None falls back to half the round-tensor capacity, and the attribute
    # can still be set post-construction (run_campaign does this)
    server2 = FederatedServer(loss_fn, {"w": jnp.ones(())}, sgd(1e-2), est)
    assert server2.round_T is None
    server2.round_T = 8
    res2 = server2.run_round(0, batches, rng)
    assert int(res2.assignments.sum()) == 8
