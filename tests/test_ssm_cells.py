"""Chunked/parallel forms must equal sequential step-by-step execution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import (
    causal_conv1d,
    causal_conv1d_step,
    mlstm_chunked,
    mlstm_step,
    slstm_scan,
    ssd_chunked,
    ssd_step,
)


def test_causal_conv_streaming():
    rng = np.random.default_rng(0)
    B, L, C, K = 2, 12, 5, 4
    x = jnp.asarray(rng.normal(size=(B, L, C)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, C)).astype(np.float32))
    y_full, state = causal_conv1d(x, w)
    # streaming: one step at a time
    st = jnp.zeros((B, K - 1, C))
    ys = []
    for t in range(L):
        y_t, st = causal_conv1d_step(x[:, t : t + 1], w, st)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st), rtol=1e-5)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_vs_sequential(chunk):
    rng = np.random.default_rng(1)
    B, L, H, P, N = 2, 16, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(B, L, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(B, L, H)).astype(np.float32))
    A = jnp.asarray(-rng.uniform(0.1, 1.0, size=(H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))

    y_chunk, st_chunk = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)

    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        y_t, st = ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], st)
        ys.append(y_t)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk), np.asarray(st), rtol=2e-4, atol=2e-4)


def test_ssd_state_carry():
    """Running two chunked segments with carried state == one long run."""
    rng = np.random.default_rng(2)
    B, L, H, P, N = 1, 16, 2, 3, 4
    x = jnp.asarray(rng.normal(size=(B, L, H, P)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(B, L, H)).astype(np.float32))
    A = jnp.asarray(-rng.uniform(0.1, 1.0, size=(H,)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, L, N)).astype(np.float32))
    y_all, st_all = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y1, st1 = ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], chunk=8)
    y2, st2 = ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:], chunk=8, state=st1)
    np.testing.assert_allclose(np.asarray(y_all), np.asarray(jnp.concatenate([y1, y2], 1)), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_all), np.asarray(st2), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8])
def test_mlstm_chunked_vs_sequential(chunk):
    rng = np.random.default_rng(3)
    B, L, H, DK, DV = 2, 16, 2, 4, 6
    q = jnp.asarray(rng.normal(size=(B, L, H, DK)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, L, H, DK)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, L, H, DV)).astype(np.float32))
    i_pre = jnp.asarray(rng.normal(size=(B, L, H)).astype(np.float32))
    f_pre = jnp.asarray(rng.normal(size=(B, L, H)).astype(np.float32) + 2.0)

    h_chunk, (S, n, m) = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=chunk)

    state = (
        jnp.zeros((B, H, DK, DV)),
        jnp.zeros((B, H, DK)),
        jnp.full((B, H), -1e30),
    )
    hs = []
    for t in range(L):
        h_t, state = mlstm_step(q[:, t], k[:, t], v[:, t], i_pre[:, t], f_pre[:, t], state)
        hs.append(h_t)
    h_seq = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(state[0]), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(state[2]), rtol=3e-4, atol=3e-4)


def test_mlstm_no_nan_extreme_gates():
    rng = np.random.default_rng(4)
    B, L, H, DK, DV = 1, 32, 1, 4, 4
    q = jnp.asarray(rng.normal(size=(B, L, H, DK)).astype(np.float32))
    k = q
    v = jnp.asarray(rng.normal(size=(B, L, H, DV)).astype(np.float32))
    i_pre = jnp.full((B, L, H), 30.0, jnp.float32)  # extreme exp input gate
    f_pre = jnp.full((B, L, H), -30.0, jnp.float32)
    h, _ = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=8)
    assert np.isfinite(np.asarray(h)).all()
    i_pre = jnp.full((B, L, H), -40.0, jnp.float32)
    f_pre = jnp.full((B, L, H), 40.0, jnp.float32)
    h, _ = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=8)
    assert np.isfinite(np.asarray(h)).all()


def test_slstm_runs_and_is_finite():
    rng = np.random.default_rng(5)
    B, L, H, D = 2, 10, 2, 4
    mk = lambda: jnp.asarray(rng.normal(size=(B, L, H, D)).astype(np.float32))
    r = {kname: jnp.asarray(rng.normal(size=(H, D, D)).astype(np.float32) * 0.1) for kname in ("rz", "ri", "rf", "ro")}
    h, final = slstm_scan(mk(), mk(), mk(), mk(), r)
    assert h.shape == (B, L, H, D)
    assert np.isfinite(np.asarray(h)).all()
