"""Deterministic fault injection + mid-round recovery (DESIGN.md §17).

The chaos contract: under every seeded FaultPlan a campaign COMPLETES, its
recovered schedules are bit-identical to a fault-free re-plan of the
surviving cohort, serial and pipelined runs see identical faults (and
produce identical histories under client-fault-only plans), a zero-fault
plan leaves the runtime bit-identical to a plain run, and a killed campaign
resumed from its checkpoint reproduces the uninterrupted run exactly.
"""

import jax
import numpy as np
import pytest

from repro.core import Problem, Solver, total_cost, validate_schedule
from repro.core.resilience import TransientEngineError
from repro.core.sweep import SweepEngine
from repro.data import client_corpora, make_lm_examples
from repro.fl import (
    ClientFault,
    EnergyEstimator,
    FaultInjector,
    FaultPlan,
    FederatedServer,
    FlakyEngine,
    PlanPolicy,
    make_fleet,
    proportional_greedy,
    residual_problem,
    run_campaign,
)
from repro.fl.toy import make_tiny_lm
from repro.optim import sgd

VOCAB = 64
DIM = 16
SEQ = 8

tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)


def _build(seed=0, n_clients=5, engine=None, policy_kwargs=None):
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n_clients, max_batches=8)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, n_clients, 400, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    T = sum(d.max_batches for d in fleet) // 2
    policy = PlanPolicy(
        engine=engine if engine is not None else SweepEngine(),
        **(policy_kwargs or {}),
    )
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(seed)),
        client_optimizer=sgd(0.3),
        estimator=est,
        policy=policy,
    )
    return server, examples, rng, T


def _assert_histories_equal(a, b):
    assert len(a.rounds) == len(b.rounds)
    for ra, rb in zip(a.rounds, b.rounds):
        np.testing.assert_array_equal(ra.assignments, rb.assignments)
        assert ra.mean_loss == rb.mean_loss
        assert ra.energy_joules == rb.energy_joules
        assert ra.estimated_joules == rb.estimated_joules
    np.testing.assert_array_equal(a.losses, b.losses)
    assert a.total_energy == b.total_energy


def _assert_params_equal(pa, pb):
    for x, y in zip(jax.tree_util.tree_leaves(pa), jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# the plan: one integer seed -> one immutable fault schedule
# ---------------------------------------------------------------------------


def test_fault_plan_generation_is_deterministic():
    kw = dict(
        num_rounds=6,
        n_clients=8,
        p_crash=0.3,
        p_straggle=0.3,
        engine_fault_rounds=0.5,
        p_burst=0.4,
    )
    a = FaultPlan.generate(11, **kw)
    b = FaultPlan.generate(11, **kw)
    assert a == b
    assert a != FaultPlan.generate(12, **kw)
    assert a.client_faults  # with these rates the plan is non-trivial
    # the per-round fault cap guarantees a surviving cohort
    for r in range(6):
        hit = [f for f in a.client_faults if f.round_index == r]
        assert len(hit) <= 4


def test_client_fault_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        ClientFault(0, 0, "melt", 0.5)
    with pytest.raises(ValueError, match="completed fraction"):
        ClientFault(0, 0, "crash", 1.5)
    with pytest.raises(ValueError, match="slowdown factor"):
        ClientFault(0, 0, "straggle", 0.5)


def test_round_faults_semantics():
    plan = FaultPlan(
        seed=0,
        client_faults=(
            ClientFault(0, 0, "crash", 0.5),
            ClientFault(0, 1, "straggle", 2.0),
            ClientFault(1, 2, "crash", 0.0),
        ),
    )
    inj = FaultInjector(plan)
    x = np.array([7, 5, 4], dtype=np.int64)
    rf = inj.round_faults(0, x)
    assert rf.crashed == (0,) and rf.stragglers == (1,)
    np.testing.assert_array_equal(rf.completed, [3, 2, 4])
    assert rf.lost_clients == (0, 1)
    # a clean round reports None; so does a fault against an x_i = 0 client
    assert inj.round_faults(2, x) is None
    assert inj.round_faults(1, np.array([3, 3, 0])) is None


def test_burst_schedule_is_deterministic():
    plan = FaultPlan(seed=5, overload_bursts=((1, 3),))
    inj = FaultInjector(plan)
    assert inj.burst(0) == 0 and inj.burst(1) == 3
    p1 = inj.burst_problem(1, 0)
    p2 = FaultInjector(plan).burst_problem(1, 0)
    assert p1.T == p2.T
    for a, b in zip(p1.cost_tables, p2.cost_tables):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# the recovery math: exact residual instance + guaranteed-feasible fallback
# ---------------------------------------------------------------------------


def _instance(rng, n=5, u=9):
    tables = tuple(
        np.concatenate([[0.0], np.cumsum(rng.uniform(0.5, 2.0, u))]) for _ in range(n)
    )
    return Problem(
        T=2 * n,
        lower=np.zeros(n, dtype=np.int64),
        upper=np.full(n, u, dtype=np.int64),
        cost_tables=tables,
    )


def test_residual_problem_is_exact_marginal():
    rng = np.random.default_rng(0)
    p = _instance(rng)
    completed = np.array([2, 0, 3, 1, 0], dtype=np.int64)
    res = residual_problem(p, completed, lost=(1,))
    assert res.T == p.T - int(completed.sum())
    np.testing.assert_array_equal(res.lower, 0)
    assert res.upper[1] == 0  # lost client takes no recovery work
    for i in (0, 2, 3, 4):
        c = int(completed[i])
        np.testing.assert_allclose(
            res.cost_tables[i],
            p.cost_tables[i][c : int(p.upper[i]) + 1] - p.cost_tables[i][c],
        )
    # the residual instance is feasible by construction, even fleet-wide
    res2 = residual_problem(p, completed, lost=(0, 1, 2, 3))
    assert res2.T <= int(res2.upper.sum())


def test_proportional_greedy_is_feasible_and_deterministic():
    rng = np.random.default_rng(3)
    for _ in range(10):
        p = _instance(rng, n=int(rng.integers(2, 7)))
        x = proportional_greedy(p)
        validate_schedule(p, x)
        np.testing.assert_array_equal(x, proportional_greedy(p))
    with pytest.raises(ValueError, match="infeasible fallback"):
        proportional_greedy(
            Problem(
                T=5,
                lower=np.zeros(2, dtype=np.int64),
                upper=np.ones(2, dtype=np.int64),
                cost_tables=(np.array([0.0, 1.0]), np.array([0.0, 1.0])),
            )
        )


def test_recover_round_matches_fault_free_replan_of_survivors():
    """The tentpole invariant: the recovered assignment is bit-identical to
    an INDEPENDENT fault-free solve of the exact residual instance."""
    server, examples, rng, T = _build(seed=2)
    plan = FaultPlan(
        seed=0,
        client_faults=(
            ClientFault(0, 0, "crash", 0.3),
            ClientFault(0, 2, "straggle", 2.5),
        ),
    )
    inj = FaultInjector(plan)
    est_problem = server.build_problem(T)
    rp = server.plan_round(0, T, est_problem)
    rf = inj.round_faults(0, rp.assignments)
    rec = server.recover_round(rp, rf)
    ri = rec.recovery
    assert ri is not None and not ri.fallback and ri.attempts == 1
    # independent re-solve of the carried residual instance, fresh engine
    y_ref = np.asarray(
        Solver(engine=SweepEngine()).solve([ri.residual_problem]).schedules[0],
        np.int64,
    )
    np.testing.assert_array_equal(ri.recovery_assignments, y_ref)
    np.testing.assert_array_equal(rec.assignments, ri.completed + y_ref)
    # lost clients got no recovery work; the effective plan stays feasible
    for i in ri.failed_clients + ri.straggler_clients:
        assert ri.recovery_assignments[i] == 0
    assert (rec.assignments <= est_problem.upper).all()
    assert rec.est_cost == pytest.approx(
        float(total_cost(est_problem, rec.assignments))
    )
    assert rec.est_cost - ri.est_cost_original == pytest.approx(ri.est_overhead_J)


def test_recover_round_persistent_solver_failure_falls_back():
    """When the SOLVER is the failing component, retries exhaust and the
    guaranteed-feasible proportional-greedy fallback engages."""
    flaky = FlakyEngine(SweepEngine(), fail_ordinals=range(100))
    server, examples, rng, T = _build(seed=2, engine=flaky)
    est_problem = server.build_problem(T)
    rp = server.plan_round(0, T, est_problem)  # plain plan: host path, no engine
    victim = int(np.argmax(rp.assignments))  # a client with work to lose
    rf = FaultInjector(
        FaultPlan(seed=0, client_faults=(ClientFault(0, victim, "crash", 0.2),))
    ).round_faults(0, rp.assignments)
    rec = server.recover_round(rp, rf)
    ri = rec.recovery
    assert ri.fallback and ri.attempts == 3
    np.testing.assert_array_equal(
        ri.recovery_assignments, proportional_greedy(ri.residual_problem)
    )
    validate_schedule(ri.residual_problem, ri.recovery_assignments)
    assert flaky.fault_stats()["injected_failures"] == 3


# ---------------------------------------------------------------------------
# campaign-level chaos
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_zero_fault_plan_is_fully_inert():
    server_a, ex_a, rng_a, T = _build(seed=0)
    h_a = run_campaign(server_a, ex_a, 3, round_T=T, batch_size=4, rng=rng_a)
    server_b, ex_b, rng_b, _ = _build(seed=0)
    h_b = run_campaign(
        server_b, ex_b, 3, round_T=T, batch_size=4, rng=rng_b,
        faults=FaultPlan(seed=0),
    )
    _assert_histories_equal(h_a, h_b)
    _assert_params_equal(server_a.params, server_b.params)
    assert "recovered_rounds" not in h_b.summary()


@pytest.mark.chaos
def test_serial_and_pipelined_chaos_campaigns_are_bit_identical():
    # client-fault-only plan: engine-fault ordinals would race across the
    # planner thread in pipelined mode, client faults are plan-indexed data
    plan = FaultPlan.generate(
        seed=13, num_rounds=4, n_clients=5, p_crash=0.4, p_straggle=0.3
    )
    assert plan.client_faults
    server_s, ex_s, rng_s, T = _build(seed=1)
    h_s = run_campaign(
        server_s, ex_s, 4, round_T=T, batch_size=4, rng=rng_s, faults=plan
    )
    server_p, ex_p, rng_p, _ = _build(seed=1)
    h_p = run_campaign(
        server_p, ex_p, 4, round_T=T, batch_size=4, rng=rng_p, faults=plan,
        pipelined=True,
    )
    _assert_histories_equal(h_s, h_p)
    _assert_params_equal(server_s.params, server_p.params)
    rec_s = [r.round_index for r in h_s.rounds if r.recovery is not None]
    rec_p = [r.round_index for r in h_p.rounds if r.recovery is not None]
    assert rec_s == rec_p and rec_s


@pytest.mark.chaos
@pytest.mark.parametrize("seed", [3, 17])
def test_seeded_chaos_campaigns_complete_with_valid_recoveries(seed):
    plan = FaultPlan.generate(
        seed=seed, num_rounds=4, n_clients=6, p_crash=0.35, p_straggle=0.25
    )
    server, examples, rng, T = _build(seed=seed, n_clients=6)
    h = run_campaign(
        server, examples, 4, round_T=T, batch_size=4, rng=rng, faults=plan
    )
    assert len(h.rounds) == 4
    recovered = [r for r in h.rounds if r.recovery is not None]
    assert recovered  # these rates always fault something
    ref = Solver(engine=SweepEngine())
    for r in recovered:
        ri = r.recovery
        y_ref = np.asarray(ref.solve([ri.residual_problem]).schedules[0], np.int64)
        np.testing.assert_array_equal(ri.recovery_assignments, y_ref)
        np.testing.assert_array_equal(r.assignments, ri.completed + y_ref)
    summ = h.summary()
    assert summ["recovered_rounds"] == len(recovered)
    assert summ["recovery_fallbacks"] == 0


@pytest.mark.chaos
def test_transient_engine_faults_leave_campaign_bit_identical():
    """Plan-time transient engine failures are retried/re-planned; the final
    history matches the fault-free run bit for bit (the retried solve is the
    same pure function of the same snapshot)."""
    server_a, ex_a, rng_a, T = _build(seed=4)
    h_a = run_campaign(server_a, ex_a, 3, round_T=T, batch_size=4, rng=rng_a)

    plan = FaultPlan(seed=0, engine_faults=(0, 2))
    inj = FaultInjector(plan)
    flaky = inj.wrap_engine(SweepEngine())
    server_b, ex_b, rng_b, _ = _build(seed=4, engine=flaky)
    h_b = run_campaign(
        server_b, ex_b, 3, round_T=T, batch_size=4, rng=rng_b, faults=inj
    )
    _assert_histories_equal(h_a, h_b)
    _assert_params_equal(server_a.params, server_b.params)


@pytest.mark.chaos
def test_frontier_campaign_replans_through_transient_engine_fault():
    """Frontier-mode planning dispatches through the engine, so an injected
    fault hits the PLAN itself; the runner's re-plan path must recover
    bit-identically (the retried frontier sweep is the same pure function)."""
    def build(engine):
        rng = np.random.default_rng(6)
        fleet = make_fleet(rng, 4, max_batches=8)
        tt = [np.sort(rng.uniform(0.1, 2.0, d.max_batches + 1)) for d in fleet]
        est = EnergyEstimator(fleet)
        est.calibrate(rng)
        corpora = client_corpora(rng, 4, 400, VOCAB)
        examples = [make_lm_examples(c, SEQ) for c in corpora]
        T = sum(d.max_batches for d in fleet) // 2
        server = FederatedServer(
            loss_fn=tiny_lm_loss,
            init_params=tiny_lm_init(jax.random.PRNGKey(6)),
            client_optimizer=sgd(0.3),
            estimator=est,
            policy=PlanPolicy(engine=engine, frontier_mode="knee", time_tables=tt),
        )
        return server, examples, rng, T

    server_a, ex_a, rng_a, T = build(SweepEngine())
    h_a = run_campaign(server_a, ex_a, 3, round_T=T, batch_size=4, rng=rng_a)

    inj = FaultInjector(FaultPlan(seed=0, engine_faults=(0,)))
    server_b, ex_b, rng_b, _ = build(inj.wrap_engine(SweepEngine()))
    h_b = run_campaign(
        server_b, ex_b, 3, round_T=T, batch_size=4, rng=rng_b, faults=inj
    )
    assert server_b.engine.fault_stats()["injected_failures"] == 1
    _assert_histories_equal(h_a, h_b)


@pytest.mark.chaos
def test_killed_campaign_resumes_bit_identically(tmp_path):
    """Round-granular checkpointing: kill the campaign mid-way (an on_round
    crash), resume from the checkpoint directory, and the final params AND
    the full history match the uninterrupted run exactly — faults included."""
    plan = FaultPlan.generate(
        seed=23, num_rounds=5, n_clients=5, p_crash=0.3, p_straggle=0.2
    )
    server_a, ex_a, rng_a, T = _build(seed=5)
    h_a = run_campaign(
        server_a, ex_a, 5, round_T=T, batch_size=4, rng=rng_a, faults=plan
    )

    class _Kill(Exception):
        pass

    def killer(res):
        if res.round_index == 2:
            raise _Kill()

    ckpt = str(tmp_path / "campaign")
    server_b, ex_b, rng_b, _ = _build(seed=5)
    with pytest.raises(_Kill):
        run_campaign(
            server_b, ex_b, 5, round_T=T, batch_size=4, rng=rng_b, faults=plan,
            checkpoint_dir=ckpt, on_round=killer,
        )
    server_c, ex_c, rng_c, _ = _build(seed=5)
    h_c = run_campaign(
        server_c, ex_c, 5, round_T=T, batch_size=4, rng=rng_c, faults=plan,
        checkpoint_dir=ckpt,
    )
    _assert_histories_equal(h_a, h_c)
    _assert_params_equal(server_a.params, server_c.params)
    # recovery provenance survives the checkpoint round-trip
    for ra, rc in zip(h_a.rounds, h_c.rounds):
        assert (ra.recovery is None) == (rc.recovery is None)
        if ra.recovery is not None:
            np.testing.assert_array_equal(
                ra.recovery.recovery_assignments, rc.recovery.recovery_assignments
            )
            assert ra.recovery.fallback == rc.recovery.fallback
    sa, sc = h_a.summary(), h_c.summary()
    # cache counters differ (the resumed engine solved fewer rounds); every
    # campaign-outcome key must match exactly
    for key in (
        "rounds", "final_loss", "total_energy_J", "recovered_rounds",
        "recovery_fallbacks", "recovery_overhead_J", "recovery_shortfall",
    ):
        assert sa[key] == sc[key], key
