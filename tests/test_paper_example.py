"""Validates the paper's own worked example (Section 3.1, Figs. 1-2)."""

import numpy as np
import pytest

from repro.core import (
    Problem,
    brute_force_schedule,
    schedule,
    solve_schedule_dp,
    solve_schedule_dp_jax,
    total_cost,
)


def paper_problem(T: int) -> Problem:
    # R = {1,2,3}; U = {6,6,5}; L = {1,0,0}
    # C1 = {1:2, 2:3.5, 3:5.5, 4:8, 5:10, 6:12}
    # C2 = {0:0, 1:1.5, 2:2.5, 3:4, 4:7, 5:9, 6:11}
    # C3 = {0:0, 1:3, 2:4, 3:5, 4:6, 5:7}
    c1 = np.array([0.0, 2, 3.5, 5.5, 8, 10, 12])  # C1(0) unused (L1=1)
    c2 = np.array([0.0, 1.5, 2.5, 4, 7, 9, 11])
    c3 = np.array([0.0, 3, 4, 5, 6, 7])
    return Problem(T=T, lower=[1, 0, 0], upper=[6, 6, 5], cost_tables=(c1, c2, c3))


def test_example_T5():
    p = paper_problem(5)
    x = solve_schedule_dp(p)
    assert total_cost(p, x) == pytest.approx(7.5)
    assert list(x) == [2, 3, 0]  # Fig. 1


def test_example_T8():
    p = paper_problem(8)
    x = solve_schedule_dp(p)
    assert total_cost(p, x) == pytest.approx(11.5)
    assert list(x) == [1, 2, 5]  # Fig. 2


def test_example_matches_brute_force():
    for T in range(1, 17):
        p = paper_problem(T)
        bf = brute_force_schedule(p)
        dp = solve_schedule_dp(p)
        assert total_cost(p, dp) == pytest.approx(total_cost(p, bf))


def test_example_jax_dp_matches():
    for T in (5, 8, 12):
        p = paper_problem(T)
        x = solve_schedule_dp_jax(p)
        assert total_cost(p, x) == pytest.approx(total_cost(p, solve_schedule_dp(p)))


def test_greedy_insight():
    """Section 3.1: the T=8 optimum does not contain the T=5 optimum, so
    naive greedy extensions of smaller optima are suboptimal in general."""
    p5, p8 = paper_problem(5), paper_problem(8)
    x5, x8 = solve_schedule_dp(p5), solve_schedule_dp(p8)
    assert not np.all(x8 >= x5)


def test_auto_dispatch_on_example():
    p = paper_problem(8)
    x = schedule(p, "auto")
    assert total_cost(p, x) == pytest.approx(11.5)
