"""Bicriteria Pareto engine (PR 7, DESIGN.md §15).

Claims under test:
  * the one-dispatch frontier is the EXACT (time, energy) Pareto set — it
    matches full enumeration of every feasible schedule on small instances;
  * any weighted-sum optimum lies on the frontier (``solve_scalarized``),
    and ε-constraint lookups (``constrain`` / ``solve_constrained``) return
    the minimal-energy point meeting the bound;
  * monotone-regime instances ride the marginal fast path
    (``split_regimes=True``) and produce the same frontier as the fused DP;
  * one frontier — and even all windows of a :class:`CostWindows` sweep —
    costs exactly ONE engine dispatch;
  * ``SweepHandle.frontier`` exposes the free workload-Pareto curve of the
    final DP row;
  * the serve layer's ``submit_frontier`` returns the same frontier as the
    direct path, as one coalescable request.
"""

import itertools

import numpy as np
import pytest

from repro.core import (
    CostWindows,
    Problem,
    Solver,
    SweepEngine,
    pareto_frontier,
    random_problem,
    total_cost,
)
from repro.core.pareto import (
    candidate_deadlines,
    deadline_grid,
    feasible_deadline_range,
    frontier_by_window,
    pareto_indices,
    workload_frontier,
)
from repro.serve import SchedulerService


def small_instance(seed=3, n=4, T=10):
    """Instance tiny enough to enumerate every feasible schedule."""
    rng = np.random.default_rng(seed)
    p = random_problem(rng, n=n, T=T, regime="arbitrary", max_upper=6)
    tt = [np.sort(rng.uniform(0.1, 2.0, int(u) + 1)) for u in p.upper]
    for t in tt:
        t[0] = 0.0
    return p, tt


def enumerate_pareto(p, tt):
    """Ground truth by full enumeration: every feasible schedule's
    (makespan, energy), pruned to the Pareto set."""
    times, energies = [], []
    ranges = [range(int(lo), int(hi) + 1) for lo, hi in zip(p.lower, p.upper)]
    for x in itertools.product(*ranges):
        if sum(x) != p.T:
            continue
        times.append(max(float(tt[i][j]) for i, j in enumerate(x)))
        energies.append(float(total_cost(p, np.asarray(x))))
    times, energies = np.asarray(times), np.asarray(energies)
    idx = pareto_indices(times, energies)
    return times[idx], energies[idx]


def test_frontier_exact_vs_full_enumeration():
    for seed in (3, 17, 29):
        p, tt = small_instance(seed=seed)
        front = pareto_frontier(p, tt)
        bt, be = enumerate_pareto(p, tt)
        assert np.array_equal(front.times, bt)
        assert np.array_equal(front.energies, be)
        # every frontier schedule is feasible and achieves its recorded pair
        for pt in front:
            assert pt.schedule.sum() == p.T
            assert pt.time <= pt.deadline
            assert pt.energy == pytest.approx(total_cost(p, pt.schedule), abs=0)
        # sorted time-ascending, energy strictly decreasing (pruned)
        assert np.all(np.diff(front.times) > 0)
        assert np.all(np.diff(front.energies) < 0)


def test_weighted_sum_optima_lie_on_frontier():
    p, tt = small_instance(seed=5, n=5, T=12)
    solver = Solver()
    front = solver.frontier(p, tt)
    weights = [(w, 1.0 - w) for w in np.linspace(0.0, 1.0, 9)]
    pts = solver.solve_scalarized(p, tt, weights)
    pairs = {(q.time, q.energy) for q in front}
    for pt in pts:
        assert (pt.time, pt.energy) in pairs
    # the pure-preference corners resolve to the frontier endpoints
    assert front.scalarize(1.0, 0.0) is front.min_energy()
    assert front.scalarize(0.0, 1.0) is front.min_time()
    with pytest.raises(ValueError):
        front.scalarize(0.0, 0.0)


def test_epsilon_constraint_lookups():
    p, tt = small_instance(seed=18, n=5, T=12)
    solver = Solver()
    front = solver.frontier(p, tt)
    assert len(front) >= 4, "degenerate frontier — pick another seed"
    mid_t = 0.5 * (front.times[0] + front.times[-1])
    pt = front.constrain(T_max=mid_t)
    assert pt.time <= mid_t
    # minimal energy among the feasible points
    feas = front.energies[front.times <= mid_t]
    assert pt.energy == feas.min()
    # the symmetric bound: minimal time under an energy budget
    mid_e = 0.5 * (front.energies[0] + front.energies[-1])
    qt = front.constrain(E_max=mid_e)
    assert qt.energy <= mid_e
    assert qt.time == front.times[front.energies <= mid_e].min()
    # facade spelling returns the same points
    assert solver.solve_constrained(p, tt, T_max=mid_t).energy == pt.energy
    assert solver.solve_constrained(p, tt, E_max=mid_e).time == qt.time
    with pytest.raises(ValueError):
        front.constrain(T_max=front.times[0] * 0.5)  # tighter than min_time
    with pytest.raises(ValueError):
        front.constrain(E_max=front.energies[-1] * 0.5)
    with pytest.raises(ValueError):
        front.constrain()  # exactly one bound required
    with pytest.raises(ValueError):
        front.constrain(T_max=1.0, E_max=1.0)


def test_select_modes():
    p, tt = small_instance(seed=18, n=5, T=12)
    front = pareto_frontier(p, tt)
    assert front.select("min_time") is front.min_time()
    assert front.select("min_energy") is front.min_energy()
    assert front.select("knee") is front.knee()
    budget = float(front.times[-1])
    assert front.select(budget) is front.min_energy()  # loosest budget
    with pytest.raises(ValueError):
        front.select("fastest-ish")


def test_monotone_fast_path_matches_dp():
    rng = np.random.default_rng(41)
    for regime in ("increasing", "decreasing", "linear"):
        p = random_problem(rng, n=5, T=14, regime=regime, max_upper=8)
        tt = [np.sort(rng.uniform(0.1, 2.0, int(u) + 1)) for u in p.upper]
        for t in tt:
            t[0] = 0.0
        fast = pareto_frontier(p, tt, split_regimes=True)
        dp = pareto_frontier(p, tt, split_regimes=False)
        assert np.array_equal(fast.times, dp.times)
        # optimal ENERGIES agree (schedules may differ only between ties)
        np.testing.assert_allclose(fast.energies, dp.energies, rtol=0, atol=1e-9)


def test_frontier_is_one_dispatch():
    p, tt = small_instance(seed=13, n=5, T=12)
    eng = SweepEngine()
    before = eng.cache_stats()
    front = pareto_frontier(p, tt, engine=eng)
    after = eng.cache_stats()
    assert (after["hits"] + after["misses"]) - (before["hits"] + before["misses"]) == 1
    assert front.num_swept == len(candidate_deadlines(p, tt))

    # time-varying costs: ALL windows x ALL points still one dispatch
    windows = CostWindows.from_carbon_intensities(
        ("night", "midday", "evening"),
        np.asarray([[100.0] * p.n, [50.0] * p.n, [200.0] * p.n]),
    )
    before = eng.cache_stats()
    fronts = frontier_by_window(p, tt, windows, engine=eng)
    after = eng.cache_stats()
    assert (after["hits"] + after["misses"]) - (before["hits"] + before["misses"]) == 1
    assert set(fronts) == {"night", "midday", "evening"}
    for label, f in fronts.items():
        assert all(pt.label == label for pt in f)
    # uniform multipliers scale energies but cannot move the frontier's
    # time axis or its schedule structure
    assert np.array_equal(fronts["night"].times, fronts["evening"].times)
    np.testing.assert_allclose(
        fronts["evening"].energies, 2.0 * fronts["night"].energies, rtol=1e-12
    )


def test_cost_windows_validation_and_carbon_math():
    with pytest.raises(ValueError):
        CostWindows(labels=("a",), multipliers=np.asarray([[1.0, -0.5]]))
    with pytest.raises(ValueError):
        CostWindows(labels=("a", "b"), multipliers=np.asarray([[1.0, 1.0]]))
    w = CostWindows.from_carbon_intensities(("w",), np.asarray([[360.0, 720.0]]))
    # g/kWh * (mg/g) / (J/kWh) = mg per J
    np.testing.assert_allclose(w.multipliers[0], [0.1, 0.2])
    p, _ = small_instance(seed=3, n=2, T=4)
    (wp,) = w.apply(p)
    np.testing.assert_allclose(wp.cost_tables[0], 0.1 * p.cost_tables[0])
    np.testing.assert_allclose(wp.cost_tables[1], 0.2 * p.cost_tables[1])


def test_candidate_deadlines_and_grid():
    p, tt = small_instance(seed=21, n=5, T=12)
    cands = candidate_deadlines(p, tt)
    lo, hi = feasible_deadline_range(p, tt)
    assert lo == cands[0] and hi == cands[-1]
    assert np.all(np.diff(cands) > 0)
    # every candidate is an actual time-table value (a staircase breakpoint)
    table_vals = {float(v) for t in tt for v in t}
    assert all(float(d) in table_vals for d in cands)
    grid = deadline_grid(p, tt, points=4)
    assert len(grid) <= 4
    assert grid[0] == cands[0] and grid[-1] == cands[-1]
    assert set(grid).issubset(set(cands))
    # a grid frontier is a subset of the exact frontier
    exact = pareto_frontier(p, tt)
    sub = pareto_frontier(p, tt, grid)
    pairs = {(q.time, q.energy) for q in exact}
    assert all((pt.time, pt.energy) in pairs for pt in sub)


def test_sweep_handle_workload_frontier():
    p, _ = small_instance(seed=7, n=4, T=8)
    eng = SweepEngine()
    handle = eng.dispatch([p], split_regimes=False)
    idx, energies = handle.frontier(0)
    k_row = np.asarray(handle.k_last())[0]
    assert np.all(np.diff(idx) > 0)  # workload strictly ascending
    assert np.all(np.diff(energies) > 0)  # energy strictly increasing
    np.testing.assert_array_equal(energies, k_row[idx])
    ref_idx, ref_e = workload_frontier(k_row)
    np.testing.assert_array_equal(idx, ref_idx)
    np.testing.assert_array_equal(energies, ref_e)


def test_served_frontier_matches_direct():
    p, tt = small_instance(seed=31, n=5, T=12)
    eng = SweepEngine()
    direct = pareto_frontier(p, tt, engine=eng, split_regimes=False)
    with SchedulerService(engine=eng, max_batch=64, max_delay_s=0.005) as svc:
        fut = svc.submit_frontier(p, tt, split_regimes=False)
        served = fut.result(timeout=300)
        assert fut.done()
        assert served is fut.result()  # cached on the future
        # a Solver built on the service takes the same path
        via_solver = Solver(service=svc).frontier(p, tt, split_regimes=False)
    for f in (served, via_solver):
        assert np.array_equal(f.times, direct.times)
        assert np.array_equal(f.energies, direct.energies)
