"""Solver facade + deprecated shims (PR 7, DESIGN.md §15).

Claims under test:
  * all six legacy entrypoints — ``schedule``, ``schedule_batch``,
    ``schedule_with_deadline``, ``deadline_sweep``,
    ``solve_dp_batch_cached``, ``solve_schedule_batch_cached`` — return
    BIT-IDENTICAL results to the facade verbs that replace them;
  * each shim warns exactly ONCE per process (DeprecationWarning naming the
    replacement), regardless of call count;
  * :class:`Solution` / :class:`SolutionBatch` round-trip: indexing a batch
    yields per-instance views whose fields match, including through the
    serve layer (``Solver(service=...)``);
  * substrate conflicts (engine vs backend, engine vs service.engine) raise
    at construction.
"""

import warnings

import numpy as np
import pytest

from repro.core import (
    Problem,
    Solver,
    SweepEngine,
    deadline_sweep,
    random_problem,
    schedule,
    schedule_batch,
    schedule_with_deadline,
    solve_dp_batch_cached,
    solve_schedule_batch_cached,
    total_cost,
)
from repro.core._deprecation import reset_deprecation_warnings
from repro.core.scheduler import _schedule
from repro.serve import SchedulerService

REGIMES = ("arbitrary", "linear", "increasing", "decreasing")


@pytest.fixture(autouse=True)
def _quiet_shims():
    """Each test sees fresh warn-once state and never fails on the shims'
    own DeprecationWarnings."""
    reset_deprecation_warnings()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield
    reset_deprecation_warnings()


def mixed_problems(seed=0, B=6, n=5, T=14):
    rng = np.random.default_rng(seed)
    return [
        random_problem(rng, n=n, T=T, regime=REGIMES[b % len(REGIMES)], max_upper=8)
        for b in range(B)
    ]


def time_tables_for(p, seed=1):
    rng = np.random.default_rng(seed)
    tt = [np.sort(rng.uniform(0.1, 2.0, int(u) + 1)) for u in p.upper]
    for t in tt:
        t[0] = 0.0
    return tt


def test_schedule_shim_bit_identity():
    for p in mixed_problems():
        for alg in ("auto", "dp", "marin" if p.regime() == "MarIn" else "auto"):
            old = schedule(p, algorithm=alg)
            new = Solver().solve(p, algorithm=alg)
            assert np.array_equal(old, new.schedule)
            assert new.algorithm != "auto"  # resolved, never leaked
            assert new.objective == total_cost(p, old)
            assert new.regime == p.regime()


def test_schedule_batch_shim_bit_identity():
    probs = mixed_problems(seed=2)
    eng = SweepEngine()
    for alg in ("auto", "dp_batch"):
        old = schedule_batch(probs, algorithm=alg, engine=eng)
        new = Solver(engine=eng).solve(probs, algorithm=alg)
        assert len(old) == len(new) == len(probs)
        for xo, xn in zip(old, new.schedules):
            assert np.array_equal(xo, xn)
    # DP-name solves carry the free final-row telemetry
    assert Solver(engine=eng).solve(probs, algorithm="dp_batch").k_last is not None


def test_schedule_with_deadline_shim_bit_identity():
    p = mixed_problems(seed=4, B=1)[0]
    tt = time_tables_for(p)
    D = float(max(t[-1] for t in tt))  # loosest: always feasible
    old = schedule_with_deadline(p, tt, D)
    new = Solver().solve(p, deadline=D, time_tables=tt)
    assert np.array_equal(old, new.schedule)
    assert new.deadline == D
    with pytest.raises(ValueError):
        Solver().solve(p, deadline=D)  # time_tables go with deadline


def test_deadline_sweep_shim_bit_identity():
    p = mixed_problems(seed=5, B=1)[0]
    tt = time_tables_for(p, seed=6)
    hi = float(max(t[-1] for t in tt))
    deadlines = np.linspace(0.7 * hi, hi, 5)
    eng = SweepEngine()
    old = deadline_sweep(p, tt, deadlines, engine=eng)
    new = Solver(engine=eng).sweep(p, tt, deadlines)
    assert np.array_equal(old, np.stack(new.schedules))
    assert np.array_equal(new.deadlines, deadlines)
    assert new.k_last is not None and len(new.k_last) == len(deadlines)
    # both spellings name the offending point on infeasible grids
    with pytest.raises(ValueError, match="sweep point"):
        Solver(engine=eng).sweep(p, tt, [1e-9])
    with pytest.raises(ValueError, match="deadline_sweep point"):
        deadline_sweep(p, tt, [1e-9], engine=eng)


def test_cached_solve_shims_bit_identity():
    probs = mixed_problems(seed=7)
    eng = SweepEngine()
    old_dp = solve_dp_batch_cached(probs, engine=eng)
    new_dp = Solver(engine=eng).solve(probs, algorithm="dp_batch")
    for b, p in enumerate(probs):
        assert np.array_equal(old_dp[b, : p.n], new_dp.schedules[b])
    old_split = solve_schedule_batch_cached(probs, engine=eng)
    new_split = Solver(engine=eng).solve(probs)  # auto = regime-split path
    for b, p in enumerate(probs):
        assert np.array_equal(old_split[b, : p.n], new_split.schedules[b])


def test_shims_warn_exactly_once():
    p = mixed_problems(seed=8, B=1)[0]
    tt = time_tables_for(p, seed=8)
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        schedule(p)
        schedule(p)  # second call: silent
        deadline_sweep(p, tt, [float(max(t[-1] for t in tt))])
        deadline_sweep(p, tt, [float(max(t[-1] for t in tt))])
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 2  # one per distinct shim, not per call
    assert any("schedule is deprecated" in str(w.message) for w in dep)
    assert any("Solver" in str(w.message) for w in dep)
    # the facade itself never warns
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        Solver().solve(p)
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]


def test_solution_batch_roundtrip_and_serve():
    probs = mixed_problems(seed=9)
    eng = SweepEngine()
    direct = Solver(engine=eng).solve(probs)
    with SchedulerService(engine=eng, max_batch=64, max_delay_s=0.005) as svc:
        served = Solver(service=svc).solve(probs)
        with pytest.raises(ValueError, match="conflicts"):
            Solver(service=svc, engine=SweepEngine())
    assert np.array_equal(direct.objectives, served.objectives)
    for xd, xs in zip(direct.schedules, served.schedules):
        assert np.array_equal(xd, xs)
    assert served.algorithms == direct.algorithms
    # batch -> per-instance Solution views
    assert len(served) == len(probs)
    for b, sol in enumerate(served):
        assert np.array_equal(sol.schedule, served.schedules[b])
        assert sol.objective == float(served.objectives[b])
        assert sol.regime == probs[b].regime()
        assert sol.algorithm == served.algorithms[b]
    assert np.array_equal(served[-1].schedule, served.schedules[-1])
    assert served.cache_stats is not None and "hits" in served.cache_stats


def test_substrate_conflicts_raise():
    eng = SweepEngine(backend="ref")
    other = "blocked" if eng.backend == "ref" else "ref"
    with pytest.raises(ValueError, match="conflicts"):
        Solver(engine=eng, backend=other)
    assert Solver(engine=eng, backend="ref").engine is eng


def test_solution_objective_is_exact_float64():
    p = Problem(
        T=3,
        lower=[0, 0],
        upper=[3, 3],
        cost_tables=(
            np.array([0.0, 0.1, 0.2, 0.3]),
            np.array([0.0, 0.15, 0.25, 0.35]),
        ),
    )
    sol = Solver().solve(p)
    assert sol.objective == total_cost(p, sol.schedule)  # host f64, exact
