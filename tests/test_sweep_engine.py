"""Sweep engine (DESIGN.md §10): shape-bucketed compile cache + sharding.

Claims under test:
  * bucketed/padded cached solves are BIT-IDENTICAL to the uncached
    :func:`solve_schedule_dp_batch` (padding is inert);
  * a 3-round FL campaign with per-round scenario planning and drifting
    energy estimates performs exactly ONE DP compilation;
  * crossing a bucket boundary recompiles, staying inside one doesn't;
  * the LRU evicts and honestly re-counts compiles on re-entry;
  * sharding the batch axis over 8 forced host devices changes nothing
    about the schedules (subprocess, same pattern as test_distribution.py).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (
    Problem,
    ProblemBatch,
    SweepEngine,
    bucket_shape,
    deadline_sweep,
    random_problem,
    schedule_batch,
    solve_schedule_dp,
    solve_schedule_dp_batch,
    total_cost,
    validate_schedule,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
REGIMES = ("arbitrary", "linear", "increasing", "decreasing")


def random_mixed_problems(rng, B, max_n=6, max_T=24):
    out = []
    for b in range(B):
        n = int(rng.integers(1, max_n + 1))
        T = int(rng.integers(max(1, n), max_T + 1))
        out.append(random_problem(rng, n=n, T=T, regime=REGIMES[b % len(REGIMES)]))
    return out


def drift(problems, factor):
    """Same shapes, scaled costs — the round-over-round estimate drift that
    must stay inside one bucket."""
    return [
        Problem(
            T=p.T,
            lower=p.lower,
            upper=p.upper,
            cost_tables=tuple(t * factor for t in p.cost_tables),
        )
        for p in problems
    ]


# ---------------------------------------------------------------------------
# bucketing + padding
# ---------------------------------------------------------------------------


def test_bucket_shape_pow2():
    assert bucket_shape(1, 1, 1, 1) == (1, 1, 1, 1)
    assert bucket_shape(3, 5, 17, 33) == (4, 8, 32, 64)
    assert bucket_shape(8, 16, 32, 64) == (8, 16, 32, 64)  # pow2 is a fixpoint
    assert bucket_shape(9, 16, 32, 64) == (16, 16, 32, 64)


def test_problem_batch_pad_to_is_inert():
    rng = np.random.default_rng(0)
    probs = random_mixed_problems(rng, 5)
    batch = ProblemBatch.from_problems(probs)
    padded = batch.pad_to(B=8, n=8, W=batch.W + 5)
    padded.validate()
    assert (padded.B, padded.n, padded.W) == (8, 8, batch.W + 5)
    # real region is untouched, phantoms solve to all-zero rows
    np.testing.assert_array_equal(padded.costs[: batch.B, : batch.n, : batch.W], batch.costs)
    X = solve_schedule_dp_batch(padded)
    X_ref = solve_schedule_dp_batch(batch)
    np.testing.assert_array_equal(X[: batch.B, : batch.n], X_ref)
    assert np.all(X[batch.B :] == 0) and np.all(X[:, batch.n :] == 0)
    # no-op and shrink behaviour
    assert batch.pad_to() is batch
    with pytest.raises(ValueError):
        batch.pad_to(B=batch.B - 1)


# ---------------------------------------------------------------------------
# compile cache: exactness + counters
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_cached_solve_bit_identical_to_uncached(seed):
    rng = np.random.default_rng(200 + seed)
    probs = random_mixed_problems(rng, 9)
    eng = SweepEngine()
    X = eng.solve(probs)
    np.testing.assert_array_equal(X, solve_schedule_dp_batch(probs))
    assert eng.cache_stats()["compiles"] == 1
    # drifted costs, same shapes: cache hit, still exact
    probs2 = drift(probs, 1.07)
    X2 = eng.solve(probs2)
    np.testing.assert_array_equal(X2, solve_schedule_dp_batch(probs2))
    s = eng.cache_stats()
    per_bucket = s.pop("per_bucket_hits")
    assert s == {
        "hits": 1,
        "misses": 1,
        "compiles": 1,
        "evictions": 0,
        "entries": 1,
        "max_entries": eng.max_entries,
    }
    # the one hit is attributed to the one (dp) bucket, by label
    assert list(per_bucket.values()) == [1]
    (label,) = per_bucket
    assert label.startswith("dp:B") and all(ax in label for ax in (":n", ":T", ":W"))
    for p, x in zip(probs2, X2):
        validate_schedule(p, x[: p.n])
        assert total_cost(p, x[: p.n]) == pytest.approx(
            total_cost(p, solve_schedule_dp(p)), rel=1e-5
        )


def test_bucket_boundary_crossing_recompiles():
    rng = np.random.default_rng(3)
    base = random_problem(rng, n=4, T=20, regime="arbitrary", with_lower=False)

    def with_T(t):
        return Problem(T=t, lower=base.lower, upper=base.upper, cost_tables=base.cost_tables)

    eng = SweepEngine()
    eng.solve([with_T(12), with_T(16)])  # T'max = 16 -> bucket T = 16
    assert eng.cache_stats()["compiles"] == 1
    eng.solve([with_T(9), with_T(14)])  # still inside the T=16 bucket
    s = eng.cache_stats()
    assert s["hits"] == 1 and s["compiles"] == 1 and s["entries"] == 1
    eng.solve([with_T(12), with_T(17)])  # T'max = 17 -> bucket T = 32: recompile
    s = eng.cache_stats()
    assert s["compiles"] == 2 and s["misses"] == 2 and s["entries"] == 2


def test_lru_eviction_and_recompile():
    rng = np.random.default_rng(4)
    small = [random_problem(rng, n=2, T=4, regime="linear") for _ in range(2)]
    big = [random_problem(rng, n=6, T=20, regime="arbitrary") for _ in range(3)]
    eng = SweepEngine(max_entries=1)
    eng.solve(small)
    eng.solve(big)  # different bucket: evicts `small`'s executable
    s = eng.cache_stats()
    assert s["evictions"] == 1 and s["entries"] == 1
    X = eng.solve(small)  # re-enter the evicted bucket: honest recompile
    s = eng.cache_stats()
    assert s["compiles"] == 3 and s["hits"] == 0
    np.testing.assert_array_equal(X, solve_schedule_dp_batch(small))
    eng.clear()
    assert eng.cache_stats()["compiles"] == 0 and eng.cache_stats()["entries"] == 0


def test_lru_evicts_oldest_of_many_buckets():
    """More buckets than cache slots: the LEAST-recently-used executable is
    the one evicted (a hit refreshes recency), re-entering an evicted bucket
    recompiles to bit-identical results, and the counters say so."""
    rng = np.random.default_rng(6)
    bucket_a = [random_problem(rng, n=2, T=4, regime="linear") for _ in range(2)]
    bucket_b = [random_problem(rng, n=6, T=20, regime="arbitrary") for _ in range(2)]
    bucket_c = [random_problem(rng, n=3, T=40, regime="increasing") for _ in range(2)]

    eng = SweepEngine(max_entries=2)
    Xa = eng.solve(bucket_a)
    eng.solve(bucket_b)  # cache (LRU -> MRU): [a, b]
    eng.solve(bucket_a)  # hit refreshes a: [b, a]
    assert eng.cache_stats()["hits"] == 1
    eng.solve(bucket_c)  # 3rd bucket: evicts b (oldest), NOT the refreshed a
    s = eng.cache_stats()
    assert s["evictions"] == 1 and s["entries"] == 2 and s["compiles"] == 3

    X = eng.solve(bucket_a)  # a survived: still warm
    s = eng.cache_stats()
    assert s["compiles"] == 3 and s["hits"] == 2
    np.testing.assert_array_equal(X, Xa)
    np.testing.assert_array_equal(X, solve_schedule_dp_batch(bucket_a))

    eng.solve(bucket_b)  # b was evicted: honest recompile, exact again
    s = eng.cache_stats()
    assert s["compiles"] == 4 and s["evictions"] == 2, s
    np.testing.assert_array_equal(eng.solve(bucket_b), solve_schedule_dp_batch(bucket_b))
    # per-bucket hit attribution saw every warm re-solve
    assert sum(s["per_bucket_hits"].values()) == s["hits"]


def test_dispatch_thread_safe_under_concurrent_producers():
    """Many threads dispatch()ing and materializing against ONE engine —
    including several threads racing .result()/.k_last() on a SHARED handle
    — must neither crash nor corrupt results (DESIGN.md §14: the serve
    layer's completer + requesters all drain one engine)."""
    import threading

    rng = np.random.default_rng(7)
    batches = []
    for i in range(8):
        probs = random_mixed_problems(rng, int(rng.integers(1, 5)))
        batches.append((ProblemBatch.from_problems(probs), solve_schedule_dp_batch(probs)))

    eng = SweepEngine()
    eng.solve(batches[0][0])  # warm one bucket; others trace under contention
    errors = []
    barrier = threading.Barrier(6)

    def producer(tid):
        try:
            barrier.wait(timeout=60)
            for r in range(6):
                batch, X_ref = batches[(tid + r) % len(batches)]
                h = eng.dispatch(batch, split_regimes=bool((tid + r) % 2))
                X = h.result()
                assert np.array_equal(X[: batch.B, : batch.n], X_ref), (tid, r)
        except BaseException as e:  # surface into the main thread
            errors.append(e)

    shared_batch, shared_ref = batches[1]
    shared_handle = eng.dispatch(shared_batch)

    def drainer():
        try:
            barrier.wait(timeout=60)
            for _ in range(4):
                assert np.array_equal(
                    shared_handle.result()[: shared_batch.B, : shared_batch.n], shared_ref
                )
                assert shared_handle.k_last().shape[0] == shared_handle.result().shape[0]
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(t,)) for t in range(4)]
    threads += [threading.Thread(target=drainer) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=300)
        assert not t.is_alive(), "deadlocked thread"
    assert not errors, errors


def test_schedule_batch_and_deadline_sweep_share_an_engine():
    rng = np.random.default_rng(5)
    probs = [random_problem(rng, n=4, T=15, regime="arbitrary") for _ in range(4)]
    eng = SweepEngine()
    xs = schedule_batch(probs, "dp_batch", engine=eng)
    assert eng.cache_stats()["misses"] == 1
    xs2 = schedule_batch(drift(probs, 1.02), "dp_batch", engine=eng)
    s = eng.cache_stats()
    assert s["hits"] == 1 and s["compiles"] == 1
    for p, x, x2 in zip(probs, xs, xs2):
        validate_schedule(p, x)
        validate_schedule(p, x2)

    # an explicit engine + a contradicting backend must raise, not silently
    # run the engine's kernel (dp_jax_pallas promises the Pallas backend)
    with pytest.raises(ValueError, match="conflicts with engine.backend"):
        schedule_batch(probs, "dp_jax_pallas", engine=eng)

    p = random_problem(rng, n=5, T=30, regime="increasing")
    speeds = rng.uniform(0.5, 3.0, size=5)
    times = [np.arange(int(u) + 1) / s for u, s in zip(p.upper, speeds)]
    x_free = solve_schedule_dp(p)
    d_max = max(float(times[i][int(x_free[i])]) for i in range(5))
    deadlines = [d_max * f for f in (1.0, 1.5, 2.5, 10.0)]
    eng2 = SweepEngine()
    X1 = deadline_sweep(p, times, deadlines, engine=eng2)
    X2 = deadline_sweep(p, times, deadlines, engine=eng2)  # warm re-sweep
    np.testing.assert_array_equal(X1, X2)
    s = eng2.cache_stats()
    assert s["compiles"] == 1 and s["hits"] == 1


# ---------------------------------------------------------------------------
# FL: a 3-round campaign with scenario planning compiles the DP exactly once
# ---------------------------------------------------------------------------


def test_three_round_campaign_compiles_dp_exactly_once():
    import jax
    import jax.numpy as jnp

    from repro.data import client_corpora, make_lm_examples
    from repro.fl import EnergyEstimator, FederatedServer, make_fleet, run_campaign
    from repro.optim import sgd

    VOCAB, SEQ = 64, 8
    rng = np.random.default_rng(0)
    fleet = make_fleet(rng, 5, max_batches=8)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, 5, 400, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]

    def loss_fn(params, batch):
        x, y = batch[:, :-1], batch[:, 1:]
        h = jnp.tanh(params["emb"][x])
        logp = jax.nn.log_softmax(h @ params["out"])
        return -jnp.take_along_axis(logp, y[..., None], axis=-1).mean()

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {
        "emb": jax.random.normal(k1, (VOCAB, 16)) * 0.1,
        "out": jax.random.normal(k2, (16, VOCAB)) * 0.1,
    }
    engine = SweepEngine()
    cap = sum(d.max_batches for d in fleet)
    server = FederatedServer(
        loss_fn,
        params,
        sgd(0.3),
        est,
        round_T=cap // 2,
        scenario_T_candidates=[cap // 3, cap // 2 + 2],
        scenario_dropouts=[(0,), (1, 2)],
        engine=engine,
    )
    hist = run_campaign(server, examples, num_rounds=3, round_T=cap // 2, batch_size=4, rng=rng)

    assert len(hist.rounds) == 3
    # energy estimates DRIFT between rounds (observe() feedback), but shapes
    # repeat -> one bucket, one compilation, rounds 2-3 fully warm
    stats = engine.cache_stats()
    assert stats["compiles"] == 1, stats
    assert stats["misses"] == 1 and stats["hits"] == 2, stats
    assert hist.dp_cache_stats["compiles"] == 1
    assert hist.summary()["dp_compiles"] == 1
    for r in hist.rounds:
        assert r.scenarios is not None
        assert r.scenarios.assignments.shape == (4, 5)


# ---------------------------------------------------------------------------
# sharding: 8 host devices, bit-identical to single-device (subprocess —
# XLA_FLAGS binds at first jax init, so the main test process can't force it)
# ---------------------------------------------------------------------------


def test_sharded_solve_matches_single_device_bit_identical():
    src = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
        )
        import sys
        sys.path.insert(0, %r)
        import numpy as np
        import jax
        from repro.core import (Problem, SweepEngine, make_sweep_mesh,
                                random_problem, solve_schedule_dp_batch)

        assert len(jax.devices()) == 8, jax.devices()
        rng = np.random.default_rng(5)
        regimes = ("arbitrary", "linear", "increasing", "decreasing")
        probs = [
            random_problem(rng, n=int(rng.integers(2, 6)), T=int(rng.integers(6, 20)),
                           regime=regimes[b %% len(regimes)])
            for b in range(5)  # B=5 -> pow2 bucket 8 == one row per device
        ]
        mesh = make_sweep_mesh()
        assert mesh.devices.size == 8
        eng_sh = SweepEngine(mesh=mesh)
        X_sh = eng_sh.solve(probs)
        X_1 = SweepEngine().solve(probs)
        X_un = solve_schedule_dp_batch(probs)
        assert np.array_equal(X_sh, X_1), "sharded != single-device"
        assert np.array_equal(X_sh, X_un), "sharded != uncached"

        # drifted re-solve stays warm AND sharded-exact
        probs2 = [Problem(T=p.T, lower=p.lower, upper=p.upper,
                          cost_tables=tuple(t * 1.03 for t in p.cost_tables))
                  for p in probs]
        X2 = eng_sh.solve(probs2)
        assert np.array_equal(X2, solve_schedule_dp_batch(probs2))
        s = eng_sh.cache_stats()
        assert s["compiles"] == 1 and s["hits"] == 1, s

        # B=3 exercises rounding the bucket up to a device-count multiple
        X3 = eng_sh.solve(probs[:3])
        assert np.array_equal(X3, solve_schedule_dp_batch(probs[:3]))
        print("SHARDED_OK")
        """
        % os.path.join(REPO, "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", src], capture_output=True, text=True, timeout=420
    )
    assert proc.returncode == 0, (
        f"subprocess failed:\nSTDOUT:{proc.stdout}\nSTDERR:{proc.stderr[-3000:]}"
    )
    assert "SHARDED_OK" in proc.stdout
