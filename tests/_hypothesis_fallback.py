"""Minimal stand-in for the slice of the ``hypothesis`` API our tests use,
so tier-1 collects and runs on a clean container without pip installs.

Implements ``given`` / ``settings`` / ``strategies.{integers, sampled_from,
composite}`` with deterministic seeded sampling (seed derived from the test
name). No shrinking, no example database — install the real ``hypothesis``
(see requirements-dev.txt) to get those; this module steps aside
automatically when it is importable (see the guarded import in
``test_core_scheduling.py``).
"""

from __future__ import annotations

import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "st"]

_DEFAULT_MAX_EXAMPLES = 50


class _Strategy:
    """A sampler: ``example(rng)`` draws one value."""

    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: np.random.Generator):
        return self._sample(rng)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def _composite(fn):
    """``@st.composite``: ``fn(draw, *args, **kwargs)`` becomes a strategy
    factory; ``draw`` pulls values from sub-strategies."""

    def builder(*args, **kwargs):
        def sample(rng):
            return fn(lambda strat: strat.example(rng), *args, **kwargs)

        return _Strategy(sample)

    return builder


strategies = types.SimpleNamespace(
    integers=_integers,
    sampled_from=_sampled_from,
    composite=_composite,
)
st = strategies


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator recording ``max_examples`` on the (already-``given``-wrapped)
    test; ``deadline`` and anything else is accepted and ignored."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies_args):
    """Runs the test body once per drawn example, deterministically: the rng
    seed is derived from the test function's name, so failures reproduce."""

    def deco(fn):
        # NOT functools.wraps: pytest must not see the original signature,
        # or it would try to resolve the drawn parameters as fixtures.
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                fn(*(s.example(rng) for s in strategies_args))

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
