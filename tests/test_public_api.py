"""Freeze the public surface (PR 8).

``repro.__all__`` is the supported API; anything else is internal machinery
or a deprecated shim. These tests fail if a new top-level entrypoint appears
anywhere but the ``repro`` facade, or if importing the library emits a
DeprecationWarning — both must be deliberate, reviewed changes.
"""

from __future__ import annotations

import os
import subprocess
import sys

import pytest

import repro
import repro.core
import repro.fl
import repro.serve

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# The supported surface. Adding a name here is an API commitment; removing
# one is a breaking change. Keep sorted.
FACADE = [
    "CircuitBreaker",
    "DriftInjector",
    "DriftPlan",
    "FaultInjector",
    "FaultPlan",
    "FleetSolution",
    "ParetoFrontier",
    "PlanPolicy",
    "Problem",
    "ProblemBatch",
    "RetryPolicy",
    "SchedulerService",
    "Solution",
    "SolutionBatch",
    "Solver",
    "TransientEngineError",
]

# Subpackage surfaces, frozen so a new entrypoint added there without a
# matching facade decision trips this test.
CORE_ALL = {
    "ALGORITHMS", "CircuitBreaker", "CostWindows", "DEVICE_CLASSES",
    "FleetSolution", "ItemClass", "JOULES_PER_KWH", "MC2MKPSolution",
    "ParetoFrontier", "ParetoPoint", "PlanPolicy", "Problem", "ProblemBatch",
    "RetryPolicy", "Solution", "SolutionBatch", "Solver", "SweepEngine",
    "TransientEngineError", "is_transient", "retry_call",
    "brute_force_schedule",
    "bucket_shape", "candidate_deadlines", "carbon_cost_table",
    "classify_regimes", "cluster_clients", "deadline_grid", "deadline_sweep",
    "default_engine", "device_fleet_problem", "feasible_deadline_range",
    "frontier_by_window", "greedy_marginal", "linear_cost", "make_sweep_mesh",
    "marco", "marco_batch", "mardec", "mardec_batch", "mardecun",
    "mardecun_batch", "marin", "marin_batch", "mc2mkp_matrices",
    "measured_cost", "olar", "pareto_frontier", "proportional",
    "random_problem", "random_schedule", "remove_lower_limits",
    "restore_lower_limits", "schedule", "schedule_batch",
    "schedule_with_deadline", "select_algorithm", "select_algorithm_batch",
    "solve_dp_batch_cached", "solve_fleet", "solve_fused_batch_jax",
    "solve_fused_batch_ring", "solve_mc2mkp", "solve_schedule_batch_cached",
    "solve_schedule_dp", "solve_schedule_dp_batch", "solve_schedule_dp_jax",
    "sublinear_cost", "superlinear_cost", "tighten_for_deadline",
    "total_cost", "total_cost_batch", "uniform", "validate_schedule",
    "validate_schedule_batch",
}

FL_ALL = {
    "AdaptiveCoordinator", "AdaptiveRoundStats", "AsyncCampaignRunner",
    "CampaignHistory", "CampaignRunner", "ClientFault",
    "DeviceProfile", "DriftDetector", "DriftInjector", "DriftPlan",
    "EnergyEstimator", "FLRoundResult", "FaultInjector",
    "FaultPlan", "FederatedServer", "FlakyEngine", "PipelineStats",
    "PlanFuture", "PlanPolicy", "RecoveryInfo", "RoundFaults", "RoundPlan",
    "ScenarioReport", "SerialPlanExecutor", "ThreadPlanExecutor",
    "WatermarkStats", "apply_dropout", "load_campaign_checkpoint",
    "local_train", "make_client_fn", "make_fleet", "proportional_greedy",
    "residual_problem", "run_campaign", "save_campaign_checkpoint",
    "watermark_split",
}

SERVE_ALL = {
    "FleetFuture", "FrontierFuture", "ScheduleFuture", "SchedulerService",
    "ServiceClosed", "ServiceOverloaded", "coalesce_key", "combine_batches",
    "pow2_ladder", "warm_batch",
}


def test_facade_all_is_frozen():
    assert list(repro.__all__) == FACADE
    assert sorted(repro.__all__) == list(repro.__all__), "keep __all__ sorted"


@pytest.mark.parametrize("name", FACADE)
def test_facade_names_resolve(name):
    obj = getattr(repro, name)
    assert obj is not None
    # every facade name must originate inside the package
    mod = getattr(obj, "__module__", "repro")
    assert mod.startswith("repro")


def test_subpackage_surfaces_are_frozen():
    assert set(repro.core.__all__) == CORE_ALL, (
        "repro.core.__all__ changed — new entrypoints must be a deliberate "
        "facade decision (update tests/test_public_api.py AND repro/__init__.py)"
    )
    assert set(repro.fl.__all__) == FL_ALL
    assert set(repro.serve.__all__) == SERVE_ALL


def test_facade_is_subset_of_subpackages():
    exported = CORE_ALL | FL_ALL | SERVE_ALL
    assert set(FACADE) <= exported


def test_import_emits_no_deprecation_warning():
    # Subprocess: -W error turns any DeprecationWarning raised at import
    # time (ours or a dependency's triggered by our imports) into a failure.
    proc = subprocess.run(
        [
            sys.executable,
            "-W", "error::DeprecationWarning",
            "-c", "import repro, repro.core, repro.fl, repro.serve",
        ],
        capture_output=True,
        text=True,
        timeout=240,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert proc.returncode == 0, (
        f"importing repro raised under -W error::DeprecationWarning:\n"
        f"{proc.stderr[-3000:]}"
    )
