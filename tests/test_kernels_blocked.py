"""Blocked min-plus backend + fused DP/backtrack + dispatch (DESIGN.md §12).

Claims under test:
  * the blocked backend is BIT-IDENTICAL to the dense oracle — values AND
    first-min argmins — over ragged (B, T, W) and odd/pathological block
    sizes, including BIG saturation and all-BIG rows (property-based, with
    the hypothesis fallback);
  * the Pallas-GPU blocked kernel (interpret mode) matches the oracle too;
  * the fused single-dispatch solver returns exactly what the legacy
    two-dispatch chain returns, plus a correct K_last row;
  * ``SweepEngine`` on the fused path still compiles once per bucket, and
    its handles expose per-instance objectives for free;
  * the per-hardware dispatch table resolves "auto" to the blocked backend
    on this CPU container;
  * vectorized ``pack_problem`` packs exactly like the old per-class loop.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean container: deterministic fallback sampler
    from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import (
    Problem,
    SweepEngine,
    random_problem,
    solve_schedule_dp,
    solve_schedule_dp_batch,
    total_cost,
)
from repro.core.jax_dp import (
    backtrack_batch_jax,
    dp_tables_batch_jax,
    pack_problem,
    solve_fused_batch_jax,
)
from repro.core.problem import ProblemBatch, remove_lower_limits
from repro.kernels import (
    BIG,
    DISPATCH_TABLE,
    auto_block_sizes,
    minplus_blocked_batch,
    minplus_pallas_gpu_batch,
    minplus_step_batch,
    minplus_step_ref_batch,
    resolve_backend,
    tpu_tuned_bt,
)


def random_band_inputs(rng, B, Tp, W, frac_inf=0.3):
    """A DP row + cost stack with BIG sprinkled in both (band edges, padded
    tails, and saturation are all exercised)."""
    kprev = rng.uniform(0, 100, (B, Tp)).astype(np.float32)
    kprev[rng.random((B, Tp)) < frac_inf] = float(BIG)
    kprev[:, 0] = 0.0
    cost = rng.uniform(0, 10, (B, W)).astype(np.float32)
    cost[rng.random((B, W)) < 0.2] = float(BIG)
    return kprev, cost


def assert_bit_identical(got, want):
    gv, gi = got
    wv, wi = want
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


# ---------------------------------------------------------------------------
# property-based parity: blocked vs dense oracle
# ---------------------------------------------------------------------------


@st.composite
def band_shapes(draw):
    B = draw(st.integers(1, 4))
    Tp = draw(st.integers(1, 400))
    W = draw(st.integers(1, 300))
    # odd, tiny, and oversized block edges all legal. BW is the chunk
    # unroll factor, i.e. compile time — the fast tier keeps it <= 64 and
    # the slow-marked sweep below covers the wide chunks.
    BT = draw(st.sampled_from([1, 3, 7, 33, 64, 100, 256, 1024]))
    BW = draw(st.sampled_from([1, 2, 5, 17, 64]))
    seed = draw(st.integers(0, 2**31 - 1))
    return B, Tp, W, BT, BW, seed


@settings(max_examples=8, deadline=None)
@given(band_shapes())
def test_blocked_matches_dense_property(shape):
    B, Tp, W, BT, BW, seed = shape
    rng = np.random.default_rng(seed)
    kprev, cost = random_band_inputs(rng, B, Tp, W)
    assert_bit_identical(
        minplus_blocked_batch(kprev, cost, BT=BT, BW=BW),
        minplus_step_ref_batch(kprev, cost),
    )


@pytest.mark.slow
@pytest.mark.parametrize("BT,BW", [(256, 128), (100, 512), (1024, 512)])
def test_blocked_matches_dense_wide_chunks(BT, BW):
    rng = np.random.default_rng(BT + BW)
    kprev, cost = random_band_inputs(rng, 3, 700, 600)
    assert_bit_identical(
        minplus_blocked_batch(kprev, cost, BT=BT, BW=BW),
        minplus_step_ref_batch(kprev, cost),
    )


def test_blocked_auto_block_sizes_parity_and_sanity():
    rng = np.random.default_rng(7)
    for B, Tp, W in [(1, 1, 1), (2, 513, 77)]:
        kprev, cost = random_band_inputs(rng, B, Tp, W)
        assert_bit_identical(
            minplus_blocked_batch(kprev, cost),  # BT/BW from the heuristic
            minplus_step_ref_batch(kprev, cost),
        )
        BT, BW = auto_block_sizes(B, Tp, W)
        assert BT >= 1 and BW >= 1
        assert BT & (BT - 1) == 0 and BW & (BW - 1) == 0  # pow2-aligned tiles
    # heuristic is deterministic and lands on the tuned config at the
    # memory-bound benchmark shape
    assert auto_block_sizes(8, 8193, 512) == auto_block_sizes(8, 8193, 512) == (512, 128)


def test_blocked_all_big_saturation_and_argmin_convention():
    # an all-infeasible row stays BIG everywhere and keeps argmin = 0 (the
    # oracle's argmin-of-constant convention) — padding inertness depends
    # on this
    B, Tp, W = 2, 37, 11
    kprev = np.full((B, Tp), float(BIG), dtype=np.float32)
    cost = np.full((B, W), float(BIG), dtype=np.float32)
    bv, bi = minplus_blocked_batch(kprev, cost, BT=8, BW=3)
    assert np.all(np.asarray(bv) == float(BIG))
    assert np.all(np.asarray(bi) == 0)
    assert_bit_identical((bv, bi), minplus_step_ref_batch(kprev, cost))


@pytest.mark.parametrize("Tp,W,BT,BW", [(64, 16, 32, 8), (255, 130, 256, 64)])
def test_pallas_gpu_matches_dense_interpret(Tp, W, BT, BW):
    rng = np.random.default_rng(Tp + W)
    kprev, cost = random_band_inputs(rng, 2, Tp, W)
    assert_bit_identical(
        minplus_pallas_gpu_batch(kprev, cost, BT=BT, BW=BW, interpret=True),
        minplus_step_ref_batch(kprev, cost),
    )


# ---------------------------------------------------------------------------
# dispatch table
# ---------------------------------------------------------------------------


@pytest.mark.skipif(
    jax.default_backend() != "cpu", reason="asserts the CPU row of the dispatch table"
)
def test_dispatch_table_resolves_auto_per_hardware():
    assert DISPATCH_TABLE == {"cpu": "blocked", "tpu": "pallas_tpu", "gpu": "pallas_gpu"}
    assert resolve_backend("auto") == "blocked"
    assert resolve_backend(None) == "blocked"
    assert resolve_backend("ref") == "ref"  # explicit names pass through
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("triton")
    # the auto path really runs the blocked kernel: identical to calling it
    rng = np.random.default_rng(0)
    kprev, cost = random_band_inputs(rng, 2, 200, 40)
    assert_bit_identical(
        minplus_step_batch(kprev, cost, backend="auto"),
        minplus_blocked_batch(kprev, cost),
    )


def test_tpu_tuned_bt_respects_vmem_budget():
    # the tile never overshoots the (tile-rounded) row; long-but-affordable
    # rows get the largest tile; rows too long for VMEM residency fall
    # back to 1024
    assert tpu_tuned_bt(4096, 512) == 4096
    assert tpu_tuned_bt(100, 512) == 1024
    assert tpu_tuned_bt(60_000, 512) == 8192
    assert tpu_tuned_bt(4_000_000, 1024) == 1024
    for Tp, W in [(1000, 100), (100_000, 2048), (1_000_000, 512)]:
        bt = tpu_tuned_bt(Tp, W)
        assert bt % 1024 == 0  # (8, 128) f32 tile granularity
        tpad = -(-Tp // bt) * bt
        assert 4 * (W + tpad) + 4 * W + 16 * bt <= 0.75 * 16 * 2**20 or bt == 1024


# ---------------------------------------------------------------------------
# fused DP + backtrack
# ---------------------------------------------------------------------------


def _random_sweep(rng, B, n_max=6, T_max=40):
    regimes = ("arbitrary", "linear", "increasing", "decreasing")
    return [
        random_problem(
            rng,
            n=int(rng.integers(1, n_max + 1)),
            T=int(rng.integers(1, T_max + 1)),
            regime=regimes[b % len(regimes)],
        )
        for b in range(B)
    ]


def test_fused_solver_matches_twodispatch_and_numpy_dp():
    rng = np.random.default_rng(11)
    probs = _random_sweep(rng, 7)
    b0 = remove_lower_limits(ProblemBatch.from_problems(probs))
    costs = pack_problem(b0)
    Tmax = int(b0.T.max())
    t_star = jnp.asarray(b0.T, dtype=jnp.int32)
    for backend in ("blocked", "ref"):
        X, k_last = solve_fused_batch_jax(costs, t_star, Tmax, backend=backend)
        k2, I = dp_tables_batch_jax(costs, Tmax, backend=backend)
        X2 = backtrack_batch_jax(I, t_star, Tmax)
        np.testing.assert_array_equal(np.asarray(X), np.asarray(X2))
        np.testing.assert_array_equal(np.asarray(k_last), np.asarray(k2))
        assert X.shape == (b0.B, b0.n) and k_last.shape == (b0.B, Tmax + 1)
    # K_last at t* IS the optimal reduced-instance objective (== numpy DP)
    X, k_last = solve_fused_batch_jax(costs, t_star, Tmax, backend="blocked")
    for b, p in enumerate(probs):
        x_np = solve_schedule_dp(p)
        k_at = float(np.asarray(k_last)[b, int(b0.T[b])])
        offset = sum(p.cost(i, int(lo)) for i, lo in enumerate(p.lower))
        assert k_at + offset == pytest.approx(total_cost(p, x_np), rel=1e-5, abs=1e-4)


def test_batched_solver_blocked_bit_identical_to_ref_end_to_end():
    rng = np.random.default_rng(23)
    probs = _random_sweep(rng, 9)
    np.testing.assert_array_equal(
        solve_schedule_dp_batch(probs, backend="blocked"),
        solve_schedule_dp_batch(probs, backend="ref"),
    )
    # and "auto" matches its resolved concrete backend ("blocked" on CPU)
    np.testing.assert_array_equal(
        solve_schedule_dp_batch(probs, backend="auto"),
        solve_schedule_dp_batch(probs, backend=resolve_backend("auto")),
    )


# ---------------------------------------------------------------------------
# sweep engine on the fused path
# ---------------------------------------------------------------------------


def test_sweep_engine_fused_path_compiles_once_per_bucket():
    rng = np.random.default_rng(31)
    probs = _random_sweep(rng, 5)
    eng = SweepEngine()  # backend="auto" resolves per hardware at init
    assert eng.backend == resolve_backend("auto")
    X = eng.solve(probs)
    np.testing.assert_array_equal(X, solve_schedule_dp_batch(probs))
    # drifted costs, same shapes: 2 more solves, still ONE compilation
    for f in (1.05, 0.93):
        drifted = [
            Problem(
                T=p.T,
                lower=p.lower,
                upper=p.upper,
                cost_tables=tuple(t * f for t in p.cost_tables),
            )
            for p in probs
        ]
        np.testing.assert_array_equal(
            eng.solve(drifted), solve_schedule_dp_batch(drifted)
        )
    s = eng.cache_stats()
    assert s["compiles"] == 1 and s["misses"] == 1 and s["hits"] == 2, s


def test_sweep_handle_exposes_k_last_and_objectives():
    rng = np.random.default_rng(41)
    probs = _random_sweep(rng, 4)
    eng = SweepEngine()
    handle = eng.dispatch(probs)
    X = handle.result()
    obj = handle.objectives()
    assert obj.shape == (len(probs),)
    k_last = handle.k_last()
    assert k_last.shape[0] == len(probs)
    for b, p in enumerate(probs):
        # objective is the REDUCED instance's cost: original minus the
        # fixed lower-limit spend (Section 5.2 rebases C'(0) = 0)
        offset = sum(p.cost(i, int(lo)) for i, lo in enumerate(p.lower))
        assert float(obj[b]) + offset == pytest.approx(
            total_cost(p, X[b, : p.n]), rel=1e-5, abs=1e-4
        )
        # k_last row is consistent with the objective at t*
        t_star = int(p.T - p.lower.sum())
        assert float(k_last[b, t_star]) == float(obj[b])


# ---------------------------------------------------------------------------
# pack_problem vectorization
# ---------------------------------------------------------------------------


def test_pack_problem_masked_scatter_matches_loop():
    rng = np.random.default_rng(53)
    for _ in range(5):
        p = random_problem(
            rng, n=int(rng.integers(1, 7)), T=int(rng.integers(2, 30)), regime="arbitrary"
        )
        p0 = remove_lower_limits(p)
        got = np.asarray(pack_problem(p0))
        W = int(p0.upper.max()) + 1
        want = np.full((p0.n, W), float(BIG), dtype=np.float32)
        for i in range(p0.n):  # the old per-class loop, as the oracle
            u = int(p0.upper[i])
            want[i, : u + 1] = p0.cost_tables[i][: u + 1]
        np.testing.assert_array_equal(got, want)
