"""Generates the §Dry-run and §Roofline markdown tables for EXPERIMENTS.md
from artifacts/dryrun/*.json."""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ARCH_ORDER = [
    "xlstm-1.3b", "zamba2-2.7b", "granite-20b", "paligemma-3b", "olmoe-1b-7b",
    "hubert-xlarge", "deepseek-v3-671b", "deepseek-7b", "gemma2-2b", "minitron-8b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# analytic MODEL_FLOPS (6ND train / 2ND inference) per device — see
# repro.models.model.model_flops_per_token
from repro.configs import INPUT_SHAPES, get_config  # noqa: E402
from repro.launch.steps import abstract_params  # noqa: E402
from repro.models.model import active_param_count, model_flops_per_token  # noqa: E402


def gb(x):
    return "-" if x is None else f"{x / 2**30:.2f}"


def model_flops_per_device(arch, shape_name, n_chips):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    params = abstract_params(cfg)
    per_tok = model_flops_per_token(params, cfg, shape.seq_len,
                                    "train" if shape.mode == "train" else "inference")
    if shape.mode == "decode":
        tokens = shape.global_batch  # ONE new token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
    return per_tok * tokens / n_chips


def main():
    arts = {}
    for f in glob.glob("artifacts/dryrun/*.json"):
        d = json.load(open(f))
        arts[(d["arch"], d["shape"], d["mesh"])] = d

    print("### §Dry-run — lower+compile status, memory analysis (per device)\n")
    print("| arch | shape | mesh | status | compile_s | args GB | temp GB | aliased GB |")
    print("|---|---|---|---|---|---|---|---|")
    for mesh in ("16x16", "2x16x16"):
        for arch in ARCH_ORDER:
            for shape in SHAPE_ORDER:
                d = arts.get((arch, shape, mesh))
                if d is None:
                    print(f"| {arch} | {shape} | {mesh} | MISSING | | | | |")
                    continue
                if d["status"] != "ok":
                    reason = d.get("reason", d.get("error", ""))[:60]
                    print(f"| {arch} | {shape} | {mesh} | {d['status']}: {reason} | | | | |")
                    continue
                m = d["memory"]
                alias = None
                print(
                    f"| {arch} | {shape} | {mesh} | ok | {d['compile_s']:.0f} "
                    f"| {gb(m['argument_bytes'])} | {gb(m['temp_bytes'])} | "
                    f"{gb(m.get('peak_bytes'))} |"
                )

    print("\n### §Roofline — per-device terms (16x16 pod mesh), loop-aware HLO analysis\n")
    print("| arch | shape | t_compute s | t_memory s | t_coll s | dominant | MODEL_FLOPs/HLO_FLOPs | top collective |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = arts.get((arch, shape, "16x16"))
            if d is None or d["status"] != "ok":
                continue
            r = d["roofline"]
            mf = model_flops_per_device(arch, shape, d["n_chips"])
            ratio = mf / max(r["hlo_flops_per_device"], 1.0)
            by_type = r.get("collective_bytes_by_type", {})
            top = max(by_type.items(), key=lambda kv: kv[1])[0] if any(by_type.values()) else "-"
            print(
                f"| {arch} | {shape} | {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
                f"| {r['t_collective_s']:.3e} | {r['dominant']} | {ratio:.2f} | {top} |"
            )


if __name__ == "__main__":
    main()
