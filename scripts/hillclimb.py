"""§Perf hillclimbing driver: lowers a (arch, shape) combo under a named
sharding/config VARIANT and records the roofline terms.

    python scripts/hillclimb.py --arch deepseek-7b --shape train_4k --variant fsdp_only

Variants encode the hypothesis being tested (see EXPERIMENTS.md §Perf).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# variant -> (cfg_overrides, rules_overrides)
VARIANTS = {
    # paper-faithful baseline: uniform 2-D fsdp+tp sharding
    "baseline": ({}, {}),
    # pure FSDP over all 256 chips: batch & weight shards over ('data','model'),
    # no tensor parallelism, no sequence-parallel gathers
    "fsdp_only": (
        {},
        {"batch": ("data", "model"), "fsdp": ("data", "model"), "tensor": None, "act_seq": None},
    ),
    # keep TP but drop sequence-parallel residuals (trades memory for gathers)
    "no_actseq": ({}, {"act_seq": None}),
    # TP=4 hybrid: fsdp gets 4x more devices via a reshaped logical mapping is
    # not expressible on the fixed mesh; approximate with fsdp over both axes
    # but tensor kept for the FFN only via act_seq off
    "fsdp_tp_noseq": ({}, {"batch": ("data",), "act_seq": None}),
    # remat policy: save dots (more memory, less recompute)
    "remat_dots": ({"remat": "dots"}, {}),
    # bigger attention query blocks (fewer scan trips, bigger tiles)
    "blockq_1024": ({"attn_block_q": 1024}, {}),
    # MoE: einsum dispatch instead of a2a (hypothesis: a2a wins at train scale)
    "moe_einsum": ({"moe_impl": "einsum"}, {}),
    # MoE: lower capacity factor (less padding waste)
    "cap_1_0": ({"capacity_factor": 1.0}, {}),
    # expert-parallel over 'model' only (ds-v3: 16 experts/device instead of 1)
    "ep_model": ({}, {"expert": ("model",)}),
    # fsdp_only + tight MoE capacity (less dispatch-buffer padding traffic)
    "fsdp_cap10": (
        {"capacity_factor": 1.0},
        {"batch": ("data", "model"), "fsdp": ("data", "model"), "tensor": None, "act_seq": None},
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    if args.save_hlo:
        os.environ["DRYRUN_HLO_DIR"] = "artifacts/perf_hlo"

    from repro.launch.dryrun import lower_one

    cfg_o, rules_o = VARIANTS[args.variant]
    result = lower_one(
        args.arch, args.shape, args.mesh == "multipod",
        cfg_overrides=cfg_o, rules_overrides=rules_o,
    )
    result["variant"] = args.variant
    out = f"artifacts/perf/{args.arch}.{args.shape}.{args.variant}.json"
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(result, f, indent=1)
    r = result.get("roofline", {})
    print(
        f"\n{args.arch} {args.shape} [{args.variant}]: "
        f"compute={r.get('t_compute_s', 0):.3e} memory={r.get('t_memory_s', 0):.3e} "
        f"collective={r.get('t_collective_s', 0):.3e} dominant={r.get('dominant')}"
    )


if __name__ == "__main__":
    main()
