"""Per-computation cost breakdown of a dry-run combo: which while loops /
computations dominate each roofline term (the §Perf profile on CPU — no
wall-clock trace exists, so this IS the profiler)."""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import hlo_analysis as H


def breakdown(hlo_text, top=14):
    comps, entry = H._parse_computations(hlo_text)
    memo = {}
    total = H._comp_cost(comps, entry, memo)
    print(f"TOTAL flops={total.flops:.3e} mem={total.mem_bytes:.3e} coll={total.coll_total:.3e}")

    # effective (trip-multiplied) contribution per while loop
    rows = []
    for comp in comps.values():
        for op in comp.ops:
            if op.kind != "while":
                continue
            b = H._BODY_RE.search(op.rhs)
            tm = H._TRIP_RE.search(op.rhs)
            trips = int(tm.group(1)) if tm else 1
            body = memo.get(b.group(1)) if b else None
            if body:
                rows.append(
                    (trips * body.mem_bytes, trips * body.flops, trips * body.coll_total,
                     trips, b.group(1)[:70], comp.name[:40])
                )
    rows.sort(reverse=True)
    print(f"\n{'mem(bytes)':>12} {'flops':>12} {'coll':>12} {'trips':>6} body (in parent)")
    for mem, fl, co, trips, body, parent in rows[:top]:
        print(f"{mem:12.3e} {fl:12.3e} {co:12.3e} {trips:6d} {body}  <- {parent}")

    # biggest single ops in entry by result bytes
    ec = comps[entry]
    big = sorted(ec.ops, key=lambda o: -o.result_bytes)[:8]
    print("\nbiggest entry-level ops:")
    for op in big:
        print(f"  {op.result_bytes:12.3e}B {op.kind:>14} {op.name[:60]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    # reuse dryrun's lowering path, but keep the HLO
    import repro.launch.dryrun as dr
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import INPUT_SHAPES
    from repro.launch import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (
        abstract_opt_state, abstract_params, batch_pspecs, build_prefill_step,
        build_serve_step, build_train_step, cache_pspecs, train_shardings,
    )
    from repro.models import init_cache
    from repro.models.model import _batch_struct

    shape = INPUT_SHAPES[args.shape]
    cfg, rules = dr.configure(args.arch, shape)
    mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    shd.set_mesh(mesh, rules)
    params_struct = abstract_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        step, opt = build_train_step(cfg)
        opt_struct = abstract_opt_state(cfg, params_struct)
        batch_struct = _batch_struct(cfg, B, S, "train")
        ps, os_, bs = train_shardings(cfg, params_struct, opt_struct, batch_struct, B)
        lowered = jax.jit(step, in_shardings=(ps, os_, bs), out_shardings=(ps, os_, None),
                          donate_argnums=(0, 1)).lower(params_struct, opt_struct, batch_struct)
    else:
        raise SystemExit("breakdown currently supports train shapes")
    hlo = lowered.compile().as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(hlo)
    breakdown(hlo)


if __name__ == "__main__":
    main()
