"""Projects the roofline effect of the Pallas flash-attention kernel on a
saved dry-run HLO.

The kernel is validated numerically (tests/test_flash_attention.py) but
Mosaic kernels cannot be compiled on the CPU host backend, so its effect on
the roofline is computed analytically from the HLO:

  * identify the attention-block scan loops (bodies whose dots carry
    'bhgqk'/'bqhgd' einsum metadata — the score/PV matmuls) and the
    non-scanned attention dots;
  * REMOVE their memory traffic (probs/scores/softmax intermediates — these
    stay in VMEM inside the kernel);
  * ADD BACK the kernel's true HBM traffic: q, k, v, o (+ lse) block reads/
    writes = 2*(q+k+v+o) bytes per invocation;
  * FLOPs are unchanged (same matmuls, now on the MXU inside the kernel).

Usage: python scripts/flash_projection.py artifacts/perf_hlo/deepseek-7b.train_4k.pod.hlo.zst
"""

import re
import sys
import os

import zstandard

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch import hlo_analysis as H  # noqa: E402
from repro.launch.roofline import HW  # noqa: E402

ATTN_EINSUMS = ("bhgqk,bkhd->bqhgd", "bqhgd", "bhgqk")


def is_attention_comp(comp) -> bool:
    return any(any(tag in op.rhs for tag in ATTN_EINSUMS) for op in comp.ops)


def main(path):
    hlo = zstandard.ZstdDecompressor().decompress(open(path, "rb").read(), max_output_size=2**33).decode()
    comps, entry = H._parse_computations(hlo)
    memo = {}
    base = H._comp_cost(comps, entry, memo)

    # zero out memory of attention computations (keep flops/collectives),
    # then re-walk with a fresh memo
    removed = 0.0
    qkvo = 0.0
    for name, comp in comps.items():
        if not is_attention_comp(comp):
            continue
        c = memo.get(name)
        if not c:
            continue
        removed += c.mem_bytes
        # kernel IO: q/k/v read + o written once per invocation ~ the dot
        # operand/result tensors (B, S, H, D)-scale, approximated by the PV
        # dot result bytes (o) * 4 (q, k, v, o) * 2 (r+w convention)
        for op in comp.ops:
            if op.kind == "dot" and "bqhgd" in op.rhs:
                qkvo += 8 * op.result_bytes

    # removed/qkvo are per-execution of those comps; approximate the total
    # scale factor from the ratio of the full walk (trip-weighted) by
    # re-walking with attention comps' memory replaced
    class Patch(dict):
        pass

    # simple approach: re-run the walk but patch memo for attention comps
    memo2 = {}
    for name, comp in comps.items():
        if is_attention_comp(comp) and name in memo:
            c = memo[name]
            patched = H.HloCost()
            patched.flops = c.flops
            patched.coll_bytes = dict(c.coll_bytes)
            patched.coll_counts = dict(c.coll_counts)
            per_exec_qkvo = sum(
                8 * op.result_bytes for op in comp.ops if op.kind == "dot" and "bqhgd" in op.rhs
            )
            patched.mem_bytes = per_exec_qkvo
            memo2[name] = patched
    flash = H._comp_cost(comps, entry, memo2)

    print(f"baseline: compute={base.flops / HW['peak_flops']:.3e}s "
          f"memory={base.mem_bytes / HW['hbm_bw']:.3e}s "
          f"collective={base.coll_total / HW['link_bw']:.3e}s")
    print(f"flash-projected: compute={flash.flops / HW['peak_flops']:.3e}s "
          f"memory={flash.mem_bytes / HW['hbm_bw']:.3e}s "
          f"collective={flash.coll_total / HW['link_bw']:.3e}s")
    print(f"memory-term reduction: {base.mem_bytes / max(flash.mem_bytes, 1):.2f}x "
          f"({(base.mem_bytes - flash.mem_bytes) / HW['hbm_bw']:.2f}s removed)")


if __name__ == "__main__":
    main(sys.argv[1])
