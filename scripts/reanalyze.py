"""Re-runs the loop-aware HLO analysis over saved .hlo.zst artifacts and
updates the matching dry-run JSONs in place (walker improvements without
recompiles)."""

import glob
import json
import os
import sys

import zstandard

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch.roofline import roofline_terms_from_hlo  # noqa: E402


def main():
    for hf in sorted(glob.glob("artifacts/hlo/*.hlo.zst")):
        base = os.path.basename(hf)[: -len(".hlo.zst")]
        jf = os.path.join("artifacts", "dryrun", base + ".json")
        if not os.path.exists(jf):
            continue
        hlo = zstandard.ZstdDecompressor().decompress(open(hf, "rb").read(), max_output_size=2**33).decode()
        terms = roofline_terms_from_hlo(hlo)
        d = json.load(open(jf))
        d["roofline"] = terms
        json.dump(d, open(jf, "w"), indent=1)
        print(base, "->", terms["dominant"],
              f"c={terms['t_compute_s']:.2e} m={terms['t_memory_s']:.2e} x={terms['t_collective_s']:.2e}")


if __name__ == "__main__":
    main()
