#!/usr/bin/env bash
# CI entry point: dev deps (best-effort — tier-1 runs without network thanks
# to tests/_hypothesis_fallback.py), lint, tier-1 tests, the perf smokes
# (BENCH_batch/sweep/async/kernels/marginal/serve/pareto/fleet/faults/
# adaptive.json), the
# examples under -W error::DeprecationWarning, and the regression gate
# (scripts/check_bench.py) against the committed baselines.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1. dev dependencies (skipped gracefully on air-gapped containers)
pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "ci.sh: pip install failed (offline?) — continuing with bundled fallbacks"

# 2. lint (non-fatal only when ruff is UNAVAILABLE — same offline pattern as
#    the hypothesis fallback; when ruff is present, findings fail the build)
if python -m ruff --version >/dev/null 2>&1; then
  python -m ruff check .
else
  echo "ci.sh: ruff unavailable (offline?) — skipping lint"
fi

# 3. tier-1 tests (pytest.ini default deselects the slow interpret-mode
#    Pallas / flash-attention sweeps; full suite: -m "slow or not slow")
python -m pytest -x -q

# 4. snapshot the COMMITTED benchmark baselines (HEAD) before the smokes
#    overwrite the working-tree copies — comparing against the previous
#    local run instead would let regressions ratchet past the 30% tolerance
#    one ci.sh invocation at a time. Outside a git checkout (tarball), fall
#    back to the working-tree copy; a missing baseline entirely (first run)
#    is fine — check_bench reports NEW.
rm -rf .bench_baseline
mkdir -p .bench_baseline
for f in BENCH_*.json; do
  if [ -e "$f" ]; then
    if ! git show "HEAD:$f" > ".bench_baseline/$f" 2>/dev/null; then
      rm -f ".bench_baseline/$f"
      cp "$f" ".bench_baseline/$f"
    fi
  fi
done

# 5. perf smokes — a crash here must fail CI with the real error, not a
#    stale-JSON KeyError from a later step
if ! python benchmarks/bench_batch.py --smoke --out BENCH_batch.json; then
  echo "ci.sh: FAIL — bench_batch.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_sweep.py --smoke --out BENCH_sweep.json; then
  echo "ci.sh: FAIL — bench_sweep.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_async.py --smoke --out BENCH_async.json; then
  echo "ci.sh: FAIL — bench_async.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_kernels.py --smoke --out BENCH_kernels.json; then
  echo "ci.sh: FAIL — bench_kernels.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_marginal.py --smoke --out BENCH_marginal.json; then
  echo "ci.sh: FAIL — bench_marginal.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_serve.py --smoke --out BENCH_serve.json; then
  echo "ci.sh: FAIL — bench_serve.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_pareto.py --smoke --out BENCH_pareto.json; then
  echo "ci.sh: FAIL — bench_pareto.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_fleet.py --smoke --out BENCH_fleet.json; then
  echo "ci.sh: FAIL — bench_fleet.py perf smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_faults.py --smoke --out BENCH_faults.json; then
  echo "ci.sh: FAIL — bench_faults.py chaos smoke crashed" >&2
  exit 1
fi
if ! python benchmarks/bench_adaptive.py --smoke --out BENCH_adaptive.json; then
  echo "ci.sh: FAIL — bench_adaptive.py drift smoke crashed" >&2
  exit 1
fi

# 6. examples must run clean against the supported API: any
#    DeprecationWarning (a legacy shim sneaking back into the docs-facing
#    code paths) is an error
for ex in examples/*.py; do
  case "$(basename "$ex")" in
    fl_energy_training.py) ex_args="--rounds 2 --clients 3 --layers 1 --d-model 32 --max-batches 2" ;;
    *) ex_args="" ;;
  esac
  # shellcheck disable=SC2086
  if ! python -W error::DeprecationWarning "$ex" $ex_args >/dev/null; then
    echo "ci.sh: FAIL — example $ex crashed or emitted a DeprecationWarning" >&2
    exit 1
  fi
done

# 7. regression gate: ratio metrics vs baseline (30% tolerance) + hard
#    floors. On GitHub Actions the trajectory tables are also appended to
#    the step summary as a markdown dashboard.
python scripts/check_bench.py --baseline-dir .bench_baseline \
  ${GITHUB_STEP_SUMMARY:+--markdown "$GITHUB_STEP_SUMMARY"} BENCH_*.json

echo "ci.sh: OK"
