#!/usr/bin/env bash
# CI entry point: dev deps (best-effort — tier-1 runs without network thanks
# to tests/_hypothesis_fallback.py), tier-1 tests, and the batched-engine
# perf smoke that emits BENCH_batch.json for perf-trajectory tracking.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1. dev dependencies (skipped gracefully on air-gapped containers)
pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "ci.sh: pip install failed (offline?) — continuing with bundled fallbacks"

# 2. tier-1 tests (pytest.ini default deselects the slow interpret-mode
#    Pallas / flash-attention sweeps; full suite: -m "slow or not slow")
python -m pytest -x -q

# 3. batched scheduling engine perf smoke -> BENCH_batch.json
python benchmarks/bench_batch.py --smoke --out BENCH_batch.json

python - <<'EOF'
import json
r = json.load(open("BENCH_batch.json"))
print(f"ci.sh: batched DP speedup at B={r['B']}: "
      f"cold {r['speedup_cold']:.1f}x, warm {r['speedup_warm']:.1f}x")
assert r["speedup_vs_loop"] >= 5.0, "batched engine regression: < 5x over looped solves"
EOF

echo "ci.sh: OK"
