#!/usr/bin/env python
"""Perf regression gate: compare emitted BENCH_*.json against baselines.

Replaces the old hardcoded ``speedup_vs_loop >= 5.0`` assert in ci.sh with a
general policy over every benchmark JSON:

  * **gated metrics** (GATED below — the stable, dimensionless headline
    ratio per file; for files not listed there, every ``speedup``/
    ``throughput`` key): a drop of more than ``--tolerance`` (default 30%)
    below the baseline FAILS, and a gated metric that *disappears* from the
    current output (a silently-skipped benchmark leg) also FAILS.
  * **absolute floors** (FLOORS below) encode hard promises — e.g. the
    batched engine must stay >= 5x over looped solves, a cached sweep
    solve >= 5x over cold, the blocked min-plus kernel >= 2x over the
    dense oracle — regardless of what the baseline says. A floored metric
    that disappears from the current output also FAILS.
  * **absolute ceilings** (CEILINGS below) are the dual, for quality
    metrics where smaller is better — e.g. the hierarchical fleet solve's
    optimality gap vs the flat DP must stay <= 5%.
  * **everything else** (raw wall-clock ``_s`` seconds, warm-path
    micro-ratios like ``speedup_warm`` that legitimately swing 2x between
    identical runs, the CPU-sharded ``throughput_ratio`` smoke) is printed
    in the trajectory table but never gates.
  * a missing baseline is fine (first run): the current numbers are
    reported as NEW and pass.

``--markdown PATH`` additionally appends the trajectory tables as
GitHub-flavored markdown — CI points this at ``$GITHUB_STEP_SUMMARY`` so
every run publishes a bench-trajectory dashboard on the workflow summary
page (and uploads the accumulated per-commit ``BENCH_*`` history as an
artifact; see .github/workflows/ci.yml).

Usage (what ci.sh runs)::

    python scripts/check_bench.py --baseline-dir .bench_baseline BENCH_*.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

RATIO_PREFIXES = ("speedup", "throughput")
TIME_SUFFIX = "_s"

# Baseline-gated metrics per file: only the stable headline ratios. Known
# files gate nothing else (BENCH_batch.json's speedup_warm moves 2x between
# identical runs — gating it would make CI flaky by design); files NOT
# listed here get the conservative default of gating every ratio metric
# until someone tunes an entry in.
GATED = {
    "BENCH_batch.json": ("speedup_vs_loop",),
    "BENCH_sweep.json": ("speedup_cached_vs_cold",),
    # speedup_pipelined_vs_serial is reported but NOT gated: on a loaded
    # 2-core CI box the planner's XLA work contends with training and the
    # ratio hovers near 1.0 — the stable promise is the overlap fraction.
    "BENCH_async.json": ("planner_overlap_fraction",),
    # no baseline-ratio gating: speedup_blocked_vs_dense legitimately swings
    # ~2x with box load (3-5x measured on an idle-vs-busy 2-core box, same
    # pathology as speedup_warm) and speedup_fused_vs_twodispatch is a
    # near-1x info metric. The stable promise is the HARD FLOOR below;
    # missing-metric detection still covers floored metrics.
    "BENCH_kernels.json": (),
    # floor-only for the same reason: speedup_marginal_vs_dp swings with box
    # load (~15-30x measured on CPU, floor 3.0 below) and the mixed-split
    # ratio is an info metric (asymptote ~2x on half-monotone batches).
    "BENCH_marginal.json": (),
    # floor-only: the coalescing speedup and served throughput swing with
    # box load like every other wall-clock ratio; served latency p50/p99 is
    # info-only (milliseconds on a shared CI box gate nothing). The stable
    # promises are the hard floors below plus the in-bench asserts
    # (bit-identity, steady_state_compiles == 0) that crash the smoke.
    "BENCH_serve.json": (),
    # floor-only: like every wall-clock ratio the frontier speedup swings
    # with box load (~5.8-7.1x measured on CPU at the acceptance shape,
    # floor 5.0 below). Exactness vs the brute-force frontier, point-for-
    # point parity with per-point solves, and the one-dispatch contract are
    # enforced inside the bench itself (RuntimeError crashes the smoke).
    "BENCH_pareto.json": (),
    # floor + ceiling only: the two-level throughput swings with box load
    # (conservative floor below); the optimality-gap headline is quality,
    # not speed, so it gets a hard CEILING instead of a baseline ratio.
    # Flat-DP oracle parity (never beats the optimum, stays within the
    # certified gap_bound, singleton clustering exact) is asserted inside
    # the bench itself and crashes the smoke on violation.
    "BENCH_fleet.json": (),
    # no baseline-ratio gating: the chaos campaign's wall-clock legs swing
    # with box load like every other timing. The stable promises are the
    # FLOOR on recovery_success_rate (exactness, == 1.0) and the CEILING on
    # replan_overhead_pct below, plus the in-bench asserts (recovery
    # bit-identity, serial == pipelined chaos histories, campaigns finish)
    # that crash the smoke.
    "BENCH_faults.json": (),
    # floor + ceiling only: wall-clock legs swing with box load; the stable
    # promises are the FLOOR on speculation_hit_rate (mild seeded drift must
    # keep committing pre-solved rounds), the CEILING on regret_vs_oracle_pct
    # (the online calibrator tracks a regime flip), and the in-bench asserts
    # (serial == pipelined under drift+chaos, exactly ceil(R/k) dispatches on
    # a stationary fleet, frozen baseline regret above the ceiling, watermark
    # recovery bit-identical to reactive) that crash the smoke.
    "BENCH_adaptive.json": (),
}

# Hard floors: benchmark file -> {metric: minimum}. These hold even on the
# very first run, when no baseline exists yet.
FLOORS = {
    "BENCH_batch.json": {"speedup_vs_loop": 5.0},
    "BENCH_sweep.json": {"speedup_cached_vs_cold": 5.0},
    # the async pipeline must hide at least half of all planning time
    # behind client training (DESIGN.md §11; measured ~0.95+ on CPU)
    "BENCH_async.json": {"planner_overlap_fraction": 0.5},
    # the blocked backend must stay >= 2x over the dense oracle at the
    # memory-bound acceptance shape B=8, T=8192, W=512 (DESIGN.md §12;
    # ~3-8x measured on CPU)
    "BENCH_kernels.json": {"speedup_blocked_vs_dense": 2.0},
    # the monotone fast path must stay >= 3x over the fused DP at the
    # acceptance shape B=8, n=16, T=4096 (DESIGN.md §13; ~15-30x measured
    # on CPU — the DP does ~T/log(nW) times the work there)
    "BENCH_marginal.json": {"speedup_marginal_vs_dp": 3.0},
    # coalesced serving must stay >= 2x over one-dispatch-per-request on the
    # same warm engine (DESIGN.md §14; ~3.5-4.5x measured on a 1-core CPU
    # box), sustain a conservative absolute request rate, and never pay a
    # cold XLA trace in steady state (the <= ceiling is expressed as a
    # floor on the negated count: 0 compiles == 0.0, any compile < 0.0)
    "BENCH_serve.json": {
        "speedup_coalesced_vs_serial": 2.0,
        "throughput_rps": 1500.0,
        "steady_state_compiles_negated": 0.0,
    },
    # the whole Pareto frontier from ONE batched dispatch must stay >= 5x
    # over solving each ε-constraint point as its own engine call at the
    # acceptance shape n=8, T=64, 48 points (DESIGN.md §15; ~6-7x measured
    # on CPU — the batched path amortizes per-dispatch overhead across the
    # deadline grid)
    "BENCH_pareto.json": {"speedup_frontier_vs_perpoint": 5.0},
    # the hierarchical fleet solve must sustain a conservative warm
    # end-to-end rate at n=2048 (DESIGN.md §16; ~550-1800 clients/s
    # measured on idle-vs-loaded CPU — floor set far below to absorb
    # box-load swings on 2-core CI runners)
    "BENCH_fleet.json": {"fleet_throughput_n2048": 100.0},
    # every recovered round's residual re-plan must be bit-identical to an
    # independent fault-free solve of the carried residual instance
    # (DESIGN.md §17) — exactness is a hard promise, not a ratio
    "BENCH_faults.json": {"recovery_success_rate": 1.0},
    # under mild seeded drift the speculative lookahead must keep committing
    # pre-solved rounds (ISSUE 10; 1.0 measured at both smoke and full
    # shapes — the floor leaves headroom for future drift-model changes)
    "BENCH_adaptive.json": {"speculation_hit_rate": 0.5},
}

# Hard ceilings: benchmark file -> {metric: maximum}. The dual of FLOORS,
# for quality metrics where SMALLER is better (an optimality gap). Like
# floors these hold even on the very first run, and a ceilinged metric that
# disappears from the current output FAILS.
CEILINGS = {
    # worst measured optimality gap of the clustered two-level solve vs the
    # flat DP at n <= 64 (ISSUE 8 acceptance: <= 5%; ~0-1.5% measured)
    "BENCH_fleet.json": {"fleet_gap_pct": 5.0},
    # mean estimated-Joules overhead of reactive mid-round recovery vs the
    # clairvoyant oracle re-plan (ISSUE 9 acceptance: <= 15%; ~0-2%
    # measured — the residual instance is exact, so the only gap is work
    # already sunk on clients the oracle would have avoided)
    "BENCH_faults.json": {"replan_overhead_pct": 15.0},
    # TRUE-energy regret of the online calibrator vs the clairvoyant oracle
    # under a 2.5x regime flip (ISSUE 10; 14.1% measured at the 6-round
    # smoke shape, 4.2% at 12 rounds — the frozen-estimator baseline sits at
    # 23.9% / 28.6% and must stay ABOVE this line, asserted in-bench)
    "BENCH_adaptive.json": {"regret_vs_oracle_pct": 20.0},
}


def flatten(d: dict, prefix: str = "") -> dict:
    out = {}
    for k, v in d.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(flatten(v, key + "."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def is_gated(name: str, key: str) -> bool:
    explicit = GATED.get(name)
    if explicit is not None:
        return key in explicit
    return key.rsplit(".", 1)[-1].startswith(RATIO_PREFIXES)


def check_file(path: str, baseline_dir: str, tolerance: float) -> tuple:
    """Returns (failure strings, table rows); prints the trajectory table.

    Rows are ``(metric, baseline_str, current_str, delta_str, status)`` —
    the same content the console table shows, reused by the markdown
    dashboard renderer.
    """
    fails, rows = [], []
    cur = flatten(json.load(open(path)))
    name = os.path.basename(path)
    base_path = os.path.join(baseline_dir, name)
    base = flatten(json.load(open(base_path))) if os.path.exists(base_path) else None

    print(f"\n== {name} " + ("" if base is not None else "(NEW — no baseline)"))
    print(f"  {'metric':<32} {'baseline':>12} {'current':>12} {'delta':>8}  status")
    for key in sorted(cur):
        val = cur[key]
        ref = base.get(key) if base else None
        delta = "" if ref in (None, 0) else f"{(val - ref) / abs(ref) * 100:+.1f}%"
        status = "info"
        if is_gated(name, key):
            status = "ok"
            if ref is not None and val < ref * (1.0 - tolerance):
                status = "FAIL"
                fails.append(
                    f"{name}: {key} regressed {val:.2f} < {ref:.2f} "
                    f"* (1 - {tolerance:.0%})"
                )
        floor = FLOORS.get(name, {}).get(key)
        if floor is not None:
            if val < floor:
                status = "FAIL"
                fails.append(f"{name}: {key} = {val:.2f} below hard floor {floor}")
            elif status == "info":
                status = "ok"  # floor-only metrics are gated, not informational
        ceiling = CEILINGS.get(name, {}).get(key)
        if ceiling is not None:
            if val > ceiling:
                status = "FAIL"
                fails.append(f"{name}: {key} = {val:.2f} above hard ceiling {ceiling}")
            elif status == "info":
                status = "ok"
        ref_s = f"{ref:.4g}" if ref is not None else "-"
        print(f"  {key:<32} {ref_s:>12} {val:>12.4g} {delta:>8}  {status}")
        rows.append((key, ref_s, f"{val:.4g}", delta, status))

    # a gated or floored metric that vanished (e.g. a benchmark leg silently
    # skipped) must not pass unnoticed
    expected = (
        set(GATED.get(name, ()))
        | set(FLOORS.get(name, {}))
        | set(CEILINGS.get(name, {}))
    )
    if base is not None:
        expected |= {k for k in base if is_gated(name, k)}
    for key in sorted(expected - set(cur)):
        fails.append(f"{name}: gated metric {key} missing from current output")
        print(f"  {key:<32} {'?':>12} {'MISSING':>12} {'':>8}  FAIL")
        rows.append((key, "?", "MISSING", "", "FAIL"))
    return fails, rows


_STATUS_MD = {"ok": "✅ ok", "FAIL": "❌ FAIL", "info": "ℹ️ info"}


def render_markdown(tables: dict, fails: list, tolerance: float) -> str:
    """The bench-trajectory dashboard: one GFM table per benchmark file
    (appended to ``$GITHUB_STEP_SUMMARY`` by CI)."""
    out = ["## Bench trajectory", ""]
    for name, (had_baseline, rows) in tables.items():
        out.append(f"### {name}" + ("" if had_baseline else " *(NEW — no baseline)*"))
        out.append("")
        out.append("| metric | baseline | current | delta | status |")
        out.append("|---|---:|---:|---:|---|")
        for key, ref_s, val_s, delta, status in rows:
            out.append(
                f"| `{key}` | {ref_s} | {val_s} | {delta or '—'} "
                f"| {_STATUS_MD.get(status, status)} |"
            )
        out.append("")
    if fails:
        out.append("**check_bench: FAIL**")
        out.extend(f"- {f}" for f in fails)
    else:
        out.append(
            f"**check_bench: OK** ({len(tables)} file(s), tolerance {tolerance:.0%})"
        )
    out.append("")
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", help="benchmark JSONs (default: BENCH_*.json)")
    ap.add_argument("--baseline-dir", default=".bench_baseline")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.30,
        help="allowed fractional drop in ratio metrics vs baseline (default 0.30)",
    )
    ap.add_argument(
        "--markdown",
        default=None,
        metavar="PATH",
        help="append the trajectory tables as GitHub-flavored markdown to "
        "PATH (CI passes $GITHUB_STEP_SUMMARY)",
    )
    args = ap.parse_args()

    files = args.files or sorted(
        f for f in os.listdir(".") if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not files:
        print("check_bench: no BENCH_*.json files found — nothing to gate")
        return 1

    fails, tables = [], {}
    for path in files:
        if not os.path.exists(path):
            fails.append(f"{path}: benchmark output missing (did the smoke crash?)")
            continue
        try:
            name = os.path.basename(path)
            had_baseline = os.path.exists(os.path.join(args.baseline_dir, name))
            file_fails, rows = check_file(path, args.baseline_dir, args.tolerance)
            fails.extend(file_fails)
            tables[name] = (had_baseline, rows)
        except (json.JSONDecodeError, OSError) as e:
            fails.append(f"{path}: unreadable ({e})")

    if args.markdown:
        with open(args.markdown, "a") as f:
            f.write(render_markdown(tables, fails, args.tolerance))

    print()
    if fails:
        for f in fails:
            print(f"check_bench: FAIL — {f}", file=sys.stderr)
        return 1
    print(f"check_bench: OK ({len(files)} file(s), tolerance {args.tolerance:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
