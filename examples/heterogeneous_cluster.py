"""Beyond-paper application: energy-aware 1-D data partition across
heterogeneous accelerator pods (the paper notes its algorithms apply to any
one-dimensional data-partition problem, §6).

Scenario: a global batch of sequences must be split across pods with
different chip generations and power envelopes. Cost tables = measured
Joules per microbatch count (superlinear once a pod exceeds its efficient
operating point). The scheduler finds the minimum-energy split subject to
per-pod memory caps (upper limits) and keep-warm floors (lower limits).
"""

import numpy as np

from repro.core import Problem, Solver
from repro.core.costs import linear_cost, superlinear_cost


def pod_cost_table(u, joules_per_mb, dvfs_knee, p=1.8):
    """Energy for j microbatches: linear until the DVFS knee, superlinear after."""
    j = np.arange(u + 1, dtype=np.float64)
    base = joules_per_mb * j
    over = np.maximum(j - dvfs_knee, 0.0)
    return base + joules_per_mb * 0.25 * over ** p


def main():
    # Four pods: v5e-256 (efficient), v5e-128, old v4-128 (power hungry),
    # and a preemptible v5e-64 kept warm with a floor of 2 microbatches.
    pods = ["v5e-256", "v5e-128", "v4-128", "v5e-64-preempt"]
    upper = [64, 32, 32, 16]  # memory caps (max microbatches)
    lower = [0, 0, 0, 2]
    tables = (
        pod_cost_table(64, 12.0, 40),
        pod_cost_table(32, 13.0, 20),
        pod_cost_table(32, 21.0, 12),  # old gen: pricier per microbatch
        pod_cost_table(16, 13.5, 10),
    )
    T = 96  # global batch in microbatches

    problem = Problem(T=T, lower=lower, upper=upper, cost_tables=tables)
    problem.validate()
    print(f"global batch: {T} microbatches over {pods}")
    print(f"cost regime: {problem.regime()}\n")

    solver = Solver()  # the facade (DESIGN.md §15)
    for alg in ("auto", "uniform", "proportional", "olar"):
        sol = solver.solve(problem, algorithm=alg)
        per_pod = ", ".join(f"{p}={int(v)}" for p, v in zip(pods, sol.schedule))
        print(f"{alg:>14}: {per_pod}  ->  {sol.objective:8.1f} J/step")

    x_opt = solver.solve(problem)
    x_uni = solver.solve(problem, algorithm="uniform")
    save = 100 * (1 - x_opt.objective / x_uni.objective)
    print(f"\nper-step energy saved vs uniform: {save:.1f}% "
          f"(~{save:.1f}% of the training-campaign compute bill)")


if __name__ == "__main__":
    main()
