"""Carbon-aware FL scheduling (paper §6: the algorithms minimize ANY cost —
weight each device's energy by the carbon intensity of its grid region).

Cost tables become gCO2e(j) = carbon_intensity[g/kWh] * E_i(j)[J] / 3.6e6.
The same optimal algorithms then minimize emissions instead of Joules; the
example shows the schedule shifting work toward low-carbon regions even when
their devices are less energy-efficient.
"""

import numpy as np

from repro.core import Problem, schedule_batch, total_cost
from repro.core.costs import linear_cost

# (region, carbon g/kWh, device J/batch, max batches)
FLEET = [
    ("IS-hydro", 28, 3.0, 24),   # efficient grid, mediocre device
    ("FR-nuclear", 79, 2.2, 24),
    ("US-CA", 216, 1.8, 24),
    ("DE", 381, 1.6, 24),        # efficient device, dirty-ish grid
    ("PL-coal", 657, 1.5, 24),   # most efficient device, dirtiest grid
]


def main():
    T = 60
    n = len(FLEET)
    energy_tables = tuple(linear_cost(u, jpb) for _, _, jpb, u in FLEET)
    carbon_tables = tuple(
        linear_cost(u, jpb) * (ci / 3.6e6) * 1000  # -> mgCO2e
        for _, ci, jpb, u in FLEET
    )
    e_prob = Problem(T=T, lower=[0] * n, upper=[u for *_, u in FLEET], cost_tables=energy_tables)
    c_prob = Problem(T=T, lower=[0] * n, upper=[u for *_, u in FLEET], cost_tables=carbon_tables)

    # both objectives solved in ONE batched DP call (DESIGN.md §9): the
    # energy and carbon instances stack on the same fleet shape
    x_energy, x_carbon = schedule_batch([e_prob, c_prob], "dp_batch")

    print(f"{'region':>12} | {'J/batch':>7} | {'g/kWh':>6} | {'x (min J)':>9} | {'x (min CO2)':>11}")
    print("-" * 60)
    for (region, ci, jpb, u), xe, xc in zip(FLEET, x_energy, x_carbon):
        print(f"{region:>12} | {jpb:7.1f} | {ci:6d} | {int(xe):9d} | {int(xc):11d}")

    print(
        f"\nmin-energy schedule: {total_cost(e_prob, x_energy):.1f} J, "
        f"{total_cost(c_prob, x_energy):.2f} mgCO2e"
    )
    print(
        f"min-carbon schedule: {total_cost(e_prob, x_carbon):.1f} J, "
        f"{total_cost(c_prob, x_carbon):.2f} mgCO2e"
    )
    drop = 100 * (1 - total_cost(c_prob, x_carbon) / total_cost(c_prob, x_energy))
    print(f"emissions reduced {drop:.1f}% by optimizing the right objective")


if __name__ == "__main__":
    main()
