"""Carbon-aware FL scheduling (paper §6: the algorithms minimize ANY cost —
weight each device's energy by the carbon intensity of its grid region).

Cost tables become gCO2e(j) = carbon_intensity[g/kWh] * E_i(j)[J] / 3.6e6
(:func:`repro.core.costs.carbon_cost_table`). The same optimal algorithms
then minimize emissions instead of Joules; the example shows the schedule
shifting work toward low-carbon regions even when their devices are less
energy-efficient.

PR 7 extensions (DESIGN.md §15): grid carbon intensity is time-varying, so
the second half of the example sweeps a day of intensity windows
(:class:`repro.core.costs.CostWindows`) and prints the exact
(completion-time, emissions) Pareto frontier per window — every window and
every frontier point solved by ONE batched engine dispatch through
``Solver.frontier``.
"""

import numpy as np

from repro.core import CostWindows, Problem, Solver, total_cost
from repro.core.costs import carbon_cost_table, linear_cost

# (region, carbon g/kWh, device J/batch, max batches)
FLEET = [
    ("IS-hydro", 28, 3.0, 24),   # efficient grid, mediocre device
    ("FR-nuclear", 79, 2.2, 24),
    ("US-CA", 216, 1.8, 24),
    ("DE", 381, 1.6, 24),        # efficient device, dirty-ish grid
    ("PL-coal", 657, 1.5, 24),   # most efficient device, dirtiest grid
]

# seconds per batch (the slow devices sit on the clean grids)
SECONDS_PER_BATCH = [2.4, 1.8, 1.3, 1.1, 1.0]

# diurnal intensity multipliers per region: solar-heavy grids (US-CA) dip at
# midday, coal-heavy grids peak in the evening, baseload barely moves
WINDOW_MULT = {
    "night": [1.00, 0.95, 1.10, 1.05, 1.00],
    "midday": [1.00, 1.00, 0.55, 0.80, 1.05],
    "evening": [1.00, 1.10, 1.20, 1.25, 1.15],
}


def main():
    T = 60
    n = len(FLEET)
    upper = [u for *_, u in FLEET]
    energy_tables = tuple(linear_cost(u, jpb) for _, _, jpb, u in FLEET)
    carbon_tables = tuple(
        carbon_cost_table(linear_cost(u, jpb), ci)  # -> mgCO2e
        for _, ci, jpb, u in FLEET
    )
    e_prob = Problem(T=T, lower=[0] * n, upper=upper, cost_tables=energy_tables)
    c_prob = Problem(T=T, lower=[0] * n, upper=upper, cost_tables=carbon_tables)

    # both objectives solved in ONE batched DP call through the facade
    solver = Solver()
    sols = solver.solve([e_prob, c_prob], algorithm="dp_batch")
    x_energy, x_carbon = sols.schedules

    print(f"{'region':>12} | {'J/batch':>7} | {'g/kWh':>6} | {'x (min J)':>9} | {'x (min CO2)':>11}")
    print("-" * 60)
    for (region, ci, jpb, u), xe, xc in zip(FLEET, x_energy, x_carbon):
        print(f"{region:>12} | {jpb:7.1f} | {ci:6d} | {int(xe):9d} | {int(xc):11d}")

    print(
        f"\nmin-energy schedule: {total_cost(e_prob, x_energy):.1f} J, "
        f"{total_cost(c_prob, x_energy):.2f} mgCO2e"
    )
    print(
        f"min-carbon schedule: {total_cost(e_prob, x_carbon):.1f} J, "
        f"{total_cost(c_prob, x_carbon):.2f} mgCO2e"
    )
    drop = 100 * (1 - total_cost(c_prob, x_carbon) / total_cost(c_prob, x_energy))
    print(f"emissions reduced {drop:.1f}% by optimizing the right objective")

    # ---- time-varying intensity: per-window (time, emissions) frontiers ----
    time_tables = [
        np.arange(u + 1, dtype=np.float64) * spb
        for (*_, u), spb in zip(FLEET, SECONDS_PER_BATCH)
    ]
    labels = tuple(WINDOW_MULT)
    intensities = np.array(
        [[ci * m for (_, ci, *_), m in zip(FLEET, WINDOW_MULT[w])] for w in labels]
    )
    windows = CostWindows.from_carbon_intensities(labels, intensities)

    # all windows x all candidate deadlines: ONE engine dispatch
    fronts = solver.frontier(e_prob, time_tables, windows=windows)

    print("\n(time, emissions) Pareto frontier per intensity window")
    print(f"{'window':>8} | pts | {'fastest (s -> mg)':>20} | {'knee (s -> mg)':>18} | {'cleanest (s -> mg)':>20}")
    print("-" * 84)
    for w in labels:
        f = fronts[w]
        lo, kn, hi = f.min_time(), f.knee(), f.min_energy()
        print(
            f"{w:>8} | {len(f):3d} | {lo.time:7.1f} -> {lo.energy:8.2f} | "
            f"{kn.time:6.1f} -> {kn.energy:6.2f} | {hi.time:7.1f} -> {hi.energy:8.2f}"
        )

    best = min(labels, key=lambda w: fronts[w].min_energy().energy)
    kn = fronts[best].knee()
    print(
        f"\ncleanest window: {best!r} — knee point runs the round in "
        f"{kn.time:.1f}s at {kn.energy:.2f} mgCO2e "
        f"(deadline {kn.deadline:.1f}s, schedule {[int(v) for v in kn.schedule]})"
    )


if __name__ == "__main__":
    main()
