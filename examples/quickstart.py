"""Quickstart: schedule one FL round's workload for minimal energy.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    DEVICE_CLASSES,
    device_fleet_problem,
    schedule,
    select_algorithm,
    total_cost,
)


def main():
    rng = np.random.default_rng(0)
    # A heterogeneous fleet: 2 low-end phones, a tablet, a laptop, two edge
    # accelerators. Each gets an energy cost table C_i(j) (Joules for j
    # mini-batches) from its device class.
    classes = ["phone_lo", "phone_lo", "tablet", "laptop", "edge_tpu", "jetson"]
    T = 48  # mini-batches to distribute this round
    problem = device_fleet_problem(
        T=T,
        classes=classes,
        upper=[12, 12, 16, 24, 32, 32],
        lower=[1, 1, 0, 0, 0, 0],  # keep both phones participating
    )
    problem.validate()

    print(f"fleet: {classes}")
    print(f"round workload T={T}, regime detected: {problem.regime()!r}")
    print(f"auto-selected algorithm: {select_algorithm(problem)}\n")

    print(f"{'algorithm':>16} | {'schedule x_i':>28} | energy (J)")
    print("-" * 72)
    for alg in ("auto", "dp", "marin", "olar", "uniform", "proportional"):
        try:
            x = schedule(problem, alg)
        except Exception as e:
            print(f"{alg:>16} | inapplicable: {e}")
            continue
        print(f"{alg:>16} | {str(list(x)):>28} | {total_cost(problem, x):8.1f}")

    x_opt = schedule(problem, "auto")
    x_uni = schedule(problem, "uniform")
    save = 100 * (1 - total_cost(problem, x_opt) / total_cost(problem, x_uni))
    print(f"\nenergy saved vs uniform split: {save:.1f}%")


if __name__ == "__main__":
    main()
