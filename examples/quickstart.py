"""Quickstart: schedule one FL round's workload for minimal energy.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import Solver
from repro.core import device_fleet_problem, random_problem


def main():
    rng = np.random.default_rng(0)
    # A heterogeneous fleet: 2 low-end phones, a tablet, a laptop, two edge
    # accelerators. Each gets an energy cost table C_i(j) (Joules for j
    # mini-batches) from its device class.
    classes = ["phone_lo", "phone_lo", "tablet", "laptop", "edge_tpu", "jetson"]
    T = 48  # mini-batches to distribute this round
    problem = device_fleet_problem(
        T=T,
        classes=classes,
        upper=[12, 12, 16, 24, 32, 32],
        lower=[1, 1, 0, 0, 0, 0],  # keep both phones participating
    )
    problem.validate()

    # the Solver facade (DESIGN.md §15): one front door for every solve
    solver = Solver()
    opt = solver.solve(problem)
    print(f"fleet: {classes}")
    print(f"round workload T={T}, regime detected: {opt.regime!r}")
    print(f"auto-selected algorithm: {opt.algorithm}\n")

    print(f"{'algorithm':>16} | {'schedule x_i':>28} | energy (J)")
    print("-" * 72)
    for alg in ("auto", "dp", "marin", "olar", "uniform", "proportional"):
        try:
            sol = solver.solve(problem, algorithm=alg)
        except Exception as e:
            print(f"{alg:>16} | inapplicable: {e}")
            continue
        print(f"{alg:>16} | {str([int(v) for v in sol.schedule]):>28} | {sol.objective:8.1f}")

    x_uni = solver.solve(problem, algorithm="uniform")
    save = 100 * (1 - opt.objective / x_uni.objective)
    print(f"\nenergy saved vs uniform split: {save:.1f}%")

    # fleet scale (DESIGN.md §16): at hundreds+ of clients, solve_fleet
    # clusters similar cost profiles, solves each cluster once, and splits
    # the round's workload across clusters with a small exact knapsack —
    # returning a per-client schedule plus a certified optimality-gap bound
    big = random_problem(rng, n=256, T=512, max_upper=16)
    fsol = solver.solve_fleet(big)
    print(
        f"\nfleet scale: n=256 clients -> {fsol.num_clusters} clusters "
        f"(quantum {fsol.quantum}), energy {fsol.objective:.1f} J, "
        f"certified gap <= {fsol.gap_bound * 100:.2f}%"
    )


if __name__ == "__main__":
    main()
