"""End-to-end driver: federated training of a transformer LM with
energy-minimal workload scheduling, vs a uniform-split baseline.

Runs a real FedAvg campaign (masked-scan clients, jitted rounds) on a
synthetic non-IID corpus with a simulated heterogeneous fleet. Model size /
rounds are CLI-scalable; defaults run on a laptop CPU in a few minutes.

    PYTHONPATH=src python examples/fl_energy_training.py \
        --rounds 40 --clients 8 --layers 2 --d-model 128

Scaling up (e.g. --layers 8 --d-model 320 --vocab 8192 ~ 10M params,
--rounds 300) reproduces the same curves at larger scale.

``--frontier-mode knee`` (or ``min_energy`` / ``min_time`` / a seconds
budget) plans every round from the live (energy, completion-time) Pareto
frontier instead of the plain min-energy solve (DESIGN.md §15): the server
sweeps a deadline grid in one batched dispatch per round and picks the
configured operating point.
"""

import argparse
import time

import jax
import numpy as np

from repro import PlanPolicy, Solver
from repro.configs.base import ModelConfig
from repro.data import client_corpora, make_lm_examples
from repro.fl import EnergyEstimator, FederatedServer, make_fleet, run_campaign
from repro.models import init_params, loss_fn, param_count
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-batches", type=int, default=10)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--algorithm", default="auto", help="auto|dp|marin|olar|uniform|proportional")
    ap.add_argument("--compare", action="store_true", help="also run the uniform baseline")
    ap.add_argument(
        "--frontier-mode", default=None,
        help="knee|min_energy|min_time|<seconds> — pick each round's "
        "operating point from the live energy x time Pareto frontier",
    )
    args = ap.parse_args()
    frontier_mode = args.frontier_mode
    if frontier_mode is not None:
        try:
            frontier_mode = float(frontier_mode)  # a round-time budget
        except ValueError:
            pass

    cfg = ModelConfig(
        arch="fl-lm", family="dense",
        num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 2), num_kv_heads=max(args.d_model // 64, 2),
        d_ff=args.d_model * 4, vocab_size=args.vocab,
    )
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
          f"-> {param_count(params0)/1e6:.2f}M params")

    def lm_loss(params, batch):
        return loss_fn(params, cfg, {"tokens": batch})

    def campaign(algorithm, seed=0):
        rng = np.random.default_rng(seed)
        fleet = make_fleet(rng, args.clients, max_batches=args.max_batches)
        est = EnergyEstimator(fleet)
        est.calibrate(rng)
        corpora = client_corpora(rng, args.clients, args.seq * 200, args.vocab)
        examples = [make_lm_examples(c, args.seq) for c in corpora]
        # per-client time tables (seconds for j batches), for frontier mode:
        # seconds-per-batch drawn once per fleet, deterministic in the seed
        seconds_per_batch = np.random.default_rng(seed + 1).uniform(
            0.5, 2.5, size=args.clients
        )
        time_tables = [
            np.arange(d.max_batches + 1, dtype=np.float64) * spb
            for d, spb in zip(fleet, seconds_per_batch)
        ]
        server = FederatedServer(
            loss_fn=lm_loss,
            init_params=init_params(cfg, jax.random.PRNGKey(seed)),
            client_optimizer=sgd(args.lr),
            estimator=est,
            policy=PlanPolicy(
                algorithm=algorithm,
                frontier_mode=frontier_mode if algorithm != "uniform" else None,
                time_tables=time_tables,
            ),
        )
        T = sum(d.max_batches for d in fleet) // 2

        if frontier_mode is not None and algorithm != "uniform":
            # one facade call shows the trade-off space the planner works in
            front = Solver(engine=server.engine).frontier(
                est.problem(T), time_tables
            )
            lo, hi = front.min_time(), front.min_energy()
            print(
                f"  round-0 frontier: {len(front)} points, "
                f"{lo.time:.1f}s/{lo.energy:.0f}J (fastest) .. "
                f"{hi.time:.1f}s/{hi.energy:.0f}J (cheapest); mode={frontier_mode!r}"
            )
        t0 = time.time()

        def on_round(r):
            if r.round_index % max(args.rounds // 10, 1) == 0:
                print(
                    f"  [{algorithm}] round {r.round_index:3d} loss {r.mean_loss:.4f} "
                    f"energy {r.energy_joules:8.1f} J  x={[int(v) for v in r.assignments]}"
                )

        hist = run_campaign(
            server, examples, args.rounds, round_T=T, batch_size=args.batch,
            rng=rng, on_round=on_round,
        )
        print(f"  [{algorithm}] wall {time.time() - t0:.1f}s  {hist.summary()}")
        return hist

    print(f"\n=== campaign: {args.algorithm} scheduler ===")
    h_opt = campaign(args.algorithm)
    if args.compare:
        print("\n=== campaign: uniform baseline ===")
        h_uni = campaign("uniform")
        save = 100 * (1 - h_opt.total_energy / h_uni.total_energy)
        print(
            f"\nenergy: {h_opt.total_energy:.0f} J vs uniform {h_uni.total_energy:.0f} J "
            f"({save:.1f}% saved); final loss {h_opt.rounds[-1].mean_loss:.4f} "
            f"vs {h_uni.rounds[-1].mean_loss:.4f}"
        )


if __name__ == "__main__":
    main()
