from .client import local_train, make_client_fn
from .energy import DeviceProfile, EnergyEstimator, make_fleet
from .pipeline import (
    AsyncCampaignRunner,
    CampaignHistory,
    CampaignRunner,
    PipelineStats,
    PlanFuture,
    SerialPlanExecutor,
    ThreadPlanExecutor,
)
from .rounds import run_campaign
from .server import (
    FederatedServer,
    FLRoundResult,
    PlanPolicy,
    RoundPlan,
    ScenarioReport,
    apply_dropout,
)

__all__ = [
    "local_train", "make_client_fn", "DeviceProfile", "EnergyEstimator",
    "make_fleet", "FederatedServer", "FLRoundResult", "PlanPolicy", "RoundPlan",
    "ScenarioReport", "apply_dropout", "CampaignHistory", "run_campaign",
    "AsyncCampaignRunner", "CampaignRunner", "PipelineStats", "PlanFuture",
    "SerialPlanExecutor", "ThreadPlanExecutor",
]
