from .client import local_train, make_client_fn
from .energy import DeviceProfile, EnergyEstimator, make_fleet
from .rounds import CampaignHistory, run_campaign
from .server import FederatedServer, FLRoundResult, ScenarioReport, apply_dropout

__all__ = [
    "local_train", "make_client_fn", "DeviceProfile", "EnergyEstimator",
    "make_fleet", "FederatedServer", "FLRoundResult", "ScenarioReport",
    "apply_dropout", "CampaignHistory", "run_campaign",
]
