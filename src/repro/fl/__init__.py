from .adaptive import (
    AdaptiveCoordinator,
    AdaptiveRoundStats,
    DriftDetector,
    DriftInjector,
    DriftPlan,
    WatermarkStats,
    watermark_split,
)
from .client import local_train, make_client_fn
from .energy import DeviceProfile, EnergyEstimator, make_fleet
from .faults import (
    ClientFault,
    FaultInjector,
    FaultPlan,
    FlakyEngine,
    RoundFaults,
    proportional_greedy,
    residual_problem,
)
from .pipeline import (
    AsyncCampaignRunner,
    CampaignHistory,
    CampaignRunner,
    PipelineStats,
    PlanFuture,
    SerialPlanExecutor,
    ThreadPlanExecutor,
    load_campaign_checkpoint,
    save_campaign_checkpoint,
)
from .rounds import run_campaign
from .server import (
    FederatedServer,
    FLRoundResult,
    PlanPolicy,
    RecoveryInfo,
    RoundPlan,
    ScenarioReport,
    apply_dropout,
)

__all__ = [
    "local_train", "make_client_fn", "DeviceProfile", "EnergyEstimator",
    "make_fleet", "FederatedServer", "FLRoundResult", "PlanPolicy", "RoundPlan",
    "ScenarioReport", "apply_dropout", "CampaignHistory", "run_campaign",
    "AsyncCampaignRunner", "CampaignRunner", "PipelineStats", "PlanFuture",
    "SerialPlanExecutor", "ThreadPlanExecutor",
    "ClientFault", "FaultInjector", "FaultPlan", "FlakyEngine", "RoundFaults",
    "RecoveryInfo", "proportional_greedy", "residual_problem",
    "load_campaign_checkpoint", "save_campaign_checkpoint",
    "AdaptiveCoordinator", "AdaptiveRoundStats", "DriftDetector",
    "DriftInjector", "DriftPlan", "WatermarkStats", "watermark_split",
]
