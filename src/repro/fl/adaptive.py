"""Adaptive planning under drift (DESIGN.md §18).

The paper's schedules are optimal only for the cost tables they are handed;
in deployment those tables drift (thermal throttling, battery state,
contention) and a frozen schedule silently decays from optimal to wrong.
This module makes the campaign runtime *proactive* on top of PR 9's
reactive fault layer, with four cooperating pieces:

  * :class:`DriftPlan` / :class:`DriftInjector` — seeded, replayable drift:
    one integer seed expands into a per-(round, client) multiplicative
    scale on the TRUE device energy (random walk + throttle events).
    Applied on the main thread at the top of each round, drift is plan
    data — serial and pipelined campaigns see identical worlds, and
    checkpoint resume replays the same trajectory.
  * :class:`DriftDetector` — a two-sided Page–Hinkley test over the
    estimator's per-round mean relative innovation. Pure deterministic
    arithmetic over the telemetry sequence: the same rounds produce the
    same in-band / drifted classifications everywhere.
  * :class:`AdaptiveCoordinator` — speculative multi-round lookahead: at a
    round boundary it solves the next ``lookahead`` rounds' schedules from
    the estimator's PREDICTED tables as ONE extra
    :class:`~repro.core.solver.Solver` batch on the existing planner
    executor. When a speculative round arrives in-band (detector quiet,
    bounds unchanged, predicted tables within ``drift_tolerance`` of the
    fresh snapshot) the pre-solved schedule commits with ZERO extra engine
    dispatches; otherwise it counts a ``speculation_miss`` and re-plans
    fresh. Planning stays a pure function of the estimator snapshot, so the
    §11 serial == pipelined bit-identity contract is preserved.
  * :func:`watermark_split` — speculative *intra-round* re-planning: a
    mid-round telemetry watermark (the ``watermark_quantile`` of planned
    per-client finish times, in batch-time units) at which crashes that
    already happened and stragglers' projected completions are known
    (client-side progress telemetry timestamps every batch, so an observed
    rate below 1 projects the exact ``floor(x_i / sev)`` completion the
    fault model charges). Early-detectable faults trigger
    :meth:`~repro.fl.server.FederatedServer.recover_round`'s residual
    re-solve BEFORE the barrier; crashes after the watermark get a second,
    post-barrier pass. When every fault is early-detectable the early
    residual instance is byte-for-byte the reactive one, so the recovered
    assignments are bit-identical — only the wall-clock improves
    (``barrier_wait`` reduction reported per round).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.problem import Problem, total_cost
from .faults import RoundFaults

__all__ = [
    "AdaptiveCoordinator",
    "AdaptiveRoundStats",
    "DriftDetector",
    "DriftInjector",
    "DriftPlan",
    "WatermarkStats",
    "watermark_split",
]


# ---------------------------------------------------------------------------
# seeded drift: the world moves, deterministically
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class DriftPlan:
    """An immutable drift schedule: ``scales[r, i]`` multiplies client
    ``i``'s TRUE energy table during round ``r`` (rounds past the last row
    hold the final scale). Like :class:`~repro.fl.faults.FaultPlan`, the
    plan is DATA — one seed, one trajectory, replayable everywhere."""

    seed: int
    scales: np.ndarray  # (num_rounds, n_clients) float64 multiplicative
    events: tuple = ()  # ((round, client, factor, duration), ...) provenance

    def __post_init__(self):
        object.__setattr__(
            self, "scales", np.asarray(self.scales, dtype=np.float64)
        )

    @classmethod
    def generate(
        cls,
        seed: int,
        num_rounds: int,
        n_clients: int,
        walk_sigma: float = 0.01,
        p_event: float = 0.1,
        event_scale=(1.5, 3.0),
        event_rounds=(2, 5),
    ) -> "DriftPlan":
        """Expands ``seed`` into a drift trajectory: a per-client geometric
        random walk (log-scale steps ~ N(0, walk_sigma)) overlaid with
        throttle events — with probability ``p_event`` per round one client's
        cost multiplies by uniform(*event_scale*) for uniform(*event_rounds*)
        rounds, then recovers."""
        rng = np.random.default_rng(seed)
        walk = np.cumsum(
            rng.normal(0.0, walk_sigma, size=(num_rounds, n_clients)), axis=0
        )
        scales = np.exp(walk)
        events = []
        for r in range(num_rounds):
            if rng.random() < p_event:
                c = int(rng.integers(0, n_clients))
                f = float(rng.uniform(event_scale[0], event_scale[1]))
                dur = int(rng.integers(event_rounds[0], event_rounds[1] + 1))
                scales[r : r + dur, c] *= f
                events.append((r, c, f, dur))
        return cls(seed=int(seed), scales=scales, events=tuple(events))

    @classmethod
    def step(
        cls, num_rounds: int, n_clients: int, round_index: int, clients, factor: float,
        seed: int = 0,
    ) -> "DriftPlan":
        """A deterministic step event: from ``round_index`` on, each client
        in ``clients`` costs ``factor``x — the regime-flip benchmarks use
        this to make a frozen estimator measurably wrong."""
        scales = np.ones((int(num_rounds), int(n_clients)), dtype=np.float64)
        for c in clients:
            scales[int(round_index):, int(c)] = float(factor)
        events = tuple(
            (int(round_index), int(c), float(factor), int(num_rounds) - int(round_index))
            for c in clients
        )
        return cls(seed=int(seed), scales=scales, events=events)


class DriftInjector:
    """Applies a :class:`DriftPlan` to a fleet: a stateless per-round
    overwrite of each :class:`~repro.fl.energy.DeviceProfile.drift_scale`
    (so checkpoint resume lands in exactly the round's world). Touches only
    the TRUE simulator tables — the scheduler finds out through its own
    noisy measurements, like a real deployment would."""

    def __init__(self, plan: DriftPlan):
        self.plan = plan

    def apply(self, round_index: int, fleet) -> None:
        scales = self.plan.scales
        row = scales[min(int(round_index), len(scales) - 1)]
        for i, dev in enumerate(fleet):
            dev.drift_scale = float(row[i]) if i < len(row) else 1.0


# ---------------------------------------------------------------------------
# drift detection: two-sided Page–Hinkley over round-mean innovations
# ---------------------------------------------------------------------------


class DriftDetector:
    """Classifies each round's estimator telemetry as in-band or drifted.

    Input per round: the mean signed relative innovation
    ``z̄ = mean((measured - C_i(x_i)) / C_i(x_i))``. A calibrated, stationary
    fleet keeps ``z̄`` near 0 (measurement noise averages out); sustained or
    abrupt cost movement pushes it away. The test is the standard two-sided
    Page–Hinkley statistic: ``m_t = Σ (z̄_s - mean_s ∓ δ)`` with an alarm
    when the excursion from its running extremum exceeds ``λ``. Defaults tie
    both to the policy's drift tolerance (``δ = tolerance/2``,
    ``λ = tolerance``): changes smaller than the tolerance are absorbed by
    the calibrator, larger ones must invalidate speculation.

    Pure deterministic float arithmetic over the input sequence — no clocks,
    no randomness — so serial/pipelined campaigns and checkpoint resumes
    classify identically (state round-trips via :meth:`state`)."""

    _STATE_KEYS = ("t", "mean", "m_pos", "min_pos", "m_neg", "max_neg", "alarms", "last_drifted")

    def __init__(self, tolerance: float = 0.1, delta: Optional[float] = None,
                 threshold: Optional[float] = None):
        self.tolerance = float(tolerance)
        self.delta = float(delta) if delta is not None else self.tolerance / 2.0
        self.threshold = float(threshold) if threshold is not None else self.tolerance
        self.alarms = 0
        self.last_drifted = False
        self.reset()

    def reset(self) -> None:
        """Re-baselines the test (called after every alarm: the calibrator
        is already chasing the new regime, so the next rounds are judged
        against a fresh baseline)."""
        self.t = 0
        self.mean = 0.0
        self.m_pos = 0.0
        self.min_pos = 0.0
        self.m_neg = 0.0
        self.max_neg = 0.0

    def update(self, value: float) -> bool:
        """Folds one round's signal in; returns True when the round is
        classified as drifted."""
        x = float(value)
        self.t += 1
        self.mean += (x - self.mean) / self.t
        self.m_pos += x - self.mean - self.delta
        self.min_pos = min(self.min_pos, self.m_pos)
        self.m_neg += x - self.mean + self.delta
        self.max_neg = max(self.max_neg, self.m_neg)
        drifted = (self.m_pos - self.min_pos > self.threshold) or (
            self.max_neg - self.m_neg > self.threshold
        )
        if drifted:
            self.alarms += 1
            self.reset()
        self.last_drifted = bool(drifted)
        return bool(drifted)

    def state(self) -> dict:
        return {
            "t": int(self.t), "mean": float(self.mean),
            "m_pos": float(self.m_pos), "min_pos": float(self.min_pos),
            "m_neg": float(self.m_neg), "max_neg": float(self.max_neg),
            "alarms": int(self.alarms), "last_drifted": bool(self.last_drifted),
        }

    def load_state(self, state: dict) -> None:
        for k in self._STATE_KEYS:
            setattr(self, k, state[k])


# ---------------------------------------------------------------------------
# intra-round watermark: re-plan before the barrier
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class WatermarkStats:
    """Timing of one watermarked round, in batch-time units (healthy client
    = 1 batch per unit; client ``i``'s local window closes at ``x_i``; the
    round barrier is ``max x_i``). ``reactive_finish`` is when the round
    would end had recovery waited for the barrier; ``early_finish`` is when
    it ends with recovery work dispatched at the watermark."""

    t_watermark: float
    t_barrier: float
    early_detected: tuple  # clients whose fault was visible at the watermark
    late_detected: tuple  # crashes after the watermark (second-pass recovery)
    reactive_finish: float = 0.0
    early_finish: float = 0.0

    @property
    def saved(self) -> float:
        return max(self.reactive_finish - self.early_finish, 0.0)

    @property
    def saved_pct(self) -> float:
        if self.reactive_finish <= 0.0:
            return 0.0
        return 100.0 * self.saved / self.reactive_finish

    def as_dict(self) -> dict:
        return {
            "t_watermark": float(self.t_watermark),
            "t_barrier": float(self.t_barrier),
            "early_detected": [int(c) for c in self.early_detected],
            "late_detected": [int(c) for c in self.late_detected],
            "reactive_finish": float(self.reactive_finish),
            "early_finish": float(self.early_finish),
        }

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> Optional["WatermarkStats"]:
        if d is None:
            return None
        return cls(
            t_watermark=float(d["t_watermark"]),
            t_barrier=float(d["t_barrier"]),
            early_detected=tuple(int(c) for c in d["early_detected"]),
            late_detected=tuple(int(c) for c in d["late_detected"]),
            reactive_finish=float(d["reactive_finish"]),
            early_finish=float(d["early_finish"]),
        )


def watermark_split(faults: RoundFaults, assignments, quantile: float):
    """Splits a round's faults into what the mid-round watermark can see.

    The watermark fires at the ``quantile`` of planned per-client finish
    times (participants only). At that instant the telemetry knows, exactly
    and deterministically:

      * crashes whose crash time (= batches banked, at unit rate) is before
        the watermark — the heartbeat already went silent;
      * every straggler's projected completion: per-batch latency telemetry
        puts its observed rate at ``1/sev``, which projects to precisely the
        ``floor(x_i / sev)`` batches the fault model will charge.

    Crashes at or after the watermark are invisible until they happen and
    are returned separately for a post-barrier second pass.

    Returns ``(early_faults, late_crashed, stats)`` where ``early_faults``
    is a :class:`~repro.fl.faults.RoundFaults` over the ORIGINAL assignments
    (None when nothing is early-detectable), ``late_crashed`` is a tuple of
    client ids, and ``stats`` is a partially-filled :class:`WatermarkStats`
    (finish times are filled in once recovery assignments are known)."""
    x = np.asarray(assignments, dtype=np.int64)
    active = x[x > 0].astype(np.float64)
    if active.size == 0:
        return None, tuple(faults.crashed), None
    t_barrier = float(active.max())
    t_watermark = float(np.quantile(active, float(quantile)))
    early_crashed = tuple(
        int(c) for c in faults.crashed if float(faults.completed[c]) < t_watermark
    )
    late_crashed = tuple(
        int(c) for c in faults.crashed if float(faults.completed[c]) >= t_watermark
    )
    stragglers = tuple(int(s) for s in faults.stragglers)
    early = None
    if early_crashed or stragglers:
        completed = x.copy()  # late crashes still look healthy at the watermark
        for c in early_crashed:
            completed[c] = min(int(faults.completed[c]), int(x[c]))
        for s in stragglers:
            completed[s] = min(int(faults.completed[s]), int(x[s]))
        early = RoundFaults(
            round_index=int(faults.round_index),
            completed=completed,
            crashed=early_crashed,
            stragglers=stragglers,
        )
    stats = WatermarkStats(
        t_watermark=t_watermark,
        t_barrier=t_barrier,
        early_detected=tuple(sorted(set(early_crashed) | set(stragglers))),
        late_detected=late_crashed,
    )
    return early, late_crashed, stats


# ---------------------------------------------------------------------------
# per-round adaptive telemetry
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdaptiveRoundStats:
    """What the adaptive layer did to one round: the drift classification of
    its telemetry, whether its plan came from a committed speculation, and
    the watermark timing when intra-round re-planning fired."""

    round_index: int
    drifted: bool = False
    innovation_mean: float = 0.0
    innovation_abs: float = 0.0
    speculation: Optional[str] = None  # "hit" | "miss" | None (fresh solve)
    watermark: Optional[WatermarkStats] = None

    def as_dict(self) -> dict:
        return {
            "round_index": int(self.round_index),
            "drifted": bool(self.drifted),
            "innovation_mean": float(self.innovation_mean),
            "innovation_abs": float(self.innovation_abs),
            "speculation": self.speculation,
            "watermark": None if self.watermark is None else self.watermark.as_dict(),
        }

    @classmethod
    def from_dict(cls, d: Optional[dict]) -> Optional["AdaptiveRoundStats"]:
        if d is None:
            return None
        return cls(
            round_index=int(d["round_index"]),
            drifted=bool(d["drifted"]),
            innovation_mean=float(d["innovation_mean"]),
            innovation_abs=float(d["innovation_abs"]),
            speculation=d["speculation"],
            watermark=WatermarkStats.from_dict(d.get("watermark")),
        )


# ---------------------------------------------------------------------------
# the coordinator: speculation + watermark + reliability, one owner
# ---------------------------------------------------------------------------


class _SpecEntry:
    """One buffered speculative plan: the predicted problem it was solved
    against and where its schedule row lives (a shared batch future until
    materialized, then a concrete array after checkpoint/restore)."""

    __slots__ = ("round_index", "problem", "future", "index", "schedule")

    def __init__(self, round_index, problem, future, index, schedule=None):
        self.round_index = int(round_index)
        self.problem = problem
        self.future = future
        self.index = int(index)
        self.schedule = schedule

    def materialize(self) -> np.ndarray:
        if self.schedule is None:
            self.schedule = np.asarray(
                self.future.result()[self.index], dtype=np.int64
            )
        return self.schedule


class AdaptiveCoordinator:
    """Owns the campaign loop's adaptive state (DESIGN.md §18): the drift
    detector, the speculative plan buffer, reliability bookkeeping, and the
    watermark recovery path. Created by the campaign runner when the
    server's :class:`~repro.core.fleet.PlanPolicy` enables any adaptive
    feature; with the policy defaults the runner never constructs one and
    every code path is byte-identical to the pre-adaptive loop.

    Determinism contract: every decision (validate/commit/miss, drift
    classification, reliability updates, watermark splits) happens on the
    MAIN thread from main-thread state; the planner executor only ever runs
    pure functions of immutable snapshots (the speculative batch solve, the
    commit materialization). The single-FIFO executor guarantee (§11) makes
    the commit task safe: its batch future was submitted earlier, so it is
    resolved — or at the head of the queue — by the time the commit runs."""

    def __init__(self, server):
        policy = server.policy
        self.server = server
        self.lookahead = int(policy.lookahead)
        self.tolerance = float(policy.drift_tolerance)
        self.watermark_quantile = (
            None if policy.watermark_quantile is None else float(policy.watermark_quantile)
        )
        self.reliability = (
            None if policy.reliability is None else float(policy.reliability)
        )
        self.detector = DriftDetector(tolerance=self.tolerance)
        self.spec_hits = 0
        self.spec_misses = 0
        self.spec_batches = 0
        self.drift_rounds = 0
        self.early_replans = 0
        self._wm_saved: list = []
        self._wm_saved_pct: list = []
        self._buffer: list = []  # _SpecEntry, ascending round order
        self._pending: Optional[dict] = None  # next round's plan decision
        self._pending_future = None
        self._per_round: dict = {}  # round -> AdaptiveRoundStats (popped per round)

    @staticmethod
    def enabled(policy) -> bool:
        return (
            int(policy.lookahead) > 0
            or policy.watermark_quantile is not None
            or policy.reliability is not None
        )

    # ---- planning ------------------------------------------------------

    def first_plan(self, round_index: int, T: int, submit):
        """The campaign's eager initial submission. After a checkpoint
        restore whose pending decision targets this round, the stored
        schedule is replayed instead of re-solving — bit-identical to the
        uninterrupted run, with zero extra dispatches."""
        if self._pending is not None and self._pending["round"] == int(round_index):
            return self._replay_pending(T, submit)
        return self._submit_fresh(round_index, T, self.server.build_problem(T), submit)

    def next_plan(self, round_index: int, T: int, submit):
        """The round-boundary planning decision for ``round_index``: commit
        the buffered speculative plan when it validates in-band (zero extra
        solves), otherwise count a miss, flush the stale buffer, and solve
        fresh (refilling the speculation window)."""
        fresh = self.server.build_problem(T)
        entry = None
        if self._buffer and self._buffer[0].round_index == int(round_index):
            entry = self._buffer.pop(0)
        elif self._buffer:
            self._buffer = []
        if entry is not None:
            if self._validates(entry, fresh):
                self.spec_hits += 1
                self._stats(round_index).speculation = "hit"
                self._pending = {"round": int(round_index), "mode": "commit"}
                f = submit(
                    f"plan[{round_index}]:commit", self._commit_plan,
                    round_index, T, entry, fresh,
                )
                self._pending_future = f
                return f
            self.spec_misses += 1
            self._stats(round_index).speculation = "miss"
            self._buffer = []
        return self._submit_fresh(round_index, T, fresh, submit)

    def _submit_fresh(self, round_index: int, T: int, fresh: Problem, submit):
        if self.lookahead <= 0:
            self._pending = None
            self._pending_future = None
            return submit(
                f"plan[{round_index}]", self.server.plan_round, round_index, T, fresh
            )
        problems = [fresh] + [
            self.server.predict_problem(T, s) for s in range(1, self.lookahead)
        ]
        last = round_index + len(problems) - 1
        batch_f = submit(f"spec[{round_index}..{last}]", self._solve_batch, problems)
        self.spec_batches += 1
        self._buffer = [
            _SpecEntry(round_index + s, problems[s], batch_f, s)
            for s in range(1, len(problems))
        ]
        self._pending = {"round": int(round_index), "mode": "solve"}
        f = submit(
            f"plan[{round_index}]", self._plan_from_batch,
            round_index, T, batch_f, 0, fresh,
        )
        self._pending_future = f
        return f

    def _solve_batch(self, problems) -> list:
        sol = self.server.solver.solve(list(problems), check=False)
        return [np.asarray(x, dtype=np.int64) for x in sol.schedules]

    def _plan_from_batch(self, round_index, T, batch_f, index, fresh):
        from .server import RoundPlan

        x = np.asarray(batch_f.result()[index], dtype=np.int64)
        return RoundPlan(
            round_index=int(round_index),
            T=int(T),
            assignments=x.copy(),
            est_cost=float(total_cost(fresh, x)),
            problem=fresh,
        )

    def _commit_plan(self, round_index, T, entry: _SpecEntry, fresh: Problem):
        from .server import RoundPlan

        x = entry.materialize()
        return RoundPlan(
            round_index=int(round_index),
            T=int(T),
            assignments=x.copy(),
            est_cost=float(total_cost(fresh, x)),
            problem=fresh,
        )

    def _replay_pending(self, T, submit):
        from .server import RoundPlan

        pend = self._pending
        x = np.asarray(pend["x"], dtype=np.int64)
        round_index = int(pend["round"])
        fresh = self.server.build_problem(T)

        def restored_plan():
            return RoundPlan(
                round_index=round_index,
                T=int(T),
                assignments=x.copy(),
                est_cost=float(total_cost(fresh, x)),
                problem=fresh,
            )

        f = submit(f"plan[{round_index}]:resume", restored_plan)
        self._pending_future = f
        return f

    def _validates(self, entry: _SpecEntry, fresh: Problem) -> bool:
        """In-band check for a speculative plan, on the MAIN thread: the
        detector's last round must be in-band, the bounds and workload must
        match exactly (a reliability down-weighting or dropout invalidates
        the plan's feasibility envelope), and each client's predicted
        full-capacity cost must sit within ``drift_tolerance`` of the fresh
        snapshot (the tables are whole-table rescales, so the endpoint
        captures the scale deviation)."""
        if self.detector.last_drifted:
            return False
        p = entry.problem
        if int(p.T) != int(fresh.T):
            return False
        if not np.array_equal(p.lower, fresh.lower):
            return False
        if not np.array_equal(p.upper, fresh.upper):
            return False
        for pt, ft, u in zip(p.cost_tables, fresh.cost_tables, fresh.upper):
            u = int(u)
            if u <= 0:
                continue
            ref = abs(float(ft[u]))
            if ref <= 0.0:
                continue
            if abs(float(pt[u]) - float(ft[u])) / ref > self.tolerance:
                return False
        return True

    # ---- telemetry -----------------------------------------------------

    def after_account(self, round_index: int, plan, faults) -> None:
        """Post-accounting telemetry fold (main thread, round order): drains
        the estimator's round innovations into the drift detector and feeds
        crash/straggle outcomes into the reliability scores."""
        innovations = self.server.estimator.drain_innovations()
        zs = np.array([z for (_, _, z) in innovations], dtype=np.float64)
        zbar = float(zs.mean()) if zs.size else 0.0
        drifted = self.detector.update(zbar)
        st = self._stats(round_index)
        st.drifted = bool(drifted)
        st.innovation_mean = zbar
        st.innovation_abs = float(np.abs(zs).mean()) if zs.size else 0.0
        if drifted:
            self.drift_rounds += 1
        if self.reliability is not None:
            x0 = (
                plan.recovery.assignments_original
                if plan.recovery is not None
                else plan.assignments
            )
            participated = [int(i) for i in np.nonzero(np.asarray(x0) > 0)[0]]
            faulty = faults.lost_clients if faults is not None else ()
            self.server.estimator.record_round_outcome(
                participated, faulty, decay=self.reliability
            )

    def handle_faults(self, plan, faults):
        """Round recovery through the adaptive layer. Without a watermark
        quantile this is exactly the reactive path; with one, faults visible
        at the watermark re-solve BEFORE the barrier and late crashes get a
        second post-barrier pass."""
        if faults is None:
            return plan
        if self.watermark_quantile is None:
            return self.server.recover_round(plan, faults)
        x0 = np.asarray(plan.assignments, dtype=np.int64)
        early, late_crashed, wm = watermark_split(faults, x0, self.watermark_quantile)
        if wm is None or early is None:
            # nothing was visible before the barrier: plain reactive recovery
            return self.server.recover_round(plan, faults)
        plan = self.server.recover_round(plan, early)
        self.early_replans += 1
        y = (
            np.asarray(plan.recovery.recovery_assignments, dtype=np.int64)
            if plan.recovery is not None
            else np.zeros_like(x0)
        )
        late_tail = 0.0
        if late_crashed:
            x1 = np.asarray(plan.assignments, dtype=np.int64)
            completed = x1.copy()
            for c in late_crashed:
                completed[c] = min(int(faults.completed[c]), int(x1[c]))
            if int(completed.sum()) < int(x1.sum()):
                late = RoundFaults(
                    round_index=int(faults.round_index),
                    completed=completed,
                    crashed=tuple(late_crashed),
                    stragglers=(),
                )
                plan = self.server.recover_round(plan, late)
                if plan.recovery is not None:
                    y2 = np.asarray(plan.recovery.recovery_assignments, np.int64)
                    late_tail = float(y2.max()) if y2.size else 0.0
        # timing model (batch-time units): reactive recovery dispatches at
        # the barrier, early recovery at the watermark — each survivor's
        # extra work starts when its own window frees up (or at the
        # watermark, whichever is later).
        t_w, t_b = wm.t_watermark, wm.t_barrier
        early_finish = t_b
        for i in np.nonzero(y > 0)[0]:
            early_finish = max(early_finish, max(t_w, float(x0[i])) + float(y[i]))
        if late_crashed:
            # late crashes force post-barrier work either way: report the
            # conservative zero-savings comparison for this round
            early_finish = max(early_finish, t_b + late_tail)
            reactive_finish = early_finish
        else:
            reactive_finish = t_b + (float(y.max()) if y.size else 0.0)
        wm.reactive_finish = reactive_finish
        wm.early_finish = early_finish
        self._stats(plan.round_index).watermark = wm
        self._wm_saved.append(wm.saved)
        self._wm_saved_pct.append(wm.saved_pct)
        return plan

    def round_stats(self, round_index: int) -> Optional[AdaptiveRoundStats]:
        return self._per_round.pop(int(round_index), None)

    def _stats(self, round_index: int) -> AdaptiveRoundStats:
        st = self._per_round.get(int(round_index))
        if st is None:
            st = AdaptiveRoundStats(round_index=int(round_index))
            self._per_round[int(round_index)] = st
        return st

    def summary_stats(self) -> dict:
        """Campaign-level adaptive telemetry (folded into
        :meth:`~repro.fl.pipeline.CampaignHistory.summary`)."""
        validated = self.spec_hits + self.spec_misses
        return {
            "drift_rounds": int(self.drift_rounds),
            "speculation_hits": int(self.spec_hits),
            "speculation_misses": int(self.spec_misses),
            "speculation_batches": int(self.spec_batches),
            "speculation_hit_rate": (
                float(self.spec_hits) / validated if validated else 0.0
            ),
            "early_replans": int(self.early_replans),
            "barrier_wait_saved": float(np.sum(self._wm_saved)) if self._wm_saved else 0.0,
            "barrier_wait_saved_pct_mean": (
                float(np.mean(self._wm_saved_pct)) if self._wm_saved_pct else 0.0
            ),
        }

    # ---- checkpoint ----------------------------------------------------

    def checkpoint_state(self) -> dict:
        """The coordinator's complete restart state, with every in-flight
        speculative schedule materialized (a failed speculative batch drops
        its entries — the resumed campaign re-plans fresh). Consumed by
        ``save_campaign_checkpoint``."""
        entries = []
        for e in self._buffer:
            try:
                x = e.materialize()
            except Exception:
                continue
            entries.append({"round": int(e.round_index), "problem": e.problem, "x": x})
        pending = None
        if self._pending is not None:
            if "x" in self._pending:
                pending = dict(self._pending)
            elif self._pending_future is not None:
                try:
                    xp = np.asarray(
                        self._pending_future.result().assignments, dtype=np.int64
                    )
                    pending = dict(self._pending, x=xp)
                except Exception:
                    pending = None
        return {
            "entries": entries,
            "pending": pending,
            "detector": self.detector.state(),
            "counters": {
                "spec_hits": int(self.spec_hits),
                "spec_misses": int(self.spec_misses),
                "spec_batches": int(self.spec_batches),
                "drift_rounds": int(self.drift_rounds),
                "early_replans": int(self.early_replans),
            },
            "per_round": {int(r): st.as_dict() for r, st in self._per_round.items()},
            "wm_saved": [float(v) for v in self._wm_saved],
            "wm_saved_pct": [float(v) for v in self._wm_saved_pct],
        }

    def load_checkpoint_state(self, state: dict) -> None:
        self._buffer = [
            _SpecEntry(e["round"], e["problem"], None, 0,
                       schedule=np.asarray(e["x"], dtype=np.int64))
            for e in state["entries"]
        ]
        self._pending = state["pending"]
        self._pending_future = None
        self.detector.load_state(state["detector"])
        c = state["counters"]
        self.spec_hits = int(c["spec_hits"])
        self.spec_misses = int(c["spec_misses"])
        self.spec_batches = int(c["spec_batches"])
        self.drift_rounds = int(c["drift_rounds"])
        self.early_replans = int(c["early_replans"])
        self._per_round = {
            int(r): AdaptiveRoundStats.from_dict(d)
            for r, d in state["per_round"].items()
        }
        self._wm_saved = [float(v) for v in state["wm_saved"]]
        self._wm_saved_pct = [float(v) for v in state["wm_saved_pct"]]
