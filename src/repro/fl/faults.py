"""Deterministic fault injection for chaos-testing the campaign runtime.

Every chaos scenario is reproducible from ONE integer seed (DESIGN.md §17):
:meth:`FaultPlan.generate` expands a seed into a concrete, immutable fault
schedule — which client crashes at what fraction of its assignment, which
straggles by what slowdown, which engine dispatch ordinals raise
:class:`~repro.core.resilience.TransientEngineError`, and which rounds see a
burst of extra service traffic. The plan is DATA, not randomness at
injection time, so serial and pipelined campaigns under the same plan see
identical faults.

Pieces:

  * :class:`ClientFault` / :class:`FaultPlan` — the seeded schedule.
  * :class:`FaultInjector` — turns a plan + a round's planned assignments
    into :class:`RoundFaults` telemetry (batches actually completed, which
    clients are lost for the rest of the round), the input to
    :meth:`~repro.fl.server.FederatedServer.recover_round`.
  * :class:`FlakyEngine` — a :class:`~repro.core.sweep.SweepEngine` wrapper
    that raises at planned dispatch ordinals (transient = a short run the
    retry budget covers; persistent = a run at least as long as the budget),
    delegating everything else to the real engine.
  * :func:`residual_problem` / :func:`proportional_greedy` — the recovery
    math: the residual instance is EXACT under the paper's atomic-task model
    (marginal tables ``C_i(c_i + j) - C_i(c_i)``), and the greedy fallback
    is guaranteed feasible whenever any residual capacity exists.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence

import numpy as np

from ..core.problem import Problem
from ..core.resilience import TransientEngineError

__all__ = [
    "ClientFault",
    "FaultInjector",
    "FaultPlan",
    "FlakyEngine",
    "RoundFaults",
    "proportional_greedy",
    "residual_problem",
]


@dataclasses.dataclass(frozen=True)
class ClientFault:
    """One client-side failure event.

    ``kind="crash"``: the client dies after completing
    ``floor(x_i * severity)`` of its ``x_i`` assigned batches
    (``severity`` in [0, 1)) and takes no recovery work.
    ``kind="straggle"``: the client runs ``severity``x slower (> 1) and only
    finishes ``floor(x_i / severity)`` batches inside the round window; the
    shortfall is re-planned onto the healthy cohort.
    """

    round_index: int
    client: int
    kind: str  # "crash" | "straggle"
    severity: float

    def __post_init__(self):
        if self.kind not in ("crash", "straggle"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "crash" and not (0.0 <= self.severity < 1.0):
            raise ValueError("crash severity is a completed fraction in [0, 1)")
        if self.kind == "straggle" and self.severity <= 1.0:
            raise ValueError("straggle severity is a slowdown factor > 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable chaos schedule, typically built by :meth:`generate`.

    ``engine_faults`` are DISPATCH ORDINALS: the k-th ``dispatch()``/
    ``solve()`` call on a :class:`FlakyEngine` wrapping this plan raises
    :class:`~repro.core.resilience.TransientEngineError` iff ``k`` is
    listed. A run of consecutive ordinals shorter than the retry budget is a
    transient failure; a run at least as long is persistent (the caller's
    retries exhaust and its fallback path must engage).
    ``overload_bursts`` maps round → number of extra one-off service
    requests injected at the top of that round.
    """

    seed: int
    client_faults: tuple = ()
    engine_faults: tuple = ()
    overload_bursts: tuple = ()  # of (round_index, n_requests)

    @classmethod
    def generate(
        cls,
        seed: int,
        num_rounds: int,
        n_clients: int,
        p_crash: float = 0.1,
        p_straggle: float = 0.1,
        engine_fault_rounds: float = 0.0,
        engine_run_len: int = 1,
        dispatch_budget: int = 256,
        p_burst: float = 0.0,
        burst_size: int = 8,
        max_faulty_fraction: float = 0.5,
    ) -> "FaultPlan":
        """Expands ``seed`` into a concrete plan.

        Per round, each client independently crashes with ``p_crash`` (at a
        uniform completed fraction) or straggles with ``p_straggle``
        (slowdown uniform in [1.5, 4]); at most
        ``floor(n_clients * max_faulty_fraction)`` clients fault per round so
        a surviving cohort always exists. ``engine_fault_rounds`` scales how
        many failure RUNS to scatter over ``dispatch_budget`` dispatch
        ordinals, each run ``engine_run_len`` consecutive ordinals long.
        ``p_burst`` adds an ``overload_bursts`` entry of ``burst_size``
        requests per selected round.
        """
        rng = np.random.default_rng(seed)
        faults = []
        cap = max(1, int(n_clients * max_faulty_fraction))
        for r in range(num_rounds):
            hit = []
            for i in range(n_clients):
                u = rng.random()
                if u < p_crash:
                    hit.append(ClientFault(r, i, "crash", float(rng.random() * 0.9)))
                elif u < p_crash + p_straggle:
                    hit.append(
                        ClientFault(r, i, "straggle", float(1.5 + 2.5 * rng.random()))
                    )
            # deterministic cap: keep the earliest-drawn faults
            faults.extend(hit[:cap])
        n_runs = int(round(engine_fault_rounds * num_rounds))
        ordinals = set()
        for _ in range(n_runs):
            start = int(rng.integers(0, max(1, dispatch_budget - engine_run_len)))
            ordinals.update(range(start, start + engine_run_len))
        bursts = tuple(
            (r, int(burst_size)) for r in range(num_rounds) if rng.random() < p_burst
        )
        return cls(
            seed=int(seed),
            client_faults=tuple(faults),
            engine_faults=tuple(sorted(ordinals)),
            overload_bursts=bursts,
        )


@dataclasses.dataclass
class RoundFaults:
    """What a round's telemetry reports after the faults fired: per-client
    batches actually completed, and which clients are lost to recovery
    (crashed clients are gone; stragglers are busy finishing their reduced
    share, so neither can absorb residual work this round)."""

    round_index: int
    completed: np.ndarray  # (n,) int64 batches actually finished
    crashed: tuple
    stragglers: tuple

    @property
    def lost_clients(self) -> tuple:
        return tuple(sorted(set(self.crashed) | set(self.stragglers)))


class FaultInjector:
    """Applies a :class:`FaultPlan` to a running campaign. Stateless across
    rounds apart from the shared dispatch-ordinal counter inside any
    :class:`FlakyEngine` built via :meth:`wrap_engine` — round fault lookup
    is a pure function of (plan, round_index, assignments)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._by_round: dict = {}
        for f in plan.client_faults:
            self._by_round.setdefault(int(f.round_index), []).append(f)
        self._bursts = {int(r): int(k) for r, k in plan.overload_bursts}

    def wrap_engine(self, engine) -> "FlakyEngine":
        return FlakyEngine(engine, self.plan.engine_faults)

    def round_faults(self, round_index: int, assignments) -> Optional[RoundFaults]:
        """The faults that fire against this round's planned ``assignments``
        — or None when the round is clean (including when every planned
        fault is a no-op because its client had ``x_i = 0``)."""
        hits = self._by_round.get(int(round_index))
        if not hits:
            return None
        x = np.asarray(assignments, dtype=np.int64)
        completed = x.copy()
        crashed, stragglers = [], []
        for f in hits:
            if f.client >= len(x):
                continue
            xi = int(x[f.client])
            if f.kind == "crash":
                completed[f.client] = min(xi, int(np.floor(xi * f.severity)))
                crashed.append(int(f.client))
            else:
                completed[f.client] = min(xi, int(np.floor(xi / f.severity)))
                stragglers.append(int(f.client))
        if int(completed.sum()) == int(x.sum()):
            return None
        return RoundFaults(
            round_index=int(round_index),
            completed=completed,
            crashed=tuple(sorted(set(crashed))),
            stragglers=tuple(sorted(set(stragglers))),
        )

    def burst(self, round_index: int) -> int:
        """Extra one-off service requests to inject at the top of a round."""
        return self._bursts.get(int(round_index), 0)

    def burst_problem(self, round_index: int, i: int) -> Problem:
        """A deterministic small instance for burst request ``i`` of a round
        (seeded off the plan seed — identical across replays)."""
        rng = np.random.default_rng((self.plan.seed, int(round_index), int(i)))
        n, upper = 4, 8
        tables = tuple(
            np.concatenate([[0.0], np.cumsum(rng.random(upper))]) for _ in range(n)
        )
        return Problem(
            T=2 * n,
            lower=np.zeros(n, dtype=np.int64),
            upper=np.full(n, upper, dtype=np.int64),
            cost_tables=tables,
        )


class FlakyEngine:
    """A :class:`~repro.core.sweep.SweepEngine` proxy that raises
    :class:`~repro.core.resilience.TransientEngineError` at the planned
    dispatch ordinals and otherwise delegates verbatim (``cache_stats``,
    ``max_entries``, ... pass straight through, so the wrapped engine drops
    into every engine-shaped seam — ``Solver``, ``SchedulerService``,
    ``FederatedServer``). The ordinal counter is shared across threads
    (lock-guarded): ordinal k means the k-th dispatch issued anywhere in the
    process against this wrapper."""

    def __init__(self, engine, fail_ordinals: Sequence[int] = ()):
        self._engine = engine
        self._fail = frozenset(int(o) for o in fail_ordinals)
        self._lock = threading.Lock()
        self._calls = 0
        self._injected = 0

    def _tick(self) -> None:
        with self._lock:
            ordinal = self._calls
            self._calls += 1
            if ordinal in self._fail:
                self._injected += 1
                raise TransientEngineError(f"injected engine fault at dispatch {ordinal}")

    def dispatch(self, problems, split_regimes: bool = False):
        self._tick()
        return self._engine.dispatch(problems, split_regimes=split_regimes)

    def solve(self, problems, split_regimes: bool = False):
        self._tick()
        return self._engine.solve(problems, split_regimes=split_regimes)

    def fault_stats(self) -> dict:
        with self._lock:
            return {"dispatches": self._calls, "injected_failures": self._injected}

    def __getattr__(self, name):
        return getattr(self._engine, name)


def residual_problem(problem: Problem, completed, lost) -> Problem:
    """The EXACT residual instance after a partial round: client ``i`` has
    ``completed[i]`` batches banked, clients in ``lost`` can take no more
    work, and the marginal cost of ``j`` extra batches on a survivor is
    ``C_i(c_i + j) - C_i(c_i)`` — exact under the paper's atomic-task model
    (Def. 1: per-batch costs are independent of when the batch runs).

    The residual workload is the shortfall ``T - sum(completed)``, clipped
    to the surviving capacity (a fleet-wide outage can shrink the round,
    mirroring :func:`~repro.fl.server.apply_dropout`). Lower limits are 0:
    participation floors applied to the ORIGINAL plan, and recovery must
    stay feasible on whatever cohort survives.
    """
    completed = np.minimum(
        np.asarray(completed, dtype=np.int64), problem.upper
    )
    lost = set(int(i) for i in lost)
    upper = problem.upper - completed
    gone = np.array([i in lost for i in range(problem.n)])
    upper = np.where(gone, 0, upper)
    tables = []
    for i in range(problem.n):
        if upper[i] == 0:
            tables.append(np.zeros(1))
        else:
            c = int(completed[i])
            tbl = problem.cost_tables[i]
            tables.append(tbl[c : c + int(upper[i]) + 1] - tbl[c])
    residual = int(problem.T) - int(completed.sum())
    T_res = int(np.clip(residual, 0, int(upper.sum())))
    return Problem(
        T=T_res,
        lower=np.zeros(problem.n, dtype=np.int64),
        upper=upper,
        cost_tables=tuple(tables),
    )


def proportional_greedy(problem: Problem) -> np.ndarray:
    """Guaranteed-feasible fallback schedule for a 0-lower-limit residual
    instance: floor-proportional to capacity, then the remainder placed one
    unit at a time on the cheapest-marginal client with headroom (ties →
    lowest index — fully deterministic). Used when the solver itself is the
    failing component; feasibility needs only ``T <= sum(upper)``, which
    :func:`residual_problem` guarantees by construction."""
    upper = np.asarray(problem.upper, dtype=np.int64)
    T = int(problem.T)
    cap = int(upper.sum())
    if T > cap:
        raise ValueError(f"infeasible fallback: T={T} > capacity {cap}")
    if cap == 0 or T == 0:
        return np.zeros(problem.n, dtype=np.int64)
    x = (upper * T) // cap  # floor-proportional, never exceeds upper
    remainder = T - int(x.sum())
    for _ in range(remainder):
        best, best_marg = -1, np.inf
        for i in range(problem.n):
            if x[i] < upper[i]:
                marg = problem.cost_tables[i][int(x[i]) + 1] - problem.cost_tables[i][int(x[i])]
                if marg < best_marg:
                    best, best_marg = i, float(marg)
        x[best] += 1
    return x.astype(np.int64)
