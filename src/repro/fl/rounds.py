"""FL campaign driver: multi-round orchestration + energy accounting."""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from ..data.pipeline import lm_round_batches
from .server import FederatedServer, FLRoundResult

__all__ = ["CampaignHistory", "run_campaign"]


@dataclasses.dataclass
class CampaignHistory:
    algorithm: str
    rounds: List[FLRoundResult]
    # sweep-engine counter deltas over the campaign (DESIGN.md §10):
    # hits/misses/compiles/evictions accrued by this campaign's DP solves.
    # Round shapes repeat, so a healthy campaign shows compiles <= 1 after
    # the first round warmed the bucket — see dp_compiles in summary().
    dp_cache_stats: Optional[dict] = None

    @property
    def total_energy(self) -> float:
        return float(sum(r.energy_joules for r in self.rounds))

    @property
    def losses(self) -> np.ndarray:
        return np.array([r.mean_loss for r in self.rounds])

    def summary(self) -> dict:
        out = {
            "algorithm": self.algorithm,
            "rounds": len(self.rounds),
            "total_energy_J": self.total_energy,
            "final_loss": float(self.rounds[-1].mean_loss) if self.rounds else float("nan"),
            "mean_makespan_J": float(np.mean([r.makespan_joules for r in self.rounds])) if self.rounds else 0.0,
        }
        if self.dp_cache_stats is not None:
            out["dp_compiles"] = self.dp_cache_stats["compiles"]
            out["dp_cache_hits"] = self.dp_cache_stats["hits"]
        return out


def run_campaign(
    server: FederatedServer,
    examples_per_client: list,
    num_rounds: int,
    round_T: int,
    batch_size: int,
    rng: np.random.Generator,
    max_steps: Optional[int] = None,
    on_round: Optional[Callable[[FLRoundResult], None]] = None,
) -> CampaignHistory:
    """Runs ``num_rounds`` FedAvg rounds with ``round_T`` total mini-batches
    scheduled across clients each round.

    The history's ``dp_cache_stats`` records the counter deltas on the
    SERVER'S sweep engine over the campaign: with warm (or repeating)
    shapes this shows one compile at most — rounds 2+ are compile-free.
    Caveat: a server left on the process-wide default engine shares those
    counters with every other ``schedule_batch``/``deadline_sweep`` caller,
    so concurrent solver traffic (including from an ``on_round`` callback)
    lands in the delta too. Pass ``FederatedServer(engine=SweepEngine())``
    when the accounting must isolate this campaign.
    """
    server.round_T = round_T
    if max_steps is None:
        max_steps = max(d.max_batches for d in server.estimator.fleet)
    before = server.engine.cache_stats()
    results = []
    for r in range(num_rounds):
        batches = lm_round_batches(examples_per_client, max_steps, batch_size, r)
        res = server.run_round(r, batches, rng)
        results.append(res)
        if on_round:
            on_round(res)
    after = server.engine.cache_stats()
    delta = {k: after[k] - before[k] for k in ("hits", "misses", "compiles", "evictions")}
    delta["entries"] = after["entries"]
    return CampaignHistory(
        algorithm=server.algorithm, rounds=results, dp_cache_stats=delta
    )
