"""FL campaign driver: multi-round orchestration + energy accounting.

The loop itself lives in :mod:`repro.fl.pipeline` (DESIGN.md §11) — ONE
code path over the server's ``plan -> train -> aggregate`` stages, run
either serially or with a background planner thread that overlaps round
*r*'s client training with round *r+1*'s scenario planning. This module
keeps the stable entry point: :func:`run_campaign`.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .pipeline import CampaignHistory, CampaignRunner
from .server import FederatedServer, FLRoundResult

__all__ = ["CampaignHistory", "run_campaign"]


def run_campaign(
    server: FederatedServer,
    examples_per_client: list,
    num_rounds: int,
    round_T: int,
    batch_size: int,
    rng: np.random.Generator,
    max_steps: Optional[int] = None,
    on_round: Optional[Callable[[FLRoundResult], None]] = None,
    pipelined: bool = False,
    faults=None,
    drift=None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
) -> CampaignHistory:
    """Runs ``num_rounds`` FedAvg rounds with ``round_T`` total mini-batches
    scheduled across clients each round.

    ``pipelined=False`` plans inline (the reference path); ``pipelined=True``
    moves every DP solve onto a background planner thread that overlaps with
    client training — schedules, losses, and energy accounting are
    bit-identical either way (asserted in tests/test_fl_pipeline.py), only
    the wall-clock interleaving changes. The history's ``pipeline_stats``
    reports how much planning time the pipeline hid (``overlap_fraction``).

    The history's ``dp_cache_stats`` records the counter deltas on the
    SERVER'S sweep engine over the campaign: with warm (or repeating)
    shapes this shows one compile at most — rounds 2+ are compile-free.
    Caveat: a server left on the process-wide default engine shares those
    counters with every other ``schedule_batch``/``deadline_sweep`` caller,
    so concurrent solver traffic (including from an ``on_round`` callback)
    lands in the delta too. Pass ``FederatedServer(engine=SweepEngine())``
    when the accounting must isolate this campaign.

    ``faults`` (a :class:`~repro.fl.faults.FaultPlan` or
    :class:`~repro.fl.faults.FaultInjector`) arms the deterministic
    fault-injection layer; ``drift`` (a :class:`~repro.fl.adaptive.DriftPlan`
    or :class:`~repro.fl.adaptive.DriftInjector`) arms deterministic
    per-round energy-cost drift on the TRUE simulator tables;
    ``checkpoint_dir``/``checkpoint_every`` arm round-granular
    checkpoint/resume — all fully inert when unset (DESIGN.md §17–18).
    """
    runner = CampaignRunner(server, mode="pipelined" if pipelined else "serial")
    return runner.run(
        examples_per_client,
        num_rounds,
        round_T,
        batch_size,
        rng,
        max_steps=max_steps,
        on_round=on_round,
        faults=faults,
        drift=drift,
        checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every,
    )
