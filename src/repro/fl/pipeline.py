"""Async round pipeline: overlap client training with next-round planning.

The campaign loop (DESIGN.md §11) is ONE code path over the server's round
stages (``plan -> train -> aggregate``; see fl/server.py), parameterized by
a *plan executor* that decides WHERE planning tasks run:

  * :class:`SerialPlanExecutor` — every task runs inline at submit time; the
    reference semantics (identical to the pre-pipeline serial driver).
  * :class:`ThreadPlanExecutor` — a single background planner thread drains
    tasks in FIFO submission order. While round *r*'s clients train inside
    the jitted SPMD program, the planner is already solving round *r*'s
    what-if scenario batch and round *r+1*'s schedule through the shared
    :class:`~repro.core.sweep.SweepEngine` (via its non-blocking
    ``dispatch``), so no DP solve ever issues a ``block_until_ready`` on the
    round hot path. Scenario batches are regime-split (DESIGN.md §13):
    monotone-cost what-ifs resolve on the marginal fast path in
    O(B·nW·log nW), so with monotone energy models the planner's per-round
    work shrinks by the full DP factor — the pipeline then hides estimator
    bookkeeping rather than heavyweight solves.

Every task is handed back as a :class:`PlanFuture`; results materialize only
when the next round actually needs them (``PlanFuture.result()``).

**Why results are bit-identical across executors.** Planning tasks are pure
functions of immutable snapshots: the campaign loop builds every
:class:`~repro.core.problem.Problem` on the main thread (after that round's
``account_round`` folded measurements into the estimator) and submits only
the deterministic solve. The random stream and estimator mutations live
exclusively in ``account_round``, which always runs on the main thread in
round order. So serial and pipelined campaigns consume identical inputs in
identical order — the executors differ only in wall-clock interleaving, and
``tests/test_fl_pipeline.py`` asserts schedules, losses, and energy match
bit-for-bit.

When the server is constructed with a
:class:`~repro.serve.service.SchedulerService`, the planner thread's
scenario solves route through the service's coalescer instead of hitting
the engine directly (``FederatedServer.solve_scenarios`` submits the batch
as one service request): campaign what-if planning and external served
traffic then merge into shared flushes and warm ONE compile cache
(DESIGN.md §14). Bit-identity is preserved — the service pads requests
inertly, exactly like the engine's own bucketing — so the executors'
determinism contract above is unchanged.

Frontier-mode planning (PR 7, DESIGN.md §15) keeps the same contract:
``PlanPolicy(frontier_mode=...)`` turns each ``plan_round`` into a
batched ε-constraint sweep plus a deterministic frontier-point selection,
but the deadline grid, the sweep, and the selection rule are all pure
functions of the immutable estimator snapshot — so frontier-planned
campaigns pipeline exactly like min-energy ones, bit-identical across
executors. Fleet-mode planning (PR 8, DESIGN.md §16) joins it:
``PlanPolicy(fleet_clusters=...)`` swaps each ``plan_round`` for the
two-level cluster-then-allocate solve, whose k-means seeding and greedy
residual repair are deterministic in the snapshot and
``policy.fleet_seed`` — thousands-of-client rounds pipeline with the same
bit-identity guarantee.

Overlap accounting: each PlanFuture records the planner time it consumed
(``busy_s``) and the main-thread time spent blocked in ``result()``
(``blocked_s``). The campaign's ``overlap_fraction`` is the share of
planning time hidden off the hot path — 0.0 by construction for the serial
executor, → 1.0 when training fully hides planning. ``benchmarks/
bench_async.py`` gates this at >= 0.5 on CPU via scripts/check_bench.py.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, List, Optional

import numpy as np

from ..checkpoint import latest_checkpoint, load_checkpoint_arrays, save_checkpoint
from ..core.problem import Problem, total_cost
from ..core.resilience import is_transient
from ..data.pipeline import lm_round_batches
from .adaptive import AdaptiveCoordinator, AdaptiveRoundStats, DriftInjector, DriftPlan
from .faults import FaultInjector, FaultPlan, proportional_greedy, residual_problem
from .server import (
    FederatedServer,
    FLRoundResult,
    RecoveryInfo,
    RoundPlan,
    ScenarioReport,
)

__all__ = [
    "AsyncCampaignRunner",
    "CampaignHistory",
    "CampaignRunner",
    "PipelineStats",
    "PlanFuture",
    "SerialPlanExecutor",
    "ThreadPlanExecutor",
    "load_campaign_checkpoint",
    "save_campaign_checkpoint",
]


# ---------------------------------------------------------------------------
# plan futures + executors
# ---------------------------------------------------------------------------


class PlanFuture:
    """Handle to one planning task (a schedule solve, a scenario batch).

    ``result()`` blocks until the task finished (re-raising any planner
    exception) and records how long the caller waited — the pipeline's
    overlap accounting. ``busy_s`` is the executor time the task consumed.
    """

    def __init__(self, label: str):
        self.label = label
        self.busy_s = 0.0  # executor time spent computing this task
        self.blocked_s = 0.0  # caller time spent blocked in result()
        self._event = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def _run(self, fn: Callable, args: tuple) -> None:
        t0 = time.perf_counter()
        try:
            self._value = fn(*args)
        except BaseException as e:  # surfaced at result() — see crash test
            self._exc = e
        finally:
            self.busy_s = time.perf_counter() - t0
            self._event.set()

    def result(self):
        """Materializes the task's value, blocking if still in flight."""
        if not self._event.is_set():
            t0 = time.perf_counter()
            self._event.wait()
            self.blocked_s += time.perf_counter() - t0
        if self._exc is not None:
            raise self._exc
        return self._value


class SerialPlanExecutor:
    """Runs every planning task inline at submit time (reference path).

    Inline tasks sit fully on the hot path, so their entire ``busy_s``
    counts as blocked — the serial overlap fraction is exactly 0.
    """

    mode = "serial"

    def submit(self, label: str, fn: Callable, *args) -> PlanFuture:
        f = PlanFuture(label)
        f._run(fn, args)
        f.blocked_s = f.busy_s
        return f

    def shutdown(self) -> None:
        pass


class ThreadPlanExecutor:
    """Single background planner thread, FIFO task order.

    One thread (not a pool): tasks execute in exactly the submission order —
    the same order the serial executor runs them — which keeps estimator
    snapshots/solves sequenced identically and the engine's compile-cache
    accounting race-free.
    """

    mode = "pipelined"

    def __init__(self, name: str = "fl-planner"):
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    def submit(self, label: str, fn: Callable, *args) -> PlanFuture:
        f = PlanFuture(label)
        self._q.put((f, fn, args))
        return f

    def _loop(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            f, fn, args = item
            f._run(fn, args)

    def shutdown(self) -> None:
        """Drains queued tasks, then joins the planner thread."""
        self._q.put(None)
        self._thread.join()


_EXECUTORS = {"serial": SerialPlanExecutor, "pipelined": ThreadPlanExecutor}


# ---------------------------------------------------------------------------
# round-granular campaign checkpointing (DESIGN.md §17)
#
# A checkpoint is the complete round-r restart state: params, estimator
# tables, the rng bit-generator state, and every completed FLRoundResult
# (recovery provenance included). Arrays ride the npz tree; scalars and
# labels ride the json manifest's ``extra``. Restoring and continuing is
# bit-identical to never having stopped: the rng stream resumes mid-sequence
# and planning is a pure function of the restored estimator snapshot.
# ---------------------------------------------------------------------------


def _problem_to_tree(p: Problem) -> dict:
    tree = {"T": np.int64(p.T), "lower": np.asarray(p.lower), "upper": np.asarray(p.upper)}
    for i, tbl in enumerate(p.cost_tables):
        tree[f"tbl{i:04d}"] = np.asarray(tbl)
    return tree


def _problem_from_arrays(get) -> Problem:
    lower = np.asarray(get("lower"), dtype=np.int64)
    tables = tuple(np.asarray(get(f"tbl{i:04d}"), np.float64) for i in range(len(lower)))
    return Problem(
        T=int(get("T")), lower=lower, upper=np.asarray(get("upper"), np.int64),
        cost_tables=tables,
    )


def _round_to_tree_meta(res: FLRoundResult):
    tree = {"assignments": np.asarray(res.assignments, dtype=np.int64)}
    meta = {
        "round_index": int(res.round_index),
        "mean_loss": float(res.mean_loss),
        "energy_joules": float(res.energy_joules),
        "estimated_joules": float(res.estimated_joules),
        "makespan_joules": float(res.makespan_joules),
        "scen_labels": None,
        "recovery": None,
        "adaptive": None if res.adaptive is None else res.adaptive.as_dict(),
    }
    if res.scenarios is not None:
        meta["scen_labels"] = [str(lbl) for lbl in res.scenarios.labels]
        tree["scen_x"] = np.asarray(res.scenarios.assignments)
        tree["scen_e"] = np.asarray(res.scenarios.energies)
    if res.recovery is not None:
        ri = res.recovery
        meta["recovery"] = {
            "failed_clients": [int(i) for i in ri.failed_clients],
            "straggler_clients": [int(i) for i in ri.straggler_clients],
            "residual_T": int(ri.residual_T),
            "shortfall": int(ri.shortfall),
            "attempts": int(ri.attempts),
            "fallback": bool(ri.fallback),
            "est_cost_original": float(ri.est_cost_original),
            "est_overhead_J": float(ri.est_overhead_J),
            "has_residual_problem": ri.residual_problem is not None,
            "has_problem": ri.problem is not None,
        }
        tree["rec_completed"] = np.asarray(ri.completed, dtype=np.int64)
        tree["rec_x0"] = np.asarray(ri.assignments_original, dtype=np.int64)
        tree["rec_y"] = np.asarray(ri.recovery_assignments, dtype=np.int64)
        if ri.residual_problem is not None:
            tree["rec_q"] = _problem_to_tree(ri.residual_problem)
        if ri.problem is not None:
            tree["rec_p"] = _problem_to_tree(ri.problem)
    return tree, meta


def _round_from_arrays(data: dict, prefix: str, meta: dict) -> FLRoundResult:
    scenarios = None
    if meta["scen_labels"] is not None:
        scenarios = ScenarioReport(
            labels=list(meta["scen_labels"]),
            assignments=np.asarray(data[f"{prefix}/scen_x"]),
            energies=np.asarray(data[f"{prefix}/scen_e"]),
        )
    recovery = None
    rm = meta["recovery"]
    if rm is not None:
        recovery = RecoveryInfo(
            failed_clients=tuple(rm["failed_clients"]),
            straggler_clients=tuple(rm["straggler_clients"]),
            completed=np.asarray(data[f"{prefix}/rec_completed"], np.int64),
            residual_T=int(rm["residual_T"]),
            shortfall=int(rm["shortfall"]),
            attempts=int(rm["attempts"]),
            fallback=bool(rm["fallback"]),
            assignments_original=np.asarray(data[f"{prefix}/rec_x0"], np.int64),
            recovery_assignments=np.asarray(data[f"{prefix}/rec_y"], np.int64),
            residual_problem=(
                _problem_from_arrays(lambda k: data[f"{prefix}/rec_q/{k}"])
                if rm["has_residual_problem"]
                else None
            ),
            problem=(
                _problem_from_arrays(lambda k: data[f"{prefix}/rec_p/{k}"])
                if rm["has_problem"]
                else None
            ),
            est_cost_original=float(rm["est_cost_original"]),
            est_overhead_J=float(rm["est_overhead_J"]),
        )
    return FLRoundResult(
        round_index=int(meta["round_index"]),
        assignments=np.asarray(data[f"{prefix}/assignments"], np.int64),
        mean_loss=float(meta["mean_loss"]),
        energy_joules=float(meta["energy_joules"]),
        estimated_joules=float(meta["estimated_joules"]),
        makespan_joules=float(meta["makespan_joules"]),
        scenarios=scenarios,
        recovery=recovery,
        # .get: pre-PR-10 checkpoints carry no adaptive telemetry
        adaptive=AdaptiveRoundStats.from_dict(meta.get("adaptive")),
    )


def save_campaign_checkpoint(
    directory: str,
    step: int,
    server: FederatedServer,
    rng: np.random.Generator,
    results,
    adaptive: Optional[AdaptiveCoordinator] = None,
) -> str:
    """Persists the round-``step`` restart state (params + estimator state
    + rng state + completed results + any adaptive-coordinator state) via
    :func:`repro.checkpoint.save_checkpoint`. ``step`` is the 0-indexed
    last COMPLETED round. Estimator persistence goes through the public
    :meth:`~repro.fl.energy.EnergyEstimator.state_dict` — table keys keep
    the pre-PR-10 ``est/{i:04d}`` npz layout, calibration state rides
    ``est/calib_*`` keys alongside."""
    rounds_tree, rounds_meta = {}, []
    for res in results:
        tree_r, meta_r = _round_to_tree_meta(res)
        rounds_tree[f"r{int(res.round_index):06d}"] = tree_r
        rounds_meta.append(meta_r)
    tree = {
        "params": server.params,
        "est": server.estimator.state_dict(),
        "rounds": rounds_tree,
    }
    extra = {
        "round": int(step),
        "rng_state": rng.bit_generator.state,
        "rounds": rounds_meta,
    }
    if adaptive is not None:
        st = adaptive.checkpoint_state()
        atree = {}
        for k, e in enumerate(st["entries"]):
            atree[f"spec{k:02d}"] = {
                "problem": _problem_to_tree(e["problem"]),
                "x": np.asarray(e["x"], dtype=np.int64),
            }
        if st["pending"] is not None:
            atree["pending_x"] = np.asarray(st["pending"]["x"], dtype=np.int64)
        if atree:
            tree["adapt"] = atree
        extra["adaptive"] = {
            "entries": [int(e["round"]) for e in st["entries"]],
            "pending": (
                None
                if st["pending"] is None
                else {k: v for k, v in st["pending"].items() if k != "x"}
            ),
            "detector": st["detector"],
            "counters": st["counters"],
            "per_round": {str(r): d for r, d in st["per_round"].items()},
            "wm_saved": st["wm_saved"],
            "wm_saved_pct": st["wm_saved_pct"],
        }
    return save_checkpoint(directory, int(step), tree, extra)


def load_campaign_checkpoint(
    directory: str,
    server: FederatedServer,
    rng: np.random.Generator,
    adaptive: Optional[AdaptiveCoordinator] = None,
):
    """Restores the latest campaign checkpoint IN PLACE (params, estimator
    state, rng state, adaptive-coordinator state when given one) and
    returns ``(last_completed_round, results)`` — or None when the
    directory holds no checkpoint. The continuation is bit-identical to the
    uninterrupted campaign (tests/test_faults.py, tests/test_adaptive.py).
    Pre-PR-10 checkpoints (bare ``est/{i:04d}`` tables, no adaptive block)
    still load: calibration state resets to fresh defaults."""
    import jax

    from ..checkpoint.checkpoint import _path_str

    step = latest_checkpoint(directory)
    if step is None:
        return None
    data, manifest = load_checkpoint_arrays(directory, int(step))
    extra = manifest["extra"]
    leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(server.params)
    new_leaves = []
    for path, leaf in leaves_with_paths:
        arr = data["params/" + _path_str(path)]
        like = np.asarray(leaf)
        new_leaves.append(arr.astype(like.dtype).reshape(like.shape))
    server.params = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(server.params), new_leaves
    )
    est_state = {
        key[len("est/"):]: arr
        for key, arr in data.items()
        if key.startswith("est/")
    }
    server.estimator.load_state_dict(est_state)
    rng.bit_generator.state = extra["rng_state"]
    results = [
        _round_from_arrays(data, f"rounds/r{int(m['round_index']):06d}", m)
        for m in extra["rounds"]
    ]
    am = extra.get("adaptive")
    if adaptive is not None and am is not None:
        entries = []
        for k, rnd in enumerate(am["entries"]):
            prefix = f"adapt/spec{k:02d}"
            prob = _problem_from_arrays(
                lambda key, _p=prefix: data[f"{_p}/problem/{key}"]
            )
            entries.append({
                "round": int(rnd),
                "problem": prob,
                "x": np.asarray(data[f"{prefix}/x"], dtype=np.int64),
            })
        pending = None
        if am["pending"] is not None:
            pending = dict(am["pending"])
            pending["x"] = np.asarray(data["adapt/pending_x"], dtype=np.int64)
        adaptive.load_checkpoint_state({
            "entries": entries,
            "pending": pending,
            "detector": am["detector"],
            "counters": am["counters"],
            "per_round": {int(r): d for r, d in am["per_round"].items()},
            "wm_saved": am["wm_saved"],
            "wm_saved_pct": am["wm_saved_pct"],
        })
    return int(extra["round"]), results


# ---------------------------------------------------------------------------
# campaign history + pipeline stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PipelineStats:
    """Where the campaign's time went, per executor mode.

    ``overlap_fraction`` = share of planning time hidden off the round hot
    path: 1 - blocked/busy (0.0 for serial by construction).
    """

    mode: str
    round_wall_s: List[float] = dataclasses.field(default_factory=list)
    planner_busy_s: float = 0.0
    planner_blocked_s: float = 0.0
    train_block_s: float = 0.0  # main-thread time blocked materializing losses
    tasks: List[dict] = dataclasses.field(default_factory=list)

    @property
    def overlap_fraction(self) -> float:
        if self.planner_busy_s <= 0.0:
            return 1.0 if self.mode == "pipelined" else 0.0
        frac = 1.0 - self.planner_blocked_s / self.planner_busy_s
        return float(min(1.0, max(0.0, frac)))

    def as_dict(self) -> dict:
        return {
            "mode": self.mode,
            "rounds": len(self.round_wall_s),
            "round_wall_s": list(self.round_wall_s),
            "round_wall_mean_s": float(np.mean(self.round_wall_s)) if self.round_wall_s else 0.0,
            "planner_busy_s": self.planner_busy_s,
            "planner_blocked_s": self.planner_blocked_s,
            "train_block_s": self.train_block_s,
            "overlap_fraction": self.overlap_fraction,
        }


@dataclasses.dataclass
class CampaignHistory:
    algorithm: str
    rounds: List[FLRoundResult]
    # sweep-engine counter deltas over the campaign (DESIGN.md §10):
    # hits/misses/compiles/evictions accrued by this campaign's DP solves.
    # Round shapes repeat, so a healthy campaign shows compiles <= 1 after
    # the first round warmed the bucket — see dp_compiles in summary().
    dp_cache_stats: Optional[dict] = None
    # executor timing (DESIGN.md §11): how much planning the pipeline hid.
    pipeline_stats: Optional[PipelineStats] = None
    # adaptive-layer rollup (DESIGN.md §18): drift rounds, speculation
    # hits/misses, early re-plans, barrier-wait savings. None unless the
    # campaign ran with an AdaptiveCoordinator.
    adaptive_stats: Optional[dict] = None

    @property
    def total_energy(self) -> float:
        return float(sum(r.energy_joules for r in self.rounds))

    @property
    def losses(self) -> np.ndarray:
        return np.array([r.mean_loss for r in self.rounds])

    def summary(self) -> dict:
        out = {
            "algorithm": self.algorithm,
            "rounds": len(self.rounds),
            "total_energy_J": self.total_energy,
            "final_loss": float(self.rounds[-1].mean_loss) if self.rounds else float("nan"),
            "mean_makespan_J": float(np.mean([r.makespan_joules for r in self.rounds])) if self.rounds else 0.0,
        }
        if self.dp_cache_stats is not None:
            out["dp_compiles"] = self.dp_cache_stats["compiles"]
            out["dp_cache_hits"] = self.dp_cache_stats["hits"]
        if self.pipeline_stats is not None:
            out["pipeline_mode"] = self.pipeline_stats.mode
            out["planner_overlap_fraction"] = self.pipeline_stats.overlap_fraction
        # recovery telemetry (DESIGN.md §17) — keyed only when some round
        # actually recovered, so zero-fault summaries are unchanged
        recovered = [r.recovery for r in self.rounds if r.recovery is not None]
        if recovered:
            out["recovered_rounds"] = len(recovered)
            out["recovery_fallbacks"] = sum(1 for ri in recovered if ri.fallback)
            out["recovery_overhead_J"] = float(
                sum(ri.est_overhead_J for ri in recovered)
            )
            out["recovery_shortfall"] = int(sum(ri.shortfall for ri in recovered))
        # adaptive telemetry (DESIGN.md §18) — keyed only for adaptive
        # campaigns, so default-policy summaries are unchanged
        if self.adaptive_stats is not None:
            a = self.adaptive_stats
            out["drift_rounds"] = a["drift_rounds"]
            out["speculation_hits"] = a["speculation_hits"]
            out["speculation_misses"] = a["speculation_misses"]
            out["speculation_batches"] = a["speculation_batches"]
            out["speculation_hit_rate"] = a["speculation_hit_rate"]
            out["replan_rate"] = (
                a["speculation_misses"] / len(self.rounds) if self.rounds else 0.0
            )
            out["early_replans"] = a["early_replans"]
            out["barrier_wait_saved"] = a["barrier_wait_saved"]
            out["barrier_wait_saved_pct_mean"] = a["barrier_wait_saved_pct_mean"]
        return out


# ---------------------------------------------------------------------------
# the (single) campaign loop
# ---------------------------------------------------------------------------


class CampaignRunner:
    """Multi-round FedAvg campaign driver over the server's round stages.

    ``mode`` picks the plan executor: "serial" (inline planning — the
    reference semantics) or "pipelined" (background planner thread). A fresh
    executor is created per :meth:`run` and always shut down — a planner
    exception drains the thread before re-raising in the caller.
    """

    def __init__(self, server: FederatedServer, mode: str = "serial"):
        if mode not in _EXECUTORS:
            raise ValueError(f"unknown pipeline mode {mode!r}; options: {sorted(_EXECUTORS)}")
        self.server = server
        self.mode = mode

    def run(
        self,
        examples_per_client: list,
        num_rounds: int,
        round_T: int,
        batch_size: int,
        rng: np.random.Generator,
        max_steps: Optional[int] = None,
        on_round: Optional[Callable[[FLRoundResult], None]] = None,
        faults: Optional[object] = None,
        drift: Optional[object] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 1,
    ) -> CampaignHistory:
        """Runs the campaign. Beyond the classic knobs (DESIGN.md §11):

        ``faults``: a :class:`~repro.fl.faults.FaultPlan` or
        :class:`~repro.fl.faults.FaultInjector` — client crashes/stragglers
        fire after each round's plan lands and are recovered via
        :meth:`~repro.fl.server.FederatedServer.recover_round` on the MAIN
        thread (recovery mutates nothing, but running it in round order
        keeps the serial/pipelined bit-identity contract auditable);
        transient planner/scenario failures retry inline; overload bursts
        submit extra one-off requests to ``server.service``. ``faults=None``
        leaves every code path bit-identical to the pre-fault-layer loop.

        ``drift``: a :class:`~repro.fl.adaptive.DriftPlan` or
        :class:`~repro.fl.adaptive.DriftInjector` (DESIGN.md §18) — the
        fleet's TRUE energy tables move per the seeded plan, applied on the
        main thread at the top of each round, so serial and pipelined
        campaigns drift identically. The adaptive planning features
        themselves are armed on the server's policy
        (``lookahead`` / ``drift_tolerance`` / ``reliability`` /
        ``watermark_quantile``); with the policy defaults this loop is
        byte-identical to the pre-adaptive one.

        ``checkpoint_dir``: round-granular checkpoint/resume (DESIGN.md
        §17) — the restart state is saved every ``checkpoint_every``
        completed rounds (and on the final round), and a non-empty directory
        resumes from its latest checkpoint, reproducing the uninterrupted
        campaign's params and history exactly (adaptive speculation state
        included).
        """
        server = self.server
        server.round_T = round_T
        if max_steps is None:
            max_steps = max(d.max_batches for d in server.estimator.fleet)
        injector = FaultInjector(faults) if isinstance(faults, FaultPlan) else faults
        drifter = DriftInjector(drift) if isinstance(drift, DriftPlan) else drift
        adaptive = (
            AdaptiveCoordinator(server)
            if AdaptiveCoordinator.enabled(server.policy)
            else None
        )
        stats = PipelineStats(mode=self.mode)
        executor = _EXECUTORS[self.mode]()
        futures: List[PlanFuture] = []
        burst_futures: list = []

        def submit(label, fn, *args):
            f = executor.submit(label, fn, *args)
            futures.append(f)
            return f

        def materialize_plan(plan_f, r):
            # transient planner failures (an injected engine fault caught
            # mid-solve) re-plan inline from the same estimator snapshot —
            # nothing mutated it since submit, so the retry is bit-identical
            try:
                return plan_f.result()
            except Exception as e:
                if injector is None or not is_transient(e):
                    raise
                return self._replan(r, round_T)

        def materialize_scenarios(scen_f, problems, labels):
            try:
                return scen_f.result()
            except Exception as e:
                if injector is None or not is_transient(e):
                    raise
            try:
                return server.solve_scenarios(problems, labels)
            except Exception as e:
                if not is_transient(e):
                    raise
                return None  # persistently failing what-ifs degrade to None

        start_round = 0
        results: List[FLRoundResult] = []
        if checkpoint_dir is not None:
            restored = load_campaign_checkpoint(
                checkpoint_dir, server, rng, adaptive=adaptive
            )
            if restored is not None:
                start_round, results = restored[0] + 1, list(restored[1])
        before = server.engine.cache_stats()
        try:
            if start_round < num_rounds:
                # The first plan has nothing to hide behind — submitted
                # eagerly so the pipelined path still has one entry point.
                # The coordinator's first_plan replays a restored pending
                # decision (bit-identical resume) or opens the speculation
                # window; without a coordinator this is the classic solve.
                if adaptive is not None:
                    plan_f = adaptive.first_plan(start_round, round_T, submit)
                else:
                    plan_f = submit(
                        f"plan[{start_round}]",
                        server.plan_round,
                        start_round,
                        round_T,
                        server.build_problem(round_T),
                    )
            for r in range(start_round, num_rounds):
                t_round = time.perf_counter()
                if drifter is not None:
                    # the world moves first (main thread, round order):
                    # round r's true charging and measurements see the
                    # drifted tables, the planner only ever sees estimates
                    drifter.apply(r, server.estimator.fleet)
                if injector is not None and server.service is not None:
                    for b in range(injector.burst(r)):
                        # chaos traffic: extra one-off requests against the
                        # shared service; overload shedding is the expected
                        # outcome, not a campaign failure
                        try:
                            burst_futures.append(
                                server.service.submit(
                                    injector.burst_problem(r, b), timeout=0.1
                                )
                            )
                        except Exception:
                            pass
                batches = lm_round_batches(examples_per_client, max_steps, batch_size, r)
                plan = materialize_plan(plan_f, r)
                round_faults = None
                if injector is not None:
                    round_faults = injector.round_faults(r, plan.assignments)
                    if round_faults is not None:
                        if adaptive is not None:
                            # watermark path: early-detectable faults
                            # re-solve before the barrier (DESIGN.md §18)
                            plan = adaptive.handle_faults(plan, round_faults)
                        else:
                            plan = server.recover_round(plan, round_faults)
                mean_loss = server.train_round(plan, batches)  # async dispatch
                # CPU-side accounting runs while the device trains; it is
                # the only stage touching rng/estimator state (see server).
                acct = server.account_round(plan, rng)
                if adaptive is not None:
                    # fold round telemetry into detector + reliability
                    # (main thread, round order — same determinism contract
                    # as account_round)
                    adaptive.after_account(r, plan, round_faults)
                else:
                    server.estimator.drain_innovations()  # unused: discard
                # Snapshot next-round planning NOW (post-accounting), hand
                # the solves to the executor, materialize only when needed.
                scen_problems, scen_labels = server.build_scenarios(plan.T)
                scen_f = submit(
                    f"scenarios[{r}]", server.solve_scenarios, scen_problems, scen_labels
                )
                if r + 1 < num_rounds:
                    if adaptive is not None:
                        plan_f = adaptive.next_plan(r + 1, round_T, submit)
                    else:
                        plan_f = submit(
                            f"plan[{r + 1}]",
                            server.plan_round,
                            r + 1,
                            round_T,
                            server.build_problem(round_T),
                        )
                t0 = time.perf_counter()
                loss = float(mean_loss)  # blocks until clients finish
                stats.train_block_s += time.perf_counter() - t0
                res = FLRoundResult(
                    round_index=r,
                    assignments=plan.assignments,
                    mean_loss=loss,
                    energy_joules=acct["energy_joules"],
                    estimated_joules=plan.est_cost,
                    makespan_joules=acct["makespan_joules"],
                    scenarios=materialize_scenarios(scen_f, scen_problems, scen_labels),
                    recovery=plan.recovery,
                    adaptive=(
                        adaptive.round_stats(r) if adaptive is not None else None
                    ),
                )
                results.append(res)
                if checkpoint_dir is not None and (
                    (r + 1) % max(1, int(checkpoint_every)) == 0 or r == num_rounds - 1
                ):
                    save_campaign_checkpoint(
                        checkpoint_dir, r, server, rng, results, adaptive=adaptive
                    )
                stats.round_wall_s.append(time.perf_counter() - t_round)
                if on_round:
                    on_round(res)
            for f in burst_futures:
                # drain injected chaos traffic so close()/stats see a clean
                # service; burst failures are chaos noise, not campaign state
                try:
                    f.result(timeout=60)
                except Exception:
                    pass
        finally:
            executor.shutdown()
        after = server.engine.cache_stats()

        stats.planner_busy_s = float(sum(f.busy_s for f in futures))
        stats.planner_blocked_s = float(sum(f.blocked_s for f in futures))
        stats.tasks = [
            {"label": f.label, "busy_s": f.busy_s, "blocked_s": f.blocked_s}
            for f in futures
        ]
        delta = {k: after[k] - before[k] for k in ("hits", "misses", "compiles", "evictions")}
        delta["entries"] = after["entries"]
        return CampaignHistory(
            algorithm=server.algorithm,
            rounds=results,
            dp_cache_stats=delta,
            pipeline_stats=stats,
            adaptive_stats=adaptive.summary_stats() if adaptive is not None else None,
        )

    def _replan(self, r: int, T: int, max_attempts: int = 3) -> RoundPlan:
        """Inline re-plan after a transient planner failure: bounded retries
        of the normal planning stage, then a guaranteed-feasible greedy plan
        (lower limits honored via the residual construction) when the solver
        stays down — the campaign always gets a valid round plan."""
        server = self.server
        for _ in range(max_attempts):
            try:
                return server.plan_round(r, T, server.build_problem(T))
            except Exception as e:
                if not is_transient(e):
                    raise
        problem = server.build_problem(T)
        res = residual_problem(problem, problem.lower, ())
        x = np.asarray(problem.lower, dtype=np.int64) + proportional_greedy(res)
        return RoundPlan(
            round_index=int(r),
            T=int(T),
            assignments=x,
            est_cost=float(total_cost(problem, x)),
            problem=problem,
        )


class AsyncCampaignRunner(CampaignRunner):
    """Campaign driver with the background planner thread pre-selected:
    round *r+1*'s schedule and scenario solves overlap round *r*'s client
    training, with results bit-identical to :class:`CampaignRunner` in
    serial mode."""

    def __init__(self, server: FederatedServer):
        super().__init__(server, mode="pipelined")
