"""Client-side local training as a masked, fixed-shape ``lax.scan``.

Heterogeneous per-client step counts (the scheduler's ``x_i``) must not
change program shapes, so every client scans over ``max_steps`` batches and
steps beyond ``x_i`` are no-ops (params carried through unchanged). This
keeps a whole FL round one SPMD program — clients are a ``vmap`` axis.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..optim.optimizers import Optimizer, apply_updates

__all__ = ["local_train", "make_client_fn"]


def local_train(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    optimizer: Optimizer,
    params: Any,
    batches: Any,
    num_steps: jnp.ndarray,
):
    """Runs ``num_steps`` (<= max_steps) local updates.

    Args:
      loss_fn: ``loss_fn(params, batch) -> scalar``.
      optimizer: client-local optimizer (state re-initialized every round, as
        FedAvg clients are stateless between rounds).
      params: starting (global) parameters.
      batches: pytree with leading ``(max_steps, ...)`` axis.
      num_steps: scalar int32 — the scheduler's ``x_i`` for this client.

    Returns:
      (final_params, mean_loss) — mean over the *executed* steps only
      (0.0 if num_steps == 0).
    """
    opt_state = optimizer.init(params)
    max_steps = jax.tree.leaves(batches)[0].shape[0]

    def step(carry, inp):
        p, s_opt, loss_acc = carry
        batch, s = inp
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        updates, new_opt = optimizer.update(grads, s_opt, p)
        new_p = apply_updates(p, updates)
        use = s < num_steps
        p = jax.tree.map(lambda new, old: jnp.where(use, new, old), new_p, p)
        s_opt = jax.tree.map(lambda new, old: jnp.where(use, new, old), new_opt, s_opt)
        loss_acc = loss_acc + jnp.where(use, loss, 0.0)
        return (p, s_opt, loss_acc), loss

    xs = (batches, jnp.arange(max_steps, dtype=jnp.int32))
    (final_params, _, loss_sum), _ = jax.lax.scan(step, (params, opt_state, jnp.zeros(())), xs)
    denom = jnp.maximum(num_steps.astype(jnp.float32), 1.0)
    return final_params, loss_sum / denom


def make_client_fn(loss_fn: Callable, optimizer: Optimizer):
    """vmappable closure: (params, batches, num_steps) -> (params, loss)."""

    def client_fn(params, batches, num_steps):
        return local_train(loss_fn, optimizer, params, batches, num_steps)

    return client_fn
