"""Device energy modeling, measurement simulation, and online estimation.

The scheduler needs per-device cost tables ``C_i(j)`` = Joules to train with
``j`` mini-batches. On a real deployment these come from profilers (paper
refs: I-Prof [35], Flower [36], PMC models [34]). Here:

  * :class:`DeviceProfile` — ground-truth energy behaviour of a simulated
    device (hidden from the scheduler), with measurement noise and an
    externally-driven ``drift_scale`` (thermal throttling, battery state —
    see :class:`repro.fl.adaptive.DriftInjector`).
  * :class:`EnergyEstimator` — what the server knows: per-device tabulated
    estimates refreshed each round from noisy measurements via a
    huber-weighted, clipped EMA (DESIGN.md §18). Beyond the raw tables the
    estimator is a full online calibrator: it tracks per-(client, workload)
    innovation statistics with uncertainty bands, a per-client multiplicative
    trend used to PREDICT future tables (speculative lookahead), and a
    reliability score fed by observed crash/straggle history that can
    down-weight a chronically flaky client's effective capacity in the
    planning :class:`~repro.core.problem.Problem` — never in the true
    simulator tables.
  * :func:`flops_scaled_tables` — adapts a reference cost table to a model's
    per-batch FLOPs (bigger model => proportionally more Joules per batch).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence

import numpy as np

from ..core.costs import DEVICE_CLASSES, _table_for_class
from ..core.problem import Problem

__all__ = ["DeviceProfile", "EnergyEstimator", "make_fleet", "flops_scaled_tables"]

_TABLE_KEY = re.compile(r"^\d{4,}$")


@dataclasses.dataclass
class DeviceProfile:
    """Ground truth for one simulated device."""

    name: str
    device_class: str
    max_batches: int  # upper limit U_i (local data / contract)
    min_batches: int = 0  # lower limit L_i (participation floor)
    noise: float = 0.03  # relative measurement noise
    flops_scale: float = 1.0
    # multiplicative drift on the TRUE energy (thermal throttling, battery
    # sag, contention). Overwritten per round by a DriftInjector; 1.0 = the
    # stationary world every pre-drift campaign ran in.
    drift_scale: float = 1.0

    def true_table(self) -> np.ndarray:
        tbl = _table_for_class(self.device_class, self.max_batches, self.flops_scale)
        if self.drift_scale != 1.0:
            tbl = tbl * self.drift_scale
        return tbl

    def measure(self, j: int, rng: np.random.Generator) -> float:
        """Simulates an energy measurement for training with j batches."""
        true = float(self.true_table()[j])
        return true * float(1.0 + self.noise * rng.standard_normal())


def make_fleet(
    rng: np.random.Generator,
    n_devices: int,
    classes: Optional[Sequence[str]] = None,
    max_batches: int = 64,
    min_batches: int = 0,
) -> list:
    classes = list(classes or DEVICE_CLASSES)
    out = []
    for i in range(n_devices):
        cls = classes[int(rng.integers(0, len(classes)))]
        ub = int(rng.integers(max(min_batches + 1, max_batches // 2), max_batches + 1))
        out.append(
            DeviceProfile(
                name=f"dev{i:03d}_{cls}",
                device_class=cls,
                max_batches=ub,
                min_batches=min_batches,
            )
        )
    return out


def flops_scaled_tables(table: np.ndarray, model_flops_per_batch: float, ref_flops_per_batch: float) -> np.ndarray:
    return table * (model_flops_per_batch / ref_flops_per_batch)


class EnergyEstimator:
    """Server-side estimate of every device's cost table, plus the online
    calibration state the adaptive layer (DESIGN.md §18) plans from.

    Starts from a coarse monotone prior (:meth:`calibrate`), then blends
    full-table measurements as rounds progress. The estimate is what the
    scheduler consumes; the *true* table is what the simulator charges — the
    gap is reported by ``fl/rounds.py``.

    Robustness (vs the pre-PR-10 plain EMA): each observation's relative
    innovation ``z = (measured - C_i(j)) / C_i(j)`` is huber-weighted
    (full EMA step inside ``|z| <= huber_delta``, attenuated outside), the
    whole-table rescale factor is clipped to ``[1/clip, clip]``, and
    non-finite or non-positive measurements are dropped outright — one
    adversarial spike can no longer corrupt every entry of a table.

    Calibration state (all pure functions of the observation sequence, so
    serial and pipelined campaigns agree bit-for-bit):

      * per-client EWMA innovation mean/variance (uncertainty bands), plus
        per-(client, workload) point statistics;
      * a per-client multiplicative ``trend`` — the EWMA of observed rescale
        factors — used by :meth:`predict_problem` to extrapolate tables
        ``s`` rounds ahead for speculative lookahead;
      * a reliability score in [0, 1] fed by :meth:`record_round_outcome`
        (crash/straggle history), consumed by :meth:`reliability_weights`
        to down-weight a flaky client's effective ``upper`` in the planning
        problem only.
    """

    def __init__(
        self,
        fleet: Sequence[DeviceProfile],
        ema: float = 0.5,
        huber_delta: float = 0.25,
        clip: float = 2.0,
        stats_decay: float = 0.3,
    ):
        self.fleet = list(fleet)
        self.ema = ema
        self.huber_delta = float(huber_delta)
        self.clip = float(clip)
        self.stats_decay = float(stats_decay)
        self._tables = [None] * len(self.fleet)
        self._reset_calibration_state()

    def _reset_calibration_state(self) -> None:
        n = len(self.fleet)
        self._innov_mean = np.zeros(n, dtype=np.float64)
        self._innov_var = np.zeros(n, dtype=np.float64)
        self._trend = np.ones(n, dtype=np.float64)
        self._reliability = np.ones(n, dtype=np.float64)
        self._obs_count = np.zeros(n, dtype=np.int64)
        self._fault_count = np.zeros(n, dtype=np.int64)
        self._dropped = 0
        self._point_stats: dict = {}  # (client, j) -> [ewma_z, ewma_z2, count]
        self._round_innovations: list = []  # (client, j, z) since last drain

    def calibrate(self, rng: np.random.Generator, probe_points: int = 4) -> None:
        """Initial profiling pass: probe a few j values per device and fit a
        monotone (isotonic-ish, via cumulative positive increments) table."""
        for i, dev in enumerate(self.fleet):
            u = dev.max_batches
            js = np.unique(np.linspace(1, u, min(probe_points, u)).astype(int))
            meas = np.array([dev.measure(int(j), rng) for j in js])
            full = np.interp(np.arange(u + 1), np.concatenate([[0], js]), np.concatenate([[0.0], meas]))
            inc = np.maximum(np.diff(full), 0.0)  # enforce monotone energy
            self._tables[i] = np.concatenate([[0.0], np.cumsum(inc)])

    def observe(self, i: int, j: int, measured_joules: float) -> None:
        """Robust EMA update of device i's table around the observed point:
        rescales the whole table so that ``C_i(j)`` matches the blended
        observation. In-band observations (``|z| <= huber_delta``) take the
        exact pre-PR-10 EMA step; outliers are huber-attenuated, the rescale
        factor is clipped, and non-finite measurements are dropped."""
        tbl = self._tables[i]
        if tbl is None or j <= 0 or j >= len(tbl) or tbl[j] <= 0:
            return
        m = float(measured_joules)
        if not np.isfinite(m) or m <= 0.0:
            self._dropped += 1
            return
        z = (m - float(tbl[j])) / float(tbl[j])
        az = abs(z)
        if az <= self.huber_delta:
            # bit-identical to the legacy plain-EMA blend for in-band points
            blended = (1 - self.ema) * tbl[j] + self.ema * m
        else:
            blended = tbl[j] + self.ema * (self.huber_delta / az) * (m - tbl[j])
        factor = float(blended / tbl[j])
        factor = min(max(factor, 1.0 / self.clip), self.clip)
        self._tables[i] = tbl * factor
        d = self.stats_decay
        self._innov_mean[i] = (1 - d) * self._innov_mean[i] + d * z
        self._innov_var[i] = (1 - d) * self._innov_var[i] + d * z * z
        # trend: EWMA of rescale factors. Under steady multiplicative drift
        # the estimate must grow at the drift rate to keep tracking, so the
        # factor EWMA converges to that rate — the s-step predictor.
        self._trend[i] = min(max((1 - d) * self._trend[i] + d * factor, 0.5), 2.0)
        self._obs_count[i] += 1
        key = (int(i), int(j))
        pm, pv, pc = self._point_stats.get(key, (0.0, 0.0, 0))
        self._point_stats[key] = [(1 - d) * pm + d * z, (1 - d) * pv + d * z * z, pc + 1]
        self._round_innovations.append((int(i), int(j), float(z)))

    # ---- calibration telemetry ----------------------------------------

    def drain_innovations(self) -> list:
        """Returns (and clears) the ``(client, j, z)`` innovations recorded
        since the last drain — the drift detector's per-round signal. Called
        on the main thread in round order, so the detector's state is a pure
        function of the observation sequence."""
        out, self._round_innovations = self._round_innovations, []
        return out

    def uncertainty(self, i: int) -> tuple:
        """Per-client innovation band: (EWMA mean, EWMA std) of the relative
        innovation ``z``. A well-calibrated client sits near (0, noise)."""
        var = max(float(self._innov_var[i]) - float(self._innov_mean[i]) ** 2, 0.0)
        return float(self._innov_mean[i]), float(np.sqrt(var))

    def point_uncertainty(self, i: int, j: int) -> tuple:
        """(EWMA mean, EWMA std, count) of the innovation at one (client,
        workload) point — the finest-grained calibration band tracked."""
        pm, pv, pc = self._point_stats.get((int(i), int(j)), (0.0, 0.0, 0))
        return float(pm), float(np.sqrt(max(pv - pm * pm, 0.0))), int(pc)

    def record_round_outcome(self, participated, faulty=(), decay: float = 0.25) -> None:
        """Feeds one round of crash/straggle telemetry into the per-client
        reliability scores: participants that completed pull toward 1,
        faulty ones toward 0 (EWMA with ``decay``)."""
        faulty = set(int(c) for c in faulty)
        for i in set(int(c) for c in participated) | faulty:
            ok = 0.0 if i in faulty else 1.0
            self._reliability[i] = (1 - decay) * self._reliability[i] + decay * ok
            if i in faulty:
                self._fault_count[i] += 1

    def reliability_scores(self) -> np.ndarray:
        return self._reliability.copy()

    def reliability_weights(self, threshold: float = 0.9, floor: float = 0.25) -> np.ndarray:
        """Effective-capacity multipliers: clients at or above ``threshold``
        reliability keep full capacity; flakier ones are down-weighted
        proportionally, never below ``floor`` (a flaky client still gets a
        chance to redeem itself — and to be observed)."""
        r = self._reliability
        return np.where(r >= threshold, 1.0, np.maximum(r / threshold, floor))

    # ---- planning snapshots -------------------------------------------

    def _bounds(self, reliability=None):
        lowers = np.array([d.min_batches for d in self.fleet])
        uppers = np.array([d.max_batches for d in self.fleet])
        if reliability is not None:
            w = np.clip(np.asarray(reliability, dtype=np.float64), 0.0, 1.0)
            uppers = np.maximum(lowers, np.floor(uppers * w).astype(np.int64))
        return lowers, uppers

    def problem(self, T: int, reliability=None) -> Problem:
        """The planning instance under the CURRENT estimates. With
        ``reliability`` (per-client multipliers in (0, 1], e.g. from
        :meth:`reliability_weights`), flaky clients' effective ``upper`` is
        down-weighted — in this planning snapshot ONLY; the true simulator
        tables are untouched — and ``T`` is clipped to the reduced capacity."""
        lowers, uppers = self._bounds(reliability)
        if reliability is not None:
            T = int(np.clip(int(T), int(lowers.sum()), int(uppers.sum())))
            tables = tuple(
                np.asarray(t, dtype=np.float64)[: int(u) + 1]
                for t, u in zip(self._tables, uppers)
            )
        else:
            tables = tuple(np.asarray(t, dtype=np.float64) for t in self._tables)
        return Problem(T=T, lower=lowers, upper=uppers, cost_tables=tables)

    def predict_problem(self, T: int, steps: int, reliability=None) -> Problem:
        """The PREDICTED instance ``steps`` rounds ahead: each client's table
        scaled by ``trend_i ** steps`` (steps=0 is exactly :meth:`problem`).
        Pure function of the calibration snapshot — the speculative lookahead
        batch is built from these."""
        if steps <= 0:
            return self.problem(T, reliability=reliability)
        base = self.problem(T, reliability=reliability)
        growth = self._trend ** int(steps)
        tables = tuple(tbl * g for tbl, g in zip(base.cost_tables, growth))
        return Problem(T=base.T, lower=base.lower, upper=base.upper, cost_tables=tables)

    def true_problem(self, T: int) -> Problem:
        lowers = np.array([d.min_batches for d in self.fleet])
        uppers = np.array([d.max_batches for d in self.fleet])
        tables = tuple(d.true_table() for d in self.fleet)
        return Problem(T=T, lower=lowers, upper=uppers, cost_tables=tables)

    # ---- persistence (public API; DESIGN.md §18) ----------------------

    def state_dict(self) -> dict:
        """The estimator's complete persistent state as flat ``{key: array}``
        — table keys are ``f"{i:04d}"`` (bit-compatible with the pre-PR-10
        checkpoint npz layout), calibration state rides ``calib_*`` keys."""
        out = {
            f"{i:04d}": np.asarray(t)
            for i, t in enumerate(self._tables)
            if t is not None
        }
        out["calib_innov_mean"] = self._innov_mean.copy()
        out["calib_innov_var"] = self._innov_var.copy()
        out["calib_trend"] = self._trend.copy()
        out["calib_reliability"] = self._reliability.copy()
        out["calib_obs_count"] = self._obs_count.copy()
        out["calib_fault_count"] = self._fault_count.copy()
        out["calib_dropped"] = np.int64(self._dropped)
        if self._point_stats:
            keys = sorted(self._point_stats)
            out["calib_point_keys"] = np.array(keys, dtype=np.int64)
            out["calib_point_vals"] = np.array(
                [self._point_stats[k] for k in keys], dtype=np.float64
            )
        return out

    def load_state_dict(self, state: dict) -> None:
        """Restores :meth:`state_dict` output IN PLACE. Tolerates pre-PR-10
        checkpoints that carry only the numeric table keys: calibration
        state then resets to its fresh defaults."""
        self._reset_calibration_state()
        for key, arr in state.items():
            if _TABLE_KEY.match(key):
                i = int(key)
                if i < len(self._tables):
                    self._tables[i] = np.asarray(arr, dtype=np.float64)
        for name, attr in (
            ("calib_innov_mean", "_innov_mean"),
            ("calib_innov_var", "_innov_var"),
            ("calib_trend", "_trend"),
            ("calib_reliability", "_reliability"),
        ):
            if name in state:
                setattr(self, attr, np.asarray(state[name], dtype=np.float64).copy())
        for name, attr in (
            ("calib_obs_count", "_obs_count"),
            ("calib_fault_count", "_fault_count"),
        ):
            if name in state:
                setattr(self, attr, np.asarray(state[name], dtype=np.int64).copy())
        if "calib_dropped" in state:
            self._dropped = int(state["calib_dropped"])
        if "calib_point_keys" in state:
            keys = np.asarray(state["calib_point_keys"], dtype=np.int64)
            vals = np.asarray(state["calib_point_vals"], dtype=np.float64)
            self._point_stats = {
                (int(k[0]), int(k[1])): [float(v[0]), float(v[1]), int(v[2])]
                for k, v in zip(keys, vals)
            }
