"""Device energy modeling, measurement simulation, and online estimation.

The scheduler needs per-device cost tables ``C_i(j)`` = Joules to train with
``j`` mini-batches. On a real deployment these come from profilers (paper
refs: I-Prof [35], Flower [36], PMC models [34]). Here:

  * :class:`DeviceProfile` — ground-truth energy behaviour of a simulated
    device (hidden from the scheduler), with measurement noise.
  * :class:`EnergyEstimator` — what the server knows: per-device tabulated
    estimates refreshed each round from noisy measurements via an EMA
    (dynamic re-estimation is listed as future work in the paper §6; we flag
    it beyond-paper in DESIGN.md §8).
  * :func:`flops_scaled_tables` — adapts a reference cost table to a model's
    per-batch FLOPs (bigger model => proportionally more Joules per batch).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.costs import DEVICE_CLASSES, _table_for_class
from ..core.problem import Problem

__all__ = ["DeviceProfile", "EnergyEstimator", "make_fleet", "flops_scaled_tables"]


@dataclasses.dataclass
class DeviceProfile:
    """Ground truth for one simulated device."""

    name: str
    device_class: str
    max_batches: int  # upper limit U_i (local data / contract)
    min_batches: int = 0  # lower limit L_i (participation floor)
    noise: float = 0.03  # relative measurement noise
    flops_scale: float = 1.0

    def true_table(self) -> np.ndarray:
        return _table_for_class(self.device_class, self.max_batches, self.flops_scale)

    def measure(self, j: int, rng: np.random.Generator) -> float:
        """Simulates an energy measurement for training with j batches."""
        true = float(self.true_table()[j])
        return true * float(1.0 + self.noise * rng.standard_normal())


def make_fleet(
    rng: np.random.Generator,
    n_devices: int,
    classes: Optional[Sequence[str]] = None,
    max_batches: int = 64,
    min_batches: int = 0,
) -> list:
    classes = list(classes or DEVICE_CLASSES)
    out = []
    for i in range(n_devices):
        cls = classes[int(rng.integers(0, len(classes)))]
        ub = int(rng.integers(max(min_batches + 1, max_batches // 2), max_batches + 1))
        out.append(
            DeviceProfile(
                name=f"dev{i:03d}_{cls}",
                device_class=cls,
                max_batches=ub,
                min_batches=min_batches,
            )
        )
    return out


def flops_scaled_tables(table: np.ndarray, model_flops_per_batch: float, ref_flops_per_batch: float) -> np.ndarray:
    return table * (model_flops_per_batch / ref_flops_per_batch)


class EnergyEstimator:
    """Server-side estimate of every device's cost table.

    Starts from a coarse linear prior (first measured marginal extrapolated),
    then blends full-table measurements with an EMA as rounds progress. The
    estimate is what the scheduler consumes; the *true* table is what the
    simulator charges — the gap is reported by ``fl/rounds.py``.
    """

    def __init__(self, fleet: Sequence[DeviceProfile], ema: float = 0.5):
        self.fleet = list(fleet)
        self.ema = ema
        self._tables = [None] * len(self.fleet)

    def calibrate(self, rng: np.random.Generator, probe_points: int = 4) -> None:
        """Initial profiling pass: probe a few j values per device and fit a
        monotone (isotonic-ish, via cumulative positive increments) table."""
        for i, dev in enumerate(self.fleet):
            u = dev.max_batches
            js = np.unique(np.linspace(1, u, min(probe_points, u)).astype(int))
            meas = np.array([dev.measure(int(j), rng) for j in js])
            full = np.interp(np.arange(u + 1), np.concatenate([[0], js]), np.concatenate([[0.0], meas]))
            inc = np.maximum(np.diff(full), 0.0)  # enforce monotone energy
            self._tables[i] = np.concatenate([[0.0], np.cumsum(inc)])

    def observe(self, i: int, j: int, measured_joules: float) -> None:
        """EMA update of device i's table around the observed point: rescales
        the whole table so that C_i(j) matches the blended observation."""
        tbl = self._tables[i]
        if tbl is None or j <= 0 or tbl[j] <= 0:
            return
        blended = (1 - self.ema) * tbl[j] + self.ema * measured_joules
        self._tables[i] = tbl * (blended / tbl[j])

    def problem(self, T: int) -> Problem:
        lowers = np.array([d.min_batches for d in self.fleet])
        uppers = np.array([d.max_batches for d in self.fleet])
        tables = tuple(np.asarray(t, dtype=np.float64) for t in self._tables)
        return Problem(T=T, lower=lowers, upper=uppers, cost_tables=tables)

    def true_problem(self, T: int) -> Problem:
        lowers = np.array([d.min_batches for d in self.fleet])
        uppers = np.array([d.max_batches for d in self.fleet])
        tables = tuple(d.true_table() for d in self.fleet)
        return Problem(T=T, lower=lowers, upper=uppers, cost_tables=tables)
