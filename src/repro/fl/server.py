"""FedAvg server with energy-minimal workload scheduling.

Per round (McMahan et al. [1] + this paper's contribution):
  1. The server asks the :class:`~repro.fl.energy.EnergyEstimator` for the
     fleet's cost tables and solves the Minimal Cost FL Schedule problem for
     the round's workload ``T`` (total mini-batches) — ``x_i`` per client.
  2. All clients execute one jitted SPMD program: ``vmap`` over clients of a
     masked local-training scan (``fl/client.py``).
  3. Aggregation: data-weighted parameter average (weights ``x_i / T``);
     clients with ``x_i = 0`` contribute nothing.
  4. The simulator charges each device its TRUE energy for ``x_i`` batches
     (with measurement noise fed back to the estimator).

A round is decomposed into explicit stages (DESIGN.md §11) so serial and
pipelined campaign executors share one code path:

  * :meth:`FederatedServer.build_problem` / :meth:`~FederatedServer.plan_round`
    — snapshot the estimator into a :class:`~repro.core.problem.Problem` and
    solve the schedule (a :class:`RoundPlan`).
  * :meth:`FederatedServer.train_round` — dispatch the jitted SPMD round
    program; returns the UN-materialized device loss (JAX async dispatch),
    so the caller decides when to block.
  * :meth:`FederatedServer.account_round` — pure-CPU energy accounting +
    estimator feedback (the only stage that mutates estimator state / rng).
  * :meth:`FederatedServer.build_scenarios` /
    :meth:`~FederatedServer.solve_scenarios` — what-if snapshot (cheap, must
    run after accounting) split from the batched DP solve (expensive, safe
    to run on a background planner thread).

:meth:`FederatedServer.run_round` composes the stages serially and is the
reference semantics the async pipeline must reproduce bit-identically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core._deprecation import warn_deprecated
from ..core.fleet import PlanPolicy
from ..core.pareto import deadline_grid
from ..core.problem import Problem, total_cost
from ..core.resilience import is_transient
from ..core.solver import Solver
from ..core.sweep import default_engine
from ..optim.optimizers import Optimizer
from .client import make_client_fn
from .energy import EnergyEstimator
from .faults import RoundFaults, proportional_greedy, residual_problem

__all__ = [
    "FLRoundResult",
    "PlanPolicy",
    "RecoveryInfo",
    "RoundPlan",
    "ScenarioReport",
    "FederatedServer",
    "apply_dropout",
]

_UNSET = object()  # sentinel: distinguishes "legacy kwarg passed" from default


@dataclasses.dataclass
class RecoveryInfo:
    """Provenance of a mid-round recovery (DESIGN.md §17): what failed, what
    each client had banked when it did, the exact residual instance the
    survivors were re-planned over, and what the detour cost on the
    planning-time tables. Carried on the recovered :class:`RoundPlan` and
    the round's :class:`FLRoundResult`, so chaos tests (and checkpoints) can
    replay the recovery solve independently."""

    failed_clients: tuple  # crashed mid-round; take no recovery work
    straggler_clients: tuple  # too slow to finish; take no recovery work
    completed: np.ndarray  # (n,) batches banked before recovery kicked in
    residual_T: int  # workload re-planned onto the survivors
    shortfall: int  # residual units the surviving capacity could NOT absorb
    attempts: int  # solver attempts consumed (1 = first try succeeded)
    fallback: bool  # proportional-greedy fallback engaged
    assignments_original: np.ndarray  # the pre-fault plan
    recovery_assignments: np.ndarray  # extra batches per survivor (the y)
    residual_problem: Optional[Problem]  # the exact re-planned instance
    problem: Optional[Problem]  # the planning-time snapshot it derives from
    est_cost_original: float  # pre-fault estimated Joules
    est_overhead_J: float  # est(recovered round) - est(pre-fault plan)


@dataclasses.dataclass
class RoundPlan:
    """Output of the planning stage: the schedule for one round plus what the
    scheduler believed it would cost (on the estimates it planned against)."""

    round_index: int
    T: int  # requested workload (pre-dropout-clipping)
    assignments: np.ndarray  # x_i, sums to the effective workload
    est_cost: float  # estimated Joules under the planning-time tables
    # frontier-mode planning only (DESIGN.md §15): the ε-constraint deadline
    # the chosen frontier point was solved under, and its achieved makespan.
    deadline: Optional[float] = None
    est_time: Optional[float] = None
    # the immutable estimator snapshot this plan was solved against — what
    # mid-round recovery re-plans over, so the residual instance is exact
    # even if the estimator drifted since (DESIGN.md §17)
    problem: Optional[Problem] = None
    recovery: Optional[RecoveryInfo] = None


@dataclasses.dataclass
class ScenarioReport:
    """Per-round what-if analysis (DESIGN.md §9): candidate workloads and
    dropout subsets, ALL solved by one batched (MC)^2MKP DP call."""

    labels: list  # human-readable scenario descriptions, e.g. "T=120", "drop=2,5"
    assignments: np.ndarray  # (B, n) schedule per scenario
    energies: np.ndarray  # (B,) estimated Joules per scenario


@dataclasses.dataclass
class FLRoundResult:
    round_index: int
    assignments: np.ndarray  # x_i
    mean_loss: float  # data-weighted mean client loss
    energy_joules: float  # true total energy charged
    estimated_joules: float  # what the scheduler thought it would cost
    makespan_joules: float  # max per-device energy (OLAR's objective, for contrast)
    scenarios: Optional[ScenarioReport] = None  # what-if planning, if enabled
    recovery: Optional[RecoveryInfo] = None  # mid-round recovery, if it fired
    # an repro.fl.adaptive.AdaptiveRoundStats when the adaptive layer is on
    # (DESIGN.md §18): drift classification, speculation outcome, watermark
    adaptive: Optional[object] = None


def apply_dropout(problem: Problem, dropped) -> Problem:
    """The instance after clients ``dropped`` leave the fleet (paper §6 "loss
    of a device"): their limits collapse to 0 and the workload shrinks to the
    surviving capacity if necessary."""
    dropped = set(int(i) for i in dropped)
    gone = np.array([i in dropped for i in range(problem.n)])
    lower = np.where(gone, 0, problem.lower)
    upper = np.where(gone, 0, problem.upper)
    tables = tuple(
        np.zeros(1) if i in dropped else tbl
        for i, tbl in enumerate(problem.cost_tables)
    )
    T_eff = int(np.clip(problem.T, lower.sum(), upper.sum()))
    return Problem(T=T_eff, lower=lower, upper=upper, cost_tables=tables)


class FederatedServer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Any], jnp.ndarray],
        init_params: Any,
        client_optimizer: Optimizer,
        estimator: EnergyEstimator,
        policy: Optional[PlanPolicy] = None,
        algorithm=_UNSET,
        participation_floor=_UNSET,
        round_T=_UNSET,
        scenario_T_candidates=_UNSET,
        scenario_dropouts=_UNSET,
        engine=_UNSET,
        service=_UNSET,
        frontier_mode=_UNSET,
        time_tables=_UNSET,
        frontier_points=_UNSET,
    ):
        """Planning configuration lives in ``policy`` — a
        :class:`~repro.core.fleet.PlanPolicy` (PR 8's API consolidation):

        * ``policy.round_T``: total mini-batches scheduled per round;
          ``None`` defaults to half the round tensor's capacity (and can
          still be set later, e.g. by :func:`repro.fl.rounds.run_campaign`).
        * ``policy.scenario_T_candidates`` / ``policy.scenario_dropouts``
          enable the per-round scenario-planning hook: alternative workloads
          and client-dropout subsets are evaluated against the CURRENT
          energy estimates via one batched DP solve and attached to each
          :class:`FLRoundResult`.
        * ``policy.engine``: the :class:`~repro.core.sweep.SweepEngine` all
          batched DP solves route through (``None``: the process-wide
          default). Round shapes repeat while only the cost *values* drift,
          so round 1 compiles the DP and every later round reuses the warm
          executable (inspect via ``server.engine.cache_stats()``).
        * ``policy.service``: an optional
          :class:`~repro.serve.service.SchedulerService`. When set, scenario
          batches are SUBMITTED to the service instead of dispatched
          directly (DESIGN.md §14); ``engine=None`` then defaults to the
          service's engine so campaign cache accounting observes the shared
          cache.
        * ``policy.frontier_mode``: picks each round's operating point from
          the LIVE (energy, completion-time) Pareto frontier — ``"knee"`` /
          ``"min_energy"`` / ``"min_time"``, or a round-time budget in
          seconds (ε-constraint). Requires ``policy.time_tables``;
          ``policy.frontier_points`` bounds the per-round sweep batch.
        * ``policy.fleet_clusters``: switches round planning to the
          two-level fleet path (DESIGN.md §16) —
          :meth:`~repro.core.solver.Solver.solve_fleet` with
          ``policy.fleet_quantum`` / ``policy.fleet_seed``. Planning remains
          a pure function of the estimator snapshot (deterministic k-means),
          so pipelined campaigns stay bit-identical.

        The pre-PR-8 constructor kwargs (``algorithm``, ``round_T``,
        ``frontier_mode``, ...) still work bit-identically — each warns
        ``DeprecationWarning`` once per process and is folded into a
        ``PlanPolicy``. Passing both ``policy`` and legacy kwargs raises.
        """
        legacy = {
            name: val
            for name, val in (
                ("algorithm", algorithm),
                ("participation_floor", participation_floor),
                ("round_T", round_T),
                ("scenario_T_candidates", scenario_T_candidates),
                ("scenario_dropouts", scenario_dropouts),
                ("engine", engine),
                ("service", service),
                ("frontier_mode", frontier_mode),
                ("time_tables", time_tables),
                ("frontier_points", frontier_points),
            )
            if val is not _UNSET
        }
        if legacy and policy is not None:
            raise ValueError(
                "pass either policy=PlanPolicy(...) or the legacy kwargs, "
                f"not both (got legacy: {sorted(legacy)})"
            )
        if legacy:
            for name in sorted(legacy):
                warn_deprecated(
                    f"FederatedServer({name}=...)",
                    f"FederatedServer(policy=PlanPolicy({name}=...))",
                    module="repro.fl",
                )
            policy = PlanPolicy(**legacy)
        elif policy is None:
            policy = PlanPolicy()
        self.policy = policy

        self.params = init_params
        self.estimator = estimator
        self.algorithm = policy.algorithm
        self.round_T = policy.round_T
        self.service = policy.service
        engine = policy.engine
        if engine is None and self.service is not None:
            engine = self.service.engine
        self.engine = engine if engine is not None else default_engine()
        self.frontier_mode = policy.frontier_mode
        self.time_tables = None if policy.time_tables is None else [
            np.asarray(t, dtype=np.float64) for t in policy.time_tables
        ]
        self.frontier_points = int(policy.frontier_points)
        self.solver = Solver(
            engine=self.engine, service=self.service, retry=policy.retry
        )
        self.scenario_T_candidates = list(policy.scenario_T_candidates)
        self.scenario_dropouts = [tuple(s) for s in policy.scenario_dropouts]
        self.n_clients = len(estimator.fleet)
        if policy.participation_floor is not None:
            for d in estimator.fleet:
                d.min_batches = policy.participation_floor

        client_fn = make_client_fn(loss_fn, client_optimizer)

        def round_fn(params, batches, num_steps):
            # clients share the same starting params (in_axes=None broadcast)
            client_params, client_loss = jax.vmap(client_fn, in_axes=(None, 0, 0))(
                params, batches, num_steps
            )
            w = num_steps.astype(jnp.float32)
            w = w / jnp.maximum(w.sum(), 1.0)
            new_params = jax.tree.map(
                lambda cp, p: jnp.tensordot(w, cp.astype(jnp.float32), axes=(0, 0)).astype(p.dtype),
                client_params,
                params,
            )
            mean_loss = jnp.sum(w * client_loss)
            return new_params, mean_loss

        self._round_fn = jax.jit(round_fn)

    # ---- round stages (plan -> train -> aggregate/account) -------------

    def build_problem(self, T: int, unavailable=None) -> Problem:
        """Snapshot stage: the scheduling instance for workload ``T`` under
        the CURRENT estimates (cheap numpy — safe to run on the round hot
        path; the returned Problem is immutable, so a background solver can
        consume it while the estimator keeps drifting).

        With ``policy.reliability`` set, chronically flaky clients get their
        effective ``upper`` down-weighted by the estimator's crash/straggle
        reliability scores (DESIGN.md §18) — in this planning snapshot only,
        never in the true simulator tables."""
        est_problem = self.estimator.problem(T, reliability=self._reliability_weights())
        if unavailable:
            est_problem = apply_dropout(est_problem, unavailable)
        return est_problem

    def predict_problem(self, T: int, steps: int) -> Problem:
        """The PREDICTED planning instance ``steps`` rounds ahead (tables
        extrapolated along the estimator's per-client trend) — what the
        speculative lookahead batch solves. ``steps=0`` is exactly
        :meth:`build_problem` without dropout."""
        return self.estimator.predict_problem(
            T, steps, reliability=self._reliability_weights()
        )

    def _reliability_weights(self):
        if self.policy.reliability is None:
            return None
        return self.estimator.reliability_weights()

    def plan_round(
        self, round_index: int, T: int, est_problem: Optional[Problem] = None
    ) -> RoundPlan:
        """Planning stage: solve the schedule for ``est_problem`` (built via
        :meth:`build_problem` if not given). Deterministic in its inputs —
        running it inline or on a planner thread yields the same plan (the
        frontier path included: the grid, sweep, and point selection are all
        pure functions of the immutable snapshot).

        With ``frontier_mode`` set, the round's operating point comes from
        the live Pareto frontier: one batched ε-constraint sweep over a
        ``frontier_points``-sized deadline grid (ONE engine dispatch — or
        one coalescable served request), then the configured selection rule
        picks the round's (energy, time) trade-off."""
        if est_problem is None:
            est_problem = self.build_problem(T)
        if self.policy.fleet_clusters is not None:
            # fleet-scale rounds (DESIGN.md §16): two-level cluster-then-
            # allocate solve — still a pure function of the snapshot (the
            # k-means is deterministic under policy.fleet_seed), so serial
            # and pipelined campaigns stay bit-identical
            fsol = self.solver.solve_fleet(est_problem, policy=self.policy)
            return RoundPlan(
                round_index=round_index,
                T=int(T),
                assignments=np.asarray(fsol.schedule),
                est_cost=float(fsol.objective),
                problem=est_problem,
            )
        if self.frontier_mode is not None:
            grid = deadline_grid(est_problem, self.time_tables, self.frontier_points)
            front = self.solver.frontier(est_problem, self.time_tables, grid)
            pt = front.select(self.frontier_mode)
            return RoundPlan(
                round_index=round_index,
                T=int(T),
                assignments=np.asarray(pt.schedule),
                est_cost=float(pt.energy),
                deadline=float(pt.deadline),
                est_time=float(pt.time),
                problem=est_problem,
            )
        sol = self.solver.solve(est_problem, algorithm=self.algorithm)
        return RoundPlan(
            round_index=round_index,
            T=int(T),
            assignments=np.asarray(sol.schedule),
            est_cost=float(sol.objective),
            problem=est_problem,
        )

    def recover_round(
        self, plan: RoundPlan, faults: RoundFaults, max_attempts: int = 3
    ) -> RoundPlan:
        """Mid-round recovery (DESIGN.md §17): given round telemetry saying
        which clients crashed or straggled and how many batches each actually
        banked, re-plan the residual workload onto the survivors with ONE
        batched solve through the :class:`~repro.core.solver.Solver` facade.

        The residual instance is exact under the paper's atomic-task model —
        survivor ``i``'s marginal table is ``C_i(c_i + j) - C_i(c_i)`` — so
        the recovered assignment is bit-identical to a fault-free re-plan of
        the surviving cohort (asserted in tests/test_faults.py). Transient
        solver failures retry up to ``max_attempts``; if the solver itself is
        the failing component, the guaranteed-feasible
        :func:`~repro.fl.faults.proportional_greedy` fallback engages. The
        returned plan carries full :class:`RecoveryInfo` provenance; its
        ``est_cost`` is re-stated for the recovered assignment on the same
        planning-time tables, so the recovery overhead is directly readable
        as ``est_cost - recovery.est_cost_original``.
        """
        problem = plan.problem
        if problem is None:
            problem = self.build_problem(plan.T)
        x = np.asarray(plan.assignments, dtype=np.int64)
        completed = np.minimum(np.asarray(faults.completed, dtype=np.int64), x)
        res_problem = residual_problem(problem, completed, faults.lost_clients)
        residual = int(x.sum()) - int(completed.sum())
        if residual <= 0:
            return plan
        attempts, fallback, y = 0, False, None
        while attempts < max_attempts:
            attempts += 1
            try:
                # one batched facade solve — same substrate (engine or
                # service) as round planning, so recovery coalesces with any
                # other traffic exactly like a plan does
                sol = self.solver.solve([res_problem], check=True)
                y = np.asarray(sol.schedules[0], dtype=np.int64)
                break
            except Exception as e:
                if not is_transient(e):
                    break  # solver is the failing component: fall back now
        if y is None:
            y = proportional_greedy(res_problem)
            fallback = True
        effective = completed + y
        est_cost = float(total_cost(problem, effective))
        info = RecoveryInfo(
            failed_clients=tuple(faults.crashed),
            straggler_clients=tuple(faults.stragglers),
            completed=completed,
            residual_T=int(res_problem.T),
            shortfall=residual - int(res_problem.T),
            attempts=attempts,
            fallback=fallback,
            assignments_original=x,
            recovery_assignments=y,
            residual_problem=res_problem,
            problem=problem,
            est_cost_original=float(plan.est_cost),
            est_overhead_J=est_cost - float(plan.est_cost),
        )
        return dataclasses.replace(
            plan, assignments=effective, est_cost=est_cost, recovery=info
        )

    def train_round(self, plan: RoundPlan, batches) -> jnp.ndarray:
        """Training stage: dispatches the jitted SPMD round program and
        updates ``self.params``. Returns the data-weighted mean loss as an
        UN-materialized device array (JAX async dispatch) — call ``float()``
        on it only when the value is actually needed, so planning work can
        proceed while clients train."""
        num_steps = jnp.asarray(plan.assignments, dtype=jnp.int32)
        self.params, mean_loss = self._round_fn(
            self.params, jnp.asarray(batches), num_steps
        )
        return mean_loss

    def account_round(self, plan: RoundPlan, rng: np.random.Generator) -> dict:
        """Accounting stage: charge each device its TRUE energy and feed
        noisy measurements back into the estimator. Pure CPU, and the ONLY
        stage consuming ``rng`` / mutating estimator state — so stage order
        fixes the random stream and serial vs pipelined campaigns stay
        bit-identical."""
        x = plan.assignments
        true_problem = self.estimator.true_problem(plan.T)
        true_cost = total_cost(true_problem, x)
        per_dev = [true_problem.cost(i, int(x[i])) for i in range(self.n_clients)]
        for i, dev in enumerate(self.estimator.fleet):
            if x[i] > 0:
                self.estimator.observe(i, int(x[i]), dev.measure(int(x[i]), rng))
        return {
            "energy_joules": float(true_cost),
            "makespan_joules": float(max(per_dev)),
        }

    def build_scenarios(self, T: int):
        """What-if snapshot (cheap): the configured candidate workloads and
        dropout subsets as concrete Problems under the current estimates.
        Must run AFTER :meth:`account_round` so scenarios see the freshest
        tables; the expensive solve (:meth:`solve_scenarios`) can then run
        anywhere."""
        if not self.scenario_T_candidates and not self.scenario_dropouts:
            return [], []
        # build_problem (not the raw estimator) so scenario what-ifs see the
        # same reliability-weighted envelope round planning does; with
        # policy.reliability unset this is the estimator snapshot verbatim
        base = self.build_problem(T)
        problems, labels = [], []
        for Tc in self.scenario_T_candidates:
            Tc_eff = int(np.clip(int(Tc), int(base.lower.sum()), int(base.upper.sum())))
            problems.append(self.build_problem(Tc_eff))
            labels.append(f"T={Tc_eff}")
        for sub in self.scenario_dropouts:
            problems.append(apply_dropout(base, sub))
            labels.append("drop=" + ",".join(str(int(i)) for i in sorted(set(sub))))
        return problems, labels

    def solve_scenarios(self, problems, labels) -> Optional[ScenarioReport]:
        """Evaluates the snapshotted what-ifs with ONE regime-split batched
        solve through the engine (the pipelined campaign runs this whole
        stage on the planner thread); returns None when no scenarios are
        configured. Scenarios whose estimated cost tables are monotone —
        e.g. dropout/deadline what-ifs over a linear or DVFS-superlinear
        energy fleet — ride the marginal fast path (DESIGN.md §13) instead
        of paying the pseudo-polynomial DP; arbitrary-regime scenarios
        still batch into the fused DP.

        With a :class:`~repro.serve.service.SchedulerService` configured,
        the whole scenario batch goes through the service as ONE request —
        the coalescer may merge it with same-bucket external traffic into a
        single dispatch, and results stay bit-identical to the direct
        engine path (inert padding)."""
        if not problems:
            return None
        # the facade's batch path: regime-split through the engine, or ONE
        # served request when a service is configured — same dispatch the
        # pre-facade code made, so campaigns stay bit-identical
        res = self.solver.solve(problems, check=False)
        X = np.stack(res.schedules)  # every scenario spans the full fleet
        return ScenarioReport(
            labels=list(labels), assignments=X, energies=res.objectives
        )

    # ---- serial composition --------------------------------------------

    def run_round(
        self,
        round_index: int,
        batches: np.ndarray,
        rng: np.random.Generator,
        unavailable=None,
    ) -> FLRoundResult:
        """One FedAvg round: the stages composed serially (the reference
        code path; ``fl/pipeline.py`` runs the same stages with the DP
        solves moved off the hot path).

        ``unavailable``: optional iterable of client indices that dropped out
        before this round (paper §6 "loss of a device" future-work item):
        their limits collapse to 0 and the workload is rescheduled over the
        remaining fleet — shrunk to the surviving capacity if necessary.
        """
        T = self._round_T(batches)
        plan = self.plan_round(round_index, T, self.build_problem(T, unavailable))
        mean_loss = self.train_round(plan, batches)
        acct = self.account_round(plan, rng)
        # what-if planning for the NEXT round, on the freshest estimates
        scenarios = self.solve_scenarios(*self.build_scenarios(T))
        return FLRoundResult(
            round_index=round_index,
            assignments=plan.assignments,
            mean_loss=float(mean_loss),
            energy_joules=acct["energy_joules"],
            estimated_joules=plan.est_cost,
            makespan_joules=acct["makespan_joules"],
            scenarios=scenarios,
        )

    def _round_T(self, batches) -> int:
        """Round workload: the explicitly configured ``round_T`` if set,
        otherwise half the total capacity of the round tensor."""
        if self.round_T is None:
            n, s = batches.shape[0], batches.shape[1]
            return (n * s) // 2
        return int(self.round_T)
