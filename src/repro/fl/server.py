"""FedAvg server with energy-minimal workload scheduling.

Per round (McMahan et al. [1] + this paper's contribution):
  1. The server asks the :class:`~repro.fl.energy.EnergyEstimator` for the
     fleet's cost tables and solves the Minimal Cost FL Schedule problem for
     the round's workload ``T`` (total mini-batches) — ``x_i`` per client.
  2. All clients execute one jitted SPMD program: ``vmap`` over clients of a
     masked local-training scan (``fl/client.py``).
  3. Aggregation: data-weighted parameter average (weights ``x_i / T``);
     clients with ``x_i = 0`` contribute nothing.
  4. The simulator charges each device its TRUE energy for ``x_i`` batches
     (with measurement noise fed back to the estimator).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.problem import Problem, total_cost
from ..core.scheduler import schedule
from ..core.sweep import SweepEngine, default_engine
from ..optim.optimizers import Optimizer
from .client import make_client_fn
from .energy import EnergyEstimator

__all__ = ["FLRoundResult", "ScenarioReport", "FederatedServer", "apply_dropout"]


@dataclasses.dataclass
class ScenarioReport:
    """Per-round what-if analysis (DESIGN.md §9): candidate workloads and
    dropout subsets, ALL solved by one batched (MC)^2MKP DP call."""

    labels: list  # human-readable scenario descriptions, e.g. "T=120", "drop=2,5"
    assignments: np.ndarray  # (B, n) schedule per scenario
    energies: np.ndarray  # (B,) estimated Joules per scenario


@dataclasses.dataclass
class FLRoundResult:
    round_index: int
    assignments: np.ndarray  # x_i
    mean_loss: float  # data-weighted mean client loss
    energy_joules: float  # true total energy charged
    estimated_joules: float  # what the scheduler thought it would cost
    makespan_joules: float  # max per-device energy (OLAR's objective, for contrast)
    scenarios: Optional[ScenarioReport] = None  # what-if planning, if enabled


def apply_dropout(problem: Problem, dropped) -> Problem:
    """The instance after clients ``dropped`` leave the fleet (paper §6 "loss
    of a device"): their limits collapse to 0 and the workload shrinks to the
    surviving capacity if necessary."""
    dropped = set(int(i) for i in dropped)
    gone = np.array([i in dropped for i in range(problem.n)])
    lower = np.where(gone, 0, problem.lower)
    upper = np.where(gone, 0, problem.upper)
    tables = tuple(
        np.zeros(1) if i in dropped else tbl
        for i, tbl in enumerate(problem.cost_tables)
    )
    T_eff = int(np.clip(problem.T, lower.sum(), upper.sum()))
    return Problem(T=T_eff, lower=lower, upper=upper, cost_tables=tables)


class FederatedServer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Any], jnp.ndarray],
        init_params: Any,
        client_optimizer: Optimizer,
        estimator: EnergyEstimator,
        algorithm: str = "auto",
        participation_floor: Optional[int] = None,
        round_T: Optional[int] = None,
        scenario_T_candidates: Optional[Sequence[int]] = None,
        scenario_dropouts: Optional[Sequence[Sequence[int]]] = None,
        engine: Optional[SweepEngine] = None,
    ):
        """``round_T``: total mini-batches scheduled per round; ``None``
        defaults to half the round tensor's capacity (and can still be set
        later, e.g. by :func:`repro.fl.rounds.run_campaign`).

        ``scenario_T_candidates`` / ``scenario_dropouts`` enable the per-round
        scenario-planning hook: alternative workloads and client-dropout
        subsets are evaluated against the CURRENT energy estimates via one
        batched DP solve and attached to each :class:`FLRoundResult`.

        ``engine``: the :class:`~repro.core.sweep.SweepEngine` all batched
        DP solves route through (``None``: the process-wide default). Round
        shapes repeat while only the cost *values* drift, so round 1
        compiles the DP and every later round reuses the warm executable
        (inspect via ``server.engine.cache_stats()``).
        """
        self.params = init_params
        self.estimator = estimator
        self.algorithm = algorithm
        self.round_T = round_T
        self.engine = engine if engine is not None else default_engine()
        self.scenario_T_candidates = list(scenario_T_candidates or ())
        self.scenario_dropouts = [tuple(s) for s in (scenario_dropouts or ())]
        self.n_clients = len(estimator.fleet)
        if participation_floor is not None:
            for d in estimator.fleet:
                d.min_batches = participation_floor

        client_fn = make_client_fn(loss_fn, client_optimizer)

        def round_fn(params, batches, num_steps):
            # clients share the same starting params (in_axes=None broadcast)
            client_params, client_loss = jax.vmap(client_fn, in_axes=(None, 0, 0))(
                params, batches, num_steps
            )
            w = num_steps.astype(jnp.float32)
            w = w / jnp.maximum(w.sum(), 1.0)
            new_params = jax.tree.map(
                lambda cp, p: jnp.tensordot(w, cp.astype(jnp.float32), axes=(0, 0)).astype(p.dtype),
                client_params,
                params,
            )
            mean_loss = jnp.sum(w * client_loss)
            return new_params, mean_loss

        self._round_fn = jax.jit(round_fn)

    def run_round(
        self,
        round_index: int,
        batches: np.ndarray,
        rng: np.random.Generator,
        unavailable=None,
    ) -> FLRoundResult:
        """One FedAvg round.

        ``unavailable``: optional iterable of client indices that dropped out
        before this round (paper §6 "loss of a device" future-work item):
        their limits collapse to 0 and the workload is rescheduled over the
        remaining fleet — shrunk to the surviving capacity if necessary.
        """
        T = self._round_T(batches)
        est_problem = self.estimator.problem(T)
        if unavailable:
            est_problem = apply_dropout(est_problem, unavailable)
        x = schedule(est_problem, self.algorithm)
        est_cost = total_cost(est_problem, x)

        num_steps = jnp.asarray(x, dtype=jnp.int32)
        self.params, mean_loss = self._round_fn(self.params, jnp.asarray(batches), num_steps)

        # charge true energy + feed measurements back
        true_problem = self.estimator.true_problem(T)
        true_cost = total_cost(true_problem, x)
        per_dev = [true_problem.cost(i, int(x[i])) for i in range(self.n_clients)]
        for i, dev in enumerate(self.estimator.fleet):
            if x[i] > 0:
                self.estimator.observe(i, int(x[i]), dev.measure(int(x[i]), rng))
        # what-if planning for the NEXT round, on the freshest estimates
        scenarios = self._plan_scenarios(T)
        return FLRoundResult(
            round_index=round_index,
            assignments=np.asarray(x),
            mean_loss=float(mean_loss),
            energy_joules=float(true_cost),
            estimated_joules=float(est_cost),
            makespan_joules=float(max(per_dev)),
            scenarios=scenarios,
        )

    def _round_T(self, batches) -> int:
        """Round workload: the explicitly configured ``round_T`` if set,
        otherwise half the total capacity of the round tensor."""
        if self.round_T is None:
            n, s = batches.shape[0], batches.shape[1]
            return (n * s) // 2
        return int(self.round_T)

    def _plan_scenarios(self, T: int) -> Optional[ScenarioReport]:
        """Evaluates every configured what-if (candidate workloads, dropout
        subsets) against the current energy estimates with ONE batched DP
        solve; returns None when no scenarios are configured."""
        if not self.scenario_T_candidates and not self.scenario_dropouts:
            return None
        base = self.estimator.problem(T)
        problems, labels = [], []
        for Tc in self.scenario_T_candidates:
            Tc_eff = int(np.clip(int(Tc), int(base.lower.sum()), int(base.upper.sum())))
            problems.append(self.estimator.problem(Tc_eff))
            labels.append(f"T={Tc_eff}")
        for sub in self.scenario_dropouts:
            problems.append(apply_dropout(base, sub))
            labels.append("drop=" + ",".join(str(int(i)) for i in sorted(set(sub))))
        X = self.engine.solve(problems)[:, : self.n_clients]
        energies = np.array(
            [total_cost(p, X[b]) for b, p in enumerate(problems)], dtype=np.float64
        )
        return ScenarioReport(labels=labels, assignments=X, energies=energies)
