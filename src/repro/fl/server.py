"""FedAvg server with energy-minimal workload scheduling.

Per round (McMahan et al. [1] + this paper's contribution):
  1. The server asks the :class:`~repro.fl.energy.EnergyEstimator` for the
     fleet's cost tables and solves the Minimal Cost FL Schedule problem for
     the round's workload ``T`` (total mini-batches) — ``x_i`` per client.
  2. All clients execute one jitted SPMD program: ``vmap`` over clients of a
     masked local-training scan (``fl/client.py``).
  3. Aggregation: data-weighted parameter average (weights ``x_i / T``);
     clients with ``x_i = 0`` contribute nothing.
  4. The simulator charges each device its TRUE energy for ``x_i`` batches
     (with measurement noise fed back to the estimator).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.problem import total_cost
from ..core.scheduler import schedule
from ..optim.optimizers import Optimizer
from .client import make_client_fn
from .energy import EnergyEstimator

__all__ = ["FLRoundResult", "FederatedServer"]


@dataclasses.dataclass
class FLRoundResult:
    round_index: int
    assignments: np.ndarray  # x_i
    mean_loss: float  # data-weighted mean client loss
    energy_joules: float  # true total energy charged
    estimated_joules: float  # what the scheduler thought it would cost
    makespan_joules: float  # max per-device energy (OLAR's objective, for contrast)


class FederatedServer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Any], jnp.ndarray],
        init_params: Any,
        client_optimizer: Optimizer,
        estimator: EnergyEstimator,
        algorithm: str = "auto",
        participation_floor: Optional[int] = None,
    ):
        self.params = init_params
        self.estimator = estimator
        self.algorithm = algorithm
        self.n_clients = len(estimator.fleet)
        if participation_floor is not None:
            for d in estimator.fleet:
                d.min_batches = participation_floor

        client_fn = make_client_fn(loss_fn, client_optimizer)

        def round_fn(params, batches, num_steps):
            # clients share the same starting params (in_axes=None broadcast)
            client_params, client_loss = jax.vmap(client_fn, in_axes=(None, 0, 0))(
                params, batches, num_steps
            )
            w = num_steps.astype(jnp.float32)
            w = w / jnp.maximum(w.sum(), 1.0)
            new_params = jax.tree.map(
                lambda cp, p: jnp.tensordot(w, cp.astype(jnp.float32), axes=(0, 0)).astype(p.dtype),
                client_params,
                params,
            )
            mean_loss = jnp.sum(w * client_loss)
            return new_params, mean_loss

        self._round_fn = jax.jit(round_fn)

    def run_round(
        self,
        round_index: int,
        batches: np.ndarray,
        rng: np.random.Generator,
        unavailable=None,
    ) -> FLRoundResult:
        """One FedAvg round.

        ``unavailable``: optional iterable of client indices that dropped out
        before this round (paper §6 "loss of a device" future-work item):
        their limits collapse to 0 and the workload is rescheduled over the
        remaining fleet — shrunk to the surviving capacity if necessary.
        """
        T = self._round_T(batches)
        est_problem = self.estimator.problem(T)
        if unavailable:
            dropped = set(int(i) for i in unavailable)
            lower = np.where([i in dropped for i in range(self.n_clients)], 0, est_problem.lower)
            upper = np.where([i in dropped for i in range(self.n_clients)], 0, est_problem.upper)
            tables = tuple(
                np.zeros(1) if i in dropped else tbl
                for i, tbl in enumerate(est_problem.cost_tables)
            )
            T_eff = min(T, int(upper.sum()))
            from ..core.problem import Problem

            est_problem = Problem(T=T_eff, lower=lower, upper=upper, cost_tables=tables)
        x = schedule(est_problem, self.algorithm)
        est_cost = total_cost(est_problem, x)

        num_steps = jnp.asarray(x, dtype=jnp.int32)
        self.params, mean_loss = self._round_fn(self.params, jnp.asarray(batches), num_steps)

        # charge true energy + feed measurements back
        true_problem = self.estimator.true_problem(T)
        true_cost = total_cost(true_problem, x)
        per_dev = [true_problem.cost(i, int(x[i])) for i in range(self.n_clients)]
        for i, dev in enumerate(self.estimator.fleet):
            if x[i] > 0:
                self.estimator.observe(i, int(x[i]), dev.measure(int(x[i]), rng))
        return FLRoundResult(
            round_index=round_index,
            assignments=np.asarray(x),
            mean_loss=float(mean_loss),
            energy_joules=float(true_cost),
            estimated_joules=float(est_cost),
            makespan_joules=float(max(per_dev)),
        )

    def _round_T(self, batches) -> int:
        """Round workload: total batches to schedule = what the round tensor
        can hold at most per client, times a utilization target — here simply
        the configured T stored on the server by the driver."""
        if not hasattr(self, "round_T"):
            # default: half the total capacity of the round tensor
            n, s = batches.shape[0], batches.shape[1]
            return (n * s) // 2
        return self.round_T
