"""Toy embedding LM shared by the FL tests and benchmarks.

A two-matrix next-token model (embed -> tanh -> unembed) that is cheap to
jit yet has a real loss surface — enough for the FL substrate's concerns
(masked local training, FedAvg aggregation, energy-vs-loss accounting)
without modeling machinery. One definition here keeps the bit-identicality
suites honest: tests/test_fl_substrate.py, tests/test_fl_pipeline.py,
benchmarks/bench_fl_energy.py, and benchmarks/bench_async.py all train the
SAME model, so a change to the loss cannot silently diverge them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_tiny_lm"]


def make_tiny_lm(vocab: int, dim: int):
    """Returns ``(init_fn, loss_fn)`` for a toy next-token LM.

    ``init_fn(key)`` -> params pytree; ``loss_fn(params, batch)`` -> scalar
    mean NLL for a ``(B, seq+1)`` int token batch (first ``seq`` positions
    are inputs, shifted-by-one are targets).
    """

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "emb": jax.random.normal(k1, (vocab, dim)) * 0.1,
            "out": jax.random.normal(k2, (dim, vocab)) * 0.1,
        }

    def loss(params, batch):
        x, y = batch[:, :-1], batch[:, 1:]
        h = jnp.tanh(params["emb"][x])
        logits = h @ params["out"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[..., None], axis=-1).mean()

    return init, loss
