"""Shared neural building blocks (pure JAX, functional params-as-pytrees).

Conventions:
  * params are nested dicts of jnp arrays; scanned layer stacks carry a
    leading ``L`` axis on every leaf.
  * activations default to bf16 compute with fp32 normalization/softmax.
  * weight names are stable — sharding rules in ``launch/sharding.py`` match
    on them (e.g. ``w_in``-like matrices shard (fsdp, tensor)).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "make_rope",
    "apply_rope",
    "dense_init",
    "attention",
    "gqa_attention",
    "mlp_gated",
    "mlp_act",
    "softcap",
]


def dense_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.float32, scale: float = 1.0):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = scale / max(fan_in, 1) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x.astype(jnp.float32) / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def make_rope(positions: jnp.ndarray, head_dim: int, base: float = 10000.0):
    """Returns (sin, cos) of shape positions.shape + (head_dim//2,)."""
    half = head_dim // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D). sin/cos: (..., S, D/2) broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    s = sin[..., None, :]  # add head axis
    c = cos[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Attention (GQA/MQA, causal / sliding-window / prefix-LM / bidirectional,
# optional logit softcap). Einsum formulation so GSPMD shards heads freely.
# ---------------------------------------------------------------------------


def _build_mask(
    q_pos: jnp.ndarray,  # (Sq,)
    kv_pos: jnp.ndarray,  # (Sk,)
    kind: str,
    window: int = 0,
    prefix_len: Optional[jnp.ndarray] = None,  # (B,) or scalar
):
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    if kind == "bidirectional":
        m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    elif kind == "causal":
        m = kp <= qp
    elif kind == "sliding":
        m = (kp <= qp) & (kp > qp - window)
    elif kind == "prefix":
        causal = kp <= qp
        pl = 0 if prefix_len is None else prefix_len  # None at decode: pure causal
        in_prefix = kp < pl  # attendable by everyone
        m = causal | in_prefix
    else:
        raise ValueError(kind)
    return m


def attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    kind: str = "causal",
    window: int = 0,
    prefix_len: Optional[jnp.ndarray] = None,
    attn_softcap: float = 0.0,
    kv_valid: Optional[jnp.ndarray] = None,  # (B, Sk) bool — cache validity
    scale: Optional[float] = None,
    block_q: int = 0,
    impl: str = "xla",
) -> jnp.ndarray:
    """Grouped-query attention. Returns (B, Sq, H, D).

    ``block_q > 0`` scans over query blocks so the logits tensor is bounded
    at (B, H, block_q, Sk) — the memory-bounded formulation used for the
    large train/prefill shapes (exact math, no online-softmax needed since
    each block sees the full key row).

    ``impl='pallas'`` routes full self-attention (train/prefill, causal /
    sliding / bidirectional, no cache) through the flash-attention Pallas
    kernel — probs never touch HBM. Falls back to XLA for decode/prefix.
    """
    B, Sq, H, D = q.shape
    if (
        impl == "pallas"
        and kind in ("causal", "sliding", "bidirectional")
        and prefix_len is None and kv_valid is None
        and Sq == k.shape[1] and Sq >= 128 and Sq % 128 == 0
        and D == v.shape[-1]
    ):
        from ..kernels.flash_attention import flash_attention

        bq = min(block_q or 512, Sq)
        o = flash_attention(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
            kind, window, attn_softcap, scale, bq, min(512, Sq), True,
        )
        return jnp.moveaxis(o, 2, 1)
    if block_q and Sq > block_q and Sq % block_q == 0:
        nb = Sq // block_q
        qb = q.reshape(B, nb, block_q, H, D)
        pb = q_pos.reshape(nb, block_q)

        def body(_, inp):
            qi, pi = inp
            out = attention(
                qi, k, v, q_pos=pi, kv_pos=kv_pos, kind=kind, window=window,
                prefix_len=prefix_len, attn_softcap=attn_softcap,
                kv_valid=kv_valid, scale=scale, block_q=0,
            )
            return None, out

        # checkpoint the block body: without this, scan AD stacks every
        # block's softmax probs/masks for backward (flash-attention-style
        # recompute instead)
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        _, outs = jax.lax.scan(body, None, (jnp.moveaxis(qb, 1, 0), pb))
        return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, v.shape[-1])
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = (q * scale).astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    if attn_softcap:
        logits = softcap(logits, attn_softcap)
    mask = _build_mask(q_pos, kv_pos, kind, window, prefix_len)  # (Sq, Sk)
    mask = mask[None, None, None]
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1])  # v head dim may differ (MLA)


def gqa_attention(params, x, cfg_heads, *, rope_sincos, kind="causal", window=0,
                  prefix_len=None, attn_softcap=0.0, query_pre_scale=None):
    """Projection + RoPE + attention + out-projection for the common case.

    params: {wq (d,H,hd), wk (d,Hkv,hd), wv (d,Hkv,hd), wo (H,hd,d)}.
    x: (B, S, d). Returns (B, S, d).
    """
    H, Hkv, hd = cfg_heads
    sin, cos = rope_sincos
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    S = x.shape[1]
    pos = jnp.arange(S)
    out = attention(
        q, k, v, q_pos=pos, kv_pos=pos, kind=kind, window=window,
        prefix_len=prefix_len, attn_softcap=attn_softcap, scale=query_pre_scale,
    )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_gated(params, x, act=jax.nn.silu):
    """SwiGLU-style: (act(x W_gate) * x W_in) W_out."""
    h = act(jnp.einsum("bsd,df->bsf", x, params["w_gate"])) * jnp.einsum(
        "bsd,df->bsf", x, params["w_in"]
    )
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])


def mlp_act(params, x, act):
    """Plain two-matrix MLP with activation (gelu / squared-relu / ...)."""
    h = act(jnp.einsum("bsd,df->bsf", x, params["w_in"]))
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r
