"""State-space / recurrent cells: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

All cells come in two forms with identical semantics:
  * chunked/parallel form used for training and prefill (scan over chunks,
    quadratic-within-chunk — the TPU-friendly formulation: big einsums on the
    MXU instead of a length-L sequential scan);
  * single-step recurrent form used for decode (O(1) state update).

Property tests assert chunked == sequential step-by-step execution.

Shapes:  x (B, L, H, P) heads/headdim;  ssm state (B, H, P, N);
         mLSTM state (B, H, DK, DV) + normalizer (B, H, DK) + stabilizer (B, H).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "ssd_chunked",
    "ssd_step",
    "mlstm_chunked",
    "mlstm_step",
    "slstm_scan",
    "slstm_step",
    "causal_conv1d",
    "causal_conv1d_step",
]


# ---------------------------------------------------------------------------
# depthwise causal conv (mamba2 front conv)
# ---------------------------------------------------------------------------


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, state=None):
    """x: (B, L, C); w: (K, C) depthwise. Returns (y, new_state) where
    state is the trailing K-1 inputs for streaming decode."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+K-1, C)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, -(K - 1) :, :] if K > 1 else jnp.zeros((x.shape[0], 0, x.shape[2]), x.dtype)
    return y, new_state


def causal_conv1d_step(x_t: jnp.ndarray, w: jnp.ndarray, state: jnp.ndarray):
    """x_t: (B, 1, C); state: (B, K-1, C)."""
    K = w.shape[0]
    window = jnp.concatenate([state.astype(x_t.dtype), x_t], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., Q). Returns (..., Q, Q) with out[t, s] = sum_{s < r <= t} a[r]
    for t >= s, -inf below the diagonal band."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, state=None):
    """Structured state-space duality (Mamba2), chunked.

    Args:
      x: (B, L, H, P) values.
      dt: (B, L, H) positive step sizes (post-softplus).
      A: (H,) negative decay rates.
      B, C: (B, L, N) shared across heads (G=1 groups).
      chunk: chunk length (must divide L).
      state: optional initial state (B, H, P, N).

    Returns: y (B, L, H, P), final_state (B, H, P, N).
    """
    Bsz, L, H, P = x.shape
    N = B.shape[-1]
    nc = L // chunk
    assert nc * chunk == L, (L, chunk)

    f32 = jnp.float32
    xc = jnp.moveaxis(x.reshape(Bsz, nc, chunk, H, P), 1, 0)  # (nc, B, Q, H, P)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, chunk, H).astype(f32), 1, 0)
    Bc = jnp.moveaxis(B.reshape(Bsz, nc, chunk, N).astype(f32), 1, 0)
    Cc = jnp.moveaxis(C.reshape(Bsz, nc, chunk, N).astype(f32), 1, 0)

    if state is None:
        state0 = jnp.zeros((Bsz, H, P, N), f32)
    else:
        state0 = state.astype(f32)

    def chunk_fn(s, inp):
        """One chunk: quadratic intra-chunk + carried-state contribution.
        Scanned (not batched over chunks) so the (B, H, Q, Q) decay matrix
        exists for ONE chunk at a time; checkpointed so backward recomputes
        it instead of stacking it across chunks."""
        xq, dq, Bq, Cq = inp  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        a = jnp.moveaxis(dq * A.astype(f32)[None, None, :], -1, 1)  # (B,H,Q)
        Lmat = jnp.exp(_segsum(a))  # (B, H, Q, Q)
        y_diag = jnp.einsum(
            "bqn,bsn,bhqs,bsh,bshp->bqhp", Cq, Bq, Lmat, dq, xq.astype(f32)
        )
        a_cum = jnp.cumsum(a, axis=-1)  # (B, H, Q)
        in_decay = jnp.exp(a_cum)
        y_off = jnp.einsum("bqn,bhq,bhpn->bqhp", Cq, in_decay, s)
        decay_to_end = jnp.exp(a_cum[..., -1:] - a_cum)
        S_c = jnp.einsum("bsn,bhs,bsh,bshp->bhpn", Bq, decay_to_end, dq, xq.astype(f32))
        s_new = s * jnp.exp(a_cum[..., -1])[..., None, None] + S_c
        return s_new, (y_diag + y_off).astype(x.dtype)

    body = jax.checkpoint(chunk_fn, policy=jax.checkpoint_policies.nothing_saveable)
    final_state, ys = jax.lax.scan(body, state0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, L, H, P)
    return y, final_state


def ssd_step(x_t, dt_t, A, B_t, C_t, state):
    """One decode step. x_t (B, H, P); dt_t (B, H); B_t, C_t (B, N);
    state (B, H, P, N). Returns (y (B, H, P), new_state)."""
    f32 = jnp.float32
    dec = jnp.exp(dt_t.astype(f32) * A.astype(f32)[None, :])  # (B, H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt_t.astype(f32), x_t.astype(f32), B_t.astype(f32))
    new_state = state.astype(f32) * dec[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t.astype(f32))
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — stabilized chunkwise form
# ---------------------------------------------------------------------------


def mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int, state=None):
    """q, k: (B, L, H, DK); v: (B, L, H, DV); i_pre, f_pre: (B, L, H).

    state: optional (S (B,H,DK,DV), n (B,H,DK), m (B,H)).
    Returns: h (B, L, H, DV), (S, n, m) final.
    """
    Bsz, L, H, DK = q.shape
    DV = v.shape[-1]
    nc = L // chunk
    assert nc * chunk == L
    f32 = jnp.float32
    scale = DK ** -0.5

    qc = q.reshape(Bsz, nc, chunk, H, DK).astype(f32) * scale
    kc = k.reshape(Bsz, nc, chunk, H, DK).astype(f32)
    vc = v.reshape(Bsz, nc, chunk, H, DV).astype(f32)
    logf = jax.nn.log_sigmoid(f_pre.reshape(Bsz, nc, chunk, H).astype(f32))
    logi = i_pre.reshape(Bsz, nc, chunk, H).astype(f32)

    F = jnp.cumsum(logf, axis=2)  # (B, nc, Q, H): decay chunk-start..t (incl t)
    F_last = F[:, :, -1, :]  # (B, nc, H)
    g = logi - F  # (B, nc, Q, H)
    g_runmax = jax.lax.cummax(g, axis=2)

    if state is None:
        S0 = jnp.zeros((Bsz, H, DK, DV), f32)
        n0 = jnp.zeros((Bsz, H, DK), f32)
        m0 = jnp.full((Bsz, H), -1e30, f32)
    else:
        S0, n0, m0 = (s.astype(f32) for s in state)

    def chunk_fn(carry, inp):
        S, n, m = carry
        qq, kk, vv, Fq, gq, gmax, flast = inp
        # qq (B,Q,H,DK) ...; Fq,gq,gmax (B,Q,H); flast (B,H)
        m_intra = Fq + gmax  # (B, Q, H)
        m_inter = Fq + m[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)

        # inter-chunk: h_inter = (q . S) * exp(F + m_prev - m_t)
        w_inter = jnp.exp(m_inter - m_t)  # (B,Q,H)
        h_inter = jnp.einsum("bqhk,bhkv->bqhv", qq, S) * w_inter[..., None]
        l_inter = jnp.einsum("bqhk,bhk->bqh", qq, n) * w_inter

        # intra-chunk: D[t,s] = exp(F_t - F_s + logi_s - m_t) for s <= t
        # F_t - F_s + logi_s = F_t + g_s
        Dlog = Fq[:, :, None, :] + gq[:, None, :, :] - m_t[:, :, None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        Dlog = jnp.where(tri[None, :, :, None], Dlog, -jnp.inf)
        D = jnp.exp(Dlog)  # (B, Q, S, H)
        qk = jnp.einsum("bqhk,bshk->bqsh", qq, kk)
        W = qk * D
        h_intra = jnp.einsum("bqsh,bshv->bqhv", W, vv)
        l_intra = jnp.einsum("bqsh->bqh", W)

        denom = jnp.maximum(jnp.abs(l_inter + l_intra), jnp.exp(-m_t))
        h = (h_inter + h_intra) / denom[..., None]

        # carry update
        m_new = jnp.maximum(flast + m, flast + gmax[:, -1, :])  # (B, H)
        w_old = jnp.exp(flast + m - m_new)
        w_in = jnp.exp(flast[:, None, :] + gq - m_new[:, None, :])  # (B,Q,H)
        S_new = S * w_old[..., None, None] + jnp.einsum("bqh,bqhk,bqhv->bhkv", w_in, kk, vv)
        n_new = n * w_old[..., None] + jnp.einsum("bqh,bqhk->bhk", w_in, kk)
        return (S_new, n_new, m_new), h

    xs = (
        jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(F, 1, 0), jnp.moveaxis(g, 1, 0), jnp.moveaxis(g_runmax, 1, 0),
        jnp.moveaxis(F_last, 1, 0),
    )
    # checkpointed: backward recomputes each chunk's (B,Q,S,H) decay matrix
    # instead of stacking all chunks' residuals
    chunk_fn = jax.checkpoint(chunk_fn, policy=jax.checkpoint_policies.nothing_saveable)
    (S, n, m), hs = jax.lax.scan(chunk_fn, (S0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(Bsz, L, H, DV)
    return h.astype(v.dtype), (S, n, m)


def mlstm_step(q_t, k_t, v_t, i_t, f_t, state):
    """One decode step. q_t,k_t (B,H,DK); v_t (B,H,DV); i_t,f_t (B,H);
    state (S, n, m). Returns (h (B,H,DV), new_state)."""
    S, n, m = (s.astype(jnp.float32) for s in state)
    f32 = jnp.float32
    DK = q_t.shape[-1]
    logf = jax.nn.log_sigmoid(f_t.astype(f32))
    logi = i_t.astype(f32)
    m_new = jnp.maximum(logf + m, logi)
    w_old = jnp.exp(logf + m - m_new)
    w_in = jnp.exp(logi - m_new)
    kk = k_t.astype(f32)
    vv = v_t.astype(f32)
    S_new = S * w_old[..., None, None] + w_in[..., None, None] * kk[..., :, None] * vv[..., None, :]
    n_new = n * w_old[..., None] + w_in[..., None] * kk
    qq = q_t.astype(f32) * DK ** -0.5
    num = jnp.einsum("bhk,bhkv->bhv", qq, S_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qq, n_new)), jnp.exp(-m_new))
    h = num / den[..., None]
    return h.astype(v_t.dtype), (S_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, sequential by construction)
# ---------------------------------------------------------------------------


def slstm_step(z_t, i_t, f_t, o_t, state):
    """z,i,f,o: (B, H, D) pre-activations; state (c, n, m) each (B, H, D)."""
    c, n, m = state
    f32 = jnp.float32
    logf = jax.nn.log_sigmoid(f_t.astype(f32))
    logi = i_t.astype(f32)
    m_new = jnp.maximum(logf + m, logi)
    c_new = jnp.exp(logf + m - m_new) * c + jnp.exp(logi - m_new) * jnp.tanh(z_t.astype(f32))
    n_new = jnp.exp(logf + m - m_new) * n + jnp.exp(logi - m_new)
    h = jax.nn.sigmoid(o_t.astype(f32)) * c_new / jnp.maximum(n_new, 1e-6)
    return h.astype(z_t.dtype), (c_new, n_new, m_new)


def slstm_scan(z, i_pre, f_pre, o_pre, r_weights, state=None, unroll: int = 16):
    """Sequential scan over time with head-wise recurrent connections.

    z, i_pre, f_pre, o_pre: (B, L, H, D). r_weights: dict of (H, D, D)
    recurrent matrices for each gate. state: optional (c, n, m, h_prev).
    Returns (h (B, L, H, D), final_state).

    ``unroll``: scan unroll factor. Under GSPMD, the backward of a unit-step
    scan all-reduces the recurrent-weight gradient EVERY timestep (partial
    batch-sharded outer products hit a replicated accumulator); unrolling
    lets XLA sum ``unroll`` partials locally per loop iteration first —
    measured 4096->256 gradient all-reduces per layer (see EXPERIMENTS §Perf).
    """
    Bsz, L, H, D = z.shape
    if state is None:
        zeros = jnp.zeros((Bsz, H, D), jnp.float32)
        state = (zeros, zeros, jnp.full((Bsz, H, D), -1e30, jnp.float32), zeros)

    # Give the recurrent weights an explicit batch axis: scan-AD then
    # accumulates their gradient with the batch dim intact (batch-sharded,
    # local), and GSPMD reduces ONCE after the scan — instead of
    # all-reducing a replicated accumulator every timestep (measured: 99% of
    # xlstm train collective traffic; EXPERIMENTS §Perf).
    rb = {k: jnp.broadcast_to(w, (Bsz,) + w.shape) for k, w in r_weights.items()}

    def step(carry, inp):
        c, n, m, h_prev = carry
        z_t, i_t, f_t, o_t = inp  # (B, H, D)
        rec = lambda w: jnp.einsum("bhd,bhde->bhe", h_prev, w)
        z_t = z_t + rec(rb["rz"])
        i_t = i_t + rec(rb["ri"])
        f_t = f_t + rec(rb["rf"])
        o_t = o_t + rec(rb["ro"])
        h, (c, n, m) = slstm_step(z_t, i_t, f_t, o_t, (c, n, m))
        return (c, n, m, h.astype(jnp.float32)), h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (z, i_pre, f_pre, o_pre))
    L = z.shape[1]
    u = max(1, min(unroll, L)) if L % max(1, min(unroll, L)) == 0 else 1
    final, hs = jax.lax.scan(step, state, xs, unroll=u)
    return jnp.moveaxis(hs, 0, 1), final
