"""Unified model API: family dispatch, input specs, parameter/FLOPs accounting.

Every architecture exposes:
  init_params(cfg, key)                -> params pytree
  loss_fn(params, cfg, batch)          -> scalar (train objective)
  prefill_fn(params, cfg, batch)       -> logits (forward over full sequence)
  init_cache(cfg, batch, max_len)      -> decode cache (None for encoders)
  decode_fn(params, cfg, cache, batch) -> (logits, new_cache)
  input_specs(cfg, shape)              -> ShapeDtypeStruct pytree per mode
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import InputShape, ModelConfig
from . import dense, encoder, hybrid, moe, vlm, xlstm

__all__ = [
    "init_params",
    "loss_fn",
    "prefill_fn",
    "init_cache",
    "decode_fn",
    "input_specs",
    "make_dummy_batch",
    "param_count",
    "active_param_count",
    "model_flops_per_token",
    "supports_mode",
]


def init_params(cfg: ModelConfig, key):
    if cfg.family == "dense":
        return dense.init_dense(cfg, key)
    if cfg.family == "moe":
        return moe.init_moe_model(cfg, key)
    if cfg.family == "ssm":
        return xlstm.init_xlstm(cfg, key)
    if cfg.family == "hybrid":
        return hybrid.init_zamba(cfg, key)
    if cfg.family == "encoder":
        return encoder.init_hubert(cfg, key)
    if cfg.family == "vlm":
        return vlm.init_paligemma(cfg, key)
    raise ValueError(cfg.family)


def loss_fn(params, cfg: ModelConfig, batch):
    if cfg.family == "dense":
        return dense.dense_loss(params, cfg, batch)
    if cfg.family == "moe":
        return moe.moe_loss(params, cfg, batch)
    if cfg.family == "ssm":
        return xlstm.xlstm_loss(params, cfg, batch)
    if cfg.family == "hybrid":
        return hybrid.zamba_loss(params, cfg, batch)
    if cfg.family == "encoder":
        return encoder.hubert_loss(params, cfg, batch)
    if cfg.family == "vlm":
        return vlm.paligemma_loss(params, cfg, batch)
    raise ValueError(cfg.family)


def prefill_fn(params, cfg: ModelConfig, batch):
    """Forward over the full sequence (the `prefill` dry-run mode)."""
    if cfg.family == "dense":
        logits, _ = dense.dense_forward(params, cfg, batch["tokens"])
        return logits
    if cfg.family == "moe":
        logits, _aux, _c, _h = moe.moe_forward(params, cfg, batch["tokens"])
        return logits
    if cfg.family == "ssm":
        logits, _ = xlstm.xlstm_forward(params, cfg, batch["tokens"])
        return logits
    if cfg.family == "hybrid":
        logits, _ = hybrid.zamba_forward(params, cfg, batch["tokens"])
        return logits
    if cfg.family == "encoder":
        return encoder.hubert_forward(params, cfg, batch["frames"])
    if cfg.family == "vlm":
        logits, _ = vlm.paligemma_forward(params, cfg, batch["patches"], batch["tokens"])
        return logits
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "dense":
        return dense.init_dense_cache(cfg, batch, max_len)
    if cfg.family == "moe":
        return moe.init_moe_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return xlstm.init_xlstm_cache(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return hybrid.init_zamba_cache(cfg, batch, max_len)
    if cfg.family == "vlm":
        return vlm.init_paligemma_cache(cfg, batch, max_len)
    if cfg.family == "encoder":
        return None
    raise ValueError(cfg.family)


def decode_fn(params, cfg: ModelConfig, cache, tokens, pos):
    if cfg.family == "dense":
        return dense.dense_decode_step(params, cfg, cache, tokens, pos)
    if cfg.family == "moe":
        return moe.moe_decode_step(params, cfg, cache, tokens, pos)
    if cfg.family == "ssm":
        return xlstm.xlstm_decode_step(params, cfg, cache, tokens, pos)
    if cfg.family == "hybrid":
        return hybrid.zamba_decode_step(params, cfg, cache, tokens, pos)
    if cfg.family == "vlm":
        return vlm.paligemma_decode_step(params, cfg, cache, tokens, pos)
    raise ValueError(f"{cfg.family} has no decode step")


def supports_mode(cfg: ModelConfig, shape: InputShape) -> tuple:
    """(supported, reason) — documented skips per DESIGN.md §4."""
    if cfg.family == "encoder" and shape.mode == "decode":
        return False, "encoder-only: no autoregressive decode"
    if shape.name == "long_500k":
        sub_quadratic = cfg.family in ("ssm", "hybrid") or (
            cfg.attn_kind == "local_global"
        )
        if not sub_quadratic:
            return False, "full-attention arch: 500k context skipped (quadratic)"
    return True, ""


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _batch_struct(cfg: ModelConfig, B: int, S: int, mode: str) -> Dict[str, Any]:
    i32 = jnp.int32
    if cfg.family == "encoder":
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.frame_dim), cfg.cdtype()),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if cfg.family == "vlm":
        S_txt = max(S - cfg.num_patches, 16)
        extra = 1 if mode == "train" else 0
        return {
            "patches": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.patch_dim), cfg.cdtype()),
            "tokens": jax.ShapeDtypeStruct((B, S_txt + extra), i32),
        }
    extra = 1 if mode == "train" else 0
    if cfg.use_mtp and mode == "train":
        extra = 2
    return {"tokens": jax.ShapeDtypeStruct((B, S + extra), i32)}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Dry-run stand-ins for one (arch, input-shape) pair.

    train/prefill: the batch pytree. decode: (cache, tokens, pos).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.mode in ("train", "prefill"):
        return {"batch": _batch_struct(cfg, B, S, shape.mode)}
    cache = init_cache(cfg, B, S)  # concrete zeros; converted by caller if needed
    cache_specs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
    return {
        "cache": cache_specs,
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_dummy_batch(cfg: ModelConfig, B: int, S: int, mode: str, rng: np.random.Generator):
    """Concrete random batch for smoke tests."""
    if cfg.family == "encoder":
        return {
            "frames": jnp.asarray(rng.normal(size=(B, S, cfg.frame_dim)).astype(np.float32)),
            "mask": jnp.asarray(rng.random((B, S)) < 0.3),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)),
        }
    if cfg.family == "vlm":
        S_txt = max(S - cfg.num_patches, 16)
        extra = 1 if mode == "train" else 0
        return {
            "patches": jnp.asarray(rng.normal(size=(B, cfg.num_patches, cfg.patch_dim)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_txt + extra)).astype(np.int32)),
        }
    extra = 1 if mode == "train" else 0
    if cfg.use_mtp and mode == "train":
        extra = 2
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + extra)).astype(np.int32))}


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------


def param_count(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


def expert_param_count(params) -> int:
    total = 0

    def visit(path, leaf):
        nonlocal total
        if "experts" in path:
            total += int(np.prod(leaf.shape))

    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        visit("/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path), leaf)
    return total


def active_param_count(params, cfg: ModelConfig) -> int:
    """Active params per token: routed experts count at (top_k / E)."""
    total = param_count(params)
    if cfg.num_experts:
        ep = expert_param_count(params)
        total = total - ep + int(ep * cfg.top_k / cfg.num_experts)
    return total


def model_flops_per_token(params, cfg: ModelConfig, seq_len: int, mode: str = "train") -> float:
    """MODEL_FLOPS (paper-style 6·N·D accounting) per token.

    6·N_active per token for train (fwd+bwd), 2·N_active for inference,
    plus the quadratic attention term 12·L·d·S (train) / 4·L·d·S (inference)
    for attention architectures (0 for pure SSM).
    """
    n_active = active_param_count(params, cfg)
    mult = 6.0 if mode == "train" else 2.0
    flops = mult * n_active
    if cfg.family in ("dense", "moe", "vlm", "encoder"):
        attn_mult = 12.0 if mode == "train" else 4.0
        flops += attn_mult * cfg.num_layers * cfg.hd * cfg.num_heads * min(seq_len, 10**9) / 2
    return float(flops)
