"""PaliGemma-style VLM (arXiv:2407.07726).

The SigLIP vision tower is STUBBED per the task spec: inputs are precomputed
patch embeddings ``(B, num_patches, patch_dim)``. This module implements the
multimodal projector + gemma-style text decoder with PaliGemma's prefix-LM
masking (bidirectional over image+prefix tokens, causal over the suffix).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.sharding import shard
from .dense import (
    _embed,
    _logits,
    cross_entropy,
    dense_decode_step,
    init_dense,
    init_dense_cache,
    stack_forward,
)
from .layers import dense_init

__all__ = [
    "init_paligemma",
    "paligemma_forward",
    "paligemma_loss",
    "init_paligemma_cache",
    "paligemma_decode_step",
]


def init_paligemma(cfg: ModelConfig, key):
    k_text, k_proj = jax.random.split(key)
    params = init_dense(cfg, k_text)
    params["patch_proj"] = dense_init(k_proj, (cfg.patch_dim, cfg.d_model), dtype=cfg.pdtype())
    return params


def _fuse(params, cfg, patches, tokens):
    img = jnp.einsum("bpf,fd->bpd", patches.astype(cfg.cdtype()), params["patch_proj"])
    if cfg.scale_embedding:
        img = img * jnp.asarray(cfg.d_model ** 0.5, img.dtype)
    txt = _embed(cfg, params, tokens)
    return shard(jnp.concatenate([img, txt], axis=1), "batch", None, None)


def paligemma_forward(params, cfg: ModelConfig, patches, tokens, *, collect_cache=False):
    """patches (B, P, patch_dim); tokens (B, St). Prefix = image patches (+
    any prompt handled by caller via loss masking). Returns logits over the
    TEXT positions only."""
    h = _fuse(params, cfg, patches, tokens)
    P = patches.shape[1]
    prefix = jnp.asarray(P, jnp.int32)
    h, caches = stack_forward(cfg, params["layers"], h, prefix_len=prefix, collect_cache=collect_cache)
    logits = _logits(cfg, params, h[:, P:, :])
    return logits, caches


def paligemma_loss(params, cfg: ModelConfig, batch):
    """batch: {patches (B,P,F), tokens (B,St+1)}."""
    tokens = batch["tokens"]
    logits, _ = paligemma_forward(params, cfg, batch["patches"], tokens[:, :-1])
    return cross_entropy(logits, tokens[:, 1:])


def init_paligemma_cache(cfg: ModelConfig, batch: int, max_len: int):
    return init_dense_cache(cfg, batch, max_len)


def paligemma_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """Standard causal decode over the (image+text) cache."""
    return dense_decode_step(params, cfg, cache, tokens, pos)
