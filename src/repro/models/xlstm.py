"""xLSTM LM (arXiv:2405.04517): mLSTM blocks with one sLSTM block every
``cfg.slstm_every`` layers (7:1 ratio for xlstm-1.3b).

Simplifications vs the reference implementation (documented in DESIGN.md §4):
qk head dim = inner/(2H) (qk_dim_factor 0.5), gates are projections of the
(pre-conv) up-projected stream, sLSTM blocks have no post-FFN. The cell
math (exp-gated matrix memory with max-stabilizer; chunkwise == sequential)
is property-tested in tests/test_ssm_cells.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.sharding import shard
from .dense import _embed, _logits, _maybe_remat, cross_entropy
from .layers import dense_init, rms_norm
from .ssm import (
    causal_conv1d,
    causal_conv1d_step,
    mlstm_chunked,
    mlstm_step,
    slstm_scan,
    slstm_step,
)

__all__ = [
    "init_xlstm",
    "xlstm_forward",
    "xlstm_loss",
    "init_xlstm_cache",
    "xlstm_decode_step",
]


def _dims(cfg: ModelConfig):
    inner = cfg.ssm_expand * cfg.d_model
    H = cfg.num_heads
    DV = inner // H
    DK = max(DV // 2, 1)
    return inner, H, DK, DV


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_mlstm_block(cfg: ModelConfig, key):
    d = cfg.d_model
    inner, H, DK, DV = _dims(cfg)
    ks = jax.random.split(key, 8)
    pd = cfg.pdtype()
    return {
        "ln": jnp.zeros((d,), pd),
        "w_up": dense_init(ks[0], (d, 2 * inner), dtype=pd),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, inner), fan_in=cfg.ssm_conv, dtype=pd),
        # block-diagonal (head-wise) projections, as in the reference impl
        "wq_m": dense_init(ks[2], (H, DV, DK), fan_in=DV, dtype=pd),
        "wk_m": dense_init(ks[3], (H, DV, DK), fan_in=DV, dtype=pd),
        "wv_m": dense_init(ks[4], (H, DV, DV), fan_in=DV, dtype=pd),
        "wi_gate": dense_init(ks[5], (inner, H), dtype=pd),
        "wf_gate": dense_init(ks[6], (inner, H), dtype=pd),
        "f_bias": jnp.full((H,), 3.0, pd),  # open forget gates at init
        "gn": jnp.zeros((H, DV), pd),
        "out_proj": dense_init(ks[7], (inner, d), fan_in=inner, dtype=pd),
    }


def _init_slstm_block(cfg: ModelConfig, key):
    d = cfg.d_model
    H = cfg.num_heads
    D = d // H
    ks = jax.random.split(key, 6)
    pd = cfg.pdtype()
    return {
        "ln": jnp.zeros((d,), pd),
        "w_zifo": dense_init(ks[0], (d, 4, H * D), fan_in=d, dtype=pd),
        "rz": dense_init(ks[1], (H, D, D), fan_in=D, dtype=pd, scale=0.3),
        "ri": dense_init(ks[2], (H, D, D), fan_in=D, dtype=pd, scale=0.3),
        "rf": dense_init(ks[3], (H, D, D), fan_in=D, dtype=pd, scale=0.3),
        "ro": dense_init(ks[4], (H, D, D), fan_in=D, dtype=pd, scale=0.3),
        "f_bias": jnp.full((H * D,), 3.0, pd),
        "gn": jnp.zeros((H, D), pd),
        "out_proj": dense_init(ks[5], (d, d), dtype=pd),
    }


def init_xlstm(cfg: ModelConfig, key):
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    pd = cfg.pdtype()
    period = cfg.slstm_every  # group = (period-1) mLSTM + 1 sLSTM
    n_groups = cfg.num_layers // period
    gkeys = jax.random.split(k_blocks, n_groups)

    def init_group(gk):
        mk = jax.random.split(gk, period)
        mlstm = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[_init_mlstm_block(cfg, k) for k in mk[:-1]]
        )
        return {"mlstm": mlstm, "slstm": _init_slstm_block(cfg, mk[-1])}

    groups = [init_group(k) for k in gkeys]
    return {
        "emb": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), fan_in=cfg.d_model, dtype=pd),
        "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        "ln_f": jnp.zeros((cfg.d_model,), pd),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=pd),
    }


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------


def _mlstm_block(cfg, p, h, state=None, step=False):
    """state: (conv_state (B,K-1,inner), (S,n,m)). Returns (h, new_state)."""
    inner, H, DK, DV = _dims(cfg)
    x = rms_norm(h, p["ln"])
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xm, z = jnp.split(up, 2, axis=-1)
    xm = shard(xm, "batch", None, "tensor")
    conv_state = state[0] if state is not None else None
    if step:
        xc, conv_state = causal_conv1d_step(xm, p["conv_w"], conv_state)
    else:
        xc, conv_state = causal_conv1d(xm, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    B, S = x.shape[0], x.shape[1]
    xc_h = xc.reshape(B, S, H, DV)  # per-head input stream (DV == inner/H)
    xm_h = xm.reshape(B, S, H, DV)
    q = jnp.einsum("bshp,hpk->bshk", xc_h, p["wq_m"])
    k = jnp.einsum("bshp,hpk->bshk", xc_h, p["wk_m"])
    v = jnp.einsum("bshp,hpk->bshk", xm_h, p["wv_m"])
    i_pre = jnp.einsum("bse,eh->bsh", xm, p["wi_gate"])
    f_pre = jnp.einsum("bse,eh->bsh", xm, p["wf_gate"]) + p["f_bias"].astype(jnp.float32)

    cell_state = state[1] if state is not None else None
    if step:
        y, cell_state = mlstm_step(
            q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0], cell_state
        )
        y = y[:, None]
    else:
        y, cell_state = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=min(cfg.chunk_size, S), state=cell_state)
    # per-head groupnorm + gate
    y = rms_norm(y, p["gn"])  # (B,S,H,DV) normalized over DV
    y = y.reshape(B, S, inner) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return h + out, (conv_state, cell_state)


def _slstm_block(cfg, p, h, state=None, step=False):
    d = cfg.d_model
    H = cfg.num_heads
    D = d // H
    x = rms_norm(h, p["ln"])
    B, S = x.shape[0], x.shape[1]
    zifo = jnp.einsum("bsd,dge->bsge", x, p["w_zifo"])  # (B,S,4,H*D)
    zifo = zifo.at[:, :, 2, :].add(p["f_bias"].astype(zifo.dtype))
    zifo = zifo.reshape(B, S, 4, H, D)
    z, i_pre, f_pre, o_pre = (zifo[:, :, g] for g in range(4))
    r = {k: p[k] for k in ("rz", "ri", "rf", "ro")}
    if step:
        c, n, m, h_prev = state
        rec = lambda w: jnp.einsum("bhd,hde->bhe", h_prev, w)
        y, (c, n, m) = slstm_step(
            z[:, 0] + rec(r["rz"]), i_pre[:, 0] + rec(r["ri"]),
            f_pre[:, 0] + rec(r["rf"]), o_pre[:, 0] + rec(r["ro"]), (c, n, m),
        )
        new_state = (c, n, m, y.astype(jnp.float32))
        y = y[:, None]
    else:
        y, new_state = slstm_scan(z, i_pre, f_pre, o_pre, r, state)
    y = rms_norm(y.astype(h.dtype), p["gn"])  # recurrent path promotes to f32
    y = y.reshape(B, S, d)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return h + out, new_state


def _empty_group_state(cfg, B):
    inner, H, DK, DV = _dims(cfg)
    D = cfg.d_model // H
    period = cfg.slstm_every
    f32 = jnp.float32
    m_state = (
        jnp.zeros((period - 1, B, cfg.ssm_conv - 1, inner), cfg.cdtype()),
        (
            jnp.zeros((period - 1, B, H, DK, DV), f32),
            jnp.zeros((period - 1, B, H, DK), f32),
            jnp.full((period - 1, B, H), -1e30, f32),
        ),
    )
    s_state = (
        jnp.zeros((B, H, D), f32),
        jnp.zeros((B, H, D), f32),
        jnp.full((B, H, D), -1e30, f32),
        jnp.zeros((B, H, D), f32),
    )
    return {"mlstm": m_state, "slstm": s_state}


def init_xlstm_cache(cfg: ModelConfig, batch: int, max_len: int = 0):
    period = cfg.slstm_every
    n_groups = cfg.num_layers // period
    one = _empty_group_state(cfg, batch)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), one)


def _group_apply(cfg, gp, h, gstate=None, step=False):
    """One (period-1 mLSTM + 1 sLSTM) group. gstate from init_xlstm_cache."""

    def m_body(hh, inp):
        lp, lstate = inp
        hh, new_state = _mlstm_block(cfg, lp, hh, lstate, step=step)
        return hh, new_state

    if gstate is None:
        period = cfg.slstm_every
        B = h.shape[0]
        gstate = _empty_group_state(cfg, B)
    m_states = (gstate["mlstm"][0], gstate["mlstm"][1])
    h, new_m = jax.lax.scan(m_body, h, (gp["mlstm"], m_states))
    h, new_s = _slstm_block(cfg, gp["slstm"], h, gstate["slstm"], step=step)
    return shard(h, "batch", "act_seq", None), {"mlstm": new_m, "slstm": new_s}


def xlstm_forward(params, cfg: ModelConfig, tokens, *, state=None, collect_state=False):
    h = _embed(cfg, params, tokens)

    def body(hh, inp):
        gp, gs = inp
        hh, new_gs = _group_apply(cfg, gp, hh, gs, step=False)
        return hh, new_gs if collect_state else None

    if state is None:
        state = init_xlstm_cache(cfg, tokens.shape[0])
    h, new_state = jax.lax.scan(_maybe_remat(cfg, body), h, (params["groups"], state))
    return _logits(cfg, params, h), new_state


def xlstm_loss(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    logits, _ = xlstm_forward(params, cfg, tokens[:, :-1])
    return cross_entropy(logits, tokens[:, 1:])


def xlstm_decode_step(params, cfg: ModelConfig, state, tokens, pos=None):
    h = _embed(cfg, params, tokens)

    def body(hh, inp):
        gp, gs = inp
        hh, new_gs = _group_apply(cfg, gp, hh, gs, step=True)
        return hh, new_gs

    h, new_state = jax.lax.scan(body, h, (params["groups"], state))
    return _logits(cfg, params, h), new_state
