"""Mixture-of-Experts decoder LMs.

Covers:
  * olmoe-1b-7b — uniform stack: GQA attention + 64-expert top-8 MoE FFN.
  * deepseek-v3-671b — MLA attention, 3 dense-FFN prefix layers, 58 MoE
    layers (1 shared + 256 routed top-8), optional MTP head.

Layer stacks are scanned; router aux losses are accumulated through the scan
and added to the LM loss with ``cfg.router_aux_weight``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.sharding import shard
from .dense import (
    _embed,
    _init_layer,
    _logits,
    _maybe_remat,
    _mlp,
    cross_entropy,
    layer_apply,
)
from .layers import apply_rope, attention, dense_init, make_rope, rms_norm
from .mla import init_mla, init_mla_cache, mla_decode_step, mla_forward
from .moe_dispatch import moe_ffn

__all__ = [
    "init_moe_model",
    "moe_forward",
    "moe_loss",
    "init_moe_cache",
    "moe_decode_step",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_moe_ffn(cfg: ModelConfig, key):
    d, E, fe = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 7)
    pd = cfg.pdtype()
    p = {
        "router": dense_init(ks[0], (d, E), dtype=pd),
        "experts": {
            "w_gate": dense_init(ks[1], (E, d, fe), fan_in=d, dtype=pd),
            "w_in": dense_init(ks[2], (E, d, fe), fan_in=d, dtype=pd),
            "w_out": dense_init(ks[3], (E, fe, d), fan_in=fe, dtype=pd),
        },
    }
    if cfg.num_shared_experts:
        fs = fe * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(ks[4], (d, fs), dtype=pd),
            "w_in": dense_init(ks[5], (d, fs), dtype=pd),
            "w_out": dense_init(ks[6], (fs, d), fan_in=fs, dtype=pd),
        }
    return p


def _init_moe_layer(cfg: ModelConfig, key):
    k_attn, k_moe = jax.random.split(key)
    d = cfg.d_model
    pd = cfg.pdtype()
    p = {"ln1": jnp.zeros((d,), pd), "ln2": jnp.zeros((d,), pd), "moe": _init_moe_ffn(cfg, k_moe)}
    if cfg.use_mla:
        p["attn_mla"] = init_mla(cfg, k_attn)
    else:
        H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        ks = jax.random.split(k_attn, 4)
        p["attn"] = {
            "wq": dense_init(ks[0], (d, H, hd), fan_in=d, dtype=pd),
            "wk": dense_init(ks[1], (d, Hkv, hd), fan_in=d, dtype=pd),
            "wv": dense_init(ks[2], (d, Hkv, hd), fan_in=d, dtype=pd),
            "wo": dense_init(ks[3], (H, hd, d), fan_in=H * hd, dtype=pd),
        }
    return p


def _stack(init_one, cfg, keys):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[init_one(cfg, k) for k in keys])


def init_moe_model(cfg: ModelConfig, key):
    k_emb, k_dense, k_moe, k_head, k_mtp = jax.random.split(key, 5)
    pd = cfg.pdtype()
    n_moe = cfg.num_layers - cfg.dense_prefix_layers
    params = {
        "emb": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), fan_in=cfg.d_model, dtype=pd),
        "moe_layers": _stack(_init_moe_layer, cfg, jax.random.split(k_moe, n_moe)),
        "ln_f": jnp.zeros((cfg.d_model,), pd),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=pd),
    }
    if cfg.dense_prefix_layers:
        dense_cfg = cfg  # same dims; plain gated-silu FFN with d_ff
        params["dense_layers"] = _stack(_init_layer, dense_cfg, jax.random.split(k_dense, cfg.dense_prefix_layers))
    if cfg.use_mtp:
        km = jax.random.split(k_mtp, 3)
        params["mtp"] = {
            "ln_in": jnp.zeros((2 * cfg.d_model,), pd),
            "proj": dense_init(km[0], (2 * cfg.d_model, cfg.d_model), dtype=pd),
            "layer": _init_layer(cfg, km[1]),
        }
    return params


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------


def _moe_attention(cfg, p, h, *, q_pos, kv_pos, rope, cache=None, write_pos=None):
    """Returns (attn_out, new_cache)."""
    if cfg.use_mla:
        a_in = h
        if cache is not None and write_pos is not None:
            return mla_decode_step(cfg, p["attn_mla"], a_in, cache, write_pos)
        y, c = mla_forward(cfg, p["attn_mla"], a_in, q_pos=q_pos, collect_cache=cache == "collect")
        return y, c
    sin, cos = rope
    from ..launch import sharding as shd

    kv_spec = "tensor" if cfg.num_kv_heads % max(shd.axis_size("tensor"), 1) == 0 else None
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
    q = shard(apply_rope(q, sin, cos), "batch", None, "tensor", None)
    k = shard(apply_rope(k, sin, cos), "batch", None, kv_spec, None)
    v = shard(v, "batch", None, kv_spec, None)
    if cache is not None and write_pos is not None:
        kc, vc = cache
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), write_pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), write_pos, axis=1)
        out = attention(q, kc, vc, q_pos=q_pos, kv_pos=kv_pos, kind="causal")
        new_cache = (kc, vc)
    else:
        out = attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, kind="causal", block_q=cfg.attn_block_q, impl=cfg.attn_impl)
        new_cache = (k, v) if cache == "collect" else None
    # head-parallel -> sequence-parallel handoff (see dense.layer_apply)
    out = shard(out, "batch", "act_seq", None, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"]), new_cache


def moe_layer_apply(cfg, p, h, *, q_pos, kv_pos, rope, cache=None, write_pos=None):
    attn_out, new_cache = _moe_attention(
        cfg, p, rms_norm(h, p["ln1"]), q_pos=q_pos, kv_pos=kv_pos, rope=rope,
        cache=cache, write_pos=write_pos,
    )
    h = h + attn_out
    y, aux = moe_ffn(cfg, p["moe"], rms_norm(h, p["ln2"]))
    h = h + y
    return shard(h, "batch", "act_seq", None), new_cache, aux


# ---------------------------------------------------------------------------
# forward / loss / decode
# ---------------------------------------------------------------------------


def moe_forward(params, cfg: ModelConfig, tokens, *, collect_cache=False):
    """Returns (logits, aux_mean, caches, h_final)."""
    h = _embed(cfg, params, tokens)
    S = h.shape[1]
    pos = jnp.arange(S)
    rope = make_rope(pos, cfg.hd, cfg.rope_base)
    caches = {}

    if cfg.dense_prefix_layers:
        def dense_body(hh, lp):
            hh, kv = layer_apply(cfg, lp, hh, "causal", rope, q_pos=pos, kv_pos=pos)
            return hh, kv if collect_cache else None

        h, dense_kv = jax.lax.scan(_maybe_remat(cfg, dense_body), h, params["dense_layers"])
        caches["dense"] = dense_kv

    def moe_body(hh, lp):
        hh, c, aux = moe_layer_apply(
            cfg, lp, hh, q_pos=pos, kv_pos=pos, rope=rope,
            cache="collect" if collect_cache else None,
        )
        return hh, (c, aux) if collect_cache else (None, aux)

    h, (moe_c, auxes) = jax.lax.scan(_maybe_remat(cfg, moe_body), h, params["moe_layers"])
    caches["moe"] = moe_c
    logits = _logits(cfg, params, h)
    return logits, auxes.mean(), caches if collect_cache else None, h


def moe_loss(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]  # (B, S+1) (+2 if MTP wants an extra shift)
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    if cfg.use_mtp:
        inp = tokens[:, :-2]
        tgt = tokens[:, 1:-1]
    logits, aux, _, h = moe_forward(params, cfg, inp)
    loss = cross_entropy(logits, tgt) + cfg.router_aux_weight * aux
    if cfg.use_mtp:
        # MTP depth-1 (DeepSeek-V3 §2.2): combine final hidden with the
        # embedding of the NEXT token, run one extra layer, predict t+2.
        nxt_emb = _embed(cfg, params, tokens[:, 1:-1])
        h_in = jnp.concatenate([rms_norm(h, params["ln_f"]), nxt_emb], axis=-1)
        h_in = rms_norm(h_in, params["mtp"]["ln_in"])
        h2 = jnp.einsum("bsd,de->bse", h_in, params["mtp"]["proj"])
        S = h2.shape[1]
        pos = jnp.arange(S)
        rope = make_rope(pos, cfg.hd, cfg.rope_base)
        h2, _ = layer_apply(cfg, params["mtp"]["layer"], h2, "causal", rope, q_pos=pos, kv_pos=pos)
        mtp_logits = _logits(cfg, params, h2)
        loss = loss + cfg.mtp_weight * cross_entropy(mtp_logits, tokens[:, 2:])
    return loss


def init_moe_cache(cfg: ModelConfig, batch: int, max_len: int):
    caches = {}
    n_moe = cfg.num_layers - cfg.dense_prefix_layers
    if cfg.dense_prefix_layers:
        shape = (cfg.dense_prefix_layers, batch, max_len, cfg.num_kv_heads, cfg.hd)
        caches["dense"] = (jnp.zeros(shape, cfg.cdtype()), jnp.zeros(shape, cfg.cdtype()))
    if cfg.use_mla:
        caches["moe"] = init_mla_cache(cfg, batch, max_len, (n_moe,))
    else:
        shape = (n_moe, batch, max_len, cfg.num_kv_heads, cfg.hd)
        caches["moe"] = (jnp.zeros(shape, cfg.cdtype()), jnp.zeros(shape, cfg.cdtype()))
    return caches


def moe_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    h = _embed(cfg, params, tokens)
    S_max = jax.tree.leaves(cache["moe"])[0].shape[2]
    q_pos = pos[None]
    kv_pos = jnp.arange(S_max)
    rope = make_rope(q_pos, cfg.hd, cfg.rope_base)
    new_cache = {}

    if cfg.dense_prefix_layers:
        def dense_body(hh, inp):
            lp, c = inp
            hh, kv = layer_apply(
                cfg, lp, hh, "causal", rope, q_pos=q_pos, kv_pos=kv_pos,
                cache_kv=c, write_pos=pos,
            )
            return hh, kv

        h, new_cache["dense"] = jax.lax.scan(dense_body, h, (params["dense_layers"], cache["dense"]))

    def moe_body(hh, inp):
        lp, c = inp
        hh, c_new, _aux = moe_layer_apply(
            cfg, lp, hh, q_pos=q_pos, kv_pos=kv_pos, rope=rope, cache=c, write_pos=pos
        )
        return hh, c_new

    h, new_cache["moe"] = jax.lax.scan(moe_body, h, (params["moe_layers"], cache["moe"]))
    return _logits(cfg, params, h), new_cache
