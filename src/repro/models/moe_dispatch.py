"""Mixture-of-Experts routing + three dispatch implementations.

  * ``dense``  — every expert on every token, combine by gate (reference /
                 smoke-test oracle; O(T·E) FLOPs, exact when nothing drops).
  * ``einsum`` — Mesh-TF-style one-hot capacity dispatch. Exact up to
                 capacity drops; efficient for SMALL token counts (decode).
  * ``a2a``    — shard_map expert parallelism: tokens sharded over all mesh
                 axes, experts sharded over ``expert`` axes; two sorts +
                 ``all_to_all`` exchange + per-expert padded GEMMs. The
                 train/prefill path (see DESIGN.md §5).

All paths share the router: softmax -> top-k -> renormalize, plus the
switch-style load-balance auxiliary loss.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..launch.sharding import current_mesh, logical_to_mesh, rules, shard

__all__ = ["route", "moe_ffn"]


def _shard_map(body, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map where available; falls back to the pre-0.5 experimental
    API (whose replication-check kwarg is ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def _act(cfg):
    return jax.nn.silu


def route(cfg: ModelConfig, x2d: jnp.ndarray, router_w: jnp.ndarray):
    """x2d (T, d) -> (gate_w (T, k), gate_idx (T, k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    # load-balance aux: E * sum_e f_e * P_e
    E = cfg.num_experts
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (T, k, E)
    f_e = onehot.mean(axis=(0, 1))  # fraction routed (per slot-averaged)
    P_e = probs.mean(axis=0)
    aux = E * jnp.sum(f_e * P_e)
    return gate_w, gate_idx, aux


def _expert_mlp(experts, xs, act):
    """xs (..., C, d) grouped per expert on leading E axis of `experts`."""
    h = act(jnp.einsum("ecd,edf->ecf", xs, experts["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xs, experts["w_in"]
    )
    return jnp.einsum("ecf,efd->ecd", h, experts["w_out"])


# ---------------------------------------------------------------------------
# dense reference
# ---------------------------------------------------------------------------


def _moe_dense(cfg, x2d, experts, gate_w, gate_idx):
    act = _act(cfg)
    h = act(jnp.einsum("td,edf->etf", x2d, experts["w_gate"])) * jnp.einsum(
        "td,edf->etf", x2d, experts["w_in"]
    )
    y_all = jnp.einsum("etf,efd->etd", h, experts["w_out"])  # (E, T, d)
    onehot = jax.nn.one_hot(gate_idx, cfg.num_experts, dtype=x2d.dtype)  # (T,k,E)
    w = (gate_w.astype(x2d.dtype)[..., None] * onehot).sum(1)  # (T, E)
    return jnp.einsum("te,etd->td", w, y_all)


# ---------------------------------------------------------------------------
# einsum one-hot capacity dispatch (small T)
# ---------------------------------------------------------------------------


def _moe_einsum(cfg, x2d, experts, gate_w, gate_idx, capacity: int):
    T, d = x2d.shape
    E, k = cfg.num_experts, cfg.top_k
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (T, k, E)
    # position of each (t, slot) within its expert, counted t-major
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat  # (T*k, E) position if routed
    pos = (pos * flat).sum(-1).reshape(T, k)  # (T, k)
    keep = pos < capacity
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity, dtype=jnp.float32)
    dispatch = jnp.einsum("tke,tkc->tec", onehot, pos_oh)  # (T, E, C) 0/1
    combine = jnp.einsum("tk,tke,tkc->tec", gate_w.astype(jnp.float32), onehot, pos_oh)
    xs = jnp.einsum("tec,td->ecd", dispatch, x2d.astype(jnp.float32)).astype(x2d.dtype)
    ys = _expert_mlp(experts, xs, _act(cfg))  # (E, C, d)
    y = jnp.einsum("tec,ecd->td", combine, ys.astype(jnp.float32))
    return y.astype(x2d.dtype)


# ---------------------------------------------------------------------------
# all-to-all expert parallelism (shard_map)
# ---------------------------------------------------------------------------


def _sort_group(ids, num_groups, capacity, *payloads):
    """Groups rows by ``ids`` into (num_groups, capacity, ...) padded buffers.

    Returns (bufs..., meta) where meta lets :func:`_ungroup` scatter results
    back to the original row order. Rows beyond capacity are dropped.
    """
    N = ids.shape[0]
    order = jnp.argsort(ids)  # stable
    sorted_ids = ids[order]
    start = jnp.searchsorted(sorted_ids, jnp.arange(num_groups), side="left")
    pos_in_group = jnp.arange(N) - start[sorted_ids]
    valid = pos_in_group < capacity
    dest = jnp.where(valid, sorted_ids * capacity + pos_in_group, num_groups * capacity)
    bufs = []
    for pl in payloads:
        flat = jnp.zeros((num_groups * capacity,) + pl.shape[1:], pl.dtype)
        bufs.append(flat.at[dest].set(pl[order], mode="drop").reshape((num_groups, capacity) + pl.shape[1:]))
    meta = (order, dest, valid)
    return bufs, meta


def _ungroup(buf, meta, N):
    """Inverse of _sort_group for one payload: (G, C, ...) -> (N, ...)."""
    order, dest, valid = meta
    flat = buf.reshape((-1,) + buf.shape[2:])
    gathered = jnp.where(
        valid.reshape((-1,) + (1,) * (flat.ndim - 1)),
        flat[jnp.minimum(dest, flat.shape[0] - 1)],
        0,
    )
    inv = jnp.argsort(order)
    return gathered[inv]


def _a2a_local(x, gate_w, gate_idx, experts, *, cfg, ep_axes, n_peers, e_local,
               cap_send, cap_expert):
    """Per-device body under shard_map.

    x (Tl, d); gate_w/idx (Tl, k); experts leaves with leading E_local axis.
    """
    Tl, d = x.shape
    k = cfg.top_k
    flat_ids = gate_idx.reshape(-1)  # (Tl*k,) global expert ids
    flat_x = jnp.repeat(x, k, axis=0)  # (Tl*k, d) token copies
    dest_peer = flat_ids // e_local
    local_eid = flat_ids % e_local

    (send_x, send_eid), meta_send = _sort_group(
        dest_peer, n_peers, cap_send, flat_x, local_eid.astype(jnp.int32)
    )
    # exchange: recv[p] = what peer p sent to me (dim0 == n_peers, so each
    # peer receives one (cap_send, d) block per sender)
    a2a_ax = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    recv_x = jax.lax.all_to_all(send_x, a2a_ax, 0, 0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid, a2a_ax, 0, 0, tiled=True)
    # per-slot validity travels implicitly: invalid slots carry eid pointing
    # at a zero row (x == 0), harmless after the expert MLP and combine.
    flat_recv_x = recv_x.reshape(-1, d)
    flat_recv_eid = recv_eid.reshape(-1)

    (grp_x,), meta_grp = _sort_group(flat_recv_eid, e_local, cap_expert, flat_recv_x)
    grp_y = _expert_mlp(experts, grp_x, _act(cfg))  # (e_local, cap_expert, d)
    flat_y = _ungroup(grp_y, meta_grp, flat_recv_eid.shape[0])
    back = flat_y.reshape(n_peers, cap_send, d)
    ret = jax.lax.all_to_all(back, a2a_ax, 0, 0, tiled=True)
    flat_ret = _ungroup(ret, meta_send, flat_ids.shape[0])  # (Tl*k, d)
    y = (flat_ret.reshape(Tl, k, d).astype(jnp.float32) * gate_w[..., None]).sum(1)
    return y.astype(x.dtype)


def _moe_a2a(cfg, x2d, experts, gate_w, gate_idx):
    mesh = current_mesh()
    assert mesh is not None, "a2a MoE requires an active mesh"
    r = rules()
    token_axes = tuple(mesh.axis_names)  # shard tokens over everything
    ep_axes = r["expert"]
    ep_axes = (ep_axes,) if isinstance(ep_axes, str) else tuple(ep_axes)
    n_peers = 1
    for a in ep_axes:
        n_peers *= int(mesh.shape[a])
    e_local = cfg.num_experts // n_peers
    T = x2d.shape[0]
    n_tok_shards = 1
    for a in token_axes:
        n_tok_shards *= mesh.shape[a]
    Tl = T // n_tok_shards
    cap_send = max(8, int(-(-Tl * cfg.top_k * cfg.capacity_factor // n_peers) // 8 * 8 + 8))
    cap_expert = max(8, int(-(-n_peers * cap_send * cfg.capacity_factor // e_local) // 8 * 8 + 8))

    body = functools.partial(
        _a2a_local, cfg=cfg, ep_axes=ep_axes, n_peers=n_peers, e_local=e_local,
        cap_send=cap_send, cap_expert=cap_expert,
    )
    expert_specs = jax.tree.map(lambda _: P(ep_axes if len(ep_axes) > 1 else ep_axes[0]), experts)
    y = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(token_axes), P(token_axes), P(token_axes), expert_specs),
        out_specs=P(token_axes),
        check_vma=False,
    )(x2d, gate_w, gate_idx, experts)
    return y


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def moe_ffn(cfg: ModelConfig, p, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """p: {router (d,E), experts {w_gate,w_in,w_out} (E,...) [, shared {...}]}.

    x (B, S, d) -> (y (B, S, d), aux_loss).
    """
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    gate_w, gate_idx, aux = route(cfg, x2d, p["router"])
    gate_w = gate_w.astype(jnp.float32)

    impl = cfg.moe_impl
    if impl == "a2a" and current_mesh() is None:
        impl = "dense"
    if impl == "dense":
        y = _moe_dense(cfg, x2d, p["experts"], gate_w, gate_idx)
    elif impl == "einsum":
        cap = max(8, int(B * S * cfg.top_k * cfg.capacity_factor / cfg.num_experts) + 8)
        y = _moe_einsum(cfg, x2d, p["experts"], gate_w, gate_idx, cap)
    elif impl == "a2a":
        y = _moe_a2a(cfg, x2d, p["experts"], gate_w, gate_idx)
    else:
        raise ValueError(cfg.moe_impl)

    if "shared" in p:  # deepseek-style always-on shared expert(s)
        sh = p["shared"]
        h = jax.nn.silu(jnp.einsum("td,df->tf", x2d, sh["w_gate"])) * jnp.einsum(
            "td,df->tf", x2d, sh["w_in"]
        )
        y = y + jnp.einsum("tf,fd->td", h, sh["w_out"])
    return y.reshape(B, S, d), aux
