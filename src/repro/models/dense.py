"""Dense decoder LMs (llama-family): deepseek-7b, granite-20b (MQA),
minitron-8b (squared-ReLU), gemma2-2b (local/global alternation, softcaps,
post-norms), and the text backbone reused by paligemma.

Scan-over-layers with a static per-period block *pattern* (period 1 for
uniform stacks, 2 for gemma2's sliding/global alternation) keeps the HLO one
layer deep regardless of depth.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.sharding import shard
from .layers import (
    apply_rope,
    attention,
    dense_init,
    make_rope,
    mlp_act,
    mlp_gated,
    rms_norm,
    softcap,
    squared_relu,
)

__all__ = [
    "init_dense",
    "dense_forward",
    "dense_decode_step",
    "dense_loss",
    "init_dense_cache",
    "attn_pattern",
    "init_layer_stack",
    "layer_apply",
    "stack_forward",
    "stack_decode",
]


def attn_pattern(cfg: ModelConfig):
    if cfg.attn_kind == "local_global":
        if cfg.long_context:  # 500k serving mode: all layers sliding-window
            return ("sliding", "sliding")
        return ("sliding", "causal")
    if cfg.attn_kind == "bidirectional":
        return ("bidirectional",)
    if cfg.attn_kind == "prefix":
        return ("prefix",)
    return ("causal",)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key):
    d, H, Hkv, hd, f = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd, cfg.d_ff
    ks = jax.random.split(key, 8)
    pd = cfg.pdtype()
    p = {
        "ln1": jnp.zeros((d,), pd),
        "ln2": jnp.zeros((d,), pd),
        "attn": {
            "wq": dense_init(ks[0], (d, H, hd), fan_in=d, dtype=pd),
            "wk": dense_init(ks[1], (d, Hkv, hd), fan_in=d, dtype=pd),
            "wv": dense_init(ks[2], (d, Hkv, hd), fan_in=d, dtype=pd),
            "wo": dense_init(ks[3], (H, hd, d), fan_in=H * hd, dtype=pd),
        },
    }
    if cfg.mlp_kind in ("gated_silu", "gated_gelu"):
        p["mlp"] = {
            "w_gate": dense_init(ks[4], (d, f), dtype=pd),
            "w_in": dense_init(ks[5], (d, f), dtype=pd),
            "w_out": dense_init(ks[6], (f, d), fan_in=f, dtype=pd),
        }
    else:  # plain activation MLP (squared_relu / gelu)
        p["mlp"] = {
            "w_in": dense_init(ks[5], (d, f), dtype=pd),
            "w_out": dense_init(ks[6], (f, d), fan_in=f, dtype=pd),
        }
    if cfg.attn_kind == "local_global":  # gemma2 post-norms
        p["ln1b"] = jnp.zeros((d,), pd)
        p["ln2b"] = jnp.zeros((d,), pd)
    return p


def init_layer_stack(cfg: ModelConfig, key, init_one=None):
    """Stacks per-layer params: (n_groups, period, ...) leading axes."""
    init_one = init_one or _init_layer
    pattern = attn_pattern(cfg)
    period = len(pattern)
    n_groups = cfg.num_layers // period
    keys = jax.random.split(key, cfg.num_layers).reshape(n_groups, period, -1)

    def init_group(gkeys):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[init_one(cfg, k) for k in gkeys])

    stacks = [init_group(keys[g]) for g in range(n_groups)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *stacks)


def init_dense(cfg: ModelConfig, key):
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    pd = cfg.pdtype()
    params = {
        "emb": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), fan_in=cfg.d_model, dtype=pd),
        "layers": init_layer_stack(cfg, k_layers),
        "ln_f": jnp.zeros((cfg.d_model,), pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=pd)
    return params


# ---------------------------------------------------------------------------
# layer body (shared by forward and decode)
# ---------------------------------------------------------------------------


def _mlp(cfg: ModelConfig, p, x):
    x = shard(x, "batch", None, None)
    if cfg.mlp_kind == "gated_silu":
        out = mlp_gated(p, x, jax.nn.silu)
    elif cfg.mlp_kind == "gated_gelu":
        out = mlp_gated(p, x, jax.nn.gelu)
    elif cfg.mlp_kind == "squared_relu":
        out = mlp_act(p, x, squared_relu)
    else:
        out = mlp_act(p, x, jax.nn.gelu)
    return out


def layer_apply(
    cfg: ModelConfig,
    p,
    h,
    kind: str,
    rope_sincos,
    *,
    q_pos,
    kv_pos,
    cache_kv=None,  # (k_cache, v_cache) (B, S_max, Hkv, hd) or None
    write_pos=None,  # decode: scalar position to write new kv
    prefix_len=None,
):
    """One transformer block. Returns (h, new_kv) where new_kv is either the
    fresh (k, v) of this call (train/prefill) or the updated caches (decode).
    """
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    sin, cos = rope_sincos
    from ..launch import sharding as shd

    kv_heads_spec = "tensor" if Hkv % max(shd.axis_size("tensor"), 1) == 0 else None
    a_in = rms_norm(h, p["ln1"])
    q = jnp.einsum("bsd,dhk->bshk", a_in, p["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", a_in, p["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", a_in, p["attn"]["wv"])
    q = shard(apply_rope(q, sin, cos), "batch", None, "tensor", None)
    k = shard(apply_rope(k, sin, cos), "batch", None, kv_heads_spec, None)
    v = shard(v, "batch", None, kv_heads_spec, None)

    if cache_kv is not None and write_pos is not None:
        k_cache, v_cache = cache_kv
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), write_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), write_pos, axis=1)
        k_use, v_use = k_cache, v_cache
        kv_pos_use = kv_pos
        new_kv = (k_cache, v_cache)
        S_max = k_cache.shape[1]
        if kind == "sliding" and q.shape[1] == 1 and S_max > 2 * cfg.window:
            # long-context decode: a sliding-window layer only ever attends
            # to the last `window` cache slots — slice them out instead of
            # scoring the whole 500k cache (the 0.02 MODEL/HLO-FLOPs waste
            # flagged in §Roofline)
            start = jnp.clip(write_pos - cfg.window + 1, 0, S_max - cfg.window)
            k_use = jax.lax.dynamic_slice_in_dim(k_cache, start, cfg.window, axis=1)
            v_use = jax.lax.dynamic_slice_in_dim(v_cache, start, cfg.window, axis=1)
            kv_pos_use = start + jnp.arange(cfg.window)
    else:
        k_use, v_use = k, v
        kv_pos_use = kv_pos
        new_kv = (k, v)

    out = attention(
        q, k_use, v_use,
        q_pos=q_pos, kv_pos=kv_pos_use, kind=kind, window=cfg.window,
        prefix_len=prefix_len, attn_softcap=cfg.attn_softcap,
        block_q=cfg.attn_block_q, impl=cfg.attn_impl,
    )
    # hand off from head-parallel to sequence-parallel BEFORE the output
    # projection: otherwise the d_wo backward einsum sees conflicting
    # shardings (heads vs seq on 'model') and GSPMD all-gathers the full
    # f32 activation cotangent (30 GB/layer on deepseek-v3 — §Perf)
    out = shard(out, "batch", "act_seq", None, None)
    attn_out = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    if "ln1b" in p:
        attn_out = rms_norm(attn_out, p["ln1b"])
    h = h + attn_out

    m_in = rms_norm(h, p["ln2"])
    mlp_out = _mlp(cfg, p["mlp"], m_in)
    if "ln2b" in p:
        mlp_out = rms_norm(mlp_out, p["ln2b"])
    h = h + mlp_out
    return shard(h, "batch", "act_seq", None), new_kv


# ---------------------------------------------------------------------------
# full stack: forward (train / prefill) and decode
# ---------------------------------------------------------------------------


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def stack_forward(cfg: ModelConfig, layers, h, *, prefix_len=None, collect_cache=False,
                  layer_fn=layer_apply):
    """Scan over the layer stack. Returns (h, caches or None)."""
    S = h.shape[1]
    pattern = attn_pattern(cfg)
    pos = jnp.arange(S)
    rope = make_rope(pos, cfg.hd, cfg.rope_base)

    def group_body(h, gp):
        kvs = []
        for sub, kind in enumerate(pattern):
            p_sub = jax.tree.map(lambda x: x[sub], gp)
            h, kv = layer_fn(
                cfg, p_sub, h, kind, rope, q_pos=pos, kv_pos=pos, prefix_len=prefix_len
            )
            kvs.append(kv)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs) if collect_cache else None
        return h, stacked

    body = _maybe_remat(cfg, group_body)
    h, caches = jax.lax.scan(body, h, layers)
    return h, caches


def stack_decode(cfg: ModelConfig, layers, h, cache, pos_scalar, *, layer_fn=layer_apply):
    """One-token decode through the stack; cache leading dims (n_groups, period)."""
    pattern = attn_pattern(cfg)
    S_max = jax.tree.leaves(cache)[0].shape[3]  # (n_groups, period, B, S, ...)
    q_pos = pos_scalar[None]
    kv_pos = jnp.arange(S_max)
    rope = make_rope(q_pos, cfg.hd, cfg.rope_base)

    def group_body(h, inp):
        gp, gcache = inp
        new_caches = []
        for sub, kind in enumerate(pattern):
            p_sub = jax.tree.map(lambda x: x[sub], gp)
            c_sub = jax.tree.map(lambda x: x[sub], gcache)
            h, new_kv = layer_fn(
                cfg, p_sub, h, kind, rope, q_pos=q_pos, kv_pos=kv_pos,
                cache_kv=c_sub, write_pos=pos_scalar,
            )
            new_caches.append(new_kv)
        return h, jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)

    h, new_cache = jax.lax.scan(group_body, h, (layers, cache))
    return h, new_cache


# ---------------------------------------------------------------------------
# public model API
# ---------------------------------------------------------------------------


def _embed(cfg: ModelConfig, params, tokens):
    h = params["emb"][tokens].astype(cfg.cdtype())
    if cfg.scale_embedding:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return shard(h, "batch", "act_seq", None)


def _logits(cfg: ModelConfig, params, h):
    h = rms_norm(h, params["ln_f"])
    head = params["emb"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head.astype(h.dtype))
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return shard(logits.astype(jnp.float32), "batch", None, "tensor")


def dense_forward(params, cfg: ModelConfig, tokens, *, prefix_len=None, collect_cache=False):
    h = _embed(cfg, params, tokens)
    h, caches = stack_forward(cfg, params["layers"], h, prefix_len=prefix_len, collect_cache=collect_cache)
    return _logits(cfg, params, h), caches


def init_dense_cache(cfg: ModelConfig, batch: int, max_len: int):
    pattern = attn_pattern(cfg)
    n_groups = cfg.num_layers // len(pattern)
    shape = (n_groups, len(pattern), batch, max_len, cfg.num_kv_heads, cfg.hd)
    return (jnp.zeros(shape, cfg.cdtype()), jnp.zeros(shape, cfg.cdtype()))


def dense_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """tokens (B, 1); pos scalar int32. Returns (logits (B, 1, V), cache)."""
    h = _embed(cfg, params, tokens)
    h, new_cache = stack_decode(cfg, params["layers"], h, cache, pos)
    return _logits(cfg, params, h), new_cache


def cross_entropy(logits, targets, valid=None):
    """One-hot-einsum formulation: a gather over the vocab-sharded logits
    would force GSPMD to all-gather the full (B, S, V) f32 tensor; the
    one-hot product keeps the vocab dim sharded through the reduction."""
    x = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(x.max(axis=-1, keepdims=True))
    shifted = x - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))  # (B, S)
    onehot = jax.nn.one_hot(targets, x.shape[-1], dtype=jnp.float32)
    at_target = jnp.einsum("bsv,bsv->bs", shifted, onehot)
    nll = lse - at_target
    if valid is None:
        return nll.mean()
    w = valid.astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)


def dense_loss(params, cfg: ModelConfig, batch):
    """batch: dict with 'tokens' (B, S+1)."""
    tokens = batch["tokens"]
    logits, _ = dense_forward(params, cfg, tokens[:, :-1])
    return cross_entropy(logits, tokens[:, 1:])
