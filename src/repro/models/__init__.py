"""Model zoo: 10 assigned architectures (dense / moe / ssm / hybrid /
encoder / vlm families), pure JAX with scan-over-layers."""

from .model import (
    active_param_count,
    decode_fn,
    init_cache,
    init_params,
    input_specs,
    loss_fn,
    make_dummy_batch,
    model_flops_per_token,
    param_count,
    prefill_fn,
    supports_mode,
)

__all__ = [
    "init_params", "loss_fn", "prefill_fn", "init_cache", "decode_fn",
    "input_specs", "make_dummy_batch", "param_count", "active_param_count",
    "model_flops_per_token", "supports_mode",
]
