"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Train/prefill uses the *non-absorbed* form (materialize per-head K/V from the
compressed latent) — best for MXU utilization on full sequences. Decode uses
the *absorbed* form: queries are projected into the latent space and attend
directly against the cached ``c_kv`` — the KV cache is ``(B, S, d_c + d_r)``
instead of ``(B, S, H, (d_nope + d_r + d_v))``, the whole point of MLA.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.sharding import shard
from .layers import apply_rope, dense_init, make_rope, rms_norm

__all__ = ["init_mla", "mla_forward", "mla_decode_step", "init_mla_cache"]


def init_mla(cfg: ModelConfig, key):
    d = cfg.d_model
    H = cfg.num_heads
    nd = cfg.hd  # nope head dim
    rd = cfg.rope_head_dim
    vd = cfg.v_head_dim or nd
    qr = cfg.q_lora_rank
    kr = cfg.kv_lora_rank
    ks = jax.random.split(key, 10)
    pd = cfg.pdtype()
    p = {
        "w_dq": dense_init(ks[0], (d, qr), dtype=pd),
        "q_ln": jnp.zeros((qr,), pd),
        "w_uq": dense_init(ks[1], (qr, H, nd + rd), fan_in=qr, dtype=pd),
        "w_dkv": dense_init(ks[2], (d, kr), dtype=pd),
        "kv_ln": jnp.zeros((kr,), pd),
        "w_uk": dense_init(ks[3], (kr, H, nd), fan_in=kr, dtype=pd),
        "w_uv": dense_init(ks[4], (kr, H, vd), fan_in=kr, dtype=pd),
        "w_kr": dense_init(ks[5], (d, rd), dtype=pd),
        "wo": dense_init(ks[6], (H, vd, d), fan_in=H * vd, dtype=pd),
    }
    return p


def _latents(cfg, p, x):
    """Shared path: compressed latents + rope key."""
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_ln"])
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_ln"])
    kr = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])  # (B, S, rd) shared across heads
    return cq, ckv, kr


def mla_forward(cfg: ModelConfig, p, x, *, q_pos, collect_cache=False):
    """Non-absorbed attention over the full sequence (train / prefill)."""
    nd, rd = cfg.hd, cfg.rope_head_dim
    vd = cfg.v_head_dim or nd
    H = cfg.num_heads
    cq, ckv, kr = _latents(cfg, p, x)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])  # (B,S,H,nd+rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    sin, cos = make_rope(q_pos, rd, cfg.rope_base)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(kr[:, :, None, :], sin, cos)  # (B,S,1,rd)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"])
    q_full = shard(jnp.concatenate([q_nope, q_rope], -1), "batch", None, "tensor", None)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (rd,))], -1)
    k_full = shard(k_full, "batch", None, "tensor", None)
    from .layers import attention  # local import to avoid cycle at module load

    out = attention(
        q_full, k_full, v, q_pos=q_pos, kv_pos=q_pos, kind="causal",
        scale=(nd + rd) ** -0.5, block_q=cfg.attn_block_q, impl=cfg.attn_impl,
    )
    # head-parallel -> sequence-parallel handoff (see dense.layer_apply)
    out = shard(out, "batch", "act_seq", None, None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    cache = (ckv, kr) if collect_cache else None
    return y, cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers_stacked):
    kr_dim = cfg.rope_head_dim
    shape_c = n_layers_stacked + (batch, max_len, cfg.kv_lora_rank)
    shape_r = n_layers_stacked + (batch, max_len, kr_dim)
    return (jnp.zeros(shape_c, cfg.cdtype()), jnp.zeros(shape_r, cfg.cdtype()))


def mla_decode_step(cfg: ModelConfig, p, x, cache, pos):
    """Absorbed decode. x (B, 1, d); cache (ckv (B,S,kr), kro (B,S,rd));
    pos scalar. Returns (y (B,1,d), new_cache)."""
    nd, rd = cfg.hd, cfg.rope_head_dim
    vd = cfg.v_head_dim or nd
    ckv_cache, kr_cache = cache
    S = ckv_cache.shape[1]
    cq, ckv_t, kr_t = _latents(cfg, p, x)  # (B,1,*)
    sin, cos = make_rope(pos[None], rd, cfg.rope_base)
    kr_t = apply_rope(kr_t[:, :, None, :], sin, cos)[:, :, 0, :]  # (B,1,rd)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(ckv_cache, ckv_t.astype(ckv_cache.dtype), pos, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, kr_t.astype(kr_cache.dtype), pos, axis=1)

    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])  # (B,1,H,nd+rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, sin, cos)
    # absorb W_uk into the query: q_c (B,1,H,kr)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    scale = (nd + rd) ** -0.5
    logits = (
        jnp.einsum("bshr,btr->bhst", q_c.astype(jnp.float32), ckv_cache.astype(jnp.float32))
        + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), kr_cache.astype(jnp.float32))
    ) * scale  # (B, H, 1, S)
    kv_pos = jnp.arange(S)
    mask = kv_pos[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out_c = jnp.einsum("bhst,btr->bshr", w, ckv_cache.astype(jnp.float32))  # (B,1,H,kr)
    out = jnp.einsum("bshr,rhk->bshk", out_c.astype(x.dtype), p["w_uv"])  # (B,1,H,vd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, (ckv_cache, kr_cache)
