"""HuBERT-style encoder-only audio model (arXiv:2106.07447).

The conv waveform frontend is STUBBED per the task spec: inputs are
precomputed frame embeddings ``(B, S, frame_dim)``. The transformer backbone
(48L/1280d for hubert-xlarge) is bidirectional; training is masked
prediction of cluster ids (vocab 504) at masked frames.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.sharding import shard
from .dense import cross_entropy, init_layer_stack, stack_forward
from .layers import dense_init, rms_norm

__all__ = ["init_hubert", "hubert_forward", "hubert_loss"]


def init_hubert(cfg: ModelConfig, key):
    k_proj, k_layers, k_head, k_mask = jax.random.split(key, 4)
    pd = cfg.pdtype()
    return {
        "frame_proj": dense_init(k_proj, (cfg.frame_dim, cfg.d_model), dtype=pd),
        "mask_emb": dense_init(k_mask, (cfg.d_model,), fan_in=cfg.d_model, dtype=pd),
        "layers": init_layer_stack(cfg, k_layers),
        "ln_f": jnp.zeros((cfg.d_model,), pd),
        "head": dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=pd),
    }


def hubert_forward(params, cfg: ModelConfig, frames, mask=None):
    """frames (B, S, frame_dim); mask (B, S) bool (True = masked)."""
    h = jnp.einsum("bsf,fd->bsd", frames.astype(cfg.cdtype()), params["frame_proj"])
    if mask is not None:
        h = jnp.where(mask[..., None], params["mask_emb"].astype(h.dtype), h)
    h = shard(h, "batch", "act_seq", None)
    h, _ = stack_forward(cfg, params["layers"], h)
    h = rms_norm(h, params["ln_f"])
    logits = jnp.einsum("bsd,dv->bsv", h, params["head"])
    return shard(logits.astype(jnp.float32), "batch", None, "tensor")


def hubert_loss(params, cfg: ModelConfig, batch):
    """batch: {frames (B,S,F), mask (B,S) bool, labels (B,S) int}."""
    logits = hubert_forward(params, cfg, batch["frames"], batch["mask"])
    return cross_entropy(logits, batch["labels"], valid=batch["mask"])
