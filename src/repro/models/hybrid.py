"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block applied
every ``cfg.shared_attn_every`` layers (arXiv:2411.15242).

The shared block's weights are reused at every application (Zamba2's memory
trick); its input is ``concat(hidden, original_embeddings)`` projected back
to ``d_model``. Each application keeps its OWN KV cache at decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..launch.sharding import shard
from .dense import _embed, _init_layer, _logits, _maybe_remat, cross_entropy, layer_apply
from .layers import dense_init, make_rope, rms_norm
from .ssm import causal_conv1d, causal_conv1d_step, ssd_chunked, ssd_step

__all__ = [
    "init_zamba",
    "zamba_forward",
    "zamba_loss",
    "init_zamba_cache",
    "zamba_decode_step",
]


def _dims(cfg: ModelConfig):
    inner = cfg.ssm_expand * cfg.d_model
    H = inner // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = inner + 2 * N  # x, B, C are convolved
    d_in_proj = 2 * inner + 2 * N + H  # z, x, B, C, dt
    return inner, H, P, N, conv_dim, d_in_proj


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_mamba_block(cfg: ModelConfig, key):
    d = cfg.d_model
    inner, H, P, N, conv_dim, d_in_proj = _dims(cfg)
    ks = jax.random.split(key, 4)
    pd = cfg.pdtype()
    return {
        "ln": jnp.zeros((d,), pd),
        "in_proj": dense_init(ks[0], (d, d_in_proj), dtype=pd),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_dim), fan_in=cfg.ssm_conv, dtype=pd),
        "A_log": jnp.zeros((H,), pd),  # A = -exp(A_log) = -1 at init
        "dt_bias": jnp.full((H,), -1.0, pd),  # softplus(-1+x) ~ 0.3
        "D": jnp.ones((H,), pd),
        "gn": jnp.zeros((inner,), pd),
        "out_proj": dense_init(ks[2], (inner, d), fan_in=inner, dtype=pd),
    }


def init_zamba(cfg: ModelConfig, key):
    k_emb, k_mamba, k_shared, k_proj, k_head = jax.random.split(key, 5)
    pd = cfg.pdtype()
    period = cfg.shared_attn_every
    n_groups = cfg.num_layers // period
    keys = jax.random.split(k_mamba, cfg.num_layers).reshape(n_groups, period, -1)

    def init_group(gkeys):
        return jax.tree.map(
            lambda *xs: jnp.stack(xs), *[_init_mamba_block(cfg, k) for k in gkeys]
        )

    groups = [init_group(keys[g]) for g in range(n_groups)]
    return {
        "emb": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), fan_in=cfg.d_model, dtype=pd),
        "mamba_groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        # single SHARED transformer block + 2d->d input projector
        "shared": _init_layer(cfg, k_shared),
        "shared_in_proj": dense_init(k_proj, (2 * cfg.d_model, cfg.d_model), dtype=pd),
        "ln_f": jnp.zeros((cfg.d_model,), pd),
        "lm_head": dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=pd),
    }


# ---------------------------------------------------------------------------
# mamba2 block body
# ---------------------------------------------------------------------------


def _mamba_block(cfg, p, h, state=None, step=False):
    """state: (conv_state (B,K-1,conv_dim), ssd_state (B,H,P,N))."""
    inner, H, P, N, conv_dim, _ = _dims(cfg)
    x = rms_norm(h, p["ln"])
    B, S = x.shape[0], x.shape[1]
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = proj[..., :inner]
    xbc = proj[..., inner : inner + conv_dim]
    dt_pre = proj[..., inner + conv_dim :]  # (B,S,H)
    xbc = shard(xbc, "batch", None, "tensor")
    conv_state = state[0] if state is not None else None
    if step:
        xbc, conv_state = causal_conv1d_step(xbc, p["conv_w"], conv_state)
    else:
        xbc, conv_state = causal_conv1d(xbc, p["conv_w"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :inner].reshape(B, S, H, P)
    Bm = xbc[..., inner : inner + N]
    Cm = xbc[..., inner + N :]
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    ssd_state = state[1] if state is not None else None
    if step:
        y, ssd_state = ssd_step(xs[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], ssd_state)
        y = y[:, None]
    else:
        y, ssd_state = ssd_chunked(xs, dt, A, Bm, Cm, chunk=min(cfg.chunk_size, S), state=ssd_state)
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, inner)
    y = rms_norm(y * jax.nn.silu(z), p["gn"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return h + out, (conv_state, ssd_state)


def _shared_block(cfg, params, h, emb0, rope, q_pos, kv_pos, cache_kv=None, write_pos=None):
    u = jnp.concatenate([h, emb0], axis=-1)
    u = jnp.einsum("bse,ed->bsd", u, params["shared_in_proj"])
    u, new_kv = layer_apply(
        cfg, params["shared"], u, "causal", rope, q_pos=q_pos, kv_pos=kv_pos,
        cache_kv=cache_kv, write_pos=write_pos,
    )
    return h + u, new_kv


# ---------------------------------------------------------------------------
# forward / decode
# ---------------------------------------------------------------------------


def init_zamba_cache(cfg: ModelConfig, batch: int, max_len: int):
    inner, H, P, N, conv_dim, _ = _dims(cfg)
    period = cfg.shared_attn_every
    n_groups = cfg.num_layers // period
    f32 = jnp.float32
    mamba = (
        jnp.zeros((n_groups, period, batch, cfg.ssm_conv - 1, conv_dim), cfg.cdtype()),
        jnp.zeros((n_groups, period, batch, H, P, N), f32),
    )
    kv_shape = (n_groups, batch, max_len, cfg.num_kv_heads, cfg.hd)
    attn = (jnp.zeros(kv_shape, cfg.cdtype()), jnp.zeros(kv_shape, cfg.cdtype()))
    return {"mamba": mamba, "attn": attn}


def zamba_forward(params, cfg: ModelConfig, tokens, *, state=None, collect_state=False):
    h = _embed(cfg, params, tokens)
    emb0 = h
    S = h.shape[1]
    pos = jnp.arange(S)
    rope = make_rope(pos, cfg.hd, cfg.rope_base)
    if state is None:
        state = init_zamba_cache(cfg, tokens.shape[0], S if collect_state else 1)

    def group_body(hh, inp):
        gp, gstate = inp

        def m_body(hh2, inp2):
            lp, ls = inp2
            hh2, ns = _mamba_block(cfg, lp, hh2, ls, step=False)
            return hh2, ns

        hh, new_mamba = jax.lax.scan(m_body, hh, (gp, gstate["mamba"]))
        if collect_state:
            hh, new_kv = _shared_block(cfg, params, hh, emb0, rope, pos, pos,
                                       cache_kv=gstate["attn"], write_pos=0)
        else:
            hh, new_kv = _shared_block(cfg, params, hh, emb0, rope, pos, pos)
        out = {"mamba": new_mamba, "attn": new_kv} if collect_state else None
        return shard(hh, "batch", "act_seq", None), out

    # regroup state to scan over groups: mamba leaves (G, period, ...) ok;
    # attn leaves (G, B, S, ...) ok.
    xs_state = {"mamba": state["mamba"], "attn": state["attn"]}
    h, new_state = jax.lax.scan(_maybe_remat(cfg, group_body), h, (params["mamba_groups"], xs_state))
    return _logits(cfg, params, h), new_state


def zamba_loss(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    logits, _ = zamba_forward(params, cfg, tokens[:, :-1])
    return cross_entropy(logits, tokens[:, 1:])


def zamba_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    h = _embed(cfg, params, tokens)
    emb0 = h
    S_max = jax.tree.leaves(cache["attn"])[0].shape[2]
    q_pos = pos[None]
    kv_pos = jnp.arange(S_max)
    rope = make_rope(q_pos, cfg.hd, cfg.rope_base)

    def group_body(hh, inp):
        gp, gstate = inp

        def m_body(hh2, inp2):
            lp, ls = inp2
            hh2, ns = _mamba_block(cfg, lp, hh2, ls, step=True)
            return hh2, ns

        hh, new_mamba = jax.lax.scan(m_body, hh, (gp, gstate["mamba"]))
        hh, new_kv = _shared_block(
            cfg, params, hh, emb0, rope, q_pos, kv_pos,
            cache_kv=gstate["attn"], write_pos=pos,
        )
        return hh, {"mamba": new_mamba, "attn": new_kv}

    h, new_state = jax.lax.scan(group_body, h, (params["mamba_groups"], cache))
    return _logits(cfg, params, h), new_state
