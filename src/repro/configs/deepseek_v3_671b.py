"""deepseek-v3-671b [moe]: MLA, 3 dense prefix layers, 58 MoE layers with
1 shared + 256 routed experts (top-8), MTP depth-1 (arXiv:2412.19437).

Optimizer is Adafactor (factored 2nd moment): AdamW fp32 state for 671B
params does not fit a 256-chip v5e pod (see DESIGN.md §5).
Expert parallelism places one expert per device: expert axes ('data','model').
"""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128,  # nope head dim
    d_ff=18432,  # dense prefix layers' FFN
    vocab_size=129280,
    num_experts=256, top_k=8, d_ff_expert=2048, num_shared_experts=1,
    dense_prefix_layers=3, router_aux_weight=0.001, capacity_factor=1.25,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
    v_head_dim=128, use_mtp=True, mtp_weight=0.3,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adafactor",
)

SMOKE = FULL.replace(
    num_layers=3, dense_prefix_layers=1, d_model=256,
    num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=512, d_ff_expert=128, num_experts=4, top_k=2, num_shared_experts=1,
    q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16, v_head_dim=32,
    vocab_size=512, param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
