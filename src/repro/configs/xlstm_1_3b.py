"""xlstm-1.3b [ssm]: 48 blocks, 1 sLSTM per 8 (7:1 mLSTM:sLSTM), 4 heads,
expansion 2, no separate FFN (d_ff=0) (arXiv:2405.04517)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    ssm_expand=2, ssm_conv=4, slstm_every=8, chunk_size=256,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=4, slstm_every=2, d_model=128, num_heads=2, num_kv_heads=2,
    vocab_size=512, chunk_size=16,
    param_dtype="float32", compute_dtype="float32", remat="none",
)

register(FULL, SMOKE)
