"""gemma2-2b [dense]: local/global alternating attention, logit softcaps,
post-norms, head_dim=256, tied embeddings (arXiv:2408.00118)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="gemma2-2b", family="dense",
    num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
    head_dim=256, d_ff=9216, vocab_size=256000,
    mlp_kind="gated_gelu", attn_kind="local_global", window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    tie_embeddings=True, scale_embedding=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, vocab_size=512, window=32,
    param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
