"""Architecture registry. One module per assigned architecture; importing
them registers (full, smoke) config pairs."""

from .base import INPUT_SHAPES, InputShape, ModelConfig, get_config, list_archs, register

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        deepseek_7b,
        deepseek_v3_671b,
        gemma2_2b,
        granite_20b,
        hubert_xlarge,
        minitron_8b,
        olmoe_1b_7b,
        paligemma_3b,
        xlstm_1_3b,
        zamba2_2_7b,
    )


__all__ = ["ModelConfig", "InputShape", "INPUT_SHAPES", "get_config", "list_archs", "register"]
