"""hubert-xlarge [audio]: encoder-only transformer 48L/1280d; conv waveform
frontend STUBBED — inputs are precomputed frame embeddings (arXiv:2106.07447)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    attn_kind="bidirectional", mlp_kind="gelu",
    frame_dim=512, mask_prob=0.08,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
    vocab_size=64, frame_dim=32,
    param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
