"""paligemma-3b [vlm]: SigLIP tower STUBBED (patch embeddings provided);
gemma-2B text decoder (18L, MQA) with prefix-LM masking (arXiv:2407.07726)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    mlp_kind="gated_gelu", attn_kind="prefix",
    tie_embeddings=True, scale_embedding=True,
    num_patches=256, patch_dim=1152,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, head_dim=64,
    d_ff=512, vocab_size=512, num_patches=16, patch_dim=64,
    param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
