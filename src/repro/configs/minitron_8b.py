"""minitron-8b [dense]: pruned nemotron, squared-ReLU MLP, GQA kv=8
(arXiv:2407.14679)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    mlp_kind="squared_relu",
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, d_ff=512,
    vocab_size=512, param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
