"""deepseek-7b [dense]: llama-arch 30L (arXiv:2401.02954)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="deepseek-7b", family="dense",
    num_layers=30, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=11008, vocab_size=102400,
    mlp_kind="gated_silu", rope_base=10000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, d_ff=512,
    vocab_size=512, param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
