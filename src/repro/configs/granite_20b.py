"""granite-20b [dense]: code model, MQA (kv=1) (arXiv:2405.04324)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    mlp_kind="gelu",  # gpt-bigcode lineage: plain (non-gated) GELU MLP
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, d_ff=512,
    vocab_size=512, param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
