"""olmoe-1b-7b [moe]: 16L, 64 experts top-8, d_ff_expert=1024
(arXiv:2409.02060)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    num_experts=64, top_k=8, d_ff_expert=1024,
    router_aux_weight=0.01, capacity_factor=1.25,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=256, d_ff_expert=256, num_experts=4, top_k=2,
    vocab_size=512, param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
