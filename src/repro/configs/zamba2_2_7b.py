"""zamba2-2.7b [hybrid]: 54 Mamba2 layers + one SHARED attention block
applied every 6 layers; ssm_state=64 (arXiv:2411.15242)."""

from .base import ModelConfig, register

FULL = ModelConfig(
    arch="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    shared_attn_every=6, chunk_size=256,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", attn_block_q=512, optimizer="adamw",
)

SMOKE = FULL.replace(
    num_layers=4, shared_attn_every=2, d_model=128, num_heads=4,
    num_kv_heads=4, d_ff=256, ssm_state=16, ssm_head_dim=32,
    vocab_size=512, chunk_size=16,
    param_dtype="float32", compute_dtype="float32",
    remat="none", attn_block_q=0,
)

register(FULL, SMOKE)
