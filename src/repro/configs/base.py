"""Model/run configuration dataclasses + the architecture registry."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "register", "get_config", "list_archs", "InputShape", "INPUT_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads

    # dense variants
    mlp_kind: str = "gated_silu"  # gated_silu | gelu | squared_relu
    attn_kind: str = "causal"  # causal | local_global (gemma2) | bidirectional
    window: int = 4096  # sliding window for local layers
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    rope_base: float = 10000.0
    tie_embeddings: bool = False
    scale_embedding: bool = False  # gemma family: h *= sqrt(d_model)
    long_context: bool = False  # serving mode: global attn layers fall back to sliding window
    attn_block_q: int = 0  # 0 = full attention matrix; >0 = query-blocked scan
    attn_impl: str = "xla"  # xla | pallas (flash-attention kernel; TPU target)
    moe_impl: str = "dense"  # dense | einsum | a2a (set by driver per shape)

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1  # every layer is MoE except the first `dense_prefix`
    dense_prefix_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0
    use_mtp: bool = False
    mtp_weight: float = 0.3

    # SSM / xLSTM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    slstm_every: int = 0  # xlstm: every k-th layer is sLSTM
    shared_attn_every: int = 0  # zamba2: shared attention block period
    chunk_size: int = 256

    # encoder (hubert) / vlm (paligemma) stub frontends
    frame_dim: int = 0  # audio frame embedding dim
    mask_prob: float = 0.08
    num_patches: int = 0  # vision patches
    patch_dim: int = 0

    # numerics / training
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"  # none | full | dots
    optimizer: str = "adamw"
    learning_rate: float = 3e-4

    # serving
    max_cache_len: int = 0

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

_REGISTRY = {}


def register(full_cfg: ModelConfig, smoke_cfg: ModelConfig):
    _REGISTRY[full_cfg.arch] = (full_cfg, smoke_cfg)
    return full_cfg


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    # import side-effect registration
    from . import _load_all  # noqa

    _load_all()
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch][1 if smoke else 0]


def list_archs():
    from . import _load_all  # noqa

    _load_all()
    return sorted(_REGISTRY)
