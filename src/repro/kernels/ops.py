"""Public entry points for the kernels package: per-hardware dispatch.

``minplus_step(kprev, cost, backend=...)`` / ``minplus_step_batch`` select
the min-plus implementation. ``backend="auto"`` (the default everywhere —
``schedule_batch``, ``deadline_sweep``, the sweep engine, FL servers)
resolves through :data:`DISPATCH_TABLE` keyed on ``jax.default_backend()``:

  platform | backend        | implementation
  ---------|----------------|------------------------------------------------
  cpu      | ``blocked``    | tiled jnp (`kernels/blocked.py`) — cache-blocked
           |                | BT x BW walk, ~4-8x over the dense oracle
  tpu      | ``pallas_tpu`` | Pallas TPU kernel (`kernels/minplus.py`) with
           |                | ``BT`` tuned from the real VMEM budget
  gpu      | ``pallas_gpu`` | Pallas-GPU blocked kernel (`kernels/gpu.py`)

Unknown platforms fall back to ``blocked`` (pure jnp, runs anywhere). The
dense reference (``backend="ref"``) is retained as the small-shape oracle
every backend is validated against; ``backend="pallas"`` keeps the
interpret-mode TPU kernel for CPU-side kernel debugging. Resolution happens
at Python/trace time (``jax.default_backend()`` is not a traced value), so
"auto" and its resolved backend share jit caches when callers resolve
before specializing — see :func:`resolve_backend`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .blocked import minplus_blocked, minplus_blocked_batch
from .gpu import minplus_pallas_gpu, minplus_pallas_gpu_batch
from .minplus import minplus_pallas, minplus_pallas_batch, tpu_tuned_bt
from .ref import BIG, minplus_step_ref, minplus_step_ref_batch

__all__ = [
    "minplus_step",
    "minplus_step_batch",
    "resolve_backend",
    "DISPATCH_TABLE",
    "BACKENDS",
    "BIG",
]

# jax.default_backend() platform -> kernel backend
DISPATCH_TABLE = {"cpu": "blocked", "tpu": "pallas_tpu", "gpu": "pallas_gpu"}

BACKENDS = ("ref", "blocked", "pallas", "pallas_tpu", "pallas_gpu")


def resolve_backend(backend: str | None = "auto") -> str:
    """Concrete backend name for ``backend`` (``None``/"auto" dispatch per
    hardware). Callers that use the backend as a jit static argument or a
    cache key should resolve first so "auto" shares compilations with its
    resolved name."""
    if backend is None or backend == "auto":
        return DISPATCH_TABLE.get(jax.default_backend(), "blocked")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; options: auto, {BACKENDS}")
    return backend


def minplus_step(kprev: jnp.ndarray, cost: jnp.ndarray, backend: str = "auto"):
    """One DP row update: ``kprev (T+1,)``, ``cost (W,)``."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return minplus_step_ref(kprev, cost)
    if backend == "blocked":
        return minplus_blocked(kprev, cost)
    if backend == "pallas":
        return minplus_pallas(kprev, cost, interpret=True)
    if backend == "pallas_tpu":
        return minplus_pallas(
            kprev, cost, BT=tpu_tuned_bt(kprev.shape[0], cost.shape[0]), interpret=False
        )
    if backend == "pallas_gpu":
        return minplus_pallas_gpu(kprev, cost, interpret=False)
    raise ValueError(f"unknown backend {backend!r}")


def minplus_step_batch(kprev: jnp.ndarray, cost: jnp.ndarray, backend: str = "auto"):
    """Batched row update: ``kprev (B, T+1)``, ``cost (B, W)``."""
    backend = resolve_backend(backend)
    if backend == "ref":
        return minplus_step_ref_batch(kprev, cost)
    if backend == "blocked":
        return minplus_blocked_batch(kprev, cost)
    if backend == "pallas":
        return minplus_pallas_batch(kprev, cost, interpret=True)
    if backend == "pallas_tpu":
        return minplus_pallas_batch(
            kprev, cost, BT=tpu_tuned_bt(kprev.shape[1], cost.shape[1]), interpret=False
        )
    if backend == "pallas_gpu":
        return minplus_pallas_gpu_batch(kprev, cost, interpret=False)
    raise ValueError(f"unknown backend {backend!r}")
