"""Public jit'd entry points for the kernels package.

``minplus_step(kprev, cost, backend=...)`` dispatches between the pure-jnp
reference (`backend="ref"`, default — runs everywhere) and the Pallas kernel
(`backend="pallas"`, interpret-mode on CPU; `backend="pallas_tpu"` compiles
for real TPU hardware).
"""

from __future__ import annotations

import jax.numpy as jnp

from .minplus import minplus_pallas, minplus_pallas_batch
from .ref import BIG, minplus_step_ref, minplus_step_ref_batch

__all__ = ["minplus_step", "minplus_step_batch", "BIG"]


def minplus_step(kprev: jnp.ndarray, cost: jnp.ndarray, backend: str = "ref"):
    if backend == "ref":
        return minplus_step_ref(kprev, cost)
    if backend == "pallas":
        return minplus_pallas(kprev, cost, interpret=True)
    if backend == "pallas_tpu":
        return minplus_pallas(kprev, cost, interpret=False)
    raise ValueError(f"unknown backend {backend!r}")


def minplus_step_batch(kprev: jnp.ndarray, cost: jnp.ndarray, backend: str = "ref"):
    """Batched row update: ``kprev (B, T+1)``, ``cost (B, W)``."""
    if backend == "ref":
        return minplus_step_ref_batch(kprev, cost)
    if backend == "pallas":
        return minplus_pallas_batch(kprev, cost, interpret=True)
    if backend == "pallas_tpu":
        return minplus_pallas_batch(kprev, cost, interpret=False)
    raise ValueError(f"unknown backend {backend!r}")
