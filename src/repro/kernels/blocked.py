"""Blocked (tiled) jnp backend for the banded min-plus convolution.

The dense oracle (`kernels/ref.py`) materializes the full ``(B, T+1, W)``
candidate tensor per class step — ~640 MB of memory traffic per step at
B=16, T=10k, W=1k — so every solve is bandwidth-bound long before it is
compute-bound. This backend walks the *output* row in ``BT``-sized tiles
(outer ``lax.scan``) and the band in ``BW``-sized chunks (inner
``fori_loop``); inside a chunk the ``BW`` band offsets are unrolled into
length-``BT`` vector min/argmin updates against a running carry, so the
live state is O(B·BT) and the per-chunk working set O(B·(BT+BW)) — bounded
by O(B·BT·BW) and tiny next to the oracle's O(B·T·W), with identical
O(B·T·W) flops. The running-carry layout mirrors the blocked-softmax trick
of FlashAttention (Dao et al. 2022): a streaming (min, argmin) pair
replaces the full-row reduction, and XLA fuses the unrolled updates into
cache-resident elementwise chains (~8x over the oracle at B=8, T=8k,
W=512 on CPU — see BENCH_kernels.json).

Bit-identity with the oracle (asserted by tests/test_kernels_blocked.py):

* **values** — each candidate is the same float32 ``kprev[t-j] + cost[j]``
  followed by the same ``>= BIG -> BIG`` saturation; regrouping a min is
  exact, so tile values equal the dense values bit-for-bit.
* **argmins** — band offsets are visited in ascending ``j`` and every
  update uses *strict* improvement (``cand < best``), so the winner is the
  first minimum over the whole band: exactly Algorithm 1's ascending-``j``
  strict-improvement update, and exactly the oracle's ``argmin``.
* **band edges / padding** — out-of-band reads land in a ``BIG`` prefix
  (``t - j < 0``) or a ``BIG`` cost tail (``j > U_i``); ``BIG + x``
  saturates back to exactly ``BIG``, and an all-BIG tile keeps the
  ``argmin = 0`` convention because nothing strictly improves the ``BIG``
  init carry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import BIG

__all__ = [
    "auto_block_sizes",
    "minplus_blocked",
    "minplus_blocked_batch",
    "pad_band_inputs",
    "DEFAULT_BLOCK_BUDGET_BYTES",
]

# Nominal block budget: 4·B·BT·BW bytes — the footprint a materialized
# (B, BT, BW) candidate block WOULD have. The streaming form only keeps
# O(B·(BT+BW)) live, so this is a knob bounding the BT·BW work-per-chunk
# product (vector length x unroll factor), not a cache-residency target;
# 2 MB lands on the empirically fastest (512, 128) at the benchmark shape.
DEFAULT_BLOCK_BUDGET_BYTES = 2 << 20


def _ceil_to(x: int, m: int) -> int:
    return -(-int(x) // m) * m


def _pow2_ceil(v: int) -> int:
    return 1 << max(0, int(v) - 1).bit_length() if v > 1 else 1


def pad_band_inputs(kprev: jnp.ndarray, cost: jnp.ndarray, BT: int, BW: int):
    """The blocked layout's shared padding: rows gain a ``Wpad``-entry BIG
    prefix (every banded read ``t - j``, including from the padded band, is
    an in-bounds slice) and a BIG tail to whole ``BT`` tiles; costs gain a
    BIG tail to whole ``BW`` chunks. Both the jnp backend and the
    Pallas-GPU kernel build their inputs here, so the layouts — and the
    bit-identity contract that rests on BIG padding never winning an
    argmin — cannot drift apart.

    Returns ``(kprev_pad (B, Wpad+Tpad), cost_pad (B, Wpad), Tpad, Wpad)``.
    """
    B, Tp = kprev.shape
    W = cost.shape[1]
    Wpad = _ceil_to(W, BW)
    Tpad = _ceil_to(Tp, BT)
    kprev_pad = jnp.concatenate(
        [
            jnp.full((B, Wpad), BIG, jnp.float32),
            kprev,
            jnp.full((B, Tpad - Tp), BIG, jnp.float32),
        ],
        axis=1,
    )
    cost_pad = jnp.concatenate(
        [cost, jnp.full((B, Wpad - W), BIG, jnp.float32)], axis=1
    )
    return kprev_pad, cost_pad, Tpad, Wpad


def auto_block_sizes(
    B: int, Tp: int, W: int, budget_bytes: int = DEFAULT_BLOCK_BUDGET_BYTES
):
    """Deterministic (BT, BW) for a row-update shape.

    Policy: ``BW = min(128, ceil_pow2(W))`` bounds the unroll factor (and
    HLO size) of the inner chunk; the nominal ``4·B·BT·BW``-byte block
    budget then buys the widest output tile it can, clamped to [64, 2048]
    and never wider than the padded row. Both are powers of two so tile
    edges stay aligned across the pow2 shape buckets of the sweep engine
    (DESIGN.md §10). Measured on CPU at B=8, T=8193, W=512 this lands on
    (512, 128) — the fastest of the swept configurations.
    """
    B, Tp, W = int(B), int(Tp), int(W)
    BW = min(128, _pow2_ceil(W))
    elems = max(1, int(budget_bytes) // (4 * max(1, B)))  # BT*BW float32s
    BT = max(64, min(2048, _pow2_ceil(elems // BW + 1) >> 1))
    BT = min(BT, _pow2_ceil(Tp))
    return BT, BW


def minplus_blocked_batch(
    kprev: jnp.ndarray,
    cost: jnp.ndarray,
    *,
    BT: int | None = None,
    BW: int | None = None,
):
    """Blocked batched DP row update. Same contract as
    :func:`repro.kernels.ref.minplus_step_ref_batch`: ``kprev (B, T+1)``,
    ``cost (B, W)`` -> ``(B, T+1)`` float32 values + int32 first-min
    argmins, bit-identical to the oracle.

    ``BT``/``BW`` default to :func:`auto_block_sizes`; any sizes >= 1 are
    valid (ragged edges are BIG-padded). Pure traceable jnp — safe inside
    the DP's ``lax.scan`` under outer jits (the sweep engine closes over it
    per bucket).
    """
    kprev = jnp.asarray(kprev).astype(jnp.float32)
    cost = jnp.asarray(cost).astype(jnp.float32)
    B, Tp = kprev.shape
    W = cost.shape[1]
    bt, bw = auto_block_sizes(B, Tp, W)
    BT = int(BT) if BT is not None else bt
    BW = int(BW) if BW is not None else bw
    if BT < 1 or BW < 1:
        raise ValueError(f"block sizes must be >= 1, got BT={BT}, BW={BW}")

    kprev_pad, cost_pad, Tpad, Wpad = pad_band_inputs(kprev, cost, BT, BW)
    nT, nW = Tpad // BT, Wpad // BW

    def tile(_, base):  # one BT-wide output tile starting at absolute t = base
        def chunk(c, carry):
            best, best_idx = carry
            j0 = c * BW
            # segment covering every read of this (tile, chunk) pair:
            # seg[:, (BW-1) + dt - jj] = kprev_pad[:, Wpad + base + dt - (j0+jj)]
            seg = jax.lax.dynamic_slice(
                kprev_pad, (0, Wpad + base - j0 - (BW - 1)), (B, BT + BW - 1)
            )
            cchunk = jax.lax.dynamic_slice(cost_pad, (0, j0), (B, BW))
            for jj in range(BW):  # unrolled length-BT vector updates
                cand = (
                    jax.lax.slice_in_dim(seg, BW - 1 - jj, BW - 1 - jj + BT, axis=1)
                    + cchunk[:, jj : jj + 1]
                )
                cand = jnp.where(cand >= BIG, BIG, cand)  # oracle's saturation
                improved = cand < best  # strict: first minimum wins
                best = jnp.where(improved, cand, best)
                best_idx = jnp.where(improved, j0 + jj, best_idx)
            return best, best_idx

        init = (
            jnp.full((B, BT), BIG, jnp.float32),
            jnp.zeros((B, BT), jnp.int32),
        )
        best, best_idx = jax.lax.fori_loop(0, nW, chunk, init)
        return None, (best, best_idx)

    _, (vals, idxs) = jax.lax.scan(tile, None, jnp.arange(nT) * BT)
    kout = jnp.moveaxis(vals, 0, 1).reshape(B, Tpad)[:, :Tp]
    iout = jnp.moveaxis(idxs, 0, 1).reshape(B, Tpad)[:, :Tp]
    return kout, iout


@functools.partial(jax.jit, static_argnames=("BT", "BW"))
def minplus_blocked(
    kprev: jnp.ndarray,
    cost: jnp.ndarray,
    *,
    BT: int | None = None,
    BW: int | None = None,
):
    """One blocked DP row update: the ``B = 1`` slice of the batched form
    (same contract as :func:`repro.kernels.ref.minplus_step_ref`)."""
    kout, iout = minplus_blocked_batch(
        jnp.asarray(kprev)[None], jnp.asarray(cost)[None], BT=BT, BW=BW
    )
    return kout[0], iout[0]
