"""Pallas-GPU variant of the blocked min-plus kernel.

Same blocked layout as ``kernels/blocked.py`` (``BT`` output tiles x ``BW``
band chunks, running first-min carry), expressed as a Pallas kernel so the
GPU lowering (Triton) keeps the tile and the row segment in registers /
shared memory instead of streaming the dense ``(B, T+1, W)`` candidate
tensor through HBM. One ``(b, ot)`` grid program owns one output tile of
one batch element; the inner ``fori_loop`` walks the band in ``BW``-sized
chunks whose updates are unrolled length-``BT`` vector min/argmin steps —
no gather, only static shifted slices of the chunk's row segment, which
Triton lowers to contiguous loads.

GPU-vs-dense tie-breaking and saturation follow the same argument as the
jnp blocked backend (ascending ``j``, strict improvement, ``BIG``
saturation), so results are bit-identical to the oracle; the parity suite
runs this kernel in interpret mode on CPU (this container has no GPU — on
hardware, ``kernels/ops.py`` dispatches ``backend="auto"`` here with
``interpret=False``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .blocked import pad_band_inputs
from .ref import BIG

__all__ = ["minplus_pallas_gpu", "minplus_pallas_gpu_batch", "GPU_DEFAULT_BT", "GPU_DEFAULT_BW"]

# Triton-friendly defaults: a 256-wide f32 tile per program keeps register
# pressure low at unroll factor 64.
GPU_DEFAULT_BT = 256
GPU_DEFAULT_BW = 64


def _minplus_gpu_kernel(
    kprev_pad_ref, cost_ref, kout_ref, iout_ref, *, BT: int, BW: int, nW: int, Wpad: int
):
    """Grid is ``(b, ot)``; the whole padded previous row of this batch
    element is visible to the program, band chunks are dynamic slices."""
    ot = pl.program_id(1)
    base = ot * BT

    def chunk(c, carry):
        best, best_idx = carry
        j0 = c * BW
        # seg[(BW-1) + dt - jj] == kprev_pad[Wpad + base + dt - (j0 + jj)]
        seg = kprev_pad_ref[0, pl.dslice(Wpad + base - j0 - (BW - 1), BT + BW - 1)]
        cchunk = cost_ref[0, pl.dslice(j0, BW)]
        for jj in range(BW):  # unrolled: static shifted slices, no gather
            cand = jax.lax.slice_in_dim(seg, BW - 1 - jj, BW - 1 - jj + BT, axis=0) + cchunk[jj]
            cand = jnp.where(cand >= BIG, BIG, cand)
            improved = cand < best  # strict: first minimum wins
            best = jnp.where(improved, cand, best)
            best_idx = jnp.where(improved, j0 + jj, best_idx)
        return best, best_idx

    init = (jnp.full((BT,), BIG, jnp.float32), jnp.zeros((BT,), jnp.int32))
    best, best_idx = jax.lax.fori_loop(0, nW, chunk, init)
    kout_ref[0, ...] = best
    iout_ref[0, ...] = best_idx


def _minplus_gpu_call(kprev, cost, BT: int, BW: int, interpret: bool) -> tuple:
    """Unjitted body shared by both entry points (jit-of-jit would trace a
    second wrapper per shape for zero caching benefit)."""
    kprev = kprev.astype(jnp.float32)
    cost = cost.astype(jnp.float32)
    B, Tp = kprev.shape
    # same layout as the jnp blocked backend, from the same helper
    kprev_pad, cost_pad, Tpad, Wpad = pad_band_inputs(kprev, cost, BT, BW)
    grid = (B, Tpad // BT)
    kout, iout = pl.pallas_call(
        functools.partial(
            _minplus_gpu_kernel, BT=BT, BW=BW, nW=Wpad // BW, Wpad=Wpad
        ),
        grid=grid,
        in_specs=[
            # the padded row stays whole per program: chunks slide over it
            pl.BlockSpec((1, Wpad + Tpad), lambda b, ot: (b, 0)),
            pl.BlockSpec((1, Wpad), lambda b, ot: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BT), lambda b, ot: (b, ot)),
            pl.BlockSpec((1, BT), lambda b, ot: (b, ot)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tpad), jnp.float32),
            jax.ShapeDtypeStruct((B, Tpad), jnp.int32),
        ],
        interpret=interpret,
    )(kprev_pad, cost_pad)
    return kout[:, :Tp], iout[:, :Tp]


@functools.partial(jax.jit, static_argnames=("BT", "BW", "interpret"))
def minplus_pallas_gpu_batch(
    kprev: jnp.ndarray,
    cost: jnp.ndarray,
    *,
    BT: int = GPU_DEFAULT_BT,
    BW: int = GPU_DEFAULT_BW,
    interpret: bool = False,
) -> tuple:
    """Batched DP row update via the Pallas-GPU blocked kernel. Same
    contract as :func:`repro.kernels.ref.minplus_step_ref_batch`:
    ``kprev (B, T+1)``, ``cost (B, W)`` -> ``(B, T+1)`` values + int32
    argmins. ``interpret=True`` runs the kernel body in Python for CPU
    parity tests."""
    return _minplus_gpu_call(kprev, cost, BT, BW, interpret)


@functools.partial(jax.jit, static_argnames=("BT", "BW", "interpret"))
def minplus_pallas_gpu(
    kprev: jnp.ndarray,
    cost: jnp.ndarray,
    *,
    BT: int = GPU_DEFAULT_BT,
    BW: int = GPU_DEFAULT_BW,
    interpret: bool = False,
) -> tuple:
    """One DP row update: the ``B = 1`` slice of the batched GPU kernel."""
    kout, iout = _minplus_gpu_call(
        jnp.asarray(kprev)[None], jnp.asarray(cost)[None], BT, BW, interpret
    )
    return kout[0], iout[0]
