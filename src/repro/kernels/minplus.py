"""Pallas TPU kernel: banded min-plus (tropical) convolution for the
(MC)^2MKP dynamic program.

TPU adaptation (see DESIGN.md §3): the DP relaxation is not a matmul, so the
MXU is of no use — this is a VPU kernel. We tile the *output* row into
``BT``-sized blocks held in VMEM; the previous DP row is kept whole in VMEM
(rows are ``4·(T+1)`` bytes — up to ~4 MB for T = 1M, within the 16 MB VMEM
budget for realistic scheduling sizes) with a ``W``-entry BIG prefix so every
banded read is an in-bounds dynamic slice. The inner ``fori_loop`` walks the
band, performing length-``BT`` vector min/argmin updates — (8,128)-friendly
when ``BT`` is a multiple of 1024.

Layout:
  kprev_pad : (W + Tp,)  previous row, first W entries = BIG
  cost      : (W,)       class cost table, padded with BIG
  out tiles : (BT,) values + (BT,) int32 argmin
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG

__all__ = ["minplus_pallas", "DEFAULT_BT"]

DEFAULT_BT = 1024  # 8 sublanes x 128 lanes


def _minplus_kernel(kprev_pad_ref, cost_ref, kout_ref, iout_ref, *, BT: int, W: int):
    ot = pl.program_id(0)
    base = ot * BT  # absolute t of this tile's first element

    def body(j, carry):
        best, best_idx = carry
        # window[dt] = kprev_pad[W + base + dt - j]  == K_{i-1}[base + dt - j]
        start = W + base - j
        window = kprev_pad_ref[pl.dslice(start, BT)]
        cand = window + cost_ref[j]
        cand = jnp.where(cand >= BIG, BIG, cand)
        improved = cand < best
        best = jnp.where(improved, cand, best)
        best_idx = jnp.where(improved, jnp.full((BT,), j, jnp.int32), best_idx)
        return best, best_idx

    init = (jnp.full((BT,), BIG, jnp.float32), jnp.zeros((BT,), jnp.int32))
    best, best_idx = jax.lax.fori_loop(0, W, body, init)
    kout_ref[...] = best
    iout_ref[...] = best_idx


@functools.partial(jax.jit, static_argnames=("BT", "interpret"))
def minplus_pallas(
    kprev: jnp.ndarray,
    cost: jnp.ndarray,
    *,
    BT: int = DEFAULT_BT,
    interpret: bool = True,
) -> tuple:
    """One DP row update via Pallas. Same contract as
    :func:`repro.kernels.ref.minplus_step_ref`.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container has no TPU); on TPU hardware pass ``interpret=False``.
    """
    kprev = kprev.astype(jnp.float32)
    cost = cost.astype(jnp.float32)
    Tp = kprev.shape[0]
    W = cost.shape[0]
    pad_t = (-Tp) % BT
    Tpad = Tp + pad_t
    kprev_pad = jnp.concatenate(
        [jnp.full((W,), BIG, jnp.float32), kprev, jnp.full((pad_t,), BIG, jnp.float32)]
    )
    grid = (Tpad // BT,)
    kout, iout = pl.pallas_call(
        functools.partial(_minplus_kernel, BT=BT, W=W),
        grid=grid,
        in_specs=[
            # previous row stays whole in VMEM: every tile reads a sliding band
            pl.BlockSpec(kprev_pad.shape, lambda ot: (0,)),
            pl.BlockSpec(cost.shape, lambda ot: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BT,), lambda ot: (ot,)),
            pl.BlockSpec((BT,), lambda ot: (ot,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tpad,), jnp.float32),
            jax.ShapeDtypeStruct((Tpad,), jnp.int32),
        ],
        interpret=interpret,
    )(kprev_pad, cost)
    return kout[:Tp], iout[:Tp]
