"""Pallas TPU kernel: banded min-plus (tropical) convolution for the
(MC)^2MKP dynamic program.

TPU adaptation (see DESIGN.md §3): the DP relaxation is not a matmul, so the
MXU is of no use — this is a VPU kernel. We tile the *output* row into
``BT``-sized blocks held in VMEM; the previous DP row is kept whole in VMEM
(rows are ``4·(T+1)`` bytes — up to ~4 MB for T = 1M, within the 16 MB VMEM
budget for realistic scheduling sizes) with a ``W``-entry BIG prefix so every
banded read is an in-bounds dynamic slice. The inner ``fori_loop`` walks the
band, performing length-``BT`` vector min/argmin updates — (8,128)-friendly
when ``BT`` is a multiple of 1024.

The batched engine (DESIGN.md §9) is the source of truth: one ``(b, ot)``
grid over independent batch elements, each with its own previous row resident
in VMEM. The single-instance entry point is its ``B = 1`` slice.

Layout (per batch element):
  kprev_pad : (W + Tp,)  previous row, first W entries = BIG
  cost      : (W,)       class cost table, padded with BIG
  out tiles : (BT,) values + (BT,) int32 argmin
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG

__all__ = ["minplus_pallas", "minplus_pallas_batch", "tpu_tuned_bt", "DEFAULT_BT"]

DEFAULT_BT = 1024  # 8 sublanes x 128 lanes

TPU_VMEM_BYTES = 16 * 2**20  # per-core VMEM on current TPU generations


def tpu_tuned_bt(
    Tp: int,
    W: int,
    vmem_bytes: int = TPU_VMEM_BYTES,
    fraction: float = 0.75,
) -> int:
    """Output-tile size for real TPU hardware, derived from the VMEM budget.

    Per grid program the kernel keeps resident (all float32, so the (8,128)
    min tile = 1024 elements is the BT granularity):

      * the whole padded previous row: ``4 * (W + Tpad)`` bytes (band reads
        are in-place dynamic slices of it — no extra window copy),
      * the cost row: ``4 * W`` bytes,
      * the value + argmin output tiles: ``8 * BT`` bytes, doubled for
        pipelining (Pallas double-buffers output blocks across grid steps).

    Picks the LARGEST ``BT`` in {8192, ..., 1024} whose footprint fits in
    ``fraction`` of VMEM, clamped so the tile never overshoots the padded
    row (a tile wider than the row just computes discarded outputs) —
    larger tiles mean fewer grid programs re-reading the row. Rows too
    long for residency fall back to ``BT = 1024`` (the compiler will
    spill; a segmented-row layout is future work).
    """
    budget = int(vmem_bytes * fraction)
    row_cap = -(-int(Tp) // DEFAULT_BT) * DEFAULT_BT  # row rounded to tiles
    for bt in (8192, 4096, 2048, 1024):
        if bt > max(row_cap, DEFAULT_BT):
            continue
        tpad = -(-int(Tp) // bt) * bt
        resident = 4 * (int(W) + tpad) + 4 * int(W) + 2 * 8 * bt
        if resident <= budget:
            return bt
    return DEFAULT_BT


def _minplus_batch_kernel(kprev_pad_ref, cost_ref, kout_ref, iout_ref, *, BT: int, W: int):
    """Grid is ``(b, ot)``; each program owns one output tile of one batch
    element, with that element's whole padded previous row resident in VMEM
    (block ``(1, W + Tpad)`` selected by the batch grid axis)."""
    ot = pl.program_id(1)
    base = ot * BT  # absolute t of this tile's first element

    def body(j, carry):
        best, best_idx = carry
        # window[dt] = kprev_pad[W + base + dt - j]  == K_{i-1}[base + dt - j]
        window = kprev_pad_ref[0, pl.dslice(W + base - j, BT)]
        cand = window + cost_ref[0, j]
        cand = jnp.where(cand >= BIG, BIG, cand)
        improved = cand < best
        best = jnp.where(improved, cand, best)
        best_idx = jnp.where(improved, jnp.full((BT,), j, jnp.int32), best_idx)
        return best, best_idx

    init = (jnp.full((BT,), BIG, jnp.float32), jnp.zeros((BT,), jnp.int32))
    best, best_idx = jax.lax.fori_loop(0, W, body, init)
    kout_ref[0, ...] = best
    iout_ref[0, ...] = best_idx


def _minplus_pallas_call(kprev, cost, BT: int, interpret: bool) -> tuple:
    """Unjitted body shared by both entry points (jit-of-jit would trace a
    second wrapper per shape for zero caching benefit)."""
    kprev = kprev.astype(jnp.float32)
    cost = cost.astype(jnp.float32)
    B, Tp = kprev.shape
    W = cost.shape[1]
    pad_t = (-Tp) % BT
    Tpad = Tp + pad_t
    kprev_pad = jnp.concatenate(
        [
            jnp.full((B, W), BIG, jnp.float32),
            kprev,
            jnp.full((B, pad_t), BIG, jnp.float32),
        ],
        axis=1,
    )
    grid = (B, Tpad // BT)
    kout, iout = pl.pallas_call(
        functools.partial(_minplus_batch_kernel, BT=BT, W=W),
        grid=grid,
        in_specs=[
            # previous rows stay whole in VMEM: every tile reads a sliding band
            pl.BlockSpec((1, W + Tpad), lambda b, ot: (b, 0)),
            pl.BlockSpec((1, W), lambda b, ot: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BT), lambda b, ot: (b, ot)),
            pl.BlockSpec((1, BT), lambda b, ot: (b, ot)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tpad), jnp.float32),
            jax.ShapeDtypeStruct((B, Tpad), jnp.int32),
        ],
        interpret=interpret,
    )(kprev_pad, cost)
    return kout[:, :Tp], iout[:, :Tp]


@functools.partial(jax.jit, static_argnames=("BT", "interpret"))
def minplus_pallas_batch(
    kprev: jnp.ndarray,
    cost: jnp.ndarray,
    *,
    BT: int = DEFAULT_BT,
    interpret: bool = True,
) -> tuple:
    """Batched DP row update via Pallas. Same contract as
    :func:`repro.kernels.ref.minplus_step_ref_batch`: ``kprev (B, T+1)``,
    ``cost (B, W)`` -> ``(B, T+1)`` values + int32 argmins.

    One ``(b, ot)`` grid; batch elements are independent, so the grid is
    embarrassingly parallel across both axes. ``interpret=True`` executes the
    kernel body in Python on CPU (this container has no TPU); on TPU hardware
    pass ``interpret=False``.
    """
    return _minplus_pallas_call(kprev, cost, BT, interpret)


@functools.partial(jax.jit, static_argnames=("BT", "interpret"))
def minplus_pallas(
    kprev: jnp.ndarray,
    cost: jnp.ndarray,
    *,
    BT: int = DEFAULT_BT,
    interpret: bool = True,
) -> tuple:
    """One DP row update via Pallas: the ``B = 1`` slice of the batched
    kernel. Same contract as :func:`repro.kernels.ref.minplus_step_ref`."""
    kout, iout = _minplus_pallas_call(
        jnp.asarray(kprev)[None], jnp.asarray(cost)[None], BT, interpret
    )
    return kout[0], iout[0]
