"""Pallas TPU flash attention (FlashAttention-2 style), fwd + bwd.

TPU adaptation: q/k/v blocks tiled into VMEM; the (Bq, Bk) score tile lives
only in registers/VMEM — attention probabilities NEVER touch HBM, which is
the dominant memory-roofline term of the naive XLA path at train shapes
(EXPERIMENTS.md §Perf: ~65 of 84 GB/layer on deepseek-7b train_4k).

Supported: causal / sliding-window / bidirectional masks, GQA (kv heads
indexed ``h // G`` in the BlockSpec index maps), optional score softcap
(gemma2), fp32 accumulation. Shapes: q (B, H, Sq, D), k/v (B, Hkv, Sk, D).

Backward follows FlashAttention-2: a precomputed row term
``delta = rowsum(dO * O)``, a dq kernel (grid over q blocks) and a dk/dv
kernel (grid over kv blocks, inner loop over q blocks x GQA group).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _mask_tile(q_ids, k_ids, kind: str, window: int):
    qi = q_ids[:, None]
    ki = k_ids[None, :]
    if kind == "bidirectional":
        return jnp.ones((q_ids.shape[0], k_ids.shape[0]), jnp.bool_)
    if kind == "causal":
        return ki <= qi
    if kind == "sliding":
        return (ki <= qi) & (ki > qi - window)
    raise ValueError(kind)


def _apply_softcap(s, softcap):
    if softcap:
        return softcap * jnp.tanh(s / softcap)
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, Bq, Bk, Sk, D, kind,
                window, softcap, scale):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (Bq, D)
    q_ids = qi * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq,), 0)

    nk = Sk // Bk
    if kind in ("causal", "sliding"):
        # blocks strictly above the diagonal band contribute nothing
        hi = jnp.minimum(((qi + 1) * Bq + Bk - 1) // Bk, nk)
    else:
        hi = nk

    def body(j, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.dslice(j * Bk, Bk)].astype(jnp.float32)  # (Bk, D)
        v = v_ref[0, 0, pl.dslice(j * Bk, Bk)].astype(jnp.float32)
        s = q @ k.T  # (Bq, Bk)
        s = _apply_softcap(s, softcap)
        k_ids = j * Bk + jax.lax.broadcasted_iota(jnp.int32, (Bk,), 0)
        mask = _mask_tile(q_ids, k_ids, kind, window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l

    acc0 = jnp.zeros((Bq, D), jnp.float32)
    m0 = jnp.full((Bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    l_safe = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = (acc / l_safe[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0] = m + jnp.log(l_safe)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               Bq, Bk, Sk, D, kind, window, softcap, scale):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]
    q_ids = qi * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq,), 0)
    nk = Sk // Bk
    hi = jnp.minimum(((qi + 1) * Bq + Bk - 1) // Bk, nk) if kind in ("causal", "sliding") else nk

    def body(j, dq):
        k = k_ref[0, 0, pl.dslice(j * Bk, Bk)].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(j * Bk, Bk)].astype(jnp.float32)
        s_raw = q @ k.T
        s = _apply_softcap(s_raw, softcap)
        k_ids = j * Bk + jax.lax.broadcasted_iota(jnp.int32, (Bk,), 0)
        mask = _mask_tile(q_ids, k_ids, kind, window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        if softcap:
            ds = ds * (1.0 - jnp.square(jnp.tanh(s_raw / softcap)))
        ds = jnp.where(mask, ds, 0.0)
        return dq + ds @ k

    dq = jax.lax.fori_loop(0, hi, body, jnp.zeros((Bq, D), jnp.float32))
    dq_ref[0, 0] = (dq * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *,
                Bq, Bk, Sq, D, G, kind, window, softcap, scale):
    ki = pl.program_id(2)
    k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    k_ids = ki * Bk + jax.lax.broadcasted_iota(jnp.int32, (Bk,), 0)
    nq = Sq // Bq
    lo = (ki * Bk) // Bq if kind in ("causal", "sliding") else 0

    def outer(g, carry):
        def body(i, carry2):
            dk, dv = carry2
            q = q_ref[0, 0, g, pl.dslice(i * Bq, Bq)].astype(jnp.float32) * scale
            do = do_ref[0, 0, g, pl.dslice(i * Bq, Bq)].astype(jnp.float32)
            lse = lse_ref[0, 0, g, pl.dslice(i * Bq, Bq)]
            delta = delta_ref[0, 0, g, pl.dslice(i * Bq, Bq)]
            s_raw = q @ k.T  # (Bq, Bk)
            s = _apply_softcap(s_raw, softcap)
            q_ids = i * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq,), 0)
            mask = _mask_tile(q_ids, k_ids, kind, window)
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse[:, None])
            dv = dv + p.T @ do
            dp = do @ v.T
            ds = p * (dp - delta[:, None])
            if softcap:
                ds = ds * (1.0 - jnp.square(jnp.tanh(s_raw / softcap)))
            ds = jnp.where(mask, ds, 0.0)
            dk = dk + ds.T @ q
            return dk, dv

        return jax.lax.fori_loop(lo, nq, body, carry)

    init = (jnp.zeros((Bk, D), jnp.float32), jnp.zeros((Bk, D), jnp.float32))
    dk, dv = jax.lax.fori_loop(0, G, outer, init)
    # q was loaded pre-scaled, so ds^T @ q already carries the one scale factor
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# host-side wrappers
# ---------------------------------------------------------------------------


def _fwd(q, k, v, kind, window, softcap, scale, Bq, Bk, interpret):
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    grid = (B, H, Sq // Bq)
    kernel = functools.partial(
        _fwd_kernel, Bq=Bq, Bk=Bk, Sk=Sk, D=D, kind=kind, window=window,
        softcap=softcap, scale=scale,
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Bq), lambda b, h, i: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


def _bwd(q, k, v, o, lse, do, kind, window, softcap, scale, Bq, Bk, interpret):
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = H // Hkv
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)  # (B,H,Sq)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, Bq=Bq, Bk=Bk, Sk=Sk, D=D, kind=kind,
                          window=window, softcap=softcap, scale=scale),
        grid=(B, H, Sq // Bq),
        in_specs=[
            pl.BlockSpec((1, 1, Bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Sk, D), lambda b, h, i: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Bq, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Bq), lambda b, h, i: (b, h, i)),
            pl.BlockSpec((1, 1, Bq), lambda b, h, i: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, Bq, D), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv: grid over kv heads/blocks; q/do/lse viewed with the GQA group
    # axis exposed: (B, Hkv, G, Sq, D) so index maps slice per kv head
    qg = q.reshape(B, Hkv, G, Sq, D)
    dog = do.reshape(B, Hkv, G, Sq, D)
    lseg = lse.reshape(B, Hkv, G, Sq)
    deltag = delta.reshape(B, Hkv, G, Sq)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, Bq=Bq, Bk=Bk, Sq=Sq, D=D, G=G, kind=kind,
                          window=window, softcap=softcap, scale=scale),
        grid=(B, Hkv, Sk // Bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, Sq, D), lambda b, h, j: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, Bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, Bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, G, Sq, D), lambda b, h, j: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, G, Sq), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, Sq), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, Bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        interpret=interpret,
    )(qg, k, v, dog, lseg, deltag)
    return dq, dk, dv


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9)
)
def flash_attention(q, k, v, kind="causal", window=0, softcap=0.0,
                    scale=None, Bq=512, Bk=512, interpret=True):
    """q (B,H,Sq,D); k,v (B,Hkv,Sk,D). Returns (B,H,Sq,D)."""
    o, _ = _fwd(q, k, v, kind, window, softcap,
                scale if scale is not None else q.shape[-1] ** -0.5, Bq, Bk, interpret)
    return o


def _fa_fwd(q, k, v, kind, window, softcap, scale, Bq, Bk, interpret):
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    o, lse = _fwd(q, k, v, kind, window, softcap, scale, Bq, Bk, interpret)
    return o, (q, k, v, o, lse)


def _fa_bwd(kind, window, softcap, scale, Bq, Bk, interpret, res, do):
    q, k, v, o, lse = res
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    dq, dk, dv = _bwd(q, k, v, o, lse, do, kind, window, softcap, scale, Bq, Bk, interpret)
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)
