"""Pallas TPU kernels for the framework's compute hot-spots.

``minplus``: banded min-plus (tropical) convolution — the inner relaxation of
the (MC)^2MKP dynamic program. ``ops`` exposes the dispatching wrapper,
``ref`` the pure-jnp oracle used by the correctness sweeps.

``flash_attention``: FlashAttention-2-style fused attention (fwd + bwd) —
attention probabilities never touch HBM; selected via ``attn_impl='pallas'``.
"""

from .flash_attention import flash_attention
from .minplus import minplus_pallas, minplus_pallas_batch
from .ops import BIG, minplus_step, minplus_step_batch
from .ref import minplus_step_ref, minplus_step_ref_batch

__all__ = [
    "minplus_step",
    "minplus_step_batch",
    "minplus_pallas",
    "minplus_pallas_batch",
    "minplus_step_ref",
    "minplus_step_ref_batch",
    "BIG",
    "flash_attention",
]
