"""Kernels for the framework's compute hot-spots, with per-hardware dispatch.

``blocked``: tiled jnp min-plus — the CPU production backend (cache-blocked
BT x BW walk of the banded tropical convolution). ``minplus``: the Pallas
TPU kernel (VMEM-budget-tuned output tiles). ``gpu``: the Pallas-GPU
blocked variant. ``ref``: the dense jnp oracle used by the correctness
sweeps. ``ops`` exposes the dispatching wrappers — ``backend="auto"``
selects per ``jax.default_backend()``.

``flash_attention``: FlashAttention-2-style fused attention (fwd + bwd) —
attention probabilities never touch HBM; selected via ``attn_impl='pallas'``.
"""

from .blocked import auto_block_sizes, minplus_blocked, minplus_blocked_batch
from .flash_attention import flash_attention
from .gpu import minplus_pallas_gpu, minplus_pallas_gpu_batch
from .minplus import minplus_pallas, minplus_pallas_batch, tpu_tuned_bt
from .ops import BIG, DISPATCH_TABLE, minplus_step, minplus_step_batch, resolve_backend
from .ref import minplus_step_ref, minplus_step_ref_batch

__all__ = [
    "minplus_step",
    "minplus_step_batch",
    "minplus_blocked",
    "minplus_blocked_batch",
    "minplus_pallas",
    "minplus_pallas_batch",
    "minplus_pallas_gpu",
    "minplus_pallas_gpu_batch",
    "minplus_step_ref",
    "minplus_step_ref_batch",
    "auto_block_sizes",
    "tpu_tuned_bt",
    "resolve_backend",
    "DISPATCH_TABLE",
    "BIG",
    "flash_attention",
]
