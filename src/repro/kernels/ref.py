"""Pure-jnp oracle for the banded min-plus (tropical) convolution.

The (MC)^2MKP relaxation for one contiguous class (paper eq. 4, with
``N_i = {0..U_i}``, ``w_ij = j``) is

    K_i[t]   = min_{0 <= j <= min(W-1, t)}  K_{i-1}[t - j] + C_i[j]
    I_i[t]   = argmin_j ...   (first minimum wins, matching Algorithm 1's
                               strict-improvement update over ascending j)

which is a min-plus convolution of the previous DP row with the class's cost
table, banded to width ``W = U_i + 1``. This module is the reference
implementation the Pallas kernel is validated against.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["minplus_step_ref", "BIG"]

# Large-but-finite stand-in for +inf: keeps arithmetic NaN-free in float32
# while dominating any real cost (energy values in this codebase are << 1e30).
# A plain Python float so Pallas kernels can close over it as a literal.
BIG = 1e30


def minplus_step_ref(kprev: jnp.ndarray, cost: jnp.ndarray):
    """One DP row update.

    Args:
      kprev: ``(T+1,)`` previous row ``Z_{i-1}`` (BIG where infeasible).
      cost:  ``(W,)`` class cost table ``C_i(0..U_i)`` padded with BIG.

    Returns:
      (kout, iout): ``(T+1,)`` new row and ``(T+1,)`` int32 argmin item j.
    """
    kprev = kprev.astype(jnp.float32)
    cost = cost.astype(jnp.float32)
    Tp = kprev.shape[0]
    W = cost.shape[0]
    t = jnp.arange(Tp)[:, None]  # (Tp, 1)
    j = jnp.arange(W)[None, :]  # (1, W)
    src = t - j  # index into kprev
    valid = src >= 0
    gathered = jnp.where(valid, kprev[jnp.clip(src, 0, Tp - 1)], BIG)
    cand = gathered + cost[None, :]
    cand = jnp.where(valid, cand, BIG)
    # saturate: anything that touched BIG stays BIG (avoid BIG+x drift)
    cand = jnp.where(cand >= BIG, BIG, cand)
    kout = cand.min(axis=1)
    iout = cand.argmin(axis=1).astype(jnp.int32)
    return kout, iout
