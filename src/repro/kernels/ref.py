"""Pure-jnp oracle for the banded min-plus (tropical) convolution.

The (MC)^2MKP relaxation for one contiguous class (paper eq. 4, with
``N_i = {0..U_i}``, ``w_ij = j``) is

    K_i[t]   = min_{0 <= j <= min(W-1, t)}  K_{i-1}[t - j] + C_i[j]
    I_i[t]   = argmin_j ...   (first minimum wins, matching Algorithm 1's
                               strict-improvement update over ascending j)

which is a min-plus convolution of the previous DP row with the class's cost
table, banded to width ``W = U_i + 1``. This module is the reference
implementation the Pallas kernel is validated against.

The batched form is the source of truth (DESIGN.md §9); the single-instance
oracle is its ``B = 1`` slice, so tie-breaking can never diverge between the
two paths.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["minplus_step_ref", "minplus_step_ref_batch", "BIG"]

# Large-but-finite stand-in for +inf: keeps arithmetic NaN-free in float32
# while dominating any real cost (energy values in this codebase are << 1e30).
# A plain Python float so Pallas kernels can close over it as a literal.
BIG = 1e30


def minplus_step_ref_batch(kprev: jnp.ndarray, cost: jnp.ndarray):
    """Batched DP row update — ``B`` independent instances at once.

    Args:
      kprev: ``(B, T+1)`` previous rows ``Z_{i-1}`` (BIG where infeasible).
      cost:  ``(B, W)`` per-instance class cost tables ``C_i(0..U_i)``,
        padded with BIG.

    Returns:
      (kout, iout): ``(B, T+1)`` new rows and ``(B, T+1)`` int32 argmin item
      ``j`` (first minimum along ascending ``j`` wins).
    """
    kprev = jnp.asarray(kprev).astype(jnp.float32)
    cost = jnp.asarray(cost).astype(jnp.float32)
    Tp = kprev.shape[1]
    W = cost.shape[1]
    t = jnp.arange(Tp)[:, None]  # (Tp, 1)
    j = jnp.arange(W)[None, :]  # (1, W)
    src = t - j  # (Tp, W) index into each kprev row
    valid = src >= 0
    gathered = jnp.take(kprev, jnp.clip(src, 0, Tp - 1), axis=1)  # (B, Tp, W)
    cand = jnp.where(valid[None], gathered + cost[:, None, :], BIG)
    # saturate: anything that touched BIG stays BIG (avoid BIG+x drift)
    cand = jnp.where(cand >= BIG, BIG, cand)
    kout = cand.min(axis=2)
    iout = cand.argmin(axis=2).astype(jnp.int32)
    return kout, iout


def minplus_step_ref(kprev: jnp.ndarray, cost: jnp.ndarray):
    """One DP row update: the ``B = 1`` slice of the batched oracle.

    Args:
      kprev: ``(T+1,)`` previous row ``Z_{i-1}`` (BIG where infeasible).
      cost:  ``(W,)`` class cost table ``C_i(0..U_i)`` padded with BIG.

    Returns:
      (kout, iout): ``(T+1,)`` new row and ``(T+1,)`` int32 argmin item j.
    """
    kout, iout = minplus_step_ref_batch(
        jnp.asarray(kprev)[None], jnp.asarray(cost)[None]
    )
    return kout[0], iout[0]
