from .checkpoint import (
    latest_checkpoint,
    load_checkpoint,
    load_checkpoint_arrays,
    save_checkpoint,
)

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "load_checkpoint_arrays",
    "latest_checkpoint",
]
