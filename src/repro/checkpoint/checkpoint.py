"""Dependency-free pytree checkpointing: .npz arrays + .json tree manifest.

Leaves are flattened with ``jax.tree_util.tree_flatten_with_path``; the path
strings key the npz entries, so save/restore round-trips arbitrary nested
dict/list/tuple/dataclass-free pytrees (the param trees in this codebase are
nested dicts). Scalars/ints/floats round-trip as 0-d arrays.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "load_checkpoint_arrays",
    "latest_checkpoint",
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:  # pragma: no cover
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(directory: str, step: int, tree: Any, extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for path, leaf in leaves_with_paths:
        k = _path_str(path)
        keys.append(k)
        arrays[k] = np.asarray(jax.device_get(leaf))
    base = os.path.join(directory, f"ckpt_{step:08d}")
    np.savez(base + ".npz", **arrays)
    manifest = {"step": step, "keys": keys, "extra": extra or {}}
    with open(base + ".json", "w") as f:
        json.dump(manifest, f)
    return base


def load_checkpoint(directory: str, step: int, like: Any):
    base = os.path.join(directory, f"ckpt_{step:08d}")
    with open(base + ".json") as f:
        manifest = json.load(f)
    data = np.load(base + ".npz")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path, leaf in leaves_with_paths:
        k = _path_str(path)
        arr = data[k]
        new_leaves.append(arr.astype(np.asarray(leaf).dtype).reshape(np.asarray(leaf).shape))
    tree = jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(like), new_leaves)
    return tree, manifest


def load_checkpoint_arrays(directory: str, step: int):
    """Schema-driven restore: the raw ``{path: np.ndarray}`` mapping plus the
    manifest, with no ``like`` tree required. For consumers whose restore
    target is not a fixed pytree — e.g. the campaign checkpoints of
    DESIGN.md §17, where the number of rounds (and whether a round carries
    recovery provenance) is data, not structure."""
    base = os.path.join(directory, f"ckpt_{step:08d}")
    with open(base + ".json") as f:
        manifest = json.load(f)
    with np.load(base + ".npz") as data:
        arrays = {k: data[k] for k in manifest["keys"]}
    return arrays, manifest


def latest_checkpoint(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[len("ckpt_") : -len(".json")])
        for f in os.listdir(directory)
        if f.startswith("ckpt_") and f.endswith(".json")
    ]
    return max(steps) if steps else None
