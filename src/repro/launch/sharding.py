"""Mesh context + sharding rules.

Models are written against *logical* axes; this module resolves them to mesh
axes at run time (or to no-ops when no mesh is active — smoke tests on CPU).

Logical axes:
  batch   -> ('pod', 'data') when the pod axis exists, else ('data',)
  fsdp    -> 'data'   (weight shards all-gathered at use; ZeRO-3 style)
  tensor  -> 'model'  (heads / ff / vocab / expert-hidden)
  expert  -> EP placement axes (('model',) or ('data','model'))
  seq     -> optional KV-cache sequence sharding for long-context decode

``set_mesh(mesh, rules)`` installs the active mesh; ``shard(x, *logical)``
applies a sharding constraint. ``param_pspecs(params)`` infers a
PartitionSpec tree from weight names (see naming conventions in
models/layers.py).
"""

from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "set_mesh",
    "current_mesh",
    "mesh_context",
    "shard",
    "logical_to_mesh",
    "param_pspecs",
    "axis_size",
]

_MESH = None
_RULES = {}

DEFAULT_RULES = {
    "batch": ("data",),
    "fsdp": ("data",),
    "tensor": ("model",),
    "expert": ("model",),
    "seq": None,
    # sequence-parallel residual activations: set to 'model' by the dry-run /
    # trainer for train/prefill shapes (divides the (L,B,S,d) residual stack
    # saved for backward by the tensor-parallel degree); None for decode.
    "act_seq": None,
}


def set_mesh(mesh, rules: Optional[dict] = None):
    global _MESH, _RULES
    _MESH = mesh
    _RULES = dict(DEFAULT_RULES)
    if mesh is not None and "pod" in mesh.axis_names:
        _RULES["batch"] = ("pod", "data")
    if rules:
        _RULES.update(rules)


def current_mesh():
    return _MESH


def rules():
    return dict(_RULES)


@contextmanager
def mesh_context(mesh, rules: Optional[dict] = None):
    prev_mesh, prev_rules = _MESH, dict(_RULES)
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        set_mesh(prev_mesh)
        _RULES.clear()
        _RULES.update(prev_rules)


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes behind a logical axis (1 if no mesh)."""
    if _MESH is None:
        return 1
    ax = _RULES.get(logical)
    if ax is None:
        return 1
    ax = (ax,) if isinstance(ax, str) else ax
    return int(np.prod([_MESH.shape[a] for a in ax]))


def logical_to_mesh(*logical) -> P:
    parts = []
    for name in logical:
        if name is None:
            parts.append(None)
            continue
        ax = _RULES.get(name, None)
        if ax is None:
            parts.append(None)
        elif isinstance(ax, (tuple, list)):
            parts.append(tuple(ax) if len(ax) > 1 else ax[0])
        else:
            parts.append(ax)
    return P(*parts)


def shard(x, *logical):
    """with_sharding_constraint against the active mesh (no-op without)."""
    if _MESH is None:
        return x
    spec = logical_to_mesh(*logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


# ---------------------------------------------------------------------------
# Parameter sharding rules (matched on the leaf's path string).
# Rules give the LOGICAL spec of the trailing dims; leading stacked-layer
# axes are padded with None.
# ---------------------------------------------------------------------------

_PARAM_RULES = [
    # embeddings / unembedding
    (r"(^|/)emb$", ("tensor", "fsdp")),  # (V, d)
    (r"(^|/)lm_head$", ("fsdp", "tensor")),  # (d, V)
    # attention
    (r"(^|/)(wq|wk|wv)$", ("fsdp", "tensor", None)),  # (d, H, hd)
    (r"(^|/)wo$", ("tensor", None, "fsdp")),  # (H, hd, d)
    # MLA
    (r"(^|/)(w_dq|w_dkv|w_kr)$", ("fsdp", None)),
    (r"(^|/)(w_uq|w_uk|w_uv)$", (None, "tensor", None)),  # (rank, H, hd)
    (r"(^|/)w_o_mla$", ("tensor", None, "fsdp")),
    # MoE — expert dim over EP axes; d/fe unsharded (the 'tensor' axis is a
    # subset of the EP axes in our configs, so using it twice would conflict)
    (r"experts/(w_gate|w_in)$", ("expert", None, None)),  # (E, d, fe)
    (r"experts/w_out$", ("expert", None, None)),  # (E, fe, d)
    (r"(^|/)router$", ("fsdp", None)),  # (d, E)
    # dense MLP
    (r"(^|/)(w_gate|w_in)$", ("fsdp", "tensor")),
    (r"(^|/)w_out$", ("tensor", "fsdp")),
    # mamba / xlstm projections
    (r"(^|/)in_proj$", ("fsdp", "tensor")),
    (r"(^|/)out_proj$", ("tensor", "fsdp")),
    (r"(^|/)conv_w$", (None, "tensor")),  # (K, conv_dim)
    (r"(^|/)(A_log|dt_bias|D)$", ("tensor",)),  # (H,)
    # mLSTM head-wise block-diagonal projections
    (r"(^|/)(wq_m|wk_m)$", (None, None, None)),  # (H, DV, DK) small
    (r"(^|/)wv_m$", (None, None, "tensor")),  # (H, DV, DV)
    (r"(^|/)(wi_gate|wf_gate|wo_gate_m)$", ("fsdp", None)),
    # sLSTM
    (r"(^|/)(rz|ri|rf|ro)$", (None, None, None)),  # (H, D, D) small
    (r"(^|/)w_zifo$", ("fsdp", None, None)),  # (d, 4, H*D)
    # frontends / misc projections
    (r"(^|/)(frame_proj|patch_proj)$", ("fsdp", "tensor")),
    (r"(^|/)mask_emb$", (None,)),
    # norms / biases / scalars: replicated
    (r".*", None),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axes_size(entry) -> int:
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else entry
    return int(np.prod([_MESH.shape[a] for a in axes])) if _MESH else 1


def infer_pspec(path: str, shape) -> P:
    ndim = len(shape)
    for pattern, logical in _PARAM_RULES:
        if re.search(pattern, path):
            if logical is None:
                return P()
            spec = list(logical_to_mesh(*logical))
            # pad leading stacked-layer axes
            while len(spec) < ndim:
                spec.insert(0, None)
            if len(spec) > ndim:  # rule longer than leaf (e.g. scalar) -> replicate
                return P()
            # drop axes that don't divide the dim (e.g. MQA kv=1 heads)
            for i, entry in enumerate(spec):
                if entry is not None and shape[i] % _axes_size(entry) != 0:
                    spec[i] = None
            return P(*spec)
    return P()


def param_pspecs(params):
    """PartitionSpec pytree matching ``params`` (requires active mesh)."""

    def leaf_spec(path, leaf):
        return infer_pspec(_path_str(path), tuple(np.shape(leaf)))

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def param_shardings(params):
    mesh = current_mesh()
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_pspecs(params))
