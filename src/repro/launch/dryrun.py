import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes and extract roofline terms.

MUST be run as its own process (the device-count flag binds at first jax
init). One combo per invocation:

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch deepseek-7b --shape train_4k --mesh pod \
        --out artifacts/dryrun/deepseek-7b.train_4k.pod.json

``--mesh pod`` = (data=16, model=16); ``--mesh multipod`` = (pod=2, 16, 16).
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HW, collective_bytes, roofline_terms, roofline_terms_from_hlo
from repro.launch.steps import (
    abstract_opt_state,
    abstract_params,
    batch_pspecs,
    build_prefill_step,
    build_serve_step,
    build_train_step,
    cache_pspecs,
    train_shardings,
)
from repro.models import init_cache, input_specs, supports_mode
from repro.models.model import _batch_struct


def configure(arch: str, shape: InputShape) -> tuple:
    """Per-(arch, shape) config tweaks + sharding rules (DESIGN.md §5)."""
    cfg = get_config(arch)
    rules = {}
    if shape.mode in ("train", "prefill"):
        rules["act_seq"] = "model"  # sequence-parallel residual activations
    if cfg.num_experts:
        cfg = cfg.replace(moe_impl="a2a" if shape.mode in ("train", "prefill") else "einsum")
        if cfg.num_experts >= 256:
            rules["expert"] = ("data", "model")  # one expert per device
    if shape.name == "long_500k" and cfg.attn_kind == "local_global":
        cfg = cfg.replace(long_context=True)  # gemma2: all-sliding serving mode
    return cfg, rules


def lower_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
              cfg_overrides: dict = None, rules_overrides: dict = None) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg, rules = configure(arch, shape)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    if rules_overrides:
        rules.update(rules_overrides)
    ok, reason = supports_mode(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode,
    }
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    shd.set_mesh(mesh, rules)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    params_struct = abstract_params(cfg)
    B, S = shape.global_batch, shape.seq_len

    if shape.mode == "train":
        step, opt = build_train_step(cfg)
        opt_struct = abstract_opt_state(cfg, params_struct)
        batch_struct = _batch_struct(cfg, B, S, "train")
        ps, os_, bs = train_shardings(cfg, params_struct, opt_struct, batch_struct, B)
        jitted = jax.jit(
            step, in_shardings=(ps, os_, bs), out_shardings=(ps, os_, None),
            donate_argnums=(0, 1),  # params/opt state update in place
        )
        lowered = jitted.lower(params_struct, opt_struct, batch_struct)
    elif shape.mode == "prefill":
        step = build_prefill_step(cfg)
        batch_struct = _batch_struct(cfg, B, S, "prefill")
        pspecs = shd.param_pspecs(params_struct)
        ns = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
        )
        bs = ns(batch_pspecs(cfg, batch_struct, B))
        jitted = jax.jit(step, in_shardings=(ns(pspecs), bs))
        lowered = jitted.lower(params_struct, batch_struct)
    else:  # decode
        step = build_serve_step(cfg)
        cache_struct = jax.eval_shape(lambda: init_cache(cfg, B, S))
        pspecs = shd.param_pspecs(params_struct)
        cspecs = cache_pspecs(cfg, cache_struct, B, S)
        ns = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
        )
        tok_struct = jax.ShapeDtypeStruct((B, 1), jax.numpy.int32)
        pos_struct = jax.ShapeDtypeStruct((), jax.numpy.int32)
        ba = batch_pspecs(cfg, tok_struct, B)
        jitted = jax.jit(
            step,
            in_shardings=(ns(pspecs), ns(cspecs), ns(ba), NamedSharding(mesh, P())),
            out_shardings=(ns(ba), ns(cspecs)),
            donate_argnums=(1,),  # KV cache updated in place
        )
        lowered = jitted.lower(params_struct, cache_struct, tok_struct, pos_struct)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)  # static (loop bodies once) — for reference
    terms = roofline_terms_from_hlo(hlo)  # loop-aware (the real numbers)
    terms_static = roofline_terms(cost, coll)

    # persist the partitioned HLO (zstd) so the analyzer can be improved and
    # re-run WITHOUT recompiling
    hlo_path = os.environ.get("DRYRUN_HLO_DIR")
    if hlo_path:
        import zstandard

        os.makedirs(hlo_path, exist_ok=True)
        fname = os.path.join(
            hlo_path, f"{arch}.{shape_name}.{'multipod' if multi_pod else 'pod'}.hlo.zst"
        )
        with open(fname, "wb") as f:
            f.write(zstandard.ZstdCompressor(level=6).compress(hlo.encode()))
        result["hlo_file"] = fname

    result.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        cost={k: cost.get(k) for k in ("flops", "bytes accessed", "transcendentals") if k in cost},
        collectives=coll,
        roofline=terms,
        roofline_static=terms_static,
    )
    if verbose:
        print(json.dumps({k: result[k] for k in ("arch", "shape", "mesh", "status")}))
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(
            "  roofline: compute %.3es memory %.3es collective %.3es -> %s"
            % (terms["t_compute_s"], terms["t_memory_s"], terms["t_collective_s"], terms["dominant"])
        )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    try:
        result = lower_one(args.arch, args.shape, args.mesh == "multipod")
    except Exception as e:  # record failures as artifacts too
        result = {
            "arch": args.arch, "shape": args.shape,
            "mesh": "2x16x16" if args.mesh == "multipod" else "16x16",
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(result["error"])

    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    if result["status"] == "error":
        raise SystemExit(1)


if __name__ == "__main__":
    main()
