"""Step builders (train / prefill / serve) + sharding spec assembly.

These are the SPMD programs the dry-run lowers and the drivers execute:
  train_step  : loss -> grads -> optimizer update (params/opt state 2-D sharded)
  prefill_step: forward over the full sequence
  serve_step  : ONE new token against a seq_len KV cache
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models import decode_fn, init_params, input_specs, loss_fn, prefill_fn
from ..optim.optimizers import AdafactorState, AdamState, get_optimizer
from . import sharding as shd

__all__ = [
    "build_train_step",
    "build_prefill_step",
    "build_serve_step",
    "abstract_params",
    "abstract_opt_state",
    "train_shardings",
    "batch_pspecs",
    "cache_pspecs",
]


def build_train_step(cfg: ModelConfig):
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(functools.partial(loss_fn, cfg=cfg))(
            params, batch=batch
        )
        updates, opt_state = opt.update(grads, opt_state, params)
        from ..optim.optimizers import apply_updates

        return apply_updates(params, updates), opt_state, loss

    return train_step, opt


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return prefill_fn(params, cfg, batch)

    return prefill_step


def build_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = decode_fn(params, cfg, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# abstract values (no allocation)
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def abstract_opt_state(cfg: ModelConfig, params_struct):
    opt = get_optimizer(cfg.optimizer, cfg.learning_rate)
    return jax.eval_shape(opt.init, params_struct)


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------


def _ns(spec: P):
    return NamedSharding(shd.current_mesh(), spec)


def opt_state_pspecs(cfg: ModelConfig, pspecs):
    name = cfg.optimizer
    if name == "sgd":
        return ()
    if name == "momentum":
        return pspecs
    if name == "adamw":
        return AdamState(step=P(), mu=pspecs, nu=pspecs)
    if name == "adafactor":
        vr = jax.tree.map(lambda s: P(*s[:-1]) if len(s) >= 2 else s, pspecs,
                          is_leaf=lambda x: isinstance(x, P))
        vc = jax.tree.map(lambda s: P(*s[:-2], s[-1]) if len(s) >= 2 else P(), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
        return AdafactorState(step=P(), vr=vr, vc=vc)
    raise ValueError(name)


def _batch_axes_for(B: int):
    """Logical batch axes that actually divide B (else unsharded)."""
    mesh = shd.current_mesh()
    rule = shd.rules()["batch"]
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if B % size == 0:
        return axes if len(axes) > 1 else axes[0]
    # try data only
    if B % mesh.shape["data"] == 0:
        return "data"
    return None


def batch_pspecs(cfg: ModelConfig, batch_struct, B: int):
    ba = _batch_axes_for(B)

    def spec(leaf):
        return P(ba, *([None] * (len(leaf.shape) - 1)))

    return jax.tree.map(spec, batch_struct)


def cache_pspecs(cfg: ModelConfig, cache_struct, B: int, S: int):
    """Heuristic per-leaf cache sharding (see DESIGN.md §5):
      batch dim -> batch axes (if divisible); else
      seq dim   -> 'data' (long-context: shard the KV cache sequence);
      largest remaining dim divisible by the tensor size -> 'model'.
    """
    mesh = shd.current_mesh()
    ba = _batch_axes_for(B)
    tensor_size = mesh.shape["model"]
    data_size = mesh.shape["data"]

    def spec(leaf):
        dims = list(leaf.shape)
        out = [None] * len(dims)
        batch_done = False
        if ba is not None:
            for i, dsz in enumerate(dims):
                if dsz == B:
                    out[i] = ba
                    batch_done = True
                    break
        data_taken = batch_done and (
            ba == "data" or (isinstance(ba, tuple) and "data" in ba)
        )
        if not data_taken:
            for i, dsz in enumerate(dims):
                if out[i] is None and dsz == S and S % data_size == 0:
                    out[i] = "data"
                    break
        # largest remaining dim divisible by the tensor size -> 'model'
        cands = [
            (dsz, i) for i, dsz in enumerate(dims)
            if out[i] is None and dsz % tensor_size == 0 and dsz >= tensor_size and dsz != S
        ]
        if cands:
            _, i = max(cands)
            out[i] = "model"
        return P(*out)

    return jax.tree.map(spec, cache_struct)


def train_shardings(cfg: ModelConfig, params_struct, opt_struct, batch_struct, B: int):
    pspecs = shd.param_pspecs(params_struct)
    ospecs = opt_state_pspecs(cfg, pspecs)
    bspecs = batch_pspecs(cfg, batch_struct, B)
    to_ns = lambda tree: jax.tree.map(_ns, tree, is_leaf=lambda x: isinstance(x, P))
    return to_ns(pspecs), to_ns(ospecs), to_ns(bspecs)
