"""Serving launcher: batched greedy decoding with a prefilled KV cache.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch gemma2-2b --smoke --batch 4 --prompt-len 32 --gen 16

Runs prefill over a batch of (synthetic) prompts, then steps the serve loop
(one token per sequence per step) — the same `serve_step` the multi-pod
dry-run lowers for `decode_32k` / `long_500k`.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import decode_fn, init_cache, init_params, supports_mode
from ..configs.base import INPUT_SHAPES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    ok, reason = supports_mode(cfg, INPUT_SHAPES["decode_32k"])
    if not ok:
        raise SystemExit(f"{args.arch}: {reason}")
    if cfg.num_experts:
        cfg = cfg.replace(moe_impl="einsum")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G
    cache = init_cache(cfg, B, max_len)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)).astype(np.int32))

    @jax.jit
    def step(params, cache, tok, pos):
        logits, cache = decode_fn(params, cfg, cache, tok, pos)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None], cache

    # teacher-forced prefill via the decode path (exercises cache writes)
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(P):
        nxt, cache = step(params, cache, prompts[:, t : t + 1], jnp.asarray(t, jnp.int32))
    t_prefill = time.time() - t0

    generated = []
    tok = nxt
    t0 = time.time()
    for t in range(P, P + G):
        generated.append(tok)
        tok, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
    jax.block_until_ready(tok)
    t_gen = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.arch} batch={B} prompt={P} gen={G}")
    print(f"prefill {t_prefill:.2f}s | decode {t_gen:.2f}s "
          f"({B * G / max(t_gen, 1e-9):.1f} tok/s on CPU)")
    for b in range(min(B, 2)):
        print(f"  seq{b}: {list(np.asarray(out[b][:12]))} ...")


if __name__ == "__main__":
    main()
