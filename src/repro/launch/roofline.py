"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh) — all in seconds, per training/serving
step, from the PER-DEVICE partitioned module:

  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (~50 GB/s/link ICI)

``cost_analysis()`` provides FLOPs and bytes. Collective bytes are parsed
from the optimized HLO text: for each collective op we take the RESULT
shapes (local/per-device in SPMD modules) and apply ring-algorithm
multipliers:

  all-gather         bytes ~ result * (n-1)/n
  all-reduce         bytes ~ 2 * size * (n-1)/n
  reduce-scatter     bytes ~ result * (n-1)
  all-to-all         bytes ~ result * (n-1)/n
  collective-permute bytes ~ result
"""

from __future__ import annotations

import re
from typing import Dict

__all__ = ["collective_bytes", "roofline_terms", "HW"]

HW = {
    "peak_flops": 197e12,  # bf16 per chip
    "hbm_bw": 819e9,  # bytes/s
    "link_bw": 50e9,  # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(line: str) -> int:
    """Sum of result-shape bytes: shapes appearing before the op keyword on
    the lhs of `=`."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    # result shapes are at the START of the rhs (possibly a tuple)
    rhs = lhs[1]
    op_pos = min((rhs.find(op) for op in _OPS if rhs.find(op) >= 0), default=-1)
    if op_pos < 0:
        return 0
    total = 0
    for dtype, dims in _SHAPE_RE.findall(rhs[:op_pos]):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-type per-device collective traffic in bytes."""
    out = {op: 0.0 for op in _OPS}
    counts = {op: 0 for op in _OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match op invocations, not fusions mentioning them
        op_found = None
        for op in _OPS:
            if f" {op}(" in stripped or f" {op}-start(" in stripped:
                op_found = op
                break
        if op_found is None:
            continue
        if stripped.startswith("ROOT"):
            stripped = stripped[len("ROOT "):]
        size = _result_bytes(stripped)
        n = max(_group_size(stripped), 2)
        if op_found == "all-gather":
            size = size * (n - 1) / n
        elif op_found == "all-reduce":
            size = 2 * size * (n - 1) / n
        elif op_found == "reduce-scatter":
            size = size * (n - 1)
        elif op_found == "all-to-all":
            size = size * (n - 1) / n
        out[op_found] += size
        counts[op_found] += 1
    out["_counts"] = counts
    out["total"] = float(sum(v for k, v in out.items() if k in _OPS))
    return out


def roofline_terms_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Loop-aware roofline terms via launch/hlo_analysis (the accurate path:
    compiled.cost_analysis() counts while-loop bodies once)."""
    from .hlo_analysis import analyze_hlo

    c = analyze_hlo(hlo_text)
    t_compute = c.flops / HW["peak_flops"]
    t_memory = c.mem_bytes / HW["hbm_bw"]
    t_coll = c.coll_total / HW["link_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "hlo_flops_per_device": c.flops,
        "hlo_bytes_per_device": c.mem_bytes,
        "collective_bytes_per_device": c.coll_total,
        "collective_bytes_by_type": dict(c.coll_bytes),
        "collective_counts": dict(c.coll_counts),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }


def roofline_terms(cost: dict, coll: Dict[str, float]) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / HW["peak_flops"]
    t_memory = byts / HW["hbm_bw"]
    t_coll = coll["total"] / HW["link_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "collective_bytes_per_device": coll["total"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
    }
