"""FL training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch deepseek-7b --smoke --rounds 20 --clients 6 --algorithm auto

Uses the assigned architecture's (reduced, unless --full) config as the FL
model, a simulated heterogeneous fleet, and the paper's scheduler for the
per-round workload split. On real hardware, point the estimator at measured
device profiles instead of the simulator.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..data import client_corpora, make_lm_examples
from ..fl import EnergyEstimator, FederatedServer, make_fleet, run_campaign
from ..models import init_params, loss_fn, param_count
from ..optim import sgd
from ..checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=6)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-batches", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--algorithm", default="auto")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family not in ("dense", "moe", "ssm", "hybrid"):
        raise SystemExit(f"{args.arch} ({cfg.family}) is not an LM; pick a decoder arch")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    print(f"arch={cfg.arch} ({'smoke' if args.smoke else 'full'}): "
          f"{param_count(params) / 1e6:.2f}M params")

    rng = np.random.default_rng(args.seed)
    fleet = make_fleet(rng, args.clients, max_batches=args.max_batches)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, args.clients, args.seq * 120, cfg.vocab_size)
    examples = [make_lm_examples(c, args.seq) for c in corpora]

    server = FederatedServer(
        loss_fn=lambda p, b: loss_fn(p, cfg, {"tokens": b}),
        init_params=params,
        client_optimizer=sgd(args.lr),
        estimator=est,
        algorithm=args.algorithm,
    )
    T = sum(d.max_batches for d in fleet) // 2
    t0 = time.time()
    hist = run_campaign(
        server, examples, args.rounds, round_T=T, batch_size=args.batch, rng=rng,
        on_round=lambda r: print(
            f"round {r.round_index:3d} loss {r.mean_loss:.4f} "
            f"energy {r.energy_joules:7.1f} J x={list(r.assignments)}"
        ),
    )
    print(f"\nwall {time.time() - t0:.1f}s  {hist.summary()}")
    if args.checkpoint_dir:
        path = save_checkpoint(args.checkpoint_dir, args.rounds, server.params,
                               extra={"arch": cfg.arch, "algorithm": args.algorithm})
        print(f"checkpoint: {path}")


if __name__ == "__main__":
    main()
