"""Loop-aware cost analysis of optimized (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scan-over-layers models by ~L×. This walker parses the HLO
module, builds the computation graph, and expands:

  * ``while`` ops by their parsed trip count (the scalar constant in the
    loop condition — scans lower to 0..N LT-loops),
  * ``fusion`` / ``call`` / ``custom-call(calls=...)`` bodies (FLOPs only —
    fusion internals don't touch HBM),

accumulating per-device:
  * flops        — exact dot FLOPs (2 * numel(result) * contraction size);
    elementwise/transcendental FLOPs are ignored (dots dominate these
    models by >100x),
  * mem_bytes    — 2 * result bytes of every materialized (non-fused-
    internal) op: a read+write HBM-traffic proxy,
  * coll_bytes   — per collective type, with ring-algorithm multipliers
    (see launch/roofline.py docstring).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# op kind = first `word(` whose argument list starts with % or ) — robust to
# tuple result types containing /*index=N*/ comments and layout annotations
_OP_RE = re.compile(r"([\w\-]+)\(\s*(?:%|\)|\d|s32|f32|bf16|pred|u32)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"(\d+)"')
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(text: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class _Op:
    name: str
    kind: str
    rhs: str
    result_bytes: int


@dataclass
class _Comp:
    name: str
    ops: List[_Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # %name -> shape text


@dataclass
class HloCost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    coll_counts: Dict[str, int] = field(default_factory=lambda: {c: 0 for c in _COLLECTIVES})

    @property
    def coll_total(self) -> float:
        return float(sum(self.coll_bytes.values()))

    def add(self, other: "HloCost", mult: float = 1.0, mem: bool = True):
        self.flops += mult * other.flops
        if mem:
            self.mem_bytes += mult * other.mem_bytes
        for c in _COLLECTIVES:
            self.coll_bytes[c] += mult * other.coll_bytes[c]
            self.coll_counts[c] += int(mult * other.coll_counts[c])


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], str]:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = _Comp(name=m.group(2))
                if m.group(1):
                    entry = cur.name
                # parameters: "name: shape, name: shape"
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,)]+(?:\([^)]*\))?)", m.group(3)):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        cur.shapes[name] = rhs.split(" ", 1)[0] if rhs else ""
        # cut metadata/backend_config off before searching for the op kind
        head = rhs.split(", metadata=")[0]
        om = _OP_RE.search(head)
        kind = om.group(1) if om else ""
        shape_part = head[: om.start(1)] if om else head
        cur.ops.append(_Op(name=name, kind=kind, rhs=rhs, result_bytes=_shape_bytes(shape_part)))
    return comps, entry


def _dot_flops(comp: _Comp, op: _Op) -> float:
    """2 * numel(result) * prod(contraction dims of lhs)."""
    res_dims = _first_shape_dims(op.rhs)
    if res_dims is None:
        return 0.0
    numel = 1
    for d in res_dims:
        numel *= d
    cm = _LHS_CDIMS_RE.search(op.rhs)
    if not cm:
        return 2.0 * numel
    cdims = [int(x) for x in cm.group(1).split(",")] if cm.group(1) else []
    # lhs operand: first %name inside dot(...)
    args = op.rhs[op.rhs.index("(") + 1 :]
    am = re.search(r"%([\w.\-]+)", args)
    contract = 1
    if am and am.group(1) in comp.shapes:
        lhs_dims = _first_shape_dims(comp.shapes[am.group(1)])
        if lhs_dims:
            for c in cdims:
                if c < len(lhs_dims):
                    contract *= lhs_dims[c]
    return 2.0 * numel * contract


def _operand_bytes(comp: _Comp, op: _Op, index: int) -> Optional[int]:
    """Bytes of the index-th %operand of an op (resolved in-computation)."""
    try:
        args = op.rhs[op.rhs.index("(") + 1 :]
    except ValueError:
        return None
    names = re.findall(r"%([\w.\-]+)", args)
    if index >= len(names):
        return None
    shape_txt = comp.shapes.get(names[index])
    return _shape_bytes(shape_txt) if shape_txt else None


def _effective_write_bytes(comps: Dict[str, _Comp], comp: _Comp, op: _Op) -> int:
    """HBM write size of an op. dynamic-update-slice (and fusions rooted in
    one — scan stacking) writes only the UPDATE slice in place, not the whole
    buffer; counting the full result would overstate scan-carry traffic by
    the trip count."""
    if op.kind == "dynamic-update-slice":
        ub = _operand_bytes(comp, op, 1)
        return ub if ub is not None else op.result_bytes
    if op.kind == "fusion":
        cm = _CALLS_RE.search(op.rhs)
        callee = comps.get(cm.group(1)) if cm else None
        if callee and callee.ops and callee.ops[-1].kind == "dynamic-update-slice":
            root = callee.ops[-1]
            ub = _operand_bytes(callee, root, 1)
            if ub is not None:
                return ub
    return op.result_bytes


def _group_size(rhs: str) -> int:
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return max(int(m.group(2)), 2)
    m = _GROUPS_LIST_RE.search(rhs)
    if m:
        return max(len(m.group(1).split(",")), 2)
    return 2


def _collective_cost(op: _Op, cost: HloCost):
    kind = op.kind.replace("-start", "")
    if kind not in _COLLECTIVES:
        return
    size = op.result_bytes
    n = _group_size(op.rhs)
    if kind == "all-gather":
        size = size * (n - 1) / n
    elif kind == "all-reduce":
        size = 2 * size * (n - 1) / n
    elif kind == "reduce-scatter":
        size = size * (n - 1)
    elif kind == "all-to-all":
        size = size * (n - 1) / n
    cost.coll_bytes[kind] += size
    cost.coll_counts[kind] += 1


def _trip_count(comps: Dict[str, _Comp], cond_name: str) -> int:
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for op in comp.ops:
        for m in _CONST_RE.finditer(op.rhs):
            best = max(best, int(m.group(1)))
    return best


def _comp_cost(comps: Dict[str, _Comp], name: str, memo: Dict[str, HloCost]) -> HloCost:
    if name in memo:
        return memo[name]
    memo[name] = HloCost()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    cost = HloCost()
    for op in comp.ops:
        if op.kind in ("dot", "convolution"):
            cost.flops += _dot_flops(comp, op)
            cost.mem_bytes += 2 * op.result_bytes
        elif op.kind.replace("-start", "") in _COLLECTIVES:
            _collective_cost(op, cost)
            cost.mem_bytes += 2 * op.result_bytes
        elif op.kind == "while":
            body = _BODY_RE.search(op.rhs)
            tm = _TRIP_RE.search(op.rhs)  # XLA annotates known trip counts
            if tm:
                trips = int(tm.group(1))
            else:
                cond = _COND_RE.search(op.rhs)
                trips = _trip_count(comps, cond.group(1)) if cond else 1
            if body:
                cost.add(_comp_cost(comps, body.group(1), memo), mult=trips)
        elif op.kind in ("fusion", "call", "custom-call", "async-start"):
            cm = _CALLS_RE.search(op.rhs)
            if cm:
                # FLOPs inside fusions count; their internals don't hit HBM
                cost.add(_comp_cost(comps, cm.group(1), memo), mem=False)
            cost.mem_bytes += 2 * _effective_write_bytes(comps, comp, op)
        elif op.kind == "conditional":
            for cm in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)[^,}]*%([\w.\-]+)", op.rhs):
                cost.add(_comp_cost(comps, cm.group(1), memo))
            cost.mem_bytes += 2 * op.result_bytes
        elif op.kind in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast"):
            pass  # no HBM traffic of note
        else:
            cost.mem_bytes += 2 * _effective_write_bytes(comps, comp, op)
    memo[name] = cost
    return cost


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        return HloCost()
    memo: Dict[str, HloCost] = {}
    return _comp_cost(comps, entry, memo)
