from .sharding import current_mesh, mesh_context, param_pspecs, set_mesh, shard

__all__ = ["set_mesh", "current_mesh", "mesh_context", "shard", "param_pspecs"]
