"""Production mesh builders (TPU v5e pods).

Functions, not module-level constants — importing this module never touches
jax device state. The dry-run sets XLA_FLAGS for 512 host devices BEFORE
importing jax (see dryrun.py); everything else sees the real device count.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_smoke_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256-chip pod) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # no explicit axis_types: Auto is the default wherever the kwarg exists,
    # and jax versions without jax.sharding.AxisType don't accept it
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes)
