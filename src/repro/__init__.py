"""Minimal-energy FL scheduling — the public facade (PR 8).

One import surface for the supported entrypoints: the :class:`Solver` verbs
(``solve`` / ``sweep`` / ``frontier`` / ``solve_fleet``), their result types,
the :class:`PlanPolicy` planning config, and the serving front-end. Anything
deeper (``repro.core.*``, ``repro.fl.*``, ``repro.serve.*``) is either
internal machinery or a deprecated warn-once shim —
``tests/test_public_api.py`` freezes this surface so new entrypoints must
land here deliberately.
"""

from .core import (
    CircuitBreaker,
    FleetSolution,
    ParetoFrontier,
    PlanPolicy,
    Problem,
    ProblemBatch,
    RetryPolicy,
    Solution,
    SolutionBatch,
    Solver,
    TransientEngineError,
)
from .fl.adaptive import DriftInjector, DriftPlan
from .fl.faults import FaultInjector, FaultPlan
from .serve import SchedulerService

__all__ = [
    "CircuitBreaker",
    "DriftInjector",
    "DriftPlan",
    "FaultInjector",
    "FaultPlan",
    "FleetSolution",
    "ParetoFrontier",
    "PlanPolicy",
    "Problem",
    "ProblemBatch",
    "RetryPolicy",
    "SchedulerService",
    "Solution",
    "SolutionBatch",
    "Solver",
    "TransientEngineError",
]
