"""Resilience primitives shared by the solver facade and the serve layer.

Three small, dependency-free pieces (DESIGN.md §17):

  * :class:`TransientEngineError` / :func:`is_transient` — the taxonomy.
    Transient failures (a flaky device runtime, an injected chaos fault)
    are worth retrying; anything else is a bug-shaped error and must
    propagate unchanged to the caller.
  * :class:`RetryPolicy` / :func:`retry_call` — bounded retries with
    exponential backoff and DETERMINISTIC jitter (seeded ``default_rng``):
    a chaos run replays the exact same delay sequence, so fault-injection
    tests stay reproducible from one integer seed.
  * :class:`CircuitBreaker` — classic closed → open → half-open gate.
    After ``failure_threshold`` consecutive failures the breaker opens and
    :meth:`CircuitBreaker.allow` answers False (callers route to their
    degraded path) until ``cooldown_s`` elapses; then exactly ONE probe is
    admitted at a time, and its outcome closes or re-opens the breaker.

Everything here is thread-safe: the serve layer calls it from the
coalescer and completer threads concurrently.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import numpy as np

__all__ = [
    "CircuitBreaker",
    "RetryPolicy",
    "TransientEngineError",
    "is_transient",
    "retry_call",
]


class TransientEngineError(RuntimeError):
    """An engine failure expected to clear on retry (flaky runtime, injected
    chaos fault). Retry/backoff layers act ONLY on this taxonomy — any other
    exception propagates unchanged, so real bugs are never retried away."""


def is_transient(exc: BaseException) -> bool:
    """True when ``exc`` is retry-worthy: a :class:`TransientEngineError`, or
    any exception carrying a truthy ``transient`` attribute (lets foreign
    error types opt in without inheriting)."""
    return isinstance(exc, TransientEngineError) or bool(getattr(exc, "transient", False))


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``max_attempts`` counts the FIRST try: ``max_attempts=3`` means one try
    plus at most two retries. Delay before retry ``k`` (1-based) is
    ``min(base_delay_s * backoff**(k-1), max_delay_s)`` stretched by up to
    ``jitter`` (a fraction, drawn from a ``seed``-ed generator — replayable).
    """

    max_attempts: int = 3
    base_delay_s: float = 0.001
    max_delay_s: float = 0.05
    backoff: float = 2.0
    jitter: float = 0.1
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")

    def make_rng(self) -> np.random.Generator:
        """A fresh jitter stream (each consumer owns one — sharing a stream
        across threads would make delays order-dependent)."""
        return np.random.default_rng(self.seed)

    def delay(self, attempt: int, rng: Optional[np.random.Generator] = None) -> float:
        d = min(self.base_delay_s * self.backoff ** (attempt - 1), self.max_delay_s)
        if self.jitter and rng is not None:
            d *= 1.0 + self.jitter * float(rng.random())
        return d


def retry_call(
    fn: Callable,
    policy: RetryPolicy,
    rng: Optional[np.random.Generator] = None,
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
):
    """Calls ``fn()`` under ``policy``: transient failures back off and retry
    up to ``policy.max_attempts`` total tries; non-transient failures (and
    the last transient one) re-raise unchanged."""
    attempt = 1
    while True:
        try:
            return fn()
        except BaseException as e:
            if not is_transient(e) or attempt >= policy.max_attempts:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(policy.delay(attempt, rng))
            attempt += 1


class CircuitBreaker:
    """Consecutive-failure circuit breaker: closed → open → half-open.

    * **closed** — calls flow; ``failure_threshold`` CONSECUTIVE failures
      (any success resets the count) trip it open.
    * **open** — :meth:`allow` is False: callers take their degraded path
      instead of hammering a failing engine.
    * **half-open** — after ``cooldown_s``, exactly one probe call is
      admitted at a time; success closes the breaker, failure re-opens it
      (and restarts the cooldown).

    ``clock`` is injectable for deterministic tests.
    """

    def __init__(
        self,
        failure_threshold: int = 3,
        cooldown_s: float = 0.25,
        clock: Callable[[], float] = time.monotonic,
    ):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = "closed"
        self._consecutive = 0
        self._opened_at: Optional[float] = None
        self._probing = False
        self._opens = 0
        self._probes = 0

    @property
    def state(self) -> str:
        """"closed", "open", or "half-open" (open + cooldown elapsed)."""
        with self._lock:
            if self._state == "open" and self._cooled():
                return "half-open"
            return self._state

    def _cooled(self) -> bool:
        return (
            self._opened_at is not None
            and self._clock() - self._opened_at >= self.cooldown_s
        )

    def allow(self) -> bool:
        """May the protected call run? True while closed; while open, True
        only for the single half-open probe after the cooldown."""
        with self._lock:
            if self._state == "closed":
                return True
            if self._cooled() and not self._probing:
                self._probing = True
                self._probes += 1
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._state = "closed"
            self._consecutive = 0
            self._opened_at = None
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self._consecutive += 1
            failed_probe = self._probing
            self._probing = False
            if self._state == "open":
                if failed_probe:  # re-open: restart the cooldown
                    self._opened_at = self._clock()
                    self._opens += 1
                return
            if self._consecutive >= self.failure_threshold:
                self._state = "open"
                self._opened_at = self._clock()
                self._opens += 1

    def stats(self) -> dict:
        with self._lock:
            return {
                "state": self._state,
                "consecutive_failures": self._consecutive,
                "opens": self._opens,
                "probes": self._probes,
            }
