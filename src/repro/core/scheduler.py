"""Regime detection + algorithm dispatch (the facade's solve internals).

Table 2 of the paper maps each marginal-cost regime to its lowest-complexity
optimal algorithm:

  regime      | no binding upper limits | with upper limits
  ------------|-------------------------|-------------------
  increasing  | MarIn                   | MarIn
  constant    | MarDecUn*               | MarCo
  decreasing  | MarDecUn                | MarDec
  arbitrary   | (MC)^2MKP DP            | (MC)^2MKP DP

(*constant marginals without upper limits: MarDecUn's Θ(n) single-resource
assignment is optimal there too, per Table 2.)

Since PR 7 (DESIGN.md §15) the supported entrypoint is the
:class:`repro.core.solver.Solver` facade — ``solve`` / ``sweep`` /
``frontier`` — which calls the private ``_schedule`` / ``_schedule_batch`` /
``_deadline_sweep`` implementations here. The old module-level names
(``schedule``, ``schedule_batch``, ``schedule_with_deadline``,
``deadline_sweep``) remain as bit-identical deprecated shims. Nothing here
solves "directly" anymore in the batched paths: every batch solve routes
through the :class:`~repro.core.sweep.SweepEngine` compile cache, and the
single-instance path delegates to the per-algorithm callables in
``ALGORITHMS``.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from . import baselines
from ._deprecation import warn_deprecated
from .jax_dp import solve_schedule_dp_jax
from .marginal import marco, mardec, mardecun, marin
from .marginal_jax import select_algorithm_batch
from .mc2mkp import solve_schedule_dp
from .problem import Problem, total_cost, validate_schedule
from .sweep import _solve_cached

__all__ = [
    "schedule",
    "schedule_batch",
    "deadline_sweep",
    "ALGORITHMS",
    "select_algorithm",
    "select_algorithm_batch",
]

# algorithm names that run the (MC)^2MKP DP — in the batched entry point all
# of these route through the one batched min-plus program
_DP_ALGORITHMS = {"dp", "dp_jax", "dp_batch", "dp_jax_pallas"}

ALGORITHMS: Dict[str, Callable] = {
    "dp": solve_schedule_dp,
    "dp_jax": solve_schedule_dp_jax,
    "dp_jax_pallas": lambda p: solve_schedule_dp_jax(p, backend="pallas"),
    "marin": marin,
    "marco": marco,
    "mardecun": mardecun,
    "mardec": mardec,
    # baselines (not total-cost-optimal in general; for comparison)
    "olar": baselines.olar,
    "uniform": baselines.uniform,
    "proportional": baselines.proportional,
    "greedy_marginal": baselines.greedy_marginal,
}


def select_algorithm(problem: Problem) -> str:
    """Lowest-complexity optimal algorithm for ``problem``'s regime (paper
    Table 2). The ``B = 1`` slice of
    :func:`~repro.core.marginal_jax.select_algorithm_batch` — one shared
    regime-detection + dispatch rule, so serial and batched "auto" can
    never disagree (DESIGN.md §13)."""
    return select_algorithm_batch([problem])[0]


# ---------------------------------------------------------------------------
# private implementations — the Solver facade's solve internals. The public
# module-level names below are deprecated warn-once shims over these; keeping
# one body per behavior is what makes the shims bit-identical by construction.
# ---------------------------------------------------------------------------


def _schedule(problem: Problem, algorithm: str = "auto", check: bool = True):
    """Single-instance solve; returns ``(x, resolved_algorithm)`` so the
    facade can report which Table-2 algorithm "auto" picked."""
    if algorithm == "auto":
        algorithm = select_algorithm(problem)
    try:
        fn = ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(f"unknown algorithm {algorithm!r}; options: auto, {sorted(ALGORITHMS)}")
    x = fn(problem)
    if check:
        validate_schedule(problem, x)
    return x, algorithm


def _schedule_batch(
    problems,
    algorithm: str = "auto",
    check: bool = True,
    backend=None,
    engine=None,
):
    """Batched solve: every DP-shaped solve goes through the sweep engine's
    shape-bucketed compile cache (DESIGN.md §10); "auto" takes the
    regime-split path (§13). Returns a list of ``(n_b,)`` int64 schedules."""
    problems = list(problems)
    if not problems:
        return []
    out = [None] * len(problems)
    dp_idx = []
    if algorithm == "auto":
        X = _solve_cached(problems, backend, engine, split_regimes=True)
        for b, p in enumerate(problems):
            out[b] = np.asarray(X[b, : p.n], dtype=np.int64)
    elif algorithm in _DP_ALGORITHMS:
        dp_idx = list(range(len(problems)))
        if algorithm == "dp_jax_pallas":
            backend = "pallas"
    else:
        for b, p in enumerate(problems):
            out[b] = _schedule(p, algorithm, check=False)[0]
    if dp_idx:
        X = _solve_cached(
            [problems[b] for b in dp_idx], backend, engine, split_regimes=False
        )
        for row, b in zip(X, dp_idx):
            out[b] = np.asarray(row[: problems[b].n], dtype=np.int64)
    if check:
        for p, x in zip(problems, out):
            validate_schedule(p, x)
    return out


def _schedule_with_deadline(
    problem: Problem,
    time_tables,
    deadline: float,
    algorithm: str = "auto",
) -> np.ndarray:
    """ε-constraint single solve: tighten, then :func:`_schedule`."""
    return _schedule(tighten_for_deadline(problem, time_tables, deadline), algorithm)[0]


def _deadline_sweep(
    problem: Problem,
    time_tables,
    deadlines,
    check: bool = True,
    backend=None,
    engine=None,
) -> np.ndarray:
    """Whole deadline grid in ONE batched DP solve; ``(B, n)`` int64, row
    ``b`` optimal for ``deadlines[b]``. Infeasible points raise ValueError
    naming the offending deadline."""
    deadlines = list(deadlines)
    tight = []
    for d in deadlines:
        try:
            tight.append(tighten_for_deadline(problem, time_tables, float(d)))
        except ValueError as e:
            raise ValueError(f"deadline_sweep point {d}: {e}") from e
    X = _solve_cached(tight, backend, engine, split_regimes=False)[:, : problem.n]
    if check:
        for p, x in zip(tight, X):
            validate_schedule(p, x)
    return X.astype(np.int64)


# ---------------------------------------------------------------------------
# deprecated shims (PR 7, DESIGN.md §15) — use repro.core.solver.Solver
# ---------------------------------------------------------------------------


def schedule(problem: Problem, algorithm: str = "auto", check: bool = True) -> np.ndarray:
    """Deprecated shim: use ``Solver().solve(problem)`` (`.schedule` on the
    returned :class:`~repro.core.solver.Solution`). Bit-identical — same
    regime dispatch, same per-algorithm callables."""
    warn_deprecated("schedule", "Solver().solve(problem).schedule")
    return _schedule(problem, algorithm, check)[0]


def schedule_batch(
    problems,
    algorithm: str = "auto",
    check: bool = True,
    backend=None,
    engine=None,
):
    """Deprecated shim: use ``Solver(engine=...).solve(problems)``
    (`.schedules` on the returned :class:`~repro.core.solver.SolutionBatch`).

    Dispatch (unchanged, now documented on the facade):
      * ``algorithm="auto"``: the engine's regime-split path — each
        instance's regime picks its algorithm (one shared rule with the
        serial dispatch), MarIn/MarCo instances ride the batched marginal
        selection kernel (§13), MarDecUn/MarDec solve on the host, and only
        the arbitrary-regime remainder pays the batched DP; results come
        back in original problem order.
      * any DP algorithm name (``dp``, ``dp_jax``, ``dp_batch``,
        ``dp_jax_pallas``): ALL instances go through the batched DP
        (``dp_jax_pallas`` selects the Pallas kernel backend).
      * any other named algorithm: a plain per-instance loop.

    ``engine``: an explicit :class:`~repro.core.sweep.SweepEngine` (e.g. a
    sharded one); ``None`` uses the process-wide default for ``backend``.
    Requesting a backend that contradicts the given engine's raises
    ValueError. Returns a list of ``(n_b,)`` int64 schedules.
    """
    warn_deprecated("schedule_batch", "Solver(engine=...).solve(problems).schedules")
    return _schedule_batch(problems, algorithm, check, backend, engine)


def schedule_cost(problem: Problem, algorithm: str = "auto") -> float:
    return total_cost(problem, _schedule(problem, algorithm)[0])


def schedule_with_deadline(
    problem: Problem,
    time_tables,
    deadline: float,
    algorithm: str = "auto",
) -> np.ndarray:
    """Deprecated shim: use ``Solver().solve(problem, deadline=D,
    time_tables=tt)``.

    Energy-minimal schedule subject to a round deadline (beyond-paper). The
    ε-constraint reduces cleanly to the SAME problem: a deadline on each
    device's computation time is just a tighter upper limit
    ``U_i' = max{j : time_i(j) <= deadline}`` — feasible sets stay
    intervals, so every optimal algorithm applies unchanged
    (:func:`tighten_for_deadline`). Raises ValueError if the deadline makes
    the instance infeasible.

    Args:
      time_tables: list of (U_i+1,) arrays; time_tables[i][j] = seconds for
        device i to train j batches (monotone non-decreasing).
      deadline: maximum allowed per-device time (the target round duration).
    """
    warn_deprecated(
        "schedule_with_deadline", "Solver().solve(problem, deadline=D, time_tables=tt)"
    )
    return _schedule_with_deadline(problem, time_tables, deadline, algorithm)


def tighten_for_deadline(problem: Problem, time_tables, deadline: float) -> Problem:
    """The deadline-tightened instance: ``U_i' = max{j : time_i(j) <= D}``
    (clipped to ``U_i``). Raises ValueError if infeasible — a device cannot
    meet its lower limit, or fleet capacity drops below ``T``.

    NOT deprecated: this is the ε-constraint reduction itself, shared by the
    facade's ``sweep``/``frontier`` paths and ``repro.core.pareto``."""
    new_upper = []
    for i in range(problem.n):
        t = np.asarray(time_tables[i], dtype=np.float64)
        feas = np.nonzero(t <= deadline)[0]
        u = int(feas.max()) if len(feas) else -1
        if u < int(problem.lower[i]):
            raise ValueError(
                f"deadline {deadline} infeasible: device {i} cannot do its "
                f"lower limit {int(problem.lower[i])} batches in time"
            )
        new_upper.append(min(u, int(problem.upper[i])))
    if sum(new_upper) < problem.T:
        raise ValueError(
            f"deadline {deadline} infeasible: fleet capacity "
            f"{sum(new_upper)} < T={problem.T}"
        )
    return Problem(
        T=problem.T,
        lower=problem.lower,
        upper=np.asarray(new_upper),
        cost_tables=tuple(
            tbl[: u + 1] for tbl, u in zip(problem.cost_tables, new_upper)
        ),
    )


def deadline_sweep(
    problem: Problem,
    time_tables,
    deadlines,
    check: bool = True,
    backend=None,
    engine=None,
) -> np.ndarray:
    """Deprecated shim: use ``Solver(engine=...).sweep(problem, tt,
    deadlines)`` — or :meth:`~repro.core.solver.Solver.frontier` for the
    pruned Pareto set.

    Energy-minimal schedules for a whole grid of deadlines in ONE batched DP
    solve: the ``B`` deadline-tightened instances (same ``n`` and ``T``,
    progressively looser ``U_i``) stack through the sweep engine, so the
    entire ε-constraint sweep costs one kernel launch — and, once its shape
    bucket is warm, zero compilations. Returns a ``(B, n)`` int64 array, row
    ``b`` optimal for ``deadlines[b]``; raises ValueError (naming the
    offending deadline) if any point is infeasible.
    """
    warn_deprecated("deadline_sweep", "Solver(engine=...).sweep(problem, tt, deadlines)")
    return _deadline_sweep(problem, time_tables, deadlines, check, backend, engine)
