"""Unified scheduling API: regime detection + algorithm dispatch.

``schedule(problem, algorithm="auto")`` picks the lowest-complexity optimal
algorithm for the detected marginal-cost regime (paper Table 2):

  regime      | no binding upper limits | with upper limits
  ------------|-------------------------|-------------------
  increasing  | MarIn                   | MarIn
  constant    | MarDecUn*               | MarCo
  decreasing  | MarDecUn                | MarDec
  arbitrary   | (MC)^2MKP DP            | (MC)^2MKP DP

(*constant marginals without upper limits: MarDecUn's Θ(n) single-resource
assignment is optimal there too, per Table 2.)
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from . import baselines
from .jax_dp import solve_schedule_dp_jax
from .marginal import marco, mardec, mardecun, marin
from .marginal_jax import select_algorithm_batch
from .mc2mkp import solve_schedule_dp
from .problem import Problem, total_cost, validate_schedule
from .sweep import solve_dp_batch_cached, solve_schedule_batch_cached

__all__ = [
    "schedule",
    "schedule_batch",
    "deadline_sweep",
    "ALGORITHMS",
    "select_algorithm",
    "select_algorithm_batch",
]

# algorithm names that run the (MC)^2MKP DP — in the batched entry point all
# of these route through the one batched min-plus program
_DP_ALGORITHMS = {"dp", "dp_jax", "dp_batch", "dp_jax_pallas"}

ALGORITHMS: Dict[str, Callable] = {
    "dp": solve_schedule_dp,
    "dp_jax": solve_schedule_dp_jax,
    "dp_jax_pallas": lambda p: solve_schedule_dp_jax(p, backend="pallas"),
    "marin": marin,
    "marco": marco,
    "mardecun": mardecun,
    "mardec": mardec,
    # baselines (not total-cost-optimal in general; for comparison)
    "olar": baselines.olar,
    "uniform": baselines.uniform,
    "proportional": baselines.proportional,
    "greedy_marginal": baselines.greedy_marginal,
}


def select_algorithm(problem: Problem) -> str:
    """Lowest-complexity optimal algorithm for ``problem``'s regime (paper
    Table 2). The ``B = 1`` slice of
    :func:`~repro.core.marginal_jax.select_algorithm_batch` — one shared
    regime-detection + dispatch rule, so serial and batched "auto" can
    never disagree (DESIGN.md §13)."""
    return select_algorithm_batch([problem])[0]


def schedule(problem: Problem, algorithm: str = "auto", check: bool = True) -> np.ndarray:
    if algorithm == "auto":
        algorithm = select_algorithm(problem)
    try:
        fn = ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(f"unknown algorithm {algorithm!r}; options: auto, {sorted(ALGORITHMS)}")
    x = fn(problem)
    if check:
        validate_schedule(problem, x)
    return x


def schedule_batch(
    problems,
    algorithm: str = "auto",
    check: bool = True,
    backend=None,
    engine=None,
):
    """Solves ``B`` instances, batching every solve into regime-wide jitted
    programs (DESIGN.md §9/§13) routed through the sweep engine's
    shape-bucketed compile cache (§10).

    Dispatch mirrors :func:`schedule`:
      * ``algorithm="auto"``: the engine's regime-split path — each
        instance's regime picks its algorithm (one shared rule with the
        serial dispatch), MarIn/MarCo instances ride the batched marginal
        selection kernel (§13), MarDecUn/MarDec solve on the host, and only
        the arbitrary-regime remainder pays the batched DP; results come
        back in original problem order.
      * any DP algorithm name (``dp``, ``dp_jax``, ``dp_batch``,
        ``dp_jax_pallas``): ALL instances go through the batched DP
        (``dp_jax_pallas`` selects the Pallas kernel backend).
      * any other named algorithm: a plain per-instance loop.

    ``engine``: an explicit :class:`~repro.core.sweep.SweepEngine` (e.g. a
    sharded one); ``None`` uses the process-wide default for ``backend``
    (``backend=None`` -> "auto": the per-hardware dispatch table — blocked
    jnp on CPU, tuned Pallas on TPU/GPU), so repeated shapes anywhere in
    the process skip compilation. Requesting a backend that contradicts the
    given engine's (e.g. ``dp_jax_pallas`` with a "blocked" engine) raises
    ValueError instead of silently running the engine's kernel.

    Returns a list of ``(n_b,)`` int64 schedules, one per input instance.
    """
    problems = list(problems)
    if not problems:
        return []
    out = [None] * len(problems)
    dp_idx = []
    if algorithm == "auto":
        X = solve_schedule_batch_cached(problems, backend=backend, engine=engine)
        for b, p in enumerate(problems):
            out[b] = np.asarray(X[b, : p.n], dtype=np.int64)
    elif algorithm in _DP_ALGORITHMS:
        dp_idx = list(range(len(problems)))
        if algorithm == "dp_jax_pallas":
            backend = "pallas"
    else:
        for b, p in enumerate(problems):
            out[b] = schedule(p, algorithm, check=False)
    if dp_idx:
        X = solve_dp_batch_cached(
            [problems[b] for b in dp_idx], backend=backend, engine=engine
        )
        for row, b in zip(X, dp_idx):
            out[b] = np.asarray(row[: problems[b].n], dtype=np.int64)
    if check:
        for p, x in zip(problems, out):
            validate_schedule(p, x)
    return out


def schedule_cost(problem: Problem, algorithm: str = "auto") -> float:
    return total_cost(problem, schedule(problem, algorithm))


def schedule_with_deadline(
    problem: Problem,
    time_tables,
    deadline: float,
    algorithm: str = "auto",
) -> np.ndarray:
    """Energy-minimal schedule subject to a round deadline (beyond-paper).

    The paper optimizes energy alone and cites time/energy bi-objective work
    ([28]) as related; the epsilon-constraint version reduces cleanly to the
    SAME problem: a deadline on each device's computation time is just a
    tighter upper limit ``U_i' = max{j : time_i(j) <= deadline}`` — the
    feasible sets stay intervals, so every optimal algorithm applies
    unchanged.

    Args:
      time_tables: list of (U_i+1,) arrays; time_tables[i][j] = seconds for
        device i to train j batches (monotone non-decreasing).
      deadline: maximum allowed per-device time (the target round duration).

    Raises ValueError if the deadline makes the instance infeasible.
    """
    return schedule(tighten_for_deadline(problem, time_tables, deadline), algorithm)


def tighten_for_deadline(problem: Problem, time_tables, deadline: float) -> Problem:
    """The deadline-tightened instance: ``U_i' = max{j : time_i(j) <= D}``
    (clipped to ``U_i``). Raises ValueError if infeasible — a device cannot
    meet its lower limit, or fleet capacity drops below ``T``."""
    new_upper = []
    for i in range(problem.n):
        t = np.asarray(time_tables[i], dtype=np.float64)
        feas = np.nonzero(t <= deadline)[0]
        u = int(feas.max()) if len(feas) else -1
        if u < int(problem.lower[i]):
            raise ValueError(
                f"deadline {deadline} infeasible: device {i} cannot do its "
                f"lower limit {int(problem.lower[i])} batches in time"
            )
        new_upper.append(min(u, int(problem.upper[i])))
    if sum(new_upper) < problem.T:
        raise ValueError(
            f"deadline {deadline} infeasible: fleet capacity "
            f"{sum(new_upper)} < T={problem.T}"
        )
    return Problem(
        T=problem.T,
        lower=problem.lower,
        upper=np.asarray(new_upper),
        cost_tables=tuple(
            tbl[: u + 1] for tbl, u in zip(problem.cost_tables, new_upper)
        ),
    )


def deadline_sweep(
    problem: Problem,
    time_tables,
    deadlines,
    check: bool = True,
    backend=None,
    engine=None,
) -> np.ndarray:
    """Pareto-front builder: energy-minimal schedules for a whole grid of
    deadlines in ONE batched DP solve.

    Constructs the ``B`` deadline-tightened instances (same ``n`` and ``T``,
    progressively looser ``U_i``) and stacks them through the sweep engine
    (``engine``, or the shared default for ``backend``), so the entire
    epsilon-constraint sweep costs one kernel launch — and, once its shape
    bucket is warm, zero compilations.

    Returns a ``(B, n)`` int64 array, row ``b`` optimal for ``deadlines[b]``.
    Raises ValueError (naming the offending deadline) if any point is
    infeasible — probe feasibility first if sweeping below the makespan
    floor.
    """
    deadlines = list(deadlines)
    tight = []
    for d in deadlines:
        try:
            tight.append(tighten_for_deadline(problem, time_tables, float(d)))
        except ValueError as e:
            raise ValueError(f"deadline_sweep point {d}: {e}") from e
    X = solve_dp_batch_cached(tight, backend=backend, engine=engine)[:, : problem.n]
    if check:
        for p, x in zip(tight, X):
            validate_schedule(p, x)
    return X.astype(np.int64)
