"""Unified scheduling API: regime detection + algorithm dispatch.

``schedule(problem, algorithm="auto")`` picks the lowest-complexity optimal
algorithm for the detected marginal-cost regime (paper Table 2):

  regime      | no binding upper limits | with upper limits
  ------------|-------------------------|-------------------
  increasing  | MarIn                   | MarIn
  constant    | MarDecUn*               | MarCo
  decreasing  | MarDecUn                | MarDec
  arbitrary   | (MC)^2MKP DP            | (MC)^2MKP DP

(*constant marginals without upper limits: MarDecUn's Θ(n) single-resource
assignment is optimal there too, per Table 2.)
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from . import baselines
from .jax_dp import solve_schedule_dp_jax
from .marginal import marco, mardec, mardecun, marin
from .mc2mkp import solve_schedule_dp
from .problem import Problem, total_cost, validate_schedule

__all__ = ["schedule", "ALGORITHMS", "select_algorithm"]

ALGORITHMS: Dict[str, Callable] = {
    "dp": solve_schedule_dp,
    "dp_jax": solve_schedule_dp_jax,
    "dp_jax_pallas": lambda p: solve_schedule_dp_jax(p, backend="pallas"),
    "marin": marin,
    "marco": marco,
    "mardecun": mardecun,
    "mardec": mardec,
    # baselines (not total-cost-optimal in general; for comparison)
    "olar": baselines.olar,
    "uniform": baselines.uniform,
    "proportional": baselines.proportional,
    "greedy_marginal": baselines.greedy_marginal,
}


def select_algorithm(problem: Problem) -> str:
    regime = problem.regime()
    unlimited = bool(np.all(problem.upper - problem.lower >= problem.T - int(problem.lower.sum())))
    if regime == "increasing":
        return "marin"
    if regime == "constant":
        return "mardecun" if unlimited else "marco"
    if regime == "decreasing":
        return "mardecun" if unlimited else "mardec"
    return "dp"


def schedule(problem: Problem, algorithm: str = "auto", check: bool = True) -> np.ndarray:
    if algorithm == "auto":
        algorithm = select_algorithm(problem)
    try:
        fn = ALGORITHMS[algorithm]
    except KeyError:
        raise ValueError(f"unknown algorithm {algorithm!r}; options: auto, {sorted(ALGORITHMS)}")
    x = fn(problem)
    if check:
        validate_schedule(problem, x)
    return x


def schedule_cost(problem: Problem, algorithm: str = "auto") -> float:
    return total_cost(problem, schedule(problem, algorithm))


def schedule_with_deadline(
    problem: Problem,
    time_tables,
    deadline: float,
    algorithm: str = "auto",
) -> np.ndarray:
    """Energy-minimal schedule subject to a round deadline (beyond-paper).

    The paper optimizes energy alone and cites time/energy bi-objective work
    ([28]) as related; the epsilon-constraint version reduces cleanly to the
    SAME problem: a deadline on each device's computation time is just a
    tighter upper limit ``U_i' = max{j : time_i(j) <= deadline}`` — the
    feasible sets stay intervals, so every optimal algorithm applies
    unchanged.

    Args:
      time_tables: list of (U_i+1,) arrays; time_tables[i][j] = seconds for
        device i to train j batches (monotone non-decreasing).
      deadline: maximum allowed per-device time (the target round duration).

    Raises ValueError if the deadline makes the instance infeasible.
    """
    new_upper = []
    for i in range(problem.n):
        t = np.asarray(time_tables[i], dtype=np.float64)
        feas = np.nonzero(t <= deadline)[0]
        u = int(feas.max()) if len(feas) else -1
        if u < int(problem.lower[i]):
            raise ValueError(
                f"deadline {deadline} infeasible: device {i} cannot do its "
                f"lower limit {int(problem.lower[i])} batches in time"
            )
        new_upper.append(min(u, int(problem.upper[i])))
    if sum(new_upper) < problem.T:
        raise ValueError(
            f"deadline {deadline} infeasible: fleet capacity "
            f"{sum(new_upper)} < T={problem.T}"
        )
    tight = Problem(
        T=problem.T,
        lower=problem.lower,
        upper=np.asarray(new_upper),
        cost_tables=tuple(
            tbl[: u + 1] for tbl, u in zip(problem.cost_tables, new_upper)
        ),
    )
    return schedule(tight, algorithm)
