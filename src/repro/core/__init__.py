"""Core library: the paper's contribution.

Minimal Cost FL Schedule problem (Def. 1), the (MC)^2MKP knapsack problem and
its DP solution (Alg. 1), the monotone-regime algorithms MarIn/MarCo/
MarDecUn/MarDec (Algs. 2-7), cost-function families, and baselines.
"""

from .baselines import greedy_marginal, olar, proportional, random_schedule, uniform
from .costs import (
    DEVICE_CLASSES,
    device_fleet_problem,
    linear_cost,
    measured_cost,
    random_problem,
    sublinear_cost,
    superlinear_cost,
)
from .jax_dp import solve_schedule_dp_jax
from .marginal import marco, mardec, mardecun, marin
from .mc2mkp import (
    ItemClass,
    MC2MKPSolution,
    brute_force_schedule,
    mc2mkp_matrices,
    solve_mc2mkp,
    solve_schedule_dp,
)
from .problem import (
    Problem,
    remove_lower_limits,
    restore_lower_limits,
    total_cost,
    validate_schedule,
)
from .scheduler import ALGORITHMS, schedule, select_algorithm

__all__ = [
    "Problem",
    "remove_lower_limits",
    "restore_lower_limits",
    "total_cost",
    "validate_schedule",
    "ItemClass",
    "MC2MKPSolution",
    "solve_mc2mkp",
    "mc2mkp_matrices",
    "solve_schedule_dp",
    "solve_schedule_dp_jax",
    "brute_force_schedule",
    "marin",
    "marco",
    "mardecun",
    "mardec",
    "olar",
    "uniform",
    "proportional",
    "random_schedule",
    "greedy_marginal",
    "schedule",
    "select_algorithm",
    "ALGORITHMS",
    "DEVICE_CLASSES",
    "device_fleet_problem",
    "linear_cost",
    "superlinear_cost",
    "sublinear_cost",
    "measured_cost",
    "random_problem",
]
