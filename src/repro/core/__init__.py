"""Core library: the paper's contribution.

Minimal Cost FL Schedule problem (Def. 1), the (MC)^2MKP knapsack problem and
its DP solution (Alg. 1), the monotone-regime algorithms MarIn/MarCo/
MarDecUn/MarDec (Algs. 2-7), cost-function families, and baselines.

The supported solve entrypoint is the :class:`Solver` facade (DESIGN.md §15):
``Solver().solve(...)`` / ``.sweep(...)`` / ``.frontier(...)``. The legacy
module-level entrypoints (``schedule``, ``schedule_batch``,
``schedule_with_deadline``, ``deadline_sweep``, ``solve_dp_batch_cached``,
``solve_schedule_batch_cached``) remain as bit-identical deprecated shims.
"""

from .baselines import greedy_marginal, olar, proportional, random_schedule, uniform
from .costs import (
    DEVICE_CLASSES,
    JOULES_PER_KWH,
    CostWindows,
    carbon_cost_table,
    device_fleet_problem,
    linear_cost,
    measured_cost,
    random_problem,
    sublinear_cost,
    superlinear_cost,
)
from .fleet import FleetSolution, PlanPolicy, cluster_clients, solve_fleet
from .jax_dp import (
    solve_fused_batch_jax,
    solve_fused_batch_ring,
    solve_schedule_dp_batch,
    solve_schedule_dp_jax,
)
from .marginal import marco, mardec, mardecun, marin
from .marginal_jax import (
    marco_batch,
    mardec_batch,
    mardecun_batch,
    marin_batch,
    select_algorithm_batch,
)
from .mc2mkp import (
    ItemClass,
    MC2MKPSolution,
    brute_force_schedule,
    mc2mkp_matrices,
    solve_mc2mkp,
    solve_schedule_dp,
)
from .resilience import (
    CircuitBreaker,
    RetryPolicy,
    TransientEngineError,
    is_transient,
    retry_call,
)
from .problem import (
    Problem,
    ProblemBatch,
    classify_regimes,
    remove_lower_limits,
    restore_lower_limits,
    total_cost,
    total_cost_batch,
    validate_schedule,
    validate_schedule_batch,
)
from .pareto import (
    ParetoFrontier,
    ParetoPoint,
    candidate_deadlines,
    deadline_grid,
    feasible_deadline_range,
    frontier_by_window,
    pareto_frontier,
)
from .scheduler import (
    ALGORITHMS,
    deadline_sweep,
    schedule,
    schedule_batch,
    schedule_with_deadline,
    select_algorithm,
    tighten_for_deadline,
)
from .solver import Solution, SolutionBatch, Solver
from .sweep import (
    SweepEngine,
    bucket_shape,
    default_engine,
    make_sweep_mesh,
    solve_dp_batch_cached,
    solve_schedule_batch_cached,
)

__all__ = [
    "Problem",
    "ProblemBatch",
    "remove_lower_limits",
    "restore_lower_limits",
    "total_cost",
    "total_cost_batch",
    "validate_schedule",
    "validate_schedule_batch",
    "ItemClass",
    "MC2MKPSolution",
    "solve_mc2mkp",
    "mc2mkp_matrices",
    "solve_schedule_dp",
    "solve_schedule_dp_jax",
    "solve_fused_batch_jax",
    "solve_schedule_dp_batch",
    "brute_force_schedule",
    "marin",
    "marco",
    "mardecun",
    "mardec",
    "marin_batch",
    "marco_batch",
    "mardecun_batch",
    "mardec_batch",
    "classify_regimes",
    "select_algorithm_batch",
    "solve_schedule_batch_cached",
    "olar",
    "uniform",
    "proportional",
    "random_schedule",
    "greedy_marginal",
    "schedule",
    "schedule_batch",
    "schedule_with_deadline",
    "deadline_sweep",
    "tighten_for_deadline",
    "select_algorithm",
    "ALGORITHMS",
    "Solver",
    "Solution",
    "SolutionBatch",
    "CircuitBreaker",
    "RetryPolicy",
    "TransientEngineError",
    "is_transient",
    "retry_call",
    "FleetSolution",
    "PlanPolicy",
    "cluster_clients",
    "solve_fleet",
    "solve_fused_batch_ring",
    "ParetoFrontier",
    "ParetoPoint",
    "pareto_frontier",
    "frontier_by_window",
    "candidate_deadlines",
    "deadline_grid",
    "feasible_deadline_range",
    "CostWindows",
    "carbon_cost_table",
    "JOULES_PER_KWH",
    "SweepEngine",
    "bucket_shape",
    "default_engine",
    "make_sweep_mesh",
    "solve_dp_batch_cached",
    "DEVICE_CLASSES",
    "device_fleet_problem",
    "linear_cost",
    "superlinear_cost",
    "sublinear_cost",
    "measured_cost",
    "random_problem",
]
