"""The ``Solver`` facade: one front door for every solve (DESIGN.md §15).

Five PRs of growth left the solve surface scattered across six entrypoints —
``schedule`` / ``schedule_batch`` / ``schedule_with_deadline`` /
``deadline_sweep`` / ``solve_dp_batch_cached`` / ``solve_schedule_batch_cached``
— each with its own return shape and engine plumbing. :class:`Solver` folds
them into three verbs:

  * :meth:`Solver.solve` — one instance or a batch, optional ε-constraint
    ``deadline``, returning :class:`Solution` / :class:`SolutionBatch`
    (schedule(s) + exact float64 objective(s) + resolved algorithm(s) +
    regime(s) + free ``k_last`` rows on pure-DP paths + engine cache stats).
  * :meth:`Solver.sweep` — a whole deadline grid in ONE batched dispatch.
  * :meth:`Solver.frontier` — the exact (energy, time) Pareto set
    (``repro.core.pareto``), plus :meth:`Solver.solve_scalarized` /
    :meth:`Solver.solve_constrained` answering any number of weighted-sum /
    ε-constraint queries from that one dispatch.

Construction picks the execution substrate once — an explicit
:class:`~repro.core.sweep.SweepEngine`, a ``backend`` name (shared default
engine), or a :class:`~repro.serve.service.SchedulerService` (batch solves
become coalescable served requests) — and every verb uses it. The legacy
entrypoints survive as bit-identical warn-once shims over the same private
implementations this facade calls.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .marginal_jax import select_algorithm_batch
from .problem import Problem, ProblemBatch, total_cost, validate_schedule
from .resilience import retry_call
from .scheduler import (
    _DP_ALGORITHMS,
    _schedule,
    _schedule_batch,
    tighten_for_deadline,
)
from .sweep import _resolve_engine

__all__ = ["Solution", "SolutionBatch", "Solver"]


@dataclasses.dataclass(frozen=True)
class Solution:
    """One solved instance.

    ``objective`` is the exact float64 energy of ``schedule`` under the
    ORIGINAL cost tables (host-evaluated — independent of device f32).
    ``algorithm`` is the resolved Table-2 algorithm ("auto" never leaks
    through). ``k_last`` is the final DP row (0-lower-limit terms, the free
    workload-Pareto curve) when the solve ran the fused DP; ``None`` on
    marginal fast paths and host algorithms. ``deadline`` records the
    ε-constraint the instance was tightened for, if any."""

    schedule: np.ndarray
    objective: float
    algorithm: str
    regime: str
    deadline: Optional[float] = None
    k_last: Optional[np.ndarray] = None
    cache_stats: Optional[dict] = None


class SolutionBatch:
    """``B`` solved instances from one facade call: per-instance schedules
    (each trimmed to its own ``n``), exact float64 ``objectives``, resolved
    ``algorithms`` and ``regimes``, the batched ``k_last`` rows (pure-DP
    dispatches only, else ``None``), the per-point ``deadlines`` for sweep
    results, and a post-solve engine ``cache_stats`` snapshot. Indexing
    yields per-instance :class:`Solution` views."""

    def __init__(
        self,
        schedules,
        objectives,
        algorithms,
        regimes,
        deadlines=None,
        k_last=None,
        cache_stats=None,
    ):
        self.schedules = list(schedules)
        self.objectives = np.asarray(objectives, dtype=np.float64)
        self.algorithms = list(algorithms)
        self.regimes = list(regimes)
        self.deadlines = None if deadlines is None else np.asarray(deadlines, np.float64)
        self.k_last = k_last
        self.cache_stats = cache_stats

    def __len__(self) -> int:
        return len(self.schedules)

    def __getitem__(self, b: int) -> Solution:
        b = range(len(self))[b]  # normalize negative indices
        return Solution(
            schedule=self.schedules[b],
            objective=float(self.objectives[b]),
            algorithm=self.algorithms[b],
            regime=self.regimes[b],
            deadline=None if self.deadlines is None else float(self.deadlines[b]),
            k_last=None if self.k_last is None else self.k_last[b],
            cache_stats=self.cache_stats,
        )

    def __iter__(self):
        return (self[b] for b in range(len(self)))


def _as_problem_list(problems):
    if isinstance(problems, ProblemBatch):
        return [problems.instance(b) for b in range(problems.B)]
    return list(problems)


class Solver:
    """One facade over every solve path.

    Args:
      engine: explicit :class:`~repro.core.sweep.SweepEngine`; ``None`` uses
        the process-wide default for ``backend``.
      backend: kernel backend name ("auto" per-hardware dispatch when
        ``None``). Naming both an engine and a contradicting backend raises
        ValueError (same rule as the engine layer).
      service: a :class:`~repro.serve.service.SchedulerService`; when set,
        batch solves and sweeps are submitted as served requests (coalescing
        with other same-bucket traffic) instead of direct engine dispatches.
        The service's engine supplies cache stats.
      retry: a :class:`~repro.core.resilience.RetryPolicy`; when set, every
        engine-facing dispatch is retried with exponential backoff on
        TRANSIENT failures (``is_transient``) before the error propagates.
        Non-transient errors always fail fast. ``None`` (default) = no
        retries, bit-identical to the pre-resilience facade.
    """

    def __init__(
        self, engine=None, backend: Optional[str] = None, service=None, retry=None
    ):
        self.service = service
        if service is not None and engine is None:
            engine = service.engine
        self.engine = _resolve_engine(backend, engine)
        if service is not None and service.engine is not self.engine:
            raise ValueError(
                "engine conflicts with service.engine; pass one or the other"
            )
        self.retry = retry
        self._retry_rng = None if retry is None else retry.make_rng()

    def _guard(self, fn):
        """Runs one dispatch closure under the retry policy (no-op when the
        solver was built without one)."""
        if self.retry is None:
            return fn()
        return retry_call(fn, self.retry, rng=self._retry_rng)

    # ---- solve ---------------------------------------------------------

    def solve(
        self,
        problems,
        *,
        deadline: Optional[float] = None,
        time_tables=None,
        algorithm: str = "auto",
        check: bool = True,
    ):
        """Solves one :class:`Problem` (→ :class:`Solution`) or a batch —
        a sequence of Problems or a :class:`ProblemBatch` (→
        :class:`SolutionBatch`).

        ``deadline`` (with ``time_tables``) applies the ε-constraint
        reduction first (:func:`~repro.core.scheduler.tighten_for_deadline`)
        — to every instance of a batch. ``algorithm`` mirrors the historical
        dispatch: "auto" picks per-regime (batches take the regime-split
        engine path), DP names force the batched DP, other names run
        per-instance host algorithms. Schedules are bit-identical to the
        legacy entrypoints — same private implementations.
        """
        if (deadline is None) != (time_tables is None):
            raise ValueError("deadline and time_tables go together")
        if isinstance(problems, Problem):
            p = problems
            if deadline is not None:
                p = tighten_for_deadline(p, time_tables, float(deadline))
            x, alg = _schedule(p, algorithm, check)
            return Solution(
                schedule=x,
                objective=float(total_cost(p, x)),
                algorithm=alg,
                regime=p.regime(),
                deadline=None if deadline is None else float(deadline),
                cache_stats=self.engine.cache_stats(),
            )
        plist = _as_problem_list(problems)
        if deadline is not None:
            plist = [
                tighten_for_deadline(p, time_tables, float(deadline)) for p in plist
            ]
        deadlines = None if deadline is None else [float(deadline)] * len(plist)
        return self._solve_batch(plist, algorithm, check, deadlines)

    def _solve_batch(self, plist, algorithm, check, deadlines) -> SolutionBatch:
        regimes = [p.regime() for p in plist]
        k_last = None
        if plist and algorithm == "auto" and self.service is not None:
            X = np.asarray(
                self._guard(
                    lambda: self.service.submit(plist, split_regimes=True).result()
                )
            )
            schedules = [np.asarray(X[b, : p.n], np.int64) for b, p in enumerate(plist)]
            if check:
                for p, x in zip(plist, schedules):
                    validate_schedule(p, x)
            algorithms = list(select_algorithm_batch(plist))
        elif plist and algorithm in _DP_ALGORITHMS and self.service is not None:

            def _served_dp():
                fut = self.service.submit(plist, split_regimes=False)
                return np.asarray(fut.result()), np.asarray(fut.k_last())

            X, k_last = self._guard(_served_dp)
            schedules = [np.asarray(X[b, : p.n], np.int64) for b, p in enumerate(plist)]
            if check:
                for p, x in zip(plist, schedules):
                    validate_schedule(p, x)
            algorithms = ["dp_batch"] * len(plist)
        elif plist and algorithm in _DP_ALGORITHMS:
            # direct dispatch (not .solve()) to keep the free k_last rows
            backend = "pallas" if algorithm == "dp_jax_pallas" else None
            engine = _resolve_engine(backend, None if backend else self.engine)

            def _direct_dp():
                handle = engine.dispatch(plist, split_regimes=False)
                return handle.result(), handle.k_last()

            X, k_last = self._guard(_direct_dp)
            schedules = [np.asarray(X[b, : p.n], np.int64) for b, p in enumerate(plist)]
            if check:
                for p, x in zip(plist, schedules):
                    validate_schedule(p, x)
            algorithms = ["dp_batch"] * len(plist)
        else:
            schedules = self._guard(
                lambda: _schedule_batch(
                    plist, algorithm, check, backend=None, engine=self.engine
                )
            )
            algorithms = (
                list(select_algorithm_batch(plist))
                if algorithm == "auto" and plist
                else [algorithm] * len(plist)
            )
        objectives = [total_cost(p, x) for p, x in zip(plist, schedules)]
        return SolutionBatch(
            schedules=schedules,
            objectives=objectives,
            algorithms=algorithms,
            regimes=regimes,
            deadlines=deadlines,
            k_last=k_last,
            cache_stats=self.engine.cache_stats(),
        )

    # ---- sweep ---------------------------------------------------------

    def sweep(self, problem: Problem, time_tables, deadlines, check: bool = True) -> SolutionBatch:
        """The whole ε-constraint grid in ONE dispatch: tightens ``problem``
        per deadline (same ``(n, T, W)`` envelope → one compile bucket),
        solves the stack through the pure-DP path (so every point's
        ``k_last`` row comes back free), and returns a
        :class:`SolutionBatch` with per-point ``deadlines`` recorded.
        Infeasible points raise ValueError naming the offending deadline."""
        deadlines = [float(d) for d in deadlines]
        tight = []
        for d in deadlines:
            try:
                tight.append(tighten_for_deadline(problem, time_tables, d))
            except ValueError as e:
                raise ValueError(f"sweep point {d}: {e}") from e
        if self.service is not None:

            def _served_sweep():
                fut = self.service.submit(tight, split_regimes=False)
                return np.asarray(fut.result()), np.asarray(fut.k_last())

            X, k_last = self._guard(_served_sweep)
        else:

            def _direct_sweep():
                handle = self.engine.dispatch(tight, split_regimes=False)
                return handle.result(), handle.k_last()

            X, k_last = self._guard(_direct_sweep)
        schedules = [np.asarray(X[b, : p.n], np.int64) for b, p in enumerate(tight)]
        if check:
            for p, x in zip(tight, schedules):
                validate_schedule(p, x)
        return SolutionBatch(
            schedules=schedules,
            objectives=[total_cost(p, x) for p, x in zip(tight, schedules)],
            algorithms=["dp_batch"] * len(tight),
            regimes=[p.regime() for p in tight],
            deadlines=deadlines,
            k_last=k_last,
            cache_stats=self.engine.cache_stats(),
        )

    # ---- fleet ---------------------------------------------------------

    def solve_fleet(
        self,
        problem: Problem,
        *,
        clusters=None,
        quantum: Optional[int] = None,
        seed: Optional[int] = None,
        time_tables=None,
        policy=None,
        check: bool = True,
    ):
        """Two-level fleet solve (DESIGN.md §16): cluster the clients, solve
        every cluster's workload-Pareto curve in one batched dispatch, run an
        exact top-level (MC)²MKP over the curves, then one regime-split
        dispatch for the per-cluster schedules. Scales ``n`` into the
        thousands; returns a :class:`~repro.core.fleet.FleetSolution` with a
        certified relative ``gap_bound`` (0 when ``quantum == 1`` — the
        decomposition is exact then).

        ``clusters``: cluster count (``None``/"auto" ≈ √n); ``quantum``:
        top-level curve sampling step (``None`` = auto, 1 = exact);
        ``seed``: k-means seed; ``time_tables``: optional per-client time
        tables folded into the clustering features. A
        :class:`~repro.core.fleet.PlanPolicy` supplies defaults for any
        argument not given explicitly. Runs over this solver's substrate:
        direct engine dispatches, or coalescable served requests when the
        solver was built over a :class:`~repro.serve.service.SchedulerService`.
        """
        from .fleet import FleetRun  # lazy: fleet imports sweep

        if policy is not None:
            clusters = clusters if clusters is not None else policy.fleet_clusters
            quantum = quantum if quantum is not None else policy.fleet_quantum
            seed = seed if seed is not None else policy.fleet_seed
            time_tables = (
                time_tables if time_tables is not None else policy.time_tables
            )
        return FleetRun(
            problem,
            engine=None if self.service is not None else self.engine,
            service=self.service,
            clusters=clusters,
            quantum=quantum,
            seed=0 if seed is None else int(seed),
            time_tables=time_tables,
            check=check,
        ).finish()

    # ---- frontier ------------------------------------------------------

    def frontier(
        self,
        problem: Problem,
        time_tables,
        deadlines=None,
        *,
        split_regimes: bool = True,
        windows=None,
    ):
        """The exact (energy, completion-time) Pareto frontier from ONE
        dispatch (:func:`repro.core.pareto.pareto_frontier`): sweeping the
        full candidate-deadline set when ``deadlines`` is None, a bounded
        grid otherwise. ``windows`` (a :class:`~repro.core.costs.CostWindows`)
        switches to per-window frontiers under time-varying costs — still
        one dispatch for all windows × points
        (:func:`~repro.core.pareto.frontier_by_window`). Monotone-regime
        points ride the marginal fast path unless ``split_regimes=False``."""
        from . import pareto

        kw = dict(
            engine=None if self.service is not None else self.engine,
            service=self.service,
            split_regimes=split_regimes,
        )
        if windows is not None:
            return pareto.frontier_by_window(problem, time_tables, windows, deadlines, **kw)
        return pareto.pareto_frontier(problem, time_tables, deadlines, **kw)

    def solve_scalarized(self, problem: Problem, time_tables, weights, deadlines=None):
        """Batched weighted-sum solves: ``weights`` is an iterable of
        ``(w_energy, w_time)`` pairs; ALL of them are answered from one
        frontier dispatch (a weighted-sum optimum always lies on the Pareto
        set). Returns a list of :class:`~repro.core.pareto.ParetoPoint`, one
        per weight pair."""
        front = self.frontier(problem, time_tables, deadlines)
        return [front.scalarize(we, wt) for we, wt in weights]

    def solve_constrained(
        self,
        problem: Problem,
        time_tables,
        *,
        T_max: Optional[float] = None,
        E_max: Optional[float] = None,
        deadlines=None,
    ):
        """ε-constraint solve from the frontier: minimal energy under a
        completion-time budget ``T_max``, or minimal completion time under
        an energy budget ``E_max``. One frontier dispatch; returns a
        :class:`~repro.core.pareto.ParetoPoint`."""
        front = self.frontier(problem, time_tables, deadlines)
        return front.constrain(T_max=T_max, E_max=E_max)

    # ---- telemetry -----------------------------------------------------

    def cache_stats(self) -> dict:
        """The underlying engine's compile-cache counters."""
        return self.engine.cache_stats()
