"""Optimal schedulers for monotone marginal-cost regimes (paper Section 5).

All four algorithms operate on the 0-lower-limit equivalent instance
(Section 5.2); the public functions accept any valid instance and apply the
removal/restore transparently.

  - :func:`marin`     — increasing marginals, Θ(n + T log n) (Alg. 2).
  - :func:`marco`     — constant marginals, Θ(n log n) (Alg. 3).
  - :func:`mardecun`  — decreasing marginals, no upper limits, Θ(n) (Alg. 4).
  - :func:`mardec`    — decreasing marginals with upper limits, O(T n^2)
                        (Alg. 5, using Algs. 6-7 and (MC)^2MKP-matrices).
"""

from __future__ import annotations

import heapq
from typing import Optional

import numpy as np

from .mc2mkp import INF, ItemClass, mc2mkp_matrices
from .problem import Problem, remove_lower_limits, restore_lower_limits

__all__ = ["marin", "marco", "mardecun", "mardec"]


def _with_lower_limit_removal(fn):
    def wrapped(problem: Problem) -> np.ndarray:
        problem.validate()
        p0 = remove_lower_limits(problem)
        x0 = fn(p0)
        return restore_lower_limits(problem, x0)

    wrapped.__name__ = fn.__name__
    wrapped.__doc__ = fn.__doc__
    return wrapped


# ---------------------------------------------------------------------------
# MarIn — Algorithm 2 (adapted from OLAR: pick minimal *marginal* cost)
# ---------------------------------------------------------------------------


@_with_lower_limit_removal
def marin(p: Problem) -> np.ndarray:
    """Increasing marginal costs: repeatedly assign the next task to the
    resource with the smallest next marginal cost (min binomial/binary heap:
    insertion amortized O(1)-ish, pop O(log n); total Θ(n + T log n))."""
    n = len(p.cost_tables)
    x = np.zeros(n, dtype=np.int64)
    heap = []
    for i in range(n):
        if p.upper[i] >= 1:
            # marginal of assigning task #1 to i: C_i(1) - C_i(0)
            heapq.heappush(heap, (p.cost_tables[i][1] - p.cost_tables[i][0], i))
    for _ in range(p.T):
        m, k = heapq.heappop(heap)
        x[k] += 1
        nxt = x[k] + 1
        if nxt <= p.upper[k]:
            heapq.heappush(
                heap, (p.cost_tables[k][nxt] - p.cost_tables[k][nxt - 1], k)
            )
    return x


# ---------------------------------------------------------------------------
# MarCo — Algorithm 3
# ---------------------------------------------------------------------------


@_with_lower_limit_removal
def marco(p: Problem) -> np.ndarray:
    """Constant marginal costs: sort resources by per-task marginal M_i(1)
    and fill each to its upper limit (or the remaining tasks). Θ(n log n)."""
    n = len(p.cost_tables)
    x = np.zeros(n, dtype=np.int64)
    # M_i(1) = C_i(1) - C_i(0); resources with U_i == 0 can't take tasks.
    order = sorted(
        (i for i in range(n) if p.upper[i] >= 1),
        key=lambda i: p.cost_tables[i][1] - p.cost_tables[i][0],
    )
    t = 0
    for k in order:
        if t >= p.T:
            break
        take = int(min(p.upper[k], p.T - t))
        x[k] = take
        t += take
    return x


# ---------------------------------------------------------------------------
# MarDecUn — Algorithm 4
# ---------------------------------------------------------------------------


@_with_lower_limit_removal
def mardecun(p: Problem) -> np.ndarray:
    """Decreasing marginals, no (binding) upper limits: all tasks go to the
    single resource with minimal C_i(T). Θ(n).

    Zero-capacity resources (``U_i == 0`` after lower-limit removal — e.g.
    dropped-out clients, or inert batch padding) can never take a task, so
    they neither trigger the guard nor join the argmin; this keeps the
    dispatch rule padding-invariant and identical to the batched
    :func:`repro.core.marginal_jax.mardecun_batch`."""
    if np.any((p.upper > 0) & (p.upper < p.T)):
        raise ValueError("MarDecUn requires U_i >= T for all resources with capacity")
    n = len(p.cost_tables)
    x = np.zeros(n, dtype=np.int64)
    k = min((i for i in range(n) if p.upper[i] >= p.T), key=lambda i: p.cost_tables[i][p.T])
    x[k] = p.T
    return x


# ---------------------------------------------------------------------------
# MarDec — Algorithm 5 (+ Prepare/Translate, Algs. 6-7)
# ---------------------------------------------------------------------------


def _prepare(r_lim, upper, cost_tables):
    """Algorithm 6: two-item classes {0, U_r} for resources with upper
    limits. Returns (classes, gamma) with gamma[class_index] = resource."""
    classes = []
    gamma = []
    for r in r_lim:
        u = int(upper[r])
        classes.append(
            ItemClass(
                weights=np.array([0, u], dtype=np.int64),
                costs=np.array([0.0, float(cost_tables[r][u])]),
            )
        )
        gamma.append(r)
    return classes, gamma


def _translate(gamma, n, classes, I, t_prime):
    """Algorithm 7: backtracks the item matrix I from capacity t_prime into a
    partial schedule over all n resources."""
    x = np.zeros(n, dtype=np.int64)
    t = int(t_prime)
    for ci in range(len(classes) - 1, -1, -1):
        j = int(I[ci, t])
        w = int(classes[ci].weights[j])
        x[gamma[ci]] = w
        t -= w
    return x


@_with_lower_limit_removal
def mardec(p: Problem) -> np.ndarray:
    """Decreasing marginals with upper limits (Alg. 5).

    Optimal solutions have every resource either at 0, at full capacity, or
    exactly one at intermediary capacity (Lemma 6). Enumerates:
      (I)  a resource without upper limits holds t tasks (incl. t == T, the
           MarDecUn case), the rest is a minimal-cost full-capacity packing
           of limited resources over T - t;
      (II) one *limited* resource is the intermediary one: re-run the packing
           DP with that resource's class reduced to {0}.
    """
    n = len(p.cost_tables)
    T = p.T
    r_lim = [i for i in range(n) if p.upper[i] < T]
    r_unl = [i for i in range(n) if p.upper[i] >= T]
    n_lim = len(r_lim)

    best_cost = INF
    best_x: Optional[np.ndarray] = None

    classes, gamma = _prepare(r_lim, p.upper, p.cost_tables)

    # Boundary extension beyond the paper's Alg. 5: when T == sum(U_i) the
    # optimum can have EVERY resource at full capacity (no intermediary, no
    # zero) — the paper excludes this by assuming T strictly below sum(U_i).
    # Checking the unreduced full-capacity packing covers it.
    if n_lim and not r_unl:
        K_full, I_full = mc2mkp_matrices(classes, T)
        if np.isfinite(K_full[n_lim - 1, T]):
            best_cost = float(K_full[n_lim - 1, T])
            best_x = _translate(gamma, n, classes, I_full, T)

    if r_unl:
        if n_lim:
            K, I = mc2mkp_matrices(classes, T)
            last = K[n_lim - 1]
        else:
            last = np.full(T + 1, INF)
            last[0] = 0.0
        for t in range(T + 1):
            k = min(r_unl, key=lambda i: p.cost_tables[i][t])
            packed = last[T - t] if n_lim else (0.0 if t == T else INF)
            cand = float(p.cost_tables[k][t]) + float(packed)
            if cand < best_cost:
                best_cost = cand
                if n_lim:
                    x = _translate(gamma, n, classes, I, T - t)
                else:
                    x = np.zeros(n, dtype=np.int64)
                x[k] = t
                best_x = x

    for ci in range(n_lim):
        k = gamma[ci]
        # Replace class ci with {0}: resource k becomes the intermediary one.
        reduced = list(classes)
        reduced[ci] = ItemClass(weights=np.array([0]), costs=np.array([0.0]))
        if n_lim:
            K, I = mc2mkp_matrices(reduced, T)
            last = K[n_lim - 1]
        else:  # pragma: no cover - n_lim >= 1 in this loop
            continue
        for t in range(int(p.upper[k])):  # t = 0 .. U_k - 1 (strictly below cap)
            if T - t < 0:
                break
            packed = last[T - t]
            if not np.isfinite(packed):
                continue
            cand = float(p.cost_tables[k][t]) + float(packed)
            if cand < best_cost:
                best_cost = cand
                x = _translate(gamma, n, reduced, I, T - t)
                x[k] = t
                best_x = x

    if best_x is None:
        raise ValueError("MarDec found no feasible solution (invalid instance?)")
    return best_x
