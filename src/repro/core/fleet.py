"""Hierarchical fleet-scale scheduling (DESIGN.md §16, ROADMAP item 1).

Every solve so far is one dense ``(B, n, W, T)`` batch with ``n ≈ 16``
clients; the pseudo-polynomial DP is O(n·T·W) per instance, so a flat solve
over thousands of clients is hopeless (n = 2048, T ≈ 25k, W ≈ 32 is ~10^9
min-plus cells). This module scales ``n`` with a two-level decomposition in
which every level stays a small exact (MC)²MKP:

  1. **Cluster** clients by their (cost_table, time_table) profiles: a
     fixed-dimension feature vector per client (log capacity, log total
     energy, a resampled normalized marginal-cost curve, optionally log
     completion time), z-scored, then jitted k-means with deterministic
     seeding (``jax.random.PRNGKey(seed)``). Labels are remapped to
     first-appearance order, so singleton clusters reproduce the original
     client order exactly.
  2. **Per-cluster curves**: ONE pure-DP :class:`~repro.core.sweep.SweepEngine`
     dispatch solves every cluster at its full capacity — clusters share pow2
     compile buckets, and the fused DP's free ``K_last`` row IS each
     cluster's exact workload-Pareto curve ``K_c(t)`` (0-lower-limit terms).
  3. **Top-level allocation**: a small exact (MC)²MKP over the cluster
     curves assigns the round workload across clusters. Curves are sampled
     every ``quantum`` units (``q = 1`` keeps them exact), so the top DP has
     ``T' / q`` rows over ``k`` classes of width ``cap_c / q``; the residual
     ``T' − q·Σm_c`` is repaired greedily on the exact curves.
  4. **Gap bound**: a second top-level instance over the *bin-minimum*
     curves ``K̲_c(m) = min_{t ∈ bin m} K_c(t)`` lower-bounds every feasible
     allocation; its final DP row (free, same dispatch as stage 3) gives
     ``LB = min_{s ∈ [s_lo, T_q]} row(s)`` where any exact allocation's bin
     total lands in ``[s_lo, T_q]`` (each cluster rounds down < ``q`` units,
     so ``s_lo = ⌈(T' − k(q−1))/q⌉``). The reported relative
     ``gap_bound = (E_curve − LB)/LB`` is a certificate: the true optimum
     lies within it. With ``q = 1`` the decomposition is exact and the bound
     collapses to ~0 (f32 association noise).
  5. **Per-cluster schedules**: ONE regime-split dispatch solves each
     cluster at its allocated workload — monotone clusters ride the §13
     marginal fast path, arbitrary ones batch into the fused DP.

The only optimality gap is intra-cluster quantization (stage 3); the
decomposition itself is exact because cluster curves are exact.

Everything is surfaced through :meth:`repro.core.solver.Solver.solve_fleet`
(→ :class:`FleetSolution`), :class:`repro.fl.server.FederatedServer` round
planning (``PlanPolicy(fleet_clusters=...)``), and the serve layer
(``SchedulerService.submit_fleet``). :class:`PlanPolicy` is the typed
planning config those three consume (PR 8's API consolidation).
"""

from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .problem import Problem, total_cost, validate_schedule
from .sweep import SweepEngine, default_engine

__all__ = [
    "FleetRun",
    "FleetSolution",
    "PlanPolicy",
    "cluster_clients",
    "solve_fleet",
]


# ---------------------------------------------------------------------------
# PlanPolicy: the typed planning config (satellite 1 of the API redesign)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanPolicy:
    """Round-planning policy consumed by ``FederatedServer(policy=...)`` and
    :meth:`repro.core.solver.Solver.solve_fleet` — the typed replacement for
    the sprawl of ``FederatedServer`` constructor kwargs (each legacy kwarg
    remains a bit-identical warn-once shim).

    Fields mirror the legacy kwargs one-for-one; the ``fleet_*`` trio is new:
    ``fleet_clusters`` switches round planning to the two-level fleet path
    (``None`` = flat planning; ``"auto"`` ≈ √n clusters), ``fleet_quantum``
    sets the top-level curve sampling step (``None`` = auto, 1 = exact), and
    ``fleet_seed`` seeds the deterministic k-means.
    """

    algorithm: str = "auto"
    round_T: Optional[int] = None
    participation_floor: Optional[int] = None
    scenario_T_candidates: Sequence[int] = ()
    scenario_dropouts: Sequence[Sequence[int]] = ()
    engine: Optional[SweepEngine] = None
    service: Optional[object] = None
    frontier_mode: Optional[object] = None
    time_tables: Optional[Sequence[np.ndarray]] = None
    frontier_points: int = 12
    fleet_clusters: Optional[object] = None  # int | "auto" | None
    fleet_quantum: Optional[int] = None
    fleet_seed: int = 0
    # a repro.core.resilience.RetryPolicy: the server's Solver retries
    # transient engine failures during round planning (DESIGN.md §17);
    # None = fail fast (the campaign loop still has its own re-plan path)
    retry: Optional[object] = None
    # adaptive planning under drift (DESIGN.md §18). lookahead=k solves the
    # next k rounds' schedules per speculative batch (0 = off);
    # drift_tolerance bounds both the Page–Hinkley detector and the
    # speculative-plan validation band; reliability (an EWMA decay in
    # (0, 1]) arms crash/straggle-history capacity down-weighting;
    # watermark_quantile (in (0, 1)) arms intra-round re-planning at that
    # quantile of planned per-client finish times. All default-off: a
    # default policy runs the pre-adaptive loop byte-identically.
    lookahead: int = 0
    drift_tolerance: float = 0.1
    reliability: Optional[float] = None
    watermark_quantile: Optional[float] = None

    def __post_init__(self):
        # normalize the sequence fields so policies compare by value
        object.__setattr__(
            self, "scenario_T_candidates", tuple(self.scenario_T_candidates or ())
        )
        object.__setattr__(
            self,
            "scenario_dropouts",
            tuple(tuple(s) for s in (self.scenario_dropouts or ())),
        )
        if self.time_tables is not None:
            object.__setattr__(
                self,
                "time_tables",
                tuple(np.asarray(t, dtype=np.float64) for t in self.time_tables),
            )
        if self.frontier_mode is not None and self.time_tables is None:
            raise ValueError("frontier_mode requires time_tables")
        if int(self.lookahead) < 0:
            raise ValueError("lookahead must be >= 0")
        if int(self.lookahead) > 0 and (
            self.frontier_mode is not None or self.fleet_clusters is not None
        ):
            raise ValueError(
                "lookahead speculation requires the default min-energy "
                "planning path (no frontier_mode / fleet_clusters)"
            )
        if not (float(self.drift_tolerance) > 0.0):
            raise ValueError("drift_tolerance must be > 0")
        if self.reliability is not None and not (0.0 < float(self.reliability) <= 1.0):
            raise ValueError("reliability is an EWMA decay in (0, 1]")
        if self.watermark_quantile is not None and not (
            0.0 < float(self.watermark_quantile) < 1.0
        ):
            raise ValueError("watermark_quantile must be in (0, 1)")


# ---------------------------------------------------------------------------
# Stage 1: deterministic client clustering
# ---------------------------------------------------------------------------

_FEATURE_POINTS = 8  # resampled marginal-curve signature length


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def _kmeans_labels(feats: jnp.ndarray, key, k: int, iters: int) -> jnp.ndarray:
    """Lloyd iterations, fully jitted: deterministic given (feats, key).
    Empty clusters keep their previous center (they simply end up unused)."""
    n = feats.shape[0]
    centers = feats[jax.random.choice(key, n, shape=(k,), replace=False)]

    def step(c, _):
        d2 = ((feats[:, None, :] - c[None, :, :]) ** 2).sum(-1)
        lab = jnp.argmin(d2, axis=1)
        one = jax.nn.one_hot(lab, k, dtype=feats.dtype)  # (n, k)
        cnt = one.sum(axis=0)
        new = jnp.where(
            cnt[:, None] > 0, (one.T @ feats) / jnp.maximum(cnt[:, None], 1.0), c
        )
        return new, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    d2 = ((feats[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
    return jnp.argmin(d2, axis=1)


def _client_features(problem: Problem, time_tables=None) -> np.ndarray:
    """Fixed-dimension profile per client, in 0-lower-limit terms: log free
    capacity, log total energy over it, the normalized cost curve resampled
    at ``_FEATURE_POINTS`` fill fractions (the shape signature that separates
    linear / increasing / decreasing marginal regimes), and — when time
    tables are given — log completion time at capacity. Columns are z-scored
    so no single scale dominates the k-means metric."""
    n = problem.n
    L, U = problem.lower, problem.upper
    fr = np.linspace(0.0, 1.0, _FEATURE_POINTS)
    cols = _FEATURE_POINTS + 2 + (1 if time_tables is not None else 0)
    feats = np.zeros((n, cols), dtype=np.float64)
    for i in range(n):
        tbl = np.asarray(problem.cost_tables[i], dtype=np.float64)
        cap = int(U[i] - L[i])
        base = float(tbl[L[i]])
        total = float(tbl[L[i] + cap]) - base
        feats[i, 0] = math.log1p(cap)
        feats[i, 1] = math.log1p(max(total, 0.0))
        if cap > 0:
            js = L[i] + np.round(fr * cap).astype(np.int64)
            feats[i, 2 : 2 + _FEATURE_POINTS] = (tbl[js] - base) / max(
                abs(total), 1e-12
            )
        if time_tables is not None:
            tt = np.asarray(time_tables[i], dtype=np.float64)
            feats[i, -1] = math.log1p(max(float(tt[min(int(U[i]), len(tt) - 1)]), 0.0))
    mu, sd = feats.mean(axis=0), feats.std(axis=0)
    return (feats - mu) / np.where(sd > 1e-12, sd, 1.0)


def _auto_clusters(n: int) -> int:
    return max(1, int(round(math.sqrt(n))))


def cluster_clients(
    problem: Problem,
    *,
    clusters=None,
    seed: int = 0,
    time_tables=None,
    iters: int = 16,
) -> np.ndarray:
    """Deterministic k-means clustering of the fleet by cost/time profiles.

    Returns ``(n,)`` int64 labels in **first-appearance order**: client 0 is
    always in cluster 0, and the first client of each new cluster fixes its
    id. That canonical order makes the decomposition reproducible under a
    fixed ``seed`` and, when every cluster is a singleton
    (``clusters == n``), makes the top-level instance literally the flat
    instance — the basis of the exactness tests.

    ``clusters``: target count (clamped to ``n``); ``None`` / ``"auto"``
    picks ``≈ √n``.
    """
    n = problem.n
    if clusters is None or clusters == "auto":
        k = _auto_clusters(n)
    else:
        k = int(clusters)
        if k < 1:
            raise ValueError("clusters must be >= 1")
    k = min(k, n)
    if k == n:
        return np.arange(n, dtype=np.int64)  # singletons: identity labels
    feats = _client_features(problem, time_tables)
    lab = np.asarray(
        _kmeans_labels(
            jnp.asarray(feats, jnp.float32), jax.random.PRNGKey(int(seed)), k, iters
        )
    )
    # canonical relabel: cluster ids in order of first appearance (empty
    # k-means cells vanish here — k_eff is the number of distinct labels)
    remap: dict = {}
    out = np.empty(n, dtype=np.int64)
    for i, c in enumerate(lab.tolist()):
        if c not in remap:
            remap[c] = len(remap)
        out[i] = remap[c]
    return out


# ---------------------------------------------------------------------------
# Stages 2-5: curves -> top-level allocation (+ gap bound) -> schedules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetSolution:
    """Result of a two-level fleet solve.

    ``schedule`` is the full ``(n,)`` per-client assignment (sums to ``T``);
    ``objective`` its exact float64 energy under the original tables.
    ``gap_bound`` is the certified relative optimality gap (see module
    docstring) — 0 means provably optimal up to f32 noise. ``allocations``
    holds each cluster's workload in original terms, ``curves`` the per-
    cluster exact workload-Pareto rows (0-lower-limit terms, f32) the
    allocation was solved over, and ``cluster_stats`` one dict per cluster
    (size / capacity / workload / regime).
    """

    schedule: np.ndarray
    objective: float
    labels: np.ndarray
    allocations: np.ndarray
    gap_bound: float
    num_clusters: int
    quantum: int
    cluster_stats: tuple
    curves: np.ndarray
    cache_stats: Optional[dict] = None


def _auto_quantum(max_cap: int, workload: int) -> int:
    """Top-level curve sampling step: keep the top DP's class width ≤ ~256
    multiples. Quantization error is paid relative to the round *workload*,
    not the fleet capacity, so over-provisioned fleets (capacity ≫ T) must
    not coarsen further than the workload itself warrants. Small fleets
    (every n ≤ 64 gap benchmark) get ``q = 1`` — the exact decomposition."""
    return max(1, math.ceil(min(max_cap, workload) / 256))


class FleetRun:
    """A staged two-level fleet solve.

    Construction runs stage 1 (clustering, host numpy + one tiny jit) and
    *launches* stage 2 (the per-cluster curve dispatch — JAX async, or one
    coalescable served request when built over a service). :meth:`finish`
    blocks on the curves, runs the top-level allocation + residual repair +
    per-cluster schedule dispatch, and returns the :class:`FleetSolution`.
    The serve layer's ``submit_fleet`` future wraps exactly this split.
    """

    def __init__(
        self,
        problem: Problem,
        *,
        engine: Optional[SweepEngine] = None,
        service=None,
        clusters=None,
        quantum: Optional[int] = None,
        seed: int = 0,
        time_tables=None,
        check: bool = True,
    ):
        problem.validate()
        self.problem = problem
        self.check = bool(check)
        self._service = service
        self._engine = (
            service.engine
            if service is not None
            else (engine if engine is not None else default_engine())
        )
        self.labels = cluster_clients(
            problem, clusters=clusters, seed=seed, time_tables=time_tables
        )
        self.num_clusters = int(self.labels.max()) + 1
        self.members = [
            np.flatnonzero(self.labels == c) for c in range(self.num_clusters)
        ]
        L, U = problem.lower, problem.upper
        self._caps = np.array(
            [int((U[idx] - L[idx]).sum()) for idx in self.members], dtype=np.int64
        )
        self._lsums = np.array(
            [int(L[idx].sum()) for idx in self.members], dtype=np.int64
        )
        Tp = int(problem.T - L.sum())  # round workload in 0-lower terms
        self.quantum = (
            _auto_quantum(int(self._caps.max()), Tp)
            if quantum is None
            else int(quantum)
        )
        if self.quantum < 1:
            raise ValueError("quantum must be >= 1")
        # stage 2 launch: each cluster's workload-Pareto curve. No cluster
        # is ever allocated more than the round workload, so the curve is
        # harvested only up to min(capacity, T' + q) — over-provisioned
        # fleets (capacity ≫ T) would otherwise pay DP tables as wide as
        # their idle capacity
        self._cluster_probs = [
            Problem(
                T=int(
                    min(
                        U[idx].sum(),
                        L[idx].sum() + Tp + self.quantum,
                    )
                ),
                lower=L[idx],
                upper=U[idx],
                cost_tables=tuple(problem.cost_tables[i] for i in idx),
            )
            for idx in self.members
        ]
        self._curve_handle = self._dispatch(self._cluster_probs, split=False)
        self._solution: Optional[FleetSolution] = None

    def _dispatch(self, probs, split: bool):
        if self._service is not None:
            return self._service.submit(probs, split_regimes=split)
        return self._engine.dispatch(probs, split_regimes=split)

    def done(self) -> bool:
        """True once the in-flight curve dispatch has landed (the remaining
        stages are small and run inside :meth:`finish`)."""
        return self._solution is not None or self._curve_handle.done()

    def _remaining(self, deadline: Optional[float]) -> Optional[float]:
        if deadline is None:
            return None
        rem = deadline - time.monotonic()
        if rem <= 0:
            raise TimeoutError("fleet solve not served within the timeout")
        return rem

    def _materialize(self, handle, deadline: Optional[float], what: str = "result"):
        """Blocks on one staged result, spending only the budget left on the
        deadline clock for served futures (direct engine handles expose no
        timeout — there the device computation is already in flight and the
        caller used the blocking ``solve_fleet`` path anyway)."""
        fn = getattr(handle, what)
        if self._service is not None and deadline is not None:
            return fn(timeout=self._remaining(deadline))
        return fn()

    def finish(self, timeout: Optional[float] = None) -> FleetSolution:
        """Runs stages 3–5 and returns the (cached) :class:`FleetSolution`.
        ``timeout`` is one deadline across ALL remaining staged solves;
        served requests that outlive it raise :class:`TimeoutError` (the
        run stays retryable — nothing is cached on a timed-out pass)."""
        if self._solution is not None:
            return self._solution
        deadline = None if timeout is None else time.monotonic() + float(timeout)
        p, q, k = self.problem, self.quantum, self.num_clusters
        caps = self._caps
        Tp = int(p.T - p.lower.sum())  # round workload in 0-lower terms

        # stage 3: top-level (MC)²MKP over the cluster curves, sampled every
        # q units — batched with the bin-minimum LB instance (stage 4) into
        # ONE dispatch (same (k, T_q, M+1) envelope -> same pow2 bucket)
        K = np.asarray(
            self._materialize(self._curve_handle, deadline, "k_last"),
            dtype=np.float64,
        )  # (k, curve)
        M0 = caps // q
        T_q = min(Tp // q, int(M0.sum()))
        # a cluster can never receive more than T_q quanta — clamping the
        # class widths is lossless and keeps the top DP's tables narrow
        # when capacity ≫ workload
        M = np.minimum(M0, T_q)
        endpoint, binmin = [], []
        for c in range(k):
            idx = (np.arange(int(M[c]) + 1)) * q
            endpoint.append(K[c, idx])
            binmin.append(
                np.array(
                    [
                        K[c, m * q : min((m + 1) * q, int(caps[c]) + 1)].min()
                        for m in range(int(M[c]) + 1)
                    ]
                )
            )
        zeros = np.zeros(k, dtype=np.int64)
        top = [
            Problem(T=T_q, lower=zeros, upper=M, cost_tables=tuple(endpoint)),
            Problem(T=T_q, lower=zeros, upper=M, cost_tables=tuple(binmin)),
        ]
        top_handle = self._dispatch(top, split=False)
        m_alloc = np.asarray(self._materialize(top_handle, deadline))[0, :k].astype(
            np.int64
        )
        row_lb = np.asarray(
            self._materialize(top_handle, deadline, "k_last"), dtype=np.float64
        )[1]

        # stage 4: the certificate. Any feasible exact allocation rounds
        # down < q units per cluster, so its bin total s lands in
        # [ceil((T' - k(q-1))/q), T_q]; the LB row minimized over that range
        # lower-bounds the true optimum.
        s_lo = max(0, -((-(Tp - k * (q - 1))) // q))  # integer ceil-div
        s_lo = min(s_lo, T_q)
        lb0 = float(row_lb[s_lo : T_q + 1].min())

        # residual repair: T' - q*T_q leftover units, added one at a time
        # where the EXACT curve's marginal is cheapest
        t = m_alloc * q
        r = Tp - int(t.sum())
        ar = np.arange(k)
        for _ in range(r):
            marg = np.where(
                t < caps, K[ar, np.minimum(t + 1, K.shape[1] - 1)] - K[ar, t], np.inf
            )
            t[int(np.argmin(marg))] += 1
        e_curve0 = float(K[ar, t].sum())  # achieved value, 0-lower curve terms

        # gap bound in ABSOLUTE terms: add the fixed lower-limit cost back
        fixed = float(
            sum(p.cost_tables[i][int(p.lower[i])] for i in range(p.n))
        )
        lb_abs = lb0 + fixed
        gap = max(0.0, (e_curve0 + fixed) - lb_abs) / max(abs(lb_abs), 1e-12)

        # stage 5: per-cluster schedules at the allocated workloads, ONE
        # regime-split dispatch (monotone clusters ride the §13 fast path)
        alloc = t + self._lsums
        sched_probs = [
            Problem(
                T=int(alloc[c]),
                lower=p.lower[idx],
                upper=p.upper[idx],
                cost_tables=tuple(p.cost_tables[i] for i in idx),
            )
            for c, idx in enumerate(self.members)
        ]
        X = np.asarray(
            self._materialize(self._dispatch(sched_probs, split=True), deadline)
        )
        x = np.zeros(p.n, dtype=np.int64)
        for c, idx in enumerate(self.members):
            x[idx] = X[c, : len(idx)]
        if self.check:
            validate_schedule(p, x)
        stats = tuple(
            {
                "size": int(len(idx)),
                "capacity": int(p.upper[idx].sum()),
                "workload": int(alloc[c]),
                "regime": sched_probs[c].regime(),
            }
            for c, idx in enumerate(self.members)
        )
        self._solution = FleetSolution(
            schedule=x,
            objective=float(total_cost(p, x)),
            labels=self.labels,
            allocations=alloc,
            gap_bound=float(gap),
            num_clusters=k,
            quantum=q,
            cluster_stats=stats,
            curves=np.asarray(self._curve_handle.k_last()),
            cache_stats=self._engine.cache_stats(),
        )
        return self._solution


def solve_fleet(
    problem: Problem,
    *,
    engine: Optional[SweepEngine] = None,
    service=None,
    clusters=None,
    quantum: Optional[int] = None,
    seed: int = 0,
    time_tables=None,
    check: bool = True,
) -> FleetSolution:
    """Blocking two-level fleet solve — :class:`FleetRun` start + finish.
    Callers go through :meth:`repro.core.solver.Solver.solve_fleet`."""
    return FleetRun(
        problem,
        engine=engine,
        service=service,
        clusters=clusters,
        quantum=quantum,
        seed=seed,
        time_tables=time_tables,
        check=check,
    ).finish()
