"""Energy cost-function families for heterogeneous devices.

The paper treats ``C_i`` as arbitrary tabulated functions; related work often
assumes linear costs. We provide the three marginal-cost regimes of paper
Definition 3 plus arbitrary/measured costs, parameterized to mimic published
device energy behaviour (paper refs [13], [27], [28], [32], [34]):

  - ``superlinear`` (increasing marginals): DVFS-style — sustaining throughput
    for larger workloads pushes clocks/voltage up; E(j) = a*j + b*j^p, p>1.
  - ``linear`` (constant marginals): fixed energy per mini-batch.
  - ``sublinear`` (decreasing marginals): fixed idle/wakeup power amortized
    over more work; E(j) = c*(1 - exp(-j/s)) + a*j with a small.
  - ``measured``: arbitrary tables (e.g. from a profiler like I-Prof/Flower),
    here synthesized with reproducible noise.

All generators return dense tables ``C_i(0..U_i)`` with ``C_i`` monotone
non-decreasing (energy cannot shrink with more work) except the ``measured``
family, which may be arbitrary (the general problem allows it).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .problem import Problem

__all__ = [
    "CostWindows",
    "JOULES_PER_KWH",
    "carbon_cost_table",
    "linear_cost",
    "superlinear_cost",
    "sublinear_cost",
    "measured_cost",
    "random_problem",
    "device_fleet_problem",
    "DEVICE_CLASSES",
]

JOULES_PER_KWH = 3.6e6


def linear_cost(u: int, per_task: float, base: float = 0.0) -> np.ndarray:
    j = np.arange(u + 1, dtype=np.float64)
    c = base + per_task * j
    c[0] = 0.0 if base == 0.0 else c[0]
    return c


def superlinear_cost(u: int, a: float, b: float, p: float = 1.5) -> np.ndarray:
    j = np.arange(u + 1, dtype=np.float64)
    return a * j + b * np.power(j, p)


def sublinear_cost(u: int, amortized: float, scale: float, a: float = 0.0) -> np.ndarray:
    j = np.arange(u + 1, dtype=np.float64)
    c = amortized * (1.0 - np.exp(-j / scale)) + a * j
    c[0] = 0.0
    return c


def measured_cost(
    u: int, rng: np.random.Generator, lo: float = 0.5, hi: float = 4.0
) -> np.ndarray:
    """Arbitrary (non-monotone-marginal) cost table: cumulative sum of random
    per-task increments, as a stand-in for profiler measurements."""
    inc = rng.uniform(lo, hi, size=u)
    c = np.concatenate([[0.0], np.cumsum(inc)])
    return c


# ---------------------------------------------------------------------------
# Device fleet modeling: classes loosely mirroring the heterogeneity spread
# reported by Lane et al. [32] (1-3 orders of magnitude) and Kim & Wu [13].
# energy_per_batch ~ Joules to train one mini-batch of the reference model.
# ---------------------------------------------------------------------------

DEVICE_CLASSES = {
    # name: (per-batch J, regime, kwargs)
    "phone_lo": dict(per_task=8.0, regime="superlinear", b=0.35, p=1.6),
    "phone_hi": dict(per_task=3.0, regime="superlinear", b=0.10, p=1.5),
    "tablet": dict(per_task=2.2, regime="linear"),
    "laptop": dict(per_task=1.2, regime="linear"),
    "edge_tpu": dict(per_task=0.6, regime="sublinear", amortized=25.0, scale=24.0),
    "jetson": dict(per_task=0.9, regime="sublinear", amortized=18.0, scale=16.0),
    "workstation": dict(per_task=0.35, regime="linear"),
}


def _table_for_class(name: str, u: int, flops_scale: float = 1.0) -> np.ndarray:
    spec = DEVICE_CLASSES[name]
    a = spec["per_task"] * flops_scale
    if spec["regime"] == "linear":
        return linear_cost(u, a)
    if spec["regime"] == "superlinear":
        return superlinear_cost(u, a, spec["b"] * flops_scale, spec["p"])
    if spec["regime"] == "sublinear":
        return sublinear_cost(u, spec["amortized"] * flops_scale, spec["scale"], a * 0.5)
    raise ValueError(spec["regime"])


def device_fleet_problem(
    T: int,
    classes: Sequence[str],
    upper: Optional[Sequence[int]] = None,
    lower: Optional[Sequence[int]] = None,
    flops_scale: float = 1.0,
) -> Problem:
    """Builds a Problem from named device classes.

    ``flops_scale`` scales per-batch energy by the model's per-batch FLOPs
    relative to the reference model (how `fl/energy.py` adapts cost tables per
    architecture).
    """
    n = len(classes)
    if upper is None:
        upper = [T] * n
    if lower is None:
        lower = [0] * n
    tables = tuple(_table_for_class(c, int(u), flops_scale) for c, u in zip(classes, upper))
    return Problem(T=T, lower=np.asarray(lower), upper=np.asarray(upper), cost_tables=tables)


# ---------------------------------------------------------------------------
# Time-varying objectives (promoted from examples/carbon_aware.py in PR 7):
# the paper's algorithms minimize ANY tabulated cost (§6), so carbon-aware or
# tariff-aware scheduling is just a reweighting of the energy tables — and a
# DAY of grid conditions is a stack of reweighted instances the sweep engine
# solves in one dispatch (repro.core.pareto.frontier_by_window).
# ---------------------------------------------------------------------------


def carbon_cost_table(
    energy_table: np.ndarray, carbon_intensity: float, unit: float = 1000.0
) -> np.ndarray:
    """Reweights an energy table (Joules) into emissions:
    ``gCO2e(j) = intensity[g/kWh] * E(j)[J] / 3.6e6``; the default
    ``unit=1000`` returns mgCO2e (readable magnitudes for per-round
    fleets)."""
    e = np.asarray(energy_table, dtype=np.float64)
    return e * (float(carbon_intensity) / JOULES_PER_KWH) * float(unit)


@dataclasses.dataclass(frozen=True)
class CostWindows:
    """Window-indexed per-device cost multipliers: carbon-intensity periods,
    tariff windows, demand-response slots.

    ``multipliers[w, i]`` scales device ``i``'s whole cost table inside
    window ``w`` (labelled ``labels[w]``). Multipliers must be positive:
    positive scaling preserves each instance's marginal-cost regime, so
    windowed instances keep riding the same fast paths as the base problem.
    :meth:`apply` yields one reweighted :class:`Problem` per window —
    identical shape envelope, so a whole day of windows batches into ONE
    engine dispatch.
    """

    labels: tuple
    multipliers: np.ndarray  # (num_windows, n) positive float64

    def __post_init__(self):
        m = np.asarray(self.multipliers, dtype=np.float64)
        object.__setattr__(self, "multipliers", m)
        object.__setattr__(self, "labels", tuple(self.labels))
        if m.ndim != 2 or m.shape[0] != len(self.labels):
            raise ValueError("multipliers must be (num_windows, n) with one row per label")
        if not np.all(m > 0):
            raise ValueError("multipliers must be positive (regime-preserving)")

    @property
    def num_windows(self) -> int:
        return len(self.labels)

    @classmethod
    def from_carbon_intensities(
        cls, labels, intensities, unit: float = 1000.0
    ) -> "CostWindows":
        """Windows from per-window, per-device grid carbon intensities
        (g/kWh), ``(num_windows, n)`` — broadcast a ``(num_windows, 1)``
        column for a single-region fleet. Applying these to energy-Joule
        tables yields emission tables in ``unit``-gCO2e (default mg), the
        same conversion as :func:`carbon_cost_table`."""
        m = np.asarray(intensities, dtype=np.float64) * float(unit) / JOULES_PER_KWH
        return cls(labels=tuple(labels), multipliers=m)

    def apply(self, problem: Problem):
        """One reweighted :class:`Problem` per window (limits and ``T``
        untouched — only the objective changes)."""
        if self.multipliers.shape[1] != problem.n:
            raise ValueError(
                f"multipliers cover {self.multipliers.shape[1]} devices, "
                f"problem has {problem.n}"
            )
        return [
            Problem(
                T=problem.T,
                lower=problem.lower,
                upper=problem.upper,
                cost_tables=tuple(
                    np.asarray(tbl, np.float64) * self.multipliers[w, i]
                    for i, tbl in enumerate(problem.cost_tables)
                ),
            )
            for w in range(self.num_windows)
        ]


def random_problem(
    rng: np.random.Generator,
    n: int,
    T: int,
    regime: str = "arbitrary",
    max_upper: Optional[int] = None,
    with_lower: bool = True,
) -> Problem:
    """Random valid instance of a given marginal-cost regime (for tests)."""
    max_upper = max_upper or T
    # Draw uppers until feasible.
    while True:
        upper = rng.integers(1, max_upper + 1, size=n)
        if upper.sum() >= T:
            break
    if with_lower:
        # lowers small enough to stay feasible
        lower = np.minimum(rng.integers(0, 3, size=n), upper)
        while lower.sum() > T:
            k = int(rng.integers(0, n))
            lower[k] = max(0, lower[k] - 1)
    else:
        lower = np.zeros(n, dtype=np.int64)
    tables = []
    for i in range(n):
        u = int(upper[i])
        if regime == "arbitrary":
            tables.append(measured_cost(u, rng))
        elif regime == "linear":
            tables.append(linear_cost(u, float(rng.uniform(0.2, 5.0))))
        elif regime == "increasing":
            tables.append(
                superlinear_cost(u, float(rng.uniform(0.2, 3.0)), float(rng.uniform(0.01, 0.6)), float(rng.uniform(1.1, 2.2)))
            )
        elif regime == "decreasing":
            tables.append(
                sublinear_cost(u, float(rng.uniform(5.0, 40.0)), float(rng.uniform(2.0, 20.0)), float(rng.uniform(0.0, 0.2)))
            )
        else:
            raise ValueError(regime)
    p = Problem(T=T, lower=lower, upper=upper, cost_tables=tuple(tables))
    p.validate()
    return p
