"""Batched monotone-cost fast path: vectorized marginal schedulers
(DESIGN.md §13).

The paper's four monotone-regime algorithms (Section 5) avoid the
O(n·T·W) (MC)^2MKP table entirely, but until now they only existed as
serial NumPy heap code (`core/marginal.py`) — so every batched/sweep/async
solve paid full DP cost even on monotone instances. This module batches
them:

  * **MarIn / MarCo** (:func:`marin_batch` / :func:`marco_batch`) — one
    jit-compatible *selection kernel* (:func:`marginal_select_jax`): build
    the ``(B, n, W-1)`` marginal-cost table from the packed cost tables,
    mask units beyond each upper limit to +inf, and take the ``T'_b``
    globally cheapest marginal units per problem with a stable sort over
    the flattened ``(n·(W-1),)`` axis; per-resource task counts come back
    via a segment sum over the sort permutation. O(B·nW·log(nW)) instead
    of O(B·n·T·W). MarCo is the constant-marginal special case of the same
    kernel (constant marginals are non-decreasing), matching the serial
    MarCo's sort-and-fill bit for bit.
  * **MarDecUn** (:func:`mardecun_batch`) — vectorized argmin of
    ``C_i(T')`` over eligible resources; O(B·n) host numpy in float64
    (exactly the serial comparison semantics).
  * **MarDec** (:func:`mardec_batch`) — decreasing marginals WITH binding
    upper limits stay on the serial host path, looped over the batch. The
    issue's proposed "reversed-marginal" reduction to the selection kernel
    is only sound for the *unlimited* case: reversing a decreasing-marginal
    table ``D_i(r) = C_i(U_i) - C_i(U_i - r)`` does yield increasing
    marginals, but the objective becomes *maximizing* total savings — the
    hard direction for increasing marginals (greedy prefix selection is
    optimal for minimization only). With upper limits the optimum has the
    Lemma-6 all-or-nothing structure and genuinely needs the (MC)^2MKP
    packing enumeration of Algorithm 5, so :func:`mardec_batch` reuses it
    verbatim (bit-identical by construction).

**Tie-breaking == the serial heap.** `marin` pops ``(marginal, resource)``
tuples from a binary heap, so for equal marginals the lowest resource index
wins, and within a resource units become available in ascending ``j`` order.
With per-resource non-decreasing marginals that pop order is exactly the
merge of n sorted streams, i.e. ascending ``(marginal, resource, j)``
lexicographic order — which is precisely a *stable* ascending sort of the
i-major flattened marginal table. Stability also makes the selection
invariant under inert batch padding (padded resources sit at higher flat
indices and are masked to +inf), which is what makes mixed-regime sub-batch
results bit-identical to solving each sub-batch alone.

**Precision.** The kernel computes in float32 (same contract as the batched
DP: `pack_problem` saturates to float32). On float32-representable cost
tables the in-kernel marginal ``fl(C(j) - C(j-1))`` is the correctly-rounded
true marginal and rounding is monotone, so batched schedules are
bit-identical to the float64 NumPy oracles unless two *distinct* float64
marginals collide in float32 exactly at the selection boundary
(measure-zero for continuous cost draws; exact for integer-valued tables).

:func:`select_algorithm_batch` is the shared dispatch rule (paper Table 2)
over :func:`~repro.core.problem.classify_regimes`; the serial
``schedule(algorithm="auto")`` delegates here too, so the two paths cannot
disagree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .marginal import mardec
from .problem import (
    Problem,
    ProblemBatch,
    classify_regimes,
    remove_lower_limits,
    restore_lower_limits,
)

__all__ = [
    "marin_batch",
    "marco_batch",
    "mardecun_batch",
    "mardec_batch",
    "marginal_select",
    "marginal_select_jax",
    "select_algorithm_batch",
    "MARGINAL_BATCH_ALGORITHMS",
]


# ---------------------------------------------------------------------------
# dispatch rule (paper Table 2) — shared by serial and batched "auto"
# ---------------------------------------------------------------------------


def select_algorithm_batch(problems) -> list:
    """Per-instance algorithm names (paper Table 2) for a batch:
    ``marin | marco | mardecun | mardec | dp``.

    The "no binding upper limits" column of Table 2 is evaluated on the
    0-lower-limit instance and **ignores zero-capacity resources**
    (``U_i - L_i == 0``): they can never take a task, so whether they exist
    (genuinely, or as inert batch padding) must not change the dispatch —
    this is what keeps batched sub-batch dispatch identical to dispatching
    each instance alone, and serial identical to batched.
    """
    batch = (
        problems
        if isinstance(problems, ProblemBatch)
        else ProblemBatch.from_problems(problems)
    )
    regimes = classify_regimes(batch.costs, batch.lower, batch.upper)
    span = batch.upper - batch.lower  # U'_i of the 0-lower-limit instance
    Tp = batch.T - batch.lower.sum(axis=1)  # T'
    # unlimited: every resource that can take tasks at all can take ALL of them
    unlimited = np.all((span == 0) | (span >= Tp[:, None]), axis=1)
    out = []
    for b in range(batch.B):
        r = regimes[b]
        if r == "increasing":
            out.append("marin")
        elif r == "constant":
            out.append("mardecun" if unlimited[b] else "marco")
        elif r == "decreasing":
            out.append("mardecun" if unlimited[b] else "mardec")
        else:
            out.append("dp")
    return out


# ---------------------------------------------------------------------------
# the selection kernel (MarIn / MarCo)
# ---------------------------------------------------------------------------


def marginal_select(costs: jnp.ndarray, upper: jnp.ndarray, t_star: jnp.ndarray):
    """Unjitted selection kernel body (the sweep engine closes over this in
    its per-bucket executables; :func:`marginal_select_jax` is the
    standalone jitted entry).

    Args:
      costs: ``(B, n, W)`` float32 packed 0-lower-limit tables (BIG beyond
        each ``U_i`` — those units are masked again here anyway).
      upper: ``(B, n)`` int32 upper limits of the 0-lower-limit instances.
      t_star: ``(B,)`` int32 workloads ``T'``.

    Returns ``(X0, obj)``: ``(B, n)`` int32 per-resource task counts and the
    ``(B,)`` float32 selected-marginal totals (the optimal 0-lower-limit
    objective when marginals are non-decreasing).
    """
    B, n, W = costs.shape
    m = costs[:, :, 1:] - costs[:, :, :-1]  # marginal unit (i, j) at [..., j-1]
    j = jnp.arange(1, W, dtype=jnp.int32)[None, None, :]
    m = jnp.where(j <= upper[:, :, None], m, jnp.inf)
    flat = m.reshape(B, n * (W - 1))
    # stable ascending sort == the serial heap's (marginal, resource, j) order
    order = jnp.argsort(flat, axis=1, stable=True)
    sorted_m = jnp.take_along_axis(flat, order, axis=1)
    picked = jnp.arange(n * (W - 1), dtype=jnp.int32)[None, :] < t_star[:, None]
    resource = (order // (W - 1)).astype(jnp.int32)
    x = jax.vmap(
        lambda r, p: jax.ops.segment_sum(p.astype(jnp.int32), r, num_segments=n)
    )(resource, picked)
    obj = jnp.sum(jnp.where(picked, sorted_m, 0.0), axis=1)
    return x, obj


marginal_select_jax = jax.jit(marginal_select)


def _as_batch(problems) -> ProblemBatch:
    batch = (
        problems
        if isinstance(problems, ProblemBatch)
        else ProblemBatch.from_problems(problems)
    )
    batch.validate()
    return batch


def marin_batch(problems) -> np.ndarray:
    """Batched MarIn (Alg. 2): ``B`` increasing-marginal instances in one
    jitted selection-kernel call. Returns ``(B, n)`` int64 schedules,
    bit-identical to looping :func:`repro.core.marginal.marin` (see the
    module docstring for the tie-break/precision contract)."""
    batch = _as_batch(problems)
    b0 = remove_lower_limits(batch)
    if b0.W < 2:  # every resource pinned to its lower limit
        return restore_lower_limits(batch, np.zeros((batch.B, batch.n), np.int64))
    from .jax_dp import pack_problem  # local import: jax_dp pulls in kernels

    x0, _ = marginal_select_jax(
        pack_problem(b0),
        jnp.asarray(b0.upper, jnp.int32),
        jnp.asarray(b0.T, jnp.int32),
    )
    return restore_lower_limits(batch, np.asarray(jax.device_get(x0), np.int64))


def marco_batch(problems) -> np.ndarray:
    """Batched MarCo (Alg. 3). Constant marginals are non-decreasing, so the
    MarIn selection kernel picks all of the cheapest resource's units before
    any of the next (stable sort, resource-major tie-break) — exactly the
    serial MarCo's sort-by-M(1)-and-fill, bit for bit."""
    return marin_batch(problems)


# ---------------------------------------------------------------------------
# MarDecUn / MarDec
# ---------------------------------------------------------------------------


def mardecun_batch(problems) -> np.ndarray:
    """Batched MarDecUn (Alg. 4): all ``T'`` tasks to the first-argmin
    ``C_i(T')`` resource per instance, vectorized over the batch (float64
    host numpy — the exact serial comparison). Zero-capacity resources
    (including inert batch padding) are ignored; a resource with
    ``0 < U'_i < T'`` raises, as in the serial guard."""
    batch = _as_batch(problems)
    b0 = remove_lower_limits(batch)
    span, Tp = b0.upper, b0.T
    if np.any((span > 0) & (span < Tp[:, None])):
        bad = np.nonzero(np.any((span > 0) & (span < Tp[:, None]), axis=1))[0]
        raise ValueError(
            f"MarDecUn requires U_i >= T for all resources with capacity; "
            f"instances {bad.tolist()} violate it"
        )
    idx = np.minimum(Tp[:, None], span)[:, :, None]
    at_T = np.take_along_axis(b0.costs, idx, axis=2)[:, :, 0]  # C_i(T')
    key = np.where(span >= Tp[:, None], at_T, np.inf)
    k = np.argmin(key, axis=1)  # first argmin, like the serial min()
    x0 = np.zeros((batch.B, batch.n), dtype=np.int64)
    x0[np.arange(batch.B), k] = Tp
    return restore_lower_limits(batch, x0)


def mardec_batch(problems) -> np.ndarray:
    """Batched MarDec (Alg. 5): the serial host solver looped over the
    batch (see module docstring — no sound selection-kernel reduction
    exists for decreasing marginals WITH binding upper limits). Accepts a
    sequence of Problems or a ProblemBatch; returns ``(B, n)`` int64.

    Padding-invariant: zero-capacity resources (``U_i = 0`` — phantom
    padding or genuine dropouts) provably take 0 tasks and only shift every
    packing candidate by the same fixed ``C_i(0)``, so they are stripped
    before solving rather than each paying a wasted O(n·T) leave-one-out
    pass inside Algorithm 5; the schedule is identical either way."""
    if isinstance(problems, ProblemBatch):
        problems.validate()
        insts = [problems.instance(b) for b in range(problems.B)]
        n = problems.n
    else:
        insts = list(problems)
        for p in insts:
            p.validate()
        n = max(p.n for p in insts)
    X = np.zeros((len(insts), n), dtype=np.int64)
    for b, p in enumerate(insts):
        keep = np.nonzero(p.upper > 0)[0]
        if len(keep) == 0:  # T == 0 (validated): nothing to assign
            continue
        if len(keep) == p.n:
            X[b, : p.n] = mardec(p)
        else:
            slim = Problem(
                T=p.T,
                lower=p.lower[keep],
                upper=p.upper[keep],
                cost_tables=tuple(p.cost_tables[i] for i in keep),
            )
            X[b, keep] = mardec(slim)
    return X


# algorithm name -> batched implementation (the regime-split sub-batch
# executors the sweep engine and schedule_batch route through)
MARGINAL_BATCH_ALGORITHMS = {
    "marin": marin_batch,
    "marco": marco_batch,
    "mardecun": mardecun_batch,
    "mardec": mardec_batch,
}
