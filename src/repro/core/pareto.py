"""Bicriteria energy × completion-time Pareto engine (DESIGN.md §15).

The paper minimizes energy for a FIXED deadline; real deployments trade
energy against wall-clock (Zhou et al., arXiv 2209.14900, jointly optimize
both). The deadline-constrained solve reduces to the SAME problem — a
deadline is just a tighter upper limit ``U_i' = max{j : time_i(j) <= D}``
(see :func:`repro.core.scheduler.tighten_for_deadline`) — so the entire
(energy, completion-time) Pareto frontier is a *batch* of tightened
instances, and the sweep engine already solves whole batches in ONE
dispatch. This module turns that observation into a first-class capability:

  * :func:`pareto_frontier` — the EXACT Pareto set over
    ``(makespan, energy)`` from one :class:`~repro.core.sweep.SweepEngine`
    dispatch (or one :class:`~repro.serve.service.SchedulerService` request,
    which coalesces with other same-bucket traffic). Exactness: any
    schedule's makespan is ``max_i time_i(x_i)`` — some time-table entry —
    so sweeping the ε-constraint over every feasible table value
    (:func:`candidate_deadlines`) hits every attainable frontier time, and
    dominated-point pruning (:func:`pareto_indices`) keeps, for each energy
    level, the minimal achievable time and vice versa.
  * :class:`ParetoFrontier` — the pruned point set plus the decision rules
    operators actually use: weighted-sum scalarization (always lands ON the
    frontier), ε-constraint lookups (``T_max`` / ``E_max``), and the knee
    point.
  * :func:`frontier_by_window` — time-varying cost tables (carbon-intensity
    / tariff windows, :class:`repro.core.costs.CostWindows`): one frontier
    per window, ALL windows × deadlines stacked into one dispatch (scaling
    tables by positive per-device multipliers preserves each instance's
    marginal regime, so monotone fleets still ride the marginal fast path).

Monotone-regime rows ride the PR-5 marginal selection kernel per frontier
point (``split_regimes=True``, the default); arbitrary-regime rows batch
into the fused DP. The facade entrypoint is
:meth:`repro.core.solver.Solver.frontier`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .problem import Problem, total_cost
from .scheduler import tighten_for_deadline
from .sweep import default_engine

__all__ = [
    "ParetoFrontier",
    "ParetoPoint",
    "assemble_frontier",
    "candidate_deadlines",
    "deadline_grid",
    "feasible_deadline_range",
    "frontier_by_window",
    "pareto_frontier",
    "pareto_indices",
    "tightened_instances",
    "workload_frontier",
]

_BIG_CUTOFF = 1e29  # anything above is an infeasible (BIG-saturated) DP entry


# ---------------------------------------------------------------------------
# pure frontier math (no engine, no threads)
# ---------------------------------------------------------------------------


def pareto_indices(times, energies) -> np.ndarray:
    """Indices of the non-dominated ``(time, energy)`` points (both
    minimized), sorted by time ascending / energy strictly descending.

    Strict dominance with exact float comparison: duplicate times keep the
    cheapest point, duplicate energies keep the fastest — the canonical
    staircase representation of the frontier.
    """
    times = np.asarray(times, dtype=np.float64)
    energies = np.asarray(energies, dtype=np.float64)
    order = np.lexsort((energies, times))  # time asc, then energy asc
    keep, best_e = [], np.inf
    for idx in order:
        if energies[idx] < best_e:
            keep.append(int(idx))
            best_e = energies[idx]
    return np.asarray(keep, dtype=np.int64)


def workload_frontier(k_row: np.ndarray):
    """The (workload, energy) Pareto set hiding in one final DP row.

    ``k_row[t]`` is the minimal cost of assigning EXACTLY ``t`` units
    (:meth:`repro.core.sweep.SweepHandle.k_last`); the bicriterion here
    maximizes workload while minimizing energy. Returns ``(t, energy)``
    arrays, workload ascending with energy strictly increasing (a dominated
    entry — more work available at no extra cost — is pruned).
    """
    k_row = np.asarray(k_row, dtype=np.float64)
    ts = np.nonzero(k_row < _BIG_CUTOFF)[0]
    keep, best_e = [], np.inf
    for t in ts[::-1]:  # largest workload first
        if k_row[t] < best_e:
            keep.append(int(t))
            best_e = k_row[t]
    keep.reverse()
    idx = np.asarray(keep, dtype=np.int64)
    return idx, k_row[idx]


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One frontier point: the ε-constraint ``deadline`` that produced it,
    the schedule's ACHIEVED makespan ``time`` (≤ deadline), its exact
    ``energy`` under the original (float64) cost tables, and the schedule
    itself. ``label`` carries the cost window for time-varying solves."""

    time: float
    energy: float
    deadline: float
    schedule: np.ndarray
    label: Optional[str] = None


class ParetoFrontier:
    """The exact, pruned (time, energy) Pareto set of one instance.

    ``points`` are sorted by time ascending with strictly decreasing energy.
    ``num_swept`` records how many ε-constraint points the one dispatch
    solved (the pre-pruning batch size — frontier telemetry for benchmarks
    and the serve layer).
    """

    def __init__(self, points: Sequence[ParetoPoint], num_swept: int = 0):
        self.points = tuple(points)
        self.num_swept = int(num_swept)
        if not self.points:
            raise ValueError("a Pareto frontier needs at least one point")

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self):
        return iter(self.points)

    def __getitem__(self, i) -> ParetoPoint:
        return self.points[i]

    @property
    def times(self) -> np.ndarray:
        return np.array([p.time for p in self.points], dtype=np.float64)

    @property
    def energies(self) -> np.ndarray:
        return np.array([p.energy for p in self.points], dtype=np.float64)

    # ---- decision rules -------------------------------------------------

    def min_time(self) -> ParetoPoint:
        return self.points[0]

    def min_energy(self) -> ParetoPoint:
        return self.points[-1]

    def knee(self) -> ParetoPoint:
        """The balanced operating point: minimal Euclidean distance to the
        ideal corner ``(min time, min energy)`` after normalizing both axes
        to the frontier's own range."""
        t, e = self.times, self.energies
        t_span = max(t[-1] - t[0], 1e-300)
        e_span = max(e[0] - e[-1], 1e-300)
        d = ((t - t[0]) / t_span) ** 2 + ((e - e[-1]) / e_span) ** 2
        return self.points[int(np.argmin(d))]

    def scalarize(
        self, w_energy: float, w_time: float, normalize: bool = True
    ) -> ParetoPoint:
        """Weighted-sum solve ``min w_E * energy + w_T * time`` — evaluated
        over the frontier, so the optimum is exact (a weighted-sum optimum
        is always Pareto-optimal) and costs no extra dispatch. With
        ``normalize`` both axes are rescaled to the frontier range first, so
        weights express preference rather than unit conversion. Ties pick
        the faster point."""
        if w_energy < 0 or w_time < 0 or (w_energy == 0 and w_time == 0):
            raise ValueError("weights must be non-negative and not both zero")
        t, e = self.times, self.energies
        if normalize:
            t = (t - t[0]) / max(t[-1] - t[0], 1e-300)
            e = (e - e[-1]) / max(e[0] - e[-1], 1e-300)
        return self.points[int(np.argmin(w_energy * e + w_time * t))]

    def constrain(
        self, T_max: Optional[float] = None, E_max: Optional[float] = None
    ) -> ParetoPoint:
        """ε-constraint lookup: minimal energy subject to ``time <= T_max``,
        or minimal time subject to ``energy <= E_max`` (exactly one bound).
        Raises ValueError when no frontier point satisfies the bound."""
        if (T_max is None) == (E_max is None):
            raise ValueError("pass exactly one of T_max / E_max")
        if T_max is not None:
            ok = np.nonzero(self.times <= float(T_max))[0]
            if not len(ok):
                raise ValueError(
                    f"T_max={T_max} infeasible: fastest frontier point needs "
                    f"time {self.points[0].time:.6g}"
                )
            return self.points[int(ok[-1])]  # loosest feasible = min energy
        ok = np.nonzero(self.energies <= float(E_max))[0]
        if not len(ok):
            raise ValueError(
                f"E_max={E_max} infeasible: cheapest frontier point needs "
                f"energy {self.points[-1].energy:.6g}"
            )
        return self.points[int(ok[0])]  # tightest feasible = min time

    def select(self, mode) -> ParetoPoint:
        """Named operating-point policies (the ``frontier_mode`` knob of
        :class:`repro.fl.server.FederatedServer`): ``"min_energy"`` |
        ``"min_time"`` | ``"knee"``, or a number — a round-time budget,
        resolved as ``constrain(T_max=mode)``."""
        if isinstance(mode, str):
            try:
                return {
                    "min_energy": self.min_energy,
                    "min_time": self.min_time,
                    "knee": self.knee,
                }[mode]()
            except KeyError:
                raise ValueError(
                    f"unknown frontier mode {mode!r}; options: min_energy, "
                    f"min_time, knee, or a numeric time budget"
                ) from None
        return self.constrain(T_max=float(mode))


# ---------------------------------------------------------------------------
# deadline candidates: the exact breakpoints of the energy(deadline) staircase
# ---------------------------------------------------------------------------


def _max_index_within(t: np.ndarray, deadlines: np.ndarray) -> np.ndarray:
    """``u[d] = max{j : t[j] <= d}`` (-1 when empty) for every deadline,
    vectorized. Works for arbitrary (non-monotone) time tables via suffix
    minima: ``max{j : t[j] <= d} = max{j : min(t[j:]) <= d}`` and suffix
    minima are non-decreasing, so searchsorted applies. Identical to the
    per-deadline rule in :func:`~repro.core.scheduler.tighten_for_deadline`.
    """
    suff = np.minimum.accumulate(np.asarray(t, dtype=np.float64)[::-1])[::-1]
    return np.searchsorted(suff, deadlines, side="right") - 1


def _feasible_mask(problem: Problem, time_tables, deadlines: np.ndarray) -> np.ndarray:
    """Which deadlines admit a feasible tightened instance (every device can
    still meet its lower limit; fleet capacity still reaches ``T``)."""
    deadlines = np.asarray(deadlines, dtype=np.float64)
    ok = np.ones(len(deadlines), dtype=bool)
    cap = np.zeros(len(deadlines), dtype=np.int64)
    for i in range(problem.n):
        u = _max_index_within(np.asarray(time_tables[i]), deadlines)
        ok &= u >= int(problem.lower[i])
        cap += np.minimum(u, int(problem.upper[i])).clip(min=0)
    return ok & (cap >= problem.T)


def candidate_deadlines(problem: Problem, time_tables) -> np.ndarray:
    """Every deadline at which the optimal energy can change: the sorted
    unique time-table values ``time_i(j)`` over each device's feasible range
    ``[L_i, U_i]``, filtered to feasibility. Sweeping exactly these points
    yields the EXACT frontier — any schedule's makespan is one of them."""
    vals = np.unique(
        np.concatenate(
            [
                np.asarray(time_tables[i], dtype=np.float64)[
                    int(problem.lower[i]) : int(problem.upper[i]) + 1
                ]
                for i in range(problem.n)
            ]
        )
    )
    feasible = vals[_feasible_mask(problem, time_tables, vals)]
    if not len(feasible):
        raise ValueError("no feasible deadline: instance cannot be scheduled at all")
    return feasible


def feasible_deadline_range(problem: Problem, time_tables):
    """``(d_min, d_max)``: the tightest feasible ε-constraint and the value
    beyond which the constraint is vacuous (every device may run its full
    upper limit)."""
    cands = candidate_deadlines(problem, time_tables)
    return float(cands[0]), float(cands[-1])


def deadline_grid(problem: Problem, time_tables, points: int) -> np.ndarray:
    """An ``<= points``-sized subsample of the exact candidate set (first and
    last always kept): the cheap approximate sweep for live planning loops
    (``FederatedServer(frontier_mode=...)``) where a bounded batch size
    matters more than frontier completeness."""
    cands = candidate_deadlines(problem, time_tables)
    if len(cands) <= int(points):
        return cands
    idx = np.unique(np.linspace(0, len(cands) - 1, int(points)).round().astype(int))
    return cands[idx]


# ---------------------------------------------------------------------------
# frontier extraction: one engine dispatch (or one service request)
# ---------------------------------------------------------------------------


def tightened_instances(problem: Problem, time_tables, deadlines) -> list:
    """The ε-constraint batch: one deadline-tightened instance per point
    (same ``n``/``T``/``W`` envelope, so the whole batch lands in ONE engine
    compile bucket). Raises ValueError naming the offending deadline when a
    point is infeasible."""
    tight = []
    for d in deadlines:
        try:
            tight.append(tighten_for_deadline(problem, time_tables, float(d)))
        except ValueError as e:
            raise ValueError(f"frontier point {d}: {e}") from e
    return tight


def assemble_frontier(
    problem: Problem, time_tables, deadlines, X: np.ndarray, label: Optional[str] = None
) -> ParetoFrontier:
    """Prunes the solved ε-constraint sweep into a :class:`ParetoFrontier`.

    ``X`` holds the ``(B, n)`` schedules of :func:`tightened_instances`;
    energies are re-evaluated on the host against the ORIGINAL float64 cost
    tables (exact — independent of the f32 device arithmetic that picked the
    schedules), times are each schedule's achieved makespan.
    """
    X = np.asarray(X, dtype=np.int64)[:, : problem.n]
    energies = np.array([total_cost(problem, x) for x in X], dtype=np.float64)
    times = np.array(
        [
            max(float(time_tables[i][int(x[i])]) for i in range(problem.n))
            for x in X
        ],
        dtype=np.float64,
    )
    keep = pareto_indices(times, energies)
    points = [
        ParetoPoint(
            time=float(times[b]),
            energy=float(energies[b]),
            deadline=float(deadlines[b]),
            schedule=X[b].copy(),
            label=label,
        )
        for b in keep
    ]
    return ParetoFrontier(points, num_swept=len(X))


def _solve_sweep(tight, engine, backend, service, split_regimes) -> np.ndarray:
    """ONE dispatch for the whole tightened batch: through the serve layer
    when a service is given (the request coalesces with other same-bucket
    traffic), else straight through the engine."""
    if service is not None:
        return np.asarray(service.submit(tight, split_regimes=split_regimes).result())
    if engine is None:
        engine = default_engine(backend or "auto")
    return engine.solve(tight, split_regimes=split_regimes)


def pareto_frontier(
    problem: Problem,
    time_tables,
    deadlines=None,
    *,
    engine=None,
    backend: Optional[str] = None,
    service=None,
    split_regimes: bool = True,
) -> ParetoFrontier:
    """The (energy, completion-time) Pareto frontier of one instance, from
    ONE batched dispatch.

    ``deadlines=None`` sweeps the exact candidate set
    (:func:`candidate_deadlines` — every point where the optimum can move),
    making the returned frontier the EXACT Pareto set; pass an explicit grid
    (e.g. :func:`deadline_grid`) to bound the batch size instead. With
    ``split_regimes=True`` (default) monotone-regime rows ride the marginal
    fast path (DESIGN.md §13); ``False`` forces every point through the
    fused DP. ``service`` routes the sweep through a
    :class:`~repro.serve.service.SchedulerService` as one coalescable
    request.
    """
    problem.validate()
    if deadlines is None:
        deadlines = candidate_deadlines(problem, time_tables)
    deadlines = np.asarray(list(deadlines), dtype=np.float64)
    tight = tightened_instances(problem, time_tables, deadlines)
    X = _solve_sweep(tight, engine, backend, service, split_regimes)
    return assemble_frontier(problem, time_tables, deadlines, X)


def frontier_by_window(
    problem: Problem,
    time_tables,
    windows,
    deadlines=None,
    *,
    engine=None,
    backend: Optional[str] = None,
    service=None,
    split_regimes: bool = True,
) -> dict:
    """Per-window frontiers under time-varying costs — ALL windows and ALL
    deadline points solved in ONE dispatch.

    ``windows`` is a :class:`repro.core.costs.CostWindows` (window-indexed
    per-device cost multipliers: carbon-intensity or tariff schedules). The
    candidate deadlines depend only on the time tables, so every window
    shares one sweep grid; the per-window tightened instances all share the
    ``(n, T, W)`` envelope and therefore one compile bucket. Returns
    ``{window label: ParetoFrontier}``.
    """
    problem.validate()
    if deadlines is None:
        deadlines = candidate_deadlines(problem, time_tables)
    deadlines = np.asarray(list(deadlines), dtype=np.float64)
    stacked, per_window = [], []
    for w, wp in enumerate(windows.apply(problem)):
        tight = tightened_instances(wp, time_tables, deadlines)
        stacked.extend(tight)
        per_window.append((windows.labels[w], wp))
    X = _solve_sweep(stacked, engine, backend, service, split_regimes)
    out, B = {}, len(deadlines)
    for w, (label, wp) in enumerate(per_window):
        out[label] = assemble_frontier(
            wp, time_tables, deadlines, X[w * B : (w + 1) * B], label=label
        )
    return out
