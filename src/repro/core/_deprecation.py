"""Warn-once deprecation plumbing for the legacy solver entrypoints.

PR 7 (DESIGN.md §15) folded the six ad-hoc solve entrypoints that five PRs
of growth accumulated — ``schedule`` / ``schedule_batch`` /
``schedule_with_deadline`` / ``deadline_sweep`` / ``solve_dp_batch_cached``
/ ``solve_schedule_batch_cached`` — behind one facade,
:class:`repro.core.solver.Solver`. The old names keep working bit-identically
(they are thin shims over the same private implementations the facade
calls), but each fires ONE :class:`DeprecationWarning` per process so
migrations are visible without drowning sweep loops in warning spam.

Kept in its own leaf module because both ``core/scheduler.py`` and
``core/sweep.py`` need it and ``core/solver.py`` imports both.
"""

from __future__ import annotations

import threading
import warnings

__all__ = ["reset_deprecation_warnings", "warn_deprecated"]

_WARNED: set = set()
_LOCK = threading.Lock()


def warn_deprecated(name: str, replacement: str, module: str = "repro.core") -> None:
    """Fires ``DeprecationWarning`` for entrypoint ``name`` exactly once per
    process (repeat calls are silent — deterministic, unlike the interpreter's
    per-call-site ``__warningregistry__`` dedup). ``module`` labels where the
    deprecated spelling lives (``repro.fl`` for the legacy ``FederatedServer``
    kwargs, PR 8)."""
    with _LOCK:
        if name in _WARNED:
            return
        _WARNED.add(name)
    warnings.warn(
        f"{module}.{name} is deprecated; use {replacement} "
        f"(the Solver facade, DESIGN.md §15) — behavior is bit-identical",
        DeprecationWarning,
        stacklevel=3,
    )


def reset_deprecation_warnings() -> None:
    """Forgets which entrypoints already warned (test isolation)."""
    with _LOCK:
        _WARNED.clear()
