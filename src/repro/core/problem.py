"""Minimal Cost FL Schedule problem (paper Definition 1).

An instance ``(R, T, U, L, C)``:
  - ``n`` heterogeneous resources,
  - workload of ``T`` identical, independent, atomic tasks,
  - per-resource lower/upper limits ``L_i <= x_i <= U_i``,
  - per-resource cost functions ``C_i : [L_i, U_i] -> R>=0``.

Goal: schedule ``X = (x_1..x_n)`` with ``sum x_i == T`` minimizing
``sum_i C_i(x_i)``.

Cost functions are represented as dense tables over ``[0, U_i]`` (entries
below ``L_i`` are present but never selected) so that all algorithms —
including the (MC)^2MKP dynamic program and the Pallas min-plus kernel —
can consume them as arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "Problem",
    "ProblemBatch",
    "Schedule",
    "classify_regimes",
    "remove_lower_limits",
    "restore_lower_limits",
    "total_cost",
    "total_cost_batch",
    "validate_schedule",
    "validate_schedule_batch",
]

# Large-but-finite stand-in for +inf in dense packed tables (mirrors
# repro.kernels.ref.BIG; duplicated here so core carries no kernel import).
PACK_BIG = 1e30


def classify_regimes(costs, lower, upper, atol: float = 1e-9) -> np.ndarray:
    """Vectorized marginal-cost regime classification (paper Definition 3).

    THE single source of truth for regime detection: ``Problem.regime``,
    ``ProblemBatch.regimes``, and the scheduler's serial AND batched
    algorithm dispatch all route through here, so the two dispatch paths can
    never disagree (DESIGN.md §13).

    Args:
      costs: ``(B, n, W)`` dense packed tables (entries beyond each ``U_i``
        may hold anything — they are masked out).
      lower/upper: ``(B, n)`` limits.

    Returns a ``(B,)`` array of ``'increasing' | 'constant' | 'decreasing' |
    'arbitrary'`` strings. A resource contributes the marginal comparisons
    ``M_i(j)`` vs ``M_i(j+1)`` for ``j`` in ``[L_i+1, U_i-1]``; resources
    with fewer than two marginals (``U_i - L_i < 2`` — including padded
    phantom resources) contribute nothing, so classification is invariant
    under the inert batch padding of :meth:`ProblemBatch.pad_to`.
    """
    costs = np.asarray(costs, dtype=np.float64)
    lower = np.asarray(lower, dtype=np.int64)
    upper = np.asarray(upper, dtype=np.int64)
    B, n, W = costs.shape
    if W < 3:  # no resource can have two marginals
        return np.full(B, "constant", dtype=object)
    d1 = costs[:, :, 1:] - costs[:, :, :-1]  # d1[..., j-1] = M(j)
    d2 = d1[:, :, 1:] - d1[:, :, :-1]  # d2[..., j-1] = M(j+1) - M(j)
    j = np.arange(1, W - 1)[None, None, :]
    valid = (j >= lower[:, :, None] + 1) & (j + 1 <= upper[:, :, None])
    d2 = np.where(valid, d2, 0.0)
    inc = ~np.any(d2 < -atol, axis=(1, 2))
    con = ~np.any(np.abs(d2) > atol, axis=(1, 2))
    dec = ~np.any(d2 > atol, axis=(1, 2))
    out = np.full(B, "arbitrary", dtype=object)
    out[dec] = "decreasing"
    out[inc] = "increasing"
    out[con] = "constant"  # constant wins over increasing/decreasing
    return out


@dataclasses.dataclass(frozen=True)
class Problem:
    """A Minimal Cost FL Schedule instance.

    Attributes:
      T: number of tasks to schedule.
      lower: ``(n,)`` int array of lower limits ``L_i``.
      upper: ``(n,)`` int array of upper limits ``U_i``.
      cost_tables: list of ``(U_i + 1,)`` float arrays; ``cost_tables[i][j]``
        is ``C_i(j)``. Values for ``j < L_i`` exist but are never selected.
    """

    T: int
    lower: np.ndarray
    upper: np.ndarray
    cost_tables: tuple

    def __post_init__(self):
        object.__setattr__(self, "lower", np.asarray(self.lower, dtype=np.int64))
        object.__setattr__(self, "upper", np.asarray(self.upper, dtype=np.int64))
        object.__setattr__(
            self,
            "cost_tables",
            tuple(np.asarray(c, dtype=np.float64) for c in self.cost_tables),
        )

    @property
    def n(self) -> int:
        return len(self.cost_tables)

    def cost(self, i: int, j: int) -> float:
        return float(self.cost_tables[i][j])

    def validate(self) -> None:
        """Checks the instance is valid & non-trivial (paper Section 3)."""
        if self.n == 0:
            raise ValueError("need at least one resource")
        if len(self.lower) != self.n or len(self.upper) != self.n:
            raise ValueError("limits and cost tables disagree on n")
        if np.any(self.lower < 0):
            raise ValueError("lower limits must be non-negative")
        if np.any(self.upper < self.lower):
            raise ValueError("upper limit below lower limit")
        for i, tbl in enumerate(self.cost_tables):
            if len(tbl) != self.upper[i] + 1:
                raise ValueError(
                    f"cost table {i} has {len(tbl)} entries, expected U_i+1="
                    f"{self.upper[i] + 1}"
                )
        if not (int(self.lower.sum()) <= self.T <= int(self.upper.sum())):
            raise ValueError(
                f"T={self.T} outside feasible range "
                f"[{int(self.lower.sum())}, {int(self.upper.sum())}]"
            )

    # ---- constructors -------------------------------------------------

    @staticmethod
    def from_functions(
        T: int,
        lower: Sequence[int],
        upper: Sequence[int],
        fns: Sequence[Callable[[int], float]],
    ) -> "Problem":
        """Tabulates callables ``C_i`` over ``[0, U_i]``."""
        tables = [
            np.array([float(f(j)) for j in range(int(u) + 1)]) for f, u in zip(fns, upper)
        ]
        return Problem(T=T, lower=np.asarray(lower), upper=np.asarray(upper), cost_tables=tuple(tables))

    def marginal_costs(self, i: int) -> np.ndarray:
        """Marginal cost function M_i over [L_i, U_i] (paper eq. 6).

        ``M_i(L_i) = 0`` by definition; ``M_i(j) = C_i(j) - C_i(j-1)``.
        Returned array is indexed by absolute j in ``[0, U_i]`` with entries
        below ``L_i`` set to 0 (never used).
        """
        tbl = self.cost_tables[i]
        m = np.zeros_like(tbl)
        lo = int(self.lower[i])
        if lo + 1 <= int(self.upper[i]):
            m[lo + 1 :] = tbl[lo + 1 :] - tbl[lo:-1]
        return m

    def regime(self, atol: float = 1e-9) -> str:
        """Classifies marginal-cost behaviour: 'increasing' | 'constant' |
        'decreasing' | 'arbitrary' (paper Definition 3). Delegates to the
        vectorized :func:`classify_regimes` — the same code the batched
        dispatch runs, so serial and batched regime detection agree by
        construction."""
        W = int(self.upper.max()) + 1
        costs = np.full((1, self.n, W), PACK_BIG, dtype=np.float64)
        for i, tbl in enumerate(self.cost_tables):
            costs[0, i, : len(tbl)] = tbl
        return str(classify_regimes(costs, self.lower[None], self.upper[None], atol)[0])


@dataclasses.dataclass(frozen=True)
class ProblemBatch:
    """A stack of ``B`` Minimal Cost FL Schedule instances in one dense,
    batch-first representation (DESIGN.md §9).

    Ragged instances are padded to common ``n`` (resource axis) and ``W``
    (cost-table width, ``max_i U_i + 1``):

      * padded *resources* get ``L = U = 0`` and cost table ``[0, BIG, ...]``
        so the DP assigns them exactly 0 tasks at 0 cost;
      * padded *table entries* beyond each ``U_i`` are ``BIG`` so those item
        sizes are never selected.

    Attributes:
      T: ``(B,)`` int array of per-instance workloads.
      lower: ``(B, n)`` int array of lower limits.
      upper: ``(B, n)`` int array of upper limits.
      costs: ``(B, n, W)`` float array; ``costs[b, i, j] = C_i(j)`` for
        instance ``b``, ``BIG``-padded beyond ``U_i``.
    """

    T: np.ndarray
    lower: np.ndarray
    upper: np.ndarray
    costs: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "T", np.asarray(self.T, dtype=np.int64))
        object.__setattr__(self, "lower", np.asarray(self.lower, dtype=np.int64))
        object.__setattr__(self, "upper", np.asarray(self.upper, dtype=np.int64))
        object.__setattr__(self, "costs", np.asarray(self.costs, dtype=np.float64))
        if self.costs.ndim != 3:
            raise ValueError(f"costs must be (B, n, W), got {self.costs.shape}")
        B, n, W = self.costs.shape
        if self.T.shape != (B,) or self.lower.shape != (B, n) or self.upper.shape != (B, n):
            raise ValueError("T/lower/upper shapes disagree with costs")
        if W < int(self.upper.max()) + 1:
            raise ValueError("cost tables narrower than max upper limit + 1")

    @property
    def B(self) -> int:
        return self.costs.shape[0]

    @property
    def n(self) -> int:
        return self.costs.shape[1]

    @property
    def W(self) -> int:
        return self.costs.shape[2]

    @staticmethod
    def from_problems(problems: Sequence["Problem"]) -> "ProblemBatch":
        """Stacks (possibly ragged) instances; each is validated first."""
        if not problems:
            raise ValueError("need at least one problem")
        for p in problems:
            p.validate()
        B = len(problems)
        n = max(p.n for p in problems)
        W = max(int(p.upper.max()) for p in problems) + 1
        T = np.array([p.T for p in problems], dtype=np.int64)
        lower = np.zeros((B, n), dtype=np.int64)
        upper = np.zeros((B, n), dtype=np.int64)
        costs = np.full((B, n, W), PACK_BIG, dtype=np.float64)
        costs[:, :, 0] = 0.0  # padded resources: only x=0, at zero cost
        for b, p in enumerate(problems):
            lower[b, : p.n] = p.lower
            upper[b, : p.n] = p.upper
            for i, tbl in enumerate(p.cost_tables):
                costs[b, i, : len(tbl)] = tbl
                costs[b, i, len(tbl) :] = PACK_BIG
        return ProblemBatch(T=T, lower=lower, upper=upper, costs=costs)

    def pad_to(self, B=None, n=None, W=None) -> "ProblemBatch":
        """Embeds the batch in a larger ``(B, n, W)`` envelope (sweep-engine
        shape bucketing, DESIGN.md §10).

        Phantom instances get ``T = 0`` with all-phantom resources; phantom
        resources get ``L = U = 0`` and cost table ``[0, BIG, ...]``; extra
        table entries are BIG. All padding is therefore inert: the DP assigns
        phantoms exactly 0 tasks at 0 cost and real rows/columns solve
        bit-identically to the unpadded batch (argmin ties resolve to the
        same ``j`` because BIG candidates never win and all-BIG ties pick
        ``j = 0`` with or without padding).
        """
        B2 = self.B if B is None else int(B)
        n2 = self.n if n is None else int(n)
        W2 = self.W if W is None else int(W)
        if (B2, n2, W2) == (self.B, self.n, self.W):
            return self
        if B2 < self.B or n2 < self.n or W2 < self.W:
            raise ValueError(
                f"pad_to target ({B2}, {n2}, {W2}) smaller than batch "
                f"({self.B}, {self.n}, {self.W})"
            )
        T = np.zeros(B2, dtype=np.int64)
        T[: self.B] = self.T
        lower = np.zeros((B2, n2), dtype=np.int64)
        lower[: self.B, : self.n] = self.lower
        upper = np.zeros((B2, n2), dtype=np.int64)
        upper[: self.B, : self.n] = self.upper
        costs = np.full((B2, n2, W2), PACK_BIG, dtype=np.float64)
        costs[:, :, 0] = 0.0  # phantoms: only x=0, at zero cost
        costs[: self.B, : self.n, : self.W] = self.costs
        return ProblemBatch(T=T, lower=lower, upper=upper, costs=costs)

    def regimes(self, atol: float = 1e-9) -> np.ndarray:
        """Per-instance marginal-cost regimes, ``(B,)`` strings — the batched
        counterpart of :meth:`Problem.regime` (same :func:`classify_regimes`
        core, so ``batch.regimes()[b] == batch.instance(b).regime()``)."""
        return classify_regimes(self.costs, self.lower, self.upper, atol)

    def instance(self, b: int) -> "Problem":
        """Materializes instance ``b`` as a standalone :class:`Problem`
        (padded resources are kept, as 0-task-only classes)."""
        tables = tuple(
            self.costs[b, i, : int(self.upper[b, i]) + 1] for i in range(self.n)
        )
        return Problem(T=int(self.T[b]), lower=self.lower[b], upper=self.upper[b], cost_tables=tables)

    def validate(self) -> None:
        if np.any(self.lower < 0):
            raise ValueError("lower limits must be non-negative")
        if np.any(self.upper < self.lower):
            raise ValueError("upper limit below lower limit")
        lo_sum = self.lower.sum(axis=1)
        up_sum = self.upper.sum(axis=1)
        if np.any(self.T < lo_sum) or np.any(self.T > up_sum):
            bad = np.nonzero((self.T < lo_sum) | (self.T > up_sum))[0]
            raise ValueError(f"instances {bad.tolist()} have T outside the feasible range")


Schedule = np.ndarray  # (n,) int array of assignments x_i


def total_cost(problem: Problem, x: Schedule) -> float:
    return float(sum(problem.cost(i, int(x[i])) for i in range(problem.n)))


def validate_schedule(problem: Problem, x: Schedule) -> None:
    x = np.asarray(x)
    if x.shape != (problem.n,):
        raise ValueError(f"schedule shape {x.shape} != ({problem.n},)")
    if int(x.sum()) != problem.T:
        raise ValueError(f"schedule assigns {int(x.sum())} tasks, T={problem.T}")
    if np.any(x < problem.lower) or np.any(x > problem.upper):
        raise ValueError("schedule violates limits")


def total_cost_batch(batch: ProblemBatch, X: np.ndarray) -> np.ndarray:
    """(B,) total cost of each row of ``X`` ((B, n) assignments) under its
    instance's packed cost tables."""
    X = np.asarray(X, dtype=np.int64)
    picked = np.take_along_axis(batch.costs, X[:, :, None], axis=2)[:, :, 0]
    return picked.sum(axis=1)


def validate_schedule_batch(batch: ProblemBatch, X: np.ndarray) -> None:
    X = np.asarray(X)
    if X.shape != (batch.B, batch.n):
        raise ValueError(f"schedule shape {X.shape} != ({batch.B}, {batch.n})")
    if np.any(X.sum(axis=1) != batch.T):
        bad = np.nonzero(X.sum(axis=1) != batch.T)[0]
        raise ValueError(f"instances {bad.tolist()}: task totals != T")
    if np.any(X < batch.lower) or np.any(X > batch.upper):
        raise ValueError("batched schedule violates limits")


def remove_lower_limits(problem):
    """Equivalent instance(s) with all lower limits shifted to zero.

    Paper Section 5.2, eqs. (8)-(10):
      T' = T - sum L_i;  U'_i = U_i - L_i;  C'_i(j) = C_i(j + L_i) - C_i(L_i).

    Accepts a :class:`Problem` or a :class:`ProblemBatch` (the shift is
    applied per instance, vectorized over the whole batch).
    """
    if isinstance(problem, ProblemBatch):
        return _remove_lower_limits_batch(problem)
    Tp = problem.T - int(problem.lower.sum())
    upper = problem.upper - problem.lower
    tables = tuple(
        tbl[int(lo) :] - tbl[int(lo)]
        for tbl, lo in zip(problem.cost_tables, problem.lower)
    )
    return Problem(T=Tp, lower=np.zeros(problem.n, dtype=np.int64), upper=upper, cost_tables=tables)


def _remove_lower_limits_batch(batch: ProblemBatch) -> ProblemBatch:
    """Vectorized eqs. (8)-(10) over a ``(B, n, W)`` stack: each cost row is
    left-shifted by its ``L`` and rebased to ``C(L) = 0``; vacated tail
    entries become BIG."""
    B, n, W = batch.costs.shape
    Tp = batch.T - batch.lower.sum(axis=1)
    upper = batch.upper - batch.lower
    j = np.arange(W)[None, None, :]  # (1, 1, W)
    src = j + batch.lower[:, :, None]  # (B, n, W) source index C(j + L)
    valid = src <= batch.upper[:, :, None]
    base = np.take_along_axis(batch.costs, batch.lower[:, :, None], axis=2)  # C(L)
    shifted = np.take_along_axis(batch.costs, np.minimum(src, W - 1), axis=2) - base
    costs = np.where(valid, shifted, PACK_BIG)
    return ProblemBatch(T=Tp, lower=np.zeros((B, n), dtype=np.int64), upper=upper, costs=costs)


def restore_lower_limits(problem, x_prime):
    """Paper eq. (11): x_i = x'_i + L_i. Batch-aware: with a
    :class:`ProblemBatch` and ``(B, n)`` assignments, adds each instance's
    lower limits row-wise."""
    return np.asarray(x_prime) + problem.lower
