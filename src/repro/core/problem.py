"""Minimal Cost FL Schedule problem (paper Definition 1).

An instance ``(R, T, U, L, C)``:
  - ``n`` heterogeneous resources,
  - workload of ``T`` identical, independent, atomic tasks,
  - per-resource lower/upper limits ``L_i <= x_i <= U_i``,
  - per-resource cost functions ``C_i : [L_i, U_i] -> R>=0``.

Goal: schedule ``X = (x_1..x_n)`` with ``sum x_i == T`` minimizing
``sum_i C_i(x_i)``.

Cost functions are represented as dense tables over ``[0, U_i]`` (entries
below ``L_i`` are present but never selected) so that all algorithms —
including the (MC)^2MKP dynamic program and the Pallas min-plus kernel —
can consume them as arrays.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

__all__ = [
    "Problem",
    "Schedule",
    "remove_lower_limits",
    "restore_lower_limits",
    "total_cost",
    "validate_schedule",
]


@dataclasses.dataclass(frozen=True)
class Problem:
    """A Minimal Cost FL Schedule instance.

    Attributes:
      T: number of tasks to schedule.
      lower: ``(n,)`` int array of lower limits ``L_i``.
      upper: ``(n,)`` int array of upper limits ``U_i``.
      cost_tables: list of ``(U_i + 1,)`` float arrays; ``cost_tables[i][j]``
        is ``C_i(j)``. Values for ``j < L_i`` exist but are never selected.
    """

    T: int
    lower: np.ndarray
    upper: np.ndarray
    cost_tables: tuple

    def __post_init__(self):
        object.__setattr__(self, "lower", np.asarray(self.lower, dtype=np.int64))
        object.__setattr__(self, "upper", np.asarray(self.upper, dtype=np.int64))
        object.__setattr__(
            self,
            "cost_tables",
            tuple(np.asarray(c, dtype=np.float64) for c in self.cost_tables),
        )

    @property
    def n(self) -> int:
        return len(self.cost_tables)

    def cost(self, i: int, j: int) -> float:
        return float(self.cost_tables[i][j])

    def validate(self) -> None:
        """Checks the instance is valid & non-trivial (paper Section 3)."""
        if self.n == 0:
            raise ValueError("need at least one resource")
        if len(self.lower) != self.n or len(self.upper) != self.n:
            raise ValueError("limits and cost tables disagree on n")
        if np.any(self.lower < 0):
            raise ValueError("lower limits must be non-negative")
        if np.any(self.upper < self.lower):
            raise ValueError("upper limit below lower limit")
        for i, tbl in enumerate(self.cost_tables):
            if len(tbl) != self.upper[i] + 1:
                raise ValueError(
                    f"cost table {i} has {len(tbl)} entries, expected U_i+1="
                    f"{self.upper[i] + 1}"
                )
        if not (int(self.lower.sum()) <= self.T <= int(self.upper.sum())):
            raise ValueError(
                f"T={self.T} outside feasible range "
                f"[{int(self.lower.sum())}, {int(self.upper.sum())}]"
            )

    # ---- constructors -------------------------------------------------

    @staticmethod
    def from_functions(
        T: int,
        lower: Sequence[int],
        upper: Sequence[int],
        fns: Sequence[Callable[[int], float]],
    ) -> "Problem":
        """Tabulates callables ``C_i`` over ``[0, U_i]``."""
        tables = [
            np.array([float(f(j)) for j in range(int(u) + 1)]) for f, u in zip(fns, upper)
        ]
        return Problem(T=T, lower=np.asarray(lower), upper=np.asarray(upper), cost_tables=tuple(tables))

    def marginal_costs(self, i: int) -> np.ndarray:
        """Marginal cost function M_i over [L_i, U_i] (paper eq. 6).

        ``M_i(L_i) = 0`` by definition; ``M_i(j) = C_i(j) - C_i(j-1)``.
        Returned array is indexed by absolute j in ``[0, U_i]`` with entries
        below ``L_i`` set to 0 (never used).
        """
        tbl = self.cost_tables[i]
        m = np.zeros_like(tbl)
        lo = int(self.lower[i])
        if lo + 1 <= int(self.upper[i]):
            m[lo + 1 :] = tbl[lo + 1 :] - tbl[lo:-1]
        return m

    def regime(self, atol: float = 1e-9) -> str:
        """Classifies marginal-cost behaviour: 'increasing' | 'constant' |
        'decreasing' | 'arbitrary' (paper Definition 3)."""
        inc = con = dec = True
        for i in range(self.n):
            lo, up = int(self.lower[i]), int(self.upper[i])
            if up - lo < 2:
                continue  # fewer than two marginals: consistent with anything
            m = self.marginal_costs(i)[lo + 1 : up + 1]
            d = np.diff(m)
            if np.any(d < -atol):
                inc = False
            if np.any(np.abs(d) > atol):
                con = False
            if np.any(d > atol):
                dec = False
        if con:
            return "constant"
        if inc:
            return "increasing"
        if dec:
            return "decreasing"
        return "arbitrary"


Schedule = np.ndarray  # (n,) int array of assignments x_i


def total_cost(problem: Problem, x: Schedule) -> float:
    return float(sum(problem.cost(i, int(x[i])) for i in range(problem.n)))


def validate_schedule(problem: Problem, x: Schedule) -> None:
    x = np.asarray(x)
    if x.shape != (problem.n,):
        raise ValueError(f"schedule shape {x.shape} != ({problem.n},)")
    if int(x.sum()) != problem.T:
        raise ValueError(f"schedule assigns {int(x.sum())} tasks, T={problem.T}")
    if np.any(x < problem.lower) or np.any(x > problem.upper):
        raise ValueError("schedule violates limits")


def remove_lower_limits(problem: Problem) -> Problem:
    """Equivalent instance with all lower limits shifted to zero.

    Paper Section 5.2, eqs. (8)-(10):
      T' = T - sum L_i;  U'_i = U_i - L_i;  C'_i(j) = C_i(j + L_i) - C_i(L_i).
    """
    Tp = problem.T - int(problem.lower.sum())
    upper = problem.upper - problem.lower
    tables = tuple(
        tbl[int(lo) :] - tbl[int(lo)]
        for tbl, lo in zip(problem.cost_tables, problem.lower)
    )
    return Problem(T=Tp, lower=np.zeros(problem.n, dtype=np.int64), upper=upper, cost_tables=tables)


def restore_lower_limits(problem: Problem, x_prime: Schedule) -> Schedule:
    """Paper eq. (11): x_i = x'_i + L_i."""
    return np.asarray(x_prime) + problem.lower
