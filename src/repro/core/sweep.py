"""Sweep engine: shape-bucketed compile cache + device-sharded batches
(DESIGN.md §10).

The batched DP (§9) amortizes kernel launches across one sweep, but every
new padded shape ``(B, n, T_max, W_max)`` still pays a fresh XLA compile
(~4 s cold vs ~25 ms warm on CPU, see BENCH_batch.json). Production traffic
— multi-round campaigns with drifting energy estimates, 100-point deadline
sweeps, what-if grids — re-solves *near*-identical shapes constantly, so the
engine:

  1. **bucketizes** shapes: each of ``B``/``n``/``T_max``/``W_max`` is
     rounded up to the next power of two, and the padded program for a
     bucket is kept in an LRU of jitted callables. Any solve landing in a
     warm bucket reuses the compiled executable — a campaign compiles once
     on round 1 and never again. Padding is *inert* (phantom instances /
     resources / BIG table entries; see :meth:`ProblemBatch.pad_to`), so
     bucketed solves are bit-identical to uncached
     :func:`~repro.core.jax_dp.solve_schedule_dp_batch`.
  2. **shards** the batch axis: with a ``mesh``, inputs are placed with
     ``jax.sharding.NamedSharding`` over ``B`` (rounded up to a multiple of
     the axis size) and GSPMD partitions the scan batch-parallel — the DP
     has no cross-instance dependence, so sharded schedules are also
     bit-identical. Testable on CPU via
     ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``cache_stats()`` exposes hits/misses/compiles/evictions; ``compiles`` is
counted by a trace-time side effect, so it reflects actual XLA tracings
(one per bucket entry), not just cache misses.

Regime-split solves (``split_regimes=True``, DESIGN.md §13) add a second
executable kind to the same LRU: ``("marginal", B, n, W)`` buckets hold the
jitted MarIn/MarCo selection kernel (no ``T`` in the key — workloads are
traced inputs there), so monotone slices of a sweep warm independently of
the DP buckets while sharing one cache budget and one set of counters.

The engine is thread-safe (cache and counters are lock-guarded) and, beyond
the blocking :meth:`SweepEngine.solve`, offers :meth:`SweepEngine.dispatch`:
the bucket executable is *launched* (JAX async dispatch, no
``block_until_ready``) and a :class:`SweepHandle` materializes the schedule
only when asked. The async round pipeline (DESIGN.md §11) gets its overlap
from running whole solves on a background planner thread; the
launch/materialize split here is the seam for callers that want to hold an
in-flight solve across other work (e.g. deeper pipeline lookahead).
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from ..kernels.ops import resolve_backend
from ._deprecation import warn_deprecated
from .jax_dp import _solve_fused_batch, pack_problem, solve_fused_batch_ring
from .marginal_jax import (
    MARGINAL_BATCH_ALGORITHMS,
    marginal_select,
    select_algorithm_batch,
)
from .problem import (
    ProblemBatch,
    remove_lower_limits,
    restore_lower_limits,
    total_cost_batch,
)

__all__ = [
    "RegimeSplitHandle",
    "SweepEngine",
    "SweepHandle",
    "bucket_shape",
    "default_engine",
    "make_sweep_mesh",
    "request_bucket",
    "reset_default_engines",
    "solve_dp_batch_cached",
    "solve_schedule_batch_cached",
]


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (int(x) - 1).bit_length()


def bucket_shape(B: int, n: int, T: int, W: int):
    """The compile-cache bucket for an actual packed shape: every dim rounds
    up to the next power of two. Worst-case padding is <2x per dim (~16x
    FLOPs in the T*W-dominated DP), bought once per bucket; in exchange all
    nearby shapes share one compiled executable."""
    return (_next_pow2(B), _next_pow2(n), _next_pow2(T), _next_pow2(W))


def _bucket_axes(b0: ProblemBatch):
    """``(n, T, W)`` pow2 bucket axes of an already-0-lower-limit batch."""
    _, nb, Tb, Wb = bucket_shape(1, b0.n, int(b0.T.max()), b0.W)
    return nb, Tb, Wb


def request_bucket(batch: ProblemBatch):
    """The non-batch pow2 bucket axes ``(n, T, W)`` that the engine's DP
    executable for ``batch`` compiles under (lower limits are shifted out
    first, exactly as :meth:`SweepEngine.dispatch` does).

    THE shared bucket math between the engine and the serve-layer coalescer
    (``repro.serve.coalesce``): requests with equal axes can merge along
    ``B`` into one dispatch without changing which executable runs — only
    the pow2-``B`` ladder varies with flush size.

    Computed in closed form — the shift preserves ``n`` and the table
    width ``W`` and maps ``T -> T - sum(L)`` — so the serve layer's
    per-request keying is O(B*n), not a full O(B*n*W) table shift.
    """
    Tp = int((batch.T - batch.lower.sum(axis=1)).max())
    return _next_pow2(batch.n), _next_pow2(Tp), _next_pow2(batch.W)


def make_sweep_mesh(axis: str = "sweep"):
    """1-D mesh over ALL visible devices, for sharding sweep batches.

    On CPU test hosts, force multiple devices *before* importing jax:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (same pattern as
    tests/test_distribution.py — the flag binds at first jax init).
    """
    devices = jax.devices()
    return jax.make_mesh((len(devices),), (axis,))


class _DeviceSchedulePart:
    """Launch/materialize seam shared by the DP and selection-kernel
    handles: a padded ``(Bb, nb)`` schedule array still computing on the
    device, plus the ORIGINAL (unpadded) batch to unpad against.

    Materialization is lock-guarded: handles are handed across threads by
    the serve layer (many requesters demux one flushed dispatch), and
    without the lock two concurrent first calls to :meth:`result` would
    race the transfer-and-cache sequence and could hand different array
    objects to different callers.
    """

    def __init__(self, raw, batch):
        self._raw = raw  # (Bb, nb) device array, still possibly computing
        self._batch = batch  # the ORIGINAL (unpadded) ProblemBatch
        self._out: Optional[np.ndarray] = None
        self._mat_lock = threading.Lock()  # guards every host-side cache

    def done(self) -> bool:
        """True once the device computation has finished (best-effort: jax
        versions without ``Array.is_ready`` report False until
        materialized)."""
        if self._out is not None:
            return True
        is_ready = getattr(self._raw, "is_ready", None)
        return bool(is_ready()) if callable(is_ready) else False

    def result(self) -> np.ndarray:
        """The ``(B, n)`` int64 schedules — blocks until the solve lands.
        Thread-safe: concurrent callers all receive the SAME array."""
        with self._mat_lock:
            if self._out is None:
                X0 = np.asarray(jax.device_get(self._raw))[: self._batch.B, : self._batch.n]
                self._out = restore_lower_limits(self._batch, X0.astype(np.int64))
            return self._out


class SweepHandle(_DeviceSchedulePart):
    """An in-flight batched solve: the bucket executable has been dispatched
    (JAX async dispatch — no ``block_until_ready`` issued), but the schedule
    is not yet on the host. :meth:`result` blocks on the device transfer,
    unpads, and restores lower limits; repeated calls return the same array.

    The fused executable (DESIGN.md §12) also returns the final DP row:
    :meth:`k_last` / :meth:`objectives` expose it without any extra
    dispatch. Both are in 0-lower-limit terms (Section 5.2) — add each
    instance's fixed cost ``sum_i C_i(L_i)`` to recover original-instance
    energies.
    """

    def __init__(self, raw, k_last, batch, t_star):
        super().__init__(raw, batch)
        self._k_last = k_last  # (Bb, Tb+1) final DP row, also in flight
        self._t_star = t_star  # (Bb,) filled capacities of the padded batch
        self._k_host: Optional[np.ndarray] = None  # cached k_last transfer

    def k_last(self) -> np.ndarray:
        """The ``(B, T_bucket+1)`` final DP row of the real instances:
        ``k_last()[b, t]`` is the minimal cost of assigning exactly ``t``
        units in 0-lower-limit instance ``b`` (BIG where infeasible) — a
        free workload-Pareto curve per solve. The device transfer happens
        once; repeated calls (and :meth:`objectives`) reuse it, from any
        thread."""
        with self._mat_lock:
            if self._k_host is None:
                self._k_host = np.asarray(jax.device_get(self._k_last))[: self._batch.B]
            return self._k_host

    def objectives(self) -> np.ndarray:
        """Per-instance optimal objective ``K_last[b, t*_b]`` of the
        0-lower-limit instances, shape ``(B,)`` float32 — what the returned
        schedules cost, with no extra dispatch or host-side re-evaluation."""
        k = self.k_last()
        t = np.asarray(self._t_star)
        return k[np.arange(self._batch.B), t[: self._batch.B]]

    def frontier(self, b: int = 0):
        """The pruned (workload, energy) Pareto set of instance ``b``,
        extracted from the final DP row with no extra dispatch: ``(t, e)``
        arrays, workload ascending / energy strictly increasing, in
        0-lower-limit terms (add ``t += sum(L_b)`` and the fixed cost
        ``sum_i C_i(L_i)`` to recover original-instance points). The
        workload-axis sibling of the deadline-axis frontier built by
        :func:`repro.core.pareto.pareto_frontier`."""
        from .pareto import workload_frontier  # leaf-ward: pareto imports sweep

        return workload_frontier(self.k_last()[int(b)])


class _SelectionPart(_DeviceSchedulePart):
    """An in-flight batched marginal-selection solve (MarIn/MarCo slice of a
    regime-split dispatch): like :class:`SweepHandle`, the jitted kernel has
    been launched async and :meth:`result` blocks, unpads, and restores
    lower limits."""

    def __init__(self, raw_x, raw_obj, batch):
        super().__init__(raw_x, batch)
        self._raw_obj = raw_obj  # (Bb,) float32 0-lower-limit objectives
        self._obj_host: Optional[np.ndarray] = None

    def objectives(self) -> np.ndarray:
        with self._mat_lock:
            if self._obj_host is None:
                self._obj_host = np.asarray(jax.device_get(self._raw_obj), np.float64)[
                    : self._batch.B
                ]
            return self._obj_host


class _HostPart:
    """An already-materialized host-solved slice (MarDecUn argmin /
    MarDec packing enumeration) of a regime-split dispatch."""

    def __init__(self, X, obj):
        self._X = X
        self._obj = obj

    def done(self) -> bool:
        return True

    def result(self) -> np.ndarray:
        return self._X

    def objectives(self) -> np.ndarray:
        return self._obj


class RegimeSplitHandle:
    """A mixed-regime in-flight solve: each regime sub-batch ran on its own
    path (selection kernel / host marginal algorithms / fused DP) and this
    handle reassembles rows in the ORIGINAL problem order.

    :meth:`objectives` returns per-instance 0-lower-limit objectives (same
    convention as :meth:`SweepHandle.objectives`; device-solved entries are
    float32-precise). :meth:`k_last` is undefined — only the fused DP
    produces a full final row, and pure-DP dispatches return a plain
    :class:`SweepHandle` which does expose it.
    """

    def __init__(self, B: int, n: int, parts):
        self._B, self._n = B, n
        self._parts = parts  # list of (original-index list, part/handle)
        self._out: Optional[np.ndarray] = None
        self._mat_lock = threading.Lock()

    def done(self) -> bool:
        return self._out is not None or all(p.done() for _, p in self._parts)

    def result(self) -> np.ndarray:
        with self._mat_lock:
            if self._out is None:
                X = np.zeros((self._B, self._n), dtype=np.int64)
                for idx, part in self._parts:
                    X[idx] = part.result()
                self._out = X
            return self._out

    def objectives(self) -> np.ndarray:
        obj = np.zeros(self._B, dtype=np.float64)
        for idx, part in self._parts:
            obj[idx] = np.asarray(part.objectives(), np.float64)
        return obj

    def k_last(self) -> np.ndarray:
        raise ValueError(
            "k_last() is only defined for pure-DP dispatches (the fused DP's "
            "final row); this batch was regime-split — use objectives(), or "
            "dispatch with split_regimes=False for the full Pareto row"
        )


class SweepEngine:
    """Compile-cached, optionally device-sharded batched (MC)^2MKP solver.

    Args:
      backend: min-plus kernel backend, forwarded to
        :func:`~repro.kernels.ops.minplus_step_batch`. The default "auto"
        resolves per hardware at construction (cpu -> "blocked",
        tpu -> "pallas_tpu", gpu -> "pallas_gpu"); "ref" forces the dense
        oracle.
      max_entries: LRU capacity — distinct shape buckets kept warm.
      mesh: optional ``jax.sharding.Mesh``; when set, the batch axis is
        sharded over ``mesh_axis`` and ``B`` buckets round up to a multiple
        of that axis size.
      mesh_axis: mesh axis name to shard ``B`` over (default: the mesh's
        first axis).
      ring_mesh: optional ``jax.sharding.Mesh``; when set, pure-DP buckets
        shard the CLASS axis ``n`` as a device ring instead
        (:func:`~repro.core.jax_dp.solve_fused_batch_ring`, DESIGN.md §16):
        the DP row is handed around the ring while each device retains only
        its own ``(n/D, B, T+1)`` argmin slab — bit-identical to the
        unsharded scan, with per-device argmin memory divided by the ring
        size. For ONE very wide problem (large ``n``); mutually exclusive
        with ``mesh`` (large ``B``).
      ring_axis: ring mesh axis name (default: the ring mesh's first axis).
    """

    def __init__(
        self,
        backend: str = "auto",
        max_entries: int = 64,
        mesh=None,
        mesh_axis: Optional[str] = None,
        ring_mesh=None,
        ring_axis: Optional[str] = None,
    ):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        if mesh is not None and ring_mesh is not None:
            raise ValueError(
                "mesh (batch-axis sharding) and ring_mesh (class-axis ring) "
                "are mutually exclusive — build one engine per strategy"
            )
        self.backend = resolve_backend(backend)
        self.max_entries = int(max_entries)
        self.mesh = mesh
        self.mesh_axis = mesh_axis or (mesh.axis_names[0] if mesh is not None else None)
        self._ndev = int(mesh.shape[self.mesh_axis]) if mesh is not None else 1
        self.ring_mesh = ring_mesh
        self.ring_axis = ring_axis or (
            ring_mesh.axis_names[0] if ring_mesh is not None else None
        )
        self._ring_ndev = (
            int(ring_mesh.shape[self.ring_axis]) if ring_mesh is not None else 1
        )
        self._cache: OrderedDict = OrderedDict()
        self._hits = self._misses = self._compiles = self._evictions = 0
        self._bucket_hits: dict = {}  # bucket key -> warm-hit count
        # Guards cache + counters: solves may come from a background planner
        # thread (fl/pipeline.py) or the serve-layer coalescer concurrently
        # with main-thread callers.
        self._lock = threading.Lock()

    # ---- cache ---------------------------------------------------------

    @staticmethod
    def _bucket_label(key) -> str:
        """JSON-friendly bucket name, e.g. ``"dp:B8:n16:T128:W64"``."""
        kind, *dims = key
        names = ("B", "n", "T", "W") if kind == "dp" else ("B", "n", "W")
        return ":".join([kind] + [f"{a}{d}" for a, d in zip(names, dims)])

    def cache_stats(self) -> dict:
        """Counters since construction (or the last :meth:`clear`).
        ``compiles`` counts actual jit tracings — with a warm cache it stays
        flat no matter how many solves run. ``per_bucket_hits`` breaks the
        warm hits down by bucket (keyed by :meth:`_bucket_label`; counts
        survive eviction — they describe traffic, not cache residency), the
        serve layer's per-shape traffic telemetry."""
        with self._lock:
            return {
                "hits": self._hits,
                "misses": self._misses,
                "compiles": self._compiles,
                "evictions": self._evictions,
                "entries": len(self._cache),
                "max_entries": self.max_entries,
                "per_bucket_hits": {
                    self._bucket_label(k): v for k, v in self._bucket_hits.items()
                },
            }

    def clear(self) -> None:
        """Drops all cached executables and zeroes the counters."""
        with self._lock:
            self._cache.clear()
            self._hits = self._misses = self._compiles = self._evictions = 0
            self._bucket_hits = {}

    def _entry(self, key):
        with self._lock:
            fn = self._cache.get(key)
            if fn is not None:
                self._hits += 1
                self._bucket_hits[key] = self._bucket_hits.get(key, 0) + 1
                self._cache.move_to_end(key)
                return fn
            self._misses += 1
            fn = self._build(key)
            self._cache[key] = fn
            while len(self._cache) > self.max_entries:
                self._cache.popitem(last=False)
                self._evictions += 1
            return fn

    def _build(self, key):
        backend = self.backend
        if key[0] == "marginal":

            def run_sel(costs, upper, t_star):
                with self._lock:
                    self._compiles += 1
                # monotone fast path (DESIGN.md §13): top-T' marginal-unit
                # selection — no DP table, O(B·nW·log nW)
                return marginal_select(costs, upper, t_star)

            return jax.jit(run_sel)

        _, _, _, Tb, _ = key
        ring_mesh, ring_axis = self.ring_mesh, self.ring_axis

        def run(costs, t_star):
            # Trace-time side effect: executes once per XLA compilation of
            # this entry (shapes are fixed per bucket, so exactly once
            # unless the entry is evicted and rebuilt).
            with self._lock:
                self._compiles += 1
            if ring_mesh is not None:
                # class-axis ring (DESIGN.md §16): bit-identical rows, argmin
                # slab sharded over the ring devices
                return solve_fused_batch_ring(
                    costs, t_star, Tb, backend, ring_mesh, ring_axis
                )
            # fused DP + backtrack (DESIGN.md §12): one dispatch, and only
            # (X, K_last) leave the program — never the (n, B, T+1) argmins
            return _solve_fused_batch(costs, t_star, Tb, backend=backend)

        return jax.jit(run)

    # ---- solving -------------------------------------------------------

    def _dispatch_dp(self, batch: ProblemBatch) -> SweepHandle:
        b0 = remove_lower_limits(batch)
        nb, Tb, Wb = _bucket_axes(b0)  # same math the coalescer keys on
        if nb % self._ring_ndev:
            # the ring splits the class axis evenly; pad the n-bucket up to a
            # multiple of the ring size (phantom classes are inert)
            nb = ((nb + self._ring_ndev - 1) // self._ring_ndev) * self._ring_ndev
        Bb = _next_pow2(b0.B)
        if Bb % self._ndev:
            Bb = ((Bb + self._ndev - 1) // self._ndev) * self._ndev
        padded = b0.pad_to(B=Bb, n=nb, W=Wb)
        costs = pack_problem(padded)  # (Bb, nb, Wb) float32, BIG-saturated
        t_star = jnp.asarray(padded.T, dtype=jnp.int32)
        if self.mesh is not None:
            P = PartitionSpec
            costs = jax.device_put(
                costs, NamedSharding(self.mesh, P(self.mesh_axis, None, None))
            )
            t_star = jax.device_put(
                t_star, NamedSharding(self.mesh, P(self.mesh_axis))
            )
        fn = self._entry(("dp", Bb, nb, Tb, Wb))
        X_raw, k_last = fn(costs, t_star)
        return SweepHandle(X_raw, k_last, batch, np.asarray(padded.T, dtype=np.int32))

    def _dispatch_selection(self, batch: ProblemBatch) -> _SelectionPart:
        """Launches the MarIn/MarCo slice on the jitted selection kernel
        from its own shape bucket (``("marginal", B, n, W)`` — no ``T`` in
        the key: the workload is a traced input, not a shape). Marginal
        buckets share the engine's LRU and counters with the DP buckets.
        Inputs are not mesh-sharded: selection solves are orders of
        magnitude smaller than the DPs they replace."""
        b0 = remove_lower_limits(batch)
        if b0.W < 2:  # every resource pinned at its lower limit: T' == 0
            zeros = np.zeros((batch.B, batch.n), dtype=np.int64)
            return _HostPart(
                restore_lower_limits(batch, zeros), np.zeros(batch.B)
            )
        Bb, nb, _, Wb = bucket_shape(b0.B, b0.n, 1, b0.W)
        padded = b0.pad_to(B=Bb, n=nb, W=Wb)
        fn = self._entry(("marginal", Bb, nb, Wb))
        x_raw, obj_raw = fn(
            pack_problem(padded),
            jnp.asarray(padded.upper, jnp.int32),
            jnp.asarray(padded.T, jnp.int32),
        )
        return _SelectionPart(x_raw, obj_raw, batch)

    @staticmethod
    def _host_part(batch: ProblemBatch, algorithm: str) -> _HostPart:
        """MarDecUn / MarDec slice: solved eagerly on the host (numpy) at
        dispatch time."""
        X = MARGINAL_BATCH_ALGORITHMS[algorithm](batch)
        b0 = remove_lower_limits(batch)
        obj = total_cost_batch(b0, X - batch.lower)
        return _HostPart(X, obj)

    @staticmethod
    def _take(batch: ProblemBatch, idx) -> ProblemBatch:
        """Row-slices a batch, keeping the (n, W) envelope — padding is
        inert on every path, so sub-batch solves are bit-identical to
        solving the instances alone."""
        idx = np.asarray(idx, dtype=np.int64)
        return ProblemBatch(
            T=batch.T[idx],
            lower=batch.lower[idx],
            upper=batch.upper[idx],
            costs=batch.costs[idx],
        )

    def dispatch(self, problems, split_regimes: bool = False):
        """Launches the batched solve WITHOUT materializing the result.

        Packing/padding happens eagerly (cheap numpy), the bucket executable
        is invoked once — JAX async dispatch returns immediately with the
        computation in flight — and the returned :class:`SweepHandle` does
        the blocking ``device_get`` only on :meth:`SweepHandle.result`, so
        a caller can keep working while the solve computes.

        ``split_regimes=True`` enables the monotone fast path (DESIGN.md
        §13): each instance's marginal-cost regime picks its algorithm
        (paper Table 2, via
        :func:`~repro.core.marginal_jax.select_algorithm_batch`), the batch
        is partitioned into per-algorithm sub-batches (MarIn/MarCo ->
        selection kernel, MarDecUn/MarDec -> host numpy, arbitrary -> fused
        DP), and a :class:`RegimeSplitHandle` reassembles rows in original
        order — bit-identical to dispatching each sub-batch alone. Batches
        that classify as pure-DP take exactly the default path (same
        buckets, same counters, plain :class:`SweepHandle`). The default
        ``False`` keeps the documented contract of bit-identity with
        :func:`~repro.core.jax_dp.solve_schedule_dp_batch` for every
        instance. MarDec sub-batches compute at dispatch time (host code
        has no async seam)."""
        batch = (
            problems
            if isinstance(problems, ProblemBatch)
            else ProblemBatch.from_problems(problems)
        )
        batch.validate()
        if not split_regimes:
            return self._dispatch_dp(batch)
        algs = select_algorithm_batch(batch)
        groups: dict = {}
        for b, alg in enumerate(algs):
            key = "selection" if alg in ("marin", "marco") else alg
            groups.setdefault(key, []).append(b)
        if set(groups) == {"dp"}:
            return self._dispatch_dp(batch)
        parts = []
        # DP first: its executable is the slowest, let it compute while the
        # host parts run
        if "dp" in groups:
            parts.append((groups["dp"], self._dispatch_dp(self._take(batch, groups["dp"]))))
        if "selection" in groups:
            parts.append(
                (groups["selection"], self._dispatch_selection(self._take(batch, groups["selection"])))
            )
        for alg in ("mardecun", "mardec"):
            if alg in groups:
                parts.append((groups[alg], self._host_part(self._take(batch, groups[alg]), alg)))
        return RegimeSplitHandle(batch.B, batch.n, parts)

    def solve(self, problems, split_regimes: bool = False) -> np.ndarray:
        """Drop-in for :func:`~repro.core.jax_dp.solve_schedule_dp_batch`:
        same inputs (sequence of :class:`Problem` or a prebuilt
        :class:`ProblemBatch`), bit-identical ``(B, n)`` int64 schedules —
        but warm buckets skip compilation entirely. With
        ``split_regimes=True``, monotone instances ride the marginal fast
        path instead of the DP (see :meth:`dispatch`)."""
        return self.dispatch(problems, split_regimes=split_regimes).result()


# ---------------------------------------------------------------------------
# Process-wide default engines: schedule_batch / deadline_sweep / FL servers
# all share these, so ANY repeated shape anywhere in the process is warm.
# ---------------------------------------------------------------------------

_DEFAULT_ENGINES: dict = {}


def default_engine(backend: str = "auto") -> SweepEngine:
    """The shared per-backend engine (created on first use). Keyed on the
    RESOLVED backend, so "auto" and its hardware-resolved name (e.g.
    "blocked" on CPU) share one engine and one warm cache."""
    backend = resolve_backend(backend)
    eng = _DEFAULT_ENGINES.get(backend)
    if eng is None:
        eng = _DEFAULT_ENGINES[backend] = SweepEngine(backend=backend)
    return eng


def reset_default_engines() -> None:
    """Drops the shared engines (test isolation)."""
    _DEFAULT_ENGINES.clear()


def _resolve_engine(backend: Optional[str], engine):
    """The engine a cached solve runs on: the given one (after checking it
    does not contradict an explicitly named backend — its executables are
    compiled for ITS backend, so we raise rather than silently running the
    wrong kernel; backends compare after "auto" resolution), else the shared
    default for ``backend`` (``None`` -> "auto": per-hardware dispatch)."""
    if engine is not None:
        if backend is not None and resolve_backend(backend) != engine.backend:
            raise ValueError(
                f"backend {backend!r} conflicts with engine.backend "
                f"{engine.backend!r}; pass an engine built for that backend"
            )
        return engine
    return default_engine(backend or "auto")


def _solve_cached(
    problems, backend: Optional[str], engine, split_regimes: bool
) -> np.ndarray:
    """THE cached batched solve every public path shares: resolves the
    engine (:func:`_resolve_engine`) and runs one blocking solve. Private —
    callers go through :class:`repro.core.solver.Solver` (or the deprecated
    shims below, which delegate here unchanged)."""
    return _resolve_engine(backend, engine).solve(problems, split_regimes=split_regimes)


def solve_dp_batch_cached(
    problems, backend: Optional[str] = None, engine=None
) -> np.ndarray:
    """Deprecated shim: use ``Solver(engine=...).solve(problems,
    algorithm="dp_batch")`` (the facade, DESIGN.md §15).

    Batched DP solve through a sweep engine (the given one, else the shared
    default for ``backend``); delegates to the same private implementation
    the facade calls, so behavior — including the backend-vs-engine conflict
    ValueError — is bit-identical."""
    warn_deprecated(
        "solve_dp_batch_cached", 'Solver(engine=...).solve(problems, algorithm="dp_batch")'
    )
    return _solve_cached(problems, backend, engine, split_regimes=False)


def solve_schedule_batch_cached(
    problems, backend: Optional[str] = None, engine=None
) -> np.ndarray:
    """Deprecated shim: use ``Solver(engine=...).solve(problems)`` (the
    facade, DESIGN.md §15).

    Regime-dispatched batched solve (DESIGN.md §13): monotone instances ride
    the marginal fast path, only arbitrary-regime instances pay the DP. Same
    engine/backend conventions (and conflict check) as
    :func:`solve_dp_batch_cached`; returns ``(B, n)`` int64 schedules in
    original problem order — bit-identical to the pre-facade behavior."""
    warn_deprecated(
        "solve_schedule_batch_cached", "Solver(engine=...).solve(problems)"
    )
    return _solve_cached(problems, backend, engine, split_regimes=True)
