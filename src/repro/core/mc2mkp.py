"""(MC)^2MKP — Multiple-Choice Minimum-Cost Maximal Knapsack Packing.

Paper Section 4: Definition 2, recurrences (3)-(5), Algorithm 1, and the
scheduling<->knapsack transformation of Section 4.1.1.

Two layers:
  * A faithful general solver over arbitrary disjoint item classes
    (`solve_mc2mkp`), matching Algorithm 1 line by line (with the vectorized
    inner relaxation over ``t`` for speed — semantics identical).
  * The scheduling entry point (`solve_schedule_dp`) that maps a
    :class:`~repro.core.problem.Problem` onto classes ``N_i = {L_i..U_i}``
    (after the Section 5.2 lower-limit removal) and translates the packing
    back into a schedule.

Complexities match the paper: space O(Tn), time O(T * sum_i |N_i|), i.e.
O(T^2 n) for the scheduling case.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .problem import Problem, remove_lower_limits, restore_lower_limits

__all__ = [
    "ItemClass",
    "MC2MKPSolution",
    "solve_mc2mkp",
    "mc2mkp_matrices",
    "solve_schedule_dp",
    "brute_force_schedule",
]

INF = np.inf


@dataclasses.dataclass(frozen=True)
class ItemClass:
    """A disjoint class N_i: parallel arrays of item weights and costs."""

    weights: np.ndarray  # (m_i,) int
    costs: np.ndarray  # (m_i,) float

    def __post_init__(self):
        object.__setattr__(self, "weights", np.asarray(self.weights, dtype=np.int64))
        object.__setattr__(self, "costs", np.asarray(self.costs, dtype=np.float64))
        if self.weights.shape != self.costs.shape:
            raise ValueError("weights/costs length mismatch")


@dataclasses.dataclass(frozen=True)
class MC2MKPSolution:
    total_cost: float  # ΣC
    used_capacity: int  # T*
    items: np.ndarray  # (n,) chosen item INDEX per class (into the class arrays)


def mc2mkp_matrices(classes: Sequence[ItemClass], T: int):
    """Algorithm 1 lines 1-19: fills the K (min cost) and I (chosen item)
    matrices for all partial problems Z_r(t), r=1..n, t=0..T.

    Returns (K, I): K float (n, T+1), I int (n, T+1) holding the item index
    within each class (-1 where no solution exists).
    """
    n = len(classes)
    K = np.full((n, T + 1), INF, dtype=np.float64)
    I = np.full((n, T + 1), -1, dtype=np.int64)

    # Z_1: only the items of the first class (lines 7-9).
    c0 = classes[0]
    for j in range(len(c0.weights)):
        w, c = int(c0.weights[j]), float(c0.costs[j])
        if w <= T and c < K[0, w]:
            K[0, w] = c
            I[0, w] = j
    # Z_i from Z_{i-1} (lines 10-19). The loop over t is vectorized: for a
    # fixed item j, K[i][w_ij:] <- min(K[i][w_ij:], K[i-1][:-w_ij or all]+c).
    for i in range(1, n):
        ci = classes[i]
        for j in range(len(ci.weights)):
            w, c = int(ci.weights[j]), float(ci.costs[j])
            if w > T:
                continue
            prev = K[i - 1, : T + 1 - w] + c
            better = prev < K[i, w:]
            K[i, w:][better] = prev[better]
            I[i, w:][better] = j
    return K, I


def solve_mc2mkp(classes: Sequence[ItemClass], T: int) -> MC2MKPSolution:
    """Algorithm 1 in full: DP fill + T* search (lines 20-23) + backtrack
    (lines 25-28)."""
    n = len(classes)
    K, I = mc2mkp_matrices(classes, T)
    t_star = T
    while t_star > 0 and not np.isfinite(K[n - 1, t_star]):
        t_star -= 1
    if not np.isfinite(K[n - 1, t_star]):
        raise ValueError("no feasible packing (some class has no item of weight <= T)")
    total = float(K[n - 1, t_star])
    items = np.zeros(n, dtype=np.int64)
    t = t_star
    for i in range(n - 1, -1, -1):
        j = int(I[i, t])
        items[i] = j
        t -= int(classes[i].weights[j])
    return MC2MKPSolution(total_cost=total, used_capacity=t_star, items=items)


# ---------------------------------------------------------------------------
# Scheduling entry point (Section 4.1.1 transformation)
# ---------------------------------------------------------------------------


def _classes_from_problem(p: Problem) -> list:
    """N_i = {L_i, ..., U_i}; c_ij = C_i(j); w_ij = j. Expects L_i == 0
    (call after remove_lower_limits)."""
    out = []
    for i in range(p.n):
        u = int(p.upper[i])
        w = np.arange(0, u + 1, dtype=np.int64)
        out.append(ItemClass(weights=w, costs=p.cost_tables[i][: u + 1]))
    return out


def solve_schedule_dp(problem: Problem) -> np.ndarray:
    """Optimal schedule via (MC)^2MKP (paper Theorem 1).

    Applies the Section 5.2 lower-limit removal first, so the DP runs on the
    0-based equivalent instance; the result is shifted back via eq. (11).
    For valid scheduling instances the packing always uses full capacity
    (T* == T), per Section 4.1.1.
    """
    problem.validate()
    p0 = remove_lower_limits(problem)
    classes = _classes_from_problem(p0)
    sol = solve_mc2mkp(classes, p0.T)
    assert sol.used_capacity == p0.T, "scheduling instances always fill the knapsack"
    # item index == number of tasks here (weights are 0..U_i)
    x_prime = sol.items.astype(np.int64)
    return restore_lower_limits(problem, x_prime)


def brute_force_schedule(problem: Problem) -> np.ndarray:
    """Exhaustive optimal schedule (tests only; exponential)."""
    problem.validate()
    n, T = problem.n, problem.T
    best = (INF, None)

    def rec(i: int, remaining: int, acc: float, xs: list):
        nonlocal best
        if acc >= best[0]:
            return
        if i == n:
            if remaining == 0 and acc < best[0]:
                best = (acc, list(xs))
            return
        lo, up = int(problem.lower[i]), int(problem.upper[i])
        # prune by feasibility of the suffix
        suffix_lo = int(problem.lower[i + 1 :].sum())
        suffix_up = int(problem.upper[i + 1 :].sum())
        for j in range(lo, up + 1):
            r = remaining - j
            if r < suffix_lo or r > suffix_up:
                continue
            xs.append(j)
            rec(i + 1, r, acc + problem.cost(i, j), xs)
            xs.pop()

    rec(0, T, 0.0, [])
    if best[1] is None:
        raise ValueError("infeasible instance")
    return np.asarray(best[1], dtype=np.int64)
