"""JAX implementation of the (MC)^2MKP dynamic program for scheduling
instances (contiguous classes), built on the min-plus convolution kernel.

The DP row update over classes is a ``lax.scan``; each step is one banded
min-plus convolution (``repro.kernels``, ``backend="auto"`` dispatches per
hardware). Backtracking is a reverse ``lax.scan`` over the stacked argmin
matrix, fused into the SAME jitted program as the class scan
(:func:`solve_fused_batch_jax`): one dispatch returns only the ``(B, n)``
schedules plus the final DP row ``K_last`` — the ``(n, B, T+1)`` argmin
matrix never crosses a program boundary, so nothing bigger than the answer
is ever transferred. This is what runs server-side every FL round when
schedules are recomputed from refreshed energy estimates.

Inputs are the 0-lower-limit equivalent instance (Section 5.2) as dense
arrays: ``costs (n, W)`` padded with BIG beyond each ``U_i``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import BIG, minplus_step_batch, resolve_backend
from .problem import (
    Problem,
    ProblemBatch,
    remove_lower_limits,
    restore_lower_limits,
)

__all__ = [
    "solve_schedule_dp_jax",
    "solve_schedule_dp_batch",
    "solve_fused_batch_jax",
    "solve_fused_batch_ring",
    "dp_tables_jax",
    "dp_tables_batch_jax",
    "pack_problem",
]


def pack_problem(p0):
    """Dense BIG-padded cost array for 0-lower-limit instance(s).

    A :class:`Problem` packs to ``(n, W)``; a :class:`ProblemBatch` packs to
    ``(B, n, W)`` (its stacked tables are already dense — they are saturated
    to BIG and downcast). Entries beyond each ``U_i`` are BIG so those item
    sizes are never selected.
    """
    if isinstance(p0, ProblemBatch):
        return jnp.asarray(np.minimum(p0.costs, float(BIG)).astype(np.float32))
    W = int(p0.upper.max()) + 1
    n = p0.n
    lens = p0.upper.astype(np.int64) + 1  # valid prefix per class: 0..U_i
    costs = np.full((n, W), float(BIG), dtype=np.float32)
    # one masked scatter instead of a per-class assignment loop (this sits
    # on the cold path of every single-instance solve)
    mask = np.arange(W)[None, :] < lens[:, None]
    costs[mask] = np.concatenate(
        [np.asarray(t[:l], dtype=np.float32) for t, l in zip(p0.cost_tables, lens)]
    )
    return jnp.asarray(costs)


@functools.partial(jax.jit, static_argnames=("T", "backend"))
def dp_tables_jax(costs: jnp.ndarray, T: int, backend: str = "ref"):
    """Scans the DP over classes for ONE instance: the ``B = 1`` slice of
    :func:`dp_tables_batch_jax`. Returns (K_last (T+1,), I (n, T+1))."""
    # slice the unjitted body: jit-of-jit would trace the batch wrapper a
    # second time per shape for zero caching benefit
    k_last, I = _dp_tables_batch(costs[None], T, backend=backend)
    return k_last[0], I[:, 0]


@functools.partial(jax.jit, static_argnames=("T",))
def backtrack_jax(I: jnp.ndarray, t_star: jnp.ndarray, T: int):
    """Reverse scan: x_i = I[i, t]; t -= x_i (weights == item index). The
    ``B = 1`` slice of :func:`backtrack_batch_jax` (unjitted body — see
    :func:`dp_tables_jax`)."""
    return _backtrack_batch(I[:, None], jnp.asarray(t_star)[None], T)[0]


def solve_schedule_dp_jax(problem: Problem, backend: str = "auto") -> np.ndarray:
    """Drop-in replacement for :func:`repro.core.mc2mkp.solve_schedule_dp`
    running as ONE jitted JAX program (DP scan + fused backtrack) on the
    hardware-dispatched kernel backend."""
    problem.validate()
    p0 = remove_lower_limits(problem)
    costs = pack_problem(p0)
    # Scheduling instances always fill the knapsack: T* == T.
    t_star = jnp.asarray([p0.T], dtype=jnp.int32)
    X, _ = solve_fused_batch_jax(
        costs[None], t_star, int(p0.T), backend=resolve_backend(backend)
    )
    x0 = np.asarray(jax.device_get(X))[0]
    return restore_lower_limits(problem, x0.astype(np.int64))


# ---------------------------------------------------------------------------
# Batched solver: B instances in one jitted program (DESIGN.md §9, §12)
# ---------------------------------------------------------------------------


def _dp_scan_from(k0: jnp.ndarray, costs: jnp.ndarray, backend: str = "ref"):
    """Continues the class scan from an arbitrary DP row ``k0 (B, T+1)`` over
    the classes in ``costs (B, n, W)``. Factored out of
    :func:`_dp_tables_batch` so the ring-sharded solver (below) can run each
    device's local classes through the IDENTICAL op sequence — bit-identity
    of the sharded path reduces to handing the row around the ring."""

    def step(krow, cost_i):
        kout, iout = minplus_step_batch(krow, cost_i, backend=backend)
        return kout, iout

    # scan over the class axis: xs must lead with n
    return jax.lax.scan(step, k0, jnp.swapaxes(costs, 0, 1))


def _dp_tables_batch(costs: jnp.ndarray, T: int, backend: str = "ref"):
    """Unjitted body of :func:`dp_tables_batch_jax` — the fused solver and
    the sweep engine (``core/sweep.py``) close over this inside their own
    per-bucket jits."""
    B = costs.shape[0]
    k0 = jnp.full((B, T + 1), BIG, jnp.float32).at[:, 0].set(0.0)
    return _dp_scan_from(k0, costs, backend=backend)


@functools.partial(jax.jit, static_argnames=("T", "backend"))
def dp_tables_batch_jax(costs: jnp.ndarray, T: int, backend: str = "ref"):
    """Scans the DP over classes for a whole batch at once.

    Args:
      costs: ``(B, n, W)`` packed tables (0-lower-limit instances).
      T: static row width — the max ``T'`` across the batch; rows are shared,
        per-instance workloads only enter at backtracking via ``t_star``.

    Returns (K_last ``(B, T+1)``, I ``(n, B, T+1)``). Production solves use
    :func:`solve_fused_batch_jax` instead, which never lets ``I`` escape the
    program; this two-dispatch path remains as the oracle the fused solver
    is validated against.
    """
    return _dp_tables_batch(costs, T, backend=backend)


def _backtrack_batch(I: jnp.ndarray, t_star: jnp.ndarray, T: int):
    """Unjitted body of :func:`backtrack_batch_jax` (see above)."""

    def step(t, irow):  # t: (B,), irow: (B, T+1)
        j = jnp.take_along_axis(irow, t[:, None].astype(jnp.int32), axis=1)[:, 0]
        return t - j, j

    _, xs_rev = jax.lax.scan(step, t_star.astype(jnp.int32), I[::-1])
    return jnp.swapaxes(xs_rev[::-1], 0, 1)  # (B, n)


@functools.partial(jax.jit, static_argnames=("T",))
def backtrack_batch_jax(I: jnp.ndarray, t_star: jnp.ndarray, T: int):
    """Batched reverse scan: per instance, x_i = I[i, b, t_b]; t_b -= x_i.

    ``t_star`` is ``(B,)`` — each instance starts from its own filled
    capacity, so ragged workloads coexist in one padded program.
    """
    return _backtrack_batch(I, t_star, T)


def _solve_fused_batch(costs: jnp.ndarray, t_star: jnp.ndarray, T: int, backend: str = "ref"):
    """Unjitted fused DP + backtrack (the sweep engine's per-bucket
    executables close over this). Returns ``(X (B, n), K_last (B, T+1))``:
    the argmin matrix ``I`` lives only inside the program — XLA keeps it
    device-resident between the class scan and the reverse scan, and only
    the schedules and the final DP row come back."""
    k_last, I = _dp_tables_batch(costs, T, backend=backend)
    X = _backtrack_batch(I, t_star, T)
    return X, k_last


@functools.partial(jax.jit, static_argnames=("T", "backend"))
def solve_fused_batch_jax(costs: jnp.ndarray, t_star: jnp.ndarray, T: int, backend: str = "ref"):
    """Fused batched solver: class scan + reverse backtrack in ONE jitted
    call (DESIGN.md §12).

    Args:
      costs: ``(B, n, W)`` packed tables (0-lower-limit instances).
      t_star: ``(B,)`` int32 filled capacities to backtrack from.
      T: static row width (max ``T'`` across the batch).

    Returns ``(X, K_last)``: ``(B, n)`` int32 schedules and the ``(B, T+1)``
    final DP row (``K_last[b, t]`` = minimal cost of assigning exactly ``t``
    units across the 0-lower-limit instance ``b`` — a free Pareto curve over
    workloads). Compared to chaining :func:`dp_tables_batch_jax` +
    :func:`backtrack_batch_jax`, the ``(n, B, T+1)`` argmin matrix never
    crosses a dispatch boundary and the second trace/launch disappears.
    """
    return _solve_fused_batch(costs, t_star, T, backend=backend)


# ---------------------------------------------------------------------------
# Class-axis ring sharding (DESIGN.md §16): the DP scan is sequential in n,
# so the row is handed around a device ring instead of split — device d holds
# classes [d*n_loc, (d+1)*n_loc) and, on its turn, continues the row through
# them with the SAME op sequence as the unsharded scan (bit-identical rows),
# then passes the row on via ppermute. What shards is the per-device state:
# each device keeps only ITS (n_loc, B, T+1) argmin slab — the memory wall of
# very wide flat problems — and backtracking walks the ring in reverse,
# handing the workload carry back. Compute is pipelined, not divided: every
# turn is one device's scan segment, so wall-clock matches the unsharded scan
# while peak argmin memory per device drops by the ring size.
# ---------------------------------------------------------------------------


def _ring_dp_body(costs_l, k0, t_star, *, T, backend, axis, ndev):
    """Per-device shard_map body: ``costs_l (B, n_loc, W)`` local classes,
    ``k0 (B, T+1)`` / ``t_star (B,)`` replicated. Returns the local schedule
    columns ``(B, n_loc)`` and the replicated final row ``(B, T+1)``."""
    d = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % ndev) for i in range(ndev)]
    bwd = [(i, (i - 1) % ndev) for i in range(ndev)]
    row = k0
    I_loc = None
    for r in range(ndev):  # static unroll: one turn per ring position
        new_row, I_r = _dp_scan_from(row, costs_l, backend=backend)
        mine = d == r
        I_loc = I_r if I_loc is None else jnp.where(mine, I_r, I_loc)
        row = jnp.where(mine, new_row, row)
        row = jax.lax.ppermute(row, axis, fwd)
    # after n/ndev turns the ring hands the final row to device 0; masked
    # psum broadcasts it (adding exact zeros — f32-exact) to every device
    k_last = jax.lax.psum(jnp.where(d == 0, row, jnp.zeros_like(row)), axis)
    # reverse ring: the workload carry t walks back through the devices,
    # each backtracking through its own retained argmin slab
    t = t_star.astype(jnp.int32)
    x_loc = jnp.zeros(costs_l.shape[:2], jnp.int32)
    for r in range(ndev - 1, -1, -1):
        xb = _backtrack_batch(I_loc, t, T)
        mine = d == r
        x_loc = jnp.where(mine, xb, x_loc)
        t = jnp.where(mine, t - xb.sum(axis=1).astype(jnp.int32), t)
        t = jax.lax.ppermute(t, axis, bwd)
    return x_loc, k_last


def solve_fused_batch_ring(costs, t_star, T: int, backend: str, mesh, axis: str):
    """Fused DP + backtrack with the CLASS axis sharded over ``mesh[axis]``
    as a ring (see module comment above). Drop-in for
    :func:`solve_fused_batch_jax` — same ``(X (B, n), K_last (B, T+1))``
    contract, bit-identical results — with ``n`` divisible by the ring size
    (the engine pads its n-bucket up to a multiple). Call under ``jax.jit``
    (the sweep engine's bucket executables do)."""
    from jax.experimental.shard_map import shard_map

    ndev = int(mesh.shape[axis])
    B = costs.shape[0]
    k0 = jnp.full((B, T + 1), BIG, jnp.float32).at[:, 0].set(0.0)
    P = jax.sharding.PartitionSpec
    body = functools.partial(
        _ring_dp_body, T=T, backend=backend, axis=axis, ndev=ndev
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, axis, None), P(None, None), P(None)),
        out_specs=(P(None, axis), P(None, None)),
        check_rep=False,
    )
    return fn(costs, k0, t_star.astype(jnp.int32))


def solve_schedule_dp_batch(problems, backend: str = "auto") -> np.ndarray:
    """Solves ``B`` scheduling instances with ONE fused jitted batched DP.

    Accepts a sequence of :class:`Problem` (ragged ``n``/``U_i``/``T`` are
    padded into a dense stack) or a prebuilt :class:`ProblemBatch`. Returns a
    ``(B, n)`` int64 array of schedules — row ``b`` solves instance ``b``;
    columns past an instance's own ``n`` are 0.

    The whole sweep is one jit call (DP scan + fused backtrack) specialized
    on the padded shape ``(B, n, W, T_max)``, so closely-related what-if
    instances (deadline sweeps, candidate workloads, dropout subsets) share
    one compilation and one kernel launch instead of ``B`` — and only the
    ``(B, n)`` schedules are transferred to the host.
    """
    batch = problems if isinstance(problems, ProblemBatch) else ProblemBatch.from_problems(problems)
    batch.validate()
    b0 = remove_lower_limits(batch)
    costs = pack_problem(b0)
    Tmax = int(b0.T.max())
    # Scheduling instances always fill the knapsack: T*_b == T'_b.
    t_star = jnp.asarray(b0.T, dtype=jnp.int32)
    X, _ = solve_fused_batch_jax(costs, t_star, Tmax, backend=resolve_backend(backend))
    X0 = np.asarray(jax.device_get(X))
    return restore_lower_limits(batch, X0.astype(np.int64))
