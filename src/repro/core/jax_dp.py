"""JAX implementation of the (MC)^2MKP dynamic program for scheduling
instances (contiguous classes), built on the min-plus convolution kernel.

The DP row update over classes is a ``lax.scan``; each step is one banded
min-plus convolution (``repro.kernels``). Backtracking is a reverse
``lax.scan`` over the stacked argmin matrix, so the whole solver is a single
jittable program — this is what runs server-side every FL round when
schedules are recomputed from refreshed energy estimates.

Inputs are the 0-lower-limit equivalent instance (Section 5.2) as dense
arrays: ``costs (n, W)`` padded with BIG beyond each ``U_i``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import BIG, minplus_step_batch
from .problem import (
    Problem,
    ProblemBatch,
    remove_lower_limits,
    restore_lower_limits,
)

__all__ = [
    "solve_schedule_dp_jax",
    "solve_schedule_dp_batch",
    "dp_tables_jax",
    "dp_tables_batch_jax",
    "pack_problem",
]


def pack_problem(p0):
    """Dense BIG-padded cost array for 0-lower-limit instance(s).

    A :class:`Problem` packs to ``(n, W)``; a :class:`ProblemBatch` packs to
    ``(B, n, W)`` (its stacked tables are already dense — they are saturated
    to BIG and downcast). Entries beyond each ``U_i`` are BIG so those item
    sizes are never selected.
    """
    if isinstance(p0, ProblemBatch):
        return jnp.asarray(np.minimum(p0.costs, float(BIG)).astype(np.float32))
    W = int(p0.upper.max()) + 1
    n = p0.n
    costs = np.full((n, W), float(BIG), dtype=np.float32)
    for i in range(n):
        u = int(p0.upper[i])
        costs[i, : u + 1] = p0.cost_tables[i][: u + 1]
    return jnp.asarray(costs)


@functools.partial(jax.jit, static_argnames=("T", "backend"))
def dp_tables_jax(costs: jnp.ndarray, T: int, backend: str = "ref"):
    """Scans the DP over classes for ONE instance: the ``B = 1`` slice of
    :func:`dp_tables_batch_jax`. Returns (K_last (T+1,), I (n, T+1))."""
    k_last, I = dp_tables_batch_jax(costs[None], T, backend=backend)
    return k_last[0], I[:, 0]


@functools.partial(jax.jit, static_argnames=("T",))
def backtrack_jax(I: jnp.ndarray, t_star: jnp.ndarray, T: int):
    """Reverse scan: x_i = I[i, t]; t -= x_i (weights == item index). The
    ``B = 1`` slice of :func:`backtrack_batch_jax`."""
    return backtrack_batch_jax(I[:, None], jnp.asarray(t_star)[None], T)[0]


def solve_schedule_dp_jax(problem: Problem, backend: str = "ref") -> np.ndarray:
    """Drop-in replacement for :func:`repro.core.mc2mkp.solve_schedule_dp`
    running as a jitted JAX program (optionally via the Pallas kernel)."""
    problem.validate()
    p0 = remove_lower_limits(problem)
    costs = pack_problem(p0)
    k_last, I = dp_tables_jax(costs, int(p0.T), backend=backend)
    # Scheduling instances always fill the knapsack: T* == T.
    t_star = jnp.asarray(p0.T)
    x0 = np.asarray(jax.device_get(backtrack_jax(I, t_star, int(p0.T))))
    return restore_lower_limits(problem, x0.astype(np.int64))


# ---------------------------------------------------------------------------
# Batched solver: B instances in one jitted program (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _dp_tables_batch(costs: jnp.ndarray, T: int, backend: str = "ref"):
    """Unjitted body of :func:`dp_tables_batch_jax` — the sweep engine
    (``core/sweep.py``) closes over this inside its own per-bucket jits."""

    def step(krow, cost_i):
        kout, iout = minplus_step_batch(krow, cost_i, backend=backend)
        return kout, iout

    B = costs.shape[0]
    k0 = jnp.full((B, T + 1), BIG, jnp.float32).at[:, 0].set(0.0)
    # scan over the class axis: xs must lead with n
    k_last, I = jax.lax.scan(step, k0, jnp.swapaxes(costs, 0, 1))
    return k_last, I


@functools.partial(jax.jit, static_argnames=("T", "backend"))
def dp_tables_batch_jax(costs: jnp.ndarray, T: int, backend: str = "ref"):
    """Scans the DP over classes for a whole batch at once.

    Args:
      costs: ``(B, n, W)`` packed tables (0-lower-limit instances).
      T: static row width — the max ``T'`` across the batch; rows are shared,
        per-instance workloads only enter at backtracking via ``t_star``.

    Returns (K_last ``(B, T+1)``, I ``(n, B, T+1)``).
    """
    return _dp_tables_batch(costs, T, backend=backend)


def _backtrack_batch(I: jnp.ndarray, t_star: jnp.ndarray, T: int):
    """Unjitted body of :func:`backtrack_batch_jax` (see above)."""

    def step(t, irow):  # t: (B,), irow: (B, T+1)
        j = jnp.take_along_axis(irow, t[:, None].astype(jnp.int32), axis=1)[:, 0]
        return t - j, j

    _, xs_rev = jax.lax.scan(step, t_star.astype(jnp.int32), I[::-1])
    return jnp.swapaxes(xs_rev[::-1], 0, 1)  # (B, n)


@functools.partial(jax.jit, static_argnames=("T",))
def backtrack_batch_jax(I: jnp.ndarray, t_star: jnp.ndarray, T: int):
    """Batched reverse scan: per instance, x_i = I[i, b, t_b]; t_b -= x_i.

    ``t_star`` is ``(B,)`` — each instance starts from its own filled
    capacity, so ragged workloads coexist in one padded program.
    """
    return _backtrack_batch(I, t_star, T)


def solve_schedule_dp_batch(problems, backend: str = "ref") -> np.ndarray:
    """Solves ``B`` scheduling instances with ONE jitted batched DP.

    Accepts a sequence of :class:`Problem` (ragged ``n``/``U_i``/``T`` are
    padded into a dense stack) or a prebuilt :class:`ProblemBatch`. Returns a
    ``(B, n)`` int64 array of schedules — row ``b`` solves instance ``b``;
    columns past an instance's own ``n`` are 0.

    The whole sweep is two jit calls (DP scan + backtrack) specialized on the
    padded shape ``(B, n, W, T_max)``, so closely-related what-if instances
    (deadline sweeps, candidate workloads, dropout subsets) share one
    compilation and one kernel launch instead of ``B``.
    """
    batch = problems if isinstance(problems, ProblemBatch) else ProblemBatch.from_problems(problems)
    batch.validate()
    b0 = remove_lower_limits(batch)
    costs = pack_problem(b0)
    Tmax = int(b0.T.max())
    _, I = dp_tables_batch_jax(costs, Tmax, backend=backend)
    # Scheduling instances always fill the knapsack: T*_b == T'_b.
    t_star = jnp.asarray(b0.T, dtype=jnp.int32)
    X0 = np.asarray(jax.device_get(backtrack_batch_jax(I, t_star, Tmax)))
    return restore_lower_limits(batch, X0.astype(np.int64))
