"""JAX implementation of the (MC)^2MKP dynamic program for scheduling
instances (contiguous classes), built on the min-plus convolution kernel.

The DP row update over classes is a ``lax.scan``; each step is one banded
min-plus convolution (``repro.kernels``). Backtracking is a reverse
``lax.scan`` over the stacked argmin matrix, so the whole solver is a single
jittable program — this is what runs server-side every FL round when
schedules are recomputed from refreshed energy estimates.

Inputs are the 0-lower-limit equivalent instance (Section 5.2) as dense
arrays: ``costs (n, W)`` padded with BIG beyond each ``U_i``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.ops import BIG, minplus_step
from .problem import Problem, remove_lower_limits, restore_lower_limits

__all__ = ["solve_schedule_dp_jax", "dp_tables_jax", "pack_problem"]


def pack_problem(p0: Problem):
    """Dense (n, W) cost matrix for a 0-lower-limit instance; entries beyond
    U_i are BIG so those items are never selected."""
    W = int(p0.upper.max()) + 1
    n = p0.n
    costs = np.full((n, W), float(BIG), dtype=np.float32)
    for i in range(n):
        u = int(p0.upper[i])
        costs[i, : u + 1] = p0.cost_tables[i][: u + 1]
    return jnp.asarray(costs)


@functools.partial(jax.jit, static_argnames=("T", "backend"))
def dp_tables_jax(costs: jnp.ndarray, T: int, backend: str = "ref"):
    """Scans the DP over classes. Returns (K_last (T+1,), I (n, T+1))."""

    def step(krow, cost_i):
        kout, iout = minplus_step(krow, cost_i, backend=backend)
        return kout, iout

    # Z_0: only capacity 0 is packable at zero cost.
    k0 = jnp.full((T + 1,), BIG, jnp.float32).at[0].set(0.0)
    k_last, I = jax.lax.scan(step, k0, costs)
    return k_last, I


@functools.partial(jax.jit, static_argnames=("T",))
def backtrack_jax(I: jnp.ndarray, t_star: jnp.ndarray, T: int):
    """Reverse scan: x_i = I[i, t]; t -= x_i (weights == item index)."""

    def step(t, irow):
        j = irow[t]
        return t - j, j

    _, xs_rev = jax.lax.scan(step, t_star.astype(jnp.int32), I[::-1])
    return xs_rev[::-1]


def solve_schedule_dp_jax(problem: Problem, backend: str = "ref") -> np.ndarray:
    """Drop-in replacement for :func:`repro.core.mc2mkp.solve_schedule_dp`
    running as a jitted JAX program (optionally via the Pallas kernel)."""
    problem.validate()
    p0 = remove_lower_limits(problem)
    costs = pack_problem(p0)
    k_last, I = dp_tables_jax(costs, int(p0.T), backend=backend)
    # Scheduling instances always fill the knapsack: T* == T.
    t_star = jnp.asarray(p0.T)
    x0 = np.asarray(jax.device_get(backtrack_jax(I, t_star, int(p0.T))))
    return restore_lower_limits(problem, x0.astype(np.int64))
