"""Baseline schedulers the paper positions itself against.

  - :func:`olar` — OLAR [26] (the author's earlier IPDPS'21 algorithm):
    assigns each next task to the resource whose *resulting* cost is minimal,
    which optimally minimizes the MAXIMUM cost (makespan/round duration) for
    increasing costs — but not the total (energy) cost this paper targets.
  - :func:`uniform` — equal split (FedAvg default behaviour).
  - :func:`proportional` — workload proportional to device efficiency
    (1 / marginal cost at 1 task), the common linear-cost heuristic
    of refs [16]-[22].
  - :func:`random_schedule` — random feasible assignment.
  - :func:`greedy_marginal` — MarIn's greedy rule applied regardless of
    regime (optimal when marginals are non-decreasing, unreliable otherwise;
    a "naive greedy" foil for the Section 3.1 insight that greedy fails in
    general).

Every baseline returns a *valid* schedule (respects limits, sums to T) so
energy comparisons are apples-to-apples.
"""

from __future__ import annotations

import heapq

import numpy as np

from .marginal import marin
from .problem import Problem, remove_lower_limits, restore_lower_limits

__all__ = ["olar", "uniform", "proportional", "random_schedule", "greedy_marginal"]


def olar(problem: Problem) -> np.ndarray:
    """OLAR: next task -> argmin_i C_i(x_i + 1) (minimizes max cost)."""
    problem.validate()
    p = remove_lower_limits(problem)
    n = p.n
    x = np.zeros(n, dtype=np.int64)
    heap = []
    for i in range(n):
        if p.upper[i] >= 1:
            heapq.heappush(heap, (float(p.cost_tables[i][1]), i))
    for _ in range(p.T):
        _, k = heapq.heappop(heap)
        x[k] += 1
        nxt = int(x[k]) + 1
        if nxt <= p.upper[k]:
            heapq.heappush(heap, (float(p.cost_tables[k][nxt]), k))
    return restore_lower_limits(problem, x)


def _distribute_respecting_limits(problem: Problem, weights: np.ndarray) -> np.ndarray:
    """Largest-remainder apportionment of T tasks ~ weights, clipped to
    [L_i, U_i] and repaired to feasibility."""
    problem.validate()
    n, T = problem.n, problem.T
    w = np.maximum(np.asarray(weights, dtype=np.float64), 0.0)
    if w.sum() <= 0:
        w = np.ones(n)
    raw = w / w.sum() * T
    x = np.clip(np.floor(raw).astype(np.int64), problem.lower, problem.upper)
    # distribute remainder by largest fractional part, then repair
    order = np.argsort(-(raw - np.floor(raw)))
    deficit = T - int(x.sum())
    idx = 0
    while deficit != 0:
        k = int(order[idx % n])
        if deficit > 0 and x[k] < problem.upper[k]:
            x[k] += 1
            deficit -= 1
        elif deficit < 0 and x[k] > problem.lower[k]:
            x[k] -= 1
            deficit += 1
        idx += 1
        if idx > 4 * n * (abs(deficit) + 1) + 16:  # pragma: no cover
            raise RuntimeError("apportionment repair failed")
    return x


def uniform(problem: Problem) -> np.ndarray:
    return _distribute_respecting_limits(problem, np.ones(problem.n))


def proportional(problem: Problem) -> np.ndarray:
    """Tasks proportional to device efficiency = 1 / M_i(1)."""
    eff = []
    for i in range(problem.n):
        tbl = problem.cost_tables[i]
        lo = int(problem.lower[i])
        if int(problem.upper[i]) > lo:
            m1 = float(tbl[lo + 1] - tbl[lo])
        else:
            m1 = np.inf
        eff.append(1.0 / max(m1, 1e-12))
    return _distribute_respecting_limits(problem, np.asarray(eff))


def random_schedule(problem: Problem, rng: np.random.Generator) -> np.ndarray:
    return _distribute_respecting_limits(problem, rng.random(problem.n) + 1e-3)


def greedy_marginal(problem: Problem) -> np.ndarray:
    """The naive-greedy baseline: MarIn's smallest-next-marginal rule applied
    unconditionally, with NO regime check.

    Guaranteed optimal only when every marginal-cost function is
    non-decreasing (the MarIn regime, paper Theorem 2 — where it IS MarIn);
    on other instances it may coincidentally land on an optimum but can be
    arbitrarily bad (the Section 3.1 counterexamples). Kept as a named
    baseline so benchmarks can show greedy failing where the DP does not —
    ``schedule(algorithm="auto")`` never dispatches here, it routes through
    the shared regime detector (:func:`repro.core.scheduler.select_algorithm`).
    """
    return marin(problem)
