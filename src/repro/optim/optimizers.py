"""Pure-JAX optimizers (no external deps): SGD, momentum, AdamW, Adafactor.

Interface mirrors optax: ``opt = adamw(lr)``; ``state = opt.init(params)``;
``updates, state = opt.update(grads, state, params)``; apply with
``apply_updates``. All state is a pytree, so optimizers compose with jit,
scan, vmap, and pjit sharding.

Adafactor (factored second moment, optional no first moment) exists so the
671B config's optimizer state fits a v5e pod (see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "momentum", "adamw", "adafactor", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        new_m = jax.tree.map(lambda m, g: beta * m + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr * (beta * m + g), new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mu_dtype: Optional[jnp.dtype] = None,
) -> Optimizer:
    def init(params):
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mu_dtype or p.dtype), params)
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params):
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any  # row second-moment (or full v for <2D params)
    vc: Any  # col second-moment (zeros placeholder for <2D params)


def adafactor(
    lr: float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018), momentum-free.

    For params of rank >= 2 the second moment is factored over the last two
    dims -> O(rows + cols) state instead of O(rows * cols); 1-D params keep a
    full second moment. This is the memory-fitting choice for the 671B MoE.
    """

    def is_factored(p):
        return p.ndim >= 2

    def init(params):
        def vr_like(p):
            if is_factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_like(p):
            if is_factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree.map(vr_like, params),
            vc=jax.tree.map(vc_like, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(g, vr, vc, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if is_factored(p):
                new_vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                new_vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                # rank-1 reconstruction of 1/sqrt(v)
                r = new_vr / jnp.maximum(new_vr.mean(axis=-1, keepdims=True), eps)
                pre = g32 / (jnp.sqrt(r)[..., None] * jnp.sqrt(new_vc)[..., None, :] + eps)
            else:
                new_vr = beta * vr + (1 - beta) * g2
                new_vc = vc
                pre = g32 / (jnp.sqrt(new_vr) + eps)
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(jnp.square(pre)) + eps)
            pre = pre / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * pre).astype(p.dtype), new_vr, new_vc

        flat_g, treedef = jax.tree.flatten(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        flat_p = treedef.flatten_up_to(params)
        outs = [upd(g, vr, vc, p) for g, vr, vc, p in zip(flat_g, flat_vr, flat_vc, flat_p)]
        updates = treedef.unflatten([o[0] for o in outs])
        new_vr = treedef.unflatten([o[1] for o in outs])
        new_vc = treedef.unflatten([o[2] for o in outs])
        return updates, AdafactorState(step=step, vr=new_vr, vc=new_vc)

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw, "adafactor": adafactor}[name](lr, **kw)
