"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "cosine", "warmup_cosine", "linear_decay"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)

    return fn


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine(lr, max(total_steps - warmup_steps, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = lr * s / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return fn


def linear_decay(lr: float, total_steps: int):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0.0, 1.0)
        return lr * (1 - t)

    return fn
