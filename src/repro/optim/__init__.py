from .optimizers import Optimizer, adafactor, adamw, apply_updates, get_optimizer, momentum, sgd
from .schedules import constant, cosine, linear_decay, warmup_cosine

__all__ = [
    "Optimizer", "sgd", "momentum", "adamw", "adafactor", "apply_updates",
    "get_optimizer", "constant", "cosine", "warmup_cosine", "linear_decay",
]
