"""Scheduler-as-a-service: coalescing request batcher over the sweep engine.

The engine (DESIGN.md §10–§13) already looks like an inference server —
shape-bucketed compile cache, non-blocking ``dispatch()``, regime-split
routing. :class:`SchedulerService` finishes the job for heavy served
traffic (ROADMAP: "scheduler-as-a-service"): a persistent front-end that
admits a stream of heterogeneous :class:`~repro.core.problem.Problem` /
:class:`~repro.core.problem.ProblemBatch` requests and serves each from a
COALESCED dispatch instead of one kernel launch per request.

Pipeline (DESIGN.md §14)::

    submit() ──▶ admission (bounded, backpressure)
             ──▶ coalescer thread: group by pow2 bucket key, flush a bucket
                 as ONE SweepEngine.dispatch() on a max-batch or max-delay
                 trigger
             ──▶ completer thread: materialize the batched handle, demux
                 per-request rows into ScheduleFuture results

  * **Admission** is bounded by ``max_pending`` rows admitted-but-not-yet-
    completed: overload blocks producers (or raises
    :class:`ServiceOverloaded` past their timeout) — latency degrades,
    memory does not.
  * **Coalescing** groups requests by :func:`~repro.serve.coalesce.
    coalesce_key` — the engine's own bucket math — so merging requests
    never changes which executable solves them, and results stay
    bit-identical to solving each request alone (inert padding).
  * **Warmup**: :meth:`SchedulerService.warm` pre-traces the hot buckets
    over the whole pow2 batch-size ladder, so steady-state traffic never
    hits a cold XLA trace no matter which trigger fires a flush.
  * **Demux**: each :class:`ScheduleFuture` slices its rows (and, for
    pure-DP flushes, ``k_last``/``objectives``) out of the shared batched
    handle; handle materialization is thread-safe (lock-guarded in
    ``core/sweep.py``), so many requesters can drain one flush at once.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Optional

import numpy as np

from ..core.pareto import assemble_frontier, candidate_deadlines, tightened_instances
from ..core.problem import Problem, ProblemBatch, total_cost
from ..core.resilience import CircuitBreaker, RetryPolicy, is_transient
from ..core.scheduler import _schedule
from ..core.sweep import SweepEngine, _next_pow2, default_engine
from .coalesce import coalesce_key, combine_batches, pow2_ladder, warm_batch

__all__ = [
    "FleetFuture",
    "FrontierFuture",
    "ScheduleFuture",
    "SchedulerService",
    "ServiceClosed",
    "ServiceOverloaded",
]


class ServiceClosed(RuntimeError):
    """Raised by :meth:`SchedulerService.submit` after :meth:`close`."""


class ServiceOverloaded(RuntimeError):
    """The bounded admission queue stayed full past the submit timeout."""


class ScheduleFuture:
    """Per-request handle to an in-flight (possibly coalesced) solve.

    :meth:`result` blocks until the request's flush materialized and
    returns this request's schedule rows — ``(B, n)`` int64 for batch
    requests, ``(n,)`` for a single-:class:`Problem` submission —
    bit-identical to solving the request alone. :meth:`objectives` and
    (for ``split_regimes=False`` requests) :meth:`k_last` demux the same
    per-request views out of the batched handle with no extra dispatch.

    ``submitted_at`` / ``completed_at`` are ``time.monotonic()`` stamps set
    by the service (completion is stamped when the completer thread lands
    the flush) — the served-latency telemetry ``bench_serve.py`` reports.
    """

    def __init__(self, rows: int, n: int, squeeze: bool):
        self._rows = rows
        self._n = n
        self._squeeze = squeeze
        self._event = threading.Event()
        self._X: Optional[np.ndarray] = None
        self._handle = None  # the flush's SweepHandle / RegimeSplitHandle
        self._lo = self._hi = 0  # this request's rows in the flushed batch
        self._exc: Optional[BaseException] = None
        self.submitted_at: Optional[float] = None
        self.completed_at: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def _resolve(self, X: np.ndarray, handle, lo: int, hi: int, t_done: float) -> None:
        self._X, self._handle, self._lo, self._hi = X, handle, lo, hi
        self.completed_at = t_done
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self.completed_at = time.monotonic()
        self._event.set()

    def _wait(self, timeout: Optional[float]):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        if self._exc is not None:
            raise self._exc

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """This request's schedule(s); blocks until served."""
        self._wait(timeout)
        return self._X[0] if self._squeeze else self._X

    def objectives(self, timeout: Optional[float] = None):
        """Per-instance 0-lower-limit objectives (float for a single-Problem
        request), demuxed from the batched handle — same convention as
        :meth:`repro.core.sweep.SweepHandle.objectives`."""
        self._wait(timeout)
        obj = np.asarray(self._handle.objectives(), np.float64)[self._lo : self._hi]
        return float(obj[0]) if self._squeeze else obj

    def k_last(self, timeout: Optional[float] = None) -> np.ndarray:
        """This request's final DP row(s) — the free workload-Pareto curve.
        Only defined for ``split_regimes=False`` (pure-DP) requests; the
        regime-split handle raises, exactly as engine callers see."""
        self._wait(timeout)
        k = self._handle.k_last()[self._lo : self._hi]
        return k[0] if self._squeeze else k


class FrontierFuture:
    """A served Pareto-frontier request (PR 7, DESIGN.md §15): wraps the
    underlying ε-constraint sweep's :class:`ScheduleFuture` and assembles
    the pruned :class:`~repro.core.pareto.ParetoFrontier` on :meth:`result`.
    The sweep itself is ONE coalescable request — every tightened instance
    shares the base problem's bucket, so frontier traffic merges with any
    other same-bucket traffic exactly like plain solves do."""

    def __init__(self, future: ScheduleFuture, problem, time_tables, deadlines):
        self._future = future
        self._problem = problem
        self._time_tables = time_tables
        self._deadlines = deadlines
        self._frontier = None

    def done(self) -> bool:
        return self._future.done()

    @property
    def submitted_at(self):
        return self._future.submitted_at

    @property
    def completed_at(self):
        return self._future.completed_at

    def result(self, timeout: Optional[float] = None):
        """The :class:`~repro.core.pareto.ParetoFrontier`; blocks until the
        sweep is served. Repeated calls return the same object."""
        if self._frontier is None:
            X = self._future.result(timeout)
            self._frontier = assemble_frontier(
                self._problem, self._time_tables, self._deadlines, X
            )
        return self._frontier


class FleetFuture:
    """A served two-level fleet solve (PR 8, DESIGN.md §16): wraps a
    :class:`~repro.core.fleet.FleetRun` whose stage-1 curve dispatch was
    admitted as ONE coalescable request at submit time. :meth:`result` runs
    the remaining stages — the top-level allocation and the per-cluster
    schedule batch also go through the service, merging with any same-bucket
    traffic — and returns the :class:`~repro.core.fleet.FleetSolution`.
    Repeated calls return the same object."""

    def __init__(self, run):
        self._run = run

    def done(self) -> bool:
        """True once the stage-1 curve request has been served (the
        remaining stages are small and run inside :meth:`result`)."""
        return self._run.done()

    def result(self, timeout: Optional[float] = None):
        """The :class:`~repro.core.fleet.FleetSolution`; ``timeout`` is a
        real deadline enforced across ALL remaining staged solves (each
        staged served request gets the budget left on the clock), raising
        :class:`TimeoutError` exactly like :meth:`ScheduleFuture.result`.
        A timed-out call may be retried — later stages re-run from the
        memoized stage-1 curves, and a completed solve is cached."""
        return self._run.finish(timeout=timeout)


class _DegradedHandle:
    """Stand-in flush handle for the circuit breaker's degraded direct-solve
    path (DESIGN.md §17): schedules were host-solved — bit-identical to the
    engine path — so ``result()``/``objectives()`` demux normally; only
    ``k_last()`` is unavailable (no fused-DP dispatch ran), and raises with
    the same flavor of error as a regime-split handle."""

    def __init__(self, X: np.ndarray, objectives: np.ndarray):
        self._X = X
        self._obj = objectives

    def done(self) -> bool:
        return True

    def result(self) -> np.ndarray:
        return self._X

    def objectives(self) -> np.ndarray:
        return self._obj

    def k_last(self) -> np.ndarray:
        raise ValueError(
            "k_last() is unavailable: this flush was served by the degraded "
            "direct-solve path (circuit breaker open) — no fused-DP row "
            "exists. Retry once the breaker closes, or solve directly "
            "against a healthy engine."
        )


class _Request:
    __slots__ = ("batch", "future", "t_submit")

    def __init__(self, batch: ProblemBatch, future: ScheduleFuture, t_submit: float):
        self.batch = batch
        self.future = future
        self.t_submit = t_submit


class SchedulerService:
    """Persistent coalescing front-end over one :class:`SweepEngine`.

    Args:
      engine: the engine all flushes dispatch through (``None``: the
        process-wide default — sharing it means FL campaign planning and
        external traffic warm ONE cache).
      max_batch: rows that trigger an immediate bucket flush. Requests are
        atomic (never split), so a flush can exceed this by the last
        request's rows.
      max_delay_s: oldest-request age that triggers a flush even when the
        bucket is not full — the latency bound under light traffic.
      max_pending: admission bound, in rows admitted but not yet completed.
        Full ⇒ ``submit`` blocks (backpressure); past its ``timeout`` ⇒
        :class:`ServiceOverloaded`. An oversize request (> ``max_pending``
        rows) is admitted only once the service is drained, alone.
      name: thread-name prefix (observability).
      retry: a :class:`~repro.core.resilience.RetryPolicy` — flushes whose
        engine dispatch/materialization raises a TRANSIENT error
        (:func:`~repro.core.resilience.is_transient`) are re-dispatched with
        exponential backoff + deterministic jitter. Non-transient errors
        always propagate to the affected futures unchanged.
      breaker: a :class:`~repro.core.resilience.CircuitBreaker` — after K
        consecutive engine failures the breaker opens and flushes are served
        by the DEGRADED direct-solve path (host algorithms, bit-identical
        schedules, no ``k_last``) instead of hammering the engine, until a
        half-open probe succeeds. With a breaker configured, transient
        failures that exhaust their retries also degrade rather than fail.
    """

    def __init__(
        self,
        engine: Optional[SweepEngine] = None,
        max_batch: int = 32,
        max_delay_s: float = 0.002,
        max_pending: int = 1024,
        name: str = "sched-serve",
        retry: Optional[RetryPolicy] = None,
        breaker: Optional[CircuitBreaker] = None,
    ):
        if max_batch < 1 or max_pending < 1:
            raise ValueError("max_batch and max_pending must be >= 1")
        self.engine = engine if engine is not None else default_engine()
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self.max_pending = int(max_pending)
        self.retry = retry
        self.breaker = breaker
        self._retry_rng = retry.make_rng() if retry is not None else None
        self._cond = threading.Condition()
        self._pending: dict = {}  # coalesce key -> [_Request]
        self._pending_rows = 0  # admitted, not yet flushed
        self._inflight_rows = 0  # admitted, not yet completed (the bound)
        self._closed = False
        self._stats = {
            "requests": 0,
            "rows": 0,
            "completed_requests": 0,
            "flushes": 0,
            "flushed_rows": 0,
            "size_flushes": 0,
            "delay_flushes": 0,
            "close_flushes": 0,
            "rejected": 0,
            "warmed_executables": 0,
            "retries": 0,
            "flush_failures": 0,
            "degraded_flushes": 0,
            "degraded_rows": 0,
        }
        self._done_q: queue.SimpleQueue = queue.SimpleQueue()
        self._coalescer = threading.Thread(
            target=self._coalesce_loop, name=f"{name}-coalescer", daemon=True
        )
        self._completer = threading.Thread(
            target=self._complete_loop, name=f"{name}-completer", daemon=True
        )
        self._coalescer.start()
        self._completer.start()

    # ---- client API ----------------------------------------------------

    def submit(
        self,
        problems,
        split_regimes: bool = False,
        timeout: Optional[float] = None,
    ) -> ScheduleFuture:
        """Admits one request — a single :class:`Problem`, a sequence of
        them, or a prebuilt :class:`ProblemBatch` — and returns its
        :class:`ScheduleFuture`. ``split_regimes`` selects the regime-split
        solve path (DESIGN.md §13) and is part of the coalescing key: split
        and plain requests never share a flush. Blocks while the admission
        bound is full; ``timeout`` seconds later raises
        :class:`ServiceOverloaded` instead.
        """
        squeeze = isinstance(problems, Problem)
        if squeeze:
            batch = ProblemBatch.from_problems([problems])
        elif isinstance(problems, ProblemBatch):
            batch = problems
        else:
            batch = ProblemBatch.from_problems(problems)
        batch.validate()
        key = coalesce_key(batch, split_regimes)  # cheap numpy, outside the lock
        future = ScheduleFuture(batch.B, batch.n, squeeze)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                if self._closed:
                    raise ServiceClosed("submit() after close()")
                if (
                    self._inflight_rows + batch.B <= self.max_pending
                    or self._inflight_rows == 0  # oversize request, alone
                ):
                    break
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    self._stats["rejected"] += 1
                    raise ServiceOverloaded(
                        f"admission queue full ({self._inflight_rows}/"
                        f"{self.max_pending} rows in flight) past timeout"
                    )
                self._cond.wait(remaining)
            t_now = time.monotonic()
            future.submitted_at = t_now
            was_idle = not self._pending
            bucket = self._pending.setdefault(key, [])
            bucket.append(_Request(batch, future, t_now))
            self._pending_rows += batch.B
            self._inflight_rows += batch.B
            self._stats["requests"] += 1
            self._stats["rows"] += batch.B
            # Wake the coalescer only when this submit changes its schedule:
            # a new deadline (queue was idle) or a size-ripe bucket. A later
            # arrival never shortens an existing delay deadline, so skipping
            # the notify here avoids a context switch per request on the
            # saturated path (the coalescer wakes on its own timer).
            if was_idle or sum(r.batch.B for r in bucket) >= self.max_batch:
                self._cond.notify_all()
        return future

    def submit_frontier(
        self,
        problem: Problem,
        time_tables,
        deadlines=None,
        split_regimes: bool = True,
        timeout: Optional[float] = None,
    ) -> FrontierFuture:
        """Admits a Pareto-frontier request: the ε-constraint sweep of
        ``problem`` over ``deadlines`` (``None``: the exact candidate set —
        :func:`~repro.core.pareto.candidate_deadlines`) as ONE coalescable
        request. Returns a :class:`FrontierFuture` whose ``result()`` is the
        pruned :class:`~repro.core.pareto.ParetoFrontier`. Same admission /
        backpressure semantics as :meth:`submit`."""
        if deadlines is None:
            deadlines = candidate_deadlines(problem, time_tables)
        deadlines = np.asarray(list(deadlines), dtype=np.float64)
        tight = tightened_instances(problem, time_tables, deadlines)
        future = self.submit(tight, split_regimes=split_regimes, timeout=timeout)
        return FrontierFuture(future, problem, time_tables, deadlines)

    def submit_fleet(
        self,
        problem: Problem,
        *,
        clusters=None,
        quantum: Optional[int] = None,
        seed: int = 0,
        time_tables=None,
        check: bool = True,
    ) -> FleetFuture:
        """Admits a two-level fleet solve (DESIGN.md §16): clusters the
        clients on the calling thread (deterministic k-means), submits the
        per-cluster curve batch as ONE coalescable request, and returns a
        :class:`FleetFuture`. The top-level allocation and per-cluster
        schedule stages run through the service too when ``result()`` is
        called. Same knobs as
        :meth:`repro.core.solver.Solver.solve_fleet`."""
        from ..core.fleet import FleetRun  # lazy: fleet sits above the engine

        return FleetFuture(
            FleetRun(
                problem,
                service=self,
                clusters=clusters,
                quantum=quantum,
                seed=seed,
                time_tables=time_tables,
                check=check,
            )
        )

    def warm(self, specs, batch_sizes=None, split_regimes: bool = False) -> int:
        """Ahead-of-time traces the executables that traffic of the given
        shapes will hit, so steady-state serving never pays a cold XLA
        trace.

        ``specs``: iterable of ``(n, T, W)`` shapes — actual request shapes
        (``T`` in 0-lower-limit terms, i.e. ``T - sum(L)``) or bucket axes
        straight from :func:`~repro.core.sweep.request_bucket`; both round
        to the same buckets. ``batch_sizes`` defaults to the full pow2
        ladder up to ``max_batch`` (:func:`~repro.serve.coalesce.
        pow2_ladder`), covering every batch bucket a size- OR delay-
        triggered flush can produce. With ``split_regimes=True`` each spec
        additionally warms the ``("marginal", ...)`` selection bucket
        (best-effort: a mixed-regime flush splits into sub-batches of
        data-dependent size, so only full-batch buckets are guaranteed).

        Returns the number of fresh XLA tracings performed (0 = everything
        was already warm). Runs synchronously on the caller's thread,
        directly against the engine — intended before opening the doors.

        Raises ``ValueError`` when the warm plan holds more executables
        than the engine's LRU (``max_entries``): warming past capacity
        would silently evict the oldest warm entries and steady-state
        traffic would pay cold traces anyway — construct the engine with a
        larger ``max_entries`` (or warm fewer buckets) instead.
        """
        sizes = list(batch_sizes) if batch_sizes is not None else pow2_ladder(self.max_batch)
        specs = [tuple(int(v) for v in spec) for spec in specs]
        planned = {
            ("dp", _next_pow2(B), _next_pow2(n), _next_pow2(T), _next_pow2(W))
            for n, T, W in specs
            for B in sizes
        }
        if split_regimes:
            planned |= {
                ("marginal", _next_pow2(B), _next_pow2(n), _next_pow2(W))
                for n, _T, W in specs
                for B in sizes
            }
        if len(planned) > self.engine.max_entries:
            raise ValueError(
                f"warm plan needs {len(planned)} executables but the engine LRU "
                f"holds max_entries={self.engine.max_entries} — the oldest warm "
                f"entries would be evicted before serving. Use "
                f"SweepEngine(max_entries>={len(planned)}) or warm fewer buckets."
            )
        before = self.engine.cache_stats()["compiles"]
        for n, T, W in specs:
            for B in sizes:
                wb = warm_batch(n, T, W, B, regime="arbitrary")
                self.engine.dispatch(wb, split_regimes=split_regimes).result()
                if split_regimes:
                    mono = warm_batch(n, T, W, B, regime="increasing")
                    self.engine.dispatch(mono, split_regimes=True).result()
        traced = self.engine.cache_stats()["compiles"] - before
        with self._cond:
            self._stats["warmed_executables"] += traced
        return traced

    def stats(self) -> dict:
        """Service counters plus live queue depths (rows)."""
        with self._cond:
            out = dict(self._stats)
            out["pending_rows"] = self._pending_rows
            out["inflight_rows"] = self._inflight_rows
            out["mean_flush_rows"] = (
                out["flushed_rows"] / out["flushes"] if out["flushes"] else 0.0
            )
        if self.breaker is not None:
            out["breaker"] = self.breaker.stats()
        return out

    def close(self, timeout: Optional[float] = None) -> None:
        """Clean shutdown: flush everything pending, serve every in-flight
        request, then stop both threads. Idempotent; later submits raise
        :class:`ServiceClosed`."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._coalescer.join(timeout)
        self._completer.join(timeout)

    def __enter__(self) -> "SchedulerService":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    # ---- coalescer thread ----------------------------------------------

    def _ripe(self, key, reqs, now: float) -> Optional[str]:
        """The flush trigger a bucket has hit, if any."""
        if sum(r.batch.B for r in reqs) >= self.max_batch:
            return "size"
        if now - reqs[0].t_submit >= self.max_delay_s:
            return "delay"
        return None

    def _coalesce_loop(self) -> None:
        while True:
            flushes = []
            with self._cond:
                while not self._closed:
                    now = time.monotonic()
                    if any(self._ripe(k, rs, now) for k, rs in self._pending.items()):
                        break
                    if self._pending:
                        oldest = min(rs[0].t_submit for rs in self._pending.values())
                        self._cond.wait(max(oldest + self.max_delay_s - now, 0.0))
                    else:
                        self._cond.wait()
                now = time.monotonic()
                for key in list(self._pending):
                    trigger = (
                        "close" if self._closed else self._ripe(key, self._pending[key], now)
                    )
                    if trigger is None:
                        continue
                    # Cap a flush at max_batch rows (requests stay atomic):
                    # rows that arrived since the bucket went ripe stay
                    # pending, so the flushed batch-axis bucket never
                    # exceeds the pow2 ladder warm() pre-traced. A single
                    # oversize request still flushes alone. When closing,
                    # drain the bucket in capped chunks too.
                    while self._pending.get(key):
                        queued = self._pending[key]
                        take, rows = [], 0
                        for r in queued:
                            if take and rows + r.batch.B > self.max_batch:
                                break
                            take.append(r)
                            rows += r.batch.B
                        if len(take) == len(queued):
                            self._pending.pop(key)
                        else:
                            self._pending[key] = queued[len(take) :]
                        self._pending_rows -= rows
                        self._stats[f"{trigger}_flushes"] += 1
                        flushes.append((key, take))
                        if not self._closed:
                            break
                drained = self._closed and not self._pending
                self._cond.notify_all()
            for key, reqs in flushes:
                self._flush(key, reqs)
            if drained:
                self._done_q.put(None)  # completer: nothing further is coming
                return

    def _flush(self, key, reqs) -> None:
        """ONE engine dispatch for a ripe bucket (async — the executable is
        launched, not materialized), handed to the completer. Failure
        handling (retry / breaker / degraded solve) runs on the completer
        thread so the coalescer's flush cadence never blocks on backoff."""
        split = key[3]
        combined, slices = combine_batches([r.batch for r in reqs])
        if self.breaker is not None and not self.breaker.allow():
            # breaker open: route straight to the degraded direct-solve path
            self._done_q.put(("degraded", None, reqs, slices, combined, split))
            return
        try:
            handle = self.engine.dispatch(combined, split_regimes=split)
        except BaseException as e:
            self._done_q.put(("failed", e, reqs, slices, combined, split))
            return
        with self._cond:
            self._stats["flushes"] += 1
            self._stats["flushed_rows"] += combined.B
        self._done_q.put(("ok", handle, reqs, slices, combined, split))

    # ---- completer thread ----------------------------------------------

    def _complete_loop(self) -> None:
        while True:
            item = self._done_q.get()
            if item is None:
                return
            kind, payload, reqs, slices, combined, split = item
            if kind == "ok":
                try:
                    X = payload.result()  # blocks until the device solve lands
                except BaseException as e:
                    self._recover_flush(reqs, slices, combined, split, e)
                    continue
                if self.breaker is not None:
                    self.breaker.record_success()
                self._land(reqs, slices, payload, X)
            elif kind == "failed":
                self._recover_flush(reqs, slices, combined, split, payload)
            else:  # "degraded": breaker was open at flush time
                self._serve_degraded(reqs, slices, combined, split)

    def _recover_flush(self, reqs, slices, combined, split, exc) -> None:
        """A flush's engine attempt failed (at dispatch or materialization):
        retry transient errors under the policy, feed the breaker, and — with
        a breaker configured — serve exhausted-transient flushes from the
        degraded path instead of failing them. Non-transient errors always
        propagate to the futures unchanged (real bugs are not retried)."""
        with self._cond:
            self._stats["flush_failures"] += 1
        if self.breaker is not None:
            self.breaker.record_failure()
        if is_transient(exc) and self.retry is not None:
            attempt = 1
            while attempt < self.retry.max_attempts:
                if self.breaker is not None and not self.breaker.allow():
                    break  # opened mid-retry: stop hammering, degrade below
                time.sleep(self.retry.delay(attempt, self._retry_rng))
                attempt += 1
                with self._cond:
                    self._stats["retries"] += 1
                try:
                    handle = self.engine.dispatch(combined, split_regimes=split)
                    X = handle.result()
                except BaseException as e:
                    exc = e
                    with self._cond:
                        self._stats["flush_failures"] += 1
                    if self.breaker is not None:
                        self.breaker.record_failure()
                    if not is_transient(exc):
                        break
                    continue
                if self.breaker is not None:
                    self.breaker.record_success()
                with self._cond:
                    self._stats["flushes"] += 1
                    self._stats["flushed_rows"] += combined.B
                self._land(reqs, slices, handle, X)
                return
        if is_transient(exc) and self.breaker is not None:
            self._serve_degraded(reqs, slices, combined, split)
        else:
            self._abort(reqs, exc)

    def _serve_degraded(self, reqs, slices, combined, split) -> None:
        """The circuit breaker's fallback: solve every instance of the flush
        with the host algorithms (``auto`` regime dispatch for split flushes,
        the reference DP otherwise) — engine-free, slower, but bit-identical
        schedules (asserted in tests/test_service_resilience.py), so callers
        cannot tell a degraded flush from a served one except by latency and
        the absence of ``k_last``."""
        try:
            X = np.zeros((combined.B, combined.n), dtype=np.int64)
            obj = np.zeros(combined.B, dtype=np.float64)
            for b in range(combined.B):
                p = combined.instance(b)
                x, _ = _schedule(p, "auto" if split else "dp", check=False)
                X[b, : p.n] = x
                fixed = float(
                    sum(p.cost_tables[i][int(p.lower[i])] for i in range(p.n))
                )
                obj[b] = total_cost(p, x) - fixed  # 0-lower-limit convention
        except BaseException as e:
            self._abort(reqs, e)
            return
        with self._cond:
            self._stats["degraded_flushes"] += 1
            self._stats["degraded_rows"] += combined.B
        self._land(reqs, slices, _DegradedHandle(X, obj), X)

    def _land(self, reqs, slices, handle, X) -> None:
        t_done = time.monotonic()
        for r, (lo, hi) in zip(reqs, slices):
            # each request sees only ITS rows, trimmed to its own n
            r.future._resolve(X[lo:hi, : r.batch.n].copy(), handle, lo, hi, t_done)
        self._retire(reqs)

    def _abort(self, reqs, exc: BaseException) -> None:
        for r in reqs:
            r.future._fail(exc)
        self._retire(reqs)

    def _retire(self, reqs) -> None:
        with self._cond:
            self._inflight_rows -= sum(r.batch.B for r in reqs)
            self._stats["completed_requests"] += len(reqs)
            self._cond.notify_all()  # wake producers blocked on admission
