"""Request coalescing: the pure shape math behind the scheduler service.

A stream of heterogeneous scheduling requests coalesces along the batch
axis when — and only when — the requests land in the same engine compile
bucket: merging then changes WHICH rows one executable solves, never which
executable runs (padding is inert, :meth:`ProblemBatch.pad_to`). The
bucket key reuses :func:`repro.core.sweep.request_bucket` — the exact math
:class:`~repro.core.sweep.SweepEngine` buckets by — so there is one source
of truth for "do these shapes share an executable".

Everything here is deterministic numpy with no threads or clocks; the
queueing/flush-trigger machinery lives in :mod:`repro.serve.service`.
"""

from __future__ import annotations

import numpy as np

from ..core.problem import ProblemBatch
from ..core.sweep import _next_pow2, request_bucket

__all__ = ["coalesce_key", "combine_batches", "pow2_ladder", "warm_batch"]


def coalesce_key(batch: ProblemBatch, split_regimes: bool) -> tuple:
    """``(n, T, W, split)`` — requests sharing this key flush as ONE
    dispatch. ``split`` is part of the key because regime-split and plain
    DP dispatches run different executables (DESIGN.md §13)."""
    nb, Tb, Wb = request_bucket(batch)
    return (nb, Tb, Wb, bool(split_regimes))


def combine_batches(batches):
    """Stacks request batches (which must share a coalesce key) into ONE
    :class:`ProblemBatch` along ``B``.

    Rows are padded to the group's max ``(n, W)`` envelope first — inert
    padding, so every row of the combined solve is bit-identical to solving
    its request alone. Returns ``(combined, slices)`` where ``slices[i] =
    (lo, hi)`` are request ``i``'s rows in the combined batch.
    """
    slices, lo = [], 0
    for b in batches:
        slices.append((lo, lo + b.B))
        lo += b.B
    if len(batches) == 1:
        return batches[0], slices
    n = max(b.n for b in batches)
    W = max(b.W for b in batches)
    padded = [b.pad_to(n=n, W=W) for b in batches]
    combined = ProblemBatch(
        T=np.concatenate([p.T for p in padded]),
        lower=np.concatenate([p.lower for p in padded], axis=0),
        upper=np.concatenate([p.upper for p in padded], axis=0),
        costs=np.concatenate([p.costs for p in padded], axis=0),
    )
    return combined, slices


def pow2_ladder(max_batch: int):
    """``[1, 2, 4, ..., next_pow2(max_batch)]`` — every batch-axis bucket a
    coalesced flush of up to ``max_batch`` rows can compile under. Warming
    the whole ladder makes steady-state traffic trace-free regardless of
    whether flushes fire on the size or the delay trigger."""
    top = _next_pow2(int(max_batch))
    out, b = [], 1
    while b <= top:
        out.append(b)
        b *= 2
    return out


def warm_batch(n: int, T: int, W: int, B: int, regime: str = "arbitrary") -> ProblemBatch:
    """A deterministic feasible ``(B, n, W)`` batch with workload ``T``,
    built to land in the same engine bucket as real ``(n, T, W)`` traffic —
    the ahead-of-time tracing vehicle for :meth:`SchedulerService.warm`.

    ``regime="arbitrary"`` builds zig-zag marginal tables (alternating
    ``+2/0``) so regime-split dispatches still route the warm batch to the
    DP executable (for ``W >= 4``; narrower tables cannot be non-monotone
    and may classify monotone — harmless for ``split_regimes=False``
    buckets, which ignore regimes entirely). ``regime="increasing"`` builds
    convex ``j^2`` tables that classify MarIn, warming the
    ``("marginal", ...)`` selection bucket instead.

    If ``T`` exceeds the envelope capacity ``n*(W-1)``, the workload is
    clamped — legal only while the pow2 bucket is preserved (a bucket real
    traffic in this envelope could actually produce); otherwise raises.
    """
    if W < 2:
        raise ValueError("warm shapes need W >= 2 (some assignable unit)")
    T_w = min(int(T), n * (W - 1))
    if T_w <= 0 or _next_pow2(T_w) != _next_pow2(int(T)):
        raise ValueError(
            f"warm shape (n={n}, T={T}, W={W}) is infeasible: capacity "
            f"{n * (W - 1)} cannot reach the T={_next_pow2(int(T))} bucket"
        )
    j = np.arange(W, dtype=np.float64)
    if regime == "increasing":
        tbl = j * j  # strictly increasing marginals -> MarIn
    elif regime == "arbitrary":
        tbl = j + (j % 2)  # marginals 2,0,2,0,... -> non-monotone for W >= 4
    else:
        raise ValueError(f"unknown warm regime {regime!r}")
    return ProblemBatch(
        T=np.full(B, T_w, dtype=np.int64),
        lower=np.zeros((B, n), dtype=np.int64),
        upper=np.full((B, n), W - 1, dtype=np.int64),
        costs=np.broadcast_to(tbl, (B, n, W)).copy(),
    )
