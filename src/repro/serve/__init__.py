"""Scheduler-as-a-service: coalescing request batcher over the sweep
engine (DESIGN.md §14).

:class:`SchedulerService` admits a stream of heterogeneous scheduling
requests, coalesces them into the engine's pow2 shape buckets, flushes
each bucket as ONE batched dispatch (max-batch or max-delay trigger), and
demuxes per-request :class:`ScheduleFuture` results — with ahead-of-time
:meth:`~SchedulerService.warm` tracing and bounded-admission backpressure.
"""

from .coalesce import coalesce_key, combine_batches, pow2_ladder, warm_batch
from .service import (
    FleetFuture,
    FrontierFuture,
    ScheduleFuture,
    SchedulerService,
    ServiceClosed,
    ServiceOverloaded,
)

__all__ = [
    "FleetFuture",
    "FrontierFuture",
    "ScheduleFuture",
    "SchedulerService",
    "ServiceClosed",
    "ServiceOverloaded",
    "coalesce_key",
    "combine_batches",
    "pow2_ladder",
    "warm_batch",
]
