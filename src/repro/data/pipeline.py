"""Batching pipeline: fixed-shape per-round batch tensors for jitted FL.

The FL round is one jit-compiled program, so every client contributes a
fixed-shape ``(max_steps, batch, seq)`` tensor each round; clients scheduled
fewer than ``max_steps`` batches simply have the excess masked inside the
scan (see ``fl/client.py``). Batches cycle through the client's local corpus
with a per-round offset (epoch-style traversal without reshuffling cost).
"""

from __future__ import annotations

import numpy as np

__all__ = ["lm_round_batches", "make_lm_examples"]


def make_lm_examples(corpus: np.ndarray, seq_len: int) -> np.ndarray:
    """Chops a token stream into (num_examples, seq_len + 1) windows
    (inputs + next-token labels)."""
    n = (len(corpus) - 1) // seq_len
    if n <= 0:
        reps = int(np.ceil((seq_len + 1) / max(len(corpus), 1)))
        corpus = np.tile(corpus, reps + 1)
        n = (len(corpus) - 1) // seq_len
    ex = np.stack(
        [corpus[i * seq_len : i * seq_len + seq_len + 1] for i in range(n)], axis=0
    )
    return ex.astype(np.int32)


def lm_round_batches(
    examples_per_client: list,
    max_steps: int,
    batch_size: int,
    round_index: int,
) -> np.ndarray:
    """(n_clients, max_steps, batch_size, seq_len+1) round tensor; each
    client's batches advance cyclically across rounds."""
    out = []
    for ex in examples_per_client:
        n = len(ex)
        need = max_steps * batch_size
        start = (round_index * need) % n
        idx = (start + np.arange(need)) % n
        out.append(ex[idx].reshape(max_steps, batch_size, -1))
    return np.stack(out, axis=0)
