"""Synthetic federated datasets.

Language-model data: Zipf-distributed token streams with client-specific
topic mixtures (so non-IID-ness is real, not just label skew). Also provides
embedding-style data for the audio/VLM stubbed frontends.
"""

from __future__ import annotations

import numpy as np

__all__ = ["zipf_lm_corpus", "client_corpora", "embedding_frames"]


def zipf_lm_corpus(
    rng: np.random.Generator,
    num_tokens: int,
    vocab_size: int,
    alpha: float = 1.1,
    topic_shift: int = 0,
) -> np.ndarray:
    """A Zipf token stream; ``topic_shift`` rotates the rank->id map so
    different clients favour different token subsets."""
    ranks = rng.zipf(alpha, size=num_tokens)
    ids = (np.minimum(ranks, vocab_size) - 1 + topic_shift) % vocab_size
    return ids.astype(np.int32)


def client_corpora(
    rng: np.random.Generator,
    n_clients: int,
    tokens_per_client: int,
    vocab_size: int,
    heterogeneity: float = 0.3,
) -> list:
    """Per-client corpora with rotated topic supports (non-IID)."""
    out = []
    for c in range(n_clients):
        shift = int(heterogeneity * vocab_size * c / max(n_clients, 1))
        out.append(zipf_lm_corpus(rng, tokens_per_client, vocab_size, topic_shift=shift))
    return out


def embedding_frames(
    rng: np.random.Generator, num_frames: int, dim: int, n_classes: int
) -> tuple:
    """Frame/patch embeddings + frame labels for encoder (audio) smoke data."""
    centers = rng.normal(size=(n_classes, dim)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=num_frames).astype(np.int32)
    x = centers[labels] + 0.5 * rng.normal(size=(num_frames, dim)).astype(np.float32)
    return x.astype(np.float32), labels
