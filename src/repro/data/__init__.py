from .partition import dirichlet_sizes, partition_stream
from .pipeline import lm_round_batches, make_lm_examples
from .synthetic import client_corpora, embedding_frames, zipf_lm_corpus

__all__ = [
    "dirichlet_sizes", "partition_stream", "lm_round_batches", "make_lm_examples",
    "client_corpora", "embedding_frames", "zipf_lm_corpus",
]
