"""Non-IID partitioning of a corpus across FL clients."""

from __future__ import annotations

import numpy as np

__all__ = ["dirichlet_sizes", "partition_stream"]


def dirichlet_sizes(
    rng: np.random.Generator, n_clients: int, total: int, alpha: float = 0.5, minimum: int = 1
) -> np.ndarray:
    """Client dataset sizes ~ Dirichlet(alpha) (smaller alpha = more skew)."""
    props = rng.dirichlet(np.full(n_clients, alpha))
    sizes = np.maximum((props * total).astype(np.int64), minimum)
    # fix rounding drift
    diff = total - int(sizes.sum())
    sizes[np.argmax(sizes)] += diff
    return sizes


def partition_stream(stream: np.ndarray, sizes: np.ndarray) -> list:
    """Contiguous split of a token stream by per-client sizes."""
    out, ofs = [], 0
    for s in sizes:
        out.append(stream[ofs : ofs + int(s)])
        ofs += int(s)
    return out
