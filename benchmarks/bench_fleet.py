"""Hierarchical fleet-scale scheduling benchmark (DESIGN.md §16).

Two legs, written to ``BENCH_fleet.json``:

  * **gap leg** (n <= 64) — the clustered two-level solve vs the flat DP
    optimum, solved by the same warm engine. Headline ``fleet_gap_pct``
    (CI ceiling: <= 5%). The flat DP is the in-bench oracle: the clustered
    objective must never beat it, must stay within the self-reported
    ``gap_bound``, and singleton clustering at quantum=1 must match it to
    float tolerance — any violation crashes the smoke, which fails CI.
  * **throughput leg** (n = 2048+) — warm end-to-end ``solve_fleet`` rate
    in clients/second. Headline ``fleet_throughput_n2048`` (CI floor,
    conservative: box-load swings).

Run as::

    PYTHONPATH=src python benchmarks/bench_fleet.py [--smoke] [--out PATH]
"""

import argparse
import json
import time

import numpy as np

GAP_CASES = (
    # (seed, n, T, clusters, quantum) — auto params where None
    (0, 16, 40, 16, 1),  # singleton clustering: must be exact
    (1, 32, 80, None, None),
    (2, 48, 120, 6, 2),
    (3, 64, 160, None, None),
    (4, 64, 192, 8, 3),
)


def _gap_leg():
    from repro.core import Solver, SweepEngine, random_problem, solve_fleet

    eng = SweepEngine()
    flat_solver = Solver(engine=eng)
    rows = []
    for seed, n, T, k, q in GAP_CASES:
        p = random_problem(np.random.default_rng(seed), n=n, T=T)
        fsol = solve_fleet(p, engine=eng, clusters=k, quantum=q)
        flat = float(flat_solver.solve([p], algorithm="dp_batch").objectives[0])
        scale = max(abs(flat), 1.0)
        gap_pct = max(0.0, (fsol.objective - flat) / scale) * 100.0

        # in-bench oracle parity: flat DP is optimal
        assert fsol.objective >= flat - 1e-6 * scale, (
            f"n={n}: clustered objective beats the flat DP optimum "
            f"({fsol.objective} < {flat})"
        )
        assert fsol.objective <= flat * (1.0 + fsol.gap_bound) + 1e-6 * scale, (
            f"n={n}: measured gap exceeds the certified bound "
            f"({gap_pct:.3f}% vs bound {fsol.gap_bound * 100:.3f}%)"
        )
        if k == n and (q or 1) == 1:
            assert abs(fsol.objective - flat) <= 1e-6 * scale, (
                f"n={n}: singleton clustering at quantum=1 must be exact"
            )
        rows.append(
            {
                "n": n,
                "T": T,
                "clusters": fsol.num_clusters,
                "quantum": fsol.quantum,
                "flat_objective": flat,
                "fleet_objective": fsol.objective,
                "gap_pct": gap_pct,
                "gap_bound_pct": fsol.gap_bound * 100.0,
            }
        )
    return rows


def _throughput_leg(n: int, repeats: int):
    from repro.core import SweepEngine, random_problem, solve_fleet

    p = random_problem(np.random.default_rng(42), n=n, T=4 * n, max_upper=64)
    eng = SweepEngine()
    fsol = solve_fleet(p, engine=eng, seed=0)  # cold: compiles
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        f2 = solve_fleet(p, engine=eng, seed=0)
        times.append(time.perf_counter() - t0)
        assert np.array_equal(f2.schedule, fsol.schedule), "warm re-solve drifted"
    warm_s = float(np.median(times))
    return {
        "n": n,
        "clusters": fsol.num_clusters,
        "quantum": fsol.quantum,
        "gap_bound_pct": fsol.gap_bound * 100.0,
        "warm_solve_s": warm_s,
        "clients_per_s": n / warm_s,
        "compiles": eng.cache_stats()["compiles"],
    }


def run_bench(throughput_n: int, repeats: int) -> dict:
    gap_rows = _gap_leg()
    tp = _throughput_leg(throughput_n, repeats)
    return {
        "gap_cases": gap_rows,
        "fleet_gap_pct": max(r["gap_pct"] for r in gap_rows),
        "throughput": tp,
        "fleet_throughput_n2048": tp["clients_per_s"],
    }


def run():
    """Harness entry point (benchmarks.run): gap sweep + one warm solve."""
    r = run_bench(throughput_n=512, repeats=1)
    tp = r["throughput"]
    return [
        (
            f"fleet_solve_n{tp['n']}",
            tp["warm_solve_s"] * 1e6,
            f"gap<=5%: max measured {r['fleet_gap_pct']:.2f}%",
        )
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast config for CI")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--n", type=int, default=None, help="throughput-leg fleet size")
    args = ap.parse_args()

    n = args.n or 2048
    result = run_bench(throughput_n=n, repeats=2 if args.smoke else 5)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
