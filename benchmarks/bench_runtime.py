"""Paper Table 2 (complexity table): empirical runtime scaling in T and n for
each algorithm; fits the scaling exponent in T to validate the stated
complexities (DP ~ T^2, MarIn ~ T log n, MarCo/MarDecUn ~ const in T,
MarDec ~ T)."""

import time

import numpy as np

from repro.core import random_problem, schedule

ALG_REGIME = {
    "dp": "arbitrary",
    "marin": "increasing",
    "marco": "linear",
    "mardecun": "decreasing",
    "mardec": "decreasing",
    "olar": "increasing",
}

T_GRID = (64, 128, 256, 512)
EXPECT_T_EXP = {"dp": 2.0, "marin": 1.0, "marco": 0.0, "mardecun": 0.0, "mardec": 1.0, "olar": 1.0}


def _time_alg(alg, p, reps=3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        schedule(p, alg, check=False)
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    rng = np.random.default_rng(1)
    rows = []
    for alg, regime in ALG_REGIME.items():
        times = []
        for T in T_GRID:
            if alg == "mardecun":
                from repro.core.costs import sublinear_cost
                from repro.core import Problem

                n = 16
                tables = tuple(
                    sublinear_cost(T, float(rng.uniform(5, 40)), float(rng.uniform(2, 20)))
                    for _ in range(n)
                )
                p = Problem(T=T, lower=np.zeros(n, int), upper=np.full(n, T), cost_tables=tables)
            else:
                p = random_problem(rng, n=16, T=T, regime=regime)
            times.append(_time_alg(alg, p))
        # fit exponent over the T grid
        exp = float(np.polyfit(np.log(T_GRID), np.log(np.maximum(times, 1e-7)), 1)[0])
        us = times[-1] * 1e6
        rows.append(
            (f"runtime_{alg}_T{T_GRID[-1]}", us, f"T_exponent={exp:.2f} expect<={EXPECT_T_EXP[alg] + 0.4}")
        )
    # scaling in n for MarCo/MarDecUn (Theta(n log n) / Theta(n))
    for alg in ("marco", "mardecun", "marin"):
        times = []
        n_grid = (8, 32, 128)
        for n in n_grid:
            if alg == "mardecun":
                from repro.core.costs import sublinear_cost
                from repro.core import Problem

                T = 128
                tables = tuple(
                    sublinear_cost(T, float(rng.uniform(5, 40)), float(rng.uniform(2, 20)))
                    for _ in range(n)
                )
                p = Problem(T=T, lower=np.zeros(n, int), upper=np.full(n, T), cost_tables=tables)
            else:
                p = random_problem(rng, n=n, T=128, regime=ALG_REGIME[alg])
            times.append(_time_alg(alg, p))
        exp = float(np.polyfit(np.log(n_grid), np.log(np.maximum(times, 1e-7)), 1)[0])
        rows.append((f"runtime_{alg}_n{n_grid[-1]}", times[-1] * 1e6, f"n_exponent={exp:.2f}"))
    rows.extend(_batched_vs_looped(rng))
    return rows


def _batched_vs_looped(rng, B=8, n=8, T=64):
    """Batched DP engine vs a Python loop of single jitted solves — a SMALL
    scaling data point; the headline config and BENCH_batch.json live in
    bench_batch (so the default harness doesn't time the same sweep twice)."""
    from benchmarks.bench_batch import make_sweep, time_sweep

    problems = make_sweep(rng, B, n, T)
    loop_cold, _ = time_sweep(problems, "loop", reps=1, cold=True)
    batch_cold, _ = time_sweep(problems, "batch", reps=1, cold=True)
    loop_warm, _ = time_sweep(problems, "loop", reps=3)
    batch_warm, _ = time_sweep(problems, "batch", reps=3)
    return [
        (
            f"runtime_dp_loop_B{B}",
            loop_warm / B * 1e6,
            f"cold={loop_cold:.3f}s warm={loop_warm:.4f}s",
        ),
        (
            f"runtime_dp_batch_B{B}",
            batch_warm / B * 1e6,
            f"cold={batch_cold:.3f}s speedup_cold={loop_cold / batch_cold:.1f}x "
            f"speedup_warm={loop_warm / batch_warm:.1f}x",
        ),
    ]
