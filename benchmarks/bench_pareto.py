"""Pareto-frontier extraction: one batched dispatch vs per-point solves
(PR 7, DESIGN.md §15).

The bicriteria engine (``repro.core.pareto``) turns the whole
(energy, completion-time) frontier into ONE ε-constraint batch through the
sweep engine; the naive alternative — what a caller without the engine
would write — solves each deadline point as its own engine call. Written to
``BENCH_pareto.json``:

  * ``speedup_frontier_vs_perpoint`` — warm best-of-reps per-point loop
    time over warm one-dispatch frontier time at the same deadline grid
    (both through warm :class:`~repro.core.sweep.SweepEngine` buckets).
    **Gated** at a hard floor of 5.0 in scripts/check_bench.py — the
    batched path amortizes the per-dispatch overhead across the grid, so
    the ratio scales with the point count (~grid-size x on CPU).
  * ``frontier_dispatches`` — engine cache lookups consumed by the
    one-dispatch frontier call; enforced == 1 (the tentpole contract).
  * parity is *enforced*, not asserted: the one-dispatch frontier must
    match the frontier assembled from the per-point solves point for point
    (times and energies), and on a small instance it must equal the
    brute-force frontier from the serial NumPy DP.

Run as::

    PYTHONPATH=src python benchmarks/bench_pareto.py [--smoke] [--out PATH]
"""

import argparse
import json
import time

import numpy as np

from repro.core import Solver, SweepEngine, solve_schedule_dp, tighten_for_deadline
from repro.core.costs import random_problem
from repro.core.pareto import (
    assemble_frontier,
    candidate_deadlines,
    deadline_grid,
    pareto_frontier,
    tightened_instances,
)

ACCEPT_N, ACCEPT_T, ACCEPT_POINTS = 8, 64, 48  # acceptance shape floor


def _bench(fn, reps):
    """Warm best-of-``reps`` seconds (fn must block on its own result)."""
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def make_instance(n, T, seed=7):
    """Arbitrary-regime instance (every frontier point pays the DP — the
    regime where batching matters most) plus monotone time tables."""
    rng = np.random.default_rng(seed)
    p = random_problem(rng, n=n, T=T, regime="arbitrary", with_lower=False)
    tt = [np.sort(rng.uniform(0.05, 1.0, int(u) + 1)) for u in p.upper]
    for t in tt:
        t[0] = 0.0
    return p, tt


def _check_exactness(n=5, T=24):
    """The one-dispatch frontier == brute force per-deadline serial DP."""
    p, tt = make_instance(n, T, seed=11)
    cands = candidate_deadlines(p, tt)
    front = pareto_frontier(p, tt, split_regimes=False)
    naive = []
    for d in cands:
        tp = tightened_instances(p, tt, [float(d)])[0]
        x = solve_schedule_dp(tp)
        naive.append(x)
    bf = assemble_frontier(p, tt, cands, np.stack(naive))
    if len(bf) != len(front) or not (
        np.array_equal(bf.times, front.times)
        and np.array_equal(bf.energies, front.energies)
    ):
        raise RuntimeError(
            f"one-dispatch frontier != brute-force frontier at n={n} T={T}: "
            f"{len(front)} vs {len(bf)} points"
        )


def bench_frontier_vs_perpoint(n, T, points, reps):
    p, tt = make_instance(n, T)
    deadlines = deadline_grid(p, tt, points)
    eng = SweepEngine()

    def one_dispatch():
        return pareto_frontier(p, tt, deadlines, engine=eng, split_regimes=False)

    def per_point():
        # the naive workflow the engine replaces: tighten, solve, and build
        # the frontier one ε-constraint point at a time
        X = np.stack(
            [
                eng.solve([tighten_for_deadline(p, tt, float(d))])[0, : p.n]
                for d in deadlines
            ]
        )
        return assemble_frontier(p, tt, deadlines, X)

    # parity enforcement (python -O must not strip it): same frontier both ways
    front = one_dispatch()
    pp_front = per_point()
    if not (
        np.array_equal(front.times, pp_front.times)
        and np.allclose(front.energies, pp_front.energies, rtol=0, atol=0)
    ):
        raise RuntimeError(
            f"batched frontier diverged from per-point frontier at "
            f"n={n} T={T} points={len(deadlines)}"
        )

    # the tentpole contract: the whole frontier is ONE engine lookup
    before = eng.cache_stats()
    one_dispatch()
    after = eng.cache_stats()
    dispatches = (after["hits"] + after["misses"]) - (before["hits"] + before["misses"])
    if dispatches != 1:
        raise RuntimeError(f"frontier consumed {dispatches} dispatches, expected 1")

    frontier_s = _bench(one_dispatch, reps)
    perpoint_s = _bench(per_point, reps)
    return eng, {
        "n": n,
        "T": T,
        "frontier_points_swept": int(len(deadlines)),
        "pareto_points": int(len(front)),
        "frontier_dispatches": int(dispatches),
        "frontier_solve_s": frontier_s,
        "perpoint_solve_s": perpoint_s,
        "speedup_frontier_vs_perpoint": perpoint_s / frontier_s,
    }


def bench_scalarizations(eng, n, T, points, reps, queries=16):
    """Info metric: answering ``queries`` weighted-sum trade-off questions
    still costs one dispatch — the scalarizations read the already-extracted
    frontier (a weighted-sum optimum always lies on the Pareto set)."""
    p, tt = make_instance(n, T, seed=23)
    deadlines = deadline_grid(p, tt, points)
    solver = Solver(engine=eng)
    weights = [(w, 1.0 - w) for w in np.linspace(0.0, 1.0, queries)]

    def scalarized():
        return solver.solve_scalarized(p, tt, weights, deadlines=deadlines)

    pts = scalarized()
    front = solver.frontier(p, tt, deadlines, split_regimes=False)
    for pt in pts:
        if not any(pt is q for q in front.points):
            # same grid -> identical point objects is not guaranteed across
            # calls; compare by value instead
            if not any(
                pt.time == q.time and pt.energy == q.energy for q in front.points
            ):
                raise RuntimeError("scalarized optimum left the Pareto frontier")
    scal_s = _bench(scalarized, reps)
    return {
        "scalarization_queries": queries,
        "scalarized_batch_s": scal_s,
        "scalarized_us_per_query": scal_s / queries * 1e6,
    }


def run_bench(smoke: bool) -> dict:
    reps = 3 if smoke else 10
    _check_exactness()
    eng, out = bench_frontier_vs_perpoint(
        n=ACCEPT_N, T=ACCEPT_T, points=ACCEPT_POINTS, reps=reps
    )
    out.update(bench_scalarizations(eng, n=ACCEPT_N, T=ACCEPT_T, points=ACCEPT_POINTS, reps=reps))
    return out


def run():
    """Harness entry point (benchmarks.run): CSV rows from one smoke pass."""
    r = run_bench(smoke=True)
    return [
        (
            f"pareto_frontier_n{r['n']}_T{r['T']}_P{r['frontier_points_swept']}",
            r["frontier_solve_s"] * 1e6,
            f"speedup_vs_perpoint={r['speedup_frontier_vs_perpoint']:.1f}x "
            f"pareto_points={r['pareto_points']}",
        ),
        (
            "pareto_scalarized",
            r["scalarized_batch_s"] * 1e6,
            f"queries={r['scalarization_queries']} one_dispatch=1",
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fewer reps for CI")
    ap.add_argument("--out", default="BENCH_pareto.json")
    args = ap.parse_args()
    result = run_bench(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
