"""Energy/time Pareto front via the deadline-constrained scheduler
(beyond-paper; the epsilon-constraint counterpart of the bi-objective work
the paper cites as [28]). Sweeps the round deadline from the fastest
feasible round to fully relaxed — the whole grid is solved by ONE batched
min-plus DP call (:func:`repro.core.deadline_sweep`, DESIGN.md §9) instead
of a per-deadline Python loop."""

import time

import numpy as np

from repro.core import deadline_sweep, random_problem, solve_schedule_dp, total_cost
from repro.core.scheduler import tighten_for_deadline


def run(n=8, T=60, points=6):
    rng = np.random.default_rng(21)
    p = random_problem(rng, n=n, T=T, regime="increasing")
    speeds = rng.uniform(0.5, 3.0, size=n)
    times = [np.arange(int(u) + 1) / s for u, s in zip(p.upper, speeds)]

    # feasible deadline range
    x_free = solve_schedule_dp(p)
    d_max = max(float(times[i][int(x_free[i])]) for i in range(p.n))
    # binary-search the minimum feasible deadline
    lo, hi = 0.0, d_max
    for _ in range(40):
        mid = (lo + hi) / 2
        try:
            tighten_for_deadline(p, times, mid)
            hi = mid
        except ValueError:
            lo = mid
    d_min = hi

    deadlines = [d_min + frac * (d_max - d_min) + 1e-9 for frac in np.linspace(0, 1, points)]
    t0 = time.perf_counter()
    X = deadline_sweep(p, times, deadlines)
    us = (time.perf_counter() - t0) / points * 1e6

    rows = []
    prev_energy = None
    for d, x in zip(deadlines, X):
        e = total_cost(p, x)
        makespan = max(float(times[i][int(x[i])]) for i in range(p.n))
        # Pareto monotonicity: relaxing the deadline never increases energy
        assert prev_energy is None or e <= prev_energy + 1e-9
        prev_energy = e
        rows.append((f"pareto_D{d:.2f}", 0.0, f"energy={e:.2f} makespan={makespan:.2f}"))
    e_free = total_cost(p, x_free)
    rows.append(
        ("pareto_summary", us,
         f"energy_range=[{e_free:.2f},{prev_energy if points else 0:.2f}] "
         f"deadline_range=[{d_min:.2f},{d_max:.2f}] batched_points={points}")
    )
    return rows
