"""Monotone fast path vs the batched DP (DESIGN.md §13).

Measures the headline claim of the marginal selection kernel at a
sweep-scale shape (B=8, n=16, T=4096): on increasing-marginal instances the
batched MarIn selection — O(B·nW·log nW) — replaces the fused O(B·n·T·W)
(MC)^2MKP program entirely. Written to ``BENCH_marginal.json``:

  * ``speedup_marginal_vs_dp`` — warm best-of-reps fused-DP solve time over
    warm marginal-path solve time at the same shape, both through
    :class:`~repro.core.sweep.SweepEngine` bucket executables (what
    production sweeps actually run). **Gated** at a hard floor of 3.0 in
    scripts/check_bench.py (floor-only — the ratio swings with box load;
    measured ~2-3 orders of magnitude on CPU since the DP does ~1000x the
    flops at this shape).
  * parity is *enforced*, not just reported: the marginal schedules must be
    bit-identical to the serial NumPy ``marin`` heap oracle on every
    instance (cost tables are float32-representable by construction, so the
    float32 kernel and float64 oracle see the same marginal order), and
    their float64 objective must match the DP objective to ~f32 precision.

Run as::

    PYTHONPATH=src python benchmarks/bench_marginal.py [--smoke] [--out PATH]
"""

import argparse
import json
import time

import numpy as np

from repro.core import Problem, SweepEngine, marin, select_algorithm_batch, total_cost

ACCEPT_B, ACCEPT_N, ACCEPT_T = 8, 16, 4096  # acceptance shape floor


def _bench(fn, reps):
    """Warm best-of-``reps`` seconds (fn must block on its own result)."""
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def make_increasing_problems(rng, B, n, T):
    """B increasing-marginal instances with integer-valued cost tables:
    integers are exact in float32 (far below 2^24 here), so the kernel's
    float32 marginals equal the float64 oracle's bit for bit AND the 1e-9
    regime tolerance survives packing — no instance silently falls back to
    the DP (the f32 rounding wobble of smooth superlinear tables would).
    The heavy integer ties also exercise the heap tie-break parity."""
    out = []
    for _ in range(B):
        # sum U ~ 2T (limits genuinely bind); W = 2T/n stays a power of two
        # so neither path pays bucket-rounding padding
        upper = np.full(n, (2 * T) // n - 1)
        tables = tuple(
            np.concatenate(
                [[0.0], np.cumsum(np.sort(rng.integers(1, 1000, size=int(u))))]
            ).astype(np.float64)
            for u in upper
        )
        out.append(
            Problem(T=T, lower=np.zeros(n, np.int64), upper=upper, cost_tables=tables)
        )
    return out


def bench_marginal_vs_dp(B, n, T, reps, check_oracle=True):
    rng = np.random.default_rng(0)
    probs = make_increasing_problems(rng, B, n, T)
    algs = set(select_algorithm_batch(probs))
    if not algs <= {"marin", "marco"}:
        raise RuntimeError(
            f"benchmark instances must dispatch to the selection kernel, got {algs}"
        )
    eng = SweepEngine()

    X_fast = eng.solve(probs, split_regimes=True)
    if check_oracle:
        # enforced, not asserted: python -O must not strip the parity gate
        for b, p in enumerate(probs):
            x_ser = marin(p)
            if not np.array_equal(X_fast[b, : p.n], x_ser):
                raise RuntimeError(
                    f"marginal fast path diverged from the serial MarIn oracle "
                    f"on instance {b} at B={B} n={n} T={T}"
                )
    X_dp = eng.solve(probs)  # split_regimes=False: the fused DP path
    gap = max(
        abs(total_cost(p, X_fast[b, : p.n]) - total_cost(p, X_dp[b, : p.n]))
        / max(1.0, total_cost(p, X_dp[b, : p.n]))
        for b, p in enumerate(probs)
    )
    if gap > 1e-5:
        raise RuntimeError(f"marginal objective diverged from DP objective: {gap}")

    # both paths warm now (buckets compiled above); time the steady state
    marginal_s = _bench(lambda: eng.solve(probs, split_regimes=True), reps)
    dp_s = _bench(lambda: eng.solve(probs), reps)
    return eng, {
        "B": B,
        "n": n,
        "T": T,
        "W": int(probs[0].upper.max()) + 1,
        "dp_solve_s": dp_s,
        "marginal_solve_s": marginal_s,
        "speedup_marginal_vs_dp": dp_s / marginal_s,
        "max_objective_gap": gap,
    }


def bench_mixed_split(eng, B, n, T, reps):
    """Info metric: a half-monotone half-arbitrary batch through the
    regime-split path vs all-DP — the realistic mixed-sweep saving (the DP
    sub-batch shrinks to the arbitrary half; asymptote ~2x here since CPU
    DP time scales with B). Runs at the acceptance shape: at toy shapes
    (T*W below ~10^6) the split's extra dispatch overhead outweighs the
    halved DP and the ratio dips below 1 — see the crossover discussion in
    DESIGN.md §13."""
    rng = np.random.default_rng(1)
    probs = make_increasing_problems(rng, B // 2, n, T)
    from repro.core import random_problem

    for _ in range(B - B // 2):
        probs.append(
            random_problem(
                rng, n=n, T=T, regime="arbitrary", max_upper=(2 * T) // n - 1, with_lower=False
            )
        )
    eng.solve(probs, split_regimes=True)  # warm the split's DP sub-bucket
    eng.solve(probs)
    split_s = _bench(lambda: eng.solve(probs, split_regimes=True), reps)
    alldp_s = _bench(lambda: eng.solve(probs), reps)
    return {
        "mixed_B": B,
        "mixed_split_solve_s": split_s,
        "mixed_alldp_solve_s": alldp_s,
        "speedup_mixed_split_vs_alldp": alldp_s / split_s,
    }


def run_bench(smoke: bool) -> dict:
    reps = 3 if smoke else 10
    eng, out = bench_marginal_vs_dp(B=ACCEPT_B, n=ACCEPT_N, T=ACCEPT_T, reps=reps)
    out.update(bench_mixed_split(eng, B=ACCEPT_B, n=ACCEPT_N, T=ACCEPT_T, reps=reps))
    return out


def run():
    """Harness entry point (benchmarks.run): CSV rows from one smoke pass."""
    r = run_bench(smoke=True)
    return [
        (
            f"marginal_fastpath_B{r['B']}_n{r['n']}_T{r['T']}",
            r["marginal_solve_s"] * 1e6,
            f"speedup_vs_dp={r['speedup_marginal_vs_dp']:.1f}x",
        ),
        (
            f"mixed_split_B{r['mixed_B']}",
            r["mixed_split_solve_s"] * 1e6,
            f"speedup_vs_alldp={r['speedup_mixed_split_vs_alldp']:.2f}x",
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fewer reps for CI")
    ap.add_argument("--out", default="BENCH_marginal.json")
    args = ap.parse_args()
    result = run_bench(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
