"""Paper Figs. 1-2: the worked example of Section 3.1, reproduced by every
algorithm applicable to it."""

import time

import numpy as np

from repro.core import Problem, schedule, solve_schedule_dp, total_cost


def paper_problem(T):
    c1 = np.array([0.0, 2, 3.5, 5.5, 8, 10, 12])
    c2 = np.array([0.0, 1.5, 2.5, 4, 7, 9, 11])
    c3 = np.array([0.0, 3, 4, 5, 6, 7])
    return Problem(T=T, lower=[1, 0, 0], upper=[6, 6, 5], cost_tables=(c1, c2, c3))


def run():
    rows = []
    for T, want_x, want_c in ((5, [2, 3, 0], 7.5), (8, [1, 2, 5], 11.5)):
        p = paper_problem(T)
        t0 = time.perf_counter()
        reps = 200
        for _ in range(reps):
            x = solve_schedule_dp(p)
        us = (time.perf_counter() - t0) / reps * 1e6
        c = total_cost(p, x)
        ok = list(x) == want_x and abs(c - want_c) < 1e-9
        rows.append((f"fig{1 if T == 5 else 2}_T{T}_dp", us, f"SigmaC={c} X={list(x)} match={ok}"))
    return rows
