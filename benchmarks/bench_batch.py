"""Batched vs looped (MC)^2MKP DP throughput (DESIGN.md §9).

A what-if sweep — ``B`` candidate workloads over one fleet — is solved two
ways:

  * ``loop``:  a Python loop of ``B`` single-instance jitted solves
    (:func:`solve_schedule_dp_jax`); every distinct ``T`` compiles its own
    program, and every instance pays packing + dispatch + device_get.
  * ``batch``: ONE :func:`solve_schedule_dp_batch` call — the instances are
    stacked ``(B, n, W)`` and the whole sweep is a single compiled program.

Reports cold (fresh jit caches, the first-sweep experience) and warm
(steady-state) timings and writes ``BENCH_batch.json`` with the headline
``speedup_vs_loop`` (cold, since a fresh sweep is the production shape of a
scenario-planning call). Run as::

    PYTHONPATH=src python benchmarks/bench_batch.py [--smoke] [--out PATH]
"""

import argparse
import json
import time

import numpy as np

from repro.core import Problem, random_problem
from repro.core.jax_dp import solve_schedule_dp_batch, solve_schedule_dp_jax


def make_sweep(rng: np.random.Generator, B: int, n: int, T: int):
    """One fleet, ``B`` distinct candidate workloads in [T/2, T]."""
    base = random_problem(rng, n=n, T=T, regime="arbitrary", with_lower=False)
    Ts = np.unique(np.linspace(max(1, T // 2), T, B).astype(int))
    while len(Ts) < B and Ts.min() > 1:  # tiny T ranges: extend downward
        Ts = np.unique(np.concatenate([[Ts.min() - 1], Ts]))
    if len(Ts) < B:  # fewer than B distinct workloads exist in [1, T]: reuse
        Ts = np.concatenate([Ts, np.resize(Ts, B - len(Ts))])
    return [
        Problem(T=int(t), lower=base.lower, upper=base.upper, cost_tables=base.cost_tables)
        for t in sorted(Ts)
    ]


def _clear_jit_caches():
    import jax

    jax.clear_caches()


def time_sweep(problems, mode: str, reps: int = 3, cold: bool = False):
    """Best-of-``reps`` wall time for one full sweep; ``cold`` clears jit
    caches before every rep so each timing includes compilation."""
    best = float("inf")
    schedules = None
    for _ in range(reps):
        if cold:
            _clear_jit_caches()
        t0 = time.perf_counter()
        if mode == "loop":
            schedules = [solve_schedule_dp_jax(p) for p in problems]
        else:
            X = solve_schedule_dp_batch(problems)
            schedules = [X[b, : p.n] for b, p in enumerate(problems)]
        best = min(best, time.perf_counter() - t0)
    return best, schedules


def run_bench(B: int, n: int, T: int, reps: int = 3) -> dict:
    rng = np.random.default_rng(0)
    problems = make_sweep(rng, B, n, T)

    loop_cold, xs_loop = time_sweep(problems, "loop", reps=1, cold=True)
    batch_cold, xs_batch = time_sweep(problems, "batch", reps=1, cold=True)
    for a, b in zip(xs_loop, xs_batch):  # same programs => identical schedules
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    loop_warm, _ = time_sweep(problems, "loop", reps=reps)
    batch_warm, _ = time_sweep(problems, "batch", reps=reps)

    return {
        "B": len(problems),
        "n": n,
        "T": T,
        "loop_cold_s": loop_cold,
        "batch_cold_s": batch_cold,
        "loop_warm_s": loop_warm,
        "batch_warm_s": batch_warm,
        "speedup_cold": loop_cold / batch_cold,
        "speedup_warm": loop_warm / batch_warm,
        # headline: a fresh sweep is how scenario planning meets the solver
        "speedup_vs_loop": loop_cold / batch_cold,
    }


def run():
    """Harness entry point (benchmarks.run): one moderate sweep."""
    r = run_bench(B=16, n=16, T=128)
    return [
        (
            f"batch_dp_B{r['B']}_T{r['T']}",
            r["batch_warm_s"] / r["B"] * 1e6,
            f"speedup_cold={r['speedup_cold']:.1f}x speedup_warm={r['speedup_warm']:.1f}x",
        )
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast config for CI")
    ap.add_argument("--out", default="BENCH_batch.json")
    ap.add_argument("--B", type=int, default=None)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--T", type=int, default=None)
    args = ap.parse_args()
    B = args.B or (16 if args.smoke else 32)
    T = args.T or (96 if args.smoke else 256)
    result = run_bench(B=B, n=args.n, T=T)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
