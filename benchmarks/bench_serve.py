"""Served-traffic benchmark: the scheduler service under a request stream
(DESIGN.md §14).

Drives a seeded Poisson arrival stream of mixed-shape, mixed-regime
scheduling requests through :class:`repro.serve.SchedulerService` and
answers three questions, written to ``BENCH_serve.json``:

  * **batching speedup** — wall time to serve N requests coalesced vs
    dispatching each alone through the same warm engine, in arrival order.
    Headline ``speedup_coalesced_vs_serial`` (CI floor: >= 2x).
  * **served throughput** — ``throughput_rps`` under saturation (requests
    submitted back-to-back), with a conservative CI floor.
  * **served latency** — p50/p99 request latency under a PACED Poisson
    stream at half the saturated service rate (info-only: latency in
    milliseconds swings with box load).

Correctness is enforced in-bench (a violation crashes the smoke, which
fails CI):

  * every coalesced result is bit-identical to solving that request alone;
  * after ``warm()`` covers the stream's buckets, steady-state serving
    performs ZERO fresh XLA tracings (``steady_state_compiles == 0``) —
    across both the saturation and the paced legs.

Run as::

    PYTHONPATH=src python benchmarks/bench_serve.py [--smoke] [--out PATH]
"""

import argparse
import json
import time

import numpy as np

# Request families: (n, T range, upper range) pinned so each family lands
# in exactly ONE pow2 bucket (the widest resource is forced to u_hi, T stays
# inside one pow2 interval, lower limits are 0) — the hot-bucket traffic
# shape the warm() API is for. Regimes cycle per request, so streams mix
# DP-regime and monotone-cost instances.
FAMILIES = (
    dict(n=8, T_lo=65, T_hi=128, u_lo=16, u_hi=31),  # bucket (8, 128, 32)
    dict(n=16, T_lo=33, T_hi=64, u_lo=4, u_hi=15),  # bucket (16, 64, 16)
    dict(n=4, T_lo=65, T_hi=128, u_lo=32, u_hi=63),  # bucket (4, 128, 64)
)
REGIMES = ("arbitrary", "linear", "increasing", "decreasing")


def _family_problem(rng, fam, regime):
    from repro.core import Problem
    from repro.core.costs import (
        linear_cost,
        measured_cost,
        sublinear_cost,
        superlinear_cost,
    )

    n = fam["n"]
    upper = rng.integers(fam["u_lo"], fam["u_hi"] + 1, size=n)
    upper[0] = fam["u_hi"]  # pin the table width -> one W bucket per family
    T = int(min(rng.integers(fam["T_lo"], fam["T_hi"] + 1), upper.sum()))
    tables = []
    for u in (int(v) for v in upper):
        if regime == "arbitrary":
            tables.append(measured_cost(u, rng))
        elif regime == "linear":
            tables.append(linear_cost(u, float(rng.uniform(0.2, 5.0))))
        elif regime == "increasing":
            tables.append(superlinear_cost(u, float(rng.uniform(0.2, 3.0)), float(rng.uniform(0.01, 0.6))))
        else:
            tables.append(sublinear_cost(u, float(rng.uniform(5.0, 40.0)), float(rng.uniform(2.0, 20.0))))
    return Problem(T=T, lower=np.zeros(n, dtype=np.int64), upper=upper, cost_tables=tuple(tables))


def make_requests(rng, N):
    out = []
    for i in range(N):
        fam = FAMILIES[int(rng.integers(len(FAMILIES)))]
        out.append(_family_problem(rng, fam, REGIMES[i % len(REGIMES)]))
    return out


def run_bench(N: int, max_batch: int, max_delay_s: float, seed: int = 0) -> dict:
    from repro.core import ProblemBatch, SweepEngine
    from repro.core.sweep import request_bucket
    from repro.serve import SchedulerService

    rng = np.random.default_rng(seed)
    requests = make_requests(rng, N)
    batches = [ProblemBatch.from_problems([p]) for p in requests]
    buckets = sorted(set(request_bucket(b) for b in batches))

    engine = SweepEngine()
    service = SchedulerService(
        engine=engine, max_batch=max_batch, max_delay_s=max_delay_s, max_pending=4 * N
    )
    t0 = time.perf_counter()
    warm_traces = service.warm(buckets)
    warm_s = time.perf_counter() - t0

    # ---- serial baseline: one request, one dispatch, in arrival order ----
    compiles0 = engine.cache_stats()["compiles"]
    t0 = time.perf_counter()
    X_serial = [engine.dispatch(b).result()[0] for b in batches]
    serial_total_s = time.perf_counter() - t0

    # ---- saturation leg: everything submitted back-to-back ---------------
    t0 = time.perf_counter()
    futs = [service.submit(b) for b in batches]
    X_served = [f.result(timeout=120) for f in futs]
    coalesced_total_s = time.perf_counter() - t0
    sat_stats = service.stats()

    for i, (xs, xc) in enumerate(zip(X_serial, X_served)):
        assert np.array_equal(xs, xc[0]), f"request {i}: coalesced != solved-alone"

    # ---- paced leg: Poisson arrivals at half the saturated rate ----------
    sat_rps = N / coalesced_total_s
    rate_hz = max(sat_rps / 2.0, 1.0)
    gaps = rng.exponential(1.0 / rate_hz, size=N)
    t0 = time.perf_counter()
    paced = []
    for b, gap in zip(batches, gaps):
        time.sleep(gap)
        paced.append(service.submit(b))
    for f in paced:
        f.result(timeout=120)
    paced_total_s = time.perf_counter() - t0
    lat_ms = np.array(
        [(f.completed_at - f.submitted_at) * 1e3 for f in paced], dtype=np.float64
    )

    steady_compiles = engine.cache_stats()["compiles"] - compiles0
    assert steady_compiles == 0, (
        f"{steady_compiles} cold XLA traces during steady-state serving "
        f"(warm() should have covered every bucket)"
    )
    stats = service.stats()
    service.close()

    return {
        "requests": N,
        "buckets": len(buckets),
        "max_batch": max_batch,
        "max_delay_ms": max_delay_s * 1e3,
        "warm_traces": warm_traces,
        "warm_s": warm_s,
        "serial_total_s": serial_total_s,
        "coalesced_total_s": coalesced_total_s,
        "speedup_coalesced_vs_serial": serial_total_s / coalesced_total_s,
        "throughput_rps": sat_rps,
        "steady_state_compiles": steady_compiles,
        # check_bench floors are minimums; the zero-cold-trace ceiling is
        # gated as a floor on the negated count (any compile -> negative)
        "steady_state_compiles_negated": -steady_compiles,
        "flushes": stats["flushes"],
        "mean_flush_rows_saturated": (
            sat_stats["flushed_rows"] / sat_stats["flushes"] if sat_stats["flushes"] else 0.0
        ),
        "mean_flush_rows": stats["mean_flush_rows"],
        "paced": {
            "arrival_rate_hz": rate_hz,
            "total_s": paced_total_s,
            "latency_p50_ms": float(np.percentile(lat_ms, 50)),
            "latency_p99_ms": float(np.percentile(lat_ms, 99)),
        },
    }


def run():
    """Harness entry point (benchmarks.run): a short saturated stream."""
    r = run_bench(N=120, max_batch=16, max_delay_s=0.002)
    return [
        (
            f"serve_coalesced_N{r['requests']}",
            r["coalesced_total_s"] / r["requests"] * 1e6,
            f"speedup_vs_serial={r['speedup_coalesced_vs_serial']:.1f}x",
        )
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast config for CI")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--N", type=int, default=None, help="requests in the stream")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    args = ap.parse_args()

    N = args.N or (200 if args.smoke else 600)
    result = run_bench(N=N, max_batch=args.max_batch, max_delay_s=args.max_delay_ms / 1e3)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
