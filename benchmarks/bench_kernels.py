"""Blocked min-plus kernel engine vs the dense oracle (DESIGN.md §12).

Two production claims are measured and written to ``BENCH_kernels.json``:

  * **blocked vs dense** — one warm batched DP row update at a memory-bound
    shape (B=8, T=8192, W=512: the oracle materializes a ~134 MB candidate
    tensor; the blocked backend streams BT x BW cache-resident blocks).
    ``speedup_blocked_vs_dense`` is the gated headline (hard floor 2.0 in
    scripts/check_bench.py; ~4-8x measured on CPU), and the same run
    asserts bit-identical values AND argmins (``max_parity_err`` must be
    exactly 0).
  * **fused vs two-dispatch** — a warm batched solve through the fused
    DP+backtrack program (one jit call returning only ``(B, n)`` + K_last)
    against the legacy chain of ``dp_tables_batch_jax`` +
    ``backtrack_batch_jax`` (two dispatches, argmin matrix crossing the
    boundary). ``speedup_fused_vs_twodispatch`` is reported info-only —
    on small solves it hovers near 1x and swings with machine load.

Run as::

    PYTHONPATH=src python benchmarks/bench_kernels.py [--smoke] [--out PATH]

(The interpret-mode Pallas TPU/GPU kernels are validated in the test
suite, not timed here — Python-interpreted kernel timing says nothing
about hardware. The blocked jnp backend IS the CPU production path.)
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import random_problem
from repro.core.jax_dp import (
    backtrack_batch_jax,
    dp_tables_batch_jax,
    pack_problem,
    solve_fused_batch_jax,
)
from repro.core.problem import ProblemBatch, remove_lower_limits
from repro.kernels import minplus_blocked_batch, minplus_step_ref_batch


def _bench(fn, reps):
    """Warm best-of-``reps`` seconds (fn must block on its own result)."""
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_blocked_vs_dense(B: int, Tp: int, W: int, reps: int) -> dict:
    rng = np.random.default_rng(0)
    kprev = rng.uniform(0, 100, (B, Tp)).astype(np.float32)
    kprev[:, 0] = 0.0
    cost = rng.uniform(0, 10, (B, W)).astype(np.float32)
    k, c = jnp.asarray(kprev), jnp.asarray(cost)

    dense = jax.jit(minplus_step_ref_batch)
    blocked = jax.jit(lambda a, b: minplus_blocked_batch(a, b))

    dv, di = dense(k, c)
    bv, bi = blocked(k, c)
    err = float(np.max(np.abs(np.asarray(dv) - np.asarray(bv))))
    idx_mismatch = int(np.sum(np.asarray(di) != np.asarray(bi)))
    # enforced here, not just reported: the property suite stops at small
    # shapes, so this is the only parity check at the production shape
    # (a real raise, not `assert` — python -O must not strip it)
    if err != 0.0 or idx_mismatch != 0:
        raise RuntimeError(
            f"blocked kernel diverged from oracle at B={B} T={Tp - 1} W={W}: "
            f"maxerr={err}, argmin mismatches={idx_mismatch}"
        )

    dense_s = _bench(lambda: dense(k, c)[0].block_until_ready(), reps)
    blocked_s = _bench(lambda: blocked(k, c)[0].block_until_ready(), reps)
    return {
        "B": B,
        "T": Tp - 1,
        "W": W,
        "dense_step_s": dense_s,
        "blocked_step_s": blocked_s,
        "speedup_blocked_vs_dense": dense_s / blocked_s,
        "max_parity_err": err,
        "argmin_mismatches": idx_mismatch,
    }


def bench_fused_vs_twodispatch(B: int, n: int, T: int, reps: int) -> dict:
    rng = np.random.default_rng(1)
    probs = [
        random_problem(rng, n=n, T=int(t), regime="arbitrary", with_lower=False)
        for t in np.linspace(max(1, T // 2), T, B).astype(int)
    ]
    b0 = remove_lower_limits(ProblemBatch.from_problems(probs))
    costs = pack_problem(b0)
    Tmax = int(b0.T.max())
    t_star = jnp.asarray(b0.T, dtype=jnp.int32)

    def fused():
        X, _ = solve_fused_batch_jax(costs, t_star, Tmax, backend="blocked")
        return np.asarray(jax.device_get(X))

    def twodispatch():
        _, I = dp_tables_batch_jax(costs, Tmax, backend="blocked")
        return np.asarray(jax.device_get(backtrack_batch_jax(I, t_star, Tmax)))

    np.testing.assert_array_equal(fused(), twodispatch())
    fused_s = _bench(fused, reps)
    two_s = _bench(twodispatch, reps)
    return {
        "solve_B": B,
        "solve_n": n,
        "solve_T": T,
        "fused_solve_s": fused_s,
        "twodispatch_solve_s": two_s,
        "speedup_fused_vs_twodispatch": two_s / fused_s,
    }


def run_bench(smoke: bool) -> dict:
    # the acceptance shape: memory-bound for the oracle on any CPU
    reps = 3 if smoke else 10
    out = bench_blocked_vs_dense(B=8, Tp=8193, W=512, reps=reps)
    out.update(bench_fused_vs_twodispatch(B=16, n=8, T=256 if smoke else 1024, reps=reps))
    return out


def run():
    """Harness entry point (benchmarks.run): CSV rows from one smoke pass."""
    r = run_bench(smoke=True)
    return [
        (
            f"minplus_blocked_B{r['B']}_T{r['T']}_W{r['W']}",
            r["blocked_step_s"] * 1e6,
            f"speedup_vs_dense={r['speedup_blocked_vs_dense']:.1f}x "
            f"maxerr={r['max_parity_err']:.1e}",
        ),
        (
            f"fused_solve_B{r['solve_B']}_T{r['solve_T']}",
            r["fused_solve_s"] * 1e6,
            f"speedup_vs_twodispatch={r['speedup_fused_vs_twodispatch']:.2f}x",
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fewer reps for CI")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    result = run_bench(smoke=args.smoke)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
