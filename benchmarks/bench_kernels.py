"""Min-plus Pallas kernel: correctness vs the jnp oracle + host-side timing
of the oracle path (interpret-mode kernel timing is not meaningful — the
kernel targets TPU; this validates and times the production jnp fallback)."""

import time

import jax
import numpy as np

from repro.kernels import minplus_pallas, minplus_step_ref


def run():
    rows = []
    rng = np.random.default_rng(0)
    for Tp, W in ((1024, 256), (4096, 1024)):
        kprev = rng.uniform(0, 100, Tp).astype(np.float32)
        cost = rng.uniform(0, 10, W).astype(np.float32)
        ref_v, _ = minplus_step_ref(kprev, cost)
        pal_v, _ = minplus_pallas(kprev, cost, interpret=True)
        err = float(np.max(np.abs(np.asarray(ref_v) - np.asarray(pal_v))))
        f = jax.jit(minplus_step_ref)
        f(kprev, cost)[0].block_until_ready()
        t0 = time.perf_counter()
        reps = 20
        for _ in range(reps):
            f(kprev, cost)[0].block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append((f"minplus_T{Tp}_W{W}", us, f"pallas_vs_ref_maxerr={err:.1e}"))
    return rows
