"""Optimality comparison (paper Theorems 1-5): mean total cost of every
algorithm vs the DP optimum over random instance distributions, per regime.
The paper has no experimental table — this substantiates the optimality
claims empirically and quantifies how much the baselines lose."""

import time

import numpy as np

from repro.core import (
    ALGORITHMS,
    random_problem,
    schedule,
    solve_schedule_dp,
    total_cost,
)

REGIMES = ("arbitrary", "increasing", "linear", "decreasing")
ALGS_BY_REGIME = {
    "arbitrary": ("dp", "dp_jax", "olar", "uniform", "proportional", "greedy_marginal"),
    "increasing": ("dp", "marin", "olar", "uniform", "proportional"),
    "linear": ("dp", "marco", "marin", "olar", "uniform", "proportional"),
    "decreasing": ("dp", "mardec", "olar", "uniform", "proportional", "greedy_marginal"),
}


def run(n_instances=40, n=8, T=60):
    rng = np.random.default_rng(0)
    rows = []
    for regime in REGIMES:
        problems = [random_problem(rng, n=n, T=T, regime=regime) for _ in range(n_instances)]
        opt = np.array([total_cost(p, solve_schedule_dp(p)) for p in problems])
        for alg in ALGS_BY_REGIME[regime]:
            t0 = time.perf_counter()
            costs = np.array([total_cost(p, schedule(p, alg)) for p in problems])
            us = (time.perf_counter() - t0) / n_instances * 1e6
            ratio = float(np.mean(costs / opt))
            rows.append((f"optimality_{regime}_{alg}", us, f"cost_vs_opt={ratio:.4f}"))
    return rows
