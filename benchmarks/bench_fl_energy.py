"""Simulated FL campaign energy: the paper's motivating metric. Total Joules
across a multi-round campaign for the optimal scheduler vs baselines, on a
heterogeneous device fleet (superlinear phones + linear laptops + sublinear
edge accelerators)."""

import time

import jax
import numpy as np

from repro.data import client_corpora, make_lm_examples
from repro.fl import EnergyEstimator, FederatedServer, make_fleet, run_campaign
from repro.fl.toy import make_tiny_lm
from repro.optim import sgd

VOCAB, DIM, SEQ = 64, 16, 8

tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)


def run(n_clients=8, rounds=5):
    rows = []
    energies = {}
    for alg in ("auto", "olar", "uniform", "proportional"):
        rng = np.random.default_rng(11)
        fleet = make_fleet(rng, n_clients, max_batches=12)
        est = EnergyEstimator(fleet)
        est.calibrate(rng)
        corpora = client_corpora(rng, n_clients, 400, VOCAB)
        examples = [make_lm_examples(c, SEQ) for c in corpora]
        server = FederatedServer(
            loss_fn=tiny_lm_loss,
            init_params=tiny_lm_init(jax.random.PRNGKey(0)),
            client_optimizer=sgd(0.3),
            estimator=est,
            algorithm=alg,
        )
        T = sum(d.max_batches for d in fleet) // 2
        t0 = time.perf_counter()
        hist = run_campaign(server, examples, rounds, round_T=T, batch_size=4, rng=rng)
        us = (time.perf_counter() - t0) / rounds * 1e6
        energies[alg] = hist.total_energy
        rows.append(
            (
                f"fl_energy_{alg}",
                us,
                f"total_J={hist.total_energy:.1f} final_loss={hist.rounds[-1].mean_loss:.3f}",
            )
        )
    saving = 100 * (1 - energies["auto"] / energies["uniform"])
    rows.append(("fl_energy_saving_vs_uniform", 0.0, f"saving={saving:.1f}%"))
    return rows
