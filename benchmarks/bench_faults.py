"""Fault-tolerant campaign runtime benchmark (DESIGN.md §17).

Runs seeded chaos campaigns through the fault-injection harness and answers
three questions, written to ``BENCH_faults.json``:

  * **recovery exactness** (headline, CI floor == 1.0 via
    scripts/check_bench.py): the fraction of recovered rounds whose
    re-planned residual assignment is bit-identical to an INDEPENDENT
    fault-free solve of the carried residual instance. Anything below 1.0
    means mid-round recovery is not the exact solve it claims to be.
  * **reactive re-plan overhead** (CI ceiling <= 15%): estimated Joules of
    the reactive recovered round vs a clairvoyant ORACLE that knew the
    faults in advance (same deliverable capacities, one solve). The gap is
    the price of recovering after the fact instead of planning around the
    failure — small because the residual instance is exact under the
    paper's atomic-task model.
  * **resilient serving**: the same chaos campaign driven through a
    :class:`~repro.serve.SchedulerService` over a persistently flaky engine
    with retry + circuit breaker + injected overload bursts — completes,
    recovers, and reports the service's retry/degraded telemetry.

Correctness is enforced in-bench (a violation crashes the smoke, which
fails CI): recovery bit-identity per recovered round, serial == pipelined
chaos histories (client-fault plans are data, not runtime randomness), and
every campaign finishing all its rounds.

Run as::

    PYTHONPATH=src python benchmarks/bench_faults.py [--smoke] [--out PATH]
"""

import argparse
import json
import time

VOCAB, DIM, SEQ = 256, 64, 16


def build_campaign(seed: int, n_clients: int, max_batches: int, engine=None, service=None):
    """A fresh (server, examples, rng, T) tuple; same seed => same campaign,
    so every leg consumes identical inputs."""
    import jax
    import numpy as np

    from repro.core.sweep import SweepEngine
    from repro.data import client_corpora, make_lm_examples
    from repro.fl import EnergyEstimator, FederatedServer, PlanPolicy, make_fleet
    from repro.fl.toy import make_tiny_lm
    from repro.optim import sgd

    tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n_clients, max_batches=max_batches)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, n_clients, 4000, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    T = sum(d.max_batches for d in fleet) // 2
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(1)),
        client_optimizer=sgd(0.3),
        estimator=est,
        policy=PlanPolicy(
            engine=engine if engine is not None else SweepEngine(),
            service=service,
        ),
    )
    return server, examples, rng, T


def _oracle_problem(ri):
    """The clairvoyant instance: a scheduler that knew the round's faults in
    advance plans once over the DELIVERABLE capacities — faulted clients cap
    at what they actually banked, survivors keep their full range — for the
    same effective workload the reactive path ended up scheduling."""
    import numpy as np

    from repro.core import Problem

    p = ri.problem
    faulty = set(ri.failed_clients) | set(ri.straggler_clients)
    cap = np.array(
        [
            int(ri.completed[i]) if i in faulty else int(p.upper[i])
            for i in range(p.n)
        ],
        dtype=np.int64,
    )
    T_eff = int(ri.completed.sum()) + int(ri.recovery_assignments.sum())
    return Problem(
        T=T_eff,
        lower=np.minimum(p.lower, cap),
        upper=cap,
        cost_tables=tuple(p.cost_tables[i][: int(cap[i]) + 1] for i in range(p.n)),
    )


def _audit_recoveries(history, solver):
    """Per recovered round: bit-identity of the recovery solve vs an
    independent re-solve, and reactive-vs-oracle overhead on the
    planning-time tables. Returns (n_recovered, n_exact, n_fallback,
    overhead_pcts)."""
    import numpy as np

    from repro.core import total_cost

    n_rec = n_exact = n_fb = 0
    overheads = []
    for r in history.rounds:
        ri = r.recovery
        if ri is None:
            continue
        n_rec += 1
        if ri.fallback:
            n_fb += 1
        y_ref = np.asarray(solver.solve([ri.residual_problem]).schedules[0], np.int64)
        if np.array_equal(ri.recovery_assignments, y_ref):
            n_exact += 1
        oracle = _oracle_problem(ri)
        x_oracle = np.asarray(solver.solve([oracle]).schedules[0], np.int64)
        oracle_J = float(total_cost(oracle, x_oracle))
        reactive_J = float(total_cost(ri.problem, ri.completed + ri.recovery_assignments))
        overheads.append(100.0 * max(0.0, reactive_J - oracle_J) / oracle_J)
    return n_rec, n_exact, n_fb, overheads


def run_bench(rounds: int, n_clients: int = 8, max_batches: int = 48, batch_size: int = 8, seed: int = 0) -> dict:
    import numpy as np

    from repro.core import CircuitBreaker, RetryPolicy, Solver
    from repro.core.sweep import SweepEngine
    from repro.fl import FaultInjector, FaultPlan, run_campaign
    from repro.serve import SchedulerService

    # client-fault-only plan for the serial==pipelined legs: engine-fault
    # ordinals race across the planner thread, client faults are plan data
    plan = FaultPlan.generate(
        seed=seed + 100,
        num_rounds=rounds,
        n_clients=n_clients,
        p_crash=0.25,
        p_straggle=0.2,
    )

    server_s, examples, rng, T = build_campaign(seed, n_clients, max_batches)
    t0 = time.perf_counter()
    h_serial = run_campaign(
        server_s, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        faults=plan,
    )
    serial_s = time.perf_counter() - t0

    server_p, examples, rng, _ = build_campaign(seed, n_clients, max_batches)
    t0 = time.perf_counter()
    h_pipe = run_campaign(
        server_p, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        faults=plan, pipelined=True,
    )
    pipelined_s = time.perf_counter() - t0

    # chaos must not break determinism (DESIGN.md §17)
    np.testing.assert_array_equal(h_serial.losses, h_pipe.losses)
    assert h_serial.total_energy == h_pipe.total_energy
    assert len(h_serial.rounds) == rounds

    auditor = Solver(engine=SweepEngine())
    n_rec, n_exact, n_fb, overheads = _audit_recoveries(h_serial, auditor)
    assert n_rec > 0, "chaos plan produced no recoveries — raise the fault rates"
    assert n_exact == n_rec, (
        f"{n_rec - n_exact} recovered rounds diverge from the independent "
        f"fault-free residual re-solve (recovery must be exact)"
    )

    # ---- resilient serving leg: flaky engine + retry + breaker + bursts --
    fail_every = 7  # persistent enough to trip retries AND the breaker
    flaky_plan = FaultPlan.generate(
        seed=seed + 200,
        num_rounds=rounds,
        n_clients=n_clients,
        p_crash=0.25,
        p_straggle=0.2,
        p_burst=0.5,
        burst_size=4,
    )
    from repro.fl.faults import FlakyEngine

    flaky = FlakyEngine(
        SweepEngine(), fail_ordinals=range(0, 64 * rounds, fail_every)
    )
    service = SchedulerService(
        engine=flaky,
        max_delay_s=0.002,
        retry=RetryPolicy(max_attempts=3),
        breaker=CircuitBreaker(failure_threshold=3, cooldown_s=0.05),
    )
    injector = FaultInjector(flaky_plan)
    server_v, examples, rng, _ = build_campaign(
        seed, n_clients, max_batches, engine=flaky, service=service
    )
    t0 = time.perf_counter()
    h_served = run_campaign(
        server_v, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        faults=injector,
    )
    served_s = time.perf_counter() - t0
    svc_stats = service.stats()
    service.close()
    assert len(h_served.rounds) == rounds, "served chaos campaign did not finish"
    v_rec, v_exact, v_fb, v_over = _audit_recoveries(h_served, auditor)

    total_rec = n_rec + v_rec
    total_exact = n_exact + v_exact
    all_over = overheads + v_over
    summary = h_serial.summary()
    out = {
        "rounds": rounds,
        "n_clients": n_clients,
        "round_T": int(T),
        "client_faults_planned": len(plan.client_faults),
        "recovered_rounds": n_rec,
        "fallback_rounds": n_fb,
        "recovery_success_rate": total_exact / total_rec,
        "replan_overhead_pct": float(np.mean(all_over)) if all_over else 0.0,
        "replan_overhead_pct_max": float(np.max(all_over)) if all_over else 0.0,
        "recovery_overhead_J": summary.get("recovery_overhead_J", 0.0),
        "serial_total_s": serial_s,
        "pipelined_total_s": pipelined_s,
        "served": {
            "total_s": served_s,
            "recovered_rounds": v_rec,
            "fallback_rounds": v_fb,
            "engine_faults_injected": flaky.fault_stats()["injected_failures"],
            "retries": svc_stats["retries"],
            "flush_failures": svc_stats["flush_failures"],
            "degraded_flushes": svc_stats["degraded_flushes"],
            "degraded_rows": svc_stats["degraded_rows"],
            "breaker": svc_stats["breaker"],
        },
    }
    return out


def run():
    """Harness entry point (benchmarks.run): a short chaos campaign."""
    r = run_bench(rounds=4, n_clients=6, max_batches=32, batch_size=4)
    return [
        (
            f"faults_recovery_x{r['recovered_rounds']}",
            r["serial_total_s"] / r["rounds"] * 1e3,
            f"overhead={r['replan_overhead_pct']:.2f}% "
            f"success={r['recovery_success_rate']:.0%}",
        )
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast config for CI")
    ap.add_argument("--out", default="BENCH_faults.json")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()

    rounds = args.rounds or (5 if args.smoke else 10)
    n_clients = 6 if args.smoke else 10
    result = run_bench(rounds=rounds, n_clients=n_clients)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
