"""Benchmark harness — one module per paper table/figure (see DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV. Run as:
    PYTHONPATH=src python -m benchmarks.run [--only example,kernels,...]
"""

import argparse
import sys

MODULES = ("example", "optimality", "runtime", "batch", "sweep", "async", "fl_energy", "pareto", "kernels", "marginal", "roofline", "serve", "fleet", "faults", "adaptive")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    which = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failed = []
    for name in which:
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.2f},{derived}")
            sys.stdout.flush()
        except Exception as e:  # keep the harness going; report at the end
            failed.append((name, repr(e)))
            print(f"bench_{name}_FAILED,0.00,{e!r}")
    if failed:
        raise SystemExit(f"benchmarks failed: {[n for n, _ in failed]}")


if __name__ == "__main__":
    main()
