"""Adaptive planning under drift benchmark (DESIGN.md §18).

Runs drifting, chaotic campaigns through the adaptive layer and answers
four questions, written to ``BENCH_adaptive.json``:

  * **determinism under drift** (in-bench assert): a serial and a pipelined
    campaign under the FULL adaptive policy (speculative lookahead + drift
    detection + watermark + reliability) with seeded drift AND seeded client
    chaos produce bit-identical params, losses, and energy accounting.
  * **speculation economics** (headline, CI floor via check_bench): on a
    stationary fleet every speculative round validates in-band and commits
    with ZERO extra engine dispatches — exactly ``ceil(R / k)`` solver
    batches for an R-round lookahead-k campaign (asserted on the engine's
    own dispatch counters). Under mild seeded drift the committed fraction
    is the ``speculation_hit_rate`` headline.
  * **energy regret vs a clairvoyant oracle** (CI ceiling): a mid-campaign
    regime flip (two busy clients get 2.5x costlier) while the online
    calibrator re-plans from drifting estimates. Regret = extra TRUE Joules
    vs an oracle that plans every round on the true drifted tables. The
    calibrated planner must stay within the ceiling; the frozen-estimator
    baseline (the pre-PR-10 planner under the same drift) must exceed it —
    asserted in-bench, so the gap the adaptive layer closes is a promise,
    not a hope.
  * **barrier-wait reduction** (reported): straggler-heavy chaos where the
    mid-round watermark dispatches recovery BEFORE the barrier; recovered
    assignments stay bit-identical to the reactive path (asserted) and the
    overlap is reported as ``barrier_wait_saved_pct``.

Run as::

    PYTHONPATH=src python benchmarks/bench_adaptive.py [--smoke] [--out PATH]
"""

import argparse
import json
import math
import time

VOCAB, DIM, SEQ = 256, 64, 16

# ISSUE 10 acceptance: the calibrated planner's energy regret vs the
# clairvoyant oracle stays under this ceiling (scripts/check_bench.py gates
# it) while the frozen-estimator baseline EXCEEDS it under the same regime
# flip — both asserted in-bench as well, so the smoke crashes if the
# adaptive layer stops earning its keep. Measured (deterministic seeds):
# 14.1% vs 23.9% frozen at the 6-round smoke shape, 4.2% vs 28.6% at 12.
REGRET_CEILING_PCT = 20.0


def build_campaign(seed: int, n_clients: int, max_batches: int, engine=None,
                   policy_kwargs=None, estimator_kwargs=None, classes=None):
    """A fresh (server, examples, rng, T) tuple; same seed => same campaign,
    so every leg consumes identical inputs."""
    import jax
    import numpy as np

    from repro.core.sweep import SweepEngine
    from repro.data import client_corpora, make_lm_examples
    from repro.fl import EnergyEstimator, FederatedServer, PlanPolicy, make_fleet
    from repro.fl.toy import make_tiny_lm
    from repro.optim import sgd

    tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n_clients, classes=classes, max_batches=max_batches)
    est = EnergyEstimator(fleet, **(estimator_kwargs or {}))
    est.calibrate(rng)
    corpora = client_corpora(rng, n_clients, 4000, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    T = sum(d.max_batches for d in fleet) // 2
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(1)),
        client_optimizer=sgd(0.3),
        estimator=est,
        policy=PlanPolicy(
            engine=engine if engine is not None else SweepEngine(),
            **(policy_kwargs or {}),
        ),
    )
    return server, examples, rng, T


def _oracle_energy(seed: int, n_clients: int, max_batches: int, rounds: int,
                   drift, classes=None) -> float:
    """Total TRUE Joules of a clairvoyant planner: for each round, apply the
    drift and solve the TRUE (drifted) tables — all rounds as ONE batch."""
    from repro.core import Solver, total_cost
    from repro.core.sweep import SweepEngine
    from repro.fl import DriftInjector

    server, _, _, T = build_campaign(seed, n_clients, max_batches, classes=classes)
    injector = DriftInjector(drift)
    problems = []
    for r in range(rounds):
        injector.apply(r, server.estimator.fleet)
        problems.append(server.estimator.true_problem(T))
    batch = Solver(engine=SweepEngine()).solve(problems, check=False)
    return sum(
        float(total_cost(p, x)) for p, x in zip(problems, batch.schedules)
    )


def _assert_bit_identical(h_a, h_b, tag: str):
    import numpy as np

    assert len(h_a.rounds) == len(h_b.rounds), tag
    for ra, rb in zip(h_a.rounds, h_b.rounds):
        np.testing.assert_array_equal(ra.assignments, rb.assignments, err_msg=tag)
        assert ra.mean_loss == rb.mean_loss, tag
        assert ra.energy_joules == rb.energy_joules, tag
    np.testing.assert_array_equal(h_a.losses, h_b.losses, err_msg=tag)
    assert h_a.total_energy == h_b.total_energy, tag


def run_bench(rounds: int, n_clients: int = 8, max_batches: int = 48,
              batch_size: int = 8, seed: int = 0, lookahead: int = 3) -> dict:
    import numpy as np

    from repro.core.sweep import SweepEngine
    from repro.fl import DriftPlan, FaultPlan, run_campaign

    adaptive_policy = dict(
        lookahead=lookahead, drift_tolerance=0.1,
        watermark_quantile=0.5, reliability=0.25,
    )

    # ---- leg 1: serial == pipelined under drift + chaos ------------------
    drift = DriftPlan.generate(seed=seed + 50, num_rounds=rounds,
                               n_clients=n_clients, p_event=0.3)
    chaos = FaultPlan.generate(seed=seed + 100, num_rounds=rounds,
                               n_clients=n_clients, p_crash=0.25, p_straggle=0.2)
    server_s, examples, rng, T = build_campaign(
        seed, n_clients, max_batches, policy_kwargs=adaptive_policy
    )
    t0 = time.perf_counter()
    h_serial = run_campaign(
        server_s, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        faults=chaos, drift=drift,
    )
    serial_s = time.perf_counter() - t0

    server_p, examples, rng, _ = build_campaign(
        seed, n_clients, max_batches, policy_kwargs=adaptive_policy
    )
    t0 = time.perf_counter()
    h_pipe = run_campaign(
        server_p, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        faults=chaos, drift=drift, pipelined=True,
    )
    pipelined_s = time.perf_counter() - t0
    _assert_bit_identical(h_serial, h_pipe, "serial vs pipelined under drift+chaos")
    import jax

    for x, y in zip(jax.tree_util.tree_leaves(server_s.params),
                    jax.tree_util.tree_leaves(server_p.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert h_serial.adaptive_stats == h_pipe.adaptive_stats

    # ---- leg 2: speculation economics ------------------------------------
    # stationary world: EVERY speculative round must commit, and the engine's
    # own dispatch counters must show exactly ceil(R / k) solver batches
    engine = SweepEngine()
    server_st, examples, rng, _ = build_campaign(
        seed, n_clients, max_batches, engine=engine,
        policy_kwargs=dict(lookahead=lookahead),
    )
    before = engine.cache_stats()
    h_st = run_campaign(
        server_st, examples, rounds, round_T=T, batch_size=batch_size, rng=rng
    )
    after = engine.cache_stats()
    dispatches = (after["hits"] + after["misses"]) - (before["hits"] + before["misses"])
    expected = math.ceil(rounds / lookahead)
    assert dispatches == expected, (
        f"stationary lookahead-{lookahead} campaign dispatched {dispatches} "
        f"solver batches, expected exactly {expected} (speculation must add "
        f"ZERO extra solves when every round validates in-band)"
    )
    st_stats = h_st.adaptive_stats
    assert st_stats["speculation_hit_rate"] == 1.0, st_stats

    # mild seeded drift: the headline hit rate (floored by check_bench)
    mild = DriftPlan.generate(seed=seed + 60, num_rounds=rounds,
                              n_clients=n_clients, walk_sigma=0.01, p_event=0.0)
    server_m, examples, rng, _ = build_campaign(
        seed, n_clients, max_batches, policy_kwargs=dict(lookahead=lookahead)
    )
    h_mild = run_campaign(
        server_m, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        drift=mild,
    )
    mild_stats = h_mild.adaptive_stats

    # ---- leg 3: energy regret vs the clairvoyant oracle ------------------
    # Two-class linear fleet (tablet 2.2 J/batch, laptop 1.2 J/batch): the
    # cheap laptops carry the work until the regime flip makes the two
    # busiest of them 2.5x costlier (3.0 > 2.2) — the true optimum then
    # shifts their load onto tablets. A fleet with no viable alternatives
    # would hide the baseline's misallocation entirely.
    regret_classes = ("tablet", "laptop")
    server_probe, _, _, regret_T = build_campaign(
        seed, n_clients, max_batches, classes=regret_classes
    )
    x0 = np.asarray(
        server_probe.plan_round(
            0, regret_T, server_probe.build_problem(regret_T)
        ).assignments
    )
    victims = tuple(int(i) for i in np.argsort(x0)[-2:])
    flip_round = rounds // 2
    step = DriftPlan.step(num_rounds=rounds, n_clients=n_clients,
                          round_index=flip_round, clients=victims, factor=2.5)
    # a wider huber band lets the calibrator chase the 2.5x jump in a few
    # rounds (one observation per client per round); robustness vs agility
    # is a knob, and this leg measures the agile end
    agile = dict(huber_delta=0.75)
    server_ad, examples, rng, _ = build_campaign(
        seed, n_clients, max_batches, estimator_kwargs=agile,
        policy_kwargs=dict(lookahead=lookahead), classes=regret_classes,
    )
    h_ad = run_campaign(
        server_ad, examples, rounds, round_T=regret_T, batch_size=batch_size,
        rng=rng, drift=step,
    )
    # the frozen baseline: ema=0 pins every table at its calibration-time
    # values — exactly the pre-adaptive planner living through the same flip
    server_fz, examples, rng, _ = build_campaign(
        seed, n_clients, max_batches, estimator_kwargs=dict(ema=0.0),
        classes=regret_classes,
    )
    h_fz = run_campaign(
        server_fz, examples, rounds, round_T=regret_T, batch_size=batch_size,
        rng=rng, drift=step,
    )
    oracle_J = _oracle_energy(
        seed, n_clients, max_batches, rounds, step, classes=regret_classes
    )
    regret_ad = 100.0 * (h_ad.total_energy - oracle_J) / oracle_J
    regret_fz = 100.0 * (h_fz.total_energy - oracle_J) / oracle_J
    assert regret_ad >= -1e-9, "campaign beat the clairvoyant oracle — impossible"
    assert regret_fz > regret_ad, (
        f"frozen-estimator regret {regret_fz:.2f}% must exceed the online "
        f"calibrator's {regret_ad:.2f}% under a regime flip"
    )
    assert regret_ad <= REGRET_CEILING_PCT, (
        f"adaptive regret {regret_ad:.2f}% above the {REGRET_CEILING_PCT}% ceiling"
    )
    assert regret_fz > REGRET_CEILING_PCT, (
        f"frozen baseline regret {regret_fz:.2f}% should exceed the "
        f"{REGRET_CEILING_PCT}% ceiling — if the flip no longer hurts the "
        f"uncalibrated planner, the leg is not measuring anything"
    )

    # ---- leg 4: watermark barrier-wait reduction -------------------------
    stragglers = FaultPlan.generate(seed=seed + 300, num_rounds=rounds,
                                    n_clients=n_clients, p_crash=0.0,
                                    p_straggle=0.5)
    server_re, examples, rng, _ = build_campaign(seed, n_clients, max_batches)
    h_re = run_campaign(
        server_re, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        faults=stragglers,
    )
    server_wm, examples, rng, _ = build_campaign(
        seed, n_clients, max_batches,
        policy_kwargs=dict(watermark_quantile=0.5),
    )
    h_wm = run_campaign(
        server_wm, examples, rounds, round_T=T, batch_size=batch_size, rng=rng,
        faults=stragglers,
    )
    # stragglers are always early-detectable: the watermark path must land
    # on the SAME recovered schedules, earlier
    _assert_bit_identical(h_re, h_wm, "watermark vs reactive straggler recovery")
    wm_stats = h_wm.adaptive_stats

    return {
        "rounds": rounds,
        "n_clients": n_clients,
        "round_T": int(T),
        "lookahead": lookahead,
        # leg 1
        "serial_total_s": serial_s,
        "pipelined_total_s": pipelined_s,
        "chaos_drift_rounds_detected": h_serial.adaptive_stats["drift_rounds"],
        "chaos_speculation_hit_rate": h_serial.adaptive_stats["speculation_hit_rate"],
        # leg 2
        "stationary_solver_dispatches": int(dispatches),
        "stationary_hit_rate": st_stats["speculation_hit_rate"],
        "speculation_hit_rate": mild_stats["speculation_hit_rate"],
        "speculation_batches": mild_stats["speculation_batches"],
        "speculation_misses": mild_stats["speculation_misses"],
        # leg 3
        "regret_vs_oracle_pct": regret_ad,
        "frozen_regret_pct": regret_fz,
        "oracle_energy_J": oracle_J,
        "adaptive_energy_J": float(h_ad.total_energy),
        "frozen_energy_J": float(h_fz.total_energy),
        # leg 4
        "barrier_wait_saved_pct": wm_stats["barrier_wait_saved_pct_mean"],
        "barrier_wait_saved": wm_stats["barrier_wait_saved"],
        "early_replans": wm_stats["early_replans"],
    }


def run():
    """Harness entry point (benchmarks.run): a short drifting campaign."""
    r = run_bench(rounds=6, n_clients=6, max_batches=32, batch_size=4)
    return [
        (
            f"adaptive_drift_x{r['rounds']}",
            r["serial_total_s"] / r["rounds"] * 1e3,
            f"hit_rate={r['speculation_hit_rate']:.0%} "
            f"regret={r['regret_vs_oracle_pct']:.2f}% "
            f"frozen={r['frozen_regret_pct']:.2f}%",
        )
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast config for CI")
    ap.add_argument("--out", default="BENCH_adaptive.json")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()

    rounds = args.rounds or (6 if args.smoke else 12)
    n_clients = 6 if args.smoke else 10
    result = run_bench(rounds=rounds, n_clients=n_clients)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
