"""Roofline table reader: summarizes artifacts/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) into one row per (arch, shape, mesh)."""

import glob
import json
import os


def run():
    rows = []
    files = sorted(glob.glob(os.path.join("artifacts", "dryrun", "*.json")))
    if not files:
        return [("roofline_no_artifacts", 0.0, "run scripts/run_dryruns.sh first")]
    n_ok = n_skip = n_err = 0
    for f in files:
        with open(f) as fh:
            d = json.load(fh)
        key = f"roofline_{d['arch']}_{d['shape']}_{d['mesh']}"
        if d["status"] == "skipped":
            n_skip += 1
            rows.append((key, 0.0, f"skipped: {d['reason']}"))
        elif d["status"] == "error":
            n_err += 1
            rows.append((key, 0.0, f"ERROR: {d.get('error', '?')[:80]}"))
        else:
            n_ok += 1
            r = d["roofline"]
            rows.append(
                (
                    key,
                    d.get("compile_s", 0.0) * 1e6,
                    "compute=%.3es memory=%.3es coll=%.3es dominant=%s"
                    % (r["t_compute_s"], r["t_memory_s"], r["t_collective_s"], r["dominant"]),
                )
            )
    rows.append(("roofline_summary", 0.0, f"ok={n_ok} skipped={n_skip} error={n_err}"))
    return rows
