"""Sweep-engine benchmark: compile cache + device sharding (DESIGN.md §10).

Two questions, answered in one run and written to ``BENCH_sweep.json``:

  * **cold vs cached**: the first solve of a shape bucket pays XLA
    compilation; every later solve in the bucket (drifting costs, shifted
    workloads — the multi-round-campaign shape of traffic) reuses the warm
    executable. Headline ``speedup_cached_vs_cold`` (CI floor: >= 5x).
  * **sharded vs single-device**: the same warm solve with the batch axis
    sharded over all host devices (forced to ``--devices`` CPU devices via
    XLA_FLAGS, which must be set BEFORE jax initializes — hence the env
    fiddling at the top of main). Schedules are checked bit-identical.
    ``throughput_ratio`` > 1 means sharding won; on one physical CPU the
    forced host devices share cores, so this is a scaling smoke, not a
    speedup demo.

Run as::

    PYTHONPATH=src python benchmarks/bench_sweep.py [--smoke] [--out PATH]
"""

import argparse
import json
import os
import time


def drift(problems, factor):
    """Same shapes, perturbed cost values — the round-over-round estimate
    drift that must stay inside one compile-cache bucket."""
    from repro.core import Problem

    return [
        Problem(
            T=p.T,
            lower=p.lower,
            upper=p.upper,
            cost_tables=tuple(t * factor for t in p.cost_tables),
        )
        for p in problems
    ]


def time_best(fn, reps):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run_bench(B: int, n: int, T: int, reps: int = 3, sharded: bool = True) -> dict:
    import jax
    import numpy as np

    from repro.core import SweepEngine, make_sweep_mesh
    from repro.core.jax_dp import solve_schedule_dp_batch

    try:  # package import (python -m benchmarks.run) or script (python benchmarks/bench_sweep.py)
        from benchmarks.bench_batch import make_sweep
    except ImportError:
        from bench_batch import make_sweep

    rng = np.random.default_rng(0)
    problems = make_sweep(rng, B, n, T)

    # cold: fresh engine + cleared jit caches — the first-campaign experience
    jax.clear_caches()
    eng = SweepEngine()
    t0 = time.perf_counter()
    X_cold = eng.solve(problems)
    cold_s = time.perf_counter() - t0

    # cached: drifted instances land in the same bucket -> warm executable
    cached_s = time_best(lambda: eng.solve(drift(problems, 1.01)), reps)
    np.testing.assert_array_equal(X_cold, solve_schedule_dp_batch(problems))

    result = {
        "B": len(problems),
        "n": n,
        "T": T,
        "cold_solve_s": cold_s,
        "cached_solve_s": cached_s,
        "speedup_cached_vs_cold": cold_s / cached_s,
        "cache": eng.cache_stats(),
    }

    n_dev = len(jax.devices())
    if sharded and n_dev > 1:
        eng_sh = SweepEngine(mesh=make_sweep_mesh())
        X_sh = eng_sh.solve(problems)  # warm-up (compiles the sharded program)
        np.testing.assert_array_equal(X_sh, X_cold)  # sharded == single-device
        sharded_s = time_best(lambda: eng_sh.solve(drift(problems, 1.01)), reps)
        result.update(
            {
                "sharded_devices": n_dev,
                "sharded_solve_s": sharded_s,
                "throughput_ratio": cached_s / sharded_s,
            }
        )
    return result


def run():
    """Harness entry point (benchmarks.run): cache behaviour only — the
    harness process has already initialized jax, so device forcing is out."""
    r = run_bench(B=16, n=16, T=128, sharded=False)
    return [
        (
            f"sweep_cached_B{r['B']}_T{r['T']}",
            r["cached_solve_s"] / r["B"] * 1e6,
            f"speedup_cached_vs_cold={r['speedup_cached_vs_cold']:.1f}x",
        )
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast config for CI")
    ap.add_argument("--out", default="BENCH_sweep.json")
    ap.add_argument("--B", type=int, default=None)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--T", type=int, default=None)
    ap.add_argument(
        "--devices",
        type=int,
        default=8,
        help="forced host device count for the sharded leg (0 disables)",
    )
    args = ap.parse_args()

    # Must precede ANY jax import: the flag binds at first jax init.
    flags = os.environ.get("XLA_FLAGS", "")
    if args.devices > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} " + flags
        )

    B = args.B or (16 if args.smoke else 32)
    T = args.T or (96 if args.smoke else 256)
    result = run_bench(B=B, n=args.n, T=T, sharded=args.devices > 1)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
