"""Async round pipeline benchmark (DESIGN.md §11): does the background
planner actually hide scenario planning behind client training?

One run answers three questions and writes ``BENCH_async.json``:

  * **planner overlap fraction** (headline, CI floor >= 0.5 via
    scripts/check_bench.py): the share of planning time (schedule solves +
    what-if scenario batches) that the pipelined campaign kept OFF the round
    hot path — 1.0 means the main thread never waited on the planner.
  * **per-round wall-clock**, serial vs pipelined, and the campaign-level
    ``speedup_pipelined_vs_serial``. Reported, not gated: on a small CPU box
    the planner's XLA work competes with training for the same cores, so the
    wall-clock win is bounded by the non-training fraction of the round and
    swings with load.
  * **bit-identicality**: the pipelined campaign's schedules, losses, and
    energy accounting are asserted equal to the serial run (a crash here
    fails the CI smoke).

Run as::

    PYTHONPATH=src python benchmarks/bench_async.py [--smoke] [--out PATH]
"""

import argparse
import json
import time

VOCAB, DIM, SEQ = 256, 64, 16


def build_campaign(seed: int, n_clients: int, max_batches: int):
    """A fresh (server, examples, rng, T) tuple; same seed => same campaign,
    so serial and pipelined runs consume identical inputs."""
    import jax
    import numpy as np

    from repro.data import client_corpora, make_lm_examples
    from repro.fl import EnergyEstimator, FederatedServer, make_fleet
    from repro.fl.toy import make_tiny_lm
    from repro.optim import sgd

    tiny_lm_init, tiny_lm_loss = make_tiny_lm(VOCAB, DIM)
    rng = np.random.default_rng(seed)
    fleet = make_fleet(rng, n_clients, max_batches=max_batches)
    est = EnergyEstimator(fleet)
    est.calibrate(rng)
    corpora = client_corpora(rng, n_clients, 4000, VOCAB)
    examples = [make_lm_examples(c, SEQ) for c in corpora]
    T = sum(d.max_batches for d in fleet) // 2
    server = FederatedServer(
        loss_fn=tiny_lm_loss,
        init_params=tiny_lm_init(jax.random.PRNGKey(1)),
        client_optimizer=sgd(0.3),
        estimator=est,
        algorithm="auto",
        scenario_T_candidates=[int(0.6 * T), int(0.8 * T), T, int(1.2 * T)],
        scenario_dropouts=[[0], [1], [2], [3]],
    )
    return server, examples, rng, T


def run_bench(rounds: int, n_clients: int = 12, max_batches: int = 48, batch_size: int = 8) -> dict:
    import numpy as np

    from repro.fl import AsyncCampaignRunner, run_campaign

    # Warm-up campaign: warms the shared default engine's scenario-shape
    # bucket (one XLA compile) so the timed runs measure steady-state
    # planning, not first-contact compilation. Each timed server still pays
    # its own round-program compile in round 0 — identically in both modes.
    server, examples, rng, T = build_campaign(0, n_clients, max_batches)
    run_campaign(server, examples, 2, round_T=T, batch_size=batch_size, rng=rng)

    server, examples, rng, T = build_campaign(0, n_clients, max_batches)
    t0 = time.perf_counter()
    h_serial = run_campaign(
        server, examples, rounds, round_T=T, batch_size=batch_size, rng=rng
    )
    serial_s = time.perf_counter() - t0

    server, examples, rng, T = build_campaign(0, n_clients, max_batches)
    t0 = time.perf_counter()
    h_pipe = AsyncCampaignRunner(server).run(
        examples, rounds, T, batch_size, rng
    )
    pipelined_s = time.perf_counter() - t0

    # pipelining must never change the results (DESIGN.md §11)
    np.testing.assert_array_equal(h_serial.losses, h_pipe.losses)
    assert h_serial.total_energy == h_pipe.total_energy
    for a, b in zip(h_serial.rounds, h_pipe.rounds):
        np.testing.assert_array_equal(a.assignments, b.assignments)
        np.testing.assert_array_equal(a.scenarios.assignments, b.scenarios.assignments)
        np.testing.assert_array_equal(a.scenarios.energies, b.scenarios.energies)

    ps, pp = h_serial.pipeline_stats, h_pipe.pipeline_stats
    return {
        "rounds": rounds,
        "n_clients": n_clients,
        "round_T": T,
        "scenarios_per_round": len(h_pipe.rounds[0].scenarios.labels),
        "serial_campaign_s": serial_s,
        "pipelined_campaign_s": pipelined_s,
        "speedup_pipelined_vs_serial": serial_s / pipelined_s,
        "planner_overlap_fraction": pp.overlap_fraction,
        "round_wall_mean_serial_s": float(np.mean(ps.round_wall_s)),
        "round_wall_mean_pipelined_s": float(np.mean(pp.round_wall_s)),
        "serial_pipeline": ps.as_dict(),
        "pipelined_pipeline": pp.as_dict(),
        "dp_cache": h_pipe.dp_cache_stats,
    }


def run():
    """Harness entry point (benchmarks.run): small config, headline row."""
    r = run_bench(rounds=4, n_clients=8, max_batches=32)
    return [
        (
            f"async_pipeline_R{r['rounds']}_n{r['n_clients']}",
            r["pipelined_campaign_s"] / r["rounds"] * 1e6,
            f"overlap={r['planner_overlap_fraction']:.2f} "
            f"speedup={r['speedup_pipelined_vs_serial']:.2f}x",
        )
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast config for CI")
    ap.add_argument("--out", default="BENCH_async.json")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--clients", type=int, default=12)
    args = ap.parse_args()

    rounds = args.rounds or (4 if args.smoke else 6)
    result = run_bench(rounds=rounds, n_clients=args.clients)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(json.dumps(result, indent=2))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
